"""Checkpoint manager: atomic step directories, async writer, cross-mesh
resharding restore (elastic restart).

Layout:  <dir>/step_<N>/MANIFEST.json + one .npy per pytree leaf (path-keyed,
"/"-joined).  Writes go to step_<N>.tmp and rename atomically, so a killed
writer never leaves a half checkpoint; ``latest_step`` only trusts renamed
dirs.  Restore materializes leaves host-side and device_puts them under the
CURRENT mesh's NamedShardings — the saved mesh shape is irrelevant, which is
what makes failover to a different slice count work.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: Optional[dict] = None):
        """Snapshot to host memory NOW; write (possibly async) afterwards."""
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        treedef = jax.tree.structure(tree)
        manifest = {
            "step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "treedef": str(treedef),
            "extra": extra or {},
        }
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, manifest), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, manifest)

    def _write(self, step: int, flat, manifest):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for k, v in flat.items():
            np.save(os.path.join(tmp, k.replace("/", "__") + ".npy"), v)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "MANIFEST.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """``like``: pytree matching the saved structure (shapes may be
        abstract).  ``shardings``: optional matching pytree of NamedShardings
        for the CURRENT mesh — cross-mesh restore path."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten(like)
        loaded = {}
        for k in flat_like:
            fn = os.path.join(d, k.replace("/", "__") + ".npy")
            loaded[k] = np.load(fn)
        leaves_order = [loaded[k] for k in _flatten(like)]
        tree = jax.tree.unflatten(jax.tree.structure(like), leaves_order)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest
