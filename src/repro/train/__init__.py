from repro.train.optim import (
    OptConfig, OptState, apply_updates, for_model, init_opt_state,
    opt_state_specs,
)
from repro.train.step import (
    init_error_feedback, jit_train_step, make_train_step,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, batch_at_step, stream

__all__ = [
    "OptConfig", "OptState", "apply_updates", "for_model", "init_opt_state",
    "opt_state_specs", "init_error_feedback", "jit_train_step",
    "make_train_step", "CheckpointManager", "DataConfig", "batch_at_step",
    "stream",
]
