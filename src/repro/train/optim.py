"""Optimizers (pure JAX): AdamW and Lion.

States inherit the parameter PartitionSpecs leaf-for-leaf (ZeRO: optimizer
state lives wherever the param shard lives — never gathered).  Lion keeps a
single momentum (2 bytes/param in bf16): the config for the 1T-param MoE
selects it so total state stays inside the 512-chip HBM budget.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | lion
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    momentum_dtype: Any = jnp.float32


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any                        # None-like (zeros scalar tree) for lion


def init_opt_state(cfg: OptConfig, params) -> OptState:
    m = jax.tree.map(
        lambda p: jnp.zeros(p.shape, cfg.momentum_dtype), params)
    if cfg.name == "adamw":
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    else:
        v = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), m, v)


def opt_state_specs(cfg: OptConfig, param_specs):
    from jax.sharding import PartitionSpec as P
    if cfg.name == "adamw":
        v_specs = param_specs
    else:
        v_specs = jax.tree.map(lambda s: P(), param_specs)
    return OptState(P(), param_specs, v_specs)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def apply_updates(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, grad_norm)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1

    if cfg.name == "adamw":
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
            v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - cfg.lr * delta
            return (p2.astype(p.dtype), m2.astype(m.dtype), v2)

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_m, new_v), gn

    if cfg.name == "lion":
        def upd(p, g, m):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32)
            u = jnp.sign(cfg.b1 * m32 + (1 - cfg.b1) * g32)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - cfg.lr * u
            m2 = cfg.b2 * m32 + (1 - cfg.b2) * g32
            return (p2.astype(p.dtype), m2.astype(m.dtype))

        out = jax.tree.map(upd, params, grads, state.m)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_m, state.v), gn

    raise ValueError(cfg.name)


def for_model(model_cfg) -> OptConfig:
    return OptConfig(name=getattr(model_cfg, "optimizer", "adamw"),
                     momentum_dtype=(jnp.bfloat16
                                     if model_cfg.optimizer == "lion"
                                     else jnp.float32))
