"""Train-step builder: microbatched gradient accumulation, optional int8
gradient compression with error feedback, donated buffers.

``make_train_step(cfg, dist, opt_cfg)`` returns a function

    (params, opt_state, ef, batch) -> (params', opt_state', ef', metrics)

suitable for jax.jit with donate_argnums=(0, 1, 2).  Microbatching splits
the batch on the leading axis and accumulates grads in fp32 via lax.scan —
activation memory is 1/M of the monolithic step, the standard knob that
makes the 32k-token-per-device train shapes fit HBM.

Gradient compression: grads are quantized to int8 (per-leaf absmax scale)
with an error-feedback residual carried across steps — the numerics of a
compressed cross-pod all-reduce; the wire format itself is XLA's concern
(noted in DESIGN.md §7).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import models as zoo
from repro.models.common import LMConfig
from repro.models.transformer import Dist
from repro.train import optim


def _quantize_int8(g, ef):
    """Error-feedback int8 quantization: returns (dequantized, new_ef)."""
    g32 = g.astype(jnp.float32) + ef
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127)
    deq = q * scale
    return deq.astype(g.dtype), (g32 - deq)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_train_step(
    cfg: LMConfig,
    dist: Dist,
    opt_cfg: Optional[optim.OptConfig] = None,
    microbatches: int = 1,
    compress_grads: bool = False,
    loss_fn: Optional[Callable] = None,
):
    opt_cfg = opt_cfg or optim.for_model(cfg)
    loss_fn = loss_fn or (lambda p, b: zoo.loss_fn(cfg, p, b, dist))

    def grads_of(params, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        def split(x):
            out = x.reshape((microbatches, x.shape[0] // microbatches)
                            + x.shape[1:])
            # Re-state the layout after splitting the sharded batch dim —
            # without this the SPMD partitioner mis-slices scan residuals.
            if dist.mesh is not None and dist.batch is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                spec = P(None, dist.batch, *([None] * (out.ndim - 2)))
                out = jax.lax.with_sharding_constraint(
                    out, NamedSharding(dist.mesh, spec))
            return out
        mb = jax.tree.map(split, batch)

        # Lion's sign-based update tolerates bf16 accumulation — at 1T
        # params the fp32 accumulator alone is 16 GB/device.
        acc_dtype = (jnp.bfloat16 if opt_cfg.name == "lion"
                     else jnp.float32)

        def one(carry, mbatch):
            acc, loss_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
            acc = jax.tree.map(
                lambda a, g: (a.astype(jnp.float32)
                              + g.astype(jnp.float32) / microbatches
                              ).astype(acc_dtype),
                acc, grads)
            return (acc, loss_acc + loss / microbatches), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (grads, loss), _ = jax.lax.scan(one, (zeros, 0.0), mb)
        return loss, grads

    def step(params, opt_state, ef, batch):
        """``ef`` is the error-feedback tree when compressing, else None."""
        loss, grads = grads_of(params, batch)
        if compress_grads:
            out = jax.tree.map(_quantize_int8, grads, ef)
            grads = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            ef = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        params, opt_state, gn = optim.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, "grad_norm": gn, "step": opt_state.step}
        return params, opt_state, ef, metrics

    return step


def jit_train_step(cfg, dist, param_spec_tree, opt_cfg=None, microbatches=1,
                   compress_grads=False, batch_specs=None, loss_fn=None):
    """Fully-specified pjit wrapper used by launch/train.py and the dry-run."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    opt_cfg = opt_cfg or optim.for_model(cfg)
    step = make_train_step(cfg, dist, opt_cfg, microbatches, compress_grads,
                           loss_fn=loss_fn)
    mesh = dist.mesh
    ns = lambda spec: NamedSharding(mesh, spec)
    p_shard = jax.tree.map(ns, param_spec_tree)
    o_shard = jax.tree.map(ns, optim.opt_state_specs(opt_cfg, param_spec_tree))
    b_shard = jax.tree.map(ns, batch_specs) if batch_specs is not None else None
    in_shardings = (p_shard, o_shard, p_shard, b_shard)
    out_shardings = (p_shard, o_shard, p_shard,
                     {"loss": ns(P()), "grad_norm": ns(P()), "step": ns(P())})
    return jax.jit(step, in_shardings=in_shardings,
                   out_shardings=out_shardings, donate_argnums=(0, 1, 2))
