"""Deterministic synthetic data pipeline.

Per-step batches are derived from (seed, step) only — any host can produce
its own shard without coordination, and restart-at-step-N replays the exact
stream (the property checkpoint/restart correctness tests rely on).  The
token stream mimics packed documents: zipf-ish unigram draw + EOS resets,
labels = next token with EOS boundaries masked.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.common import LMConfig, ShapeCfg

EOS = 0


@dataclasses.dataclass
class DataConfig:
    seed: int = 0
    mean_doc_len: int = 512
    zipf_a: float = 1.2


def batch_at_step(cfg: LMConfig, shape: ShapeCfg, step: int,
                  data_cfg: DataConfig = DataConfig(),
                  host_slice: Optional[slice] = None) -> Dict[str, np.ndarray]:
    """Materialize the global (or per-host slice of the) batch for ``step``."""
    B, L = shape.global_batch, shape.seq_len
    rows = range(B)[host_slice] if host_slice is not None else range(B)
    # Per-ROW seeding so any host materializes exactly its slice of the
    # global batch (coordination-free sharded loading).
    tokens = np.empty((len(rows), L), np.int32)
    labels = np.empty((len(rows), L), np.int32)
    for k, r in enumerate(rows):
        rng = np.random.default_rng(
            np.random.SeedSequence([data_cfg.seed, step, r]))
        toks = rng.zipf(data_cfg.zipf_a, size=L + 1)
        toks = np.clip(toks, 1, cfg.vocab - 1).astype(np.int32)
        eos = rng.random(L + 1) < 1.0 / data_cfg.mean_doc_len
        toks[eos] = EOS
        tokens[k] = toks[:L]
        lab = toks[1:L + 1].astype(np.int32)
        labels[k] = np.where(tokens[k] == EOS, -100, lab)
    out = {"tokens": tokens, "labels": labels}
    rng = np.random.default_rng(np.random.SeedSequence(
        [data_cfg.seed, step, 1 << 20]))
    if cfg.family == "encdec":
        F = min(max(cfg.frontend_len, L // 4), 4096)
        out["frames"] = rng.standard_normal(
            (len(rows), F, cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "vlm":
        out["patches"] = rng.standard_normal(
            (len(rows), cfg.frontend_len, cfg.frontend_dim)).astype(np.float32)
    return out


def stream(cfg: LMConfig, shape: ShapeCfg, start_step: int = 0,
           data_cfg: DataConfig = DataConfig()) -> Iterator[Dict]:
    step = start_step
    while True:
        yield batch_at_step(cfg, shape, step, data_cfg)
        step += 1
