"""kimi-k2-1t-a32b [moe] — 61L d7168 64H (GQA kv=8) v163840; trillion-param
MoE: 384 routed experts top-8 (expert dff=2048) + 1 shared; first layer
dense (dff=18432).  Optimizer = lion (momentum-only): the second-moment-free
update is what keeps 1T of state inside a 512-chip HBM budget (DESIGN.md §5).
[arXiv:2501.kimi2; unverified — paper-table config]"""
import jax.numpy as jnp

from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=18_432, vocab=163_840, rope_theta=1_000_000.0,
    n_experts=384, n_shared_experts=1, top_k=8, expert_d_ff=2048,
    first_dense_layers=1, capacity_factor=1.5,
    # §Perf iteration K3: at 1T params, fp32 masters are 16 GB/device on a
    # single pod before anything else loads.  bf16 params + Lion's single
    # bf16 momentum is the only state budget that fits 512 chips.
    optimizer="lion", param_dtype=jnp.bfloat16,
    # §Perf iteration K4: ZeRO over the pod axis halves per-device state;
    # finer grad accumulation halves live activations.
    fsdp_over_pod=True, train_microbatches=8,
)

SMOKE = LMConfig(
    name="kimi-k2-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=256, vocab=512, remat=False,
    n_experts=16, n_shared_experts=1, top_k=4, expert_d_ff=16,
    first_dense_layers=1, optimizer="lion",
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §4)"}
