"""phi3-mini-3.8b [dense] — 32L d3072 32H (GQA kv=32 = MHA) dff8192 v32064,
RoPE SwiGLU. [arXiv:2404.14219; unverified]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32_064, rope_theta=10_000.0,
)

SMOKE = LMConfig(
    name="phi3-mini-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, remat=False,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §4)"}
