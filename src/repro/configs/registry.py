"""Architecture registry: ``--arch <id>`` resolution, shape applicability,
and ShapeDtypeStruct input stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import LMConfig, SHAPES, ShapeCfg

ARCHS = {
    "llama3.2-1b": "llama3_2_1b",
    "qwen2.5-32b": "qwen2_5_32b",
    "yi-9b": "yi_9b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "zamba2-1.2b": "zamba2_1_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-2b": "internvl2_2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> LMConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> LMConfig:
    return _module(arch).SMOKE


def skip_reason(arch: str, shape: str) -> Optional[str]:
    return getattr(_module(arch), "SKIP_SHAPES", {}).get(shape)


def applicable_shapes(arch: str):
    return [s for s in SHAPES if skip_reason(arch, s) is None]


def all_cells():
    """Every (arch, shape) baseline cell, with skips resolved (40 total,
    minus documented long_500k skips)."""
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape, skip_reason(arch, shape)


def frames_len(cfg: LMConfig, shape: ShapeCfg) -> int:
    """Audio-frontend stub length: frames scale with the text length but are
    capped (a 30 s utterance ~ 1500 frames)."""
    return min(max(cfg.frontend_len, shape.seq_len // 4), 4096)


def input_specs(cfg: LMConfig, shape: ShapeCfg) -> Dict:
    """ShapeDtypeStruct stand-ins for one step's inputs (dry-run contract).

    train/prefill: the full batch.  decode: one new token + the KV/state
    cache at seq_len occupancy (built abstractly via eval_shape).
    """
    B = shape.global_batch
    L = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, L), i32),
            "labels": jax.ShapeDtypeStruct((B, L), i32),
        }
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, frames_len(cfg, shape), cfg.frontend_dim), cfg.dtype)
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), cfg.dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, L), i32)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, frames_len(cfg, shape), cfg.frontend_dim), cfg.dtype)
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), cfg.dtype)
        return specs
    # decode: one token against a cache filled to seq_len.
    from repro import models as zoo
    cache = jax.eval_shape(lambda: zoo.init_cache(cfg, B, L))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "cache": cache,
    }
