"""zamba2-1.2b [hybrid] — 38L d2048, Mamba2 backbone + ONE shared attention
block (32H, GQA kv=32, dff8192) applied every 6 layers; ssm_state=64, v32000.
[arXiv:2411.15242; hf]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32_000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, attn_every=6,
)

SMOKE = LMConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, remat=False,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=16, attn_every=2,
)

SKIP_SHAPES = {}          # hybrid: sub-quadratic decode -> long_500k runs
