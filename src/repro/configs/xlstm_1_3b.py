"""xlstm-1.3b [ssm] — 48 blocks d2048 4H v50304; mLSTM backbone with one
sLSTM block every 8 (xLSTM[7:1]); d_ff=0 (block-internal projections).
[arXiv:2405.04517; unverified]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50_304, ssm_expand=2, slstm_every=8,
)

SMOKE = LMConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512, remat=False, ssm_expand=2, slstm_every=3,
)

SKIP_SHAPES = {}          # recurrent decode -> long_500k runs
