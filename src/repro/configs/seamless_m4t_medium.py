"""seamless-m4t-medium [audio enc-dec] — 12L enc + 12L dec, d1024 16H
(kv=16) dff4096 v256206.  Modality frontend is a STUB: input_specs provides
precomputed frame embeddings. [arXiv:2308.11596; hf]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256_206, rope_theta=10_000.0,
    frontend_dim=1024, frontend_len=1024,
)

SMOKE = LMConfig(
    name="seamless-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, remat=False, frontend_dim=32, frontend_len=12,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §4)"}
