"""yi-9b [dense] — 48L d4096 32H (GQA kv=4) dff11008 v64000, llama-arch.
[arXiv:2403.04652; hf]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11_008, vocab=64_000, rope_theta=500_000.0,
)

SMOKE = LMConfig(
    name="yi-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=192, vocab=512, remat=False,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §4)"}
