"""internvl2-2b [vlm] — InternLM2 backbone: 24L d2048 16H (GQA kv=8) dff8192
v92553; InternViT frontend is a STUB supplying patch embeddings.
[arXiv:2404.16821; hf]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92_553, rope_theta=1_000_000.0,
    frontend_dim=1024, frontend_len=256,
)

SMOKE = LMConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, remat=False, frontend_dim=32, frontend_len=8,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §4)"}
