"""The paper's OWN workload: 2-layer GCN/GAT/GraphSAGE over SIoT/Yelp
(Sec. VI-A — input dims 52/100, hidden 16, binary output)."""
from repro.gnn.models import GNNConfig

SIOT_GCN = GNNConfig("gcn", (52, 16, 2))
SIOT_GAT = GNNConfig("gat", (52, 16, 2))
SIOT_SAGE = GNNConfig("sage", (52, 16, 2))
YELP_GCN = GNNConfig("gcn", (100, 16, 2))
YELP_GAT = GNNConfig("gat", (100, 16, 2))
YELP_SAGE = GNNConfig("sage", (100, 16, 2))

ALL = {
    ("siot", "gcn"): SIOT_GCN, ("siot", "gat"): SIOT_GAT,
    ("siot", "sage"): SIOT_SAGE,
    ("yelp", "gcn"): YELP_GCN, ("yelp", "gat"): YELP_GAT,
    ("yelp", "sage"): YELP_SAGE,
}
