"""qwen2.5-32b [dense] — 64L d5120 40H (GQA kv=8) dff27648 v152064, QKV bias.
[hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27_648, vocab=152_064, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = LMConfig(
    name="qwen2.5-32b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=256, vocab=512, qkv_bias=True, remat=False,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §4)"}
