"""llama3.2-1b [dense] — 16L d2048 32H (GQA kv=8) dff8192 v128256.
[hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=128_256, rope_theta=500_000.0, tie_embeddings=True,
    # §Perf iteration 3: a 1.2B model's activations fit HBM at 4k tokens —
    # remat only adds a recompute pass (FLOPs +33%, bytes +~20%).  Finer
    # grad accumulation (16 microbatches) keeps one microbatch's live
    # activations under the HBM budget without remat.
    remat=False, train_microbatches=16,
)

SMOKE = LMConfig(
    name="llama3.2-1b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, tie_embeddings=True, remat=False,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch: O(L^2) softmax over "
                            "512k KV is out of scope (DESIGN.md §4)"}
