from repro.configs.registry import (
    ARCHS, all_cells, applicable_shapes, get_config, get_smoke_config,
    input_specs, skip_reason,
)

__all__ = [
    "ARCHS", "all_cells", "applicable_shapes", "get_config",
    "get_smoke_config", "input_specs", "skip_reason",
]
