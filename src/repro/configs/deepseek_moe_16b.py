"""deepseek-moe-16b [moe] — 28L d2048 16H (GQA kv=16) v102400; fine-grained
MoE: 64 routed experts top-6 (expert dff=1408) + 2 shared experts; first
layer is a dense FFN (dff=10944). [arXiv:2401.06066; hf]"""
from repro.models.common import LMConfig

CONFIG = LMConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10_944, vocab=102_400, rope_theta=10_000.0,
    n_experts=64, n_shared_experts=2, top_k=6, expert_d_ff=1408,
    first_dense_layers=1,
)

SMOKE = LMConfig(
    name="deepseek-moe-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=192, vocab=512, remat=False,
    n_experts=8, n_shared_experts=2, top_k=2, expert_d_ff=32,
    first_dense_layers=1,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md §4)"}
