"""xLSTM stack (arXiv:2405.04517): mLSTM blocks (parallel, chunkwise) with
interleaved sLSTM blocks (sequential scan), for the xlstm-1.3b arch.

mLSTM — matrix-memory LSTM.  Recurrence per head
    C_t = f_t C_{t-1} + i_t (k_t (x) v_t),   n_t = f_t n_{t-1} + i_t k_t,
    y_t = (q_t . C_t) / max(|q_t . n_t|, 1)
is the SSD recurrence with B<-k, xbar<-i*v, C<-q, loga<-log f, so training
reuses the chunkwise SSD machinery from models/ssm.py (exact — chunking does
not approximate).  Input gate i = exp(clamp(itilde)) computed in fp32; the
running-max stabilizer of the reference implementation is replaced by this
clamp (noted in DESIGN.md §7 — identical numerics at the sequence lengths we
train, cheaper on TPU).

sLSTM — scalar-memory LSTM with block-diagonal recurrence, exponential gating
and the (m_t) stabilizer, executed as a lax.scan over time.  O(1)-state
decode makes long_500k runnable for this family.

Per the 1.3B config: d_ff = 0 (no FFN; the block's own up/down projections
carry the nonlinearity), 4 heads.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import (LMConfig, dense_init, rms_norm,
    scan_layers, sharded_ce_loss)
from repro.models.transformer import Dist, _embed, _unembed, vocab_padded

ICLAMP = 8.0


# ------------------------------------------------------------------- mLSTM
def _hdims(cfg: LMConfig):
    din = cfg.ssm_expand * cfg.d_model if cfg.ssm_expand else 2 * cfg.d_model
    H = cfg.n_heads
    P = din // H
    return din, H, P


def mlstm_forward(cfg: LMConfig, p, x, dist: Dist, state=None):
    """x (B, L, d) -> (out, (C, n)) — C (B,H,P,P) matrix memory, n (B,H,P)."""
    Bz, L, d = x.shape
    din, H, P = _hdims(cfg)
    h = rms_norm(x, p["norm"].astype(x.dtype), cfg.norm_eps)
    up = h @ p["up"].astype(h.dtype)
    up = dist.wsc(up, dist.batch, None, dist.model_axis)
    xm, z = jnp.split(up, 2, axis=-1)
    q = (xm @ p["wq"].astype(h.dtype)).reshape(Bz, L, H, P) * (P ** -0.5)
    k = (xm @ p["wk"].astype(h.dtype)).reshape(Bz, L, H, P) * (P ** -0.5)
    v = (xm @ p["wv"].astype(h.dtype)).reshape(Bz, L, H, P)
    gif = (xm @ p["w_if"].astype(h.dtype)).astype(jnp.float32)
    it, ft = jnp.split(gif.reshape(Bz, L, H, 2), 2, axis=-1)
    logf = jax.nn.log_sigmoid(ft[..., 0])                    # (B,L,H)
    i = jnp.exp(jnp.minimum(it[..., 0], ICLAMP))             # (B,L,H)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = q.astype(jnp.float32)
    if state is not None and L == 1:
        C0, n0 = state
        f1 = jnp.exp(logf[:, 0])                              # (B,H)
        Cn = (C0 * f1[:, :, None, None]
              + i[:, 0][:, :, None, None] * kf[:, 0][..., :, None]
              * vf[:, 0][..., None, :])                       # (B,H,P,P)
        nn = n0 * f1[:, :, None] + i[:, 0][:, :, None] * kf[:, 0]
        num = jnp.einsum("bhp,bhpq->bhq", qf[:, 0], Cn)
        den = jnp.abs(jnp.einsum("bhp,bhp->bh", qf[:, 0], nn))
        y = (num / jnp.maximum(den, 1.0)[..., None])[:, None]  # (B,1,H,P)
        Sn, nn_out = Cn, nn
    else:
        # Chunkwise: S carries (B,H,N=P,P); n via a width-1 value channel.
        xbar = vf * i[..., None]
        y_num, Sn = _ssd_chunked_heads(xbar, logf, kf, qf,
                                       state0=state[0] if state else None)
        ones = i[..., None]                                   # (B,L,H,1)
        n_y, nn_out = _ssd_chunked_heads(
            ones, logf, kf, qf,
            state0=state[1][..., None] if state else None)
        nn_out = nn_out[..., 0]
        den = jnp.abs(n_y[..., 0])
        y = y_num / jnp.maximum(den, 1.0)[..., None]

    y = y.reshape(Bz, L, din).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = dist.wsc(y, dist.batch, None, dist.model_axis)
    return x + y @ p["down"].astype(x.dtype), (Sn, nn_out)


def _ssd_chunked_heads(xbar, loga, keys, queries, state0=None, chunk=128):
    """SSD scan with PER-HEAD B/C (keys/queries (B,L,H,N)) — the mLSTM case.

    xbar (B,L,H,P).  Returns (y (B,L,H,P), S (B,H,N,P))."""
    Bsz, L, H, Pd = xbar.shape
    N = keys.shape[-1]
    pad = (-L) % chunk
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        xbar = jnp.pad(xbar, z4)
        keys = jnp.pad(keys, z4)
        queries = jnp.pad(queries, z4)
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
    C_ = xbar.shape[1] // chunk
    xb = xbar.reshape(Bsz, C_, chunk, H, Pd)
    la = loga.reshape(Bsz, C_, chunk, H)
    Kc = keys.reshape(Bsz, C_, chunk, H, N)
    Qc = queries.reshape(Bsz, C_, chunk, H, N)

    cum = jnp.cumsum(la, axis=2)
    total = cum[:, :, -1]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    dec = jnp.exp(seg)                                        # (B,C,Q,S,H)
    qk = jnp.einsum("bcqhn,bcshn->bcqsh", Qc, Kc)
    y_intra = jnp.einsum("bcqsh,bcqsh,bcshp->bcqhp", qk, dec, xb)
    w = jnp.exp(total[:, :, None, :] - cum)
    S_loc = jnp.einsum("bcshn,bcsh,bcshp->bchnp", Kc, w, xb)

    def scan_fn(S_prev, inp):
        S_l, tot = inp
        S_new = S_prev * jnp.exp(tot)[:, :, None, None] + S_l
        return S_new, S_prev

    S0 = (jnp.zeros((Bsz, H, N, Pd), xbar.dtype) if state0 is None else state0)
    S_fin, S_prevs = jax.lax.scan(
        scan_fn, S0, (jnp.moveaxis(S_loc, 1, 0), jnp.moveaxis(total, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)
    y_inter = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp", Qc, jnp.exp(cum), S_prevs)
    y = (y_intra + y_inter).reshape(Bsz, C_ * chunk, H, Pd)
    return y[:, :L], S_fin


# ------------------------------------------------------------------- sLSTM
def slstm_shapes(cfg: LMConfig):
    d = cfg.d_model
    H = cfg.n_heads
    P = d // H
    return {
        "norm": (d,),
        "w_in": (d, 4 * d),               # z, i, f, o pre-activations
        "r": (H, P, 4 * P),               # block-diagonal recurrent weights
        "bias": (4 * d,),
        "out": (d, d),
    }


def slstm_forward(cfg: LMConfig, p, x, dist: Dist, state=None):
    """x (B, L, d) -> (out, (h, c, n, m)) with exponential-gate stabilizer."""
    Bz, L, d = x.shape
    H = cfg.n_heads
    P = d // H
    xin = rms_norm(x, p["norm"].astype(x.dtype), cfg.norm_eps)
    pre = (xin @ p["w_in"].astype(x.dtype)
           + p["bias"].astype(x.dtype)).astype(jnp.float32)   # (B,L,4d)
    pre = pre.reshape(Bz, L, H, 4 * P)

    if state is None:
        h0 = jnp.zeros((Bz, H, P), jnp.float32)
        c0 = jnp.zeros((Bz, H, P), jnp.float32)
        n0 = jnp.ones((Bz, H, P), jnp.float32)
        m0 = jnp.zeros((Bz, H, P), jnp.float32)
    else:
        h0, c0, n0, m0 = state
    r = p["r"].astype(jnp.float32)

    def step(carry, pre_t):
        h, c, n, m = carry                                    # (B,H,P)
        rec = jnp.einsum("bhp,hpq->bhq", h, r)                # (B,H,4P)
        g = pre_t + rec
        z_, i_, f_, o_ = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(z_)
        o = jax.nn.sigmoid(o_)
        logf = jax.nn.log_sigmoid(f_)
        m_new = jnp.maximum(logf + m, i_)
        ig = jnp.exp(i_ - m_new)
        fg = jnp.exp(logf + m - m_new)
        c_new = fg * c + ig * z
        n_new = fg * n + ig
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    (h, c, n, m), ys = jax.lax.scan(step, (h0, c0, n0, m0),
                                    jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bz, L, d).astype(x.dtype)
    return x + y @ p["out"].astype(x.dtype), (h, c, n, m)


# -------------------------------------------------------------------- stack
def _layer_kinds(cfg: LMConfig):
    if not cfg.slstm_every:
        return ["m"] * cfg.n_layers
    return ["s" if (i + 1) % cfg.slstm_every == 0 else "m"
            for i in range(cfg.n_layers)]


def init_params(cfg: LMConfig, key: jax.Array) -> Dict:
    vp = vocab_padded(cfg)
    pdt = cfg.param_dtype
    kinds = _layer_kinds(cfg)
    nm, ns = kinds.count("m"), kinds.count("s")

    def init_stack(key, shapes, n):
        out = {}
        for name, shp in shapes.items():
            key, sub = jax.random.split(key)
            if name == "norm":
                out[name] = jnp.ones((n,) + shp, pdt)
            elif name == "bias":
                out[name] = jnp.zeros((n,) + shp, pdt)
            else:
                out[name] = (jax.random.normal(sub, (n,) + shp)
                             * shp[-2] ** -0.5).astype(pdt)
        return out

    key, ke, ku, k1, k2 = jax.random.split(key, 5)
    params = {
        "embed": dense_init(ke, (vp, cfg.d_model), pdt, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
        "mlstm": init_stack(k1, _mlstm_shapes_fixed(cfg), nm),
    }
    if ns:
        params["slstm"] = init_stack(k2, slstm_shapes(cfg), ns)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ku, (cfg.d_model, vp), pdt, scale=0.02)
    return params


def _mlstm_shapes_fixed(cfg: LMConfig):
    d = cfg.d_model
    din, H, P = _hdims(cfg)
    return {
        "norm": (d,),
        "up": (d, 2 * din),
        "wq": (din, din), "wk": (din, din), "wv": (din, din),
        "w_if": (din, 2 * H),
        "down": (din, d),
    }


def param_specs(cfg: LMConfig, dist: Dist) -> Dict:
    from jax.sharding import PartitionSpec as P
    m, da = dist.model_axis, dist.data_axis
    kinds = _layer_kinds(cfg)
    specs = {
        "embed": P(None, m), "final_norm": P(None),
        "mlstm": {
            "norm": P(None, None), "up": P(None, da, m),
            "wq": P(None, da, m), "wk": P(None, da, m), "wv": P(None, da, m),
            "w_if": P(None, da, None), "down": P(None, m, da),
        },
    }
    if "s" in kinds:
        specs["slstm"] = {
            "norm": P(None, None), "w_in": P(None, da, m),
            "r": P(None, None, None, None), "bias": P(None, m),
            "out": P(None, da, m),
        }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(da, m)
    return specs


def _segments(cfg: LMConfig):
    """Contiguous same-kind runs: [(kind, start_in_its_stack, count), ...]."""
    kinds = _layer_kinds(cfg)
    segs = []
    offsets = {"m": 0, "s": 0}
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        segs.append((kinds[i], offsets[kinds[i]], j - i))
        offsets[kinds[i]] += j - i
        i = j
    return segs


def forward(cfg: LMConfig, params, batch: Dict, dist: Dist = Dist()):
    x = _embed(cfg, params, batch["tokens"], dist)

    for kind, off, cnt in _segments(cfg):
        stack = params["mlstm" if kind == "m" else "slstm"]
        sl = jax.tree.map(lambda t: t[off:off + cnt], stack)
        fwd = mlstm_forward if kind == "m" else slstm_forward

        def body(x, p, fwd=fwd):
            out, _ = fwd(cfg, p, x, dist)
            return out, None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = scan_layers(cfg.analysis_unroll, body, x, sl, cnt)

    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    return _unembed(cfg, params, x, dist), 0.0


def loss_fn(cfg: LMConfig, params, batch: Dict, dist: Dist = Dist(), **_):
    logits, _ = forward(cfg, params, batch, dist)
    return sharded_ce_loss(logits, batch["labels"])


# ------------------------------------------------------------------ serving
def init_cache(cfg: LMConfig, batch: int, max_len: int):
    din, H, P = _hdims(cfg)
    kinds = _layer_kinds(cfg)
    nm, ns = kinds.count("m"), kinds.count("s")
    d = cfg.d_model
    Ph = d // cfg.n_heads
    cache = {
        "mC": jnp.zeros((nm, batch, H, P, P), jnp.float32),
        "mn": jnp.zeros((nm, batch, H, P), jnp.float32),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if ns:
        cache.update({
            "sh": jnp.zeros((ns, batch, cfg.n_heads, Ph), jnp.float32),
            "sc": jnp.zeros((ns, batch, cfg.n_heads, Ph), jnp.float32),
            "sn": jnp.ones((ns, batch, cfg.n_heads, Ph), jnp.float32),
            "sm": jnp.zeros((ns, batch, cfg.n_heads, Ph), jnp.float32),
        })
    return cache


def _run_segments(cfg, params, x, dist, cache):
    """Shared segment walker for prefill/decode (state-threading)."""
    new = dict(cache)
    mC, mn = [], []
    sh, sc, sn, sm = [], [], [], []
    for kind, off, cnt in _segments(cfg):
        stack = params["mlstm" if kind == "m" else "slstm"]
        sl = jax.tree.map(lambda t: t[off:off + cnt], stack)
        if kind == "m":
            st = (cache["mC"][off:off + cnt], cache["mn"][off:off + cnt])

            def body(x, inp):
                p, C0, n0 = inp
                out, (C1, n1) = mlstm_forward(cfg, p, x, dist, state=(C0, n0))
                return out, (C1, n1)
            x, (C1, n1) = scan_layers(cfg.analysis_unroll, body, x,
                                      (sl, st[0], st[1]), cnt)
            mC.append(C1)
            mn.append(n1)
        else:
            st = tuple(cache[kk][off:off + cnt]
                       for kk in ("sh", "sc", "sn", "sm"))

            def body(x, inp):
                p, h0, c0, n0, m0 = inp
                out, s1 = slstm_forward(cfg, p, x, dist,
                                        state=(h0, c0, n0, m0))
                return out, s1
            x, s1 = scan_layers(cfg.analysis_unroll, body, x,
                                (sl,) + st, cnt)
            sh.append(s1[0])
            sc.append(s1[1])
            sn.append(s1[2])
            sm.append(s1[3])
    new["mC"] = jnp.concatenate(mC, axis=0)
    new["mn"] = jnp.concatenate(mn, axis=0)
    if sh:
        new["sh"] = jnp.concatenate(sh, axis=0)
        new["sc"] = jnp.concatenate(sc, axis=0)
        new["sn"] = jnp.concatenate(sn, axis=0)
        new["sm"] = jnp.concatenate(sm, axis=0)
    return x, new


def prefill(cfg: LMConfig, params, batch: Dict, max_len: int,
            dist: Dist = Dist()):
    x = _embed(cfg, params, batch["tokens"], dist)
    B, L, _ = x.shape
    cache = init_cache(cfg, B, max_len)
    x, cache = _run_segments(cfg, params, x, dist, cache)
    cache["len"] = jnp.full((B,), L, jnp.int32)
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    return _unembed(cfg, params, x[:, -1:], dist), cache


def decode_step(cfg: LMConfig, params, tokens, cache, dist: Dist = Dist()):
    x = _embed(cfg, params, tokens, dist)
    x, new = _run_segments(cfg, params, x, dist, cache)
    new["len"] = cache["len"] + 1
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    return _unembed(cfg, params, x, dist), new
