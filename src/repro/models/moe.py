"""Fine-grained MoE layer (DeepSeekMoE / Kimi-K2 style) — expert parallel.

Parallelism (DESIGN.md §5):
  * experts sharded over the 'model' axis (E_local = E / model_size),
  * expert weights additionally ZeRO-3 sharded on d_model over 'data',
    all-gathered per layer inside the manual region (2 TB of Kimi experts
    never exist unsharded anywhere),
  * tokens are batch-sharded and REPLICATED over 'model', so dispatch is a
    local mask + sort — the combine is one psum over 'model', the exact same
    collective a dense TP MLP pays.  No all-to-all: this is the paper's
    C_T insight applied to experts (co-locate computation with data already
    in place rather than moving tokens).

Capacity: each model shard processes at most CAP = T*k/model_size * cf
assignments (static shape); overflow tokens drop their weakest expert —
standard capacity-factor semantics.

The router, shared experts, and the top-k run OUTSIDE the manual region in
plain GSPMD land.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat
from repro.models.common import LMConfig


def router_topk(x, w_router, k: int):
    """x (..., d) -> (idx (..., k) i32, weights (..., k) fp32, aux_loss)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e.
    E = w_router.shape[-1]
    flat = probs.reshape(-1, E)
    me = flat.mean(0)
    one_hot = jax.nn.one_hot(idx.reshape(-1, k), E, dtype=jnp.float32).sum(1)
    ce = one_hot.mean(0) / k
    aux = E * jnp.sum(me * ce)
    return idx, w.astype(x.dtype), aux


@jax.custom_vjp
def grouped_gemm(x, w, gs):
    """Grouped GEMM y[i] = x[i] @ w[group(i)] with hand-written VJP.

    jax.lax.ragged_dot's autodiff computes dW densely (every row against
    every group: x E_local more FLOPs — measured 30x total-step compute on
    kimi train_4k).  The proper adjoints are themselves ragged:
      dx = ragged_dot(dy, w^T, gs)                      (mode 1)
      dW = ragged_dot_general(x, dy, ragged-contracting) (mode 2: grouped
           outer product, same FLOPs as the forward)
    """
    return jax.lax.ragged_dot(x, w, gs)


def _gg_fwd(x, w, gs):
    return jax.lax.ragged_dot(x, w, gs), (x, w, gs)


def _gg_bwd(res, dy):
    x, w, gs = res
    dx = jax.lax.ragged_dot(dy, jnp.swapaxes(w, 1, 2), gs)
    if jaxcompat.has_ragged_dot_general():
        dn = jax.lax.RaggedDotDimensionNumbers(
            dot_dimension_numbers=(((0,), (0,)), ((), ())),
            lhs_ragged_dimensions=[0],
            rhs_group_dimensions=[],
        )
        dw = jax.lax.ragged_dot_general(
            x, dy, gs, dn, preferred_element_type=w.dtype)
    else:
        # Legacy-JAX fallback: per-group masked GEMM dW[g] = (x*1[gid=g])^T
        # dy, sequentially over groups (lax.map) so peak memory stays
        # O(m*k + k*n) — never the (m, k, n) per-token outer-product tensor.
        # FLOPs are E_local * forward (the dense-adjoint behavior old JAX
        # had anyway); new JAX takes the ragged_dot_general branch above.
        # Rows past sum(gs) get gid == E_local -> masked out everywhere,
        # matching ragged_dot's zero contribution for out-of-group rows.
        gid = jnp.searchsorted(jnp.cumsum(gs), jnp.arange(x.shape[0]),
                               side="right")
        xf = x.astype(jnp.float32)
        dyf = dy.astype(jnp.float32)

        def _one_group(g):
            sel = (gid == g).astype(jnp.float32)
            return (xf * sel[:, None]).T @ dyf

        dw = jax.lax.map(_one_group, jnp.arange(w.shape[0]))
    return dx.astype(x.dtype), dw.astype(w.dtype), None


grouped_gemm.defvjp(_gg_fwd, _gg_bwd)


def moe_ffn(
    cfg: LMConfig,
    p: dict,
    x: jnp.ndarray,
    mesh,
    batch_axes,
    model_axis: str = "model",
    data_axis: str = "data",
    fsdp_axes=None,
):
    """x (B, L, d) -> (B, L, d) MoE output (routed experts only; shared
    experts and router aux handled by the caller).

    p: {'w13': (E, d, 2*f), 'w2': (E, f, d)} sharded
       P(model_axis, fsdp, None) / P(model_axis, None, fsdp).
    ``idx``/``weights`` come from router_topk on the same x.
    """
    fsdp_axes = tuple(fsdp_axes) if fsdp_axes else (data_axis,)
    idx, weights, aux = router_topk(x, p["router"], cfg.top_k)

    B, L, d = x.shape
    k = cfg.top_k
    msize = mesh.shape[model_axis]
    E_local = cfg.n_experts // msize
    # Per-device token count (batch is sharded over batch_axes).
    bshard = 1
    for a in batch_axes:
        bshard *= mesh.shape[a]
    T_local = (B // bshard) * L

    # Per-expert capacity (standard MoE semantics): overflow beyond C drops.
    C = int((T_local * k / cfg.n_experts) * cfg.capacity_factor)
    C = max(64, ((C + 63) // 64) * 64)

    def body(xb, idxb, wb, w13, w2):
        # xb (B_l, L, d); idxb/wb (B_l, L, k); w13 (E_local, d/dsize, 2f).
        m_idx = jax.lax.axis_index(model_axis)
        w13 = jax.lax.all_gather(w13, fsdp_axes, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, fsdp_axes, axis=2, tiled=True)
        w13 = w13.astype(xb.dtype)
        w2 = w2.astype(xb.dtype)

        xf = xb.reshape(-1, d)
        T = xf.shape[0]
        flat_idx = idxb.reshape(T * k)
        flat_w = wb.reshape(T * k)
        local_e = flat_idx - m_idx * E_local
        is_mine = (local_e >= 0) & (local_e < E_local)
        # Sort assignments by local expert (non-mine to the tail), then give
        # each expert a FIXED block of C rows — the compute becomes a plain
        # batched GEMM (einsum), which is FLOP-exact on every backend
        # (ragged_dot decomposes densely off-TPU: measured 24x FLOPs).
        sort_key = jnp.where(is_mine, local_e, E_local)
        order = jnp.argsort(sort_key, stable=True)
        gs = jnp.bincount(jnp.where(is_mine, local_e, E_local),
                          length=E_local + 1)[:E_local]
        offs = jnp.concatenate([jnp.zeros((1,), gs.dtype),
                                jnp.cumsum(gs)[:-1]])
        pos = offs[:, None] + jnp.arange(C)[None, :]        # (E_local, C)
        valid = jnp.arange(C)[None, :] < jnp.minimum(gs, C)[:, None]
        src = order[jnp.minimum(pos, T * k - 1)]            # rows in flat
        tok = src // k                                      # (E_local, C)
        xB = xf[tok] * valid[..., None].astype(xf.dtype)    # (E_local, C, d)
        h = jnp.einsum("ecd,edf->ecf", xB, w13)
        g, u = jnp.split(h, 2, axis=-1)
        act = (jax.nn.silu(g.astype(jnp.float32)) *
               u.astype(jnp.float32)).astype(xB.dtype)
        y = jnp.einsum("ecf,efd->ecd", act, w2)             # (E_local, C, d)
        y = y * flat_w[src][..., None] * valid[..., None].astype(y.dtype)
        out = jnp.zeros((T, d), y.dtype).at[tok.reshape(-1)].add(
            y.reshape(-1, d))
        out = jax.lax.psum(out, model_axis)
        return out.reshape(xb.shape)

    fs = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    bspec = P(batch_axes, None, None)
    out = jaxcompat.shard_map(
        body, mesh=mesh,
        in_specs=(bspec, P(batch_axes, None, None), P(batch_axes, None, None),
                  P(model_axis, fs, None),
                  P(model_axis, None, fs)),
        out_specs=bspec,
        check_vma=False,
    )(x, idx, weights, p["w13"], p["w2"])
    return out, aux


def moe_ffn_dense_ref(cfg: LMConfig, p: dict, x: jnp.ndarray):
    """Oracle: every expert on every token, one-hot combine (tests only)."""
    idx, weights, aux = router_topk(x, p["router"], cfg.top_k)
    B, L, d = x.shape
    xf = x.reshape(-1, d)
    h = jnp.einsum("td,edf->tef", xf, p["w13"].astype(x.dtype))
    g, u = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
    y = jnp.einsum("tef,efd->ted", act.astype(xf.dtype),
                   p["w2"].astype(x.dtype))
    comb = jnp.zeros((xf.shape[0], cfg.n_experts), x.dtype)
    flat_idx = idx.reshape(-1, cfg.top_k)
    flat_w = weights.reshape(-1, cfg.top_k)
    comb = comb.at[jnp.arange(xf.shape[0])[:, None], flat_idx].add(flat_w)
    out = jnp.einsum("te,ted->td", comb, y)
    return out.reshape(B, L, d), aux
