"""Model zoo: family registry dispatching to the right implementation."""
from repro.models.common import LMConfig, SHAPES, ShapeCfg
from repro.models.transformer import Dist
from repro.models import encdec, ssm, transformer, xlstm

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "hybrid": ssm,
    "ssm": xlstm,
    "encdec": encdec,
}


def family_module(cfg: LMConfig):
    return _FAMILY[cfg.family]


def init_params(cfg, key):
    return family_module(cfg).init_params(cfg, key)


def param_specs(cfg, dist):
    return family_module(cfg).param_specs(cfg, dist)


def forward(cfg, params, batch, dist=Dist()):
    return family_module(cfg).forward(cfg, params, batch, dist)


def loss_fn(cfg, params, batch, dist=Dist()):
    return family_module(cfg).loss_fn(cfg, params, batch, dist)


def prefill(cfg, params, batch, max_len, dist=Dist()):
    return family_module(cfg).prefill(cfg, params, batch, max_len, dist)


def decode_step(cfg, params, tokens, cache, dist=Dist()):
    return family_module(cfg).decode_step(cfg, params, tokens, cache, dist)


def init_cache(cfg, batch, max_len):
    mod = family_module(cfg)
    if hasattr(mod, "init_cache"):
        return mod.init_cache(cfg, batch, max_len)
    return transformer.init_cache(cfg, batch, max_len)


__all__ = [
    "LMConfig", "SHAPES", "ShapeCfg", "Dist", "family_module", "init_params",
    "param_specs", "forward", "loss_fn", "prefill", "decode_step",
    "init_cache", "encdec", "ssm", "transformer", "xlstm",
]
