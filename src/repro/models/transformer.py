"""Decoder-only transformer LM: dense GQA (llama/qwen/yi/phi3), fine-grained
MoE (deepseek/kimi), and VLM-backbone (internvl2, stub patch frontend).

Execution paths:
  forward      — teacher-forced logits (training / evaluation)
  prefill      — forward + KV-cache construction (inference prefill)
  decode_step  — one token against a padded KV cache (inference decode)

Layers are stacked on a leading axis and scanned (remat-wrapped for
training); the first ``first_dense_layers`` (DeepSeek) live in their own
stack.  Sharding: batch over ('pod','data'), TP over 'model', FSDP over
'data' — see param_specs for the exact layout of every tensor.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import moe as moe_lib
from repro.models.common import (LMConfig, apply_rope, attention_any,
                                 dense_init, full_attention, rms_norm,
                                 rope_tables, scan_layers, sharded_ce_loss)


@dataclasses.dataclass(frozen=True)
class Dist:
    """Distribution context threaded through model functions."""
    mesh: Any = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    data_axis: str = "data"
    seq_shard: bool = False        # long-context: shard KV sequence dim
    fsdp_axes: Tuple[str, ...] = ()   # () -> (data_axis,); kimi adds 'pod'

    @property
    def fsdp(self):
        axes = self.fsdp_axes or (self.data_axis,)
        return axes if len(axes) > 1 else axes[0]

    def wsc(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    @property
    def batch(self):
        if not self.batch_axes:
            return None                # tiny-batch shapes: replicate batch dim
        if len(self.batch_axes) > 1:
            return self.batch_axes
        return self.batch_axes[0]


def vocab_padded(cfg: LMConfig, mult: int = 256) -> int:
    return ((cfg.vocab + mult - 1) // mult) * mult


# ---------------------------------------------------------------- parameters
def _attn_shapes(cfg: LMConfig):
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": (d, cfg.n_heads * hd), "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd), "wo": (cfg.n_heads * hd, d),
    }


def _layer_shapes(cfg: LMConfig, moe: bool):
    d = cfg.d_model
    shapes = {"ln1": (d,), "ln2": (d,), **_attn_shapes(cfg)}
    if cfg.qkv_bias:
        shapes.update({"bq": (cfg.n_heads * cfg.hd,),
                       "bk": (cfg.n_kv_heads * cfg.hd,),
                       "bv": (cfg.n_kv_heads * cfg.hd,)})
    if moe:
        f = cfg.expert_d_ff
        shapes.update({
            "router": (d, cfg.n_experts),
            "moe_w13": (cfg.n_experts, d, 2 * f),
            "moe_w2": (cfg.n_experts, f, d),
        })
        if cfg.n_shared_experts:
            fs = cfg.n_shared_experts * f
            shapes.update({"shared_w13": (d, 2 * fs), "shared_w2": (fs, d)})
    else:
        shapes.update({"w13": (d, 2 * cfg.d_ff), "w2": (cfg.d_ff, d)})
    return shapes


def _stack_init(key, shapes: Dict[str, tuple], n: int, dtype):
    out = {}
    for name, shp in shapes.items():
        key, sub = jax.random.split(key)
        if name.startswith(("ln",)):
            out[name] = jnp.ones((n,) + shp, dtype)
        elif name.startswith("b"):
            out[name] = jnp.zeros((n,) + shp, dtype)
        else:
            flat = jax.random.normal(sub, (n,) + shp) * (shp[-2] if len(shp) > 1
                                                         else shp[-1]) ** -0.5
            out[name] = flat.astype(dtype)
    return out


def init_params(cfg: LMConfig, key: jax.Array) -> Dict:
    vp = vocab_padded(cfg)
    key, ke, ku, kl, kd, kp = jax.random.split(key, 6)
    pdt = cfg.param_dtype
    n_moe = cfg.n_layers - cfg.first_dense_layers if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    params = {
        "embed": dense_init(ke, (vp, cfg.d_model), pdt, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
        "layers": _stack_init(kl, _layer_shapes(cfg, moe=bool(cfg.n_experts)),
                              n_moe if cfg.n_experts else cfg.n_layers, pdt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ku, (cfg.d_model, vp), pdt, scale=0.02)
    if cfg.n_experts and n_dense:
        params["dense_layers"] = _stack_init(
            kd, _layer_shapes(cfg, moe=False), n_dense, pdt)
    if cfg.family == "vlm":
        params["patch_proj"] = dense_init(
            kp, (cfg.frontend_dim, cfg.d_model), pdt)
    return params


def _layer_specs(cfg: LMConfig, moe: bool, dist: Dist) -> Dict[str, P]:
    m, d = dist.model_axis, dist.fsdp
    specs = {
        "ln1": P(None, None), "ln2": P(None, None),
        "wq": P(None, d, m), "wk": P(None, d, m), "wv": P(None, d, m),
        "wo": P(None, m, d),
    }
    if cfg.qkv_bias:
        specs.update({"bq": P(None, m), "bk": P(None, m), "bv": P(None, m)})
    if moe:
        specs.update({
            "router": P(None, d, None),
            "moe_w13": P(None, m, d, None),
            "moe_w2": P(None, m, None, d),
        })
        if cfg.n_shared_experts:
            specs.update({"shared_w13": P(None, d, m),
                          "shared_w2": P(None, m, d)})
    else:
        specs.update({"w13": P(None, d, m), "w2": P(None, m, d)})
    return specs


def param_specs(cfg: LMConfig, dist: Dist) -> Dict:
    m, d = dist.model_axis, dist.fsdp
    # Tied tables MUST be vocab-sharded: d_model-sharding makes the unembed
    # matmul contraction-sharded, and GSPMD then all-reduces the full
    # (B, L, V) fp32 logits (31 GB/device measured on llama train_4k).
    # Vocab sharding keeps logits output-sharded; the embedding lookup pays
    # only a (B, L, d) all-reduce.
    specs = {
        "embed": P(m, None) if cfg.tie_embeddings else P(None, m),
        "final_norm": P(None),
        "layers": _layer_specs(cfg, moe=bool(cfg.n_experts), dist=dist),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(d, m)
    if cfg.n_experts and cfg.first_dense_layers:
        specs["dense_layers"] = _layer_specs(cfg, moe=False, dist=dist)
    if cfg.family == "vlm":
        specs["patch_proj"] = P(None, m)
    return specs


# ------------------------------------------------------------------- blocks
def _attn(cfg: LMConfig, p, x, dist: Dist, cos, sin, cache=None,
          cache_at=None, kv_len=None):
    """Attention block.  Returns (residual_out, (k_new, v_new))."""
    B, L, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = h @ p["wq"].astype(h.dtype)
    k = h @ p["wk"].astype(h.dtype)
    v = h @ p["wv"].astype(h.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    q = dist.wsc(q, dist.batch, None, dist.model_axis)
    q = q.reshape(B, L, H, hd)
    k = k.reshape(B, L, Hkv, hd)
    v = v.reshape(B, L, Hkv, hd)
    q = apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
    k = apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])

    if cache is not None:
        ck, cv = cache
        if jnp.ndim(cache_at) == 0:          # synchronized decode offset
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, cache_at, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, cache_at, 0, 0))
        else:                                 # continuous batching: per-row
            rows = jnp.arange(B)[:, None]
            cols = cache_at[:, None] + jnp.arange(L)[None, :]
            ck = ck.at[rows, cols].set(k.astype(ck.dtype))
            cv = cv.at[rows, cols].set(v.astype(cv.dtype))
        out = full_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                             causal=False, kv_len=kv_len)
        knew, vnew = ck, cv
    else:
        out = attention_any(q, k, v, causal=True, chunk=cfg.attn_chunk,
                            unroll=cfg.analysis_unroll)
        knew, vnew = k, v
    out = out.reshape(B, L, H * hd)
    out = dist.wsc(out, dist.batch, None, dist.model_axis)
    return x + (out @ p["wo"].astype(out.dtype)), (knew, vnew)


def _ffn_dense(cfg, p, x, dist: Dist):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    hh = h @ p["w13"].astype(h.dtype)
    hh = dist.wsc(hh, dist.batch, None, dist.model_axis)
    g, u = jnp.split(hh, 2, axis=-1)
    act = (jax.nn.silu(g.astype(jnp.float32)) *
           u.astype(jnp.float32)).astype(h.dtype)
    return x + act @ p["w2"].astype(h.dtype)


def _ffn_moe(cfg, p, x, dist: Dist):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    routed, aux = moe_lib.moe_ffn(
        cfg, {"router": p["router"], "w13": p["moe_w13"], "w2": p["moe_w2"]},
        h, dist.mesh, dist.batch_axes, dist.model_axis, dist.data_axis,
        fsdp_axes=dist.fsdp_axes or None)
    out = routed
    if cfg.n_shared_experts:
        hh = h @ p["shared_w13"].astype(h.dtype)
        hh = dist.wsc(hh, dist.batch, None, dist.model_axis)
        g, u = jnp.split(hh, 2, axis=-1)
        act = (jax.nn.silu(g.astype(jnp.float32)) *
               u.astype(jnp.float32)).astype(h.dtype)
        out = out + act @ p["shared_w2"].astype(h.dtype)
    return x + out, aux


def _ffn_moe_local(cfg, p, x, dist: Dist):
    """Mesh-free MoE path (smoke tests / 1-device): dense-combine oracle."""
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    routed, aux = moe_lib.moe_ffn_dense_ref(
        cfg, {"router": p["router"], "w13": p["moe_w13"], "w2": p["moe_w2"]}, h)
    out = routed
    if cfg.n_shared_experts:
        hh = h @ p["shared_w13"].astype(h.dtype)
        g, u = jnp.split(hh, 2, axis=-1)
        act = (jax.nn.silu(g.astype(jnp.float32)) *
               u.astype(jnp.float32)).astype(h.dtype)
        out = out + act @ p["shared_w2"].astype(h.dtype)
    return x + out, aux


def _one_layer(cfg, p, x, dist, cos, sin, moe: bool, cache=None,
               cache_at=None, kv_len=None):
    x, kv = _attn(cfg, p, x, dist, cos, sin, cache, cache_at, kv_len)
    if moe:
        fn = _ffn_moe if dist.mesh is not None else _ffn_moe_local
        x, aux = fn(cfg, p, x, dist)
    else:
        x, aux = _ffn_dense(cfg, p, x, dist), 0.0
    return x, kv, aux


# ------------------------------------------------------------------ forward
def _embed(cfg, params, tokens, dist: Dist):
    x = params["embed"].astype(cfg.dtype)[tokens]
    return dist.wsc(x, dist.batch, None, None)


def _unembed(cfg, params, x, dist: Dist):
    w = (params["embed"].T if cfg.tie_embeddings
         else params["unembed"]).astype(cfg.dtype)
    logits = x @ w
    return dist.wsc(logits, dist.batch, None, dist.model_axis)


def _run_stack(cfg, stack, x, dist, cos, sin, moe: bool):
    """Scan over stacked layers (remat per layer when training)."""
    def body(x, p):
        out, _, aux = _one_layer(cfg, p, x, dist, cos, sin, moe)
        return out, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    n = jax.tree.leaves(stack)[0].shape[0]
    if n == 0:
        return x, 0.0
    x, auxs = scan_layers(cfg.analysis_unroll, body, x, stack, n)
    return x, jnp.sum(auxs)


def forward(cfg: LMConfig, params, batch: Dict, dist: Dist = Dist()):
    """batch: {'tokens': (B, L) i32, optional 'patches': (B, Pn, fd)}.
    Returns (logits (B, L_total, vocab_padded), aux_loss)."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens, dist)
    if cfg.family == "vlm" and "patches" in batch:
        pe = batch["patches"].astype(cfg.dtype) @ params["patch_proj"].astype(
            cfg.dtype)
        pe = dist.wsc(pe, dist.batch, None, None)
        x = jnp.concatenate([pe, x], axis=1)
    B, L, _ = x.shape
    pos = jnp.arange(L)[None, :]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta, cfg.dtype)

    aux = 0.0
    if cfg.n_experts:
        if cfg.first_dense_layers:
            x, a = _run_stack(cfg, params["dense_layers"], x, dist, cos, sin,
                              moe=False)
            aux += a
        x, a = _run_stack(cfg, params["layers"], x, dist, cos, sin, moe=True)
        aux += a
    else:
        x, a = _run_stack(cfg, params["layers"], x, dist, cos, sin, moe=False)
        aux += a
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    return _unembed(cfg, params, x, dist), aux


def loss_fn(cfg: LMConfig, params, batch: Dict, dist: Dist = Dist(),
            aux_weight: float = 0.01):
    """Next-token CE.  'labels' (B, L) with -100 = ignore."""
    logits, aux = forward(cfg, params, batch, dist)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:          # vlm: drop patch positions
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    return sharded_ce_loss(logits, labels, aux, aux_weight)


# ------------------------------------------------------------------ serving
def cache_spec(cfg: LMConfig, dist: Dist) -> P:
    """KV cache (n_layers, B, S, Hkv, hd) sharding: batch-sharded when B
    divides, sequence-sharded (SP) for long-context B=1."""
    if dist.seq_shard:
        return P(None, None, dist.batch, None, None)
    return P(None, dist.batch, None, None, None)


def init_cache(cfg: LMConfig, batch: int, max_len: int,
               n_layers: Optional[int] = None, dtype=None):
    n_layers = n_layers or cfg.n_layers
    dtype = dtype or cfg.dtype
    shp = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


def prefill(cfg: LMConfig, params, batch: Dict, max_len: int,
            dist: Dist = Dist()):
    """Run the prompt, build the KV cache.  Returns (logits_last, cache).

    Optional ``batch["lengths"]`` (B,) marks the true prompt length of each
    row when prompts are right-padded to a shared bucket: logits are
    gathered at position length-1 and ``cache["len"]`` is set per row, so
    one trace serves every prompt length in the bucket.  Trailing pad is
    harmless — attention is causal (pad rows never feed real rows) and
    decode masks KV beyond ``len``."""
    tokens = batch["tokens"]
    lengths = batch.get("lengths")
    x = _embed(cfg, params, tokens, dist)
    if cfg.family == "vlm" and "patches" in batch:
        pe = batch["patches"].astype(cfg.dtype) @ params["patch_proj"].astype(
            cfg.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    B, L, _ = x.shape
    max_len = max(max_len, L)          # vlm: patch positions extend the cache
    pos = jnp.arange(L)[None, :]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta, cfg.dtype)

    def body(x, p):
        moe = bool(cfg.n_experts)
        out, kv, _ = _one_layer(cfg, p, x, dist, cos, sin, moe)
        return out, kv

    stacks = []
    if cfg.n_experts and cfg.first_dense_layers:
        stacks.append((params["dense_layers"], False))
    stacks.append((params["layers"], bool(cfg.n_experts)))

    ks, vs = [], []
    for stack, moe in stacks:
        n = jax.tree.leaves(stack)[0].shape[0]
        if n == 0:
            continue
        def body(x, p, moe=moe):
            out, kv, _ = _one_layer(cfg, p, x, dist, cos, sin, moe)
            return out, kv
        x, (k_l, v_l) = scan_layers(cfg.analysis_unroll, body, x, stack, n)
        ks.append(k_l)
        vs.append(v_l)
    k = jnp.concatenate(ks, axis=0) if len(ks) > 1 else ks[0]
    v = jnp.concatenate(vs, axis=0) if len(vs) > 1 else vs[0]
    pad = max_len - L
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    if lengths is not None:
        lengths = lengths.astype(jnp.int32)
        idx = jnp.clip(lengths - 1, 0, L - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, jnp.broadcast_to(
            idx, (B, 1, x.shape[-1])), axis=1)
        cache_len = lengths
    else:
        x_last = x[:, -1:]
        cache_len = jnp.full((B,), L, jnp.int32)
    logits = _unembed(cfg, params, x_last, dist)
    cache = {"k": k, "v": v, "len": cache_len}
    return logits, cache


def decode_step(cfg: LMConfig, params, tokens, cache, dist: Dist = Dist()):
    """One token per sequence: tokens (B, 1) -> (logits (B,1,V), cache')."""
    x = _embed(cfg, params, tokens, dist)
    cur = cache["len"]                         # per-row offsets (ragged slots)
    pos = cache["len"][:, None]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta, cfg.dtype)
    kv_len = cache["len"] + 1

    n_dense = cfg.first_dense_layers if cfg.n_experts else 0

    def body(x, sl):
        p, ck, cv, is_moe = sl
        out, (k2, v2), _ = _one_layer(
            cfg, p, x, dist, cos, sin, moe=bool(cfg.n_experts) and is_moe,
            cache=(ck, cv), cache_at=cur, kv_len=kv_len)
        return out, (k2, v2)

    n_moe_layers = cfg.n_layers - n_dense
    if cfg.n_experts and n_dense and n_moe_layers == 0:
        # Probe configs may have only the dense-first layer.
        def body_d0(x, sl):
            p, ck, cv = sl
            out, kv, _ = _one_layer(cfg, p, x, dist, cos, sin, False,
                                    cache=(ck, cv), cache_at=cur,
                                    kv_len=kv_len)
            return out, kv
        x, (k2, v2) = scan_layers(
            cfg.analysis_unroll, body_d0, x,
            (params["dense_layers"], cache["k"], cache["v"]), n_dense)
    elif cfg.n_experts and n_dense:
        kd, km = cache["k"][:n_dense], cache["k"][n_dense:]
        vd, vm = cache["v"][:n_dense], cache["v"][n_dense:]

        def body_d(x, sl):
            p, ck, cv = sl
            out, kv, _ = _one_layer(cfg, p, x, dist, cos, sin, False,
                                    cache=(ck, cv), cache_at=cur, kv_len=kv_len)
            return out, kv
        x, (kd2, vd2) = scan_layers(
            cfg.analysis_unroll, body_d, x,
            (params["dense_layers"], kd, vd), n_dense)

        def body_m(x, sl):
            p, ck, cv = sl
            out, kv, _ = _one_layer(cfg, p, x, dist, cos, sin, True,
                                    cache=(ck, cv), cache_at=cur, kv_len=kv_len)
            return out, kv
        x, (km2, vm2) = scan_layers(
            cfg.analysis_unroll, body_m, x,
            (params["layers"], km, vm), cfg.n_layers - n_dense)
        k2 = jnp.concatenate([kd2, km2], axis=0)
        v2 = jnp.concatenate([vd2, vm2], axis=0)
    else:
        def body_p(x, sl):
            p, ck, cv = sl
            out, kv, _ = _one_layer(cfg, p, x, dist, cos, sin,
                                    bool(cfg.n_experts),
                                    cache=(ck, cv), cache_at=cur, kv_len=kv_len)
            return out, kv
        x, (k2, v2) = scan_layers(
            cfg.analysis_unroll, body_p, x,
            (params["layers"], cache["k"], cache["v"]), cfg.n_layers)

    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    logits = _unembed(cfg, params, x, dist)
    return logits, {"k": k2, "v": v2, "len": cache["len"] + 1}
