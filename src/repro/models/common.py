"""Shared LM substrate: config, sharding vocabulary, core blocks.

Sharding vocabulary (GSPMD, driven by with_sharding_constraint):
  batch   -> ('pod', 'data')     activations' batch dim
  heads / d_ff / vocab -> 'model' tensor parallel dim
  experts -> 'model'              expert parallel (MoE layers, shard_map)
  params  -> FSDP over 'data' on the largest non-TP dim

Attention runs through a KV-chunked online-softmax path (pure jnp lax.scan)
so compiled memory is O(L * chunk), never O(L^2); on TPU the Pallas
flash_attention kernel takes over (same math, kernels/flash_attention.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")


# ------------------------------------------------------------------- configs
@dataclasses.dataclass(frozen=True)
class LMConfig:
    """One assigned architecture.  Fields cover every family; unused ones
    stay at their defaults (e.g. MoE fields for dense archs)."""

    name: str
    family: str                    # dense | moe | hybrid | encdec | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False         # qwen-style
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_dense_layers: int = 0    # deepseek: layer 0 is dense FFN
    capacity_factor: float = 2.0

    # SSM / hybrid (zamba2 Mamba2 blocks)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0            # hybrid: shared attn block cadence

    # xLSTM
    slstm_every: int = 0           # one sLSTM block per this many mLSTM

    # enc-dec
    n_enc_layers: int = 0
    frontend_dim: int = 0          # audio/vision stub embedding width
    frontend_len: int = 0          # frames / patches per example

    # numerics / execution
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    attn_chunk: int = 1024
    optimizer: str = "adamw"       # adamw | lion (memory-light for 1T MoE)
    # ZeRO sharding also over the 'pod' axis (cross-pod DCN all-gathers in
    # exchange for halved state residency — the 1T MoE needs it).
    fsdp_over_pod: bool = False
    # Per-arch gradient-accumulation override (0 = use the shape default).
    # Trades activation residency against step granularity; the no-remat
    # configs raise it so one microbatch's activations fit HBM.
    train_microbatches: int = 0
    # Analysis mode: fully unroll layer/microbatch scans so that XLA's
    # cost_analysis and the HLO collective scrape count every iteration
    # (scan bodies are otherwise counted ONCE — verified on XLA:CPU).
    analysis_unroll: bool = False

    def scan_unroll(self, length: int) -> int:
        return length if self.analysis_unroll else 1

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    def params_count(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting)."""
        d, hd = self.d_model, self.hd
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.family in ("ssm",):      # xlstm: mLSTM blocks, no std attn
            att = 0
        mlp_dense = 3 * d * self.d_ff if self.d_ff else 0
        per_layer = att + mlp_dense + 2 * d
        total = self.n_layers * per_layer
        if self.n_experts:
            moe_layers = self.n_layers - self.first_dense_layers
            per_exp = 3 * d * self.expert_d_ff
            total = (
                self.first_dense_layers * (att + mlp_dense + 2 * d)
                + moe_layers * (att + 2 * d
                                + (self.n_experts + self.n_shared_experts)
                                * per_exp
                                + d * self.n_experts)   # router
            )
        if self.family == "ssm":
            din = self.ssm_expand * d
            per = d * 2 * din + din * d + 2 * d        # mLSTM-ish in/out
            total = self.n_layers * per
        if self.family == "hybrid":
            din = self.ssm_expand * d
            nh = din // self.ssm_head_dim
            mamba = (d * (2 * din + 2 * self.ssm_state + nh) + din * d + 2 * d)
            shared = att + 3 * d * self.d_ff + 2 * d
            total = self.n_layers * mamba + shared
        if self.n_enc_layers:
            total += self.n_enc_layers * (att + 3 * d * self.d_ff + 2 * d) \
                + self.n_layers * (att + 2 * d)        # dec cross-attn extra
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)


def sharded_ce_loss(logits, labels, aux=0.0, aux_weight: float = 0.0):
    """Cross entropy that never gathers the vocab-sharded logits.

    take_along_axis over a sharded axis makes GSPMD all-gather the full
    (B, L, V) fp32 logits (31 GB/device for llama train_4k — measured).
    Formulating the gold logit as a masked reduction and the logsumexp as
    local-max/local-sum keeps every op shardable on V; the only collectives
    are (B, L)-sized all-reduces.  labels: -100 = ignore.
    """
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    l32 = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(l32, axis=-1))
    s = jnp.sum(jnp.exp(l32 - m[..., None]), axis=-1)
    lse = m + jnp.log(s)
    iota = jax.lax.broadcasted_iota(jnp.int32, l32.shape, l32.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], l32, 0.0), axis=-1)
    nll = (lse - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux


def scan_layers(analysis_unroll: bool, body, carry, xs, length: int):
    """lax.scan normally; a PYTHON loop in analysis mode.

    scan(unroll=n) is not enough for cost accounting: the TRANSPOSE scan of
    reverse-mode AD keeps unroll=1, so backward FLOPs still vanish from
    cost_analysis.  A python loop inlines both directions.
    """
    if not analysis_unroll:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        sl = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    microbatches: int = 1


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train", microbatches=4),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


# ------------------------------------------------------------------ sharding
def wsc(x, *spec):
    """with_sharding_constraint that no-ops outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def batch_spec(*rest) -> P:
    return P(BATCH_AXES, *rest)


# ------------------------------------------------------------- building blocks
def rms_norm(x, g, eps: float):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * g.astype(x.dtype)


def rope_tables(positions, hd: int, theta: float, dtype=jnp.float32):
    """positions (...,) -> cos/sin (..., hd//2)."""
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x (..., L, H, hd); cos/sin (..., L, 1, hd//2) broadcastable."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                      kv_len=None, scale: Optional[float] = None,
                      unroll: bool = False):
    """Online-softmax attention, O(Lq * chunk) memory, differentiable.

    q (B, Lq, Hq, D); k/v (B, Lk, Hkv, D); kv_len (B,) live KV prefix.
    GQA folds q heads onto kv heads without materializing repeats.
    """
    B, Lq, Hq, D = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Lq, Hkv, g, D) * jnp.asarray(scale, q.dtype)

    nchunks = (Lk + chunk - 1) // chunk
    pad = nchunks * chunk - Lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, Hkv, D)
    vc = v.reshape(B, nchunks, chunk, Hkv, D)
    live = jnp.full((B,), Lk, jnp.int32) if kv_len is None else kv_len
    q_pos = jnp.arange(Lq) + (Lk - Lq)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, j = inp
        s = jnp.einsum("blhgd,bchd->blhgc", qg, kb,
                       preferred_element_type=jnp.float32)
        k_pos = j * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] < live[:, None]                 # (B, chunk)
        if causal:
            cm = k_pos[None, :] <= q_pos[:, None]             # (Lq, chunk)
            mask = mask[:, None, :] & cm[None]                # (B, Lq, chunk)
            mask = mask[:, :, None, None, :]
        else:
            mask = mask[:, None, None, None, :]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("blhgc,bchd->blhgd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Lq, Hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Lq, Hkv, g), jnp.float32)
    a0 = jnp.zeros((B, Lq, Hkv, g, D), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    if unroll:
        # Analysis mode: python loop so forward AND backward FLOPs of every
        # chunk appear in cost_analysis (see scan_layers).
        carry = (m0, l0, a0)
        for j in range(nchunks):
            carry, _ = step(carry, (kc_t[j], vc_t[j], jnp.asarray(j)))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), (kc_t, vc_t, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Lq, Hq, D).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, kv_len=None,
                   scale: Optional[float] = None):
    """Direct einsum attention for short L (decode steps, smoke tests)."""
    B, Lq, Hq, D = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Lq, Hkv, g, D)
    s = jnp.einsum("blhgd,bkhd->blhgk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(Lq) + (Lk - Lq)
    k_pos = jnp.arange(Lk)
    if causal:
        cm = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(cm[None, :, None, None, :], s, -1e30)
    if kv_len is not None:
        lm = k_pos[None, :] < kv_len[:, None]
        s = jnp.where(lm[:, None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("blhgk,bkhd->blhgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Lq, Hq, D).astype(q.dtype)


def attention_any(q, k, v, *, causal: bool, chunk: int, kv_len=None,
                  unroll: bool = False):
    """Pick the chunked path when the KV extent warrants it."""
    if k.shape[1] > 2 * chunk:
        return chunked_attention(q, k, v, causal=causal, chunk=chunk,
                                 kv_len=kv_len, unroll=unroll)
    return full_attention(q, k, v, causal=causal, kv_len=kv_len)


# --------------------------------------------------------------- param utils
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def abstract_like(tree, dtype=None):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype), tree)


def param_bytes(tree) -> int:
    return sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))
