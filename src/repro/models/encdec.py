"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, F, frontend_dim), which are projected and
run through a bidirectional encoder; the decoder stacks causal self-attention
+ cross-attention + FFN.  Decode keeps a growing self-attn KV cache and a
static cross-attn KV (computed once from the encoder output).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (
    LMConfig, apply_rope, attention_any, dense_init, full_attention, rms_norm,
    rope_tables, scan_layers, sharded_ce_loss,
)
from repro.models.transformer import Dist, _embed, _unembed, vocab_padded


def _attn_shapes(cfg: LMConfig):
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": (d, cfg.n_heads * hd), "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd), "wo": (cfg.n_heads * hd, d),
    }


def _enc_layer_shapes(cfg):
    d = cfg.d_model
    return {"ln1": (d,), "ln2": (d,), **_attn_shapes(cfg),
            "w13": (d, 2 * cfg.d_ff), "w2": (cfg.d_ff, d)}


def _dec_layer_shapes(cfg):
    d = cfg.d_model
    base = _enc_layer_shapes(cfg)
    base.update({"ln_x": (d,)})
    base.update({f"x_{k}": v for k, v in _attn_shapes(cfg).items()})
    return base


def init_params(cfg: LMConfig, key: jax.Array) -> Dict:
    vp = vocab_padded(cfg)
    pdt = cfg.param_dtype

    def stack(key, shapes, n):
        out = {}
        for name, shp in shapes.items():
            key, sub = jax.random.split(key)
            if name.startswith("ln"):
                out[name] = jnp.ones((n,) + shp, pdt)
            else:
                out[name] = (jax.random.normal(sub, (n,) + shp)
                             * shp[-2] ** -0.5).astype(pdt)
        return out

    key, ke, ku, kf, k1, k2 = jax.random.split(key, 6)
    return {
        "embed": dense_init(ke, (vp, cfg.d_model), pdt, scale=0.02),
        "unembed": dense_init(ku, (cfg.d_model, vp), pdt, scale=0.02),
        "frontend_proj": dense_init(kf, (cfg.frontend_dim, cfg.d_model), pdt),
        "enc_norm": jnp.ones((cfg.d_model,), pdt),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
        "encoder": stack(k1, _enc_layer_shapes(cfg), cfg.n_enc_layers),
        "decoder": stack(k2, _dec_layer_shapes(cfg), cfg.n_layers),
    }


def param_specs(cfg: LMConfig, dist: Dist) -> Dict:
    m, da = dist.model_axis, dist.data_axis
    att = {"wq": P(None, da, m), "wk": P(None, da, m), "wv": P(None, da, m),
           "wo": P(None, m, da)}
    enc = {"ln1": P(None, None), "ln2": P(None, None), **att,
           "w13": P(None, da, m), "w2": P(None, m, da)}
    dec = dict(enc)
    dec.update({"ln_x": P(None, None)})
    dec.update({f"x_{k}": v for k, v in att.items()})
    return {
        "embed": P(None, m), "unembed": P(da, m),
        "frontend_proj": P(None, m),
        "enc_norm": P(None), "final_norm": P(None),
        "encoder": enc, "decoder": dec,
    }


def _mha(cfg, p, prefix, x, kv_src, dist, cos_q, sin_q, cos_k, sin_k,
         causal, cache=None, cache_at=None, kv_len=None, rope: bool = True):
    B, L, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    w = lambda n: p[prefix + n].astype(x.dtype)
    q = (x @ w("wq")).reshape(B, L, H, hd)
    if kv_src is not None:
        Lk = kv_src.shape[1]
        k = (kv_src @ w("wk")).reshape(B, Lk, Hkv, hd)
        v = (kv_src @ w("wv")).reshape(B, Lk, Hkv, hd)
    else:
        k = v = None
    if rope:
        q = apply_rope(q, cos_q[:, :, None, :], sin_q[:, :, None, :])
        if k is not None:
            k = apply_rope(k, cos_k[:, :, None, :], sin_k[:, :, None, :])
    if cache is not None:
        ck, cv = cache
        if k is not None:                      # self-attn decode: append
            if jnp.ndim(cache_at) == 0:
                ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                                  (0, cache_at, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                                  (0, cache_at, 0, 0))
            else:                              # per-row (continuous batching)
                rows = jnp.arange(B)[:, None]
                cols = cache_at[:, None] + jnp.arange(L)[None, :]
                ck = ck.at[rows, cols].set(k.astype(ck.dtype))
                cv = cv.at[rows, cols].set(v.astype(cv.dtype))
        out = full_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                             causal=False, kv_len=kv_len)
        kv_out = (ck, cv)
    else:
        out = attention_any(q, k, v, causal=causal, chunk=cfg.attn_chunk,
                            unroll=cfg.analysis_unroll)
        kv_out = (k, v)
    out = out.reshape(B, L, H * hd)
    out = dist.wsc(out, dist.batch, None, dist.model_axis)
    return out @ w("wo"), kv_out


def _ffn(cfg, p, x, dist):
    hh = x @ p["w13"].astype(x.dtype)
    hh = dist.wsc(hh, dist.batch, None, dist.model_axis)
    g, u = jnp.split(hh, 2, axis=-1)
    act = (jax.nn.silu(g.astype(jnp.float32)) *
           u.astype(jnp.float32)).astype(x.dtype)
    return act @ p["w2"].astype(x.dtype)


def encode(cfg: LMConfig, params, frames, dist: Dist = Dist()):
    """frames (B, F, frontend_dim) -> encoder memory (B, F, d)."""
    x = frames.astype(cfg.dtype) @ params["frontend_proj"].astype(cfg.dtype)
    x = dist.wsc(x, dist.batch, None, None)
    B, F, _ = x.shape
    pos = jnp.arange(F)[None, :]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta, cfg.dtype)

    def body(x, p):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, _ = _mha(cfg, p, "", h, h, dist, cos, sin, cos, sin, causal=False)
        x = x + a
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + _ffn(cfg, p, h, dist), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = scan_layers(cfg.analysis_unroll, body, x, params["encoder"],
                       cfg.n_enc_layers)
    return rms_norm(x, params["enc_norm"].astype(cfg.dtype), cfg.norm_eps)


def _decoder_stack(cfg, params, x, memory, dist, cos, sin, cos_m, sin_m,
                   cache=None, cache_at=None, kv_len=None):
    def body(x, sl):
        if cache is not None:
            p, ck, cv, xk, xv = sl
        else:
            p = sl
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if cache is not None:
            a, (k2, v2) = _mha(cfg, p, "", h, h, dist, cos, sin, cos, sin,
                               causal=False, cache=(ck, cv),
                               cache_at=cache_at, kv_len=kv_len)
        else:
            a, (k2, v2) = _mha(cfg, p, "", h, h, dist, cos, sin, cos, sin,
                               causal=True)
        x = x + a
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        if cache is not None:
            a, _ = _mha(cfg, p, "x_", h, None, dist, cos, sin, cos_m, sin_m,
                        causal=False, cache=(xk, xv), rope=False)
        else:
            a, (xk2, xv2) = _mha(cfg, p, "x_", h, memory, dist, cos, sin,
                                 cos_m, sin_m, causal=False, rope=False)
        x = x + a
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + _ffn(cfg, p, h, dist)
        if cache is not None:
            return x, (k2, v2)
        return x, (k2, v2, xk2, xv2)

    if cache is not None:
        xs = (params["decoder"], cache["k"], cache["v"],
              cache["xk"], cache["xv"])
    else:
        xs = params["decoder"]
        if cfg.remat:
            body = jax.checkpoint(body)
    return scan_layers(cfg.analysis_unroll, body, x, xs, cfg.n_layers)


def forward(cfg: LMConfig, params, batch: Dict, dist: Dist = Dist()):
    """batch: {'frames': (B,F,fd), 'tokens': (B,L)} -> (logits, 0.0)."""
    memory = encode(cfg, params, batch["frames"], dist)
    x = _embed(cfg, params, batch["tokens"], dist)
    B, L, _ = x.shape
    Fm = memory.shape[1]
    cos, sin = rope_tables(jnp.arange(L)[None], cfg.hd, cfg.rope_theta,
                           cfg.dtype)
    cos_m, sin_m = rope_tables(jnp.arange(Fm)[None], cfg.hd, cfg.rope_theta,
                               cfg.dtype)
    x, _ = _decoder_stack(cfg, params, x, memory, dist, cos, sin, cos_m, sin_m)
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    return _unembed(cfg, params, x, dist), 0.0


def loss_fn(cfg: LMConfig, params, batch: Dict, dist: Dist = Dist(), **_):
    logits, _ = forward(cfg, params, batch, dist)
    return sharded_ce_loss(logits, batch["labels"])


def init_cache(cfg: LMConfig, batch: int, max_len: int):
    """Self-attn cache grows to max_len; cross-attn KV is sized by the
    (stub) frontend length."""
    F = cfg.frontend_len
    kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    xkv = (cfg.n_layers, batch, F, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(kv, cfg.dtype), "v": jnp.zeros(kv, cfg.dtype),
        "xk": jnp.zeros(xkv, cfg.dtype), "xv": jnp.zeros(xkv, cfg.dtype),
        "len": jnp.zeros((batch,), jnp.int32),
        "xlen": jnp.full((batch,), F, jnp.int32),
    }


def prefill(cfg: LMConfig, params, batch: Dict, max_len: int,
            dist: Dist = Dist()):
    """Encode + run the target prefix; build self+cross caches."""
    memory = encode(cfg, params, batch["frames"], dist)
    x = _embed(cfg, params, batch["tokens"], dist)
    B, L, _ = x.shape
    Fm = memory.shape[1]
    cos, sin = rope_tables(jnp.arange(L)[None], cfg.hd, cfg.rope_theta,
                           cfg.dtype)
    cos_m, sin_m = rope_tables(jnp.arange(Fm)[None], cfg.hd, cfg.rope_theta,
                               cfg.dtype)
    x, (k, v, xk, xv) = _decoder_stack(cfg, params, x, memory, dist, cos, sin,
                                       cos_m, sin_m)
    pad = max_len - L
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    logits = _unembed(cfg, params, x[:, -1:], dist)
    cache = {"k": k, "v": v, "xk": xk, "xv": xv,
             "len": jnp.full((B,), L, jnp.int32),
             "xlen": jnp.full((B,), Fm, jnp.int32)}
    return logits, cache


def decode_step(cfg: LMConfig, params, tokens, cache, dist: Dist = Dist()):
    x = _embed(cfg, params, tokens, dist)
    cur = cache["len"]                         # per-row offsets (ragged slots)
    pos = cache["len"][:, None]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta, cfg.dtype)
    kv_len = cache["len"] + 1
    x, (k2, v2) = _decoder_stack(
        cfg, params, x, None, dist, cos, sin, None, None,
        cache=cache, cache_at=cur, kv_len=kv_len)
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    logits = _unembed(cfg, params, x, dist)
    new = dict(cache)
    new["k"], new["v"] = k2, v2
    new["len"] = cache["len"] + 1
    return logits, new
