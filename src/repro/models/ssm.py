"""Mamba2 (SSD) blocks and the Zamba2-style hybrid stack.

Mamba2 layer = in_proj -> causal depthwise conv (x,B,C) -> selective SSM with
scalar-per-head decay (the SSD formulation) -> gated out_proj.  Training uses
the chunkwise-parallel SSD algorithm (intra-chunk quadratic + inter-chunk
state recurrence, O(L * chunk) memory); decode keeps a recurrent state
(B, H, P, N) + a conv tail — O(1) per token, which is what makes the
``long_500k`` shape runnable for this family.

Zamba2 hybrid: a stack of Mamba2 blocks with ONE shared attention+MLP block
(weights reused) applied every ``attn_every`` layers on concat(hidden,
embedding) — per arXiv:2411.15242.  The shared block's KV cache is kept per
invocation site.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import (LMConfig, dense_init, rms_norm, rope_tables,
                                 scan_layers, sharded_ce_loss)
from repro.models.transformer import (
    Dist, _attn, _ffn_dense, _embed, _unembed, vocab_padded,
)

SSD_CHUNK = 128


# ------------------------------------------------------------- mamba2 (SSD)
def _mamba_dims(cfg: LMConfig):
    din = cfg.ssm_expand * cfg.d_model
    H = din // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = din + 2 * N
    return din, H, N, conv_ch


def mamba_layer_shapes(cfg: LMConfig):
    d = cfg.d_model
    din, H, N, conv_ch = _mamba_dims(cfg)
    return {
        "norm": (d,),
        "in_proj": (d, 2 * din + 2 * N + H),
        "conv_w": (cfg.ssm_conv, conv_ch),
        "conv_b": (conv_ch,),
        "A_log": (H,),
        "D": (H,),
        "dt_bias": (H,),
        "out_proj": (din, d),
    }


def _ssd_chunked(xbar, loga, Bm, Cm, state0=None, chunk=SSD_CHUNK):
    """Chunkwise SSD scan.

    xbar (B, L, H, P): dt-scaled inputs;  loga (B, L, H): per-step log decay;
    Bm/Cm (B, L, N): input/output projections (single group).
    Returns (y (B, L, H, P), final_state (B, H, N, P)).
    """
    Bsz, L, H, Pd = xbar.shape
    N = Bm.shape[-1]
    pad = (-L) % chunk
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    C_ = xbar.shape[1] // chunk
    xb = xbar.reshape(Bsz, C_, chunk, H, Pd)
    la = loga.reshape(Bsz, C_, chunk, H)
    Bc = Bm.reshape(Bsz, C_, chunk, N)
    Cc = Cm.reshape(Bsz, C_, chunk, N)

    cum = jnp.cumsum(la, axis=2)                               # (B,C,Q,H)
    total = cum[:, :, -1]                                      # (B,C,H)
    # Intra-chunk: scores[t,s] = (C_t . B_s) exp(cum[t]-cum[s]) [s<=t]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,C,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    dec = jnp.exp(seg)
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)                 # (B,C,Q,Q)
    y_intra = jnp.einsum("bcqs,bcqsh,bcshp->bcqhp", cb, dec, xb)
    # Chunk-local states: S_c = sum_s exp(total - cum[s]) B_s (x) xbar[s]
    w = jnp.exp(total[:, :, None, :] - cum)                    # (B,C,Q,H)
    S_loc = jnp.einsum("bcsn,bcsh,bcshp->bchnp", Bc, w, xb)    # (B,C,H,N,P)

    # Inter-chunk recurrence over C (sequential scan, C_ steps).
    def scan_fn(S_prev, inp):
        S_l, tot = inp                                         # (B,H,N,P),(B,H)
        S_new = S_prev * jnp.exp(tot)[:, :, None, None] + S_l
        return S_new, S_prev

    S0 = (jnp.zeros((Bsz, H, N, Pd), xbar.dtype)
          if state0 is None else state0)
    S_final, S_prevs = jax.lax.scan(
        scan_fn, S0,
        (jnp.moveaxis(S_loc, 1, 0), jnp.moveaxis(total, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                      # (B,C,H,N,P)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc, jnp.exp(cum), S_prevs)
    y = (y_intra + y_inter).reshape(Bsz, C_ * chunk, H, Pd)
    return y[:, :L], S_final


def mamba_forward(cfg: LMConfig, p, x, dist: Dist, state=None,
                  conv_tail=None):
    """One Mamba2 block.  x (B, L, d) -> (out, (ssm_state, conv_tail)).

    ``state``/``conv_tail`` given -> recurrent decode semantics (L small).
    """
    Bsz, L, d = x.shape
    din, H, N, conv_ch = _mamba_dims(cfg)
    h = rms_norm(x, p["norm"].astype(x.dtype), cfg.norm_eps)
    zxbcdt = h @ p["in_proj"].astype(h.dtype)
    zxbcdt = dist.wsc(zxbcdt, dist.batch, None, dist.model_axis)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)          # (B,L,conv_ch)
    K = cfg.ssm_conv
    if conv_tail is not None:
        ctx = jnp.concatenate([conv_tail, conv_in], axis=1)    # (B,K-1+L,ch)
        new_tail = ctx[:, -(K - 1):]
    else:
        ctx = jnp.pad(conv_in, ((0, 0), (K - 1, 0), (0, 0)))
        new_tail = ctx[:, -(K - 1):]
    # Depthwise causal conv: stack K shifted views.
    conv = sum(ctx[:, k:k + L] * p["conv_w"].astype(x.dtype)[k][None, None]
               for k in range(K)) + p["conv_b"].astype(x.dtype)
    conv = jax.nn.silu(conv)
    xin, Bm, Cm = jnp.split(conv, [din, din + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,L,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,) < 0
    loga = dt * A[None, None]                                  # (B,L,H)
    xh = xin.reshape(Bsz, L, H, cfg.ssm_head_dim)
    xbar = xh * dt[..., None].astype(xh.dtype)

    if state is not None and L == 1:
        # Recurrent step: S' = exp(loga) S + B (x) xbar; y = C . S'
        Sn = (state * jnp.exp(loga)[:, 0, :, None, None]
              + jnp.einsum("bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                           xbar[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), Sn)
        y = y[:, None]
        S_final = Sn
    else:
        y, S_final = _ssd_chunked(
            xbar.astype(jnp.float32), loga, Bm.astype(jnp.float32),
            Cm.astype(jnp.float32), state0=state)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :,
                                                                None]
    y = y.reshape(Bsz, L, din).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = dist.wsc(y, dist.batch, None, dist.model_axis)
    return x + y @ p["out_proj"].astype(x.dtype), (S_final, new_tail)


# --------------------------------------------------------------- zamba2 stack
def init_params(cfg: LMConfig, key: jax.Array) -> Dict:
    vp = vocab_padded(cfg)
    din, H, N, conv_ch = _mamba_dims(cfg)
    key, ke, km, ks, kp = jax.random.split(key, 5)
    pdt = cfg.param_dtype

    shapes = mamba_layer_shapes(cfg)
    stack = {}
    for name, shp in shapes.items():
        key, sub = jax.random.split(key)
        if name == "norm":
            stack[name] = jnp.ones((cfg.n_layers,) + shp, pdt)
        elif name == "A_log":
            a0 = jnp.log(jnp.linspace(1.0, 16.0, shp[0]))
            stack[name] = jnp.tile(a0[None], (cfg.n_layers, 1)).astype(pdt)
        elif name == "D":
            stack[name] = jnp.ones((cfg.n_layers,) + shp, pdt)
        elif name in ("conv_b", "dt_bias"):
            stack[name] = jnp.zeros((cfg.n_layers,) + shp, pdt)
        elif name == "conv_w":
            stack[name] = (jax.random.normal(sub, (cfg.n_layers,) + shp)
                           * 0.1).astype(pdt)
        else:
            stack[name] = (jax.random.normal(sub, (cfg.n_layers,) + shp)
                           * shp[0] ** -0.5).astype(pdt)

    params = {
        "embed": dense_init(ke, (vp, cfg.d_model), pdt, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
        "mamba": stack,
    }
    if not cfg.tie_embeddings:
        key, ku = jax.random.split(key)
        params["unembed"] = dense_init(ku, (cfg.d_model, vp), pdt, scale=0.02)
    if cfg.attn_every:
        d = cfg.d_model
        hd = cfg.hd
        sk = jax.random.split(ks, 8)
        params["shared"] = {
            "concat_proj": dense_init(sk[0], (2 * d, d), pdt),
            "ln1": jnp.ones((d,), pdt), "ln2": jnp.ones((d,), pdt),
            "wq": dense_init(sk[1], (d, cfg.n_heads * hd), pdt),
            "wk": dense_init(sk[2], (d, cfg.n_kv_heads * hd), pdt),
            "wv": dense_init(sk[3], (d, cfg.n_kv_heads * hd), pdt),
            "wo": dense_init(sk[4], (cfg.n_heads * hd, d), pdt),
            "w13": dense_init(sk[5], (d, 2 * cfg.d_ff), pdt),
            "w2": dense_init(sk[6], (cfg.d_ff, d), pdt),
        }
    return params


def param_specs(cfg: LMConfig, dist: Dist) -> Dict:
    from jax.sharding import PartitionSpec as P
    m, da = dist.model_axis, dist.data_axis
    stack = {
        "norm": P(None, None),
        "in_proj": P(None, da, m),
        "conv_w": P(None, None, m),
        "conv_b": P(None, m),
        "A_log": P(None, None), "D": P(None, None), "dt_bias": P(None, None),
        "out_proj": P(None, m, da),
    }
    specs = {"embed": P(None, m), "final_norm": P(None), "mamba": stack}
    if not cfg.tie_embeddings:
        specs["unembed"] = P(da, m)
    if cfg.attn_every:
        specs["shared"] = {
            "concat_proj": P(da, m),
            "ln1": P(None), "ln2": P(None),
            "wq": P(da, m), "wk": P(da, m), "wv": P(da, m), "wo": P(m, da),
            "w13": P(da, m), "w2": P(m, da),
        }
    return specs


def _shared_block(cfg, sp, x, x0, dist, cos, sin, cache=None, cache_at=None,
                  kv_len=None):
    """Zamba2 shared attention+MLP on concat(hidden, embedding)."""
    h = jnp.concatenate([x, x0], axis=-1) @ sp["concat_proj"].astype(x.dtype)
    h, kv = _attn(cfg, sp, h, dist, cos, sin, cache, cache_at, kv_len)
    h = _ffn_dense(cfg, sp, h, dist)
    return x + h, kv


def num_shared_calls(cfg: LMConfig) -> int:
    if not cfg.attn_every:
        return 0
    return sum(1 for i in range(cfg.n_layers)
               if (i + 1) % cfg.attn_every == 0)


def forward(cfg: LMConfig, params, batch: Dict, dist: Dist = Dist()):
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens, dist)
    x0 = x
    B, L, _ = x.shape
    pos = jnp.arange(L)[None, :]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta, cfg.dtype)
    shared = params.get("shared")

    def body(carry, sl):
        x, idx = carry
        p = sl
        x, _ = mamba_forward(cfg, p, x, dist)
        if shared is not None:
            x = jax.lax.cond(
                (idx + 1) % cfg.attn_every == 0,
                lambda q: _shared_block(cfg, shared, q, x0, dist, cos, sin)[0],
                lambda q: q, x)
        return (x, idx + 1), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, _), _ = scan_layers(cfg.analysis_unroll, body, (x, 0),
                            params["mamba"], cfg.n_layers)
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    return _unembed(cfg, params, x, dist), 0.0


def loss_fn(cfg: LMConfig, params, batch: Dict, dist: Dist = Dist(), **_):
    logits, _ = forward(cfg, params, batch, dist)
    return sharded_ce_loss(logits, batch["labels"])


# ------------------------------------------------------------------ serving
def init_cache(cfg: LMConfig, batch: int, max_len: int):
    din, H, N, conv_ch = _mamba_dims(cfg)
    nsh = num_shared_calls(cfg)
    cache = {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, cfg.ssm_head_dim, N),
                         jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch),
                          cfg.dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if nsh:
        cache["k"] = jnp.zeros((nsh, batch, max_len, cfg.n_kv_heads, cfg.hd),
                               cfg.dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
    return cache


def decode_step(cfg: LMConfig, params, tokens, cache, dist: Dist = Dist()):
    """tokens (B, 1) against recurrent state (+ shared-attn KV cache)."""
    x = _embed(cfg, params, tokens, dist)
    x0 = x
    cur = cache["len"]                         # per-row offsets (ragged slots)
    pos = cache["len"][:, None]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta, cfg.dtype)
    kv_len = cache["len"] + 1
    shared = params.get("shared")
    nsh = num_shared_calls(cfg)

    def body(carry, sl):
        x, idx, sh_idx = carry
        p, S, tail = sl
        x, (S2, tail2) = mamba_forward(cfg, p, x, dist, state=S, conv_tail=tail)
        return (x, idx + 1, sh_idx), (S2, tail2)

    # Mamba layers run in a scan; shared-attn invocations run between scan
    # segments (they carry distinct KV caches, so they stay unrolled).
    x = x0
    outs_S, outs_tail, ks, vs = [], [], [], []
    sh_i = 0
    layer_ids = list(range(cfg.n_layers))
    boundaries = [i for i in layer_ids
                  if shared is not None and (i + 1) % cfg.attn_every == 0]
    segments = []
    prev = 0
    for b in boundaries:
        segments.append((prev, b + 1, True))
        prev = b + 1
    if prev < cfg.n_layers:
        segments.append((prev, cfg.n_layers, False))

    if not segments:
        segments = [(0, cfg.n_layers, False)]

    for (a, b, has_shared) in segments:
        sl = jax.tree.map(lambda t: t[a:b], params["mamba"])
        Sseg = cache["ssm"][a:b]
        Tseg = cache["conv"][a:b]

        def seg_body(x, inp):
            p, S, tail = inp
            x, (S2, t2) = mamba_forward(cfg, p, x, dist, state=S,
                                        conv_tail=tail)
            return x, (S2, t2)

        x, (S2, T2) = scan_layers(cfg.analysis_unroll, seg_body, x,
                                  (sl, Sseg, Tseg), b - a)
        outs_S.append(S2)
        outs_tail.append(T2)
        if has_shared:
            ck, cv = cache["k"][sh_i], cache["v"][sh_i]
            x, (k2, v2) = _shared_block(cfg, shared, x, x0, dist, cos, sin,
                                        cache=(ck, cv), cache_at=cur,
                                        kv_len=kv_len)
            ks.append(k2)
            vs.append(v2)
            sh_i += 1

    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    logits = _unembed(cfg, params, x, dist)
    new_cache = {
        "ssm": jnp.concatenate(outs_S, axis=0),
        "conv": jnp.concatenate(outs_tail, axis=0),
        "len": cache["len"] + 1,
    }
    if nsh:
        new_cache["k"] = jnp.stack(ks)
        new_cache["v"] = jnp.stack(vs)
    return logits, new_cache


def prefill(cfg: LMConfig, params, batch: Dict, max_len: int,
            dist: Dist = Dist()):
    """Chunked-SSD prompt processing, returning decode-ready state."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens, dist)
    x0 = x
    B, L, _ = x.shape
    pos = jnp.arange(L)[None, :]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta, cfg.dtype)
    shared = params.get("shared")
    nsh = num_shared_calls(cfg)

    boundaries = [i for i in range(cfg.n_layers)
                  if shared is not None and (i + 1) % cfg.attn_every == 0]
    segments, prev = [], 0
    for b in boundaries:
        segments.append((prev, b + 1, True))
        prev = b + 1
    if prev < cfg.n_layers:
        segments.append((prev, cfg.n_layers, False))
    if not segments:
        segments = [(0, cfg.n_layers, False)]

    Ss, Ts, ks, vs = [], [], [], []
    for (a, b, has_shared) in segments:
        sl = jax.tree.map(lambda t: t[a:b], params["mamba"])

        def seg_body(x, p):
            x, (S2, t2) = mamba_forward(cfg, p, x, dist)
            return x, (S2, t2)

        x, (S2, T2) = scan_layers(cfg.analysis_unroll, seg_body, x, sl,
                                  b - a)
        Ss.append(S2)
        Ts.append(T2)
        if has_shared:
            x, (k2, v2) = _shared_block(cfg, shared, x, x0, dist, cos, sin)
            pad = max_len - L
            ks.append(jnp.pad(k2, ((0, 0), (0, pad), (0, 0), (0, 0))))
            vs.append(jnp.pad(v2, ((0, 0), (0, pad), (0, 0), (0, 0))))

    x = rms_norm(x, params["final_norm"].astype(cfg.dtype), cfg.norm_eps)
    logits = _unembed(cfg, params, x[:, -1:], dist)
    cache = {
        "ssm": jnp.concatenate(Ss, axis=0),
        "conv": jnp.concatenate(Ts, axis=0),
        "len": jnp.full((B,), L, jnp.int32),
    }
    if nsh:
        cache["k"] = jnp.stack(ks)
        cache["v"] = jnp.stack(vs)
    return logits, cache
