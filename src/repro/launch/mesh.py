"""Production mesh builders.  Functions, not module constants — importing
this module never touches jax device state (smoke tests keep 1 device).

Mesh construction goes through repro.jaxcompat so the same code runs on
JAX versions with and without ``jax.sharding.AxisType``.
"""
from __future__ import annotations

import jax

from repro.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n // model) or 1
    return make_mesh((data, model), ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
