"""Serving launcher CLI — continuous batching over a reduced (or full) arch.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models as zoo
from repro.configs import get_config, get_smoke_config
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    if cfg.family in ("encdec",):
        raise SystemExit("serve CLI drives decoder-only archs; "
                         "enc-dec serving needs frames input (see tests)")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        plen = int(rng.integers(4, 20))
        prompt = rng.integers(1, cfg.vocab, size=plen).astype(np.int32)
        eng.submit(Request(uid=uid, prompt=prompt,
                           max_new_tokens=args.max_new, eos_id=-1))
    stats = eng.run()
    dt = time.perf_counter() - t0
    print(f"{stats.completed}/{args.requests} requests, "
          f"{stats.generated_tokens} tokens in {stats.ticks} ticks, "
          f"{dt:.2f}s ({stats.generated_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
