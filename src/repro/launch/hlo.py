"""HLO post-mortem: collective-bytes scrape + three-term roofline.

cost_analysis() reports per-device FLOPs and bytes AFTER SPMD partitioning
(verified against hand-computed shards), but has no collective entry — so we
parse the optimized HLO text and sum the bytes every collective moves.

Per-device wire-bytes model (ring algorithms, group size N):
  all-reduce        2 (N-1)/N x buffer
  all-gather        (N-1)/N x output
  reduce-scatter    (N-1)/N x input  ~= (N-1) x output
  all-to-all        (N-1)/N x buffer
  collective-permute  1 x buffer

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


@dataclasses.dataclass
class Collective:
    kind: str
    bytes_buffer: int            # per-device buffer size in the HLO
    group_size: int
    wire_bytes: float            # per-device bytes on the wire (ring model)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _wire_bytes(kind: str, buf: int, n: int) -> float:
    if kind == "collective-permute":
        return float(buf)        # point-to-point: group size is irrelevant
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * f * buf
    if kind == "all-gather":
        return f * buf                     # buf = gathered output
    if kind == "reduce-scatter":
        return (n - 1) * buf               # buf = scattered output
    if kind == "all-to-all":
        return f * buf
    if kind == "collective-permute":
        return float(buf)
    return float(buf)


def parse_collectives(hlo_text: str, default_group: int = 1) -> List[Collective]:
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        buf = _shape_bytes(shape_str)
        g = _GROUPS_RE.search(line)
        if g:
            n = g.group(1).count(",") + 1
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else default_group
        out.append(Collective(kind, buf, n, _wire_bytes(kind, buf, n)))
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    collectives: Optional[Dict[str, float]] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(compiled, mesh_devices: int, model_flops: float = 0.0,
             cost: Optional[dict] = None, hlo: Optional[str] = None) -> Roofline:
    from repro.jaxcompat import cost_analysis
    ca = cost or cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo if hlo is not None else compiled.as_text()
    colls = parse_collectives(text, default_group=mesh_devices)
    cbytes = sum(c.wire_bytes for c in colls)
    per_kind: Dict[str, float] = {}
    for c in colls:
        per_kind[c.kind] = per_kind.get(c.kind, 0.0) + c.wire_bytes
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": cbytes / ICI_BW,
    }
    bott = max(terms, key=terms.get)
    useful = (model_flops / (flops * mesh_devices)
              if flops > 0 and model_flops else 0.0)
    return Roofline(
        flops_per_device=flops, hbm_bytes_per_device=hbm,
        collective_bytes_per_device=cbytes,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], bottleneck=bott,
        model_flops=model_flops, useful_ratio=useful,
        collectives=per_kind,
    )


def model_flops_for(cfg, shape) -> float:
    """6*N*D accounting (N = params, active params for MoE; D = tokens)."""
    n = cfg.params_count()
    if cfg.n_experts:
        per_exp = 3 * cfg.d_model * cfg.expert_d_ff
        moe_layers = cfg.n_layers - cfg.first_dense_layers
        routed_total = moe_layers * cfg.n_experts * per_exp
        routed_active = moe_layers * cfg.top_k * per_exp
        n = n - routed_total + routed_active
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch                    # decode: one token each
    return 2.0 * n * tokens
