"""Exact roofline accounting via structural extrapolation.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip count
(verified on XLA:CPU — benchmarks/artifacts keep the probe), so a scanned
64-layer model reports ~1 layer of FLOPs, and collectives inside the layer
loop are similarly undercounted.  Rather than unrolling 61-layer models
(compile blowup), we exploit linearity: every per-layer quantity Q satisfies

    Q_total = A + n_body * q_body            (A = embed/unembed/loss/...)

so TWO shallow probe lowerings (depth 1 and 2, scans fully unrolled,
microbatches=1 — microbatching repartitions but does not change totals)
recover A and q exactly:  Q_total = (2 - L) * Q1 + (L - 1) * Q2.

Hybrid stacks (zamba2: mamba + shared-attn; xlstm: mLSTM + sLSTM;
MoE: first-dense + moe) need one extra probe per extra body type; the
coefficients below solve each family's linear system.  The sLSTM *time*
scan is corrected analytically (its recurrent einsum is the only in-loop
term; everything else is vectorized over time).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


from repro.models.common import LMConfig, ShapeCfg


def probe_plan(cfg: LMConfig, shape: ShapeCfg) -> List[Tuple[LMConfig, float]]:
    """Return [(probe_cfg, coefficient)] with sum(coef * Q(probe)) = Q_total."""
    L = cfg.n_layers

    def rep(**kw):
        base = dict(analysis_unroll=True, remat=cfg.remat)
        base.update(kw)
        return dataclasses.replace(cfg, **base)

    if cfg.family in ("dense", "vlm"):
        return [(rep(n_layers=1), 2.0 - L), (rep(n_layers=2), L - 1.0)]

    if cfg.family == "moe":
        # total = A + dense_first + (L-1) * moe:  P0 = A + d;  P1 = A + d + m.
        nmoe = L - cfg.first_dense_layers
        return [(rep(n_layers=1, first_dense_layers=1), 1.0 - nmoe),
                (rep(n_layers=2, first_dense_layers=1), float(nmoe))]

    if cfg.family == "encdec":
        # enc and dec stacks share the depth; both scale together.
        return [(rep(n_layers=1, n_enc_layers=1), 2.0 - L),
                (rep(n_layers=2, n_enc_layers=2), L - 1.0)]

    if cfg.family == "hybrid":
        # total = A + n_mamba * m + n_shared * s.
        ns = sum(1 for i in range(L) if (i + 1) % cfg.attn_every == 0)
        p0 = rep(n_layers=1, attn_every=10_000)       # A + m
        p1 = rep(n_layers=2, attn_every=10_000)       # A + 2m
        p2 = rep(n_layers=2, attn_every=2)            # A + 2m + s
        # A = 2P0 - P1; m = P1 - P0; s = P2 - P1.
        cA, cm_, cs = 1.0, float(L), float(ns)
        return [(p0, 2 * cA - cm_), (p1, cm_ - cA - cs), (p2, cs)]

    if cfg.family == "ssm":                            # xlstm
        kinds = [1 if (i + 1) % cfg.slstm_every == 0 else 0
                 for i in range(L)] if cfg.slstm_every else [0] * L
        n_s = sum(kinds)
        n_m = L - n_s
        p0 = rep(n_layers=1, slstm_every=0)            # A + m
        p1 = rep(n_layers=2, slstm_every=0)            # A + 2m
        probes = [(p0, 2.0 - n_m), (p1, n_m - 1.0)]
        if n_s:
            p2 = rep(n_layers=2, slstm_every=2)        # A + m + s
            # total += n_s * s = n_s * (P2 - P0)
            probes = [(p0, 2.0 - n_m - n_s), (p1, n_m - 1.0), (p2, float(n_s))]
        return probes

    raise ValueError(cfg.family)


def slstm_time_flops(cfg: LMConfig, shape: ShapeCfg, devices: int) -> float:
    """Analytic add-on: the sLSTM recurrent einsum runs once per TIME step
    inside a lax.scan (body counted once by the probes).  Per step per row:
    H heads x (P x 4P) block-diagonal matvec."""
    if cfg.family != "ssm" or not cfg.slstm_every:
        return 0.0
    n_s = sum(1 for i in range(cfg.n_layers)
              if (i + 1) % cfg.slstm_every == 0)
    H = cfg.n_heads
    P = cfg.d_model // H
    T = shape.seq_len if shape.kind in ("train", "prefill") else 1
    tokens = shape.global_batch * T
    flops = n_s * tokens * H * P * (4 * P) * 2
    if shape.kind == "train":
        flops *= 3                                   # fwd + bwd(2x)
    return flops / devices


def combine(probes_results: List[Tuple[Dict, float]]) -> Dict:
    """Linear combination of probe measurements (flops/bytes/collectives)."""
    out = {"flops": 0.0, "bytes": 0.0, "collectives": {}}
    for meas, coef in probes_results:
        out["flops"] += coef * meas["flops"]
        out["bytes"] += coef * meas["bytes"]
        for k, v in meas["collectives"].items():
            out["collectives"][k] = out["collectives"].get(k, 0.0) + coef * v
    out["flops"] = max(out["flops"], 0.0)
    out["bytes"] = max(out["bytes"], 0.0)
    out["collectives"] = {k: max(v, 0.0)
                          for k, v in out["collectives"].items()}
    return out
