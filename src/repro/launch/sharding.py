"""Input/cache PartitionSpecs per (arch family x shape kind).

Rules (DESIGN.md §5):
  * batch dims shard over ('pod','data') when divisible, else replicate;
  * KV caches shard batch normally; the long-context B=1 shape switches to
    SEQUENCE sharding of the cache (SP) — attention over an S-sharded cache
    is handled by GSPMD (the softmax reductions pick up all-reduces);
  * SSM/xLSTM recurrent states shard batch when possible, else heads when
    divisible, else replicate (they are small).
"""
from __future__ import annotations

from typing import Dict, Tuple

from jax.sharding import PartitionSpec as P

from repro.models.common import LMConfig, ShapeCfg
from repro.models.transformer import Dist


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def _axes_size(mesh, axes: Tuple[str, ...]) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def batch_dim_spec(B: int, dist: Dist):
    """The sharding of a leading batch dim, or None when not divisible."""
    bs = _axes_size(dist.mesh, dist.batch_axes)
    if _div(B, bs):
        return dist.batch
    # Try data axis alone (e.g. B=16 on a 2x16x16 mesh).
    if "data" in dist.mesh.axis_names and _div(B, dist.mesh.shape["data"]):
        return "data"
    return None


def input_sharding_specs(cfg: LMConfig, shape: ShapeCfg, dist: Dist) -> Dict:
    B = shape.global_batch
    b = batch_dim_spec(B, dist)
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": P(b, None)}
        if shape.kind == "train":
            specs["labels"] = P(b, None)
        if cfg.family == "encdec":
            specs["frames"] = P(b, None, None)
        if cfg.family == "vlm":
            specs["patches"] = P(b, None, None)
        return specs
    return {"tokens": P(b, None), "cache": cache_specs(cfg, shape, dist)}


def cache_specs(cfg: LMConfig, shape: ShapeCfg, dist: Dist) -> Dict:
    B = shape.global_batch
    b = batch_dim_spec(B, dist)
    long_ctx = b is None               # B too small -> sequence-shard
    m = dist.model_axis

    def heads_spec(h):
        if _div(h, dist.mesh.shape[m]):
            return m
        return None

    def kv_seq_spec():
        """S-dim sharding of a KV cache.  When kv-heads don't divide the TP
        axis, split the SEQUENCE over 'model' instead (flash-decoding style:
        each shard attends over its KV slice; GSPMD all-reduces the softmax
        stats) — otherwise a replicated cache costs TP-way memory+FLOPs."""
        axes = []
        if (long_ctx and "data" in dist.mesh.axis_names
                and _div(shape.seq_len, dist.mesh.shape["data"])):
            axes.append("data")
        if heads_spec(cfg.n_kv_heads) is None and \
                _div(shape.seq_len, dist.mesh.shape[m]):
            axes.append(m)
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    if cfg.family in ("dense", "moe", "vlm"):
        kv = P(None, b, kv_seq_spec(), heads_spec(cfg.n_kv_heads), None)
        return {"k": kv, "v": kv, "len": P(None)}
    if cfg.family == "encdec":
        kv = P(None, b, kv_seq_spec(), heads_spec(cfg.n_kv_heads), None)
        xkv = P(None, b, None, heads_spec(cfg.n_kv_heads), None)
        return {"k": kv, "v": kv, "xk": xkv, "xv": xkv,
                "len": P(None), "xlen": P(None)}
    if cfg.family == "hybrid":
        din = cfg.ssm_expand * cfg.d_model
        H = din // cfg.ssm_head_dim
        # States live model-sharded on heads: the in/out projections are
        # TP-sharded on din = H*P, so a replicated state forces a gather +
        # re-scatter around every recurrent update.
        specs = {
            "ssm": P(None, b, heads_spec(H), None, None),
            "conv": P(None, b, None, heads_spec(cfg.ssm_expand * cfg.d_model
                                                + 2 * cfg.ssm_state)),
            "len": P(None),
        }
        from repro.models.ssm import num_shared_calls
        if num_shared_calls(cfg):
            kv = P(None, b, kv_seq_spec(), heads_spec(cfg.n_kv_heads), None)
            specs["k"] = kv
            specs["v"] = kv
        return specs
    if cfg.family == "ssm":           # xlstm
        din = (cfg.ssm_expand or 2) * cfg.d_model
        Pm = din // cfg.n_heads                      # mLSTM head width
        Ps = cfg.d_model // cfg.n_heads              # sLSTM head width
        # The matrix memory C (B,H,Pk,Pv) follows the TP sharding of the
        # q/k/v projections (din over 'model'): shard the value dim so the
        # recurrent update is local (a replicated state all-gathers 256 MB
        # x 48 layers per decode step — measured).
        pv = m if _div(Pm, dist.mesh.shape[m]) else None
        ps = m if _div(Ps, dist.mesh.shape[m]) else None
        st = P(None, b, None, ps)
        # P_v sharding measured 8.4x cheaper than P_k sharding (the k (x) v
        # update stays local; P_k sharding makes XLA re-gather the state).
        return {
            "mC": P(None, b, None, None, pv),
            "mn": P(None, b, None, pv), "len": P(None),
            "sh": st, "sc": st, "sn": st, "sm": st,
        }
    raise ValueError(cfg.family)


def decode_cache_present_keys(cfg: LMConfig) -> Tuple[str, ...]:
    if cfg.family in ("dense", "moe", "vlm"):
        return ("k", "v", "len")
    if cfg.family == "encdec":
        return ("k", "v", "xk", "xv", "len", "xlen")
    if cfg.family == "hybrid":
        from repro.models.ssm import num_shared_calls
        base = ("ssm", "conv", "len")
        return base + (("k", "v") if num_shared_calls(cfg) else ())
    if cfg.family == "ssm":
        from repro.models.xlstm import _layer_kinds
        base = ("mC", "mn", "len")
        if "s" in _layer_kinds(cfg):
            base = base + ("sh", "sc", "sn", "sm")
        return base
    raise ValueError(cfg.family)
