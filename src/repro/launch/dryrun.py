import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): .lower().compile() every
(architecture x input shape x mesh) cell, dump memory/cost/roofline
artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--out benchmarks/artifacts]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Each cell writes <out>/<mesh>/<arch>__<shape>.json with:
  memory_analysis (bytes/device), cost_analysis (FLOPs, bytes), the
  collective schedule (per-kind wire bytes), and the three roofline terms.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import jaxcompat
from repro import models as zoo
from repro.configs import (ARCHS, get_config, input_specs, skip_reason)
from repro.launch.hlo import model_flops_for, roofline
from repro.launch.mesh import batch_axes_of, make_production_mesh
from repro.launch.sharding import (batch_dim_spec, cache_specs,
                                   input_sharding_specs)
from repro.models.common import SHAPES
from repro.models.transformer import Dist
from repro.train import optim
from repro.train.step import make_train_step


def build_dist(mesh, cfg, shape) -> Dist:
    axes = batch_axes_of(mesh)
    fsdp = (("data", "pod") if (cfg.fsdp_over_pod and "pod" in mesh.axis_names)
            else ())
    probe = Dist(mesh, batch_axes=axes, fsdp_axes=fsdp)
    if batch_dim_spec(shape.global_batch, probe) is None:
        return Dist(mesh, batch_axes=(), seq_shard=True, fsdp_axes=fsdp)
    return probe


def lower_cell(arch: str, shape_name: str, multi_pod: bool, cfg=None,
               microbatches=None):
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = build_dist(mesh, cfg, shape)
    ns = lambda s: NamedSharding(mesh, s)

    params_abs = jax.eval_shape(
        lambda: zoo.init_params(cfg, jax.random.PRNGKey(0)))
    if shape.kind != "train":
        # Serving runs on bf16 weights (fp32 masters are a training concern).
        params_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            params_abs)
    pspecs = zoo.param_specs(cfg, dist)
    tp_weight_bytes = cfg.params_count() * 2 / mesh.shape[dist.model_axis]
    if shape.kind != "train" and tp_weight_bytes <= 8 * 2**30:
        # Serving keeps weights TP-sharded but NOT FSDP-sharded: a decode
        # step re-gathers every FSDP shard for one token of compute (the
        # all-gathers dominated the xlstm decode baseline).  bf16 weights
        # replicated across 'data' fit serving HBM comfortably — EXCEPT at
        # 1T params (kimi), where expert shards must stay FSDP-sharded.
        def strip_fsdp(spec):
            from jax.sharding import PartitionSpec
            clean = []
            for entry in spec:
                if entry in ("data", "pod"):
                    clean.append(None)
                elif isinstance(entry, tuple):
                    kept = tuple(a for a in entry if a not in ("data", "pod"))
                    clean.append(kept if kept else None)
                else:
                    clean.append(entry)
            return PartitionSpec(*clean)
        pspecs = jax.tree.map(strip_fsdp, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    p_shard = jax.tree.map(ns, pspecs)
    batch_abs = input_specs(cfg, shape)
    in_sh = input_sharding_specs(cfg, shape, dist)

    if shape.kind == "train":
        opt_cfg = optim.for_model(cfg)
        opt_abs = jax.eval_shape(
            lambda p: optim.init_opt_state(opt_cfg, p), params_abs)
        o_shard = jax.tree.map(ns, optim.opt_state_specs(opt_cfg, pspecs))
        b_shard = {k: ns(v) for k, v in in_sh.items()}
        mb = (microbatches if microbatches is not None
              else (cfg.train_microbatches or shape.microbatches))
        step = make_train_step(cfg, dist, opt_cfg, microbatches=mb)
        fn = jax.jit(step,
                     in_shardings=(p_shard, o_shard, None, b_shard),
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_abs, opt_abs, None, batch_abs)
    elif shape.kind == "prefill":
        b_shard = {k: ns(v) for k, v in in_sh.items()}
        csp = cache_specs(cfg, shape, dist)
        cache_abs = jax.eval_shape(
            lambda: zoo.init_cache(cfg, shape.global_batch, shape.seq_len))
        c_shard = {k: ns(csp[k]) for k in cache_abs}
        logits_shard = ns(P(dist.batch, None, dist.model_axis))
        fn = jax.jit(
            lambda p, b: zoo.prefill(cfg, p, b, shape.seq_len, dist),
            in_shardings=(p_shard, b_shard),
            out_shardings=(logits_shard, c_shard))
        lowered = fn.lower(params_abs, batch_abs)
    else:                                  # decode
        cache_abs = batch_abs["cache"]
        csp = cache_specs(cfg, shape, dist)
        c_shard = {k: ns(csp[k]) for k in cache_abs}
        t_shard = ns(in_sh["tokens"])
        logits_shard = ns(P(dist.batch, None, dist.model_axis))
        fn = jax.jit(
            lambda p, t, c: zoo.decode_step(cfg, p, t, c, dist),
            in_shardings=(p_shard, t_shard, c_shard),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(2,))
        lowered = fn.lower(params_abs, batch_abs["tokens"], cache_abs)
    return cfg, shape, mesh, lowered


def measure_probe(cfg, arch, shape_name, multi_pod):
    """Probe lowering -> per-device {flops, bytes, collectives-per-kind}."""
    from repro.launch.hlo import parse_collectives
    # microbatches=1: grad accumulation repartitions the same total compute,
    # and the mb loop is a scan (counted once) — probes must bypass it.
    _, _, mesh, lowered = lower_cell(arch, shape_name, multi_pod, cfg=cfg,
                                     microbatches=1)
    compiled = lowered.compile()
    ca = jaxcompat.cost_analysis(compiled)
    colls = parse_collectives(compiled.as_text(), default_group=mesh.size)
    per_kind = {}
    for c in colls:
        per_kind[c.kind] = per_kind.get(c.kind, 0.0) + c.wire_bytes
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collectives": per_kind}


def corrected_cost(arch, shape_name, multi_pod, devices):
    """Structural extrapolation (launch/analysis.py) over probe lowerings."""
    from repro.launch.analysis import combine, probe_plan, slstm_time_flops
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    results = []
    for pcfg, coef in probe_plan(cfg, shape):
        results.append((measure_probe(pcfg, arch, shape_name, multi_pod),
                        coef))
    out = combine(results)
    out["flops"] += slstm_time_flops(cfg, shape, devices)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             keep_hlo: bool = False, exact: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    skip = skip_reason(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if skip:
        rec.update({"status": "skipped", "reason": skip})
        return rec
    t0 = time.time()
    try:
        cfg, shape, mesh, lowered = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = jaxcompat.cost_analysis(compiled)
        hlo = compiled.as_text()
        rf = roofline(compiled, mesh.size,
                      model_flops_for(cfg, shape), cost, hlo)
        if exact:
            # Correct the scan-body-counted-once undercount (analysis.py).
            cc = corrected_cost(arch, shape_name, multi_pod, mesh.size)
            from repro.launch import hlo as H
            cbytes = sum(cc["collectives"].values())
            terms = {"compute": cc["flops"] / H.PEAK_FLOPS,
                     "memory": cc["bytes"] / H.HBM_BW,
                     "collective": cbytes / H.ICI_BW}
            mf = model_flops_for(cfg, shape)
            rf = H.Roofline(
                flops_per_device=cc["flops"],
                hbm_bytes_per_device=cc["bytes"],
                collective_bytes_per_device=cbytes,
                compute_s=terms["compute"], memory_s=terms["memory"],
                collective_s=terms["collective"],
                bottleneck=max(terms, key=terms.get),
                model_flops=mf,
                useful_ratio=(mf / (cc["flops"] * mesh.size)
                              if cc["flops"] else 0.0),
                collectives=cc["collectives"],
            )
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "devices": mesh.size,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": (mem.argument_size_in_bytes
                                        + mem.output_size_in_bytes
                                        + mem.temp_size_in_bytes
                                        - mem.alias_size_in_bytes),
            },
            "cost": {k: cost[k] for k in ("flops", "bytes accessed")
                     if k in cost},
            "roofline": rf.as_dict(),
        })
        if keep_hlo:
            hpath = os.path.join(outdir, mesh_name,
                                 f"{arch}__{shape_name}.hlo.txt")
            with open(hpath, "w") as f:
                f.write(hlo)
    except Exception as e:                                 # noqa: BLE001
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--no-exact", action="store_true",
                    help="skip probe lowerings (compile-proof only; the "
                         "roofline table is single-pod per the spec)")
    args = ap.parse_args()

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    os.makedirs(os.path.join(args.out, mesh_name), exist_ok=True)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for a in archs:
            for s in shapes:
                cells.append((a, s))

    ok = skipped = failed = 0
    for arch, shape in cells:
        rec = run_cell(arch, shape, args.multi_pod, args.out, args.keep_hlo,
                       exact=not args.no_exact)
        path = os.path.join(args.out, mesh_name, f"{arch}__{shape}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        st = rec["status"]
        ok += st == "ok"
        skipped += st == "skipped"
        failed += st == "error"
        extra = ""
        if st == "ok":
            pk = rec["memory"]["peak_estimate_bytes"] / 2**30
            extra = (f" peak={pk:.2f}GiB/dev "
                     f"bottleneck={rec['roofline']['bottleneck']}")
        if st == "error":
            extra = " " + rec["error"][:160]
        print(f"[{st:7s}] {arch:22s} {shape:12s} {mesh_name}{extra}",
              flush=True)
    print(f"\ndry-run {mesh_name}: {ok} ok, {skipped} skipped, "
          f"{failed} failed")
    return 0 if failed == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
