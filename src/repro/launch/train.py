"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 20 --smoke [--ckpt-dir /tmp/ck] [--resume]

--smoke runs the arch's reduced config on the local device(s) — the same
code path the pod runs with the full config under make_production_mesh.
Checkpoints are atomic step directories; --resume restores the latest and
replays the deterministic data stream from that step.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import models as zoo
from repro.configs import get_config, get_smoke_config
from repro.models.common import ShapeCfg
from repro.models.transformer import Dist
from repro.train import (CheckpointManager, batch_at_step, init_opt_state,
                         make_train_step, optim)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    shape = ShapeCfg("cli", args.seq_len, args.batch, "train",
                     microbatches=args.microbatches)
    dist = Dist()                                   # local; pods use mesh.py

    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = optim.for_model(cfg)
    opt_cfg = dataclasses.replace(opt_cfg, lr=args.lr)
    state = init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, dist, opt_cfg,
                                      microbatches=args.microbatches))

    ck = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ck and args.resume and ck.latest_step() is not None:
        start = ck.latest_step()
        restored, _ = ck.restore(start, {"p": params, "o": state})
        params, state = restored["p"], restored["o"]
        print(f"resumed from step {start}")

    t0 = time.perf_counter()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in batch_at_step(cfg, shape, s).items()}
        params, state, _, m = step_fn(params, state, None, batch)
        if s % max(1, args.steps // 10) == 0 or s == args.steps - 1:
            print(f"step {s:5d} loss {float(m['loss']):8.4f} "
                  f"|g| {float(m['grad_norm']):8.3f}")
        if ck and (s + 1) % args.ckpt_every == 0:
            ck.save(s + 1, {"p": params, "o": state})
    if ck:
        ck.wait()
    toks = (args.steps - start) * args.batch * args.seq_len
    dt = time.perf_counter() - t0
    print(f"done: {toks} tokens in {dt:.1f}s ({toks / dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
