"""Edge network substrate (paper Sec. III-A, VI-A).

An EdgeNetwork holds the server fleet (heterogeneous A/B/C SKUs per Table II),
their connectivity W, and all unit-cost parameters:

  mu[v, i]     client-v -> server-i upload cost         (distance-based)
  tau[i, j]    per-unit cross-edge traffic cost          (distance-based)
  alpha/beta/gamma[i]  GNN compute coefficients          (profiled per SKU)
  rho[i], eps[i]       maintenance costs                 (Gaussian, [100])

The same class doubles as the TPU-pod abstraction: servers = mesh slices,
tau = ICI/DCN hop cost, alpha = per-device step-time coefficient (used by the
straggler-mitigation runtime).  See DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.graphs.datagraph import DataGraph
from repro.graphs.kmeans import kmeans

# Table II SKU profile -> relative compute-cost multipliers.  Profiled offline
# on the paper's three machine classes (weak/moderate/powerful); the absolute
# scale is folded into alpha/beta/gamma units.
SKU_PROFILES = {
    "A": {"compute_scale": 1.00},   # 3.40GHz i7-6700, 4GB  (weak)
    "B": {"compute_scale": 0.60},   # 3.40GHz i7-6700, 8GB  (moderate)
    "C": {"compute_scale": 0.25},   # 3.70GHz W-2145, 32GB  (powerful)
}

# Base per-op unit costs for a type-A machine (arbitrary cost units; the paper
# profiles operator-wise wall time and folds a price factor in).
_BASE_ALPHA = 2.0e-4   # vector-add per element
_BASE_BETA = 1.0e-4    # matvec MAC
_BASE_GAMMA = 5.0e-5   # activation per element

# Fixed sentinel for unusable routes (failed server, disconnected pair).
# A CONSTANT, not derived from the current tau: deriving BIG from
# max(tau) re-inflates it by 1e6 on every successive failure (the previous
# sentinel is already the max), overflowing float64 after a handful of
# events and corrupting the flow solver's integer quantization.  1e12 is
# ~9 orders above any realistic tau/mu unit cost in this repo, and the
# sentinel never reaches the quantizer anyway: dead servers have w=0, so
# `pairs` excludes them from every sweep and orphans are reassigned before
# a solve.
OFFLINE_COST = 1.0e12


@dataclasses.dataclass
class EdgeNetwork:
    m: int
    w: np.ndarray                # (m, m) {0,1} connectivity
    tau: np.ndarray              # (m, m) unit traffic cost (BIG if w=0)
    alpha: np.ndarray            # (m,)
    beta: np.ndarray             # (m,)
    gamma: np.ndarray            # (m,)
    rho: np.ndarray              # (m,)
    eps: np.ndarray              # (m,)
    mu: np.ndarray               # (n, m) upload cost per client
    sku: Optional[np.ndarray] = None      # (m,) of 'A'|'B'|'C'
    coords: Optional[np.ndarray] = None   # (m, 2) server locations

    @property
    def pairs(self) -> np.ndarray:
        """Connected server pairs (i < j)."""
        ii, jj = np.where(np.triu(self.w, 1) > 0)
        return np.stack([ii, jj], axis=1)

    def degrade(self, i: int, factor: float) -> "EdgeNetwork":
        """Model a straggler: server i's compute coefficients scale up."""
        net = dataclasses.replace(
            self,
            alpha=self.alpha.copy(),
            beta=self.beta.copy(),
            gamma=self.gamma.copy(),
        )
        net.alpha[i] *= factor
        net.beta[i] *= factor
        net.gamma[i] *= factor
        return net

    def without_server(self, i: int) -> "EdgeNetwork":
        """Model a node failure: disconnect server i (tau -> OFFLINE_COST,
        w -> 0).  Idempotent, and repeated failures of DIFFERENT servers
        write the same fixed sentinel — costs stay finite and bit-stable no
        matter how many on_failure events stack up."""
        w = self.w.copy()
        tau = self.tau.copy()
        mu = self.mu.copy()
        w[i, :] = 0
        w[:, i] = 0
        tau[i, :] = OFFLINE_COST
        tau[:, i] = OFFLINE_COST
        mu[:, i] = OFFLINE_COST
        return dataclasses.replace(self, w=w, tau=tau, mu=mu)


def build_edge_network(
    graph: DataGraph,
    num_servers: int,
    seed: int = 0,
    mu_factor: float = 0.05,
    tau_factor: float = 0.5,
    rho_mean: float = 0.5,
    rho_std: float = 0.1,
    eps_mean: float = 5.0,
    eps_std: float = 1.0,
    connectivity: float = 1.0,
) -> EdgeNetwork:
    """Construct the heterogeneous fleet per the paper's methodology:

    - Server locations = k-means pivots over client coordinates (Sec. VI-A).
    - SKU labels round-robin A/B/C in equal proportion, remainders assigned in
      priority A, B, C (Sec. VI-A "Methodology").
    - mu = mu_factor * distance(client, server); tau = tau_factor * distance.
      tau_factor defaults high enough that cross-edge traffic dominates the
      total cost — the regime the paper reports ("the cross-edge traffic cost
      contributes a majority of the total system cost", Sec. VI-B).
    - rho/eps drawn from a Gaussian process (hourly electricity price, [100]).
    """
    rng = np.random.default_rng(seed)
    assert graph.coords is not None, "data graph needs client coordinates"
    centers, _ = kmeans(graph.coords, num_servers, seed=seed)

    # SKU assignment in equal proportion with A,B,C priority on remainders.
    skus = []
    base, rem = divmod(num_servers, 3)
    counts = {"A": base, "B": base, "C": base}
    for t in ["A", "B", "C"][:rem]:
        counts[t] += 1
    for t in ["A", "B", "C"]:
        skus += [t] * counts[t]
    skus = np.array(skus[:num_servers])
    rng.shuffle(skus)

    scale = np.array([SKU_PROFILES[t]["compute_scale"] for t in skus])
    alpha = _BASE_ALPHA * scale
    beta = _BASE_BETA * scale
    gamma = _BASE_GAMMA * scale
    rho = np.abs(rng.normal(rho_mean, rho_std, size=num_servers)) * scale
    eps = np.abs(rng.normal(eps_mean, eps_std, size=num_servers))

    # Distances.
    d_cs = np.linalg.norm(
        graph.coords[:, None, :] - centers[None, :, :], axis=-1
    )  # (n, m)
    d_ss = np.linalg.norm(centers[:, None, :] - centers[None, :, :], axis=-1)
    mu = mu_factor * d_cs
    tau = tau_factor * d_ss
    np.fill_diagonal(tau, 0.0)

    # Connectivity: city WAN is (near-)fully connected; optionally sparsify.
    w = np.ones((num_servers, num_servers), dtype=np.int64)
    np.fill_diagonal(w, 0)
    if connectivity < 1.0:
        drop = rng.uniform(size=(num_servers, num_servers)) > connectivity
        drop = np.triu(drop, 1)
        drop = drop | drop.T
        w[drop] = 0
        # Keep the graph connected via a ring.
        for i in range(num_servers):
            j = (i + 1) % num_servers
            w[i, j] = w[j, i] = 1
    big = tau[w > 0].max() * 1e6 if (w > 0).any() else 1e12
    tau = np.where(w > 0, tau, big)
    np.fill_diagonal(tau, 0.0)

    return EdgeNetwork(
        m=num_servers, w=w, tau=tau, alpha=alpha, beta=beta, gamma=gamma,
        rho=rho, eps=eps, mu=mu, sku=skus, coords=centers,
    )


def pod_edge_network(
    num_slices: int,
    vertices: int,
    pods: int = 1,
    link_cost: float = 1.0,
    cross_pod_factor: float = 4.0,
    seed: int = 0,
) -> EdgeNetwork:
    """TPU-pod flavoured EdgeNetwork: slices are homogeneous, tau is the
    ICI hop cost (cross-pod DCN hops cost `cross_pod_factor` more).  Used by
    the runtime layer (expert layout, straggler re-balance)."""
    rng = np.random.default_rng(seed)
    per_pod = num_slices // max(pods, 1)
    pod_of = np.arange(num_slices) // max(per_pod, 1)
    tau = np.full((num_slices, num_slices), link_cost)
    cross = pod_of[:, None] != pod_of[None, :]
    tau[cross] = link_cost * cross_pod_factor
    np.fill_diagonal(tau, 0.0)
    w = np.ones((num_slices, num_slices), dtype=np.int64)
    np.fill_diagonal(w, 0)
    ones = np.ones(num_slices)
    return EdgeNetwork(
        m=num_slices, w=w, tau=tau,
        alpha=_BASE_ALPHA * ones, beta=_BASE_BETA * ones, gamma=_BASE_GAMMA * ones,
        rho=0.0 * ones, eps=0.0 * ones,
        mu=np.zeros((vertices, num_slices)),
        sku=np.array(["C"] * num_slices),
        coords=rng.uniform(0, 1, size=(num_slices, 2)),
    )
