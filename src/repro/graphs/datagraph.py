"""Data graph substrate.

The *data graph* G = (V, E) is the GNN's input: clients are vertices, their
relationships are links (paper Sec. III-A).  Stored as a canonical undirected
edge list plus a CSR view for fast neighbor iteration.  All host-side
scheduling (GLAD) operates on numpy; the JAX models consume the exported
arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def csr_multirange(indptr: np.ndarray, rows: np.ndarray):
    """Vectorized concatenation of CSR row slices.

    Returns ``(flat, rep)`` where ``flat`` indexes the CSR data arrays for
    the concatenation of slices ``indptr[r]:indptr[r+1]`` over ``rows`` (in
    order), and ``rep[i]`` is the position within ``rows`` that produced
    ``flat[i]``.  O(output) with no Python loop — the shared primitive
    behind neighbor gathers, incident-edge queries and residual BFS.
    """
    rows = np.asarray(rows)
    starts = indptr[rows]
    counts = indptr[rows + 1] - starts
    total = int(counts.sum())
    if total == 0:
        empty = np.zeros(0, dtype=np.int64)
        return empty, empty
    rep = np.repeat(np.arange(len(rows)), counts)
    flat = (np.arange(total)
            - np.repeat(np.cumsum(counts) - counts, counts)
            + starts[rep])
    return flat, rep


def _canonicalize(edges: np.ndarray, n: int) -> np.ndarray:
    """Dedup + sort an undirected edge list; drop self loops."""
    if edges.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    e = e[e[:, 0] != e[:, 1]]
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    key = lo * n + hi
    _, idx = np.unique(key, return_index=True)
    return np.stack([lo[idx], hi[idx]], axis=1)


@dataclasses.dataclass
class DataGraph:
    """Undirected attributed graph (clients + links).

    ``edge_weights`` (optional, aligned with the canonical ``edges`` order)
    generalizes the paper's unit links: C_T charges tau * weight per cut
    link.  Used by the MoE expert-placement mapping (co-activation counts).
    """

    n: int
    edges: np.ndarray                      # (E, 2) canonical u < v
    features: Optional[np.ndarray] = None  # (n, d) float32
    labels: Optional[np.ndarray] = None    # (n,) int64
    coords: Optional[np.ndarray] = None    # (n, 2) client locations
    edge_weights: Optional[np.ndarray] = None   # (E,) aligned with edges

    # CSR views (built lazily)
    _indptr: Optional[np.ndarray] = None
    _indices: Optional[np.ndarray] = None
    _edge_ids: Optional[np.ndarray] = None
    _degrees: Optional[np.ndarray] = None

    def __post_init__(self):
        self.edges = _canonicalize(self.edges, self.n)

    def weights_or_ones(self) -> np.ndarray:
        if self.edge_weights is None:
            return np.ones(len(self.edges))
        return self.edge_weights

    # ------------------------------------------------------------------ CSR
    def _build_csr(self) -> None:
        E = len(self.edges)
        src = np.concatenate([self.edges[:, 0], self.edges[:, 1]])
        dst = np.concatenate([self.edges[:, 1], self.edges[:, 0]])
        eid = np.concatenate([np.arange(E), np.arange(E)])
        # Sort by (src, dst) — not just src — so every row's neighbor list
        # is ascending.  The layout engine relies on this: auxiliary-graph
        # arcs gathered row-by-row are then already in canonical (row, col)
        # order and the flow-CSR assembly skips its per-solve lexsort.
        order = np.lexsort((dst, src))
        src, dst, eid = src[order], dst[order], eid[order]
        self._indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(self._indptr, src + 1, 1)
        self._indptr = np.cumsum(self._indptr)
        self._indices = dst
        self._edge_ids = eid

    @property
    def indptr(self) -> np.ndarray:
        if self._indptr is None:
            self._build_csr()
        return self._indptr

    @property
    def indices(self) -> np.ndarray:
        if self._indices is None:
            self._build_csr()
        return self._indices

    @property
    def edge_ids(self) -> np.ndarray:
        """Undirected edge index aligned with ``indices``: entry k says which
        row of ``edges`` produced the CSR slot k (each edge appears twice)."""
        if self._edge_ids is None:
            self._build_csr()
        return self._edge_ids

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        if self._degrees is None:
            deg = np.zeros(self.n, dtype=np.int64)
            if self.num_edges:
                np.add.at(deg, self.edges[:, 0], 1)
                np.add.at(deg, self.edges[:, 1], 1)
            self._degrees = deg
        return self._degrees

    def release_views(self) -> None:
        """Drop the lazily-built CSR/degree caches (``indptr``/``indices``/
        ``edge_ids``/``degrees``).

        They are pure deterministic functions of ``edges`` — the next
        property access rebuilds them BITWISE identical — so releasing is
        always safe; it only trades a rebuild (one ``lexsort`` over the
        directed edge list) for the ~40B/edge the views hold resident.
        The streamed coarsening build calls this on every level it has
        finished with: at the SIoT edge density a level's CSR is over half
        its retained footprint, and the hierarchy's edge count shrinks far
        slower than its vertex count, so a fully-cached hierarchy would
        dominate peak RSS no matter how bounded the transients are."""
        self._indptr = None
        self._indices = None
        self._edge_ids = None
        self._degrees = None

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def incident_edge_ids(self, vertices: np.ndarray) -> np.ndarray:
        """Edge ids with >=1 endpoint in ``vertices``, each id once.

        Vectorized multi-range gather over the CSR slices of ``vertices``
        (O(sum deg) — no per-vertex Python loop, no scan of the edge list).
        """
        vertices = np.asarray(vertices, dtype=np.int64)
        if len(vertices) == 0 or self.num_edges == 0:
            return np.zeros(0, dtype=np.int64)
        flat, _ = csr_multirange(self.indptr, vertices)
        if len(flat) == 0:
            return np.zeros(0, dtype=np.int64)
        return np.unique(self.edge_ids[flat])

    # ------------------------------------------------------------ mutation
    def with_changes(
        self,
        add_edges: Optional[np.ndarray] = None,
        del_edges: Optional[np.ndarray] = None,
        add_vertices: int = 0,
        del_vertices: Optional[np.ndarray] = None,
        new_features: Optional[np.ndarray] = None,
        new_coords: Optional[np.ndarray] = None,
    ) -> "DataGraph":
        """Return an evolved copy (paper Sec. V-A: vertex/link insert/delete).

        Deleted vertices keep their index slot (isolated, masked) so that
        layout vectors stay aligned across time slots; this mirrors a client
        leaving the service while the id space persists.
        """
        n = self.n + add_vertices
        edges = self.edges
        if del_edges is not None and len(del_edges):
            de = _canonicalize(np.asarray(del_edges), n)
            key = edges[:, 0] * n + edges[:, 1]
            dkey = de[:, 0] * n + de[:, 1]
            edges = edges[~np.isin(key, dkey)]
        if add_edges is not None and len(add_edges):
            edges = np.concatenate([edges, np.asarray(add_edges).reshape(-1, 2)])
        if del_vertices is not None and len(del_vertices):
            dv = np.asarray(del_vertices)
            mask = ~(np.isin(edges[:, 0], dv) | np.isin(edges[:, 1], dv))
            edges = edges[mask]

        feats = self.features
        if feats is not None and add_vertices:
            if new_features is None:
                new_features = np.zeros((add_vertices, feats.shape[1]), feats.dtype)
            feats = np.concatenate([feats, new_features], axis=0)
        coords = self.coords
        if coords is not None and add_vertices:
            if new_coords is None:
                new_coords = coords[
                    np.random.default_rng(0).integers(0, self.n, add_vertices)
                ]
            coords = np.concatenate([coords, new_coords], axis=0)
        labels = self.labels
        if labels is not None and add_vertices:
            labels = np.concatenate([labels, np.zeros(add_vertices, labels.dtype)])
        return DataGraph(n=n, edges=edges, features=feats, labels=labels, coords=coords)


# --------------------------------------------------------------- coarsening
#: Largest cluster count whose packed edge key ``lo * nc + hi`` still fits
#: int64 (isqrt(2^63 - 1)).  Past it the key arithmetic would WRAP
#: silently (numpy int64 overflow raises nothing) and alias distinct
#: coarse edges onto each other.
_MAX_CLUSTER_KEY_N = 3_037_000_499


def _check_cluster_key_domain(num_clusters: int) -> None:
    """Refuse, loudly, cluster counts whose packed keys overflow int64."""
    if num_clusters > _MAX_CLUSTER_KEY_N:
        raise ValueError(
            f"num_clusters={num_clusters} overflows the int64 packed edge "
            f"key domain (lo * num_clusters + hi); max supported is "
            f"{_MAX_CLUSTER_KEY_N}")


def contract_graph(graph: DataGraph, cluster_of: np.ndarray,
                   num_clusters: int) -> DataGraph:
    """Cluster-quotient graph (multilevel coarsening): vertices are the
    clusters, intra-cluster links vanish, parallel inter-cluster links merge
    with SUMMED weights — so tau * weight over the coarse links equals the
    fine C_T of any projected layout exactly.

    The merged edge list is built already canonical (unique lo < hi keys in
    sorted order), so ``edge_weights`` aligns with the post-init
    canonical ``edges`` order by construction.  Deterministic: the per-key
    weight sums are sequential ``np.add.reduceat`` segments over the sorted
    key order.
    """
    _check_cluster_key_domain(num_clusters)
    cluster_of = np.asarray(cluster_of, dtype=np.int64)
    e = graph.edges
    if len(e) == 0:
        return DataGraph(n=num_clusters, edges=np.zeros((0, 2), np.int64))
    w = graph.weights_or_ones().astype(np.float64)
    cu = cluster_of[e[:, 0]]
    cv = cluster_of[e[:, 1]]
    keep = cu != cv
    lo = np.minimum(cu[keep], cv[keep])
    hi = np.maximum(cu[keep], cv[keep])
    key = lo * num_clusters + hi
    order = np.argsort(key, kind="stable")
    ks = key[order]
    if len(ks) == 0:
        return DataGraph(n=num_clusters, edges=np.zeros((0, 2), np.int64))
    ws = w[keep][order]
    uniq, start = np.unique(ks, return_index=True)
    wsum = np.add.reduceat(ws, start)
    if not np.isfinite(wsum).all():
        raise ValueError(
            "contracted edge weight sum overflowed to non-finite; "
            "parallel-edge weights saturated the float64 domain")
    edges = np.stack([uniq // num_clusters, uniq % num_clusters], axis=1)
    g = DataGraph(n=num_clusters, edges=edges)
    g.edge_weights = wsum
    return g


# ---------------------------------------------------------------- synthetic
def synthetic_siot(
    n: int = 8001,
    target_links: int = 33509,
    feat_dim: int = 52,
    seed: int = 0,
    area: float = 10.0,
) -> DataGraph:
    """SIoT-like graph: long-tail degree distribution (paper Fig. 6),
    8001 vertices / 33509 links, 52-d features, binary labels.

    Built with a Barabasi-Albert style preferential-attachment process which
    reproduces the long-tail CDF reported for SIoT.
    """
    rng = np.random.default_rng(seed)
    m = max(1, int(round(target_links / max(n - 1, 1))))  # links per new vertex
    src, dst = [], []
    # Seed clique.
    seed_n = m + 1
    for a in range(seed_n):
        for b in range(a + 1, seed_n):
            src.append(a), dst.append(b)
    targets = list(range(seed_n)) * 2
    for v in range(seed_n, n):
        picks = rng.choice(len(targets), size=m, replace=False)
        chosen = {targets[p] for p in picks}
        for u in chosen:
            src.append(u), dst.append(v)
            targets.append(u)
        targets.extend([v] * len(chosen))
    edges = np.stack([np.array(src), np.array(dst)], axis=1)
    # Trim / top up to the exact target link count.
    g = DataGraph(n=n, edges=edges)
    e = g.edges
    if len(e) > target_links:
        keep = rng.choice(len(e), size=target_links, replace=False)
        e = e[keep]
    while len(e) < target_links:
        extra = rng.integers(0, n, size=(target_links - len(e), 2))
        e = _canonicalize(np.concatenate([e, extra]), n)
    feats = rng.normal(size=(n, feat_dim)).astype(np.float32)
    labels = (feats[:, 0] + 0.5 * feats[:, 1] > 0).astype(np.int64)  # public/private
    coords = rng.uniform(0, area, size=(n, 2)).astype(np.float32)
    return DataGraph(n=n, edges=e, features=feats, labels=labels, coords=coords)


def synthetic_yelp(
    n: int = 3912,
    target_links: int = 4677,
    feat_dim: int = 100,
    seed: int = 1,
    area: float = 10.0,
) -> DataGraph:
    """Yelp-like graph: sparse with many isolated vertices (paper Fig. 6),
    3912 vertices / 4677 links, 100-d features (Word2Vec-like), spam labels.

    Links connect reviews by the same user: we emulate by grouping vertices
    into 'users' with heavy-tailed review counts and forming small cliques.
    """
    rng = np.random.default_rng(seed)
    edges = []
    v = 0
    while v < n:
        c = int(min(n - v, max(1, rng.pareto(2.5) + 1)))
        group = list(range(v, v + c))
        for a in range(len(group)):
            for b in range(a + 1, len(group)):
                edges.append((group[a], group[b]))
        v += c
    edges = np.array(edges, dtype=np.int64) if edges else np.zeros((0, 2), np.int64)
    g = DataGraph(n=n, edges=edges)
    e = g.edges
    if len(e) > target_links:
        keep = rng.choice(len(e), size=target_links, replace=False)
        e = e[keep]
    while len(e) < target_links:
        extra = rng.integers(0, n, size=(target_links - len(e), 2))
        e = _canonicalize(np.concatenate([e, extra]), n)
    feats = rng.normal(size=(n, feat_dim)).astype(np.float32)
    labels = (rng.uniform(size=n) < 0.15).astype(np.int64)  # spam ratio
    # Clients' spatial coords synthesized from a taxi-trace-like mixture
    # ("workload composition", paper Sec. VI-A): dense downtown + sparse tail.
    centers = rng.uniform(0, area, size=(8, 2))
    which = rng.integers(0, 8, size=n)
    coords = centers[which] + rng.normal(scale=0.6, size=(n, 2))
    solitary = rng.uniform(size=n) < 0.1
    coords[solitary] = rng.uniform(-area * 0.3, area * 1.3, size=(solitary.sum(), 2))
    return DataGraph(
        n=n, edges=e, features=feats, labels=labels, coords=coords.astype(np.float32)
    )
