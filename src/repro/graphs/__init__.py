from repro.graphs.datagraph import DataGraph, synthetic_siot, synthetic_yelp
from repro.graphs.edgenet import EdgeNetwork, build_edge_network, pod_edge_network
from repro.graphs.kmeans import kmeans

__all__ = [
    "DataGraph", "synthetic_siot", "synthetic_yelp",
    "EdgeNetwork", "build_edge_network", "pod_edge_network", "kmeans",
]
