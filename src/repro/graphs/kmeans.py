"""Plain Lloyd k-means (paper Sec. VI-A uses k-means on client coordinates to
pick edge-server locations [95][96]).  numpy-only, deterministic given seed."""
from __future__ import annotations

import numpy as np


def kmeans(points: np.ndarray, k: int, iters: int = 50, seed: int = 0):
    """Return (centers (k,d), assign (n,))."""
    rng = np.random.default_rng(seed)
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    if k >= n:
        centers = pts.copy()
        extra = pts[rng.integers(0, n, size=k - n)] if k > n else pts[:0]
        centers = np.concatenate([centers, extra], axis=0)
        return centers, np.arange(n) % k
    # k-means++ style init for stability.
    centers = [pts[rng.integers(0, n)]]
    for _ in range(k - 1):
        d2 = np.min(
            ((pts[:, None, :] - np.array(centers)[None]) ** 2).sum(-1), axis=1
        )
        p = d2 / max(d2.sum(), 1e-12)
        centers.append(pts[rng.choice(n, p=p)])
    centers = np.array(centers)
    assign = np.zeros(n, dtype=np.int64)
    for _ in range(iters):
        d2 = ((pts[:, None, :] - centers[None]) ** 2).sum(-1)
        new_assign = d2.argmin(axis=1)
        if np.array_equal(new_assign, assign) and _ > 0:
            break
        assign = new_assign
        for c in range(k):
            mask = assign == c
            if mask.any():
                centers[c] = pts[mask].mean(axis=0)
    return centers, assign
