"""Flash attention Pallas kernel (blockwise online softmax), GQA-aware.

TPU tiling: the grid walks (batch, q_head, q_block, kv_block) with the
kv_block axis innermost ("arbitrary" semantics) so the running max / sum /
accumulator scratch persists in VMEM across the kv sweep.  Blocks strictly
above the causal diagonal are skipped via pl.when — for long-context decode
(Lq=1) only the prefix up to kv_len is visited numerically.

GQA: kv tiles are indexed by q_head // group_size, so a kv head's tile is
reused by its whole query group without materializing repeats (this is the
memory-term win over the naive repeat-then-attend reference).

Shapes: q (B, Hq, Lq, D); k/v (B, Hkv, Lk, D); kv_len (B,) i32 optional live
length per batch row (padded caches).  D rides whole in each block (<= 256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import jaxcompat

_NEG_INF = -1e30


def _kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, causal, bq, bkv, lq, lk):
    b = pl.program_id(0)
    i = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal: q global row = i*bq + r + (lk - lq); kv col = j*bkv + c.
    q_off = lk - lq
    first_q = i * bq + q_off
    live = kv_len_ref[b]

    def body():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bkv, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                # (bq, bkv)
        q_pos = first_q + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = k_pos < live
        if causal:
            mask &= k_pos <= q_pos
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                                    # (bq, 1)
        m_new = jnp.maximum(m_prev[:, 0], s.max(axis=1))[:, None]
        p = jnp.exp(s - m_new)                                 # (bq, bkv)
        corr = jnp.exp(m_prev - m_new)                         # (bq, 1)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)[:, None]
        pv = jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    if causal:
        # Skip blocks entirely above the diagonal (and past live length).
        pl.when(jnp.logical_and(j * bkv <= first_q + bq - 1, j * bkv < live))(body)
    else:
        pl.when(j * bkv < live)(body)

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "bq", "bkv", "interpret"))
def flash_attention(
    q, k, v, kv_len=None, *, causal: bool = True, scale: float | None = None,
    bq: int = 128, bkv: int = 128, interpret: bool = False,
):
    """Blockwise attention.  Pads Lq/Lk internally; returns (B, Hq, Lq, D)."""
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)

    bq = min(bq, max(8, 1 << (Lq - 1).bit_length()))
    bkv = min(bkv, max(8, 1 << (Lk - 1).bit_length()))
    lq_pad = ((Lq + bq - 1) // bq) * bq
    lk_pad = ((Lk + bkv - 1) // bkv) * bkv
    if lq_pad != Lq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, lq_pad - Lq), (0, 0)))
    if lk_pad != Lk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, lk_pad - Lk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, lk_pad - Lk), (0, 0)))
    if kv_len is None:
        kv_len = jnp.full((B,), Lk, jnp.int32)
    kv_len = kv_len.astype(jnp.int32)

    grid = (B, Hq, lq_pad // bq, lk_pad // bkv)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bkv=bkv,
        lq=Lq, lk=Lk)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j, kvl: (b, h, i, 0)),
                pl.BlockSpec((1, 1, bkv, D),
                             lambda b, h, i, j, kvl: (b, h // group, j, 0)),
                pl.BlockSpec((1, 1, bkv, D),
                             lambda b, h, i, j, kvl: (b, h // group, j, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, D), lambda b, h, i, j, kvl: (b, h, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, lq_pad, D), q.dtype),
        compiler_params=jaxcompat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(kv_len, q, k, v)
    return out[:, :, :Lq]
