"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------- gnn_aggregate (SpMM)
def spmm_ref(values, block_cols, feats, bm: int, bk: int):
    """Block-sparse A @ H oracle.

    values:     (n_dst_blocks * max_blocks, bm, bk) dense link blocks
    block_cols: (n_dst_blocks, max_blocks) source block-row ids (0 pad; padded
                entries have all-zero values so they contribute nothing)
    feats:      (n_src_blocks * bk, d)
    Returns (n_dst_blocks * bm, d).
    """
    n_dst_blocks, max_blocks = block_cols.shape
    d = feats.shape[1]
    out = jnp.zeros((n_dst_blocks * bm, d), feats.dtype)
    vals = values.reshape(n_dst_blocks, max_blocks, bm, bk)
    for i in range(n_dst_blocks):
        acc = jnp.zeros((bm, d), jnp.float32)
        for j in range(max_blocks):
            src = block_cols[i, j]
            blk = jax.lax.dynamic_slice(feats, (src * bk, 0), (bk, d))
            acc = acc + vals[i, j].astype(jnp.float32) @ blk.astype(jnp.float32)
        out = out.at[i * bm:(i + 1) * bm].set(acc.astype(feats.dtype))
    return out


def segment_sum_ref(messages, dst, n: int):
    """Edge-list aggregation oracle: sum messages per destination."""
    return jax.ops.segment_sum(messages, dst, num_segments=n)


# -------------------------------------------------------------- flash attention
def attention_ref(q, k, v, causal: bool = True, scale: float | None = None,
                  kv_len: jnp.ndarray | None = None):
    """Reference softmax attention.

    q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D).  GQA: Hq % Hkv == 0, each kv head
    serves Hq/Hkv query heads.  ``kv_len`` optionally masks the KV suffix
    (decode with a padded cache).
    """
    B, Hq, Lq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    Lk = k.shape[2]
    if causal:
        qi = jnp.arange(Lq)[:, None] + (Lk - Lq)
        ki = jnp.arange(Lk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    if kv_len is not None:
        mask = jnp.arange(Lk)[None, None, None, :] < kv_len[:, None, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v)
