from repro.kernels.ops import (
    BSRAggregate, aggregate_features, attention, on_tpu,
)
from repro.kernels.gnn_aggregate import build_bsr, bsr_density, spmm
from repro.kernels.flash_attention import flash_attention

__all__ = [
    "BSRAggregate", "aggregate_features", "attention", "on_tpu",
    "build_bsr", "bsr_density", "spmm", "flash_attention",
]
