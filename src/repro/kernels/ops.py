"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels run compiled; everywhere else they fall back to
``interpret=True`` (Pallas executes the kernel body in Python — bit-faithful
semantics, CPU speed) or to the jnp reference for big shapes.  The wrappers
are the only entry points the rest of the framework uses, so swapping the
execution path never touches model code.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gnn_aggregate import (
    build_bsr, spmm as _spmm, spmm_jnp as _spmm_jnp)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ----------------------------------------------------------------- attention
def attention(q, k, v, kv_len=None, *, causal: bool = True,
              scale: Optional[float] = None, bq: int = 128, bkv: int = 128,
              impl: str = "auto"):
    """Dispatch: 'pallas' | 'ref' | 'auto' (pallas on TPU, ref elsewhere).

    The ref path is used as the CPU default because interpret-mode Pallas is
    O(python) per block — fine for tests, wrong for the CPU examples.
    """
    if impl == "auto":
        impl = "pallas" if on_tpu() else "ref"
    if impl == "pallas":
        return _flash(q, k, v, kv_len, causal=causal, scale=scale,
                      bq=bq, bkv=bkv, interpret=not on_tpu())
    return _ref.attention_ref(q, k, v, causal=causal, scale=scale,
                              kv_len=kv_len)


# --------------------------------------------------------------- aggregation
class BSRAggregate:
    """Precompiled block-sparse aggregation bound to a fixed graph.

    Usage: build once per (graph, ordering), then call on feature matrices.
    Plugs into gnn.models.forward as the ``aggregate`` argument via
    ``as_aggregate_fn`` (weights=1: plain neighbor sum).
    """

    def __init__(self, src_dst: np.ndarray, n: int, bm: int = 8,
                 bk: int = 128, weights: Optional[np.ndarray] = None):
        self.n = n
        self.bm, self.bk = bm, bk
        vals, cols, self.n_dst_pad, self.n_src_pad = build_bsr(
            src_dst, weights, n, bm, bk)
        self.values = jnp.asarray(vals)
        self.block_cols = jnp.asarray(cols)
        self.stored_blocks = int(cols.size)
        self.nnz_density = float((vals != 0).mean())

    def __call__(self, feats: jnp.ndarray, impl: str = "auto") -> jnp.ndarray:
        """feats (n, d) -> (n, d) aggregated by incoming links.

        ``impl``: 'pallas' (the kernel; interpret-mode off TPU), 'jnp' (the
        vectorized gather+einsum execution of the same BSR layout — the fast
        non-TPU path), 'ref' (the per-block oracle loop), or 'auto'
        (pallas on TPU, jnp elsewhere).
        """
        if impl == "auto":
            impl = "pallas" if on_tpu() else "jnp"
        d = feats.shape[1]
        pad_d = (-d) % 128
        x = jnp.pad(feats, ((0, self.n_src_pad - feats.shape[0]), (0, pad_d)))
        if impl == "pallas":
            out = _spmm(self.values, self.block_cols, x,
                        bm=self.bm, bk=self.bk, interpret=not on_tpu())
        elif impl == "jnp":
            out = _spmm_jnp(self.values, self.block_cols, x,
                            self.bm, self.bk)
        else:
            out = _ref.spmm_ref(self.values, self.block_cols, x,
                                self.bm, self.bk)
        return out[: self.n, :d]

    def as_aggregate_fn(self):
        """Adapter for gnn.models.forward(aggregate=...).

        Only valid when messages are raw per-source features h[src] and the
        destination ids match this BSR's edge list (GCN/SAGE sum path).
        """
        def agg(messages, dst, n):  # noqa: ARG001 - signature parity
            raise NotImplementedError(
                "BSRAggregate operates on the feature matrix, not edge "
                "messages; use forward_bsr below.")
        return agg


def aggregate_features(src_dst: np.ndarray, feats, n: int,
                       impl: str = "auto") -> jnp.ndarray:
    """One-shot neighbor-sum of features: sum_{u in N_v} h_u for all v."""
    agg = BSRAggregate(np.asarray(src_dst), n)
    return agg(jnp.asarray(feats), impl=impl)
