"""Block-sparse SpMM Pallas kernel — the GNN aggregation hot spot on TPU.

Hardware adaptation (DESIGN.md §3): the paper's edge servers run scalar CSR
gather loops on CPUs; a mechanical port would be a data-dependent gather,
which the TPU's systolic design punishes.  Instead we re-tile aggregation as
**block-sparse matmul**: the (GLAD-ordered) adjacency is chopped into dense
(bm, bk) link blocks; only nonempty blocks are stored, and each one becomes
an MXU matmul-accumulate against a (bk, d) feature tile.  GLAD's layout (and
degree ordering within a partition) concentrates links near the diagonal, so
block density — and thus MXU utilization — is a direct function of layout
quality: the paper's C_T minimization doubles as an MXU-efficiency knob.

Layout:
  values     (n_dst_blocks * max_blocks, bm, bk)  dense link-weight blocks
  block_cols (n_dst_blocks, max_blocks) int32     source block-row per block
                                                  (0-padded; padded values=0)
  feats      (n_src_blocks * bk, d)
  out        (n_dst_blocks * bm, d)

Grid: (n_dst_blocks, max_blocks, d_blocks).  ``block_cols`` rides in scalar
prefetch so the feature BlockSpec index_map can pick the right (bk, d) tile —
the canonical TPU scalar-prefetch block-sparse pattern.  The accumulator
lives in the output VMEM block across the j loop (dimension_semantics mark j
"arbitrary" so the block persists).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import jaxcompat


def _kernel(block_cols_ref, vals_ref, feats_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = jnp.dot(
        vals_ref[0].astype(jnp.float32),
        feats_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bd", "interpret"))
def spmm(values, block_cols, feats, *, bm: int, bk: int, bd: int = 128,
         interpret: bool = False):
    """Block-sparse A @ H.  See module docstring for the layout contract."""
    n_dst_blocks, max_blocks = block_cols.shape
    n_rows_out = n_dst_blocks * bm
    d = feats.shape[1]
    bd = min(bd, d)
    assert d % bd == 0, (d, bd)
    assert feats.shape[0] % bk == 0

    grid = (n_dst_blocks, max_blocks, d // bd)

    def vals_map(i, j, kd, cols):
        return (i * max_blocks + j, 0, 0)

    def feats_map(i, j, kd, cols):
        return (cols[i, j], kd)

    def out_map(i, j, kd, cols):
        return (i, kd)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), vals_map),
                pl.BlockSpec((bk, bd), feats_map),
            ],
            out_specs=pl.BlockSpec((bm, bd), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((n_rows_out, d), feats.dtype),
        compiler_params=jaxcompat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "parallel"),
        ),
        interpret=interpret,
    )(block_cols, values, feats)
    return out


def spmm_jnp(values, block_cols, feats, bm: int, bk: int):
    """Vectorized jnp execution of the kernel's exact BSR layout.

    The non-TPU fallback for ``spmm``: one gather of (bk, d) feature tiles by
    ``block_cols`` plus one einsum contraction, instead of interpret-mode
    Pallas (O(python) per block) or the per-block ``ref.spmm_ref`` loop.  It
    may differ from the kernel only in accumulation order (the einsum
    contracts all ``max_blocks`` tiles at once vs the kernel's sequential
    j-loop); both accumulate in fp32.
    """
    n_dst_blocks, max_blocks = block_cols.shape
    d = feats.shape[1]
    assert feats.shape[0] % bk == 0, (feats.shape, bk)
    tiles = feats.reshape(-1, bk, d)
    gathered = tiles[block_cols]                   # (nb, maxb, bk, d)
    vals = values.reshape(n_dst_blocks, max_blocks, bm, bk)
    out = jnp.einsum(
        "nmbk,nmkd->nbd",
        vals.astype(jnp.float32), gathered.astype(jnp.float32))
    return out.reshape(n_dst_blocks * bm, d).astype(feats.dtype)


# --------------------------------------------------------------- host packing
def build_bsr(
    src_dst: np.ndarray,
    weights: np.ndarray | None,
    n: int,
    bm: int = 8,
    bk: int = 128,
):
    """Pack a directed edge list into the kernel's BSR layout.

    Returns (values, block_cols, n_pad) where n_pad = rows padded to
    lcm-friendly multiples of bm (dst) and bk (src).  Padded blocks carry
    zero weights and column 0 — they multiply the first feature tile by zero,
    keeping the grid rectangular with no masking logic in the kernel.
    """
    if weights is None:
        weights = np.ones(len(src_dst), dtype=np.float32)
    n_dst_pad = max(bm, ((n + bm - 1) // bm) * bm)
    n_src_pad = max(bk, ((n + bk - 1) // bk) * bk)
    n_dst_blocks = n_dst_pad // bm

    by_block: dict[tuple[int, int], np.ndarray] = {}
    if len(src_dst):
        ib = src_dst[:, 1] // bm           # dst block
        jb = src_dst[:, 0] // bk           # src block
        order = np.lexsort((jb, ib))
        s = src_dst[order]
        w = weights[order]
        ib, jb = ib[order], jb[order]
        bounds = np.flatnonzero(np.diff(ib * (n_src_pad // bk + 1) + jb)) + 1
        starts = np.concatenate([[0], bounds])
        ends = np.concatenate([bounds, [len(s)]])
        for a, b in zip(starts, ends):
            key = (int(ib[a]), int(jb[a]))
            blk = np.zeros((bm, bk), np.float32)
            rows = s[a:b, 1] - key[0] * bm
            cols = s[a:b, 0] - key[1] * bk
            np.add.at(blk, (rows, cols), w[a:b])
            by_block[key] = blk

    per_row: list[list[tuple[int, np.ndarray]]] = [[] for _ in range(n_dst_blocks)]
    for (i, j), blk in by_block.items():
        per_row[i].append((j, blk))
    max_blocks = max(1, max((len(r) for r in per_row), default=1))

    values = np.zeros((n_dst_blocks * max_blocks, bm, bk), np.float32)
    block_cols = np.zeros((n_dst_blocks, max_blocks), np.int32)
    for i, row in enumerate(per_row):
        for k, (j, blk) in enumerate(sorted(row)):
            values[i * max_blocks + k] = blk
            block_cols[i, k] = j
    return values, block_cols, n_dst_pad, n_src_pad


def bsr_density(block_cols: np.ndarray, values: np.ndarray) -> float:
    """Fraction of nonzero entries within stored blocks (MXU efficiency)."""
    stored = values.size
    nnz = int((values != 0).sum())
    return nnz / max(stored, 1)
