from repro.runtime.fault import (
    DeviceHealth, ElasticCoordinator, FailureDetector, RelayoutEvent,
)

__all__ = ["DeviceHealth", "ElasticCoordinator", "FailureDetector",
           "RelayoutEvent"]
