"""Fault tolerance runtime: failure detection, elastic re-layout, restart.

This is where the paper's algorithms become the cluster's control plane:

  * node loss      -> vertex deletions in the server graph; GLAD-E proves
                      deletions never raise cost (Sec. V-B), so the surviving
                      fleet re-layouts incrementally in O(changed) time;
  * straggler      -> per-device step-time EWMA feeds the alpha_i compute
                      coefficients; the Thm-8 drift bound decides WHEN a
                      re-layout pays for the migration it causes;
  * restart        -> CheckpointManager's mesh-agnostic restore re-shards the
                      state onto whatever slice count survived.

Heartbeats are timestamps supplied by the caller (tests drive a fake clock).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.core.cost import CostModel, GNNWorkload
from repro.core.engine import LayoutSession
from repro.core.glad_s import glad_s
from repro.core.partition import DevicePartition, partition_from_assign
from repro.graphs.datagraph import DataGraph
from repro.graphs.edgenet import EdgeNetwork


@dataclasses.dataclass
class DeviceHealth:
    last_heartbeat: float = 0.0
    step_time_ewma: float = 0.0
    alive: bool = True
    # False until the device is first observed (heartbeat/revive/sweep).
    # Guards the timeout compare: the 0.0 default is not a real heartbeat
    # time, and wall-clock sweeps must not treat it as one.
    seen: bool = False


class FailureDetector:
    """Heartbeat-timeout failure detection + step-time EWMA (straggler)."""

    def __init__(self, num_devices: int, timeout_s: float = 30.0,
                 ewma: float = 0.2):
        self.devices = [DeviceHealth() for _ in range(num_devices)]
        self.timeout_s = timeout_s
        self.ewma = ewma

    def heartbeat(self, device: int, now: float,
                  step_time_s: Optional[float] = None):
        """Record a heartbeat from a LIVE device.  A late heartbeat from a
        device already swept dead is ignored: sweep() reports each death
        exactly once, so silently flipping ``alive`` back would desync the
        detector from a coordinator that has already removed the server
        from the net.  Re-admitting a repaired device is an explicit
        control-plane action — :meth:`revive`."""
        d = self.devices[device]
        if not d.alive:
            return
        d.last_heartbeat = now
        d.seen = True
        if step_time_s is not None:
            d.step_time_ewma = (step_time_s if d.step_time_ewma == 0.0 else
                                (1 - self.ewma) * d.step_time_ewma
                                + self.ewma * step_time_s)

    def revive(self, device: int, now: float):
        """Explicitly re-admit a repaired device (fresh EWMA, live again)."""
        d = self.devices[device]
        d.alive = True
        d.last_heartbeat = now
        d.step_time_ewma = 0.0
        d.seen = True

    def sweep(self, now: float) -> List[int]:
        """Mark timed-out devices dead; return newly-dead ids.

        A device that has never been observed is STAMPED with the sweep
        time instead of judged by it: the fresh-detector default
        ``last_heartbeat=0.0`` is not a real heartbeat, and comparing a
        wall-clock ``now`` against it would declare the entire fleet dead
        on the first sweep.  The stamp starts that device's timeout clock
        at first observation (registration time, effectively), so a device
        that stays silent still dies exactly one timeout period later."""
        dead = []
        for i, d in enumerate(self.devices):
            if not d.alive:
                continue
            if not d.seen:
                d.last_heartbeat = now
                d.seen = True
                continue
            if now - d.last_heartbeat > self.timeout_s:
                d.alive = False
                dead.append(i)
        return dead

    def stragglers(self, factor: float = 2.0) -> List[int]:
        """Devices whose EWMA step time exceeds factor x the median step
        time of the OTHER live devices (leave-one-out).  Including a
        device's own sample would let an extreme straggler drag the fleet
        median up past its own threshold: with two devices at 1s and 10s,
        the self-inclusive median is 5.5s and the 10s device passes a
        factor-2 check — mathematically undetectable."""
        live = [(i, d.step_time_ewma) for i, d in enumerate(self.devices)
                if d.alive and d.step_time_ewma > 0]
        if len(live) < 2:
            return []
        out = []
        for k, (i, t) in enumerate(live):
            others = [t2 for j, (_, t2) in enumerate(live) if j != k]
            if t > factor * float(np.median(others)):
                out.append(i)
        return out


@dataclasses.dataclass
class RelayoutEvent:
    kind: str                   # 'failure' | 'straggler' | 'revive'
    devices: List[int]
    old_cost: float
    new_cost: float
    migrated: int
    wall_time_s: float
    # Move delta vs the pre-event layout: the vertices a serving layer must
    # re-home — feed straight into gnn.distributed.patch_plan to patch the
    # live ShardPlan instead of recompiling it.
    moved: Optional[np.ndarray] = None


class ElasticCoordinator:
    """Drives GLAD re-layout when the failure detector reports changes.

    Holds the data-graph layout of the current workload (the GNN data
    partition, or any workload expressed as a graph — MoE expert placement
    plugs in the same way).
    """

    def __init__(self, net: EdgeNetwork, graph: DataGraph, gnn: GNNWorkload,
                 part: DevicePartition, workers: int = 0,
                 cache: "bool | str" = "auto",
                 chunk_nodes: "int | str" = "auto",
                 warm: "bool | str" = "auto",
                 multilevel: "bool | str" = False,
                 coarsen_to: int = 1024,
                 levels: Optional[int] = None,
                 chunk_vertices: "int | str | None" = None,
                 replicate: "bool | dict" = False,
                 session: bool = True):
        self.net = net
        self.graph = graph
        self.gnn = gnn
        self.part = part
        # EdgeNetwork mutations have no inverse (without_server floods the
        # dead server's rows with OFFLINE_COST; the originals are gone), so
        # on_revive rebuilds the current net by replaying the surviving ops
        # — ("dead", d) / ("degrade", s, factor), in commit order — over
        # the pristine topology.
        self._pristine_net = net
        self._net_ops: List[tuple] = []
        self.events: List[RelayoutEvent] = []
        # Move delta of the most recent relayout (also on each event) — the
        # input to the serving layer's ShardPlan patch.
        self.last_moved: np.ndarray = np.zeros(0, dtype=np.int64)
        # Engine knobs for the GLAD re-layouts (assembly caching, chunked
        # block fan-out, warm-started incremental re-solves) — relayout
        # latency is the control plane's budget.  The warm-started
        # relayouts carry no active mask, so cache/warm 'auto' resolve OFF
        # there; pass cache=True, warm=True to retain flow state across a
        # coordinator's repeated relayouts of the same fleet.  'multilevel'
        # ('auto' recommended for very large graphs) escalates relayouts to
        # the coarsen/solve/refine V-cycle — the warm init is restricted up
        # the hierarchy by majority vote, so survivors still anchor the
        # coarse solve.
        # 'replicate' (True or replicate_greedy kwargs) keeps a
        # move-vs-replicate overlay attached to every partition this
        # coordinator produces; its replicas double as the degraded-mode
        # fallback on failure — an orphan with a live replica re-homes to
        # the replica's host instead of a random survivor.
        # One persistent LayoutSession for the coordinator's lifetime:
        # consecutive relayouts of the same fleet rebind the engine
        # (diff-driven epoch bumps for the degraded/dead/revived servers)
        # instead of rebuilding it from scratch, keeping the assembly
        # cache and warm residuals alive across events.  With multilevel
        # the session ALSO carries the persistent LevelStack: the data
        # graph is constant across fault events, so every escalated
        # relayout refreshes the cached coarsening hierarchy (reused
        # matchings, rebuilt coarse cost models) instead of re-coarsening
        # from scratch, and the V-cycle's finest refinement adopts the
        # engine.  'chunk_vertices' streams any coarsening in bounded
        # vertex windows (out-of-core scale).  session=False forces the
        # per-event rebuild (the benchmark's A/B control arm).
        self._session = (None if not session else
                         LayoutSession(workers=workers, cache=cache,
                                       chunk_nodes=chunk_nodes, warm=warm))
        self._glad_opts = dict(workers=workers, cache=cache,
                               chunk_nodes=chunk_nodes, warm=warm,
                               multilevel=multilevel, coarsen_to=coarsen_to,
                               levels=levels, chunk_vertices=chunk_vertices,
                               replicate=replicate, session=self._session)

    def on_failure(self, dead: List[int], seed: int = 0) -> DevicePartition:
        """Node loss: disconnect dead servers, re-layout incrementally
        (warm-started — survivors keep their placement unless they hosted
        orphans)."""
        t0 = time.perf_counter()
        net = self.net
        for d in dead:
            net = net.without_server(d)
        cm = CostModel(net, self.graph, self.gnn)
        # Recompute under the DEGRADED net (same convention as
        # on_straggler) so RelayoutEvent deltas are comparable across event
        # kinds: old_cost is "what staying put would cost now", not the
        # stale stored total from before the failure.
        old_cost = cm.total(self.part.assign)
        # Orphans must move; everything else is warm-started.  An orphan
        # whose row is REPLICATED on a surviving server re-homes there (the
        # copy is already resident — degraded mode serves from it with zero
        # migration); lowest replica-hosting part wins, deterministically.
        # Remaining orphans scatter randomly as before.
        assign = self.part.assign.copy()
        orphan = np.isin(assign, dead)
        alive = [i for i in range(net.m) if i not in dead]
        rng = np.random.default_rng(seed)
        assign[orphan] = rng.choice(alive, size=int(orphan.sum()))
        repl = getattr(self.part, "replication", None)
        if repl is not None:
            placed = np.zeros(self.graph.n, dtype=bool)
            for p in sorted(repl.by_part):
                if p in dead:
                    continue                 # the copy died with its host
                ids = np.asarray(repl.by_part[p], dtype=np.int64)
                take = ids[orphan[ids] & ~placed[ids]]
                assign[take] = p
                placed[take] = True
        res = glad_s(cm, init=assign, R=net.m, seed=seed, sweep="batched",
                     **self._glad_opts)
        new_part = partition_from_assign(self.graph, res.assign,
                                         self.part.num_parts, res.factors,
                                         replication=res.replication)
        moved = np.flatnonzero(res.assign != self.part.assign)
        self.events.append(RelayoutEvent(
            "failure", dead, old_cost, res.cost, len(moved),
            time.perf_counter() - t0, moved=moved))
        self._net_ops += [("dead", d) for d in dead]
        self.net = net
        self.part = new_part
        self.last_moved = moved
        return new_part

    def on_straggler(self, slow: List[int], slow_factor: float = 3.0,
                     seed: int = 0) -> DevicePartition:
        """Degrade the straggler's compute coefficients and re-layout."""
        t0 = time.perf_counter()
        net = self.net
        for s in slow:
            net = net.degrade(s, slow_factor)
        cm = CostModel(net, self.graph, self.gnn)
        old_cost = cm.total(self.part.assign)
        res = glad_s(cm, init=self.part.assign, R=net.m, seed=seed,
                     sweep="batched", **self._glad_opts)
        new_part = partition_from_assign(self.graph, res.assign,
                                         self.part.num_parts, res.factors,
                                         replication=res.replication)
        moved = np.flatnonzero(res.assign != self.part.assign)
        self.events.append(RelayoutEvent(
            "straggler", slow, old_cost, res.cost, len(moved),
            time.perf_counter() - t0, moved=moved))
        self._net_ops += [("degrade", s, slow_factor) for s in slow]
        self.net = net
        self.part = new_part
        self.last_moved = moved
        return new_part

    def on_revive(self, devices: List[int], seed: int = 0) -> DevicePartition:
        """Re-admit repaired servers and re-layout onto the restored fleet.

        The detector's :meth:`FailureDetector.revive` flips the device
        live again, but without this hook the coordinator's net keeps
        pricing it at OFFLINE_COST forever — ``without_server`` has no
        inverse.  The current net is therefore rebuilt from the pristine
        topology by replaying, in commit order, every failure/degrade op
        whose device is NOT being revived: the revived server returns at
        its pristine coefficients (mirroring the detector's fresh EWMA),
        and the warm-started relayout pulls work back onto it wherever
        that pays."""
        t0 = time.perf_counter()
        back = set(devices)
        self._net_ops = [op for op in self._net_ops if op[1] not in back]
        net = self._pristine_net
        for op in self._net_ops:
            net = (net.without_server(op[1]) if op[0] == "dead"
                   else net.degrade(op[1], op[2]))
        cm = CostModel(net, self.graph, self.gnn)
        old_cost = cm.total(self.part.assign)
        res = glad_s(cm, init=self.part.assign, R=net.m, seed=seed,
                     sweep="batched", **self._glad_opts)
        new_part = partition_from_assign(self.graph, res.assign,
                                         self.part.num_parts, res.factors,
                                         replication=res.replication)
        moved = np.flatnonzero(res.assign != self.part.assign)
        self.events.append(RelayoutEvent(
            "revive", list(devices), old_cost, res.cost, len(moved),
            time.perf_counter() - t0, moved=moved))
        self.net = net
        self.part = new_part
        self.last_moved = moved
        return new_part
