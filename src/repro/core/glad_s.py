"""GLAD-S: graph-layout scheduling for static input graphs (paper Alg. 1).

Iteratively picks the least-visited connected server pair <i, j>, builds the
auxiliary graph A(i, j) over the clients currently resident on i or j, solves
a minimum s-t cut (Thm 4: exact for the restricted two-server subproblem),
and accepts the induced layout whenever total cost improves.  Terminates when
R consecutive attempts fail to improve (Thm 6 guarantees convergence;
Thm 5 gives C(pi) <= 2*lambda*C(pi*) + eps).

Auxiliary-graph weights (Sec. IV-B):
  t-link  s(=i) -> v : unary cost of v living on j  +  side-effect traffic
                       from v's links to vertices on *other* servers k
                       (paid when v lands on the sink side = server j)
  t-link  v -> t(=j) : symmetric, for v living on i
  n-link  u <-> v    : tau_ij  (paid when a data link is cut by the layout)

The side-effect terms make each pairwise cut *globally* cost-aware, which is
what lets the pairwise sweep descend the full objective.

Two execution engines:
  * ``engine='incremental'`` (default) — repro.core.engine.PairCutEngine:
    vectorized auxiliary-graph assembly, reused scratch arenas, and an exact
    O(moved + incident links) delta on the accept path (no full-objective
    re-evaluation per iteration).
  * ``engine='reference'`` — the direct transcription of Alg. 1 kept as the
    oracle for property tests and the speedup benchmark.

Two sweep disciplines (incremental engine only):
  * ``sweep='single'`` — Alg. 1 verbatim: one least-visited pair at a time.
  * ``sweep='batched'`` — a round-robin matching of disjoint server pairs
    per round; disjoint pairs host disjoint member sets so their cuts are
    solved from one snapshot and composed, each acceptance guarded by an
    exact live delta.  ``round_solver`` picks how a round's cuts are
    solved: ``'block'`` (the ``'auto'`` default) batch-assembles every
    dirty pair into one block-diagonal flow problem solved by a single
    scipy pass (pure-python fallback: per-block Dinic over ``workers``
    threads/processes); ``'pairwise'`` keeps PR 1's one-solve-per-pair
    path (benchmark baseline).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np

from repro.core.cost import CostModel
from repro.core.engine import LayoutSession, PairCutEngine, round_robin_rounds
from repro.core.maxflow import min_st_cut


@dataclasses.dataclass
class GladResult:
    assign: np.ndarray
    cost: float
    history: List[float]            # total cost after every iteration
    iterations: int
    accepted: int
    wall_time_s: float
    factors: dict
    # Net move delta vs the starting layout (vertices whose final server
    # differs from ``init``) — feeds gnn.distributed.patch_plan so the
    # serving layer patches its ShardPlan instead of recompiling.  All
    # vertices for a random init.
    moved: Optional[np.ndarray] = None
    # Multilevel runs only: one stats dict per level solve (coarsest solve
    # first, then each refinement down to the finest), carrying the
    # projected init / boundary-active mask each level ran under — enough
    # to replay any level on the flat engine bit-for-bit.  With
    # ``record_levels=False`` the replay arrays collapse to checksums +
    # sizes (scale-cell telemetry).
    levels: Optional[List[dict]] = None
    # Multilevel runs with a session only: the LevelStack's coarsening
    # reuse stats for this solve (mode build/refresh, levels
    # reused/rebuilt, cumulative builds/refreshes).
    coarsen: Optional[dict] = None
    # replicate=True runs only: the accepted move-vs-replicate overlay on
    # the final cut (core.cost.Replication), the objective with it applied
    # (cost - replication.gain), and the replicated total recorded after
    # each ACCEPTED round.  The overlay never feeds back into the cut
    # decisions, so the assign/cost/history trajectory is bit-identical
    # with the knob on or off.
    replication: Optional[object] = None
    replicated_cost: Optional[float] = None
    repl_history: Optional[List[float]] = None


def _pair_members(assign: np.ndarray, i: int, j: int,
                  active: Optional[np.ndarray]) -> np.ndarray:
    members = (assign == i) | (assign == j)
    if active is not None:
        members &= active
    return np.where(members)[0]


def solve_pair(
    cm: CostModel,
    assign: np.ndarray,
    i: int,
    j: int,
    active: Optional[np.ndarray] = None,
    backend: str = "auto",
) -> Optional[np.ndarray]:
    """One min s-t cut for server pair (i, j).  Returns a full proposed
    assignment vector (copy), or None if the pair hosts no active vertices.

    Reference construction (per-edge scan of the whole graph); the engine
    path in repro.core.engine builds the same auxiliary graph from the CSR
    incident-edge view.  Kept as the oracle for the Thm-4 exactness tests.
    """
    members = _pair_members(assign, i, j, active)
    if len(members) == 0:
        return None
    net, graph = cm.net, cm.graph
    n_aux = len(members) + 2
    S, T = len(members), len(members) + 1      # aux ids of source/sink
    aux_id = {int(v): k for k, v in enumerate(members)}

    # Unary terms: theta_i[v] = unary[v, i], theta_j[v] = unary[v, j],
    # plus side-effect traffic to neighbors on other servers.
    theta_i = cm.unary[members, i].astype(np.float64).copy()
    theta_j = cm.unary[members, j].astype(np.float64).copy()

    edges = graph.edges
    weights = graph.weights_or_ones()
    eu, ev = edges[:, 0], edges[:, 1]
    m_mask = np.zeros(graph.n, dtype=bool)
    m_mask[members] = True

    # Internal links (both endpoints in member set): pairwise tau_ij.
    internal = m_mask[eu] & m_mask[ev]
    # Boundary links: one endpoint in member set -> side-effect unary.
    bnd_u = m_mask[eu] & ~m_mask[ev]
    bnd_v = ~m_mask[eu] & m_mask[ev]
    if bnd_u.any():
        ins, outs, w = eu[bnd_u], ev[bnd_u], weights[bnd_u]
        np.add.at(theta_i, [aux_id[int(x)] for x in ins],
                  net.tau[i, assign[outs]] * w)
        np.add.at(theta_j, [aux_id[int(x)] for x in ins],
                  net.tau[j, assign[outs]] * w)
    if bnd_v.any():
        ins, outs, w = ev[bnd_v], eu[bnd_v], weights[bnd_v]
        np.add.at(theta_i, [aux_id[int(x)] for x in ins],
                  net.tau[i, assign[outs]] * w)
        np.add.at(theta_j, [aux_id[int(x)] for x in ins],
                  net.tau[j, assign[outs]] * w)

    # Build the flow network.  Convention: source side = server i.
    #   cap(s -> v) = theta_j[v]   (paid when v ends on sink side, i.e. j? no:
    #   s->v is cut exactly when v is in the sink component => v on j ...
    #   => the cut pays the cost of assigning v to j) -- see maxflow.min_st_cut.
    k = len(members)
    us = [S] * k + [kk for kk in range(k)]
    vs = list(range(k)) + [T] * k
    caps_uv = list(theta_j) + list(theta_i)
    caps_vu = [0.0] * (2 * k)
    if internal.any():
        tij = float(net.tau[i, j])
        for a, b, w in zip(eu[internal], ev[internal], weights[internal]):
            us.append(aux_id[int(a)])
            vs.append(aux_id[int(b)])
            caps_uv.append(tij * w)
            caps_vu.append(tij * w)
    _, side = min_st_cut(
        n_aux, S, T, np.array(us), np.array(vs),
        np.array(caps_uv), np.array(caps_vu), backend=backend,
    )
    proposal = assign.copy()
    on_source = side[:k]          # True -> stays with server i
    proposal[members[on_source]] = i
    proposal[members[~on_source]] = j
    return proposal


def _init_assign(cm: CostModel, init: Optional[np.ndarray],
                 rng: np.random.Generator) -> np.ndarray:
    if init is None:
        return rng.integers(0, cm.net.m, size=cm.graph.n).astype(np.int64)
    return np.asarray(init, dtype=np.int64).copy()


def _empty_result(cm: CostModel, assign: np.ndarray) -> GladResult:
    f = cm.factors(assign)
    return GladResult(assign, f["total"], [f["total"]], 0, 0, 0.0, f,
                      moved=np.zeros(0, dtype=np.int64))


def glad_s(
    cm: CostModel,
    R: Optional[int] = None,
    init: Optional[np.ndarray] = None,
    active: Optional[np.ndarray] = None,
    seed: int = 0,
    backend: str = "auto",
    max_iterations: int = 100_000,
    on_iteration: Optional[Callable[[int, float], None]] = None,
    sweep: str = "single",
    engine: str = "incremental",
    round_solver: str = "auto",
    workers: int = 0,
    worker_mode: str = "thread",
    cache: "bool | str" = "auto",
    cache_bytes: int = 256 << 20,
    chunk_nodes: "int | str" = "auto",
    warm: "bool | str" = "auto",
    multilevel: "bool | str" = False,
    coarsen_to: int = 1024,
    levels: Optional[int] = None,
    chunk_vertices: "int | str | None" = None,
    record_levels: bool = True,
    replicate: "bool | dict" = False,
    session: Optional[LayoutSession] = None,
) -> GladResult:
    """Paper Algorithm 1.

    Args:
      cm: cost model binding (net, graph, gnn workload).
      R: convergence patience — consecutive non-improving attempts tolerated.
         Defaults to |D|(|D|-1)/2 (the exhaustive setting in Sec. IV-B).
      init: starting layout; random if None (Alg. 1 line 1).
      active: optional mask — only these vertices may move (GLAD-E reuses
        this to freeze the unfiltered layout).
      backend: max-flow backend.
      sweep: 'single' (Alg. 1 verbatim) or 'batched' (disjoint-pair rounds).
      engine: 'incremental' (delta-cost engine) or 'reference' (seed Alg. 1
        transcription — oracle/benchmark baseline).
      round_solver: batched-sweep round solver — 'auto'/'block' (one
        block-diagonal flow per round) or 'pairwise' (PR-1 per-pair solves).
      workers: fan a round's block/chunk solves out over this many
        threads/processes ('worker_mode'); scipy holds the GIL, so thread
        mode mainly helps the pure-python fallback — measure first.
      cache: cross-round AssemblyCache — persist each pair's assembled
        t-link vectors / arc lists / core classification and patch theta
        or membership deltas in O(touched) between visits.  'auto' enables
        it exactly when an ``active`` mask is present (incremental
        GLAD-E-style relayouts, where touched sets stay small); cold full
        sweeps — warm-started ones without a mask included — churn pair
        memberships too fast for per-pair reuse to beat the fused batch
        assembly, so they only cache when explicitly asked (cache=True).
        Trajectories are bit-identical with the cache on or off.
      cache_bytes: LRU budget for the AssemblyCache.
      chunk_nodes: bound on one glued block-diagonal flow union ('auto' =
        engine default; 0 = single glued pass per round).
      warm: warm-start incremental max-flow — retain each cached pair's
        flow/residual arrays (maxflow.ResidualCut, stored on its
        AssemblyCache entry under the same per-vertex epochs) and repair
        them on re-solve (drain over-saturated arcs, augment the delta)
        instead of re-pushing the whole flow.  'auto' follows the cache
        policy; an adaptive gate falls back to the cold (peeled) path
        whenever the touched fraction is large, so warm='auto' is never a
        regression.  Masks are bit-identical warm or cold — the minimal
        source side is unique per quantized problem — so trajectories are
        unchanged (differential-fuzz + golden-fixture pinned).
      multilevel: route the solve through the coarsen/solve/refine V-cycle
        (:func:`repro.core.multilevel.glad_multilevel`) — the scaling path
        for n >> 10^5.  'auto' enables it for maskless solves at
        ``multilevel.MULTILEVEL_AUTO_MIN_N`` vertices and beyond; the
        default False preserves every existing flat trajectory.  The
        V-cycle always sweeps batched internally and is incompatible with
        an ``active`` mask (it is a full-layout construct) and with
        ``engine='reference'``.
      coarsen_to: V-cycle coarsest-level size (multilevel only).
      levels: cap on the number of hierarchy levels (None = until
        ``coarsen_to`` or stagnation; multilevel only).
      chunk_vertices: stream the V-cycle's coarsening in bounded vertex
        windows of this size ('auto' = default window) — peak coarsening
        RSS becomes a knob instead of O(n + m) per level, with levels
        bit-identical to the in-core build (multilevel only).
      record_levels: keep the full per-level replay arrays on
        ``result.levels`` (default).  False slims them to checksums +
        sizes so scale cells don't retain O(levels x n) telemetry
        (multilevel only; the trajectory is unchanged).
      replicate: move-vs-replicate overlay (Fograph-style inference
        replication).  True — or a dict of
        :meth:`CostModel.replicate_greedy` kwargs (``sync_weight``,
        ``storage``, ``budget``) — re-runs the greedy after each ACCEPTED
        round, recording the replicated total in ``repl_history``, and
        attaches the final overlay as ``result.replication`` /
        ``result.replicated_cost``.  The overlay is a post-pass on the
        current cut: it never alters which moves are proposed or accepted,
        so layouts are bit-identical with the knob on or off (default
        False skips the extra per-accept work entirely).
      session: optional :class:`repro.core.engine.LayoutSession` — a
        persistent cross-slot engine.  The call ADOPTS the session's
        engine (rebinding its model/assignment/mask in place, keeping
        cached assemblies + warm residuals from previous slots alive)
        instead of building a fresh one; per-call engine knobs
        (cache/warm/chunk_nodes/workers) are fixed at session construction
        and ignored here.  Trajectories are bit-identical to the
        sessionless call.  With ``multilevel`` the session additionally
        carries the persistent coarsening hierarchy
        (:class:`repro.core.multilevel.LevelStack` — reused matchings
        across relayouts of an unchanged graph) and its engine is adopted
        by the V-cycle's finest refinement.  Incompatible with
        ``engine='reference'``.
    """
    if session is not None and engine == "reference":
        raise ValueError("session= requires engine='incremental'")
    if multilevel == "auto":
        from repro.core.multilevel import MULTILEVEL_AUTO_MIN_N
        multilevel = active is None and cm.graph.n >= MULTILEVEL_AUTO_MIN_N
    if multilevel:
        if engine == "reference":
            raise ValueError("multilevel requires engine='incremental'")
        if active is not None:
            raise ValueError(
                "multilevel solves the full layout; run flat glad_s for "
                "masked (GLAD-E-style) refinements")
        from repro.core.multilevel import glad_multilevel
        return _attach_replication(cm, glad_multilevel(
            cm, R=R, init=init, seed=seed, backend=backend,
            coarsen_to=coarsen_to, levels=levels,
            round_solver=round_solver, workers=workers,
            worker_mode=worker_mode, cache=cache, cache_bytes=cache_bytes,
            chunk_nodes=chunk_nodes, warm=warm,
            max_iterations=max_iterations, on_iteration=on_iteration,
            chunk_vertices=chunk_vertices, record_levels=record_levels,
            session=session),
            replicate)
    rng = np.random.default_rng(seed)
    net, graph = cm.net, cm.graph
    t0 = time.perf_counter()

    assign = _init_assign(cm, init, rng)
    pairs = net.pairs
    if len(pairs) == 0 or graph.n == 0:
        return _empty_result(cm, assign)
    if R is None:
        R = net.m * (net.m - 1) // 2

    if engine == "reference":
        return _attach_replication(cm, _glad_s_reference(
            cm, assign, pairs, R, active, rng, backend, max_iterations,
            on_iteration, t0), replicate)
    if engine != "incremental":
        raise ValueError(f"unknown engine {engine!r}")

    init_snapshot = assign.copy()
    if session is not None:
        eng = session.adopt(cm, assign, active=active)
    else:
        eng = PairCutEngine(cm, assign, active=active, backend=backend,
                            workers=workers, worker_mode=worker_mode,
                            cache=cache, cache_bytes=cache_bytes,
                            chunk_nodes=chunk_nodes, warm=warm)
    history = [eng.state.total]
    repl_history: Optional[List[float]] = None
    if replicate:
        # Per-accepted-round replicated-cost ledger: acceptance is exactly
        # a strict drop of the live total, so the wrapper re-greedies the
        # overlay on every improvement without touching the sweep loops
        # (the trajectory stays bit-identical — replication reads the cut,
        # never writes it).
        repl_opts = replicate if isinstance(replicate, dict) else {}
        repl_history = []
        base_cb, best = on_iteration, {"c": eng.state.total}

        def _repl_cb(it, cost):
            if cost < best["c"] - 1e-12:
                best["c"] = cost
                r = cm.replicate_greedy(eng.state.assign, **repl_opts)
                repl_history.append(
                    cm.replication_cost(eng.state.assign, r)["total"])
            if base_cb is not None:
                base_cb(it, cost)

        on_iteration = _repl_cb
    if sweep == "single":
        iters, accepted = _sweep_single(
            eng, pairs, R, rng, max_iterations, on_iteration, history)
    elif sweep == "batched":
        iters, accepted = _sweep_batched(
            eng, net, R, max_iterations, on_iteration, history,
            round_solver)
    else:
        raise ValueError(f"unknown sweep {sweep!r}")

    # Net movers via the engine's commit ledger: only vertices it ever
    # committed can differ from the init, so the diff is O(touched).
    touched = eng.touched_vertices()
    moved = touched[eng.state.assign[touched] != init_snapshot[touched]]
    res = GladResult(
        assign=eng.state.assign, cost=eng.state.total, history=history,
        iterations=iters, accepted=accepted,
        wall_time_s=time.perf_counter() - t0,
        factors=eng.state.factors(), moved=moved,
    )
    res.repl_history = repl_history
    return _attach_replication(cm, res, replicate)


def _attach_replication(cm: CostModel, res: GladResult,
                        replicate) -> GladResult:
    """Final move-vs-replicate overlay on the solved cut (post-pass)."""
    if not replicate:
        return res
    opts = replicate if isinstance(replicate, dict) else {}
    repl = cm.replicate_greedy(res.assign, **opts)
    res.replication = repl
    res.replicated_cost = cm.replication_cost(res.assign, repl)["total"]
    return res


def _sweep_single(eng, pairs, R, rng, max_iterations, on_iteration, history):
    """Alg. 1 line 3-9: least-visited pair, accept on (delta) improvement."""
    visits = np.zeros(len(pairs), dtype=np.int64)
    r = iters = accepted = 0
    while r <= R and iters < max_iterations:
        mn = visits.min()
        cand = np.where(visits == mn)[0]
        p = cand[rng.integers(0, len(cand))]
        visits[p] += 1
        i, j = int(pairs[p, 0]), int(pairs[p, 1])

        solved, ok = eng.try_pair(i, j)
        iters += 1
        if solved and ok:
            accepted += 1
            r = 0
        else:
            r += 1
        history.append(eng.state.total)
        if on_iteration is not None:
            on_iteration(iters, eng.state.total)
    return iters, accepted


def _sweep_batched(eng, net, R, max_iterations, on_iteration, history,
                   round_solver="auto"):
    """Disjoint-pair rounds: each round solves a matching of server pairs
    from one snapshot (one block-diagonal flow per round by default), then
    applies the cuts with exact live deltas."""
    connected = {(int(i), int(j)) for i, j in net.pairs}
    rounds = [
        [p for p in rnd if p in connected]
        for rnd in round_robin_rounds(net.m)
    ]
    rounds = [rnd for rnd in rounds if rnd]
    if not rounds:
        return 0, 0
    r = iters = accepted = 0
    while r <= R and iters < max_iterations:
        for rnd in rounds:
            for _solved, ok in eng.sweep_round(rnd, solver=round_solver):
                iters += 1
                if ok:
                    accepted += 1
                    r = 0
                else:
                    r += 1
                history.append(eng.state.total)
                if on_iteration is not None:
                    on_iteration(iters, eng.state.total)
                if r > R or iters >= max_iterations:
                    return iters, accepted
    return iters, accepted


def _glad_s_reference(cm, assign, pairs, R, active, rng, backend,
                      max_iterations, on_iteration, t0):
    """Seed-path Alg. 1: full total() per proposal, per-edge-scan auxiliary
    construction.  Oracle for equivalence tests + the speedup benchmark."""
    init_snapshot = assign.copy()
    visits = np.zeros(len(pairs), dtype=np.int64)
    cur_cost = cm.total(assign)
    history = [cur_cost]
    r = iters = accepted = 0
    while r <= R and iters < max_iterations:
        mn = visits.min()
        cand = np.where(visits == mn)[0]
        p = cand[rng.integers(0, len(cand))]
        visits[p] += 1
        i, j = int(pairs[p, 0]), int(pairs[p, 1])

        proposal = solve_pair(cm, assign, i, j, active=active, backend=backend)
        iters += 1
        if proposal is not None:
            new_cost = cm.total(proposal)
            if new_cost < cur_cost - 1e-9:
                assign, cur_cost = proposal, new_cost
                accepted += 1
                r = 0
            else:
                r += 1
        else:
            r += 1
        history.append(cur_cost)
        if on_iteration is not None:
            on_iteration(iters, cur_cost)

    return GladResult(
        assign=assign, cost=cur_cost, history=history, iterations=iters,
        accepted=accepted, wall_time_s=time.perf_counter() - t0,
        factors=cm.factors(assign),
        moved=np.flatnonzero(assign != init_snapshot),
    )
