"""Out-of-core multilevel coarsening: streamed matching + contraction.

PR 6's in-core coarsening materializes O(n + m) transient arrays per
round per level — the gathered CSR candidate view alone is ~10 arrays of
2m entries, which is what capped the V-cycle at n=500k / ~3GB RSS on the
reference box.  This module walks the DataGraph CSR in bounded vertex
windows (``chunk_vertices``) instead, in the style of the chunked
dispatch/shuffle pipelines used by distributed-partitioning tooling:

  matching     each round gathers one window's candidate edges at a
               time, reduces them to at most one proposal per proposer
               (the per-window reduction equals the global one because a
               proposer's whole CSR row lives in its window), and SPILLS
               the surviving proposals — 4 arrays bounded by the
               unmatched count, i.e. O(n), never O(m).  Acceptance and
               the mutual handshake then run over the spilled proposals
               exactly as in core.
  contraction  edge chunks map endpoints to clusters and spill compact
               (coarse-key, weight) pairs into key-range buckets; each
               bucket is reduced independently.  Per-key weight sums are
               bit-identical to the in-core path because a ``reduceat``
               segment sum is a pure function of the segment slice (the
               buckets only re-partition the identically-ordered key
               sequence).
  coarse model the summed-unary fold runs per cluster range, so the
               O(n x servers) permuted-unary copy never materializes.

Every function here is BIT-IDENTICAL to its in-core counterpart in
``repro.core.multilevel`` for ANY window size (hypothesis-pinned,
including windows that split matched pairs): the streamed matcher
reproduces the exact proposal/acceptance winners because the in-core
lexsort reductions decompose by proposer, and all integer quantization /
mu-gate arithmetic is elementwise.  Peak transient memory becomes a knob
instead of a function of the graph.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.cost import CostModel
from repro.core.multilevel import (
    COARSEN_TO, MATCH_ROUNDS, MAX_CLUSTER_FACTOR, STAGNATION_FRAC,
    _WQ_SCALE, Level, _mix, _quantize_scaled, clusters_from_matching,
    matching_gate,
)
from repro.graphs.datagraph import (
    DataGraph, _check_cluster_key_domain, csr_multirange,
)
from repro.graphs.edgenet import EdgeNetwork

#: Default streaming window (vertices per window; ``chunk_vertices='auto'``).
#: At the SIoT edge density (~4.2 links/vertex) a window's gathered
#: candidate view stays under ~50MB — small enough that per-level peak
#: RSS is dominated by the graph itself, large enough that the per-window
#: Python overhead is noise at n=2M (BENCH_layout streamed cells).
AUTO_CHUNK_VERTICES = 65536


def _resolve_chunk(chunk_vertices: "int | str | None") -> int:
    if chunk_vertices in (None, "auto"):
        return AUTO_CHUNK_VERTICES
    c = int(chunk_vertices)
    if c <= 0:
        raise ValueError(f"chunk_vertices must be positive, got {c}")
    return c


def _edge_weight_scale(graph: DataGraph) -> float:
    """Global quantization scale (``_WQ_SCALE / max weight``) without
    materializing the O(m) float copy the in-core path makes.  Mirrors
    :func:`repro.core.multilevel.quantize_weights` exactly, including the
    loud non-finite/overflow refusal."""
    if graph.num_edges == 0:
        return 0.0
    if graph.edge_weights is None:
        return float(_WQ_SCALE)          # unit weights: max == 1.0
    mx = float(graph.edge_weights.max())     # nan propagates
    mn = float(graph.edge_weights.min())
    if not (np.isfinite(mx) and np.isfinite(mn)):
        # Same refusal the in-core quantize_weights makes up front, so
        # corrupt weights fail identically whether or not the bad edge
        # ever becomes a matching candidate.
        raise ValueError("non-finite edge weight entering quantization "
                         "(overflowed parallel-edge weight sum?)")
    if mx <= 0.0:
        return 0.0
    return _WQ_SCALE / mx


def matching_gate_streamed(
    graph: DataGraph,
    unary: np.ndarray,
    tau_ref: float,
    chunk_vertices: "int | str | None" = None,
) -> np.ndarray:
    """Full-CSR mu-gate bits assembled window by window.

    The output array is 1 byte per CSR entry (bools are the cheap part);
    what streaming avoids is the per-entry int64/float64 gather
    temporaries, which now peak at one window's worth."""
    chunk = _resolve_chunk(chunk_vertices)
    n = graph.n
    gate = np.empty(len(graph.indices), dtype=bool)
    pref = np.argmin(unary, axis=1).astype(np.int64)
    base = unary[np.arange(n), pref]
    indptr = graph.indptr
    for a in range(0, n, chunk):
        b = min(a + chunk, n)
        gate[indptr[a]:indptr[b]] = matching_gate(
            graph, unary, tau_ref, lo=a, hi=b, pref=pref, base=base)
    return gate


def heavy_edge_matching_streamed(
    graph: DataGraph,
    vertex_w: np.ndarray,
    max_w: int,
    unary: Optional[np.ndarray] = None,
    tau_ref: float = 0.0,
    rounds: int = MATCH_ROUNDS,
    gate: Optional[np.ndarray] = None,
    chunk_vertices: "int | str | None" = None,
) -> np.ndarray:
    """Windowed HEM, bit-identical to
    :func:`repro.core.multilevel.heavy_edge_matching`.

    Why the decomposition is exact: the in-core per-round reduction
    ``lexsort((h, -cw, v))`` + head-mask picks, per PROPOSER v, the
    heaviest eligible neighbor — and every candidate of v lives in v's
    CSR row, which is wholly contained in v's window.  So per-window
    reductions produce the identical proposal list (windows ascending ==
    the in-core v-sorted order), and the acceptance pass — a pure
    function of the full proposal list — runs unchanged over the spilled
    proposals.  Spill size is bounded by the unmatched-vertex count."""
    chunk = _resolve_chunk(chunk_vertices)
    n = graph.n
    match = np.arange(n, dtype=np.int64)
    if graph.num_edges == 0:
        return match
    indptr, indices, eids = graph.indptr, graph.indices, graph.edge_ids
    scale = _edge_weight_scale(graph)
    weights = graph.edge_weights
    matched = np.zeros(n, dtype=bool)
    if gate is None and unary is not None and tau_ref > 0.0:
        gate = matching_gate_streamed(graph, unary, tau_ref,
                                      chunk_vertices=chunk)
    for _ in range(rounds):
        spill_v: List[np.ndarray] = []      # proposer
        spill_t: List[np.ndarray] = []      # target
        spill_w: List[np.ndarray] = []      # quantized link weight
        spill_h: List[np.ndarray] = []      # tie-break hash
        any_candidates = False
        any_ok = False
        for a in range(0, n, chunk):
            b = min(a + chunk, n)
            un = a + np.flatnonzero(~matched[a:b])
            if len(un) == 0:
                continue
            flat, rep = csr_multirange(indptr, un)
            if len(flat) == 0:
                continue
            any_candidates = True
            v = un[rep]
            nbr = indices[flat]
            ok = ~matched[nbr]
            ok &= vertex_w[v] + vertex_w[nbr] <= max_w
            if gate is not None:
                ok &= gate[flat]
            if not ok.any():
                continue
            any_ok = True
            v, nbr = v[ok], nbr[ok]
            if scale == 0.0:
                cw = np.zeros(len(v), dtype=np.int64)
            elif weights is None:
                cw = np.full(len(v), _WQ_SCALE, dtype=np.int64)
            else:
                cw = _quantize_scaled(
                    weights[eids[flat[ok]]].astype(np.float64), scale)
            h = _mix(v, nbr)
            # Per-proposer best candidate (heaviest, hash tie-break) —
            # exact within the window because proposers are window-local.
            order = np.lexsort((h, -cw, v))
            vs_, nb_, cw_, h_ = v[order], nbr[order], cw[order], h[order]
            head = np.ones(len(order), dtype=bool)
            head[1:] = vs_[1:] != vs_[:-1]
            spill_v.append(vs_[head])
            spill_t.append(nb_[head])
            spill_w.append(cw_[head])
            spill_h.append(h_[head])
        if not any_candidates or not any_ok:
            break
        pv = np.concatenate(spill_v)
        pt = np.concatenate(spill_t)
        pw = np.concatenate(spill_w)
        ph = np.concatenate(spill_h)
        # Acceptance: per target, heaviest incoming proposer — identical
        # to the in-core pass (the spill concatenation IS the in-core
        # proposal list: windows ascend, so pv is globally sorted).
        order2 = np.lexsort((pv, ph, -pw, pt))
        t2, p2 = pt[order2], pv[order2]
        head2 = np.ones(len(order2), dtype=bool)
        head2[1:] = t2[1:] != t2[:-1]
        c = np.full(n, -1, dtype=np.int64)
        c[pv] = pt                               # own outgoing proposal
        c[t2[head2]] = p2[head2]                 # incoming winner overrides
        cand = np.flatnonzero(c >= 0)
        partner = c[cand]
        mutual = (c[partner] == cand) & (cand < partner)
        a_, b_ = cand[mutual], partner[mutual]
        if len(a_) == 0:
            break
        match[a_] = b_
        match[b_] = a_
        matched[a_] = True
        matched[b_] = True
    return match


def contract_graph_streamed(
    graph: DataGraph,
    cluster_of: np.ndarray,
    num_clusters: int,
    chunk_vertices: "int | str | None" = None,
) -> DataGraph:
    """Chunked cluster-quotient graph, bit-identical to
    :func:`repro.graphs.datagraph.contract_graph`.

    Edge chunks spill compact (coarse key, weight) pairs into key-range
    buckets (split on the coarse ``lo`` endpoint); each bucket sorts and
    segment-sums independently.  Bucket outputs concatenate into the
    globally key-sorted merged edge list, and each per-key ``reduceat``
    segment holds the same weights in the same (fine edge list) order as
    the in-core global sort — so the float sums match bit for bit.  The
    O(~100B/edge) in-core transient (endpoint maps, keep mask, sort
    permutation, sorted copies) shrinks to 16B/edge of spill + one
    chunk's working set."""
    _check_cluster_key_domain(num_clusters)
    cluster_of = np.asarray(cluster_of, dtype=np.int64)
    e = graph.edges
    if len(e) == 0:
        return DataGraph(n=num_clusters, edges=np.zeros((0, 2), np.int64))
    chunk = _resolve_chunk(chunk_vertices)
    chunk_e = max(4 * chunk, 1024)
    weights = graph.edge_weights
    n_buckets = max(1, -(-len(e) // chunk_e))
    # Bucket j holds coarse keys with lo in [j*nc/B, (j+1)*nc/B).
    lo_bounds = (np.arange(1, n_buckets, dtype=np.int64)
                 * num_clusters // n_buckets)
    spill_k: List[List[np.ndarray]] = [[] for _ in range(n_buckets)]
    spill_w: List[List[np.ndarray]] = [[] for _ in range(n_buckets)]
    for s in range(0, len(e), chunk_e):
        t = min(s + chunk_e, len(e))
        cu = cluster_of[e[s:t, 0]]
        cv = cluster_of[e[s:t, 1]]
        keep = cu != cv
        if not keep.any():
            continue
        lo = np.minimum(cu[keep], cv[keep])
        hi = np.maximum(cu[keep], cv[keep])
        key = lo * num_clusters + hi
        if weights is None:
            ws = np.ones(len(key), dtype=np.float64)
        else:
            ws = weights[s:t][keep].astype(np.float64)
        if n_buckets == 1:
            spill_k[0].append(key)
            spill_w[0].append(ws)
            continue
        bucket = np.searchsorted(lo_bounds, lo, side="right")
        order = np.argsort(bucket, kind="stable")   # edge order kept per bucket
        bs = bucket[order]
        key, ws = key[order], ws[order]
        cuts = np.searchsorted(bs, np.arange(n_buckets + 1))
        for j in range(n_buckets):
            if cuts[j] < cuts[j + 1]:
                spill_k[j].append(key[cuts[j]:cuts[j + 1]])
                spill_w[j].append(ws[cuts[j]:cuts[j + 1]])
    out_edges: List[np.ndarray] = []
    out_w: List[np.ndarray] = []
    for j in range(n_buckets):
        if not spill_k[j]:
            continue
        ks_j = np.concatenate(spill_k[j])
        ws_j = np.concatenate(spill_w[j])
        spill_k[j], spill_w[j] = [], []          # release as we go
        order = np.argsort(ks_j, kind="stable")
        ks_j, ws_j = ks_j[order], ws_j[order]
        uniq, start = np.unique(ks_j, return_index=True)
        wsum = np.add.reduceat(ws_j, start)
        if not np.isfinite(wsum).all():
            raise ValueError(
                "contracted edge weight sum overflowed to non-finite; "
                "parallel-edge weights saturated the float64 domain")
        out_edges.append(
            np.stack([uniq // num_clusters, uniq % num_clusters], axis=1))
        out_w.append(wsum)
    if not out_edges:
        return DataGraph(n=num_clusters, edges=np.zeros((0, 2), np.int64))
    g = DataGraph(n=num_clusters, edges=np.concatenate(out_edges))
    g.edge_weights = np.concatenate(out_w)
    return g


def coarse_cost_model_streamed(
    cm: CostModel,
    graph_c: DataGraph,
    cluster_of: np.ndarray,
    nc: int,
    chunk_vertices: "int | str | None" = None,
) -> CostModel:
    """Chunked summed-unary fold, bit-identical to
    :func:`repro.core.multilevel.coarse_cost_model`: the per-cluster
    ``reduceat`` segments see the same unary rows in the same (stable
    fine-id) order; only the O(n x servers) permuted copy is replaced by
    per-cluster-range slices."""
    chunk = _resolve_chunk(chunk_vertices)
    net = cm.net
    n = cm.graph.n
    order = np.argsort(cluster_of, kind="stable")
    starts = np.searchsorted(cluster_of[order], np.arange(nc))
    mu_c = np.empty((nc, net.m), dtype=np.float64)
    # Cluster ranges covering ~chunk members each (a range never splits a
    # cluster, so reduceat segments stay whole).
    cut_members = np.arange(chunk, n, chunk, dtype=np.int64)
    cuts = np.unique(np.concatenate([
        np.zeros(1, np.int64), np.searchsorted(starts, cut_members),
        np.asarray([nc], np.int64)]))
    for c0, c1 in zip(cuts[:-1], cuts[1:]):
        m0 = int(starts[c0])
        m1 = int(starts[c1]) if c1 < nc else n
        rows = cm.unary[order[m0:m1]]
        mu_c[c0:c1] = np.add.reduceat(rows, starts[c0:c1] - m0, axis=0)
    zeros = np.zeros(net.m, dtype=np.float64)
    net_c = EdgeNetwork(
        m=net.m, w=net.w, tau=net.tau, alpha=zeros, beta=zeros, gamma=zeros,
        rho=zeros, eps=net.eps, mu=mu_c, sku=net.sku, coords=net.coords,
    )
    return CostModel(net_c, graph_c, cm.gnn)


def coarse_vertex_w_streamed(
    cluster_of: np.ndarray,
    vertex_w: np.ndarray,
    nc: int,
    chunk_vertices: "int | str | None" = None,
) -> np.ndarray:
    """Chunked fine-vertex-count fold.  Counts are integers well inside
    float64's exact range, so partial-sum order cannot matter — the
    result equals the in-core single ``bincount`` exactly."""
    chunk = _resolve_chunk(chunk_vertices)
    acc = np.zeros(nc, dtype=np.float64)
    for a in range(0, len(cluster_of), chunk):
        b = min(a + chunk, len(cluster_of))
        acc += np.bincount(cluster_of[a:b], weights=vertex_w[a:b],
                           minlength=nc)
    return acc.astype(np.int64)


def release_level_views(level: Level) -> None:
    """Release a finished level's derived caches: the graph's CSR views and
    the cost model's unary matrix.  Both are pure deterministic functions
    of the level's primary data (edges, weights, mu) and rebuild bitwise
    identical on the next property access, so the level's CONTENT is
    untouched — only its resident footprint shrinks (CSR + unary are well
    over half a retained level at SIoT density)."""
    level.cm.graph.release_views()
    level.cm.release_unary()


def build_levels_streamed(
    cm: CostModel,
    coarsen_to: int = COARSEN_TO,
    max_levels: Optional[int] = None,
    mu_gate: bool = True,
    chunk_vertices: "int | str | None" = None,
    release_views: bool = True,
) -> List[Level]:
    """Streamed coarsening hierarchy — same levels as
    :func:`repro.core.multilevel.build_levels`, bounded working set.

    ``release_views`` (default on) drops each level's derived caches (CSR
    views + unary matrix) as soon as the next-coarser level exists.  The
    hierarchy's EDGE count shrinks far slower than its vertex count (SIoT
    contraction mostly merges parallel edges late), so a fully-cached
    hierarchy retains ~40B/edge of CSR plus an ``nc x m`` unary duplicate
    of mu PER RUNG — at n=500k that is most of the build's peak RSS, and
    no amount of transient streaming can get under it.  Released views
    rebuild lazily (and bitwise identically) wherever refinement or a
    later stack refresh touches the level, so trajectories are unchanged;
    only the coarsest level keeps its caches (the V-cycle solves it
    immediately after the build).  The finest level is the CALLER's cost
    model: its caches are released too (the refine phase is the next
    consumer and rebuilds them once), which is safe for the same reason —
    engines copy values out of ``unary``, never hold the array itself.
    """
    chunk = _resolve_chunk(chunk_vertices)
    levels = [Level(cm=cm, cluster_of=None,
                    vertex_w=np.ones(cm.graph.n, dtype=np.int64))]
    tau_ref = cm.tau_ref() if mu_gate else 0.0
    cap = max(2, int(np.ceil(
        MAX_CLUSTER_FACTOR * cm.graph.n / max(coarsen_to, 1))))
    while True:
        cur = levels[-1]
        g = cur.cm.graph
        if g.n <= coarsen_to or g.num_edges == 0:
            break
        if max_levels is not None and len(levels) >= max_levels:
            break
        gate = (matching_gate_streamed(g, cur.cm.unary, tau_ref, chunk)
                if mu_gate and tau_ref > 0.0 else None)
        match = heavy_edge_matching_streamed(
            g, cur.vertex_w, cap, gate=gate, chunk_vertices=chunk)
        cluster_of, nc = clusters_from_matching(match)
        if nc >= STAGNATION_FRAC * g.n:
            break
        g_c = contract_graph_streamed(g, cluster_of, nc, chunk)
        cm_c = coarse_cost_model_streamed(cur.cm, g_c, cluster_of, nc, chunk)
        vw_c = coarse_vertex_w_streamed(cluster_of, cur.vertex_w, nc, chunk)
        levels.append(Level(cm=cm_c, cluster_of=cluster_of, vertex_w=vw_c))
        if release_views:
            release_level_views(cur)
    return levels
