"""De-facto placement baselines the paper compares against (Sec. VI-A):

  Random — each client to an arbitrary server.
  Greedy — each client to the server minimizing its *individual* cost
           (data collection + GNN computation + data-dependent maintenance,
           i.e. the unary term; ignores cross-edge traffic).
"""
from __future__ import annotations

import numpy as np

from repro.core.cost import CostModel


def random_layout(cm: CostModel, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, cm.net.m, size=cm.graph.n).astype(np.int64)


def greedy_layout(cm: CostModel) -> np.ndarray:
    """argmin_i [ mu_vi + C_P(v,i) + rho_i ] per vertex."""
    return cm.unary.argmin(axis=1).astype(np.int64)


def uploading_first_layout(cm: CostModel) -> np.ndarray:
    """The initialization tactic discussed in Sec. IV-B: greedily minimize C_U
    only — useful when data collection dominates."""
    return cm.net.mu.argmin(axis=1).astype(np.int64)
