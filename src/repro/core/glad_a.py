"""GLAD-A: adaptive scheduling between GLAD-E and GLAD-S (paper Alg. 3).

The performance drift f(t) = C^E(t) - C^S(t) cannot be observed (only one
algorithm runs per slot), so GLAD-A tracks the Thm-8 upper bound

    f(t) <= C(pi(t-1) | G(t)) - C(t-1)

i.e. the cost of the *unadjusted* layout on the evolved graph minus last
slot's cost — computable from known quantities.  While the accumulated drift
stays within the SLA theta, the cheap incremental GLAD-E runs; once exceeded,
a global GLAD-S re-layout is triggered and the accumulator resets.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.cost import CostModel, GNNWorkload
from repro.core.engine import LayoutSession
from repro.core.glad_e import glad_e, seed_new_vertices
from repro.core.glad_s import glad_s
from repro.graphs.datagraph import DataGraph
from repro.graphs.edgenet import EdgeNetwork


def drift_bound(
    cm_new: CostModel,
    old_graph: DataGraph,
    assign_old: np.ndarray,
    last_cost: float,
) -> float:
    """Thm 8: f(t) <= C(pi(t-1)|G(t)) - C(t-1).

    The unadjusted layout is pi(t-1) carried forward; per the proof, inserted
    vertices are charged at their *maximum*-cost server to keep the bound an
    upper bound; deletions never raise cost.
    """
    new_graph = cm_new.graph
    assign = np.zeros(new_graph.n, dtype=np.int64)
    keep = min(old_graph.n, new_graph.n)
    assign[:keep] = assign_old[:keep]
    carried = cm_new.total(assign)
    if new_graph.n > old_graph.n:
        placed = np.ones(new_graph.n, dtype=bool)
        placed[old_graph.n:] = False
        extra = 0.0
        for v in range(old_graph.n, new_graph.n):
            # Vectorized over servers x placed neighbors (CostModel caches).
            extra += float(cm_new.marginal_all(placed, assign, v).max())
            placed[v] = True
        # carried already counted them at server 0; replace with the max bound.
        base_ids = np.arange(old_graph.n, new_graph.n)
        carried -= float(cm_new.unary[base_ids, assign[base_ids]].sum())
        carried += extra
    return max(0.0, carried - last_cost)


@dataclasses.dataclass
class SlotRecord:
    t: int
    algorithm: str          # 'glad-e' | 'glad-s'
    cost: float
    drift_estimate: float
    accumulated_drift: float
    migrated_vertices: int
    wall_time_s: float


class GladA:
    """Stateful adaptive scheduler over a stream of evolved graphs."""

    def __init__(
        self,
        net: EdgeNetwork,
        gnn: GNNWorkload,
        graph0: DataGraph,
        theta: float,
        R: Optional[int] = None,
        seed: int = 0,
        backend: str = "auto",
        session: "bool | LayoutSession" = True,
    ):
        self.net, self.gnn, self.theta = net, gnn, theta
        self.R, self.seed, self.backend = R, seed, backend
        # Cross-slot persistent engine: assembly cache + warm residuals
        # earned in slot t survive into slot t+1 (trajectories stay
        # bit-identical with session=False; only wall time changes).
        if session is True:
            session = LayoutSession(backend=backend)
        elif session is False:
            session = None
        self.session = session
        cm0 = CostModel(net, graph0, gnn)
        res = glad_s(cm0, R=R, seed=seed, backend=backend,
                     session=self.session)
        self.graph = graph0
        self.assign = res.assign
        self.last_cost = res.cost
        self.acc_drift = 0.0
        self.t = 0
        self.records: List[SlotRecord] = [
            SlotRecord(0, "glad-s", res.cost, 0.0, 0.0, 0, res.wall_time_s)
        ]

    def step(self, new_graph: DataGraph) -> SlotRecord:
        """Paper Alg. 3 for one time slot."""
        self.t += 1
        cm_new = CostModel(self.net, new_graph, self.gnn)
        f_hat = drift_bound(cm_new, self.graph, self.assign, self.last_cost)
        self.acc_drift += f_hat

        if self.acc_drift <= self.theta:
            algo = "glad-e"
            res = glad_e(
                cm_new, self.graph, self.assign,
                R=self.R, seed=self.seed + self.t, backend=self.backend,
                session=self.session,
            )
        else:
            algo = "glad-s"
            # Warm-start global re-layout from the carried layout.
            assign = np.zeros(new_graph.n, dtype=np.int64)
            keep = min(self.graph.n, new_graph.n)
            assign[:keep] = self.assign[:keep]
            if new_graph.n > self.graph.n:
                mask = np.zeros(new_graph.n, dtype=bool)
                mask[self.graph.n:] = True
                assign = seed_new_vertices(cm_new, assign, mask)
            res = glad_s(
                cm_new, R=self.R, init=assign,
                seed=self.seed + self.t, backend=self.backend,
                session=self.session,
            )
            self.acc_drift = 0.0

        keep = min(self.graph.n, new_graph.n, len(res.assign), len(self.assign))
        migrated = int((res.assign[:keep] != self.assign[:keep]).sum())
        self.graph = new_graph
        self.assign = res.assign
        self.last_cost = res.cost
        rec = SlotRecord(
            self.t, algo, res.cost, f_hat, self.acc_drift, migrated,
            res.wall_time_s,
        )
        self.records.append(rec)
        return rec
