"""Layout -> device-placement bridge (DESIGN.md §4).

Promotes the paper's technique to a first-class placement engine for the
framework's parallel workloads:

  * ``data_partition``   — GLAD layout of the GNN data graph over mesh slices,
                           exported as padded per-device vertex lists + halo
                           exchange plans for the shard_map BSP engine.
  * ``expert_layout``    — MoE expert placement: experts are vertices weighted
                           by routed-token load (C_P), expert co-activation is
                           the link set (C_T = all-to-all bytes), mesh slices
                           are the servers.  GLAD-S minimizes collective
                           traffic + compute imbalance.
  * ``rebalance``        — straggler mitigation: re-run GLAD-E with degraded
                           alpha_i for the slow device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.cost import CostModel, GNNWorkload
from repro.core.glad_s import glad_s
from repro.graphs.datagraph import DataGraph
from repro.graphs.edgenet import EdgeNetwork, pod_edge_network


@dataclasses.dataclass
class DevicePartition:
    """A static, padding-complete partition consumable by shard_map.

    All arrays are rectangular (padded with -1 / last-valid) so the compiled
    program is shape-static regardless of the layout.
    """

    num_parts: int
    assign: np.ndarray            # (n,) vertex -> part
    part_vertices: np.ndarray     # (P, cap) vertex ids, -1 padded
    part_sizes: np.ndarray        # (P,)
    halo_src: np.ndarray          # (P, halo_cap) vertex ids this part must RECEIVE
    halo_sizes: np.ndarray        # (P,)
    cut_links: int
    cost_factors: dict
    # Optional move-vs-replicate overlay (core.cost.Replication) attached by
    # the replicate= solver knob: the read-only copies each part should host
    # on top of its residents.  compile_plan promotes it to the ShardPlan's
    # persistent replica table.
    replication: Optional[object] = None

    @property
    def capacity(self) -> int:
        return int(self.part_vertices.shape[1])


def _pad_lists(lists, pad_val=-1, cap: Optional[int] = None) -> np.ndarray:
    cap = cap or max((len(l) for l in lists), default=1)
    cap = max(cap, 1)
    out = np.full((len(lists), cap), pad_val, dtype=np.int64)
    for k, l in enumerate(lists):
        out[k, : len(l)] = l
    return out


def halos_of(
    graph: DataGraph,
    assign: np.ndarray,
    num_parts: int,
    parts: Optional[np.ndarray] = None,
) -> dict:
    """Per-part halo sets: the out-of-part neighbors each part aggregates.

    One grouped pass over the cut links (no per-part edge scan): every cut
    link (u, v) contributes (part(v), u) and (part(u), v) need-pairs; a
    single ``np.unique`` over the combined key yields each part's halo,
    sorted ascending by vertex id — deterministic, and the order the
    ShardPlan's searchsorted halo coordinates rely on.

    ``parts`` restricts the output (and the grouping work) to a subset —
    the plan-patch path asks only for the dirty parts.
    """
    targets = range(num_parts) if parts is None else [int(p) for p in parts]
    empty = np.zeros(0, np.int64)
    out = {p: empty for p in targets}
    e = graph.edges
    if len(e) == 0 or graph.n == 0:
        return out
    pu, pv = assign[e[:, 0]], assign[e[:, 1]]
    cross = pu != pv
    if parts is not None:
        # Restrict BEFORE materializing the need-pairs: a dirty-part patch
        # pays O(cut links incident to dirty parts), not O(cut links).
        inpart = np.zeros(num_parts, dtype=bool)
        inpart[np.asarray(parts, dtype=np.int64)] = True
        c1 = cross & inpart[pv]
        c2 = cross & inpart[pu]
    else:
        c1 = c2 = cross
    owner = np.concatenate([pv[c1], pu[c2]]).astype(np.int64)
    need = np.concatenate([e[c1, 0], e[c2, 1]]).astype(np.int64)
    if len(owner) == 0:
        return out
    key = np.unique(owner * np.int64(graph.n) + need)
    ow = key // graph.n
    nd = key % graph.n
    bounds = np.searchsorted(ow, np.array(sorted(targets) + [num_parts]))
    for k, p in enumerate(sorted(targets)):
        out[p] = nd[bounds[k]:bounds[k + 1]]
    return out


def partition_from_assign(
    graph: DataGraph, assign: np.ndarray, num_parts: int, factors: dict,
    replication=None,
) -> DevicePartition:
    parts = [np.where(assign == p)[0] for p in range(num_parts)]
    sizes = np.array([len(p) for p in parts], dtype=np.int64)
    # Halo: for each part, the out-of-part neighbors its vertices aggregate.
    e = graph.edges
    halo_map = halos_of(graph, assign, num_parts)
    halos = [halo_map[p] for p in range(num_parts)]
    cut = int((assign[e[:, 0]] != assign[e[:, 1]]).sum()) if len(e) else 0
    return DevicePartition(
        num_parts=num_parts,
        assign=assign.astype(np.int64),
        part_vertices=_pad_lists(parts),
        part_sizes=sizes,
        halo_src=_pad_lists(halos),
        halo_sizes=np.array([len(h) for h in halos], dtype=np.int64),
        cut_links=cut,
        cost_factors=factors,
        replication=replication,
    )


def data_partition(
    graph: DataGraph,
    gnn: GNNWorkload,
    num_parts: int,
    pods: int = 1,
    net: Optional[EdgeNetwork] = None,
    R: Optional[int] = None,
    seed: int = 0,
    init: Optional[np.ndarray] = None,
    workers: int = 0,
    cache: "bool | str" = "auto",
    chunk_nodes: "int | str" = "auto",
    warm: "bool | str" = "auto",
    multilevel: "bool | str" = False,
    coarsen_to: int = 1024,
    levels: Optional[int] = None,
    replicate: "bool | dict" = False,
) -> DevicePartition:
    """GLAD-S over a pod-shaped EdgeNetwork -> shard_map-ready partition.

    Uses the batched disjoint-pair sweep — the placement bridge wants wall
    time, not the paper's exact Alg.-1 trajectory.  ``workers`` /
    ``cache`` / ``chunk_nodes`` / ``warm`` tune the engine's block fan-out,
    cross-round assembly caching and warm-started incremental re-solves;
    ``multilevel`` ('auto' recommended for n >= 200k) routes the layout
    through the coarsen/solve/refine V-cycle
    (see :func:`repro.core.glad_s.glad_s`).  ``replicate`` (True or a dict
    of ``replicate_greedy`` kwargs) attaches the move-vs-replicate overlay
    to the partition — ``compile_plan`` then materializes the replica
    table; the cut itself is unchanged."""
    if net is None:
        net = pod_edge_network(num_parts, graph.n, pods=pods, seed=seed)
    cm = CostModel(net, graph, gnn)
    res = glad_s(cm, R=R, seed=seed, init=init, sweep="batched",
                 workers=workers, cache=cache, chunk_nodes=chunk_nodes,
                 warm=warm, multilevel=multilevel, coarsen_to=coarsen_to,
                 levels=levels, replicate=replicate)
    return partition_from_assign(graph, res.assign, num_parts, res.factors,
                                 replication=res.replication)


# --------------------------------------------------------------------- MoE
def coactivation_graph(
    routing_counts: np.ndarray, top_pairs: int = 4096
) -> DataGraph:
    """Build the expert co-activation graph from a routing histogram.

    Args:
      routing_counts: (E, E) symmetric counts of token-level co-routing
        (tokens whose top-k set contains both experts), diagonal = load.
    """
    E = routing_counts.shape[0]
    iu, ju = np.triu_indices(E, 1)
    wts = routing_counts[iu, ju]
    order = np.argsort(wts)[::-1][:top_pairs]
    keep = order[wts[order] > 0]
    edges = np.stack([iu[keep], ju[keep]], axis=1)
    g = DataGraph(n=E, edges=edges)
    # Weights aligned to the CANONICAL edge order (C_T = tau * co-activation).
    g.edge_weights = routing_counts[g.edges[:, 0], g.edges[:, 1]].astype(
        np.float64)
    g.coords = np.zeros((E, 2), dtype=np.float32)
    return g


def expert_layout(
    routing_counts: np.ndarray,
    num_slices: int,
    pods: int = 1,
    flops_per_token: float = 1.0,
    bytes_per_pair: float = 1.0,
    balance_rounds: int = 5,
    balance_tol: float = 1.15,
    seed: int = 0,
) -> DevicePartition:
    """GLAD applied to MoE expert placement (DESIGN.md §4, kimi/deepseek).

    Cost mapping: the unary term carries per-expert routed load (alpha_i *
    load_v — the paper's C_P), C_T carries co-activation traffic (tau *
    co-routed tokens).  Because makespan (max per-slice load) is not
    expressible in GLAD's linear unary terms, we add *congestion pricing*
    on top of the paper: after each layout, alpha_i of overloaded slices is
    scaled up exponentially and GLAD-S re-runs warm-started, until the load
    imbalance meets ``balance_tol`` (beyond-paper extension, DESIGN.md §7).
    """
    E = routing_counts.shape[0]
    g = coactivation_graph(routing_counts)
    net = pod_edge_network(num_slices, E, pods=pods, seed=seed,
                           link_cost=bytes_per_pair)
    load = routing_counts.diagonal().astype(np.float64)
    net.mu = np.zeros((E, num_slices))
    gnn = GNNWorkload([1, 1], agg_scale=flops_per_token, name="moe")
    target = load.sum() / num_slices

    # 1) Capacity-capped agglomeration: merge heaviest co-activation pairs
    #    while cluster load stays under target*tol (union-find).
    cap = target * balance_tol
    parent = np.arange(E)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    cl_load = load.copy()
    order = np.argsort(-g.edge_weights) if len(g.edges) else []
    for ei in order:
        u, v = g.edges[ei]
        ru, rv = find(u), find(v)
        if ru != rv and cl_load[ru] + cl_load[rv] <= cap:
            parent[rv] = ru
            cl_load[ru] += cl_load[rv]

    # 2) Bin-pack clusters largest-first onto the least-loaded slice.
    roots = {}
    for v in range(E):
        roots.setdefault(find(v), []).append(v)
    slices_load = np.zeros(num_slices)
    assign0 = np.zeros(E, dtype=np.int64)
    for r, members in sorted(roots.items(),
                             key=lambda kv: -load[kv[1]].sum()):
        s = int(np.argmin(slices_load))
        assign0[members] = s
        slices_load[s] += load[members].sum()

    # 3) GLAD-S refinement with a balance guard: accept the refined layout
    #    only while the load imbalance stays within tolerance (makespan is
    #    outside GLAD's linear objective — noted in DESIGN.md §7).
    cm = CostModel(net, g, gnn)
    res = glad_s(cm, seed=seed, init=assign0, R=num_slices, sweep="batched")
    sl = np.array([load[res.assign == s].sum() for s in range(num_slices)])
    if sl.max() > cap * 1.05:
        assign = assign0
        factors = cm.factors(assign0)
    else:
        assign = res.assign
        factors = res.factors
    return partition_from_assign(g, assign, num_slices, factors)


def rebalance(
    graph: DataGraph,
    gnn: GNNWorkload,
    part: DevicePartition,
    net: EdgeNetwork,
    straggler: int,
    slow_factor: float,
    seed: int = 0,
    workers: int = 0,
    cache: "bool | str" = "auto",
    chunk_nodes: "int | str" = "auto",
    warm: "bool | str" = "auto",
    multilevel: "bool | str" = False,
    coarsen_to: int = 1024,
    levels: Optional[int] = None,
    replicate: "bool | dict" = False,
    session=None,
) -> DevicePartition:
    """Straggler mitigation: degrade the slow server's compute coefficients
    and run an incremental re-layout warm-started from the current one.
    ``multilevel`` escalates to the V-cycle (warm init restricted up the
    hierarchy by majority vote) — for fleets serving very large graphs.
    ``replicate`` re-greedies the move-vs-replicate overlay against the
    degraded fleet and attaches it to the new partition.  ``session``
    (a :class:`repro.core.engine.LayoutSession`) reuses engine state from
    previous relayouts; incompatible with ``multilevel``."""
    net2 = net.degrade(straggler, slow_factor)
    cm = CostModel(net2, graph, gnn)
    res = glad_s(cm, init=part.assign, R=net2.m, seed=seed, sweep="batched",
                 workers=workers, cache=cache, chunk_nodes=chunk_nodes,
                 warm=warm, multilevel=multilevel, coarsen_to=coarsen_to,
                 levels=levels, replicate=replicate, session=session)
    return partition_from_assign(graph, res.assign, part.num_parts,
                                 res.factors, replication=res.replication)
