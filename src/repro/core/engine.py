"""Incremental pairwise min-cut layout engine (the fast path behind GLAD).

The seed implementation of Alg. 1 re-evaluated the full O(n+m) objective per
proposal and rebuilt every auxiliary graph with per-edge Python loops; at the
ROADMAP's production graph sizes the *optimizer* dominated end-to-end time.
This engine makes one Alg.-1 iteration cost O(|members| + vol(members)):

  * cached assignment state (:class:`repro.core.cost.LayoutState`) turns the
    accept decision into an exact delta over moved vertices + incident links;
  * auxiliary graphs are assembled with pure array ops — global->local index
    translation via preallocated scratch vectors, incident-edge discovery via
    the CSR edge-id view (no scan of the global edge list);
  * scratch buffers (member mask, local ids, theta vectors, flow arenas) are
    allocated once and reused across iterations;
  * a *batched sweep* solves a round-robin matching of disjoint server pairs
    per round.  Disjoint pairs touch disjoint member sets, so their cuts can
    be solved from one snapshot and composed; every acceptance still uses an
    exact delta against the live state, so composing never mis-accepts.

Round -> block -> scatter pipeline (the block-diagonal round solver):

  1. **round** — :meth:`PairCutEngine.sweep_round` takes one round-robin
     matching of disjoint server pairs, skips the clean ones, and
     batch-assembles the dirty ones' auxiliary graphs in a single pass of
     array ops: one vertex->block lookup classifies every vertex, one
     ragged CSR gather yields all incident links, and per-block t-link /
     n-link weights come from vectorized gathers over the concatenated
     member list (no per-pair Python work).
  2. **block** — members without intra-pair links are settled by the
     vectorized t-link argmin; the connected cores of all blocks are packed
     into ONE block-diagonal symmetric-CSR flow problem glued at a shared
     source/sink and solved by a single scipy max-flow pass whose BFS never
     crosses block boundaries (:func:`repro.core.maxflow.
     min_st_cut_csr_blocks`).  Scratch (member masks, local ids, the flow
     CSR arena) is grown once per sweep and reused across rounds.  Without
     scipy, blocks fall back to per-block pure-python Dinic solves, fanned
     out over ``workers`` threads/processes.
  3. **scatter** — each block's slice of the source-side mask maps back to
     "member stays on i / moves to j"; the proposals are then applied in
     pair order, each guarded by an exact O(moved + incident) live delta,
     so composition semantics are identical to the per-pair batched sweep.

The engine preserves the paper's auxiliary-graph semantics exactly
(Sec. IV-B: t-link = unary + side-effect traffic to third servers, n-link =
tau_ij per internal link), so Thm 4-6 continue to hold per pair.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import CostModel, LayoutState
from repro.core.maxflow import (_HAVE_SCIPY, CutArena,
                                assemble_symmetric_flow_csr, min_st_cut,
                                min_st_cut_csr, min_st_cut_csr_blocks)
from repro.graphs.datagraph import csr_multirange


def round_robin_rounds(m: int) -> List[List[Tuple[int, int]]]:
    """Circle-method tournament schedule: m-1 rounds (m even; m rounds if
    odd) of vertex-disjoint pairs that jointly cover every pair i < j."""
    ids = list(range(m))
    if m % 2:
        ids.append(-1)                       # bye slot
    k = len(ids)
    rounds: List[List[Tuple[int, int]]] = []
    for _ in range(max(k - 1, 0)):
        rnd = []
        for a in range(k // 2):
            x, y = ids[a], ids[k - 1 - a]
            if x >= 0 and y >= 0:
                rnd.append((min(x, y), max(x, y)))
        rounds.append(rnd)
        ids = [ids[0], ids[-1]] + ids[1:-1]  # rotate all but the pivot
    return rounds


class PairCutEngine:
    """Stateful solver of restricted two-server subproblems over one layout.

    Owns a :class:`LayoutState` (read ``.state.assign`` / ``.state.total``)
    plus the preallocated scratch that keeps per-pair work at
    O(n bool-scan + pair member volume): the accept path is
    O(moved + incident links), auxiliary construction is proportional to
    the pair's member volume, and the only full-graph term left is the
    vectorized member scan in :meth:`members_of` — deliberate, it is
    memory-bandwidth noise next to one min-cut solve.
    """

    def __init__(
        self,
        cm: CostModel,
        assign: np.ndarray,
        active: Optional[np.ndarray] = None,
        backend: str = "auto",
        workers: int = 0,
        worker_mode: str = "thread",
    ):
        self.cm = cm
        self._workers = int(workers)
        self._worker_mode = worker_mode
        self.state = cm.layout_state(assign)
        g = cm.graph
        self._indptr = g.indptr
        self._indices = g.indices
        self._eids = g.edge_ids
        self._w = self.state._w                  # share LayoutState's copy
        self._unit_w = g.edge_weights is None    # skip weight gathers
        self._tau = cm.net.tau
        self._active = None if active is None else np.asarray(active, bool)
        self._backend = backend
        self._use_csr = _HAVE_SCIPY and backend in ("auto", "scipy")
        self._arena = CutArena()
        # Scratch, allocated once: member mask + global->local translation.
        self._mask = np.zeros(g.n, dtype=bool)
        self._loc = np.full(g.n, -1, dtype=np.int64)
        # Grown-on-demand per-pair buffers (theta / flow edge arrays).
        self._theta_cap = 0
        self._theta_i = self._theta_j = None
        # Dirty-pair tracking: the auxiliary graph of (i, j) depends only on
        # its member set and the layout of members' neighbors, so a pair is
        # clean — its solve would reproduce the last (rejected) proposal
        # verbatim — until a commit touches one of its servers.  Clean
        # probes are skipped; this keeps the Alg.-1 trajectory bit-identical
        # while eliding most non-improving cut solves near convergence.
        self._version = 0
        self._server_dirty = np.zeros(cm.net.m, dtype=np.int64)
        self._pair_stamp: dict = {}

    def pair_clean(self, i: int, j: int) -> bool:
        """True iff (i, j)'s auxiliary graph is unchanged since its last
        solve AND that solve did not end in an accept (an accepted solve
        dirties both servers, so clean implies last-result == reject)."""
        stamp = self._pair_stamp.get((i, j), -1)
        return stamp >= max(self._server_dirty[i], self._server_dirty[j])

    def _mark_dirty(self, moved: np.ndarray, old_servers: np.ndarray) -> None:
        """After committing ``moved``, dirty every server whose pairs could
        see a different auxiliary graph: the movers' old and new servers
        (membership changes) plus every server hosting a neighbor of a
        mover (their boundary side-effect terms reference the movers'
        layout)."""
        assign = self.state.assign
        servers = [old_servers, assign[moved]]
        flat, _ = csr_multirange(self._indptr, moved)
        if len(flat):
            servers.append(assign[self._indices[flat]])
        dirty = np.unique(np.concatenate(servers))
        self._version += 1
        self._server_dirty[dirty] = self._version

    # ------------------------------------------------------------- internals
    def _thetas(self, k: int):
        if k > self._theta_cap:
            cap = max(256, 1 << int(np.ceil(np.log2(max(k, 1)))))
            self._theta_i = np.empty(cap, dtype=np.float64)
            self._theta_j = np.empty(cap, dtype=np.float64)
            self._theta_cap = cap
        return self._theta_i[:k], self._theta_j[:k]

    def members_of(self, i: int, j: int) -> np.ndarray:
        assign = self.state.assign
        pair_mask = (assign == i) | (assign == j)
        if self._active is not None:
            pair_mask &= self._active
        return np.flatnonzero(pair_mask)

    # ----------------------------------------------------------- pair solve
    def solve_pair(
        self, i: int, j: int
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Min s-t cut of the auxiliary graph A(i, j) over the current
        layout.  Returns (members, proposed_servers_for_members) or None if
        the pair hosts no active vertices.  Does NOT mutate the state."""
        members = self.members_of(i, j)
        k = len(members)
        if k == 0:
            return None
        cm, assign = self.cm, self.state.assign
        mask, loc = self._mask, self._loc
        mask[members] = True
        loc[members] = np.arange(k)

        theta_i, theta_j = self._thetas(k)
        theta_i[:] = cm.unary[members, i]
        theta_j[:] = cm.unary[members, j]

        # Incident links, straight from the member rows of the CSR view:
        # one ragged multi-range gather gives (member-local row, neighbor,
        # edge id) triples — no scan of the global edge list, no sort/unique.
        flat, row = csr_multirange(self._indptr, members)
        if len(flat):
            nbr = self._indices[flat]
            nbr_in = mask[nbr]
            # Boundary links (neighbor outside the member set) appear exactly
            # once: side-effect traffic to the frozen third-server neighbor,
            # added to BOTH unary columns so each cut stays globally
            # cost-aware (Sec. IV-B).
            bnd = ~nbr_in
            if bnd.any():
                ins = row[bnd]
                outs = assign[nbr[bnd]]
                ti = self._tau[i, outs]
                tj = self._tau[j, outs]
                if not self._unit_w:
                    bw = self._w[self._eids[flat[bnd]]]
                    ti = ti * bw
                    tj = tj * bw
                theta_i += np.bincount(ins, weights=ti, minlength=k)
                theta_j += np.bincount(ins, weights=tj, minlength=k)
            # Internal links appear twice (once per endpoint's row) — which
            # is exactly the two directed arcs the flow network needs.
            internal = nbr_in
            int_a = row[internal]
            int_b = loc[nbr[internal]]
            tij = float(self._tau[i, j])
            if self._unit_w:
                int_w = np.broadcast_to(tij, len(int_a))
            else:
                int_w = tij * self._w[self._eids[flat[internal]]]
        else:
            int_a = int_b = np.zeros(0, dtype=np.int64)
            int_w = np.zeros(0, dtype=np.float64)

        # Members without intra-pair links are singleton flow components:
        # the cut decides them by the cheaper t-link alone, so settle them
        # with a vectorized argmin and solve the flow only over the core.
        # (Disjoint components of a flow network optimize independently —
        # this is exact, and it shrinks the solver input by the boundary-
        # heavy majority of members on sparse layouts.)
        new_assign = np.empty(k, dtype=np.int64)
        has_int = np.zeros(k, dtype=bool)
        has_int[int_a] = True
        singles = ~has_int
        # Tie -> sink side (j), matching the max-flow residual convention
        # (both t-links saturate, so v is unreachable from s).
        new_assign[singles] = np.where(
            theta_i[singles] < theta_j[singles], i, j)

        core = np.flatnonzero(has_int)
        kc = len(core)
        if kc:
            cloc = np.empty(k, dtype=np.int64)
            cloc[core] = np.arange(kc)
            int_a = cloc[int_a]
            int_b = cloc[int_b]
            th_i = theta_i[core]
            th_j = theta_j[core]
            side = self._solve_flow(kc, int_a, int_b, int_w, th_i, th_j)
            new_assign[core] = np.where(side[:kc], i, j)

        # Reset scratch (only the touched entries).
        mask[members] = False
        loc[members] = -1
        return members, new_assign

    def _solve_flow(self, k, int_a, int_b, int_w, theta_i, theta_j):
        """Min cut of the (connected-core) auxiliary flow network: nodes
        0..k-1 plus S=k, T=k+1; t-link caps theta_j (s->v) / theta_i (v->t);
        internal arcs already both directions in (int_a, int_b)."""
        S, T = k, k + 1
        n_int = len(int_w)
        if self._use_csr:
            # Direct CSR assembly with SYMMETRIC structure (zero-capacity
            # reverse arcs for every t-link; internal arcs are already both
            # directions): scipy's flow matrix then shares this sparsity
            # exactly, making the residual a plain array difference in
            # min_st_cut_csr.  scipy's canonical flow output requires
            # canonical input; the member gather already yields arcs in
            # (row, col) order (DataGraph rows are dst-sorted, member-local
            # ids rank-monotone), so the assembler's lexsort is skipped.
            n_aux, S, T, indptr, cols, caps = assemble_symmetric_flow_csr(
                k, int_a, int_b, int_w, theta_i, theta_j, arena=self._arena,
                presorted=True)
            _, side = min_st_cut_csr(n_aux, S, T, indptr, cols, caps)
            return side
        us = np.empty(2 * k + n_int, dtype=np.int64)
        vs = np.empty(2 * k + n_int, dtype=np.int64)
        caps_uv = np.empty(2 * k + n_int, dtype=np.float64)
        caps_vu = np.zeros(2 * k + n_int, dtype=np.float64)
        us[:k] = S
        vs[:k] = np.arange(k)
        caps_uv[:k] = theta_j
        us[k:2 * k] = np.arange(k)
        vs[k:2 * k] = T
        caps_uv[k:2 * k] = theta_i
        # Internal arcs appear twice in (int_a, int_b) (both directions);
        # emit them as one-way capacities.
        us[2 * k:] = int_a
        vs[2 * k:] = int_b
        caps_uv[2 * k:] = int_w
        _, side = min_st_cut(
            k + 2, S, T, us, vs, caps_uv, caps_vu,
            backend=self._backend, arena=self._arena,
        )
        return side

    # ----------------------------------------------------------- accept path
    def try_pair(self, i: int, j: int, tol: float = 1e-9) -> Tuple[bool, bool]:
        """Solve pair (i, j) and commit iff the exact delta improves.

        Returns (solved, accepted).  Clean pairs (see :meth:`pair_clean`)
        skip the solve entirely — the result is known to be a reject.  The
        accept decision costs O(|moved| + incident links) via the cached
        LayoutState — no full objective evaluation."""
        if self.pair_clean(i, j):
            return True, False
        sol = self.solve_pair(i, j)
        if sol is None:
            self._pair_stamp[(i, j)] = self._version
            return False, False
        members, proposed = sol
        accepted = self.try_apply(members, proposed, tol=tol)
        # Stamp AFTER a possible commit: re-solving the just-accepted pair
        # reproduces the committed layout verbatim (same auxiliary graph,
        # deterministic cut), i.e. a reject — so the pair starts clean.
        self._pair_stamp[(i, j)] = self._version
        return True, accepted

    def sweep_round(
        self,
        pairs: Sequence[Tuple[int, int]],
        tol: float = 1e-9,
        solver: str = "auto",
    ) -> List[Tuple[bool, bool]]:
        """One batched round: solve a matching of disjoint server pairs from
        the current snapshot, then apply each cut with an exact live delta.

        The member sets are disjoint, so the solves are independent;
        composition is guarded per pair by the delta against the state as
        commits land.  Returns (solved, accepted) per pair, in order.

        ``solver``:
          * ``'block'`` (the ``'auto'`` default) — batch-assemble every
            dirty pair's auxiliary graph and solve them as ONE
            block-diagonal flow problem (one scipy pass; per-block Dinic
            with optional ``workers`` fan-out without scipy).
          * ``'pairwise'`` — PR-1 behavior: one cut solve per dirty pair.
        """
        if solver == "auto":
            solver = "block"
        # Solve phase — nothing mutates the state, so every solve sees the
        # same snapshot and the same dirty-version.
        snapshot_version = self._version
        if solver == "pairwise":
            sols = [
                "clean" if self.pair_clean(i, j) else self.solve_pair(i, j)
                for i, j in pairs
            ]
        elif solver == "block":
            sols: List = []
            dirty_slots, dirty_pairs = [], []
            for slot, (i, j) in enumerate(pairs):
                if self.pair_clean(i, j):
                    sols.append("clean")
                else:
                    sols.append(None)
                    dirty_slots.append(slot)
                    dirty_pairs.append((i, j))
            servers = [s for p in dirty_pairs for s in p]
            if len(servers) != len(set(servers)):
                # Blocks are only well-defined for a MATCHING; a shared
                # server would silently misclassify its members, so solve
                # overlapping rounds per pair instead.
                for slot, (i, j) in zip(dirty_slots, dirty_pairs):
                    sols[slot] = self.solve_pair(i, j)
            elif dirty_pairs:
                for slot, sol in zip(dirty_slots,
                                     self._solve_round_blocks(dirty_pairs)):
                    sols[slot] = sol
        else:
            raise ValueError(f"unknown round solver {solver!r}")

        # Apply phase — identical for every solver: pair order, exact live
        # delta per acceptance, PR-1 dirty-stamp semantics.
        out = []
        for (i, j), sol in zip(pairs, sols):
            if isinstance(sol, str):                 # clean: known reject
                out.append((True, False))
                continue
            if sol is None:
                self._pair_stamp[(i, j)] = snapshot_version
                out.append((False, False))
                continue
            dirt_before = max(self._server_dirty[i], self._server_dirty[j])
            accepted = self.try_apply(*sol, tol=tol)
            # "Clean implies re-solve == reject" only holds for an accepted
            # pair if nothing ELSE dirtied it between its snapshot solve and
            # this commit — then its layout equals its own deterministic cut
            # and the post-commit stamp is valid.  If another pair's commit
            # in this round touched its servers (dirt_before > solve
            # version), or it was rejected, keep the solve-time stamp so the
            # pair is re-solved against the fresh state.
            if accepted and dirt_before <= snapshot_version:
                self._pair_stamp[(i, j)] = self._version
            else:
                self._pair_stamp[(i, j)] = snapshot_version
            out.append((True, accepted))
        return out

    # ---------------------------------------------------- block round solve
    def _solve_round_blocks(
        self, dirty: Sequence[Tuple[int, int]]
    ) -> List[Optional[Tuple[np.ndarray, np.ndarray]]]:
        """Batch-assemble the auxiliary graphs of the round's dirty pairs
        and solve them as one block-diagonal flow problem.

        Returns, per dirty pair (in order), ``None`` (no members) or
        ``(members, proposed_servers)`` exactly as :meth:`solve_pair` —
        does NOT mutate the state.

        Vertex-disjoint server pairs => disjoint member sets, so one
        vertex->block classification covers the whole round and a single
        ragged CSR gather yields every block's incident links at once."""
        cm, assign = self.cm, self.state.assign
        B = len(dirty)
        srv_i = np.fromiter((p[0] for p in dirty), np.int64, count=B)
        srv_j = np.fromiter((p[1] for p in dirty), np.int64, count=B)
        lookup = np.full(cm.net.m, -1, dtype=np.int64)
        lookup[srv_i] = np.arange(B)
        lookup[srv_j] = np.arange(B)
        vblk = lookup[assign]                       # vertex -> block (or -1)
        if self._active is not None:
            vblk = np.where(self._active, vblk, -1)
        sel = np.flatnonzero(vblk >= 0)
        if len(sel) == 0:
            return [None] * B
        vb = vblk[sel]
        order = np.argsort(vb, kind="stable")       # block-grouped, ascending
        members_all = sel[order]                    # within each block
        sizes = np.bincount(vb, minlength=B)
        bptr = np.zeros(B + 1, dtype=np.int64)
        np.cumsum(sizes, out=bptr[1:])
        N = len(members_all)

        rep_i = np.repeat(srv_i, sizes)             # per-member block servers
        rep_j = np.repeat(srv_j, sizes)
        mrow_blk = np.repeat(np.arange(B), sizes)
        theta_i = cm.unary[members_all, rep_i].astype(np.float64)
        theta_j = cm.unary[members_all, rep_j].astype(np.float64)
        loc = self._loc                             # global -> member row
        loc[members_all] = np.arange(N)

        flat, rep = csr_multirange(self._indptr, members_all)
        if len(flat):
            nbr = self._indices[flat]
            rowb = mrow_blk[rep]
            # A neighbor is internal iff it is a member of the SAME block;
            # members of other blocks are frozen third-server vertices for
            # this pair (their commits land only in the apply phase).
            internal = vblk[nbr] == rowb
            bnd = ~internal
            if bnd.any():
                ins = rep[bnd]
                outs = assign[nbr[bnd]]
                bi = rowb[bnd]
                ti = self._tau[srv_i[bi], outs]
                tj = self._tau[srv_j[bi], outs]
                if not self._unit_w:
                    bw = self._w[self._eids[flat[bnd]]]
                    ti = ti * bw
                    tj = tj * bw
                theta_i += np.bincount(ins, weights=ti, minlength=N)
                theta_j += np.bincount(ins, weights=tj, minlength=N)
            int_rows = rep[internal]
            int_cols = loc[nbr[internal]]
            int_w = self._tau[srv_i, srv_j][rowb[internal]]  # per-block tau_ij
            if not self._unit_w:
                int_w = int_w * self._w[self._eids[flat[internal]]]
        else:
            int_rows = int_cols = np.zeros(0, dtype=np.int64)
            int_w = np.zeros(0, dtype=np.float64)

        # Singleton reduction across ALL blocks at once (tie -> sink side,
        # matching the per-pair path); only the connected cores reach flow.
        new_assign = np.where(theta_i < theta_j, rep_i, rep_j)
        has_int = np.zeros(N, dtype=bool)
        has_int[int_rows] = True
        core = np.flatnonzero(has_int)              # stays block-grouped
        if len(core):
            cloc = np.empty(N, dtype=np.int64)
            cloc[core] = np.arange(len(core))
            core_ptr = np.zeros(B + 1, dtype=np.int64)
            np.cumsum(np.bincount(mrow_blk[core], minlength=B),
                      out=core_ptr[1:])
            side = min_st_cut_csr_blocks(
                core_ptr, cloc[int_rows], cloc[int_cols], int_w,
                theta_i[core], theta_j[core], arena=self._arena,
                backend="scipy" if self._use_csr else self._backend,
                workers=self._workers, worker_mode=self._worker_mode,
                presorted=True)
            new_assign[core] = np.where(side, rep_i[core], rep_j[core])

        loc[members_all] = -1                       # reset scratch
        return [
            (members_all[lo:hi], new_assign[lo:hi]) if hi > lo else None
            for lo, hi in zip(bptr[:-1], bptr[1:])
        ]

    def try_apply(
        self, members: np.ndarray, proposed: np.ndarray, tol: float = 1e-9
    ) -> bool:
        """Delta-check a proposed re-assignment of ``members`` against the
        LIVE state and commit when improving (used by the batched sweep,
        where the cut may have been computed against a slightly stale
        snapshot: the exact live delta is what guards acceptance)."""
        changed = proposed != self.state.assign[members]
        if not changed.any():
            return False
        moved = members[changed]
        new_servers = proposed[changed]
        old_servers = self.state.assign[moved].copy()
        if self.state.propose(moved, new_servers) < -tol:
            self.state.commit_pending()
            self._mark_dirty(moved, old_servers)
            return True
        self.state.discard_pending()
        return False
