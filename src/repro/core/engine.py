"""Incremental pairwise min-cut layout engine (the fast path behind GLAD).

The seed implementation of Alg. 1 re-evaluated the full O(n+m) objective per
proposal and rebuilt every auxiliary graph with per-edge Python loops; at the
ROADMAP's production graph sizes the *optimizer* dominated end-to-end time.
This engine makes one Alg.-1 iteration cost O(|members| + vol(members)):

  * cached assignment state (:class:`repro.core.cost.LayoutState`) turns the
    accept decision into an exact delta over moved vertices + incident links;
  * auxiliary graphs are assembled with pure array ops — global->local index
    translation via preallocated scratch vectors, incident-edge discovery via
    the CSR edge-id view (no scan of the global edge list);
  * scratch buffers (member mask, local ids, theta vectors, flow arenas) are
    allocated once and reused across iterations;
  * a *batched sweep* solves a round-robin matching of disjoint server pairs
    per round.  Disjoint pairs touch disjoint member sets, so their cuts can
    be solved from one snapshot and composed; every acceptance still uses an
    exact delta against the live state, so composing never mis-accepts.

Round -> block -> scatter pipeline (the block-diagonal round solver):

  1. **round** — :meth:`PairCutEngine.sweep_round` takes one round-robin
     matching of disjoint server pairs, skips the clean ones, and
     batch-assembles the dirty ones' auxiliary graphs in a single pass of
     array ops: one vertex->block lookup classifies every vertex, one
     ragged CSR gather yields all incident links, and per-block t-link /
     n-link weights come from vectorized gathers over the concatenated
     member list (no per-pair Python work).
  2. **block** — members without intra-pair links are settled by the
     vectorized t-link argmin; the connected cores of all blocks are packed
     into ONE block-diagonal symmetric-CSR flow problem glued at a shared
     source/sink and solved by a single scipy max-flow pass whose BFS never
     crosses block boundaries (:func:`repro.core.maxflow.
     min_st_cut_csr_blocks`).  Scratch (member masks, local ids, the flow
     CSR arena) is grown once per sweep and reused across rounds.  Without
     scipy, blocks fall back to per-block pure-python Dinic solves, fanned
     out over ``workers`` threads/processes.
  3. **scatter** — each block's slice of the source-side mask maps back to
     "member stays on i / moves to j"; the proposals are then applied in
     pair order, each guarded by an exact O(moved + incident) live delta,
     so composition semantics are identical to the per-pair batched sweep.

Cross-round assembly caching (the AssemblyCache):

  Quadratic submodularity makes GLAD's auxiliary graphs *local*: pair
  (i, j)'s t-link vectors and internal arcs depend only on its member set
  and the layout of the members' neighbors.  Between two visits to the same
  pair, most of that context is unchanged — so each pair's assembled arrays
  (theta_i/theta_j, member-local CSR arc lists, connected-core
  classification, and the symmetric flow-CSR structure) are persisted in a
  per-pair :class:`AssemblyCache` entry stamped with the engine's dirty
  version.  A per-vertex epoch array (bumped for movers and their neighbors
  on every commit) tells a later solve exactly which vertices were touched
  since the entry's stamp:

    * touched set empty           -> reuse every array verbatim;
    * touched, membership intact  -> patch the touched members' theta rows
      in O(touched + their degree) — internal arcs, the singleton/core
      split and the flow-CSR *structure* are provably unchanged (an
      internal arc can only flip to boundary when an endpoint leaves the
      member set, i.e. membership changes);
    * membership changed          -> full re-assembly (stored back).

  All patched values reproduce the fresh assembly bit-for-bit (same unary
  base, same bincount accumulation order), so cached trajectories are
  identical to uncached ones.  Entries live in an LRU dict under a byte
  budget; eviction only costs the evicted pair a re-assembly.  Admission
  is frequency-gated (see :meth:`PairCutEngine._admit`): under budget
  pressure, first-touch pairs are solved but not stored, so cyclic sweeps
  whose pair universe overruns the budget keep a stable resident set
  instead of scan-thrashing.

The engine preserves the paper's auxiliary-graph semantics exactly
(Sec. IV-B: t-link = unary + side-effect traffic to third servers, n-link =
tau_ij per internal link), so Thm 4-6 continue to hold per pair.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import CostModel
from repro.core.maxflow import (_HAVE_SCIPY, PEEL_GATE_FRAC, CutArena,
                                ResidualCut, _chunk_block_spans, min_st_cut,
                                min_st_cut_csr_blocks, peel_gate_fraction,
                                peel_warm_solve)
from repro.graphs.datagraph import csr_multirange

#: Default node budget for one glued block-diagonal flow union
#: (``chunk_nodes='auto'``).  Beyond this the union's working set (the
#: assembly gathers and the flow CSR together) outgrows cache and a single
#: glued pass loses to bounded chunks (the n=50k inversion); below it,
#: splitting only adds per-call scipy overhead.
AUTO_CHUNK_NODES = 8192


class _PairAssembly:
    """One pair's persisted auxiliary-graph assembly (AssemblyCache entry).

    ``members`` (ascending global ids), t-link vectors ``theta_i/theta_j``,
    member-local internal arcs (both directions, row-grouped ascending —
    the presorted canonical order), and, built lazily on first use:
    the singleton/core classification and the symmetric flow-CSR structure
    with a capacity template (int_w filled in, theta slots zero).
    ``residual`` optionally holds the pair's warm-start flow state
    (:class:`repro.core.maxflow.ResidualCut`) — valid across theta patches
    (the flow structure is membership-determined), dropped on membership
    patches and rebuilds, and counted against the LRU byte budget.
    ``residual_key`` says WHICH problem the residual was primed on: None
    for the full core, else the persistency peel's alive mask (the state
    lives over the reduced survivor problem and is only reusable while the
    forced set repeats — the peel-composed warm start).
    ``stamp`` is the engine dirty-version the arrays are valid for.
    """

    __slots__ = ("members", "theta_i", "theta_j", "int_a", "int_b", "int_w",
                 "stamp", "has_int", "core", "core_int_a", "core_int_b",
                 "residual", "residual_key", "nbytes")

    def __init__(self, members, theta_i, theta_j, int_a, int_b, int_w,
                 stamp):
        self.members = members
        self.theta_i = theta_i
        self.theta_j = theta_j
        self.int_a = int_a
        self.int_b = int_b
        self.int_w = int_w
        self.stamp = stamp
        self.has_int = None
        self.core = None
        self.core_int_a = None
        self.core_int_b = None
        self.residual = None
        self.residual_key = None
        self.nbytes = (members.nbytes + theta_i.nbytes + theta_j.nbytes
                       + int_a.nbytes + int_b.nbytes + int_w.nbytes)


def round_robin_rounds(m: int) -> List[List[Tuple[int, int]]]:
    """Circle-method tournament schedule: m-1 rounds (m even; m rounds if
    odd) of vertex-disjoint pairs that jointly cover every pair i < j."""
    ids = list(range(m))
    if m % 2:
        ids.append(-1)                       # bye slot
    k = len(ids)
    rounds: List[List[Tuple[int, int]]] = []
    for _ in range(max(k - 1, 0)):
        rnd = []
        for a in range(k // 2):
            x, y = ids[a], ids[k - 1 - a]
            if x >= 0 and y >= 0:
                rnd.append((min(x, y), max(x, y)))
        rounds.append(rnd)
        ids = [ids[0], ids[-1]] + ids[1:-1]  # rotate all but the pivot
    return rounds


class PairCutEngine:
    """Stateful solver of restricted two-server subproblems over one layout.

    Owns a :class:`LayoutState` (read ``.state.assign`` / ``.state.total``)
    plus the preallocated scratch that keeps per-pair work at
    O(n bool-scan + pair member volume): the accept path is
    O(moved + incident links), auxiliary construction is proportional to
    the pair's member volume, and the only full-graph term left is the
    vectorized member scan in :meth:`members_of` — deliberate, it is
    memory-bandwidth noise next to one min-cut solve.
    """

    def __init__(
        self,
        cm: CostModel,
        assign: np.ndarray,
        active: Optional[np.ndarray] = None,
        backend: str = "auto",
        workers: int = 0,
        worker_mode: str = "thread",
        cache: "bool | str" = "auto",
        cache_bytes: int = 256 << 20,
        chunk_nodes: "int | str" = "auto",
        warm: "bool | str" = "auto",
    ):
        self.cm = cm
        self._workers = int(workers)
        self._worker_mode = worker_mode
        self.state = cm.layout_state(assign)
        # Epoch plumbing: EVERY commit that lands on the state — the
        # engine's own accept path or a caller committing directly through
        # the LayoutState API (fault-runtime warm restarts, benchmark
        # perturbations) — must bump the dirty stamps and vertex epochs, or
        # cached assemblies and warm-start residuals go silently stale.
        self.state.on_commit = self._mark_dirty
        g = cm.graph
        self._indptr = g.indptr
        self._indices = g.indices
        self._eids = g.edge_ids
        self._w = self.state._w                  # share LayoutState's copy
        self._unit_w = g.edge_weights is None    # skip weight gathers
        self._tau = cm.net.tau
        self._active = None if active is None else np.asarray(active, bool)
        self._backend = backend
        self._use_csr = _HAVE_SCIPY and backend in ("auto", "scipy")
        self._arena = CutArena()
        # Scratch, allocated once: member mask + global->local translation.
        self._mask = np.zeros(g.n, dtype=bool)
        self._loc = np.full(g.n, -1, dtype=np.int64)
        # Touched-vertex ledger: every committed mover (the engine's own
        # accepts AND external apply_assignment commits) is flagged here, so
        # callers get the run's move delta without an O(n) diff — the same
        # epoch machinery the caches ride feeds the plan-patch pipeline.
        self._moved_mask = np.zeros(g.n, dtype=bool)
        # Dirty-pair tracking: the auxiliary graph of (i, j) depends only on
        # its member set and the layout of members' neighbors, so a pair is
        # clean — its solve would reproduce the last (rejected) proposal
        # verbatim — until a commit touches one of its servers.  Clean
        # probes are skipped; this keeps the Alg.-1 trajectory bit-identical
        # while eliding most non-improving cut solves near convergence.
        self._version = 0
        self._server_dirty = np.zeros(cm.net.m, dtype=np.int64)
        self._pair_stamp: dict = {}
        # Cross-slot rebind epochs (see :meth:`rebind`): per-server (whole
        # unary column / tau row changed), per-tau-entry (internal arc
        # capacities — theta patches never repair int_w, so any touched
        # (i, j) forces a full rebuild of that pair), and per-vertex
        # STRUCTURAL (edge insert/delete/reweight — arcs can't be patched
        # either).  The scalar maxes gate the _refresh_entry checks so
        # engines that never rebind pay nothing on the hot path.
        self._server_epoch = np.zeros(cm.net.m, dtype=np.int64)
        self._struct_epoch = np.zeros(g.n, dtype=np.int64)
        self._tau_pair_epoch: Optional[np.ndarray] = None
        self._server_max = 0
        self._tau_max = 0
        self._struct_max = 0
        # Cross-round assembly cache: per-vertex epochs say when a vertex's
        # assembly-relevant context (its own slot, or a neighbor's) last
        # changed; per-pair entries stamped against them decide verbatim
        # reuse / O(touched) theta patch / incremental membership patch /
        # full re-assembly.  'auto' enables it for incremental workloads
        # (an ``active`` mask means a GLAD-E-style relayout whose touched
        # sets stay small between visits); cold full sweeps — including
        # the fault-runtime's warm-started but unmasked relayouts — churn
        # memberships too fast for per-pair reuse to beat the fused batch
        # assembly, so they cache only when explicitly asked.
        if cache == "auto":
            self._cache_on = active is not None
        else:
            self._cache_on = bool(cache)
        # Warm-start incremental max-flow: per-pair ResidualCut state rides
        # the cache entries (same per-vertex epoch keying), so warm solving
        # requires the cache.  'auto' follows the cache policy; warm=True
        # promotes cache='auto' to ON, but an explicit cache=False is a
        # contradiction worth surfacing.  Masks are bit-identical warm or
        # cold (the minimal source side is unique per integer problem), so
        # the knob only picks a schedule, never a trajectory.
        if warm == "auto":
            self._warm_on = self._cache_on
        else:
            self._warm_on = bool(warm)
            if self._warm_on and not self._cache_on:
                if cache == "auto":
                    self._cache_on = True
                else:
                    raise ValueError(
                        "warm=True requires the assembly cache "
                        "(warm state is stored on cache entries); "
                        "drop cache=False or pass warm=False")
        self._warm_on = self._warm_on and self._use_csr
        self._cache_bytes = int(cache_bytes)
        self._cache: "OrderedDict[Tuple[int, int], _PairAssembly]" = \
            OrderedDict()
        self._cache_used = 0
        # Pair-frequency admission (TinyLFU-lite): per-pair touch counts
        # back the under-pressure admission decision in _admit.
        self._touches: Dict[Tuple[int, int], int] = {}
        self._vertex_epoch = np.zeros(g.n, dtype=np.int64)
        self.cache_hits = 0          # verbatim reuse (nothing touched)
        self.cache_patched = 0       # O(touched) theta patch
        self.cache_misses = 0        # full (re-)assembly
        self.cache_evictions = 0
        self.cache_rejected = 0      # assemblies not admitted under pressure
        self.warm_hits = 0           # integer caps unchanged: mask-only BFS
        self.warm_repairs = 0        # drain + delta augment
        self.warm_cold = 0           # primed / gated back to a cold solve
        if chunk_nodes == "auto":
            chunk_nodes = AUTO_CHUNK_NODES
        self._chunk_nodes = int(chunk_nodes or 0)
        # Movable-member universe: what one full matching round can touch.
        # Drives the 'auto' round-solver policy (see :meth:`sweep_round`).
        self._universe = (int(self._active.sum())
                          if self._active is not None else g.n)

    def cache_stats(self) -> Dict[str, int]:
        return {
            "hits": self.cache_hits, "patched": self.cache_patched,
            "misses": self.cache_misses, "evictions": self.cache_evictions,
            "rejected": self.cache_rejected,
            "entries": len(self._cache), "bytes": self._cache_used,
            "warm_hits": self.warm_hits, "warm_repairs": self.warm_repairs,
            "warm_cold": self.warm_cold,
        }

    def touched_vertices(self) -> np.ndarray:
        """Vertices committed as movers at least once on this engine (a
        superset of the net movers — a vertex may move and move back)."""
        return np.flatnonzero(self._moved_mask)

    def pair_clean(self, i: int, j: int) -> bool:
        """True iff (i, j)'s auxiliary graph is unchanged since its last
        solve AND that solve did not end in an accept (an accepted solve
        dirties both servers, so clean implies last-result == reject)."""
        stamp = self._pair_stamp.get((i, j), -1)
        return stamp >= max(self._server_dirty[i], self._server_dirty[j])

    def _mark_dirty(self, moved: np.ndarray, old_servers: np.ndarray) -> None:
        """After committing ``moved``, dirty every server whose pairs could
        see a different auxiliary graph: the movers' old and new servers
        (membership changes) plus every server hosting a neighbor of a
        mover (their boundary side-effect terms reference the movers'
        layout)."""
        assign = self.state.assign
        servers = [old_servers, assign[moved]]
        flat, _ = csr_multirange(self._indptr, moved)
        if len(flat):
            servers.append(assign[self._indices[flat]])
        dirty = np.unique(np.concatenate(servers))
        self._version += 1
        self._server_dirty[dirty] = self._version
        self._moved_mask[moved] = True
        # Vertex epochs feed the AssemblyCache: a mover's own slot changed,
        # and every neighbor's boundary/t-link context references it.
        self._vertex_epoch[moved] = self._version
        if len(flat):
            self._vertex_epoch[self._indices[flat]] = self._version

    # ------------------------------------------------------------- internals
    def members_of(self, i: int, j: int) -> np.ndarray:
        assign = self.state.assign
        pair_mask = (assign == i) | (assign == j)
        if self._active is not None:
            pair_mask &= self._active
        return np.flatnonzero(pair_mask)

    # ------------------------------------------------------- assembly cache
    def _cache_entry(self, i: int, j: int) -> Optional[_PairAssembly]:
        """The pair's up-to-date assembly: verbatim reuse, O(touched)
        theta/membership patch, or full re-assembly — stored back under the
        LRU byte budget.  Returns None when the pair hosts no active
        vertices."""
        key = (i, j)
        touches = self._touches.get(key, 0) + 1
        self._touches[key] = touches
        resident = False
        e = self._cache.get(key)
        if e is not None:
            if self._refresh_entry(i, j, e):
                self._cache.move_to_end(key)
                return e
            self._cache_used -= self._entry_bytes(e)
            del self._cache[key]
            resident = True                # rebuild of a proven-hot entry
        e = self._assemble_full(i, j)
        self.cache_misses += 1
        if e is not None:
            self._ensure_core(e)           # eager: every entry gets solved
            if resident or self._admit(e.nbytes, touches):
                self._cache[key] = e
                self._cache_used += e.nbytes   # base + core bytes, while
                self._evict_over_budget()      # still resident
            else:
                # Not admitted: the assembly is still used for this solve,
                # just not stored (and never primes warm state — the
                # refreshed/allow_prime plumbing treats it as fresh).
                self.cache_rejected += 1
        return e

    def _admit(self, nbytes: int, touches: int) -> bool:
        """Pair-frequency admission (TinyLFU-lite): under budget pressure a
        fresh assembly is admitted only when the pair has been touched
        before AND more often than the LRU victim it would displace.

        Plain LRU scan-thrashes on cyclic sweeps whose pair universe
        overruns the byte budget (the n=50k flat path): every visit evicts
        the entry that is next to be reused, so the cache degrades into
        pure overhead.  Frequency admission freezes a resident set instead
        — a uniform scan stops evicting entirely, while genuinely hot
        pairs (skewed revisit patterns, GLAD-E masks) out-touch stale
        victims and still displace them.

        The required lead is TWO touches, not one: a cyclic scan touches
        the candidate before it touches the not-yet-visited LRU resident,
        so mid-scan the candidate always leads by exactly one — a margin
        of one would re-admit once per pass (thrash with extra steps).  A
        genuinely hotter pair's lead grows without bound and clears the
        margin immediately.  Admission changes WHICH pairs are cached,
        never any cached value, so trajectories remain bit-identical with
        the policy on or off."""
        if not self._cache or self._cache_used + nbytes <= self._cache_bytes:
            return True
        if touches < 2:
            return False
        victim = next(iter(self._cache))
        return touches > self._touches.get(victim, 0) + 1

    def _evict_over_budget(self) -> None:
        """LRU eviction down to the byte budget (never below one entry).
        Run after ANY ledger growth — fresh assemblies and warm-state
        primes alike; a converged re-probe sweep primes residuals on
        verbatim hits without ever taking the assembly-miss path, and
        those bytes must not silently overrun the budget."""
        while (self._cache_used > self._cache_bytes
               and len(self._cache) > 1):
            _, old = self._cache.popitem(last=False)
            self._cache_used -= self._entry_bytes(old)
            self.cache_evictions += 1

    @staticmethod
    def _entry_bytes(e: _PairAssembly) -> int:
        return e.nbytes

    def _gather_theta_rows(self, tm: np.ndarray, i: int, j: int):
        """Fresh t-link rows for members ``tm`` (member mask set in
        ``self._mask``): same unary base + one bincount in CSR row order as
        the full assembly, so the values are bit-identical to a fresh
        gather.  Also returns the gather arrays for arc extraction."""
        assign = self.state.assign
        k = len(tm)
        th_i = self.cm.unary[tm, i]
        th_j = self.cm.unary[tm, j]
        flat, rep = csr_multirange(self._indptr, tm)
        nbr_in = None
        nbr = None
        if len(flat):
            nbr = self._indices[flat]
            nbr_in = self._mask[nbr]
            bnd = ~nbr_in
            if bnd.any():
                ins = rep[bnd]
                outs = assign[nbr[bnd]]
                ti = self._tau[i, outs]
                tj = self._tau[j, outs]
                if not self._unit_w:
                    bw = self._w[self._eids[flat[bnd]]]
                    ti = ti * bw
                    tj = tj * bw
                th_i += np.bincount(ins, weights=ti, minlength=k)
                th_j += np.bincount(ins, weights=tj, minlength=k)
        return th_i, th_j, flat, rep, nbr, nbr_in

    def _refresh_entry(self, i: int, j: int, e: _PairAssembly) -> bool:
        """Bring a cached assembly up to the current version in place.

        Verbatim reuse when nothing relevant was touched; an O(touched)
        theta patch when the member set is intact; an incremental
        membership patch (retained rows copied, touched/arrived rows
        re-gathered, arc list merged) when few members changed.  All
        patched arrays are bit-identical to a fresh assembly.  Returns
        False when the entry should be rebuilt from scratch instead."""
        members = self.members_of(i, j)
        k = len(members)
        if k == 0:
            return False
        # Cross-slot rebind invalidations (scalar-gated: all the maxes
        # stay 0 on engines that never rebind).  A changed tau[i,j] /
        # tau[j,i] rescales every internal arc — beyond what any patch
        # can repair, so rebuild from scratch.
        if (self._tau_max > e.stamp
                and (self._tau_pair_epoch[i, j] > e.stamp
                     or self._tau_pair_epoch[j, i] > e.stamp)):
            return False
        # A server epoch on i or j (dense unary column repricing, or a
        # dense tau row whose (i,j)/(j,i) entries happen to be intact —
        # the gate above already caught the rest) moves whole theta
        # columns without bumping per-vertex epochs.
        col_stale = (self._server_max > e.stamp
                     and (self._server_epoch[i] > e.stamp
                          or self._server_epoch[j] > e.stamp))
        # Structural edge deltas bump BOTH endpoints' vertex epochs (see
        # rebind), so the membership patch re-derives every arc touching
        # them — only the theta-only fast path (which never rewrites arc
        # lists) must be disqualified for entries that saw struct churn.
        arc_stale = (self._struct_max > e.stamp
                     and bool((self._struct_epoch[members] > e.stamp).any()))
        tmask = self._vertex_epoch[members] > e.stamp
        same = (k == len(e.members)
                and bool(np.array_equal(members, e.members)))
        if same and not tmask.any() and not col_stale:
            self.cache_hits += 1            # struct-touched members always
            e.stamp = self._version         # carry a vertex-epoch bump, so
            return True                     # a pure hit implies !arc_stale
        if col_stale:
            # Theta COLUMNS changed (fault-loop degrade/revive repricing):
            # the internal arcs only read tau[i,j]*w, which the tau gate
            # above proved intact, so re-gathering EVERY member's theta
            # rows restores the entry exactly — and, unlike a rebuild,
            # keeps the arc lists, core classification and warm residual
            # (the warm solve re-quantizes against current capacities, so
            # a retained flow is repaired, not trusted).
            if not same or arc_stale:
                return False
            mask = self._mask
            mask[members] = True
            th_i, th_j, _, _, _, _ = self._gather_theta_rows(members, i, j)
            e.theta_i[:] = th_i
            e.theta_j[:] = th_j
            mask[members] = False
            self.cache_patched += 1
            e.stamp = self._version
            return True
        tm = members[tmask]
        if 4 * len(tm) > k:
            return False                    # patch would not beat re-gather
        mask, loc = self._mask, self._loc
        mask[members] = True
        if same and not arc_stale:
            # Membership intact => internal arcs and the singleton/core
            # split are unchanged (an internal arc only flips to boundary
            # when an endpoint leaves the member set); only the touched
            # members' t-link rows can differ.
            th_i, th_j, _, _, _, _ = self._gather_theta_rows(tm, i, j)
            rows = np.flatnonzero(tmask)
            e.theta_i[rows] = th_i
            e.theta_j[rows] = th_j
            mask[members] = False
            self.cache_patched += 1
            e.stamp = self._version
            return True
        # Membership changed (arrivals/departures are movers, so they are
        # all in the touched set).  Untouched members kept their exact
        # theta values and their arcs among themselves; everything
        # involving a touched member is re-derived from a gather of the
        # touched rows only.
        untouched = ~tmask
        pos_in_old = np.searchsorted(e.members, members[untouched])
        if (pos_in_old >= len(e.members)).any() or not bool(
                np.array_equal(e.members[pos_in_old], members[untouched])):
            # An untouched vertex missing from the old member set would
            # contradict the epoch invariant — rebuild defensively.
            mask[members] = False          # pragma: no cover
            return False                   # pragma: no cover
        loc[members] = np.arange(k)
        theta_i = np.empty(k, dtype=np.float64)
        theta_j = np.empty(k, dtype=np.float64)
        theta_i[untouched] = e.theta_i[pos_in_old]
        theta_j[untouched] = e.theta_j[pos_in_old]
        th_i, th_j, flat, rep, nbr, nbr_in = \
            self._gather_theta_rows(tm, i, j)
        trows = np.flatnonzero(tmask)
        theta_i[trows] = th_i
        theta_j[trows] = th_j
        # Old arcs between two untouched survivors carry over (remapped);
        # arcs touching a mover/arrival come from the touched-row gather —
        # the copy with an untouched tail is the gathered copy swapped.
        old_to_new = np.full(len(e.members), -1, dtype=np.int64)
        old_to_new[pos_in_old] = np.flatnonzero(untouched)
        oa = old_to_new[e.int_a]
        ob = old_to_new[e.int_b]
        keep = (oa >= 0) & (ob >= 0)
        if nbr is not None and nbr_in is not None and nbr_in.any():
            ta = trows[rep[nbr_in]]
            tb = loc[nbr[nbr_in]]
            tij = float(self._tau[i, j])
            if self._unit_w:
                tw = np.full(len(ta), tij, dtype=np.float64)
            else:
                tw = tij * self._w[self._eids[flat[nbr_in]]]
            swap = untouched[tb]
            ia = np.concatenate([oa[keep], ta, tb[swap]])
            ib = np.concatenate([ob[keep], tb, ta[swap]])
            iw = np.concatenate([e.int_w[keep], tw, tw[swap]])
        else:
            ia = oa[keep]
            ib = ob[keep]
            iw = e.int_w[keep]
        order = np.lexsort((ib, ia))       # canonical (row, col) order
        self._cache_used -= e.nbytes
        e.members = members
        e.theta_i = theta_i
        e.theta_j = theta_j
        e.int_a = ia[order].astype(np.int32)
        e.int_b = ib[order].astype(np.int32)
        e.int_w = iw[order]
        e.has_int = None                   # core classification changed
        e.core = e.core_int_a = e.core_int_b = None
        e.residual = None                  # warm flow keyed to old structure
        e.residual_key = None
        e.nbytes = (members.nbytes + theta_i.nbytes + theta_j.nbytes
                    + e.int_a.nbytes + e.int_b.nbytes + e.int_w.nbytes)
        self._cache_used += e.nbytes
        mask[members] = False
        loc[members] = -1
        self.cache_patched += 1
        e.stamp = self._version
        # Rebuild the core classification NOW, while the entry is still
        # resident, and charge the budget for it here — a later
        # _ensure_core on an entry evicted in the meantime must not touch
        # the accounting (_ensure_core itself never does).
        before = e.nbytes
        self._ensure_core(e)
        self._cache_used += e.nbytes - before
        return True

    def _assemble_full(self, i: int, j: int) -> Optional[_PairAssembly]:
        """Fresh pair assembly into owned arrays (the cache-entry twin of
        :meth:`solve_pair`'s scratch assembly — identical values)."""
        members = self.members_of(i, j)
        k = len(members)
        if k == 0:
            return None
        cm, assign = self.cm, self.state.assign
        mask, loc = self._mask, self._loc
        mask[members] = True
        loc[members] = np.arange(k)
        theta_i = cm.unary[members, i]
        theta_j = cm.unary[members, j]
        flat, row = csr_multirange(self._indptr, members)
        if len(flat):
            nbr = self._indices[flat]
            nbr_in = mask[nbr]
            bnd = ~nbr_in
            if bnd.any():
                ins = row[bnd]
                outs = assign[nbr[bnd]]
                ti = self._tau[i, outs]
                tj = self._tau[j, outs]
                if not self._unit_w:
                    bw = self._w[self._eids[flat[bnd]]]
                    ti = ti * bw
                    tj = tj * bw
                theta_i += np.bincount(ins, weights=ti, minlength=k)
                theta_j += np.bincount(ins, weights=tj, minlength=k)
            internal = nbr_in
            int_a = row[internal].astype(np.int32)
            int_b = loc[nbr[internal]].astype(np.int32)
            tij = float(self._tau[i, j])
            if self._unit_w:
                int_w = np.full(len(int_a), tij, dtype=np.float64)
            else:
                int_w = tij * self._w[self._eids[flat[internal]]]
        else:
            int_a = int_b = np.zeros(0, dtype=np.int32)
            int_w = np.zeros(0, dtype=np.float64)
        mask[members] = False
        loc[members] = -1
        return _PairAssembly(members, theta_i, theta_j, int_a, int_b, int_w,
                             self._version)

    def _ensure_core(self, e: _PairAssembly) -> None:
        """Singleton/core classification + core-local arcs (valid across
        theta patches; a membership patch resets them)."""
        if e.has_int is not None:
            return
        k = len(e.members)
        has_int = np.zeros(k, dtype=bool)
        has_int[e.int_a] = True
        core = np.flatnonzero(has_int).astype(np.int32)
        cloc = np.empty(k, dtype=np.int32)
        cloc[core] = np.arange(len(core), dtype=np.int32)
        e.has_int = has_int
        e.core = core
        e.core_int_a = cloc[e.int_a]
        e.core_int_b = cloc[e.int_b]
        e.nbytes += (has_int.nbytes + core.nbytes + e.core_int_a.nbytes
                     + e.core_int_b.nbytes)

    def _solve_entry(self, e: _PairAssembly, i: int, j: int,
                     allow_prime: bool = True) -> np.ndarray:
        """Cut the cached pair: singleton argmin + core flow solve over the
        cached core classification (peeled/assembled per solve — theta may
        have been patched since).  With warm starts on, the core solve
        repairs the entry's retained residual instead of pushing the flow
        from zero (:meth:`_solve_core_warm`) — bit-identical masks.
        ``allow_prime=False`` withholds the warm-state investment for
        freshly (re-)assembled entries: under membership churn or LRU
        scan-thrash the state would be invalidated/evicted before reuse,
        so priming is pure overhead (existing state is still repaired)."""
        k = len(e.members)
        self._ensure_core(e)
        new_assign = np.empty(k, dtype=np.int64)
        sing = ~e.has_int
        new_assign[sing] = np.where(
            e.theta_i[sing] < e.theta_j[sing], i, j)
        kc = len(e.core)
        if kc:
            if self._warm_on:
                side = self._solve_core_warm(e, kc, (i, j), allow_prime)
            else:
                side = self._solve_flow(
                    kc, e.core_int_a, e.core_int_b, e.int_w,
                    e.theta_i[e.core], e.theta_j[e.core])
            new_assign[e.core] = np.where(side[:kc], i, j)
        return new_assign

    def _drop_residual(self, e: _PairAssembly, key) -> None:
        """Detach an entry's warm state; the byte budget is only touched
        while the entry is still RESIDENT (a batched round can solve an
        entry that a later pair's assembly already evicted — its bytes left
        the ledger at eviction time)."""
        if e.residual is not None:
            nb = e.residual.nbytes
            e.residual = None
            e.residual_key = None
            e.nbytes -= nb
            if self._cache.get(key) is e:
                self._cache_used -= nb

    def _solve_core_warm(self, e: _PairAssembly, kc: int,
                         key: Tuple[int, int],
                         allow_prime: bool = True) -> np.ndarray:
        """Warm-start route for one cached core's flow solve.

        Composition with the persistency peel: the shared adaptive gate
        (:func:`peel_gate_fraction`) decides peel-vs-direct exactly as the
        cold block solver would.  When the gate says PEEL, the solve runs
        through :func:`repro.core.maxflow.peel_warm_solve`: the peel
        reduces the problem exactly as the cold path would, and the
        SURVIVOR flow is primed/repaired from a residual keyed by the
        forced set — the converged-but-peel-gated regime (stable forced
        sets, tiny theta perturbations) warms instead of re-pushing.  When
        the gate says direct (~90% survivors), the entry's full-core
        ResidualCut is primed / repaired as before.  A regime flip drops
        the other regime's state (the structures are incompatible).
        Either way the mask is bit-identical to the cold path's."""
        th_i = e.theta_i[e.core]
        th_j = e.theta_j[e.core]
        frac = peel_gate_fraction(kc, e.core_int_a, e.int_w, th_i, th_j)
        if frac >= PEEL_GATE_FRAC:
            if e.residual is not None and e.residual_key is None:
                self._drop_residual(e, key)    # full-core state: wrong shape
            old_rc = e.residual
            side, rc, rkey, mode = peel_warm_solve(
                kc, e.core_int_a, e.core_int_b, e.int_w, th_i, th_j,
                residual=e.residual, residual_key=e.residual_key,
                allow_prime=allow_prime or e.residual is not None)
            if mode == "hit":
                self.warm_hits += 1
            elif mode == "warm":
                self.warm_repairs += 1
            else:
                self.warm_cold += 1
            if rc is not old_rc:
                if old_rc is not None:
                    self._drop_residual(e, key)
                if rc is not None:
                    e.residual = rc
                    e.residual_key = rkey
                    e.nbytes += rc.nbytes
                    if self._cache.get(key) is e:
                        self._cache_used += rc.nbytes
                        self._evict_over_budget()
            return side
        if e.residual is not None and e.residual_key is not None:
            self._drop_residual(e, key)        # peel-keyed state: wrong shape
        rc = e.residual
        if rc is not None and rc.k == kc:
            side, mode = rc.resolve(e.core_int_a, e.core_int_b, e.int_w,
                                    th_i, th_j)
            if mode == "hit":
                self.warm_hits += 1
            elif mode == "warm":
                self.warm_repairs += 1
            else:
                self.warm_cold += 1
            return side
        if not allow_prime:
            self.warm_cold += 1
            return self._solve_flow(kc, e.core_int_a, e.core_int_b,
                                    e.int_w, th_i, th_j, peel_frac=frac)
        side, rc = ResidualCut.prime(kc, e.core_int_a, e.core_int_b,
                                     e.int_w, th_i, th_j)
        self.warm_cold += 1
        if rc is not None:
            e.residual = rc
            e.nbytes += rc.nbytes
            if self._cache.get(key) is e:
                self._cache_used += rc.nbytes
                self._evict_over_budget()
        return side

    # ----------------------------------------------------------- pair solve
    def solve_pair(
        self, i: int, j: int
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Min s-t cut of the auxiliary graph A(i, j) over the current
        layout.  Returns (members, proposed_servers_for_members) or None if
        the pair hosts no active vertices.  Does NOT mutate the state.

        Cached and uncached modes share one assembly (:meth:`_assemble_full`
        — boundary side-effect terms folded into BOTH t-link columns per
        Sec. IV-B, internal links as both directed arcs) and one solve tail
        (:meth:`_solve_entry` — vectorized t-link argmin for singleton
        members, tie -> sink side to match the max-flow residual
        convention; only the connected core reaches the flow solver); the
        cache merely decides whether the assembly is reused/patched or
        built fresh and discarded."""
        if self._cache_on:
            before = self.cache_misses
            e = self._cache_entry(i, j)
            refreshed = e is not None and self.cache_misses == before
        else:
            e = self._assemble_full(i, j)
            refreshed = False
        if e is None:
            return None
        return e.members, self._solve_entry(e, i, j,
                                            allow_prime=refreshed)

    def _solve_flow(self, k, int_a, int_b, int_w, theta_i, theta_j,
                    peel_frac=None):
        """Min cut of the (connected-core) auxiliary flow network: nodes
        0..k-1 plus S=k, T=k+1; t-link caps theta_j (s->v) / theta_i (v->t);
        internal arcs already both directions in (int_a, int_b)."""
        S, T = k, k + 1
        n_int = len(int_w)
        if self._use_csr:
            # Single-block route through the block solver: integer
            # persistency peel first (most of the core is settled without a
            # flow solve), then direct symmetric-CSR assembly of the
            # survivors — bit-identical masks to the unpeeled solve.  The
            # member gather already yields arcs in canonical (row, col)
            # order (DataGraph rows are dst-sorted, member-local ids
            # rank-monotone), so no lexsort is paid.
            return min_st_cut_csr_blocks(
                np.array([0, k], dtype=np.int64), int_a, int_b, int_w,
                theta_i, theta_j, arena=self._arena, backend="scipy",
                presorted=True, chunk_nodes=0, peel_frac=peel_frac)
        us = np.empty(2 * k + n_int, dtype=np.int64)
        vs = np.empty(2 * k + n_int, dtype=np.int64)
        caps_uv = np.empty(2 * k + n_int, dtype=np.float64)
        caps_vu = np.zeros(2 * k + n_int, dtype=np.float64)
        us[:k] = S
        vs[:k] = np.arange(k)
        caps_uv[:k] = theta_j
        us[k:2 * k] = np.arange(k)
        vs[k:2 * k] = T
        caps_uv[k:2 * k] = theta_i
        # Internal arcs appear twice in (int_a, int_b) (both directions);
        # emit them as one-way capacities.
        us[2 * k:] = int_a
        vs[2 * k:] = int_b
        caps_uv[2 * k:] = int_w
        _, side = min_st_cut(
            k + 2, S, T, us, vs, caps_uv, caps_vu,
            backend=self._backend, arena=self._arena,
        )
        return side

    # ----------------------------------------------------------- accept path
    def try_pair(self, i: int, j: int, tol: float = 1e-9) -> Tuple[bool, bool]:
        """Solve pair (i, j) and commit iff the exact delta improves.

        Returns (solved, accepted).  Clean pairs (see :meth:`pair_clean`)
        skip the solve entirely — the result is known to be a reject.  The
        accept decision costs O(|moved| + incident links) via the cached
        LayoutState — no full objective evaluation."""
        if self.pair_clean(i, j):
            return True, False
        sol = self.solve_pair(i, j)
        if sol is None:
            self._pair_stamp[(i, j)] = self._version
            return False, False
        members, proposed = sol
        accepted = self.try_apply(members, proposed, tol=tol)
        # Stamp AFTER a possible commit: re-solving the just-accepted pair
        # reproduces the committed layout verbatim (same auxiliary graph,
        # deterministic cut), i.e. a reject — so the pair starts clean.
        self._pair_stamp[(i, j)] = self._version
        return True, accepted

    def sweep_round(
        self,
        pairs: Sequence[Tuple[int, int]],
        tol: float = 1e-9,
        solver: str = "auto",
    ) -> List[Tuple[bool, bool]]:
        """One batched round: solve a matching of disjoint server pairs from
        the current snapshot, then apply each cut with an exact live delta.

        The member sets are disjoint, so the solves are independent;
        composition is guarded per pair by the delta against the state as
        commits land.  Returns (solved, accepted) per pair, in order.

        ``solver``:
          * ``'auto'`` — ``'block'`` while the round's member universe fits
            the glued-union budget, ``'pairwise'`` beyond it (at ~50k
            members the fused batch assembly itself outgrows cache and
            per-pair composition measures faster — the two produce
            identical proposals, so this only picks the faster schedule).
          * ``'block'`` — batch-assemble every dirty pair's auxiliary
            graph and solve them as block-diagonal flow unions, glued in
            groups bounded by ``chunk_nodes`` (one scipy pass per group;
            per-block Dinic with optional ``workers`` fan-out without
            scipy).
          * ``'pairwise'`` — PR-1 behavior: one cut solve per dirty pair.
        """
        if solver == "auto":
            big = (self._chunk_nodes
                   and self._universe > 4 * self._chunk_nodes)
            solver = "pairwise" if big else "block"
        # Solve phase — nothing mutates the state, so every solve sees the
        # same snapshot and the same dirty-version.
        snapshot_version = self._version
        if solver == "pairwise":
            sols = [
                "clean" if self.pair_clean(i, j) else self.solve_pair(i, j)
                for i, j in pairs
            ]
        elif solver == "block":
            sols: List = []
            dirty_slots, dirty_pairs = [], []
            for slot, (i, j) in enumerate(pairs):
                if self.pair_clean(i, j):
                    sols.append("clean")
                else:
                    sols.append(None)
                    dirty_slots.append(slot)
                    dirty_pairs.append((i, j))
            servers = [s for p in dirty_pairs for s in p]
            if len(servers) != len(set(servers)):
                # Blocks are only well-defined for a MATCHING; a shared
                # server would silently misclassify its members, so solve
                # overlapping rounds per pair instead.
                for slot, (i, j) in zip(dirty_slots, dirty_pairs):
                    sols[slot] = self.solve_pair(i, j)
            elif dirty_pairs:
                for slot, sol in zip(dirty_slots,
                                     self._solve_round_blocks(dirty_pairs)):
                    sols[slot] = sol
        else:
            raise ValueError(f"unknown round solver {solver!r}")

        # Apply phase — identical for every solver: pair order, exact live
        # delta per acceptance, PR-1 dirty-stamp semantics.
        out = []
        for (i, j), sol in zip(pairs, sols):
            if isinstance(sol, str):                 # clean: known reject
                out.append((True, False))
                continue
            if sol is None:
                self._pair_stamp[(i, j)] = snapshot_version
                out.append((False, False))
                continue
            dirt_before = max(self._server_dirty[i], self._server_dirty[j])
            accepted = self.try_apply(*sol, tol=tol)
            # "Clean implies re-solve == reject" only holds for an accepted
            # pair if nothing ELSE dirtied it between its snapshot solve and
            # this commit — then its layout equals its own deterministic cut
            # and the post-commit stamp is valid.  If another pair's commit
            # in this round touched its servers (dirt_before > solve
            # version), or it was rejected, keep the solve-time stamp so the
            # pair is re-solved against the fresh state.
            if accepted and dirt_before <= snapshot_version:
                self._pair_stamp[(i, j)] = self._version
            else:
                self._pair_stamp[(i, j)] = snapshot_version
            out.append((True, accepted))
        return out

    # ---------------------------------------------------- block round solve
    def _solve_round_blocks(
        self, dirty: Sequence[Tuple[int, int]]
    ) -> List[Optional[Tuple[np.ndarray, np.ndarray]]]:
        """Batch-assemble the auxiliary graphs of the round's dirty pairs
        and solve them as one block-diagonal flow problem.

        Returns, per dirty pair (in order), ``None`` (no members) or
        ``(members, proposed_servers)`` exactly as :meth:`solve_pair` —
        does NOT mutate the state.

        Vertex-disjoint server pairs => disjoint member sets, so one
        vertex->block classification covers the whole round and a single
        ragged CSR gather yields every block's incident links at once.
        With the assembly cache on, the blocks are instead drawn from the
        per-pair cache (verbatim / patched / re-assembled as needed) and
        only the glued union is rebuilt per round.

        Large rounds are split into consecutive pair groups whose combined
        member estimate stays under ``chunk_nodes``: the batch assembly's
        gathers and the glued flow CSR then stay cache-resident (one
        50k-member union loses to bounded groups on every path — the
        assembly, not just the solve, is what outgrows cache), while the
        grouping itself cannot change any cut (per-block quantization is
        composition-invariant)."""
        if self._cache_on:
            return self._solve_round_blocks_cached(dirty)
        if self._chunk_nodes and len(dirty) > 1:
            sizes = np.bincount(self.state.assign, minlength=self.cm.net.m)
            groups: List[List[Tuple[int, int]]] = []
            cur: List[Tuple[int, int]] = []
            acc = 0
            for p in dirty:
                est = int(sizes[p[0]] + sizes[p[1]])
                if cur and acc + est > self._chunk_nodes:
                    groups.append(cur)
                    cur, acc = [], 0
                cur.append(p)
                acc += est
            groups.append(cur)
            if len(groups) > 1:
                out: List = []
                for grp in groups:
                    out.extend(self._solve_round_blocks_fused(grp))
                return out
        return self._solve_round_blocks_fused(dirty)

    def _solve_round_blocks_fused(
        self, dirty: Sequence[Tuple[int, int]]
    ) -> List[Optional[Tuple[np.ndarray, np.ndarray]]]:
        """One fused batch assembly + glued solve over ``dirty`` (see
        :meth:`_solve_round_blocks`)."""
        cm, assign = self.cm, self.state.assign
        B = len(dirty)
        srv_i = np.fromiter((p[0] for p in dirty), np.int64, count=B)
        srv_j = np.fromiter((p[1] for p in dirty), np.int64, count=B)
        lookup = np.full(cm.net.m, -1, dtype=np.int64)
        lookup[srv_i] = np.arange(B)
        lookup[srv_j] = np.arange(B)
        vblk = lookup[assign]                       # vertex -> block (or -1)
        if self._active is not None:
            vblk = np.where(self._active, vblk, -1)
        sel = np.flatnonzero(vblk >= 0)
        if len(sel) == 0:
            return [None] * B
        vb = vblk[sel]
        order = np.argsort(vb, kind="stable")       # block-grouped, ascending
        members_all = sel[order]                    # within each block
        sizes = np.bincount(vb, minlength=B)
        bptr = np.zeros(B + 1, dtype=np.int64)
        np.cumsum(sizes, out=bptr[1:])
        N = len(members_all)

        rep_i = np.repeat(srv_i, sizes)             # per-member block servers
        rep_j = np.repeat(srv_j, sizes)
        mrow_blk = np.repeat(np.arange(B), sizes)
        theta_i = cm.unary[members_all, rep_i].astype(np.float64)
        theta_j = cm.unary[members_all, rep_j].astype(np.float64)
        loc = self._loc                             # global -> member row
        loc[members_all] = np.arange(N)

        flat, rep = csr_multirange(self._indptr, members_all)
        if len(flat):
            nbr = self._indices[flat]
            rowb = mrow_blk[rep]
            # A neighbor is internal iff it is a member of the SAME block;
            # members of other blocks are frozen third-server vertices for
            # this pair (their commits land only in the apply phase).
            internal = vblk[nbr] == rowb
            bnd = ~internal
            if bnd.any():
                ins = rep[bnd]
                outs = assign[nbr[bnd]]
                bi = rowb[bnd]
                ti = self._tau[srv_i[bi], outs]
                tj = self._tau[srv_j[bi], outs]
                if not self._unit_w:
                    bw = self._w[self._eids[flat[bnd]]]
                    ti = ti * bw
                    tj = tj * bw
                theta_i += np.bincount(ins, weights=ti, minlength=N)
                theta_j += np.bincount(ins, weights=tj, minlength=N)
            int_rows = rep[internal]
            int_cols = loc[nbr[internal]]
            int_w = self._tau[srv_i, srv_j][rowb[internal]]  # per-block tau_ij
            if not self._unit_w:
                int_w = int_w * self._w[self._eids[flat[internal]]]
        else:
            int_rows = int_cols = np.zeros(0, dtype=np.int64)
            int_w = np.zeros(0, dtype=np.float64)

        # Singleton reduction across ALL blocks at once (tie -> sink side,
        # matching the per-pair path); only the connected cores reach flow.
        new_assign = np.where(theta_i < theta_j, rep_i, rep_j)
        has_int = np.zeros(N, dtype=bool)
        has_int[int_rows] = True
        core = np.flatnonzero(has_int)              # stays block-grouped
        if len(core):
            cloc = np.empty(N, dtype=np.int64)
            cloc[core] = np.arange(len(core))
            core_ptr = np.zeros(B + 1, dtype=np.int64)
            np.cumsum(np.bincount(mrow_blk[core], minlength=B),
                      out=core_ptr[1:])
            side = min_st_cut_csr_blocks(
                core_ptr, cloc[int_rows], cloc[int_cols], int_w,
                theta_i[core], theta_j[core], arena=self._arena,
                backend="scipy" if self._use_csr else self._backend,
                workers=self._workers, worker_mode=self._worker_mode,
                presorted=True, chunk_nodes=self._chunk_nodes)
            new_assign[core] = np.where(side, rep_i[core], rep_j[core])

        loc[members_all] = -1                       # reset scratch
        return [
            (members_all[lo:hi], new_assign[lo:hi]) if hi > lo else None
            for lo, hi in zip(bptr[:-1], bptr[1:])
        ]

    def _solve_round_blocks_cached(
        self, dirty: Sequence[Tuple[int, int]]
    ) -> List[Optional[Tuple[np.ndarray, np.ndarray]]]:
        """Block round solve over cached per-pair assemblies: each dirty
        pair's block comes from the AssemblyCache (verbatim, patched, or
        re-assembled), their connected cores are glued into one
        block-diagonal flow union (chunked to ``chunk_nodes``), and the
        per-block mask slices scatter back — value-identical to the fused
        batch assembly (same theta, arcs, quantization).

        With warm starts on, REFRESHED entries (verbatim hits and
        theta/membership patches — their member set survived since the last
        visit) are solved per pair so each can repair its retained
        :class:`ResidualCut` instead of re-pushing its flow inside a glued
        union; freshly (re-)assembled entries stay on the glued cold path —
        a fresh assembly means membership churn, which would invalidate
        warm state before it is ever reused, so priming there is pure
        overhead.  Masks are identical either way (the block solver's
        per-block normalization reproduces the per-pair quantization
        exactly, and warm masks are bit-identical to cold)."""
        B = len(dirty)
        entries: List[Optional[_PairAssembly]] = []
        refreshed: List[bool] = []
        for i, j in dirty:
            before = self.cache_misses
            e = self._cache_entry(int(i), int(j))
            entries.append(e)
            refreshed.append(e is not None and self.cache_misses == before)
        warm_assign: Dict[int, np.ndarray] = {}
        core_sizes = np.zeros(B, dtype=np.int64)
        for b, e in enumerate(entries):
            if e is None:
                continue
            if self._warm_on and refreshed[b]:
                i, j = dirty[b]
                warm_assign[b] = self._solve_entry(e, int(i), int(j))
            else:
                self._ensure_core(e)
                core_sizes[b] = len(e.core)
        core_ptr = np.zeros(B + 1, dtype=np.int64)
        np.cumsum(core_sizes, out=core_ptr[1:])
        # Glue consecutive blocks in groups bounded by the chunk budget so
        # the concatenated union stays cache-resident (grouping cannot
        # change any cut: per-block quantization is composition-invariant).
        if self._chunk_nodes and core_ptr[-1] > self._chunk_nodes:
            spans = _chunk_block_spans(core_ptr, self._chunk_nodes)
        else:
            spans = [(0, B)] if core_ptr[-1] else []
        block_side: List[Optional[np.ndarray]] = [None] * B
        for blo, bhi in spans:
            sub = entries[blo:bhi]
            sub_sizes = core_sizes[blo:bhi]
            total = int(sub_sizes.sum())
            if total == 0:
                continue
            sub_ptr = np.zeros(len(sub) + 1, dtype=np.int64)
            np.cumsum(sub_sizes, out=sub_ptr[1:])
            offs = sub_ptr[:-1]
            # Entries with sub_sizes 0 contribute nothing — pairs with no
            # connected core, and warm-solved entries already settled above.
            glue = [(b, e) for b, e in enumerate(sub)
                    if e is not None and sub_sizes[b]]
            g_ia = np.concatenate(
                [e.core_int_a.astype(np.int64) + offs[b] for b, e in glue])
            g_ib = np.concatenate(
                [e.core_int_b.astype(np.int64) + offs[b] for b, e in glue])
            g_iw = np.concatenate([e.int_w for _, e in glue])
            g_ti = np.concatenate([e.theta_i[e.core] for _, e in glue])
            g_tj = np.concatenate([e.theta_j[e.core] for _, e in glue])
            side = min_st_cut_csr_blocks(
                sub_ptr, g_ia, g_ib, g_iw, g_ti, g_tj, arena=self._arena,
                backend="scipy" if self._use_csr else self._backend,
                workers=self._workers, worker_mode=self._worker_mode,
                presorted=True, chunk_nodes=0)
            for b in range(blo, bhi):
                if core_sizes[b]:
                    lo = sub_ptr[b - blo]
                    block_side[b] = side[lo:lo + core_sizes[b]]
        out: List[Optional[Tuple[np.ndarray, np.ndarray]]] = []
        for b, ((i, j), e, bs) in enumerate(zip(dirty, entries, block_side)):
            if e is None:
                out.append(None)
                continue
            if b in warm_assign:
                out.append((e.members, warm_assign[b]))
                continue
            new_assign = np.empty(len(e.members), dtype=np.int64)
            sing = ~e.has_int
            new_assign[sing] = np.where(
                e.theta_i[sing] < e.theta_j[sing], i, j)
            if bs is not None:
                new_assign[e.core] = np.where(bs, i, j)
            out.append((e.members, new_assign))
        return out

    def try_apply(
        self, members: np.ndarray, proposed: np.ndarray, tol: float = 1e-9
    ) -> bool:
        """Delta-check a proposed re-assignment of ``members`` against the
        LIVE state and commit when improving (used by the batched sweep,
        where the cut may have been computed against a slightly stale
        snapshot: the exact live delta is what guards acceptance)."""
        changed = proposed != self.state.assign[members]
        if not changed.any():
            return False
        moved = members[changed]
        new_servers = proposed[changed]
        if self.state.propose(moved, new_servers) < -tol:
            self.state.commit_pending()      # on_commit hook marks dirty
            return True
        self.state.discard_pending()
        return False

    def apply_assignment(self, members: np.ndarray,
                         new_servers: np.ndarray) -> float:
        """Commit a re-assignment UNCONDITIONALLY (no improvement guard)
        and keep every cache coherent via the on_commit epoch hook.  The
        entry point for externally-imposed moves — fault-runtime orphan
        reseeding, straggler perturbations, benchmark churn — after which
        the engine's warm-started re-solves stay exact.  Returns the exact
        cost delta that was applied."""
        members = np.asarray(members, dtype=np.int64)
        new_servers = np.asarray(new_servers, dtype=np.int64)
        changed = new_servers != self.state.assign[members]
        if not changed.any():
            return 0.0
        return self.state.commit(members[changed], new_servers[changed])

    # ------------------------------------------------------ cross-slot rebind
    def rebind(self, cm: CostModel, assign: np.ndarray,
               active: Optional[np.ndarray] = None) -> None:
        """Adopt the next slot's (CostModel, assignment, active mask)
        WITHOUT discarding cross-slot state: the AssemblyCache, warm-start
        residuals, pair-touch frequencies and arena scratch all survive.

        The model diff (:meth:`CostModel.rebind`) is translated into the
        same epoch machinery commits use: changed unary rows, neighbors of
        vertices on changed tau columns, vertices whose assignment or
        active status differs from the previous slot (plus their
        neighbors) bump ``_vertex_epoch`` — the theta patch repairs them;
        structural edge deltas bump both the vertex AND struct epochs —
        the membership patch re-derives the touched rows' arcs (the
        struct epoch only disqualifies the arc-blind theta fast path);
        densely repriced servers (degrade/revive) bump ``_server_epoch``
        — affected pairs re-gather whole theta columns but KEEP their
        arcs, core split and warm residual (tau, and therefore every
        internal arc, is untouched by compute repricing); only changed
        tau entries force rebuilds.  Untouched entries refresh verbatim.  Every pair starts
        dirty (``_server_dirty`` = new version), so the first sweep after
        adoption probes exactly the schedule a fresh engine would — the
        savings are pure assembly/flow reuse, and trajectories are
        bit-identical to a per-slot rebuild.

        Raises ValueError when the fleet size changed or the graph shrank
        (no incremental mapping exists — build a fresh engine)."""
        old_cm = self.cm
        old_assign = self.state.assign            # pre-adopt layout (owned)
        old_active = self._active
        diff = cm.rebind(old_cm)                  # validates m / graph growth
        g = cm.graph
        n_old = old_cm.graph.n
        assign = np.asarray(assign, dtype=np.int64)
        self.cm = cm
        self._tau = cm.net.tau
        self._indptr = g.indptr
        self._indices = g.indices
        self._eids = g.edge_ids
        self.state = cm.layout_state(assign)
        self.state.on_commit = self._mark_dirty
        self._w = self.state._w
        self._unit_w = g.edge_weights is None
        self._active = None if active is None else np.asarray(active, bool)
        if g.n > n_old:
            grow = g.n - n_old
            self._mask = np.zeros(g.n, dtype=bool)
            self._loc = np.full(g.n, -1, dtype=np.int64)
            self._moved_mask = np.concatenate(
                [self._moved_mask, np.zeros(grow, dtype=bool)])
            self._vertex_epoch = np.concatenate(
                [self._vertex_epoch, np.zeros(grow, dtype=np.int64)])
            self._struct_epoch = np.concatenate(
                [self._struct_epoch, np.zeros(grow, dtype=np.int64)])
        # The touched-vertex ledger restarts per adoption: callers read the
        # CURRENT run's movers, exactly like a fresh engine's.
        self._moved_mask[:] = False
        self._universe = (int(self._active.sum())
                          if self._active is not None else g.n)
        self._version += 1
        v = self._version
        self._server_dirty[:] = v
        # --- per-vertex epochs: theta-patchable changes -------------------
        if len(diff.unary_rows):
            self._vertex_epoch[diff.unary_rows] = v
        if len(diff.tau_cols):
            # tau[i, c] changed sparsely: any member with a boundary
            # neighbor homed on c folds the stale price into its theta.
            on_cols = np.flatnonzero(np.isin(assign, diff.tau_cols))
            flat, _ = csr_multirange(self._indptr, on_cols)
            if len(flat):
                self._vertex_epoch[self._indices[flat]] = v
        # Vertices re-assigned between the slots (orphan scatter, replica
        # re-homing, external churn) and active-mask flips change pair
        # memberships without a commit — mirror _mark_dirty: the vertex
        # AND its neighbors are stale.
        movers = np.flatnonzero(assign[:n_old] != old_assign)
        oa = (old_active if old_active is not None
              else np.ones(n_old, dtype=bool))
        na = (self._active[:n_old] if self._active is not None
              else np.ones(n_old, dtype=bool))
        xor = np.flatnonzero(oa != na)
        touch = np.unique(np.concatenate([movers, xor]))
        if len(touch):
            self._vertex_epoch[touch] = v
            flat, _ = csr_multirange(self._indptr, touch)
            if len(flat):
                self._vertex_epoch[self._indices[flat]] = v
        # --- rebuild-forcing epochs ---------------------------------------
        if len(diff.servers):
            self._server_epoch[diff.servers] = v
            self._server_max = v
        if diff.tau_pairs is not None:
            if self._tau_pair_epoch is None:
                self._tau_pair_epoch = np.zeros(
                    (cm.net.m, cm.net.m), dtype=np.int64)
            self._tau_pair_epoch[diff.tau_pairs] = v
            self._tau_max = v
        if len(diff.struct_vertices):
            # Both endpoints of every changed/new edge are in the struct
            # set, so re-gathering the struct vertices' rows (the
            # membership patch's touched path) reproduces a fresh
            # assembly's arcs exactly; the struct epoch only disqualifies
            # the theta-only fast path, which cannot rewrite arc lists.
            self._vertex_epoch[diff.struct_vertices] = v
            self._struct_epoch[diff.struct_vertices] = v
            self._struct_max = v


class LayoutSession:
    """Persistent cross-slot layout engine (the adaptive loop's warm path).

    Owns one :class:`PairCutEngine` across GLAD-S/E/A calls and fault
    relayouts: ``adopt`` rebinds the live engine to the next slot's
    (CostModel, assignment, active mask) via :meth:`PairCutEngine.rebind`,
    keeping every untouched assembly and warm-start residual alive —
    per-slot relayouts stop paying the from-scratch engine build the
    ISSUE/ROADMAP call out at ``glad_s``'s rebuild site.  Trajectories are
    bit-identical to per-slot rebuilds (pinned by golden + fuzz tests);
    only the schedule of cache/warm reuse changes.

    Engine knobs are fixed at session construction (a session IS one
    engine configuration); ``cache='auto'`` resolves ON — persistence is
    the point, and the first adoption often carries no active mask.  A
    fleet resize or graph shrink has no incremental mapping: ``adopt``
    transparently falls back to a fresh engine (state reset, same
    semantics as the first adoption).
    """

    def __init__(self, backend: str = "auto", workers: int = 0,
                 worker_mode: str = "thread", cache: "bool | str" = "auto",
                 cache_bytes: int = 256 << 20,
                 chunk_nodes: "int | str" = "auto",
                 warm: "bool | str" = "auto"):
        self._opts = dict(
            backend=backend, workers=workers, worker_mode=worker_mode,
            cache=(True if cache == "auto" else cache),
            cache_bytes=cache_bytes, chunk_nodes=chunk_nodes, warm=warm)
        self.engine: Optional[PairCutEngine] = None
        self.adoptions = 0           # total adopt() calls
        self.rebinds = 0             # adoptions served by an engine rebind
        # Persistent multilevel coarsening hierarchies, one per V-cycle
        # configuration — the LevelStack cache that survives GLAD-E
        # escalations and fault relayouts (see
        # repro.core.multilevel.LevelStack).
        self._stacks: dict = {}

    def level_stack(self, coarsen_to: int = 1024,
                    max_levels: Optional[int] = None,
                    mu_gate: bool = True):
        """Get-or-create the session's persistent
        :class:`repro.core.multilevel.LevelStack` for one V-cycle
        configuration (local import — engine <-> multilevel cycle)."""
        key = (int(coarsen_to), max_levels, bool(mu_gate))
        st = self._stacks.get(key)
        if st is None:
            from repro.core.multilevel import LevelStack
            st = LevelStack(coarsen_to=coarsen_to, max_levels=max_levels,
                            mu_gate=mu_gate)
            self._stacks[key] = st
        return st

    def stack_valid_for(self, cm: CostModel, coarsen_to: int = 1024,
                        max_levels: Optional[int] = None,
                        mu_gate: bool = True) -> bool:
        """True when a cached LevelStack for this configuration was built
        over ``cm``'s graph — i.e. a V-cycle escalation would refresh the
        hierarchy instead of coarsening from scratch (the signal GLAD-E's
        churn-measured auto policy reads)."""
        key = (int(coarsen_to), max_levels, bool(mu_gate))
        st = self._stacks.get(key)
        return st is not None and st.valid_for(cm)

    def adopt(self, cm: CostModel, assign: np.ndarray,
              active: Optional[np.ndarray] = None) -> PairCutEngine:
        """Bind the session's engine to the next slot; returns the engine
        (rebound in place when possible, freshly built otherwise)."""
        self.adoptions += 1
        if self.engine is not None:
            try:
                self.engine.rebind(cm, assign, active=active)
            except ValueError:
                self.engine = None   # fleet resized / graph shrank
            else:
                self.rebinds += 1
                return self.engine
        self.engine = PairCutEngine(cm, assign, active=active, **self._opts)
        return self.engine
