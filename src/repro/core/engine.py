"""Incremental pairwise min-cut layout engine (the fast path behind GLAD).

The seed implementation of Alg. 1 re-evaluated the full O(n+m) objective per
proposal and rebuilt every auxiliary graph with per-edge Python loops; at the
ROADMAP's production graph sizes the *optimizer* dominated end-to-end time.
This engine makes one Alg.-1 iteration cost O(|members| + vol(members)):

  * cached assignment state (:class:`repro.core.cost.LayoutState`) turns the
    accept decision into an exact delta over moved vertices + incident links;
  * auxiliary graphs are assembled with pure array ops — global->local index
    translation via preallocated scratch vectors, incident-edge discovery via
    the CSR edge-id view (no scan of the global edge list);
  * scratch buffers (member mask, local ids, theta vectors, flow arenas) are
    allocated once and reused across iterations;
  * a *batched sweep* solves a round-robin matching of disjoint server pairs
    per round.  Disjoint pairs touch disjoint member sets, so their cuts can
    be solved from one snapshot and composed; every acceptance still uses an
    exact delta against the live state, so composing never mis-accepts.

The engine preserves the paper's auxiliary-graph semantics exactly
(Sec. IV-B: t-link = unary + side-effect traffic to third servers, n-link =
tau_ij per internal link), so Thm 4-6 continue to hold per pair.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import CostModel, LayoutState
from repro.core.maxflow import _HAVE_SCIPY, CutArena, min_st_cut, min_st_cut_csr
from repro.graphs.datagraph import csr_multirange


def round_robin_rounds(m: int) -> List[List[Tuple[int, int]]]:
    """Circle-method tournament schedule: m-1 rounds (m even; m rounds if
    odd) of vertex-disjoint pairs that jointly cover every pair i < j."""
    ids = list(range(m))
    if m % 2:
        ids.append(-1)                       # bye slot
    k = len(ids)
    rounds: List[List[Tuple[int, int]]] = []
    for _ in range(max(k - 1, 0)):
        rnd = []
        for a in range(k // 2):
            x, y = ids[a], ids[k - 1 - a]
            if x >= 0 and y >= 0:
                rnd.append((min(x, y), max(x, y)))
        rounds.append(rnd)
        ids = [ids[0], ids[-1]] + ids[1:-1]  # rotate all but the pivot
    return rounds


class PairCutEngine:
    """Stateful solver of restricted two-server subproblems over one layout.

    Owns a :class:`LayoutState` (read ``.state.assign`` / ``.state.total``)
    plus the preallocated scratch that keeps per-pair work at
    O(n bool-scan + pair member volume): the accept path is
    O(moved + incident links), auxiliary construction is proportional to
    the pair's member volume, and the only full-graph term left is the
    vectorized member scan in :meth:`members_of` — deliberate, it is
    memory-bandwidth noise next to one min-cut solve.
    """

    def __init__(
        self,
        cm: CostModel,
        assign: np.ndarray,
        active: Optional[np.ndarray] = None,
        backend: str = "auto",
    ):
        self.cm = cm
        self.state = cm.layout_state(assign)
        g = cm.graph
        self._indptr = g.indptr
        self._indices = g.indices
        self._eids = g.edge_ids
        self._w = self.state._w                  # share LayoutState's copy
        self._unit_w = g.edge_weights is None    # skip weight gathers
        self._tau = cm.net.tau
        self._active = None if active is None else np.asarray(active, bool)
        self._backend = backend
        self._use_csr = _HAVE_SCIPY and backend in ("auto", "scipy")
        self._arena = CutArena()
        # Scratch, allocated once: member mask + global->local translation.
        self._mask = np.zeros(g.n, dtype=bool)
        self._loc = np.full(g.n, -1, dtype=np.int64)
        # Grown-on-demand per-pair buffers (theta / flow edge arrays).
        self._theta_cap = 0
        self._theta_i = self._theta_j = None
        # Dirty-pair tracking: the auxiliary graph of (i, j) depends only on
        # its member set and the layout of members' neighbors, so a pair is
        # clean — its solve would reproduce the last (rejected) proposal
        # verbatim — until a commit touches one of its servers.  Clean
        # probes are skipped; this keeps the Alg.-1 trajectory bit-identical
        # while eliding most non-improving cut solves near convergence.
        self._version = 0
        self._server_dirty = np.zeros(cm.net.m, dtype=np.int64)
        self._pair_stamp: dict = {}

    def pair_clean(self, i: int, j: int) -> bool:
        """True iff (i, j)'s auxiliary graph is unchanged since its last
        solve AND that solve did not end in an accept (an accepted solve
        dirties both servers, so clean implies last-result == reject)."""
        stamp = self._pair_stamp.get((i, j), -1)
        return stamp >= max(self._server_dirty[i], self._server_dirty[j])

    def _mark_dirty(self, moved: np.ndarray, old_servers: np.ndarray) -> None:
        """After committing ``moved``, dirty every server whose pairs could
        see a different auxiliary graph: the movers' old and new servers
        (membership changes) plus every server hosting a neighbor of a
        mover (their boundary side-effect terms reference the movers'
        layout)."""
        assign = self.state.assign
        servers = [old_servers, assign[moved]]
        flat, _ = csr_multirange(self._indptr, moved)
        if len(flat):
            servers.append(assign[self._indices[flat]])
        dirty = np.unique(np.concatenate(servers))
        self._version += 1
        self._server_dirty[dirty] = self._version

    # ------------------------------------------------------------- internals
    def _thetas(self, k: int):
        if k > self._theta_cap:
            cap = max(256, 1 << int(np.ceil(np.log2(max(k, 1)))))
            self._theta_i = np.empty(cap, dtype=np.float64)
            self._theta_j = np.empty(cap, dtype=np.float64)
            self._theta_cap = cap
        return self._theta_i[:k], self._theta_j[:k]

    def members_of(self, i: int, j: int) -> np.ndarray:
        assign = self.state.assign
        pair_mask = (assign == i) | (assign == j)
        if self._active is not None:
            pair_mask &= self._active
        return np.flatnonzero(pair_mask)

    # ----------------------------------------------------------- pair solve
    def solve_pair(
        self, i: int, j: int
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Min s-t cut of the auxiliary graph A(i, j) over the current
        layout.  Returns (members, proposed_servers_for_members) or None if
        the pair hosts no active vertices.  Does NOT mutate the state."""
        members = self.members_of(i, j)
        k = len(members)
        if k == 0:
            return None
        cm, assign = self.cm, self.state.assign
        mask, loc = self._mask, self._loc
        mask[members] = True
        loc[members] = np.arange(k)

        theta_i, theta_j = self._thetas(k)
        theta_i[:] = cm.unary[members, i]
        theta_j[:] = cm.unary[members, j]

        # Incident links, straight from the member rows of the CSR view:
        # one ragged multi-range gather gives (member-local row, neighbor,
        # edge id) triples — no scan of the global edge list, no sort/unique.
        flat, row = csr_multirange(self._indptr, members)
        if len(flat):
            nbr = self._indices[flat]
            nbr_in = mask[nbr]
            # Boundary links (neighbor outside the member set) appear exactly
            # once: side-effect traffic to the frozen third-server neighbor,
            # added to BOTH unary columns so each cut stays globally
            # cost-aware (Sec. IV-B).
            bnd = ~nbr_in
            if bnd.any():
                ins = row[bnd]
                outs = assign[nbr[bnd]]
                ti = self._tau[i, outs]
                tj = self._tau[j, outs]
                if not self._unit_w:
                    bw = self._w[self._eids[flat[bnd]]]
                    ti = ti * bw
                    tj = tj * bw
                theta_i += np.bincount(ins, weights=ti, minlength=k)
                theta_j += np.bincount(ins, weights=tj, minlength=k)
            # Internal links appear twice (once per endpoint's row) — which
            # is exactly the two directed arcs the flow network needs.
            internal = nbr_in
            int_a = row[internal]
            int_b = loc[nbr[internal]]
            tij = float(self._tau[i, j])
            if self._unit_w:
                int_w = np.broadcast_to(tij, len(int_a))
            else:
                int_w = tij * self._w[self._eids[flat[internal]]]
        else:
            int_a = int_b = np.zeros(0, dtype=np.int64)
            int_w = np.zeros(0, dtype=np.float64)

        # Members without intra-pair links are singleton flow components:
        # the cut decides them by the cheaper t-link alone, so settle them
        # with a vectorized argmin and solve the flow only over the core.
        # (Disjoint components of a flow network optimize independently —
        # this is exact, and it shrinks the solver input by the boundary-
        # heavy majority of members on sparse layouts.)
        new_assign = np.empty(k, dtype=np.int64)
        has_int = np.zeros(k, dtype=bool)
        has_int[int_a] = True
        singles = ~has_int
        # Tie -> sink side (j), matching the max-flow residual convention
        # (both t-links saturate, so v is unreachable from s).
        new_assign[singles] = np.where(
            theta_i[singles] < theta_j[singles], i, j)

        core = np.flatnonzero(has_int)
        kc = len(core)
        if kc:
            cloc = np.empty(k, dtype=np.int64)
            cloc[core] = np.arange(kc)
            int_a = cloc[int_a]
            int_b = cloc[int_b]
            th_i = theta_i[core]
            th_j = theta_j[core]
            side = self._solve_flow(kc, int_a, int_b, int_w, th_i, th_j)
            new_assign[core] = np.where(side[:kc], i, j)

        # Reset scratch (only the touched entries).
        mask[members] = False
        loc[members] = -1
        return members, new_assign

    def _solve_flow(self, k, int_a, int_b, int_w, theta_i, theta_j):
        """Min cut of the (connected-core) auxiliary flow network: nodes
        0..k-1 plus S=k, T=k+1; t-link caps theta_j (s->v) / theta_i (v->t);
        internal arcs already both directions in (int_a, int_b)."""
        S, T = k, k + 1
        n_int = len(int_w)
        if self._use_csr:
            # Direct CSR assembly with SYMMETRIC structure (zero-capacity
            # reverse arcs for every t-link; internal arcs are already both
            # directions): scipy's flow matrix then shares this sparsity
            # exactly, making the residual a plain array difference in
            # min_st_cut_csr.  That fast path compares flow.indices against
            # mat.indices, and scipy returns the flow CANONICALIZED — so the
            # input must be canonical too: sort internal arcs by (row, col).
            # ``int_a`` arrives row-grouped from the CSR member gather, and
            # each member row ends with ->S(=k), ->T(=k+1) which exceed
            # every member column, so sorting columns within rows suffices.
            if n_int:
                order = np.lexsort((int_b, int_a))
                int_a = int_a[order]
                int_b = int_b[order]
                if not self._unit_w:
                    int_w = int_w[order]
            int_counts = np.bincount(int_a, minlength=k)
            aux_indptr = np.zeros(k + 3, dtype=np.int32)
            np.cumsum(int_counts + 2, out=aux_indptr[1:k + 1])
            aux_indptr[k + 1] = aux_indptr[k] + k        # S row
            aux_indptr[k + 2] = aux_indptr[k + 1] + k    # T row
            nnz = n_int + 4 * k
            cols = np.empty(nnz, dtype=np.int32)
            caps = np.empty(nnz, dtype=np.float64)
            ar = np.arange(k)
            row_start = aux_indptr[:k].astype(np.int64)  # of member rows
            if n_int:
                # Within-row offsets of the (already grouped) internal arcs.
                excl = np.cumsum(int_counts) - int_counts
                pos = np.arange(n_int) - np.repeat(excl, int_counts) \
                    + row_start[int_a]
                cols[pos] = int_b
                caps[pos] = int_w
            t_pos = row_start + int_counts
            cols[t_pos] = S
            caps[t_pos] = 0.0
            cols[t_pos + 1] = T
            caps[t_pos + 1] = theta_i
            cols[n_int + 2 * k:n_int + 3 * k] = ar
            caps[n_int + 2 * k:n_int + 3 * k] = theta_j
            cols[n_int + 3 * k:] = ar
            caps[n_int + 3 * k:] = 0.0
            _, side = min_st_cut_csr(k + 2, S, T, aux_indptr, cols, caps)
            return side
        us = np.empty(2 * k + n_int, dtype=np.int64)
        vs = np.empty(2 * k + n_int, dtype=np.int64)
        caps_uv = np.empty(2 * k + n_int, dtype=np.float64)
        caps_vu = np.zeros(2 * k + n_int, dtype=np.float64)
        us[:k] = S
        vs[:k] = np.arange(k)
        caps_uv[:k] = theta_j
        us[k:2 * k] = np.arange(k)
        vs[k:2 * k] = T
        caps_uv[k:2 * k] = theta_i
        # Internal arcs appear twice in (int_a, int_b) (both directions);
        # emit them as one-way capacities.
        us[2 * k:] = int_a
        vs[2 * k:] = int_b
        caps_uv[2 * k:] = int_w
        _, side = min_st_cut(
            k + 2, S, T, us, vs, caps_uv, caps_vu,
            backend=self._backend, arena=self._arena,
        )
        return side

    # ----------------------------------------------------------- accept path
    def try_pair(self, i: int, j: int, tol: float = 1e-9) -> Tuple[bool, bool]:
        """Solve pair (i, j) and commit iff the exact delta improves.

        Returns (solved, accepted).  Clean pairs (see :meth:`pair_clean`)
        skip the solve entirely — the result is known to be a reject.  The
        accept decision costs O(|moved| + incident links) via the cached
        LayoutState — no full objective evaluation."""
        if self.pair_clean(i, j):
            return True, False
        sol = self.solve_pair(i, j)
        if sol is None:
            self._pair_stamp[(i, j)] = self._version
            return False, False
        members, proposed = sol
        accepted = self.try_apply(members, proposed, tol=tol)
        # Stamp AFTER a possible commit: re-solving the just-accepted pair
        # reproduces the committed layout verbatim (same auxiliary graph,
        # deterministic cut), i.e. a reject — so the pair starts clean.
        self._pair_stamp[(i, j)] = self._version
        return True, accepted

    def sweep_round(
        self, pairs: Sequence[Tuple[int, int]], tol: float = 1e-9
    ) -> List[Tuple[bool, bool]]:
        """One batched round: solve a matching of disjoint server pairs from
        the current snapshot, then apply each cut with an exact live delta.

        The member sets are disjoint, so the solves are independent (and
        parallelizable); composition is guarded per pair by the delta
        against the state as commits land.  Returns (solved, accepted) per
        pair, in order."""
        sols = []
        for i, j in pairs:
            if self.pair_clean(i, j):
                sols.append((i, j, "clean", self._version))
            else:
                sols.append((i, j, self.solve_pair(i, j), self._version))
        out = []
        for i, j, sol, solve_version in sols:
            if isinstance(sol, str):                 # clean: known reject
                out.append((True, False))
                continue
            if sol is None:
                self._pair_stamp[(i, j)] = solve_version
                out.append((False, False))
                continue
            dirt_before = max(self._server_dirty[i], self._server_dirty[j])
            accepted = self.try_apply(*sol, tol=tol)
            # "Clean implies re-solve == reject" only holds for an accepted
            # pair if nothing ELSE dirtied it between its snapshot solve and
            # this commit — then its layout equals its own deterministic cut
            # and the post-commit stamp is valid.  If another pair's commit
            # in this round touched its servers (dirt_before > solve
            # version), or it was rejected, keep the solve-time stamp so the
            # pair is re-solved against the fresh state.
            if accepted and dirt_before <= solve_version:
                self._pair_stamp[(i, j)] = self._version
            else:
                self._pair_stamp[(i, j)] = solve_version
            out.append((True, accepted))
        return out

    def try_apply(
        self, members: np.ndarray, proposed: np.ndarray, tol: float = 1e-9
    ) -> bool:
        """Delta-check a proposed re-assignment of ``members`` against the
        LIVE state and commit when improving (used by the batched sweep,
        where the cut may have been computed against a slightly stale
        snapshot: the exact live delta is what guards acceptance)."""
        changed = proposed != self.state.assign[members]
        if not changed.any():
            return False
        moved = members[changed]
        new_servers = proposed[changed]
        old_servers = self.state.assign[moved].copy()
        if self.state.propose(moved, new_servers) < -tol:
            self.state.commit_pending()
            self._mark_dirty(moved, old_servers)
            return True
        self.state.discard_pending()
        return False
