"""The DGPE cost model (paper Sec. III-B, Eq. (4)-(9)).

All evaluation is vectorized numpy over an assignment vector
``assign[v] in [0, m)`` (the dense encoding of the binary layout x_vi).

    C   = C_U + C_P + C_T + C_M                                   (Eq. 9)
    C_U = sum_i sum_v  mu[v,i] x_vi                               (Eq. 4)
    C_P = sum_i sum_v  C_P(v,i) x_vi                              (Eq. 6)
          C_P(v,i) = sum_k alpha_i |N_v| s_{k-1}
                     + beta_i s_{k-1} s_k + gamma_i s_k           (Eq. 5)
    C_T = sum_ij sum_uv tau[i,j] e_uv w_ij x_vi x_uj              (Eq. 7)
    C_M = sum_i ( sum_v rho_i x_vi + eps_i )                      (Eq. 8)

The decomposition C = C0 + C1 + C2 (Thm 2: constant / linear / quadratic
pseudo-boolean terms) is exposed as ``unary`` (C1 coefficient matrix) and the
edge-wise quadratic evaluation — GLAD's auxiliary graphs are built directly
from these.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

from repro.graphs.datagraph import DataGraph
from repro.graphs.edgenet import EdgeNetwork


@dataclasses.dataclass
class Replication:
    """A set of read-only replica placements ON TOP of an assignment.

    ``by_part[p]`` holds the vertex ids replicated INTO partition p (sorted
    ascending, never including vertices homed on p — a replica of a resident
    is meaningless).  Replication is a *unary* overlay on a fixed cut: each
    (v -> p) decision trades the saved directed read traffic from v's home
    into p against a one-time sync + storage charge, independently of every
    other replica — so the greedy that accepts all positive-gain candidates
    is exact for the overlay subproblem (the cut itself is GLAD's job).
    """

    by_part: Dict[int, np.ndarray]
    gain: float                       # total objective improvement (>= 0)
    saved: float                      # read traffic no longer crossing links
    sync: float                       # sum sync_weight * tau[home, p]
    storage: float                    # count * storage_cost
    sync_weight: float
    storage_cost: float

    @property
    def count(self) -> int:
        return int(sum(len(v) for v in self.by_part.values()))

    def pairs(self) -> np.ndarray:
        """(k, 2) array of (vertex, part) placements, part-major sorted."""
        out = [np.stack([ids, np.full(len(ids), p, dtype=np.int64)], axis=1)
               for p, ids in sorted(self.by_part.items()) if len(ids)]
        return (np.concatenate(out, axis=0) if out
                else np.zeros((0, 2), dtype=np.int64))


@dataclasses.dataclass(frozen=True)
class GNNWorkload:
    """Feature-dim schedule of the served GNN: s = [s_0, .., s_K] (Sec. II-A).

    ``agg_ops / upd_ops / act_ops`` let different models (GCN/GAT/SAGE) scale
    the three Eq.-(5) terms: e.g. GAT's attention adds per-link work (folded
    into the aggregation coefficient), SAGE's concat doubles the update GEMM.
    """

    layer_dims: Sequence[int]          # [s_0, s_1, ..., s_K]
    agg_scale: float = 1.0
    upd_scale: float = 1.0
    act_scale: float = 1.0
    name: str = "gcn"

    @property
    def agg_units(self) -> float:
        # sum_k s_{k-1}: per-neighbor vector-add elements across layers.
        return self.agg_scale * float(sum(self.layer_dims[:-1]))

    @property
    def upd_units(self) -> float:
        # sum_k s_{k-1} * s_k: matvec MACs across layers.
        return self.upd_scale * float(
            sum(a * b for a, b in zip(self.layer_dims[:-1], self.layer_dims[1:]))
        )

    @property
    def act_units(self) -> float:
        # sum_k s_k: activation elements across layers.
        return self.act_scale * float(sum(self.layer_dims[1:]))


def workload_for(model: str, in_dim: int, hidden: int = 16, out_dim: int = 2,
                 layers: int = 2) -> GNNWorkload:
    """Paper Sec. VI-A model zoo: 2-layer GCN/GAT/GraphSAGE, hidden=16."""
    dims = [in_dim] + [hidden] * (layers - 1) + [out_dim]
    model = model.lower()
    if model == "gcn":
        return GNNWorkload(dims, 1.0, 1.0, 1.0, "gcn")
    if model == "gat":
        # attention: extra per-neighbor weighting + per-link score matvecs.
        return GNNWorkload(dims, 2.0, 1.25, 1.0, "gat")
    if model in ("sage", "graphsage"):
        # mean aggregation (divide folded into act), concat doubles update GEMM
        # but SAGE aggregates neighbors only (no self in sum) -> lighter agg.
        return GNNWorkload(dims, 0.75, 2.0, 1.0, "sage")
    raise ValueError(f"unknown GNN model {model!r}")


@dataclasses.dataclass
class ModelDiff:
    """What changed between two CostModels over the same fleet (m equal,
    graph grown or equal).  Consumed by ``PairCutEngine.rebind`` to bump
    per-vertex / per-server epochs so only genuinely-affected cache entries
    and warm-start residuals are invalidated across slots.

    ``unary_rows``      vertices whose unary row changed in a sparse set of
                        server columns (each needs a theta re-gather);
    ``servers``         servers whose whole unary column or tau row changed
                        (degrade / kill: every pair touching them rebuilds);
    ``tau_pairs``       (m, m) bool of CHANGED tau entries, or None — a tau
                        change alters internal arc capacities, which theta
                        patches never repair, so affected pairs must
                        reassemble from scratch;
    ``tau_cols``        servers j with sparse tau-column changes: vertices
                        assigned to j impose new arc prices on their
                        neighbors' pair problems;
    ``struct_vertices`` endpoints of inserted/deleted/reweighted links plus
                        brand-new vertices (membership arrays stale).
    """

    unary_rows: np.ndarray
    servers: np.ndarray
    tau_pairs: "np.ndarray | None"
    tau_cols: np.ndarray
    struct_vertices: np.ndarray


class CostModel:
    """Vectorized evaluator of the four cost factors for a (net, graph, gnn).

    Structural contract (Thm 2, relied on by ``repro.core.multilevel``):
    every vertex-separable term of the objective lives in :attr:`unary`
    (mu + C_P + rho), the only pairwise term is the tau-weighted link sum,
    and the only data-independent term is :attr:`constant` (sum eps).  So
    ``total(a) == unary[arange(n), a].sum() + tau-link-sum + constant``,
    which is what makes the multilevel coarse models EXACT: summing unary
    rows per cluster into the coarse mu (alpha/beta/gamma/rho zeroed so
    nothing double counts) and summing parallel edge weights preserves the
    objective of every projected assignment, since intra-cluster links
    land on the tau diagonal (zero)."""

    def __init__(self, net: EdgeNetwork, graph: DataGraph, gnn: GNNWorkload,
                 traffic: "np.ndarray | None" = None):
        # Graph evolution can add clients the fleet has no upload entry for
        # yet (Sec. V-A): derive mu for them from coordinates when present,
        # else charge the fleet-average upload cost.  The padded mu lives on
        # a shallow copy — the caller's EdgeNetwork (often shared between
        # several CostModels over different graph snapshots) is never
        # mutated.
        if net.mu.shape[0] < graph.n:
            extra = graph.n - net.mu.shape[0]
            if graph.coords is not None and net.coords is not None:
                d = np.linalg.norm(
                    graph.coords[net.mu.shape[0]:, None, :]
                    - net.coords[None, :, :], axis=-1)
                new_mu = d * (net.mu.mean() / max(d.mean(), 1e-9))
            else:
                new_mu = np.tile(net.mu.mean(0, keepdims=True), (extra, 1))
            mu = np.concatenate([net.mu, new_mu], axis=0)
        else:
            # Own a copy regardless: the layout engine's caches (LayoutState
            # unary picks, AssemblyCache theta vectors) embed mu-derived
            # values, so a caller mutating its mu array after construction
            # must not be able to desynchronize them.
            mu = np.array(net.mu, dtype=np.float64)
        mu.setflags(write=False)
        self.net = dataclasses.replace(net, mu=mu)
        self.graph = graph
        self.gnn = gnn
        # Per-vertex request rate (requests/vertex, Sec. II-A's stream
        # workload): scales the vertex's COMPUTE row C_P(v, ·) — a vertex
        # serving r times as many requests costs r times the per-inference
        # work on whichever server hosts it.  Upload (one-time residency),
        # tau (per-link sync, already per-BSP-round) and maintenance are
        # NOT scaled.  None = traffic-blind (the repo's historical
        # behavior, bit-identical).  Normalize to mean 1 (see
        # ``repro.gnn.serving.request_traffic``) to keep the C_P scale
        # comparable across traffic-aware and traffic-blind layouts.
        if traffic is not None:
            traffic = np.asarray(traffic, dtype=np.float64).copy()
            if traffic.shape != (graph.n,):
                if traffic.shape[0] < graph.n:
                    # Evolution can add vertices after the window the
                    # traffic histogram was measured on: neutral weight.
                    traffic = np.concatenate(
                        [traffic, np.ones(graph.n - traffic.shape[0])])
                else:
                    raise ValueError(
                        f"traffic shape {traffic.shape} != ({graph.n},)")
            if (traffic < 0).any():
                raise ValueError("traffic weights must be non-negative")
            traffic.setflags(write=False)
        self.traffic = traffic
        self._unary = None

    # ------------------------------------------------------------ components
    @property
    def cp_matrix(self) -> np.ndarray:
        """C_P(v, i) per Eq. (5): (n, m).  With :attr:`traffic` set, row v is
        scaled by the vertex's request rate (serving workload, Sec. II-A)."""
        deg = self.graph.degrees.astype(np.float64)  # |N_v|
        net, g = self.net, self.gnn
        out = (
            np.outer(deg, net.alpha) * g.agg_units
            + net.beta[None, :] * g.upd_units
            + net.gamma[None, :] * g.act_units
        )
        if self.traffic is not None:
            out *= self.traffic[:, None]
        return out

    @property
    def unary(self) -> np.ndarray:
        """C1 coefficients (Thm 2): unary[v,i] = mu + C_P(v,i) + rho_i.
        Frozen: every cached delta in the engine is derived from it, so
        in-place edits would silently corrupt them — copy to modify."""
        if self._unary is None:
            self._unary = self.net.mu + self.cp_matrix + self.net.rho[None, :]
            self._unary.setflags(write=False)
        return self._unary

    def release_unary(self) -> None:
        """Drop the cached :attr:`unary` matrix.  It is a deterministic
        elementwise function of (mu, degrees, gnn coefficients, traffic),
        so the next access rebuilds it bitwise identical — callers that
        copied values out (engine picks, assembly deltas) are untouched.
        The streamed coarsening build releases each level's unary once the
        level is contracted: a coarse model's unary duplicates its mu
        (compute/maintenance coefficients are zeroed), so the cache is
        pure resident redundancy across a retained hierarchy."""
        self._unary = None

    @property
    def constant(self) -> float:
        """C0 (Thm 2): data-independent maintenance sum_i eps_i."""
        return float(self.net.eps.sum())

    def tau_ref(self) -> float:
        """Mean inter-server transmission coefficient over CONNECTED pairs
        — the traffic scale one link unit can cost.  Used by the multilevel
        matcher's mu gate (a merge commits both endpoints to one server, so
        candidates whose unary disagreement exceeds what the merged link
        could save at this scale are pruned) and usable as a drift scale
        anywhere a single tau number is needed."""
        p = self.net.pairs
        if not len(p):
            return 0.0
        return float(self.net.tau[p[:, 0], p[:, 1]].mean())

    # ------------------------------------------------------------- evaluation
    def factors(self, assign: np.ndarray) -> Dict[str, float]:
        assign = np.asarray(assign, dtype=np.int64)
        n = self.graph.n
        net = self.net
        cu = float(net.mu[np.arange(n), assign].sum())
        cp = float(self.cp_matrix[np.arange(n), assign].sum())
        e = self.graph.edges
        if len(e):
            w = self.graph.weights_or_ones()
            ct = float((net.tau[assign[e[:, 0]], assign[e[:, 1]]] * w).sum())
        else:
            ct = 0.0
        cm = float(net.rho[assign].sum() + net.eps.sum())
        return {"C_U": cu, "C_P": cp, "C_T": ct, "C_M": cm,
                "total": cu + cp + ct + cm}

    def total(self, assign: np.ndarray) -> float:
        return self.factors(assign)["total"]

    def traffic_bytes(self, assign: np.ndarray, feat_bytes: int) -> float:
        """Physical bytes crossing servers under BSP (for runtime validation:
        cut links x per-layer feature bytes x layers)."""
        e = self.graph.edges
        if not len(e):
            return 0.0
        cut = assign[e[:, 0]] != assign[e[:, 1]]
        return float(cut.sum()) * 2 * feat_bytes * (len(self.gnn.layer_dims) - 1)

    # --------------------------------------------------- marginal quantities
    def marginal_all(
        self, placed: np.ndarray, assign: np.ndarray, v: int
    ) -> np.ndarray:
        """Incremental placement cost of vertex v at EVERY server, given the
        subset mask ``placed`` with layout ``assign``: (m,) vector.

        Vectorized over servers x placed neighbors — used by GLAD-E to seed
        newly-inserted vertices (argmin) and by GLAD-A's drift bound (max,
        Thm 8)."""
        out = self.unary[v].astype(np.float64).copy()
        nbrs = self.graph.neighbors(v)
        if len(nbrs):
            nbrs = nbrs[placed[nbrs]]
        if len(nbrs):
            out += self.net.tau[:, assign[nbrs]].sum(axis=1)
        return out

    def marginal(self, placed: np.ndarray, assign: np.ndarray, v: int, i: int) -> float:
        """Scalar view of :meth:`marginal_all` (kept for the Thm-8 tests)."""
        return float(self.marginal_all(placed, assign, v)[i])

    def layout_state(self, assign: np.ndarray) -> "LayoutState":
        """Cached per-assignment state for O(moved + incident) delta costs."""
        return LayoutState(self, assign)

    def rebind(self, old: "CostModel") -> ModelDiff:
        """Diff this model against the previous slot's: the minimal epoch
        bumps a persistent engine needs to adopt it (see ModelDiff).  Both
        models must price the same fleet; the graph may only grow (GLAD-E's
        evolution contract — deletions re-enter as weight-0 links)."""
        if self.net.m != old.net.m:
            raise ValueError(
                f"rebind across fleet sizes ({old.net.m} -> {self.net.m})")
        n_old, n_new = old.graph.n, self.graph.n
        if n_new < n_old:
            raise ValueError(f"rebind shrank the graph ({n_old} -> {n_new})")
        m = self.net.m

        # Unary: dense columns (degrade/kill/traffic-rescale hit every row
        # of a server) become server epochs; remaining sparse changes become
        # per-vertex rows.
        D = self.unary[:n_old] != old.unary
        colcnt = D.sum(axis=0)
        dense = colcnt * 2 > max(n_old, 1)
        servers = set(np.flatnonzero(dense).tolist())
        if dense.all():
            unary_rows = np.zeros(0, dtype=np.int64)
        else:
            unary_rows = np.flatnonzero(D[:, ~dense].any(axis=1))

        # Tau: any change poisons internal arc capacities of pairs reading
        # the changed entries.  Dense rows (a server's whole link pricing
        # moved) fold into server epochs; sparse leftover columns are
        # reported so the engine can bump the neighbors of vertices homed
        # on those servers.
        T = self.net.tau != old.net.tau
        if T.any():
            tau_pairs = T
            dense_r = T.sum(axis=1) * 2 > m
            servers.update(np.flatnonzero(dense_r).tolist())
            rest = T[~dense_r] if not dense_r.all() else np.zeros((0, m), bool)
            tau_cols = np.flatnonzero(rest.any(axis=0))
        else:
            tau_pairs = None
            tau_cols = np.zeros(0, dtype=np.int64)

        # Graph delta: symmetric difference of edge keys + weight changes on
        # common edges + brand-new vertices.
        if self.graph is old.graph:
            struct = np.zeros(0, dtype=np.int64)
        else:
            N = np.int64(max(n_new, 1))
            eo, en = old.graph.edges, self.graph.edges
            ko = (eo[:, 0].astype(np.int64) * N + eo[:, 1]
                  if len(eo) else np.zeros(0, np.int64))
            kn = (en[:, 0].astype(np.int64) * N + en[:, 1]
                  if len(en) else np.zeros(0, np.int64))
            only_o = ~np.isin(ko, kn)
            only_n = ~np.isin(kn, ko)
            touched = [eo[only_o].ravel(), en[only_n].ravel(),
                       np.arange(n_old, n_new, dtype=np.int64)]
            if (old.graph.edge_weights is not None
                    or self.graph.edge_weights is not None):
                wo = old.graph.weights_or_ones().astype(np.float64)
                wn = self.graph.weights_or_ones().astype(np.float64)
                so, sn = np.argsort(ko, kind="stable"), np.argsort(
                    kn, kind="stable")
                cko, ckn = ko[so], kn[sn]
                if len(cko) and len(ckn):
                    pos = np.minimum(np.searchsorted(cko, ckn), len(cko) - 1)
                    changed = (cko[pos] == ckn) & (wo[so][pos] != wn[sn])
                    touched.append(en[sn[changed]].ravel())
            struct = np.unique(np.concatenate(touched)).astype(np.int64)

        return ModelDiff(
            unary_rows=np.asarray(unary_rows, dtype=np.int64),
            servers=np.array(sorted(servers), dtype=np.int64),
            tau_pairs=tau_pairs,
            tau_cols=np.asarray(tau_cols, dtype=np.int64),
            struct_vertices=struct)

    def marginal_fp(self, subset: np.ndarray, v: int) -> float:
        """Paper's F_P(X, v) under auxiliary-graph accounting (Thm 3, Eq. 14):
        the *new* aggregation work is over N_v \\ A(X), where
        A(X) = X  ∪  (union of neighbors of X).  Submodular in X — the
        property test checks F_P(X,v) >= F_P(Y,v) for X ⊆ Y."""
        in_aux = subset.copy()
        for u in np.where(subset)[0]:
            in_aux[self.graph.neighbors(u)] = True
        new = [u for u in self.graph.neighbors(v) if not in_aux[u]]
        # Use mean C_P(u, ·) — server-independent comparison is what Thm 3 uses
        # (the newly added vertex goes to the *same* server for X and Y).
        return float(self.cp_matrix[new, 0].sum()) if new else 0.0

    # ------------------------------------------------------------ replication
    def _replica_savings(self, assign: np.ndarray):
        """Per-candidate saved read traffic, keyed ``v * m + p``.

        Directed-read split of C_T: each cut link's tau * w prices two
        directed reads (either endpoint's host pulling the other's row once
        per BSP round), half the link cost each.  Replicating v into a
        consumer part p serves p's reads of v locally, saving
        ``0.5 * tau[home_v, p] * W(v, p)`` where W(v, p) sums the weights of
        v's cut links into p.  Returns (keys sorted ascending, savings)."""
        e = self.graph.edges
        m = np.int64(self.net.m)
        if not len(e):
            return np.zeros(0, np.int64), np.zeros(0, np.float64)
        a_u, a_v = assign[e[:, 0]], assign[e[:, 1]]
        cut = a_u != a_v
        if not cut.any():
            return np.zeros(0, np.int64), np.zeros(0, np.float64)
        w = self.graph.weights_or_ones()[cut]
        u, v = e[cut, 0], e[cut, 1]
        au, av = a_u[cut], a_v[cut]
        half = 0.5 * self.net.tau[au, av] * w
        keys = np.concatenate([u * m + av, v * m + au])
        vals = np.concatenate([half, half])
        uniq, inv = np.unique(keys, return_inverse=True)
        saved = np.zeros(len(uniq), dtype=np.float64)
        np.add.at(saved, inv, vals)
        return uniq, saved

    def replicate_greedy(self, assign: np.ndarray, sync_weight: float = 0.5,
                         storage: float = 0.0,
                         budget: "int | None" = None) -> Replication:
        """Accept every replica placement with positive gain on top of the
        given cut (paper Sec. III-B extended with Fograph-style inference
        replication).

        Replicating v into consumer p is a unary decision given the cut:
        gain(v, p) = 0.5 * tau[home, p] * W(v, p)
                     - (sync_weight * tau[home, p] + storage).
        Candidates are independent, so accepting all positive gains is the
        exact optimum of the overlay; ``budget`` caps replicas per part
        (keep the top gains, vertex-id tie break — deterministic)."""
        assign = np.asarray(assign, dtype=np.int64)
        m = np.int64(self.net.m)
        keys, saved = self._replica_savings(assign)
        vs = (keys // m).astype(np.int64)
        ps = (keys % m).astype(np.int64)
        cost = sync_weight * self.net.tau[assign[vs], ps] + storage
        gain = saved - cost
        keep = gain > 1e-12
        vs, ps, gain = vs[keep], ps[keep], gain[keep]
        by_part: Dict[int, np.ndarray] = {}
        for p in np.unique(ps):
            sel = ps == p
            ids, g = vs[sel], gain[sel]
            if budget is not None and len(ids) > budget:
                top = np.lexsort((ids, -g))[:budget]
                ids, g = ids[top], g[top]
            by_part[int(p)] = np.sort(ids)
        repl = Replication(by_part=by_part, gain=0.0, saved=0.0, sync=0.0,
                           storage=0.0, sync_weight=float(sync_weight),
                           storage_cost=float(storage))
        acc = self.replication_cost(assign, repl)
        repl.saved, repl.sync = acc["saved"], acc["sync"]
        repl.storage, repl.gain = acc["storage"], -acc["net"]
        return repl

    def replication_cost(self, assign: np.ndarray,
                         repl: Replication) -> Dict[str, float]:
        """Exact accounting of a replication overlay on ``assign``:
        ``saved`` (read traffic served locally), ``sync``/``storage`` (the
        overlay's recurring charges), ``net`` = sync + storage - saved, and
        ``total`` = the layout objective with the overlay applied.  The
        greedy's own output always has ``net <= 0``."""
        assign = np.asarray(assign, dtype=np.int64)
        m = np.int64(self.net.m)
        keys, saved_all = self._replica_savings(assign)
        saved = sync = 0.0
        count = 0
        for p, ids in sorted(repl.by_part.items()):
            ids = np.asarray(ids, dtype=np.int64)
            ids = ids[assign[ids] != p]       # a home-resident needs no copy
            if not len(ids):
                continue
            k = ids * m + p
            if len(keys):
                pos = np.minimum(np.searchsorted(keys, k), len(keys) - 1)
                match = keys[pos] == k
                saved += float(saved_all[pos[match]].sum())
            sync += float(
                (repl.sync_weight * self.net.tau[assign[ids], p]).sum())
            count += len(ids)
        storage = repl.storage_cost * count
        net = sync + storage - saved
        return {"saved": saved, "sync": sync, "storage": storage,
                "net": net, "total": self.total(assign) + net}


class LayoutState:
    """Cached evaluation state of one assignment under one CostModel.

    Holds the per-vertex unary picks ``unary[v, assign[v]]`` and the
    per-edge quadratic contributions ``tau[a_u, a_v] * w_uv`` so that the
    cost change of moving a vertex subset is computable in
    O(|moved| + incident links) — the accept path of the layout engine
    never re-evaluates the full O(n+m) objective.

    Invariant: ``total == unary_pick.sum() + edge_ct.sum() + cm.constant``
    (Thm 2's C1 + C2 + C0), kept exact by routing every mutation through
    :meth:`commit`.

    ``on_commit`` (optional): callback ``(moved, old_servers)`` invoked
    after EVERY applied mutation, with the movers and the servers they
    left.  The layout engine registers its dirty/epoch bookkeeping here so
    that commits arriving through this API directly (fault-runtime warm
    restarts, externally-imposed churn) keep its assembly cache and
    warm-start residual state coherent — not just commits routed through
    the engine's own accept path.
    """

    def __init__(self, cm: CostModel, assign: np.ndarray):
        self.cm = cm
        self.on_commit = None
        self.assign = np.array(assign, dtype=np.int64)      # owned copy
        g = cm.graph
        if self.assign.shape != (g.n,):
            raise ValueError(
                f"assign shape {self.assign.shape} != ({g.n},)")
        idx = np.arange(g.n)
        self.unary_pick = cm.unary[idx, self.assign].astype(np.float64)
        e = g.edges
        self._w = g.weights_or_ones().astype(np.float64)
        if len(e):
            self.edge_ct = (
                cm.net.tau[self.assign[e[:, 0]], self.assign[e[:, 1]]]
                * self._w)
        else:
            self.edge_ct = np.zeros(0, dtype=np.float64)
        self.total = float(
            self.unary_pick.sum() + self.edge_ct.sum() + cm.constant)
        # Proposal overlay: kept identical to ``assign`` between calls so
        # delta() can evaluate incident edges against the proposed layout
        # without copying the full vector.
        self._overlay = self.assign.copy()
        self._pending = None

    # ------------------------------------------------------------- delta API
    def _delta_parts(self, moved: np.ndarray, new_servers: np.ndarray):
        cm, g = self.cm, self.cm.graph
        du = float(
            cm.unary[moved, new_servers].sum()
            - self.unary_pick[moved].sum())
        eids = g.incident_edge_ids(moved)
        if len(eids) == 0:
            return du, eids, np.zeros(0, dtype=np.float64)
        overlay = self._overlay
        overlay[moved] = new_servers
        e = g.edges
        new_ct = (
            cm.net.tau[overlay[e[eids, 0]], overlay[e[eids, 1]]]
            * self._w[eids])
        overlay[moved] = self.assign[moved]                  # restore
        return du + float(new_ct.sum() - self.edge_ct[eids].sum()), eids, new_ct

    def delta(self, moved: np.ndarray, new_servers: np.ndarray) -> float:
        """Exact cost change of re-assigning ``moved[k] -> new_servers[k]``
        (everything else fixed), in O(|moved| + incident links)."""
        moved = np.asarray(moved, dtype=np.int64)
        new_servers = np.asarray(new_servers, dtype=np.int64)
        d, _, _ = self._delta_parts(moved, new_servers)
        return d

    def commit(self, moved: np.ndarray, new_servers: np.ndarray) -> float:
        """Apply the move, updating all caches incrementally; returns the
        (exact) delta that was applied."""
        moved = np.asarray(moved, dtype=np.int64)
        new_servers = np.asarray(new_servers, dtype=np.int64)
        return self._apply(moved, new_servers,
                           self._delta_parts(moved, new_servers))

    def propose(self, moved: np.ndarray, new_servers: np.ndarray) -> float:
        """Like :meth:`delta`, but keeps the computed parts so an immediate
        :meth:`commit_pending` applies them without re-evaluation (the
        engine's accept path: one delta pass per accepted move)."""
        moved = np.asarray(moved, dtype=np.int64)
        new_servers = np.asarray(new_servers, dtype=np.int64)
        parts = self._delta_parts(moved, new_servers)
        self._pending = (moved, new_servers, parts)
        return parts[0]

    def commit_pending(self) -> float:
        if self._pending is None:
            raise RuntimeError("no pending proposal: call propose() first")
        moved, new_servers, parts = self._pending
        return self._apply(moved, new_servers, parts)

    def discard_pending(self) -> None:
        """Drop a rejected proposal so a later commit_pending() cannot
        apply stale parts."""
        self._pending = None

    def _apply(self, moved, new_servers, parts) -> float:
        # Any commit invalidates an outstanding proposal (its cached edge
        # contributions were computed against the pre-commit layout).
        self._pending = None
        d, eids, new_ct = parts
        old_servers = self.assign[moved].copy()
        self.assign[moved] = new_servers
        self._overlay[moved] = new_servers
        self.unary_pick[moved] = self.cm.unary[moved, new_servers]
        if len(eids):
            self.edge_ct[eids] = new_ct
        self.total += d
        if self.on_commit is not None:
            self.on_commit(moved, old_servers)
        return d

    def factors(self) -> Dict[str, float]:
        """Full factor breakdown of the current assignment (O(n+m); for
        reporting — never on the per-iteration accept path)."""
        return self.cm.factors(self.assign)
