"""GLAD-E: incremental layout optimization for evolved graphs (paper Alg. 2).

Only the vertices that can *increase* cost — newly inserted ones and those
with fresh cross-server links — are re-optimized; everything else keeps its
slot (no migration, no service interruption).  Implemented by running GLAD-S
with an ``active`` mask so the frozen layout contributes exact side-effect
terms to every auxiliary cut (a boundary-aware refinement of the paper's
"extract G+ and call GLAD-S" description; noted in DESIGN.md §7).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.cost import CostModel
from repro.core.evolution import changed_vertices
from repro.core.engine import LayoutSession
from repro.core.glad_s import GladResult, glad_s
from repro.graphs.datagraph import DataGraph

#: Churn-measured escalation policy (``multilevel='auto'``): escalate to
#: the V-cycle iff its estimated cost undercuts the masked incremental
#: sweep's.  Both scale ~linearly in the vertices they touch — the sweep
#: in churned vertices (plus their boundary rings), the V-cycle in ALL
#: vertices — so the decision reduces to a break-even churn fraction:
#: escalate iff measured churn > (V-cycle per-vertex cost) / (incremental
#: per-vertex cost).  A fresh coarsen+solve+refine pass costs about twice
#: an incremental sweep per touched vertex, putting the fresh break-even
#: at 0.5 — exactly the pre-existing ``active.mean() > 0.5`` heuristic,
#: now derived instead of guessed.
MULTILEVEL_ESCALATE_FRESH = 0.5
#: With a valid persistent LevelStack (session carries a hierarchy built
#: over this same graph) the escalation skips matching + contraction and
#: only rebuilds coarse cost models, roughly halving the V-cycle's
#: per-vertex cost — the break-even churn drops with it.
MULTILEVEL_ESCALATE_STACKED = 0.25


def seed_new_vertices(
    cm: CostModel, assign: np.ndarray, new_mask: np.ndarray
) -> np.ndarray:
    """Greedy-marginal initial placement for vertices with no slot yet.

    Sequential over new vertices (each placement feeds the next one's
    marginal), vectorized over servers x placed neighbors via
    :meth:`CostModel.marginal_all`."""
    assign = assign.copy()
    placed = ~new_mask
    for v in np.where(new_mask)[0]:
        assign[v] = int(np.argmin(cm.marginal_all(placed, assign, int(v))))
        placed[v] = True
    return assign


def glad_e(
    cm_new: CostModel,
    old_graph: DataGraph,
    assign_old: np.ndarray,
    R: Optional[int] = None,
    seed: int = 0,
    backend: str = "auto",
    sweep: str = "batched",
    workers: int = 0,
    cache: "bool | str" = "auto",
    chunk_nodes: "int | str" = "auto",
    warm: "bool | str" = "auto",
    multilevel: "bool | str" = False,
    coarsen_to: int = 1024,
    levels: Optional[int] = None,
    chunk_vertices: "int | str | None" = None,
    replicate: "bool | dict" = False,
    session: Optional[LayoutSession] = None,
) -> GladResult:
    """Args:
      cm_new: cost model bound to the *evolved* graph G(t).
      old_graph / assign_old: G(t-1) and its layout pi(t-1).
      sweep: GLAD-S sweep discipline — incremental relayout defaults to the
        batched disjoint-pair rounds (block-diagonal round solver), since
        the changed-vertex filter wants wall time, not the Alg.-1 order.
      workers / cache / chunk_nodes / warm: engine knobs, passed through to
        :func:`glad_s` (assembly caching, chunked/parallel block solves,
        warm-started incremental re-solves).  GLAD-E's active-mask workload
        is exactly the regime both 'auto' policies enable themselves for.
      multilevel / coarsen_to / levels / chunk_vertices: escalation to
        the multilevel V-cycle when the churn is too large for the
        incremental path to pay: with ``multilevel=True`` — or 'auto' and
        measured churn above the break-even fraction
        (:data:`MULTILEVEL_ESCALATE_FRESH`, dropping to
        :data:`MULTILEVEL_ESCALATE_STACKED` when the session holds a
        still-valid LevelStack for this graph) — the masked refinement is
        replaced by a full coarsen/solve/refine V-cycle warm-started from
        the carried-over layout — a massively-evolved graph is a fresh
        layout problem, and the V-cycle is the fast full solver.
        ``chunk_vertices`` streams the escalation's coarsening in bounded
        vertex windows.  Default False keeps the masked incremental path
        (bit-identical to previous behavior).
      replicate: move-vs-replicate overlay, forwarded to :func:`glad_s` —
        re-greedied after each accepted round of the refinement and
        attached to the result (``result.replication``).  A post-pass:
        the evolved layout itself is bit-identical with the knob off.
      session: optional :class:`~repro.core.engine.LayoutSession` carrying
        engine state (assembly cache + warm residuals) and the persistent
        LevelStack hierarchy across slots.  The masked incremental
        refinement adopts its engine; a multilevel escalation threads it
        through so the V-cycle refreshes the session's LevelStack (and
        its finest refinement adopts the engine) instead of coarsening
        from scratch.  Only the no-change early exit leaves the session
        untouched.  Trajectories are bit-identical with or without a
        session.

    The result's ``moved`` is the relayout's move delta RELATIVE TO the
    carried-over old layout — net movers plus every newly-inserted vertex —
    i.e. exactly the set :func:`repro.gnn.distributed.patch_plan` needs to
    patch a live ShardPlan after the incremental relayout.
    """
    new_graph = cm_new.graph
    active = changed_vertices(old_graph, new_graph, assign_old)

    # Carry forward the old layout; pad and seed newly-inserted vertices.
    assign = np.zeros(new_graph.n, dtype=np.int64)
    keep = min(old_graph.n, new_graph.n)
    assign[:keep] = assign_old[:keep]
    new_ids = np.arange(old_graph.n, new_graph.n, dtype=np.int64)
    if new_graph.n > old_graph.n:
        new_mask = np.zeros(new_graph.n, dtype=bool)
        new_mask[old_graph.n:] = True
        assign = seed_new_vertices(cm_new, assign, new_mask)

    if not active.any():
        from repro.core.glad_s import _attach_replication
        f = cm_new.factors(assign)
        return _attach_replication(
            cm_new,
            GladResult(assign, f["total"], [f["total"]], 0, 0, 0.0, f,
                       moved=new_ids),
            replicate)

    # Churn-triggered escalation: when (almost) everything changed, the
    # masked incremental refinement degenerates into a flat full sweep —
    # hand the problem to the V-cycle instead, warm-started from the
    # carried layout (the mask is dropped; the V-cycle refines boundaries
    # at every level, a superset of the changed set's effect).  The
    # break-even churn is cost-measured: cheaper V-cycles (a session
    # whose LevelStack is still valid for this graph skips the coarsening
    # work) escalate earlier.  Evolution normally changes the graph and so
    # invalidates the stack — the stacked threshold engages on relayouts
    # of an UNCHANGED graph (fault-runtime degrades/stragglers).
    if multilevel == "auto":
        churn = float(active.mean())
        stacked = session is not None and session.stack_valid_for(
            cm_new, coarsen_to=coarsen_to, max_levels=levels)
        multilevel = churn > (MULTILEVEL_ESCALATE_STACKED if stacked
                              else MULTILEVEL_ESCALATE_FRESH)
    if multilevel:
        res = glad_s(
            cm_new, R=R, init=assign, seed=seed, backend=backend,
            workers=workers, cache=cache, chunk_nodes=chunk_nodes,
            warm=warm, multilevel=True, coarsen_to=coarsen_to,
            levels=levels, chunk_vertices=chunk_vertices,
            replicate=replicate, session=session,
        )
        res.moved = (np.union1d(res.moved, new_ids) if len(new_ids)
                     else res.moved)
        return res

    # R defaults small for incremental updates (the filtered set is small).
    if R is None:
        R = max(3, cm_new.net.m)
    res = glad_s(
        cm_new, R=R, init=assign, active=active, seed=seed, backend=backend,
        sweep=sweep, workers=workers, cache=cache, chunk_nodes=chunk_nodes,
        warm=warm, replicate=replicate, session=session,
    )
    # glad_s diffs against the seeded init; fold the insertions back in.
    res.moved = np.union1d(res.moved, new_ids) if len(new_ids) else res.moved
    return res
