from repro.core.cost import (
    CostModel, GNNWorkload, LayoutState, Replication, workload_for,
)
from repro.core.engine import PairCutEngine, round_robin_rounds
from repro.core.glad_s import GladResult, glad_s, solve_pair
from repro.core.glad_e import glad_e
from repro.core.glad_a import GladA, drift_bound
from repro.core.baselines import greedy_layout, random_layout, uploading_first_layout
from repro.core.evolution import (
    GraphDelta, apply_delta, changed_vertices, evolution_trace, sample_delta,
)
from repro.core.partition import (
    DevicePartition, data_partition, expert_layout, partition_from_assign,
    rebalance,
)

__all__ = [
    "CostModel", "GNNWorkload", "LayoutState", "Replication", "workload_for",
    "PairCutEngine", "round_robin_rounds",
    "GladResult", "glad_s", "solve_pair", "glad_e", "GladA", "drift_bound",
    "greedy_layout", "random_layout", "uploading_first_layout",
    "GraphDelta", "apply_delta", "changed_vertices", "evolution_trace",
    "sample_delta",
    "DevicePartition", "data_partition", "expert_layout",
    "partition_from_assign", "rebalance",
]
