"""Minimum s-t cut solvers.

GLAD-S settles each server pair by a min s-t cut on an auxiliary graph
(paper Sec. IV-B; solver reference [101] Orlin O(nm)).  Two backends:

  * 'scipy'  — scipy.sparse.csgraph.maximum_flow (C implementation of
               Dinic/BFS).  scipy requires integer capacities, so float
               weights are scaled to int64 with a fixed resolution; the cut
               *partition* is exact as long as weight gaps exceed 1/SCALE.
  * 'dinic'  — pure-python Dinic with float capacities (exact, slower);
               used as fallback and as the oracle in tests.

Both return the source-side membership mask, from which GLAD's Eq. (15)
mapping derives the layout.
"""
from __future__ import annotations

from collections import deque
from typing import Tuple

import numpy as np

try:
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import maximum_flow as _scipy_maxflow

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False

_SCALE = 10 ** 7  # float -> int64 capacity resolution for the scipy backend


class Dinic:
    """Textbook Dinic max-flow with adjacency arrays (float capacities)."""

    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[float] = []
        self.head: list[list[int]] = [[] for _ in range(n)]

    def add_edge(self, u: int, v: int, cap_uv: float, cap_vu: float = 0.0):
        self.head[u].append(len(self.to)); self.to.append(v); self.cap.append(cap_uv)
        self.head[v].append(len(self.to)); self.to.append(u); self.cap.append(cap_vu)

    def _bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and self.level[v] < 0:
                    self.level[v] = self.level[u] + 1
                    q.append(v)
        return self.level[t] >= 0

    def _dfs(self, u: int, t: int, f: float, it: list[int]) -> float:
        if u == t:
            return f
        while it[u] < len(self.head[u]):
            eid = self.head[u][it[u]]
            v = self.to[eid]
            if self.cap[eid] > 1e-12 and self.level[v] == self.level[u] + 1:
                d = self._dfs(v, t, min(f, self.cap[eid]), it)
                if d > 1e-12:
                    self.cap[eid] -= d
                    self.cap[eid ^ 1] += d
                    return d
            it[u] += 1
        return 0.0

    def max_flow(self, s: int, t: int) -> float:
        flow = 0.0
        while self._bfs(s, t):
            it = [0] * self.n
            while True:
                f = self._dfs(s, t, float("inf"), it)
                if f <= 1e-12:
                    break
                flow += f
        return flow

    def min_cut_side(self, s: int) -> np.ndarray:
        """Source-side reachability in the residual graph (call after max_flow)."""
        side = np.zeros(self.n, dtype=bool)
        side[s] = True
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and not side[v]:
                    side[v] = True
                    q.append(v)
        return side


def min_st_cut(
    n: int,
    s: int,
    t: int,
    edges_u: np.ndarray,
    edges_v: np.ndarray,
    caps_uv: np.ndarray,
    caps_vu: np.ndarray,
    backend: str = "auto",
) -> Tuple[float, np.ndarray]:
    """Solve min s-t cut on a directed-capacity graph.

    Args:
      n: node count (s, t included).
      edges_u/v: endpoints; caps_uv/vu: directed capacities per edge row.
      backend: 'scipy' | 'dinic' | 'auto'.

    Returns:
      (cut_value, source_side_mask) with mask[s]=True, mask[t]=False.
    """
    edges_u = np.asarray(edges_u, dtype=np.int64)
    edges_v = np.asarray(edges_v, dtype=np.int64)
    caps_uv = np.asarray(caps_uv, dtype=np.float64)
    caps_vu = np.asarray(caps_vu, dtype=np.float64)
    if backend == "auto":
        backend = "scipy" if _HAVE_SCIPY else "dinic"

    if backend == "scipy":
        # Merge parallel directed edges; scale to int64.  The scale adapts
        # to the largest capacity so huge costs (e.g. congestion-priced
        # layouts) cannot overflow: resolution is relative, and the cut
        # PARTITION is exact as long as gaps exceed max_cap/_SCALE.
        u = np.concatenate([edges_u, edges_v])
        v = np.concatenate([edges_v, edges_u])
        c = np.concatenate([caps_uv, caps_vu])
        keep = c > 0
        u, v, c = u[keep], v[keep], c[keep]
        cmax = float(c.max()) if len(c) else 1.0
        scale = _SCALE / max(cmax, 1e-30)
        ci = np.round(c * scale).astype(np.int64)
        ci = np.maximum(ci, 0)
        mat = csr_matrix((ci, (u, v)), shape=(n, n))
        mat.sum_duplicates()
        res = _scipy_maxflow(mat, s, t)
        flow = res.flow  # antisymmetric flow matrix (csr)
        residual = mat - flow
        # BFS from s over strictly-positive residual capacity.
        side = np.zeros(n, dtype=bool)
        side[s] = True
        q = deque([s])
        indptr, indices, data = residual.indptr, residual.indices, residual.data
        while q:
            x = q.popleft()
            for k in range(indptr[x], indptr[x + 1]):
                y = indices[k]
                if data[k] > 0 and not side[y]:
                    side[y] = True
                    q.append(y)
        return res.flow_value / scale, side

    dinic = Dinic(n)
    for u, v, cuv, cvu in zip(edges_u, edges_v, caps_uv, caps_vu):
        dinic.add_edge(int(u), int(v), float(cuv), float(cvu))
    val = dinic.max_flow(s, t)
    return val, dinic.min_cut_side(s)
