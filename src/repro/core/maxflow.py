"""Minimum s-t cut solvers.

GLAD-S settles each server pair by a min s-t cut on an auxiliary graph
(paper Sec. IV-B; solver reference [101] Orlin O(nm)).  Two backends:

  * 'scipy'  — scipy.sparse.csgraph.maximum_flow (C implementation of
               Dinic/BFS).  scipy requires integer capacities, so float
               weights are scaled to int64 with a fixed resolution; the cut
               *partition* is exact as long as weight gaps exceed 1/SCALE.
  * 'dinic'  — pure-python Dinic with float capacities (exact, slower);
               used as fallback and as the oracle in tests.

Both return the source-side membership mask, from which GLAD's Eq. (15)
mapping derives the layout.

Round-level solving: a round-robin round of GLAD's batched sweep yields a
set of vertex-disjoint auxiliary graphs (one per disjoint server pair).
:func:`min_st_cut_csr_blocks` solves them all in ONE flow pass by gluing
the blocks at a shared source/sink — the union network decomposes into
per-block flows (every s-t path stays inside one block), so the residual
reachability from the shared source restricted to block b is exactly
block b's minimal min cut.  The scipy BFS/DFS therefore never crosses a
block boundary, and no super-arc capacity bounds (which would cost integer
resolution) are needed.  Without scipy the blocks fall back to independent
pure-python Dinic solves, optionally fanned out over a thread/process pool
(:func:`min_st_cut_many`).
"""
from __future__ import annotations

from collections import deque
from typing import List, Sequence, Tuple

import numpy as np

try:
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import maximum_flow as _scipy_maxflow

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False

_SCALE = 10 ** 7  # float -> int64 capacity resolution for the scipy backend


def _pow2_at_least(size: int) -> int:
    return 1 << int(np.ceil(np.log2(max(size, 1))))


class CutArena:
    """Reusable scratch buffers for repeated min-cut solves.

    The layout engine solves tens of thousands of small cuts per sweep; the
    per-call assembly of the merged directed edge list is served from one
    geometrically-grown arena instead of four fresh allocations per call.
    Pass the same instance to every :func:`min_st_cut` of a sweep.

    Capacity growth is MONOTONE: a request smaller than an earlier one
    returns views of the existing buffers, and a regrowth never allocates
    below the current capacity — rounds of differing dirty-pair counts
    (large round, small round, large round again) reuse one allocation.
    """

    def __init__(self):
        self._cap = 0
        self._u = self._v = self._c = self._ci = None
        # Flow-CSR scratch (block-diagonal round assembly): row pointers +
        # column/capacity arrays, grown independently of the edge buffers.
        self._rows_cap = 0
        self._nnz_cap = 0
        self._indptr = self._cols = self._caps = None

    def edge_buffers(self, size: int):
        """(u, v, c, ci) views of length ``size`` (int64/int64/f64/int64)."""
        if self._u is None or size > self._cap:
            cap = max(256, self._cap, _pow2_at_least(size))
            self._u = np.empty(cap, dtype=np.int64)
            self._v = np.empty(cap, dtype=np.int64)
            self._c = np.empty(cap, dtype=np.float64)
            self._ci = np.empty(cap, dtype=np.int64)
            self._cap = cap
        return (self._u[:size], self._v[:size], self._c[:size],
                self._ci[:size])

    def flow_csr_buffers(self, n_rows: int, nnz: int):
        """(indptr, cols, caps) views for a flow CSR with ``n_rows`` row
        pointers and ``nnz`` entries (int32/int32/f64).  Contents are
        uninitialized; ``caps`` may be clobbered by the solver."""
        if self._indptr is None or n_rows > self._rows_cap:
            self._rows_cap = max(256, self._rows_cap, _pow2_at_least(n_rows))
            self._indptr = np.empty(self._rows_cap, dtype=np.int32)
        if self._cols is None or nnz > self._nnz_cap:
            self._nnz_cap = max(1024, self._nnz_cap, _pow2_at_least(nnz))
            self._cols = np.empty(self._nnz_cap, dtype=np.int32)
            self._caps = np.empty(self._nnz_cap, dtype=np.float64)
        return (self._indptr[:n_rows], self._cols[:nnz], self._caps[:nnz])


class Dinic:
    """Textbook Dinic max-flow with adjacency arrays (float capacities)."""

    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[float] = []
        self.head: list[list[int]] = [[] for _ in range(n)]

    def add_edge(self, u: int, v: int, cap_uv: float, cap_vu: float = 0.0):
        self.head[u].append(len(self.to))
        self.to.append(v)
        self.cap.append(cap_uv)
        self.head[v].append(len(self.to))
        self.to.append(u)
        self.cap.append(cap_vu)

    def _bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and self.level[v] < 0:
                    self.level[v] = self.level[u] + 1
                    q.append(v)
        return self.level[t] >= 0

    def _dfs(self, u: int, t: int, f: float, it: list[int]) -> float:
        if u == t:
            return f
        while it[u] < len(self.head[u]):
            eid = self.head[u][it[u]]
            v = self.to[eid]
            if self.cap[eid] > 1e-12 and self.level[v] == self.level[u] + 1:
                d = self._dfs(v, t, min(f, self.cap[eid]), it)
                if d > 1e-12:
                    self.cap[eid] -= d
                    self.cap[eid ^ 1] += d
                    return d
            it[u] += 1
        return 0.0

    def max_flow(self, s: int, t: int) -> float:
        flow = 0.0
        while self._bfs(s, t):
            it = [0] * self.n
            while True:
                f = self._dfs(s, t, float("inf"), it)
                if f <= 1e-12:
                    break
                flow += f
        return flow

    def min_cut_side(self, s: int) -> np.ndarray:
        """Source-side reachability in the residual graph (call after max_flow)."""
        side = np.zeros(self.n, dtype=bool)
        side[s] = True
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and not side[v]:
                    side[v] = True
                    q.append(v)
        return side


def _bfs_source_side(indptr, indices, data, n: int, s: int) -> np.ndarray:
    """Reachability from s over strictly-positive entries of a CSR graph.

    Frontier-vectorized BFS on raw CSR arrays: each level is one ragged
    multi-range gather, so the Python-loop count is the BFS depth
    (typically 2-4 for GLAD's auxiliary graphs), not the entry count.
    """
    from repro.graphs.datagraph import csr_multirange

    side = np.zeros(n, dtype=bool)
    side[s] = True
    frontier = np.array([s], dtype=np.int64)
    while len(frontier):
        flat, _ = csr_multirange(indptr, frontier)
        if len(flat) == 0:
            break
        nxt = indices[flat][data[flat] > 0]
        nxt = nxt[~side[nxt]]
        if len(nxt) == 0:
            break
        nxt = np.unique(nxt)
        side[nxt] = True
        frontier = nxt
    return side


def _residual_source_side(mat, flow, n: int, s: int) -> np.ndarray:
    """Source-side reachability of the min cut, via the residual graph."""
    residual = mat - flow
    return _bfs_source_side(residual.indptr, residual.indices,
                            residual.data, n, s)


def min_st_cut_csr(
    n: int,
    s: int,
    t: int,
    indptr: np.ndarray,
    indices: np.ndarray,
    caps: np.ndarray,
    prescaled: bool = False,
) -> Tuple[float, np.ndarray]:
    """Min s-t cut on a caller-built CSR capacity structure (scipy backend).

    Fast path for the layout engine: the auxiliary graph's CSR arrays are
    assembled directly (int32 indices, canonical order, no duplicates),
    skipping the COO round-trip, dtype upcasting and duplicate merging of
    the generic :func:`min_st_cut`.  When the structure is *symmetric*
    (every arc's reverse is present, zero-capacity reverse arcs included —
    the engine builds it this way), scipy's flow matrix shares the input's
    sparsity exactly, so the residual is a plain elementwise array
    difference — no sparse subtraction, no format conversions.

    ``caps`` is float64; capacities are scaled to int32 with relative
    resolution 1/_SCALE exactly like the generic path.  ``caps`` is
    clobbered (scaled in place) — pass a scratch array.  With
    ``prescaled=True`` the caps already hold exact integer values (the
    persistency-peel path quantizes before reducing) and are used verbatim.
    """
    if prescaled:
        scale = 1.0
    else:
        cmax = float(caps.max()) if len(caps) else 1.0
        scale = _SCALE / max(cmax, 1e-30)
        np.multiply(caps, scale, out=caps)
        np.rint(caps, out=caps)
        np.maximum(caps, 0, out=caps)
    data = caps.astype(np.int32)
    try:
        # The engine guarantees well-formed arrays; skip csr validation
        # (check_format + index-dtype sniffing are ~20% of small solves).
        mat = csr_matrix.__new__(csr_matrix)
        mat.data = data
        mat.indices = indices
        mat.indptr = indptr
        mat._shape = (n, n)
    except Exception:  # pragma: no cover - scipy internals drift
        mat = csr_matrix((data, indices, indptr), shape=(n, n))
    res = _scipy_maxflow(mat, s, t)
    flow = res.flow
    if (np.array_equal(flow.indptr, mat.indptr)
            and np.array_equal(flow.indices, mat.indices)):
        side = _bfs_source_side(mat.indptr, mat.indices,
                                mat.data - flow.data, n, s)
    else:  # pragma: no cover - asymmetric structure / scipy internals drift
        side = _residual_source_side(mat, flow, n, s)
    return res.flow_value / scale, side


def assemble_symmetric_flow_csr(
    k: int,
    int_a: np.ndarray,
    int_b: np.ndarray,
    int_w: np.ndarray,
    theta_i: np.ndarray,
    theta_j: np.ndarray,
    arena: "CutArena | None" = None,
    presorted: bool = False,
):
    """Build the symmetric-structure flow CSR of a GLAD auxiliary network.

    Nodes 0..k-1 are the (core) members, S=k, T=k+1.  ``int_a/int_b/int_w``
    hold the internal arcs with BOTH directions present (the CSR member
    gather emits each undirected link twice).  T-links: cap(S->v)=theta_j[v]
    (cut => v lands on the sink server), cap(v->T)=theta_i[v]; the reverse
    arcs (v->S, T->v) are materialized with zero capacity so every arc's
    transpose slot exists — scipy's flow matrix then shares this sparsity
    exactly and :func:`min_st_cut_csr`'s residual is a plain array
    difference.  The structure must also be CANONICAL (sorted column
    indices, no duplicates): internal arcs are lexsorted by (row, col), and
    each member row ends with ->S(=k), ->T(=k+1), which exceed every member
    column.  Works identically for a block-diagonal union of disjoint
    auxiliary graphs glued at the shared S/T: rows of different blocks never
    reference each other's columns, so per-block sorted order is global
    sorted order.

    ``presorted=True`` skips the canonicalizing lexsort: the layout engine
    guarantees it by construction (DataGraph rows are (src, dst)-sorted and
    member-local ids are rank-monotone, so gathered arcs arrive row-grouped
    with ascending columns).

    Returns ``(n, s, t, indptr, cols, caps)`` ready for
    :func:`min_st_cut_csr`.  With ``arena``, the output arrays are views of
    reused scratch (``caps`` is clobbered by the solve).
    """
    n_int = len(int_a)
    if n_int and not presorted:
        order = np.lexsort((int_b, int_a))
        int_a = int_a[order]
        int_b = int_b[order]
        int_w = np.asarray(int_w)[order]
    int_counts = np.bincount(int_a, minlength=k)
    nnz = n_int + 4 * k
    if arena is not None:
        aux_indptr, cols, caps = arena.flow_csr_buffers(k + 3, nnz)
    else:
        aux_indptr = np.empty(k + 3, dtype=np.int32)
        cols = np.empty(nnz, dtype=np.int32)
        caps = np.empty(nnz, dtype=np.float64)
    aux_indptr[0] = 0
    np.cumsum(int_counts + 2, out=aux_indptr[1:k + 1])
    aux_indptr[k + 1] = aux_indptr[k] + k        # S row
    aux_indptr[k + 2] = aux_indptr[k + 1] + k    # T row
    S, T = k, k + 1
    ar = np.arange(k)
    row_start = aux_indptr[:k].astype(np.int64)  # of member rows
    if n_int:
        # Within-row offsets of the (already grouped) internal arcs.
        excl = np.cumsum(int_counts) - int_counts
        pos = np.arange(n_int) - np.repeat(excl, int_counts) \
            + row_start[int_a]
        cols[pos] = int_b
        caps[pos] = int_w
    t_pos = row_start + int_counts
    cols[t_pos] = S
    caps[t_pos] = 0.0
    cols[t_pos + 1] = T
    caps[t_pos + 1] = theta_i
    cols[n_int + 2 * k:n_int + 3 * k] = ar
    caps[n_int + 2 * k:n_int + 3 * k] = theta_j
    cols[n_int + 3 * k:] = ar
    caps[n_int + 3 * k:] = 0.0
    return k + 2, S, T, aux_indptr, cols, caps


def concat_flow_blocks(blocks: Sequence[tuple]):
    """Concatenate per-block auxiliary problems into one block-diagonal one.

    ``blocks``: sequence of ``(k, int_a, int_b, int_w, theta_i, theta_j)``
    with block-local node ids (internal arcs both directions).  Returns
    ``(block_ptr, int_a, int_b, int_w, theta_i, theta_j)`` with GLOBAL node
    ids, where block b's nodes occupy ``block_ptr[b]:block_ptr[b+1]`` —
    the input format of :func:`min_st_cut_csr_blocks`.
    """
    sizes = np.array([b[0] for b in blocks], dtype=np.int64)
    block_ptr = np.zeros(len(blocks) + 1, dtype=np.int64)
    np.cumsum(sizes, out=block_ptr[1:])
    int_a = [np.asarray(b[1], np.int64) + off
             for b, off in zip(blocks, block_ptr[:-1])]
    int_b = [np.asarray(b[2], np.int64) + off
             for b, off in zip(blocks, block_ptr[:-1])]
    cat = lambda xs, dt: (np.concatenate(xs) if xs else np.zeros(0, dt))  # noqa: E731
    return (
        block_ptr,
        cat(int_a, np.int64),
        cat(int_b, np.int64),
        np.concatenate([np.broadcast_to(np.asarray(b[3], np.float64),
                                        (len(b[1]),)) for b in blocks])
        if blocks else np.zeros(0, np.float64),
        cat([np.asarray(b[4], np.float64) for b in blocks], np.float64),
        cat([np.asarray(b[5], np.float64) for b in blocks], np.float64),
    )


def peel_forced(
    k: int,
    int_a: np.ndarray,
    int_b: np.ndarray,
    w_int: np.ndarray,
    th_i: np.ndarray,
    th_j: np.ndarray,
    max_rounds: int = 100_000,
):
    """Persistency reduction of a (quantized) auxiliary cut problem.

    A node whose t-link gap strictly exceeds the total capacity of its live
    internal arcs takes its cheaper side in EVERY min cut (flipping it to
    the expensive side changes any cut by ``gap - capsum > 0``), so it can
    be settled before the flow solve and its arcs absorbed into the
    neighbors' t-links (an arc to a node fixed on the source side is paid
    exactly when the neighbor lands on the sink side, and vice versa) —
    the same argument iterated until a fixed point.  This is the
    singleton reduction's generalization (``capsum = 0``) and the standard
    roof-duality/QPBO persistency for s-t cuts; on GLAD auxiliary graphs
    (t-links carry unary + boundary mass, n-links only tau_ij) it retires
    the large majority of the connected core, which is what keeps the
    scipy input — and its O(nnz) per-call conversions — small.

    All arithmetic is integer (int64 via exact float64 bincounts), applied
    AFTER the 1/_SCALE quantization, so the surviving problem's min cuts
    are exactly the full quantized problem's min cuts restricted to the
    survivors; the minimal source side (what the residual BFS returns) is
    the reduced one union the nodes forced to the source.  Stopping early
    (``max_rounds``) only peels less — every prefix of the cascade is
    exact, because each forcing step's justification is invariant under
    the later ones (monotone closure: absorbing mass only ever grows
    t-link gaps relative to live capacity).  Mutates ``th_i/th_j`` in
    place.  ``int_a`` must be sorted (arcs row-grouped by tail — the
    canonical presorted order the callers already guarantee).

    Returns ``(alive, src)``: the survivor mask and the forced-to-source
    mask (disjoint; forced-to-sink is ``~alive & ~src``).
    """
    from repro.graphs.datagraph import csr_multirange

    alive = np.ones(k, dtype=bool)
    src = np.zeros(k, dtype=bool)
    # Arcs arrive row-grouped by tail (the canonical presorted order), so a
    # bincount + cumsum gives per-node arc slices; capsum is maintained
    # incrementally — the whole peel is O(k + arcs) total, frontier rounds
    # only touch the neighbors of freshly forced nodes.
    counts = np.bincount(int_a, minlength=k)
    aptr = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(counts, out=aptr[1:])
    capsum = np.bincount(int_a, weights=w_int, minlength=k).astype(np.int64)
    gap = th_j - th_i
    f_src = gap > capsum
    f_snk = -gap > capsum
    forced = np.flatnonzero(f_src | f_snk)
    src[forced] = f_src[forced]
    for _ in range(max_rounds):
        if len(forced) == 0:
            break
        alive[forced] = False
        # Absorb each dying node's arcs into its still-live neighbors: the
        # arc is cut exactly when the neighbor lands opposite the fixed
        # side.  Each undirected link has both directed copies, but only
        # the copy whose tail is the forced node is gathered here (the
        # reverse copy's tail is live), so it counts once.
        flat, _ = csr_multirange(aptr, forced)
        if len(flat) == 0:
            break
        head = int_b[flat]
        live = alive[head]
        if not live.any():
            break
        head = head[live]
        w = w_int[flat[live]]
        tail_src = src[int_a[flat[live]]]
        np.add.at(th_j, head[tail_src], w[tail_src])
        np.add.at(th_i, head[~tail_src], w[~tail_src])
        np.subtract.at(capsum, head, w)
        cand = np.unique(head)
        gap = th_j[cand] - th_i[cand]
        cs = capsum[cand]
        newly_src = gap > cs
        newly = newly_src | (-gap > cs)
        forced = cand[newly]
        src[forced] = newly_src[newly]
    return alive, src


#: Adaptive persistency-gate threshold: skip the peel when the initial
#: forced fraction of a (sub)problem falls below this — near convergence
#: almost everything survives and the peel's quantize/compact passes are
#: pure overhead.  Shared by the block solver and the warm-start router so
#: both make the same peel-vs-direct decision for the same problem.
PEEL_GATE_FRAC = 0.25


def peel_gate_fraction(k: int, int_a: np.ndarray, int_w: np.ndarray,
                       theta_i: np.ndarray, theta_j: np.ndarray) -> float:
    """Fraction of nodes the persistency peel would force IMMEDIATELY (one
    cheap float capsum pass — the first peel round, no cascade).  This is
    the adaptive gate's estimate: below :data:`PEEL_GATE_FRAC` the peel is
    skipped and the problem solved directly.  Scale-invariant per block
    (gap > capsum is preserved by any positive per-block rescaling)."""
    if k == 0:
        return 0.0
    capf = np.bincount(int_a, weights=int_w, minlength=k)
    gapf = np.abs(np.asarray(theta_j, np.float64)
                  - np.asarray(theta_i, np.float64))
    return float(np.count_nonzero(gapf > capf)) / k


class ResidualCut:
    """Warm-startable min s-t cut state over one fixed symmetric flow CSR.

    Retains the integer capacities and a maximum flow of the LAST solve of
    one auxiliary problem (fixed structure: same node count, same internal
    arcs — the engine's membership-intact regime).  A re-solve with
    perturbed capacities repairs the retained flow instead of pushing the
    whole flow again from zero:

      1. **re-quantize** the new float capacities exactly like
         :func:`min_st_cut_csr` (same cmax/scale/rint/clip op order), so the
         integer problem is the one the cold path would solve;
      2. **drain** over-saturated arcs: flow above the new capacity is
         cancelled along its own source->u and v->sink flow-carrying paths
         (integer arithmetic; the flow stays feasible and conservative, and
         flow cycles encountered on a walk are cancelled outright);
      3. **augment**: one scipy max-flow pass over the RESIDUAL network
         tops the repaired flow back up to maximal.  Near convergence the
         repaired flow is already maximal and the pass degenerates to a
         single BFS — this is where the warm start wins over re-pushing
         the full flow value.

    Exactness: the minimal source side of a min cut is UNIQUE for a given
    integer capacity vector (it is the residual reachability of ANY maximum
    flow — the lattice-minimum cut), so the warm mask is bit-identical to
    the cold path's for every perturbation sequence.  The differential fuzz
    harness (tests/test_warm_start.py) pins this against both the cold
    scipy path and the pure-python Dinic oracle.
    """

    __slots__ = ("k", "n", "s", "t", "indptr", "cols", "cap", "flow")

    #: Warm-repair gate: beyond this touched-entry fraction the drain +
    #: delta-augment repair stops beating a cold re-push, so ``resolve``
    #: resets the flow and re-solves from zero (same structure, no
    #: re-assembly) instead.
    WARM_GATE_FRAC = 0.25

    def __init__(self, k, n, s, t, indptr, cols, cap):
        self.k = int(k)
        self.n = int(n)
        self.s = int(s)
        self.t = int(t)
        self.indptr = np.ascontiguousarray(indptr)
        self.cols = np.ascontiguousarray(cols)
        self.cap = cap
        self.flow = np.zeros(len(cap), dtype=np.int64)

    @property
    def nbytes(self) -> int:
        return (self.indptr.nbytes + self.cols.nbytes
                + self.cap.nbytes + self.flow.nbytes)

    def _row_of(self, e: int) -> int:
        return int(np.searchsorted(self.indptr, e, side="right")) - 1

    def _rev_of(self, e: int, row_of_e: int) -> int:
        """Index of entry (v, u) given entry ``e`` = (u, v).  The structure
        is symmetric and canonical (columns ascending within each row), so
        the reverse entry is one binary search in row v — O(log deg) per
        LOOKED-UP arc, instead of an O(nnz log nnz) transpose permutation
        built eagerly at prime time (the drain only ever touches the
        handful of arcs on its cancellation paths)."""
        v = int(self.cols[e])
        lo, hi = int(self.indptr[v]), int(self.indptr[v + 1])
        return lo + int(np.searchsorted(self.cols[lo:hi], row_of_e))

    # -------------------------------------------------------------- internals
    @staticmethod
    def _quantize(caps: np.ndarray) -> np.ndarray:
        """Integer capacities with :func:`min_st_cut_csr`'s exact op order
        (multiply / rint / clip / int32 cast), widened for flow arithmetic.
        Clobbers ``caps``."""
        cmax = float(caps.max()) if len(caps) else 1.0
        scale = _SCALE / max(cmax, 1e-30)
        np.multiply(caps, scale, out=caps)
        np.rint(caps, out=caps)
        np.maximum(caps, 0, out=caps)
        return caps.astype(np.int32).astype(np.int64)

    @classmethod
    def prime(cls, k, int_a, int_b, int_w, theta_i, theta_j,
              prescaled: bool = False):
        """Cold solve that RETAINS its flow: assemble the symmetric CSR,
        quantize, push the max flow once, and return ``(side, state)``.
        ``side`` is bit-identical to the cold :func:`min_st_cut_csr` mask.
        Returns ``(side, None)`` if scipy's flow matrix stops sharing the
        input sparsity (internals drift) — the caller then stays cold.
        ``prescaled=True``: the inputs are already exact integers (the
        persistency-peel path quantizes before reducing) — use verbatim,
        exactly like :func:`min_st_cut_csr`'s prescaled path."""
        n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
            k, int_a, int_b, int_w, theta_i, theta_j, presorted=True)
        cap = (caps.astype(np.int32).astype(np.int64) if prescaled
               else cls._quantize(caps))
        rc = cls(k, n, s, t, indptr.copy(), cols.copy(), cap)
        side = rc._augment_and_mask()
        if side is None:                       # pragma: no cover - drift
            n2, s2, t2, ip, co, ca = assemble_symmetric_flow_csr(
                k, int_a, int_b, int_w, theta_i, theta_j, presorted=True)
            _, full = min_st_cut_csr(n2, s2, t2, ip, co, ca,
                                     prescaled=prescaled)
            return full[:k], None
        return side, rc

    def resolve(self, int_a, int_b, int_w, theta_i, theta_j,
                prescaled: bool = False):
        """Warm re-solve with perturbed capacities on the SAME structure.

        Returns ``(side, mode)`` where mode is ``'hit'`` (integer caps
        unchanged — mask-only), ``'warm'`` (drain + delta augment) or
        ``'cold'`` (touched fraction beyond :data:`WARM_GATE_FRAC` — flow
        reset and re-pushed, still without re-building the structure).
        ``side`` is bit-identical to a cold solve in every mode."""
        k = len(np.asarray(theta_i))
        n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
            k, int_a, int_b, int_w, theta_i, theta_j, presorted=True)
        # Full adjacency comparison, not just sizes: a same-degree member
        # swap preserves n and nnz but reorders columns, and applying the
        # new caps against the retained structure would return a silently
        # wrong mask.  O(nnz) — noise next to the assembly just done.
        if (n != self.n or len(cols) != len(self.cols)
                or not np.array_equal(cols, self.cols)
                or not np.array_equal(indptr, self.indptr)):
            raise ValueError("ResidualCut.resolve: structure changed — "
                             "re-prime instead")
        new_cap = (caps.astype(np.int32).astype(np.int64) if prescaled
                   else self._quantize(caps))
        touched = int(np.count_nonzero(new_cap != self.cap))
        self.cap = new_cap
        if touched == 0:
            # The retained flow is still a maximum flow of the identical
            # integer problem; only the mask BFS is needed.
            side = _bfs_source_side(self.indptr, self.cols,
                                    self.cap - self.flow, self.n, self.s)
            return side[:self.k], "hit"
        if touched > self.WARM_GATE_FRAC * len(new_cap):
            self.flow[:] = 0
            mode = "cold"
        else:
            self._drain()
            mode = "warm"
        side = self._augment_and_mask()
        if side is None:                       # pragma: no cover - drift
            raise RuntimeError("scipy flow sparsity drifted mid-resolve")
        return side, mode

    def _augment_and_mask(self):
        """Top the retained (feasible) flow up to maximal via one scipy
        pass over the residual network, then return the minimal-source-side
        mask over the first ``k`` nodes.  Residual capacities fit int32 by
        construction (cap <= _SCALE, |flow| <= _SCALE)."""
        res_caps = (self.cap - self.flow).astype(np.int32)
        try:
            mat = csr_matrix.__new__(csr_matrix)
            mat.data = res_caps
            mat.indices = self.cols
            mat.indptr = self.indptr
            mat._shape = (self.n, self.n)
        except Exception:  # pragma: no cover - scipy internals drift
            mat = csr_matrix((res_caps, self.cols, self.indptr),
                             shape=(self.n, self.n))
        res = _scipy_maxflow(mat, self.s, self.t)
        flow = res.flow
        if not (np.array_equal(flow.indptr, self.indptr)
                and np.array_equal(flow.indices, self.cols)):
            return None                        # pragma: no cover - drift
        self.flow += flow.data
        side = _bfs_source_side(self.indptr, self.cols,
                                self.cap - self.flow, self.n, self.s)
        return side[:self.k]

    def _drain(self):
        """Restore feasibility after capacity decreases: for every entry
        whose retained flow exceeds its new capacity, cancel the excess
        along the flow's own source->tail and head->sink paths (each
        reduction keeps the flow conservative and nonnegative; flow cycles
        met on a walk are cancelled outright, which only removes
        circulation)."""
        over = np.flatnonzero(self.flow > self.cap)
        for e in over:
            e = int(e)
            u, v = self._row_of(e), int(self.cols[e])
            while self.flow[e] > self.cap[e]:
                # The backward walk may run into v (a flow cycle through e
                # itself): seed it with v so that case cancels THROUGH e.
                back = self._flow_walk(u, self.s, incoming=True,
                                       e_entry=(e, u), cross=({v: 0}, []))
                if back is None:
                    continue                   # cancelled a cycle; retry
                carriers, nodes = back
                # The forward walk must not touch any backward-path node:
                # a shared node (hence any shared arc) closes a cycle
                # through e — cancel it instead of double-reducing the
                # shared arc below (which would drive its flow negative).
                fwd = self._flow_walk(v, self.t, incoming=False,
                                      e_entry=(e, u),
                                      cross=(nodes, carriers))
                if fwd is None:
                    continue
                fcarriers, _ = fwd
                # back + e + fwd is now a SIMPLE path (node-disjoint walks,
                # so every arc appears exactly once) — the uniform
                # reduction below keeps the flow conservative and >= 0.
                m = int(self.flow[e] - self.cap[e])
                for p, _ in carriers:
                    m = min(m, int(self.flow[p]))
                for p, _ in fcarriers:
                    m = min(m, int(self.flow[p]))
                for p, r in carriers + [(e, u)] + fcarriers:
                    self.flow[p] -= m
                    self.flow[self._rev_of(p, r)] += m

    def _cancel_cycle(self, cyc) -> None:
        """Cancel a directed flow cycle (pure circulation: removing it
        changes neither feasibility nor the flow value; when the cycle
        runs through the over-saturated entry it also reduces its
        excess).  Every cycle arc carries flow >= 1, so each cancellation
        zeroes at least one entry and retries terminate."""
        m = min(int(self.flow[p]) for p, _ in cyc)
        for p, r in cyc:
            self.flow[p] -= m
            self.flow[self._rev_of(p, r)] += m

    def _flow_walk(self, start: int, target: int, incoming: bool,
                   e_entry, cross):
        """Walk flow-carrying arcs from ``start`` to ``target`` (backward
        toward the source when ``incoming``, forward toward the sink
        otherwise).  Returns ``(carriers, nodes)``: the path's
        flow-carrying forward-direction entries as ``(entry, entry_row)``
        pairs plus the visited-node -> walk-index map; or None after
        cancelling a flow cycle found on the way (the caller retries).

        ``cross = (other_nodes, other_carriers)`` is the companion walk's
        node map and carrier prefix: stepping onto one of its nodes closes
        a directed cycle THROUGH the over-saturated entry ``e_entry``
        (other-prefix -> e -> own-path), which is cancelled outright —
        this is what keeps the final back + e + fwd composition a SIMPLE
        path in which no arc is reduced twice."""
        flow, cols, indptr = self.flow, self.cols, self.indptr
        path: list = []
        nodes = {start: 0}
        x = start
        while x != target:
            lo, hi = int(indptr[x]), int(indptr[x + 1])
            seg = flow[lo:hi]
            cand = np.flatnonzero(seg < 0 if incoming else seg > 0)
            # Conservation guarantees a flow-carrying arc exists at every
            # intermediate node of a flow path (start included: it carries
            # the over-saturated entry's flow).
            e2 = lo + int(cand[0])
            nxt = int(cols[e2])
            # The forward-direction entry actually carrying the flow: for
            # a backward step it is (nxt -> x), i.e. e2's reverse.
            carrier = (self._rev_of(e2, x), nxt) if incoming else (e2, x)
            if nxt in nodes:
                self._cancel_cycle(path[nodes[nxt]:] + [carrier])
                return None
            other_nodes, other_carriers = cross
            if nxt in other_nodes:
                self._cancel_cycle(other_carriers[:other_nodes[nxt]]
                                   + [e_entry] + path + [carrier])
                return None
            nodes[nxt] = len(path) + 1
            path.append(carrier)
            x = nxt
        return path, nodes


def peel_warm_solve(
    k: int,
    int_a: np.ndarray,
    int_b: np.ndarray,
    int_w: np.ndarray,
    theta_i: np.ndarray,
    theta_j: np.ndarray,
    residual: "ResidualCut | None" = None,
    residual_key: "np.ndarray | None" = None,
    allow_prime: bool = True,
):
    """Peel-composed warm start: quantize + persistency-peel one auxiliary
    problem exactly like the cold single-block path of
    :func:`min_st_cut_csr_blocks`, then warm-start the SURVIVOR flow solve
    from a :class:`ResidualCut` keyed by the forced set.

    The peel's forced set is a pure function of the quantized capacities,
    so when two successive solves of the same pair force the same nodes
    (the converged-but-peel-gated regime: theta perturbations small enough
    not to flip any persistency decision), the reduced problems share one
    structure and the retained residual repairs instead of re-pushing.
    ``residual_key`` is the alive mask the retained state was primed under;
    a mismatch re-primes (or solves cold when ``allow_prime`` is False).

    Returns ``(side, residual, residual_key, mode)`` with mode in
    ``'hit' | 'warm' | 'cold'``; ``side`` is bit-identical to the cold peel
    path for every input (minimal source side is unique per integer
    problem, and the peel composition is exact).
    """
    int_w = np.asarray(int_w, dtype=np.float64)
    cmax = max(float(theta_i.max()), float(theta_j.max()))
    if len(int_w):
        cmax = max(cmax, float(int_w.max()))
    scale = _SCALE / max(cmax, 1e-30)
    ti = np.maximum(np.rint(theta_i * scale), 0).astype(np.int64)
    tj = np.maximum(np.rint(theta_j * scale), 0).astype(np.int64)
    iw = np.maximum(np.rint(int_w * scale), 0).astype(np.int64)
    alive, src = peel_forced(k, int_a, int_b, iw, ti, tj)
    na = int(alive.sum())
    if na == 0:                                # peel settled every node
        return src, residual, residual_key, "cold"

    peak = max(int(ti[alive].max()), int(tj[alive].max()))
    if peak >= np.iinfo(np.int32).max:         # pragma: no cover
        # Absorbed t-links outgrew int32: solve the full quantized problem
        # (caps all <= _SCALE by construction); retained state unusable
        # this round but may match again once the spike passes.
        fti = np.maximum(np.rint(theta_i * scale), 0)
        ftj = np.maximum(np.rint(theta_j * scale), 0)
        fiw = np.maximum(np.rint(int_w * scale), 0)
        n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
            k, int_a, int_b, fiw, fti, ftj, presorted=True)
        _, side = min_st_cut_csr(n, s, t, indptr, cols, caps,
                                 prescaled=True)
        return side[:k], residual, residual_key, "cold"

    # Compact the survivors (order-preserving — canonical arc order holds).
    new_id = np.cumsum(alive, dtype=np.int64) - 1
    keep = alive[int_a] & alive[int_b]
    ria = new_id[int_a[keep]]
    rib = new_id[int_b[keep]]
    riw = iw[keep].astype(np.float64)
    rti = ti[alive].astype(np.float64)
    rtj = tj[alive].astype(np.float64)
    if (residual is not None and residual_key is not None
            and np.array_equal(residual_key, alive)):
        try:
            rside, mode = residual.resolve(ria, rib, riw, rti, rtj,
                                           prescaled=True)
        except ValueError:
            # Same forced set but the survivor structure drifted (internal
            # arcs changed under an unchanged peel) — fall through to
            # re-prime / cold below.
            residual, residual_key = None, None
        else:
            side = src.copy()
            side[alive] = rside
            return side, residual, residual_key, mode
    if not allow_prime:
        n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
            na, ria, rib, riw, rti, rtj, presorted=True)
        _, full_side = min_st_cut_csr(n, s, t, indptr, cols, caps,
                                      prescaled=True)
        side = src.copy()
        side[alive] = full_side[:na]
        return side, None, None, "cold"
    rside, rc = ResidualCut.prime(na, ria, rib, riw, rti, rtj,
                                  prescaled=True)
    side = src.copy()
    side[alive] = rside
    return side, rc, (alive.copy() if rc is not None else None), "cold"


def _chunk_block_spans(block_ptr: np.ndarray, chunk_nodes: int):
    """Greedily group consecutive blocks into chunks of <= ``chunk_nodes``
    nodes (a single block larger than the budget gets its own chunk).
    Returns a list of (block_lo, block_hi) index pairs into ``block_ptr``."""
    spans = []
    nb = len(block_ptr) - 1
    lo = 0
    while lo < nb:
        hi = lo + 1
        while (hi < nb
               and block_ptr[hi + 1] - block_ptr[lo] <= chunk_nodes):
            hi += 1
        spans.append((lo, hi))
        lo = hi
    return spans


def min_st_cut_csr_blocks(
    block_ptr: np.ndarray,
    int_a: np.ndarray,
    int_b: np.ndarray,
    int_w: np.ndarray,
    theta_i: np.ndarray,
    theta_j: np.ndarray,
    arena: "CutArena | None" = None,
    backend: str = "auto",
    workers: int = 0,
    worker_mode: str = "thread",
    presorted: bool = False,
    chunk_nodes: int = 0,
    peel_frac: "float | None" = None,
) -> np.ndarray:
    """Solve all blocks of a block-diagonal auxiliary flow problem at once.

    ``peel_frac``: the caller's precomputed :func:`peel_gate_fraction` for
    THESE inputs (single-block callers that already ran the gate pass it
    down so it is not recomputed).  The fraction is scale-invariant per
    block, so pre-normalization values are valid.

    Block b's nodes are the global ids ``block_ptr[b]:block_ptr[b+1]``;
    ``int_a/int_b/int_w`` are its internal arcs in global ids (both
    directions present), ``theta_i/theta_j`` the t-link capacities per node.
    Blocks share no arcs (vertex-disjoint server pairs), so the union glued
    at one shared source/sink decomposes exactly: one scipy max-flow pass
    solves every block, and the residual BFS from the shared source never
    crosses a block boundary.  Returns the concatenated source-side mask
    over all ``block_ptr[-1]`` nodes (True = source server of the node's
    own block).

    ``chunk_nodes > 0`` bounds the glued-union working set: consecutive
    blocks are grouped into chunks of at most that many nodes and each chunk
    is glued + solved separately (per-block integer quantization is
    unchanged, so the cut masks are bit-identical to the single glued
    pass).  This is what keeps large rounds cache-resident — one 50k-node
    union outgrows L2 and loses to per-pair solving, bounded chunks do not.
    With ``workers > 1`` the chunk solves are fanned out over a
    thread/process pool (:func:`min_st_cut_csr_many`); note scipy's
    ``maximum_flow`` holds the GIL, so thread mode only overlaps the numpy
    assembly work and process mode pays pickling — measure before enabling.

    Without scipy (or ``backend='dinic'``) the blocks are solved
    independently by the pure-python Dinic, fanned out over ``workers``
    threads/processes when ``workers > 1`` (:func:`min_st_cut_many`).
    """
    nc = int(block_ptr[-1])
    if nc == 0:
        return np.zeros(0, dtype=bool)
    if backend == "auto":
        backend = "scipy" if _HAVE_SCIPY else "dinic"
    if backend == "scipy":
        nb = len(block_ptr) - 1
        if nb > 1:
            # Normalize every block to its own capacity maximum before the
            # shared integer scaling: blocks are arc-disjoint, so a
            # per-block constant factor cannot change a block's cut
            # partition, but it keeps each block's full 1/_SCALE relative
            # resolution when magnitudes differ across the round (a block
            # 1e6x cheaper than the round's max would otherwise quantize
            # to noise).  This reproduces the per-pair path's quantization
            # exactly: caps become round(cap / cmax_block * _SCALE).
            node_blk = np.repeat(np.arange(nb), np.diff(block_ptr))
            bmax = np.zeros(nb, dtype=np.float64)
            np.maximum.at(bmax, node_blk, theta_i)
            np.maximum.at(bmax, node_blk, theta_j)
            arc_blk = None
            if len(int_a):
                arc_blk = node_blk[int_a]
                np.maximum.at(bmax, arc_blk, int_w)
            inv = 1.0 / np.maximum(bmax, 1e-30)
            theta_i = theta_i * inv[node_blk]
            theta_j = theta_j * inv[node_blk]
            if len(int_a):
                int_w = np.asarray(int_w) * inv[arc_blk]
        int_w = np.asarray(int_w, dtype=np.float64)
        if not presorted and len(int_a):
            order = np.lexsort((int_b, int_a))
            int_a, int_b = int_a[order], int_b[order]
            int_w = int_w[order]

        # Adaptive persistency gate: one cheap float capsum pass estimates
        # how much of the union the peel would retire.  Near convergence
        # almost everything survives (boundary mass shrinks relative to
        # internal arcs) and the peel's quantize/compact passes are pure
        # overhead — take the direct float path, which solves the exact
        # same integer problem.  Early rounds force the large majority and
        # the peel pays for itself many times over.
        frac = (peel_frac if peel_frac is not None else
                peel_gate_fraction(nc, int_a, int_w, theta_i, theta_j))
        if frac < PEEL_GATE_FRAC:
            n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
                nc, int_a, int_b, int_w, theta_i, theta_j, arena=arena,
                presorted=True)
            _, side = min_st_cut_csr(n, s, t, indptr, cols, caps)
            return side[:nc]

        # Quantize to the shared integer resolution exactly as
        # min_st_cut_csr would (same multiply/rint/clip op order), then run
        # the persistency peel in the integer domain: the surviving
        # problem's min cuts are the full quantized problem's min cuts
        # conditioned on the forced nodes, so the composed mask is
        # bit-identical to the unpeeled solve.
        cmax = max(float(theta_i.max()), float(theta_j.max()))
        if len(int_w):
            cmax = max(cmax, float(int_w.max()))
        scale = _SCALE / max(cmax, 1e-30)
        ti = np.maximum(np.rint(theta_i * scale), 0).astype(np.int64)
        tj = np.maximum(np.rint(theta_j * scale), 0).astype(np.int64)
        iw = np.maximum(np.rint(int_w * scale), 0).astype(np.int64)
        alive, src = peel_forced(nc, int_a, int_b, iw, ti, tj)
        na = int(alive.sum())
        if na == 0:                            # peel settled every node
            return src

        peak = max(int(ti[alive].max()), int(tj[alive].max()))
        if peak >= np.iinfo(np.int32).max:     # pragma: no cover
            # Absorbed t-links outgrew int32: solve the full quantized
            # problem instead (its caps are all <= _SCALE by construction).
            fti = np.maximum(np.rint(theta_i * scale), 0)
            ftj = np.maximum(np.rint(theta_j * scale), 0)
            fiw = np.maximum(np.rint(int_w * scale), 0)
            n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
                nc, int_a, int_b, fiw, fti, ftj, arena=arena,
                presorted=True)
            _, side = min_st_cut_csr(n, s, t, indptr, cols, caps,
                                     prescaled=True)
            return side[:nc]

        # Compact the survivors (order-preserving, so the canonical arc
        # ordering carries over) and solve — chunked when the reduced union
        # still exceeds the working-set budget.
        new_id = np.cumsum(alive, dtype=np.int64) - 1
        keep = alive[int_a] & alive[int_b]
        ria = new_id[int_a[keep]]
        rib = new_id[int_b[keep]]
        riw = iw[keep].astype(np.float64)
        rti = ti[alive].astype(np.float64)
        rtj = tj[alive].astype(np.float64)
        if nb > 1:
            counts = np.bincount(node_blk[alive], minlength=nb)
            rptr = np.zeros(nb + 1, dtype=np.int64)
            np.cumsum(counts, out=rptr[1:])
        else:
            rptr = np.array([0, na], dtype=np.int64)
        rside = np.empty(na, dtype=bool)
        if chunk_nodes and nb > 1 and na > chunk_nodes:
            spans = _chunk_block_spans(rptr, int(chunk_nodes))
            arc_bounds = np.searchsorted(ria, rptr)
            if workers and workers > 1 and len(spans) > 1:
                problems = []
                for blo, bhi in spans:
                    lo, hi = int(rptr[blo]), int(rptr[bhi])
                    alo, ahi = arc_bounds[blo], arc_bounds[bhi]
                    problems.append(assemble_symmetric_flow_csr(
                        hi - lo, ria[alo:ahi] - lo, rib[alo:ahi] - lo,
                        riw[alo:ahi], rti[lo:hi], rtj[lo:hi],
                        presorted=True) + (True,))
                results = min_st_cut_csr_many(
                    problems, workers=workers, worker_mode=worker_mode)
                for (blo, bhi), (_, cside) in zip(spans, results):
                    lo, hi = int(rptr[blo]), int(rptr[bhi])
                    rside[lo:hi] = cside[:hi - lo]
            else:
                for blo, bhi in spans:
                    lo, hi = int(rptr[blo]), int(rptr[bhi])
                    alo, ahi = arc_bounds[blo], arc_bounds[bhi]
                    n, s, t, indptr, cols, caps = \
                        assemble_symmetric_flow_csr(
                            hi - lo, ria[alo:ahi] - lo,
                            rib[alo:ahi] - lo, riw[alo:ahi],
                            rti[lo:hi], rtj[lo:hi], arena=arena,
                            presorted=True)
                    _, cside = min_st_cut_csr(n, s, t, indptr, cols, caps,
                                              prescaled=True)
                    rside[lo:hi] = cside[:hi - lo]
        else:
            n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
                na, ria, rib, riw, rti, rtj, arena=arena, presorted=True)
            _, full_side = min_st_cut_csr(n, s, t, indptr, cols, caps,
                                          prescaled=True)
            rside = full_side[:na]
        side = src.copy()
        side[alive] = rside
        return side

    # Pure-python fallback: split the arcs back per block (arcs sorted by
    # row are block-grouped — rows of block b lie in [ptr[b], ptr[b+1])).
    if presorted:
        ia, ib, iw = int_a, int_b, np.asarray(int_w)
    else:
        order = np.argsort(int_a, kind="stable")
        ia, ib = int_a[order], int_b[order]
        iw = np.asarray(int_w)[order]
    bounds = np.searchsorted(ia, block_ptr)
    problems = []
    spans = []
    for b in range(len(block_ptr) - 1):
        lo, hi = int(block_ptr[b]), int(block_ptr[b + 1])
        k = hi - lo
        if k == 0:
            continue
        alo, ahi = bounds[b], bounds[b + 1]
        n_int = ahi - alo
        S, T = k, k + 1
        us = np.empty(2 * k + n_int, dtype=np.int64)
        vs = np.empty(2 * k + n_int, dtype=np.int64)
        caps_uv = np.empty(2 * k + n_int, dtype=np.float64)
        caps_vu = np.zeros(2 * k + n_int, dtype=np.float64)
        us[:k] = S
        vs[:k] = np.arange(k)
        caps_uv[:k] = theta_j[lo:hi]
        us[k:2 * k] = np.arange(k)
        vs[k:2 * k] = T
        caps_uv[k:2 * k] = theta_i[lo:hi]
        us[2 * k:] = ia[alo:ahi] - lo
        vs[2 * k:] = ib[alo:ahi] - lo
        caps_uv[2 * k:] = iw[alo:ahi]
        problems.append((k + 2, S, T, us, vs, caps_uv, caps_vu))
        spans.append((lo, hi, k))
    results = min_st_cut_many(problems, backend="dinic", workers=workers,
                              worker_mode=worker_mode)
    side = np.zeros(nc, dtype=bool)
    for (lo, hi, k), (_, blk_side) in zip(spans, results):
        side[lo:hi] = blk_side[:k]
    return side


def _solve_one_cut(problem: tuple, backend: str = "dinic"):
    """Top-level (picklable) worker for :func:`min_st_cut_many`."""
    n, s, t, us, vs, caps_uv, caps_vu = problem
    return min_st_cut(n, s, t, us, vs, caps_uv, caps_vu, backend=backend)


def _solve_one_cut_csr(problem: tuple):
    """Top-level (picklable) worker for :func:`min_st_cut_csr_many`."""
    n, s, t, indptr, cols, caps = problem[:6]
    prescaled = bool(problem[6]) if len(problem) > 6 else False
    return min_st_cut_csr(n, s, t, indptr, cols, caps, prescaled=prescaled)


def _pool_map(fn, problems: Sequence[tuple], workers: int,
              worker_mode: str) -> list:
    import concurrent.futures as cf
    pool_cls = (cf.ProcessPoolExecutor if worker_mode == "process"
                else cf.ThreadPoolExecutor)
    with pool_cls(max_workers=int(workers)) as pool:
        return list(pool.map(fn, problems))


def min_st_cut_csr_many(
    problems: Sequence[tuple],
    workers: int = 0,
    worker_mode: str = "thread",
) -> List[Tuple[float, np.ndarray]]:
    """Solve independent pre-assembled CSR cut problems ``(n, s, t, indptr,
    cols, caps)`` (the scipy fast path), optionally over a ``workers``
    thread/process pool — the CSR counterpart of :func:`min_st_cut_many`,
    used by the chunked block solver's fan-out.  ``caps`` arrays are
    clobbered; results are returned in input order.

    The problems must be INDEPENDENTLY OWNED: arena-backed assembly views
    share one scratch buffer, so accumulating several
    :func:`assemble_symmetric_flow_csr` results built on the same arena
    silently turns every problem into the last one (and the in-place
    capacity scaling clobbers across problems).  That aliasing is detected
    here and raised loudly — in any worker mode, since serial execution
    corrupts the same way, just one solve later."""
    caps = [np.asarray(p[5]) for p in problems]
    for a in range(len(caps)):
        for b in range(a + 1, len(caps)):
            # bounds-based check: exact for the contiguous slices the
            # assembly produces, and cheap enough to run unconditionally
            if np.may_share_memory(caps[a], caps[b]):
                raise ValueError(
                    "min_st_cut_csr_many: problems share capacity memory "
                    f"(problems {a} and {b}) — assemble each problem into "
                    "owned arrays (no shared arena) before batching")
    if workers and workers > 1 and len(problems) > 1:
        return _pool_map(_solve_one_cut_csr, problems, workers, worker_mode)
    return [_solve_one_cut_csr(p) for p in problems]


def min_st_cut_many(
    problems: Sequence[tuple],
    backend: str = "dinic",
    workers: int = 0,
    worker_mode: str = "thread",
) -> List[Tuple[float, np.ndarray]]:
    """Solve independent cut problems ``(n, s, t, us, vs, caps_uv,
    caps_vu)``, optionally in a pool of ``workers`` threads or processes
    (``worker_mode``) — the fan-out primitive behind a round's disjoint
    blocks.  ``backend`` may be ``'dinic'`` (pure python, the no-scipy
    fallback) or ``'scipy'``.  Results are returned in input order."""
    if workers and workers > 1 and len(problems) > 1:
        import functools
        return _pool_map(functools.partial(_solve_one_cut, backend=backend),
                         problems, workers, worker_mode)
    return [_solve_one_cut(p, backend=backend) for p in problems]


def min_st_cut(
    n: int,
    s: int,
    t: int,
    edges_u: np.ndarray,
    edges_v: np.ndarray,
    caps_uv: np.ndarray,
    caps_vu: np.ndarray,
    backend: str = "auto",
    arena: CutArena | None = None,
) -> Tuple[float, np.ndarray]:
    """Solve min s-t cut on a directed-capacity graph.

    Args:
      n: node count (s, t included).
      edges_u/v: endpoints; caps_uv/vu: directed capacities per edge row.
      backend: 'scipy' | 'dinic' | 'auto'.
      arena: optional reusable scratch (see :class:`CutArena`) for callers
        that solve many cuts in a loop.

    Returns:
      (cut_value, source_side_mask) with mask[s]=True, mask[t]=False.
    """
    edges_u = np.asarray(edges_u, dtype=np.int64)
    edges_v = np.asarray(edges_v, dtype=np.int64)
    caps_uv = np.asarray(caps_uv, dtype=np.float64)
    caps_vu = np.asarray(caps_vu, dtype=np.float64)
    if backend == "auto":
        backend = "scipy" if _HAVE_SCIPY else "dinic"

    if backend == "scipy":
        # Merge parallel directed edges; scale to int64.  The scale adapts
        # to the largest capacity so huge costs (e.g. congestion-priced
        # layouts) cannot overflow: resolution is relative, and the cut
        # PARTITION is exact as long as gaps exceed max_cap/_SCALE.
        E = len(edges_u)
        if arena is not None:
            u, v, c, ci = arena.edge_buffers(2 * E)
            u[:E], u[E:] = edges_u, edges_v
            v[:E], v[E:] = edges_v, edges_u
            c[:E], c[E:] = caps_uv, caps_vu
        else:
            u = np.concatenate([edges_u, edges_v])
            v = np.concatenate([edges_v, edges_u])
            c = np.concatenate([caps_uv, caps_vu])
            ci = np.empty_like(u)
        cmax = float(c.max()) if len(c) else 1.0
        scale = _SCALE / max(cmax, 1e-30)
        np.multiply(c, scale, out=c)
        np.rint(c, out=c)
        np.maximum(c, 0, out=c)
        ci[:] = c
        keep = ci > 0
        mat = csr_matrix((ci[keep], (u[keep], v[keep])), shape=(n, n))
        mat.sum_duplicates()
        res = _scipy_maxflow(mat, s, t)
        side = _residual_source_side(mat, res.flow, n, s)
        return res.flow_value / scale, side

    dinic = Dinic(n)
    for u, v, cuv, cvu in zip(edges_u, edges_v, caps_uv, caps_vu):
        dinic.add_edge(int(u), int(v), float(cuv), float(cvu))
    val = dinic.max_flow(s, t)
    return val, dinic.min_cut_side(s)
