"""Minimum s-t cut solvers.

GLAD-S settles each server pair by a min s-t cut on an auxiliary graph
(paper Sec. IV-B; solver reference [101] Orlin O(nm)).  Two backends:

  * 'scipy'  — scipy.sparse.csgraph.maximum_flow (C implementation of
               Dinic/BFS).  scipy requires integer capacities, so float
               weights are scaled to int64 with a fixed resolution; the cut
               *partition* is exact as long as weight gaps exceed 1/SCALE.
  * 'dinic'  — pure-python Dinic with float capacities (exact, slower);
               used as fallback and as the oracle in tests.

Both return the source-side membership mask, from which GLAD's Eq. (15)
mapping derives the layout.
"""
from __future__ import annotations

from collections import deque
from typing import Tuple

import numpy as np

try:
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import maximum_flow as _scipy_maxflow

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False

_SCALE = 10 ** 7  # float -> int64 capacity resolution for the scipy backend


class CutArena:
    """Reusable scratch buffers for repeated min-cut solves.

    The layout engine solves tens of thousands of small cuts per sweep; the
    per-call assembly of the merged directed edge list is served from one
    geometrically-grown arena instead of four fresh allocations per call.
    Pass the same instance to every :func:`min_st_cut` of a sweep.
    """

    def __init__(self):
        self._cap = 0
        self._u = self._v = self._c = self._ci = None

    def edge_buffers(self, size: int):
        """(u, v, c, ci) views of length ``size`` (int64/int64/f64/int64)."""
        if self._u is None or size > self._cap:
            cap = max(256, 1 << int(np.ceil(np.log2(max(size, 1)))))
            self._u = np.empty(cap, dtype=np.int64)
            self._v = np.empty(cap, dtype=np.int64)
            self._c = np.empty(cap, dtype=np.float64)
            self._ci = np.empty(cap, dtype=np.int64)
            self._cap = cap
        return (self._u[:size], self._v[:size], self._c[:size],
                self._ci[:size])


class Dinic:
    """Textbook Dinic max-flow with adjacency arrays (float capacities)."""

    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[float] = []
        self.head: list[list[int]] = [[] for _ in range(n)]

    def add_edge(self, u: int, v: int, cap_uv: float, cap_vu: float = 0.0):
        self.head[u].append(len(self.to)); self.to.append(v); self.cap.append(cap_uv)
        self.head[v].append(len(self.to)); self.to.append(u); self.cap.append(cap_vu)

    def _bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and self.level[v] < 0:
                    self.level[v] = self.level[u] + 1
                    q.append(v)
        return self.level[t] >= 0

    def _dfs(self, u: int, t: int, f: float, it: list[int]) -> float:
        if u == t:
            return f
        while it[u] < len(self.head[u]):
            eid = self.head[u][it[u]]
            v = self.to[eid]
            if self.cap[eid] > 1e-12 and self.level[v] == self.level[u] + 1:
                d = self._dfs(v, t, min(f, self.cap[eid]), it)
                if d > 1e-12:
                    self.cap[eid] -= d
                    self.cap[eid ^ 1] += d
                    return d
            it[u] += 1
        return 0.0

    def max_flow(self, s: int, t: int) -> float:
        flow = 0.0
        while self._bfs(s, t):
            it = [0] * self.n
            while True:
                f = self._dfs(s, t, float("inf"), it)
                if f <= 1e-12:
                    break
                flow += f
        return flow

    def min_cut_side(self, s: int) -> np.ndarray:
        """Source-side reachability in the residual graph (call after max_flow)."""
        side = np.zeros(self.n, dtype=bool)
        side[s] = True
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and not side[v]:
                    side[v] = True
                    q.append(v)
        return side


def _bfs_source_side(indptr, indices, data, n: int, s: int) -> np.ndarray:
    """Reachability from s over strictly-positive entries of a CSR graph.

    Frontier-vectorized BFS on raw CSR arrays: each level is one ragged
    multi-range gather, so the Python-loop count is the BFS depth
    (typically 2-4 for GLAD's auxiliary graphs), not the entry count.
    """
    from repro.graphs.datagraph import csr_multirange

    side = np.zeros(n, dtype=bool)
    side[s] = True
    frontier = np.array([s], dtype=np.int64)
    while len(frontier):
        flat, _ = csr_multirange(indptr, frontier)
        if len(flat) == 0:
            break
        nxt = indices[flat][data[flat] > 0]
        nxt = nxt[~side[nxt]]
        if len(nxt) == 0:
            break
        nxt = np.unique(nxt)
        side[nxt] = True
        frontier = nxt
    return side


def _residual_source_side(mat, flow, n: int, s: int) -> np.ndarray:
    """Source-side reachability of the min cut, via the residual graph."""
    residual = mat - flow
    return _bfs_source_side(residual.indptr, residual.indices,
                            residual.data, n, s)


def min_st_cut_csr(
    n: int,
    s: int,
    t: int,
    indptr: np.ndarray,
    indices: np.ndarray,
    caps: np.ndarray,
) -> Tuple[float, np.ndarray]:
    """Min s-t cut on a caller-built CSR capacity structure (scipy backend).

    Fast path for the layout engine: the auxiliary graph's CSR arrays are
    assembled directly (int32 indices, canonical order, no duplicates),
    skipping the COO round-trip, dtype upcasting and duplicate merging of
    the generic :func:`min_st_cut`.  When the structure is *symmetric*
    (every arc's reverse is present, zero-capacity reverse arcs included —
    the engine builds it this way), scipy's flow matrix shares the input's
    sparsity exactly, so the residual is a plain elementwise array
    difference — no sparse subtraction, no format conversions.

    ``caps`` is float64; capacities are scaled to int32 with relative
    resolution 1/_SCALE exactly like the generic path.  ``caps`` is
    clobbered (scaled in place) — pass a scratch array.
    """
    cmax = float(caps.max()) if len(caps) else 1.0
    scale = _SCALE / max(cmax, 1e-30)
    np.multiply(caps, scale, out=caps)
    np.rint(caps, out=caps)
    np.maximum(caps, 0, out=caps)
    data = caps.astype(np.int32)
    try:
        # The engine guarantees well-formed arrays; skip csr validation
        # (check_format + index-dtype sniffing are ~20% of small solves).
        mat = csr_matrix.__new__(csr_matrix)
        mat.data = data
        mat.indices = indices
        mat.indptr = indptr
        mat._shape = (n, n)
    except Exception:  # pragma: no cover - scipy internals drift
        mat = csr_matrix((data, indices, indptr), shape=(n, n))
    res = _scipy_maxflow(mat, s, t)
    flow = res.flow
    if (np.array_equal(flow.indptr, mat.indptr)
            and np.array_equal(flow.indices, mat.indices)):
        side = _bfs_source_side(mat.indptr, mat.indices,
                                mat.data - flow.data, n, s)
    else:  # pragma: no cover - asymmetric structure / scipy internals drift
        side = _residual_source_side(mat, flow, n, s)
    return res.flow_value / scale, side


def min_st_cut(
    n: int,
    s: int,
    t: int,
    edges_u: np.ndarray,
    edges_v: np.ndarray,
    caps_uv: np.ndarray,
    caps_vu: np.ndarray,
    backend: str = "auto",
    arena: CutArena | None = None,
) -> Tuple[float, np.ndarray]:
    """Solve min s-t cut on a directed-capacity graph.

    Args:
      n: node count (s, t included).
      edges_u/v: endpoints; caps_uv/vu: directed capacities per edge row.
      backend: 'scipy' | 'dinic' | 'auto'.
      arena: optional reusable scratch (see :class:`CutArena`) for callers
        that solve many cuts in a loop.

    Returns:
      (cut_value, source_side_mask) with mask[s]=True, mask[t]=False.
    """
    edges_u = np.asarray(edges_u, dtype=np.int64)
    edges_v = np.asarray(edges_v, dtype=np.int64)
    caps_uv = np.asarray(caps_uv, dtype=np.float64)
    caps_vu = np.asarray(caps_vu, dtype=np.float64)
    if backend == "auto":
        backend = "scipy" if _HAVE_SCIPY else "dinic"

    if backend == "scipy":
        # Merge parallel directed edges; scale to int64.  The scale adapts
        # to the largest capacity so huge costs (e.g. congestion-priced
        # layouts) cannot overflow: resolution is relative, and the cut
        # PARTITION is exact as long as gaps exceed max_cap/_SCALE.
        E = len(edges_u)
        if arena is not None:
            u, v, c, ci = arena.edge_buffers(2 * E)
            u[:E], u[E:] = edges_u, edges_v
            v[:E], v[E:] = edges_v, edges_u
            c[:E], c[E:] = caps_uv, caps_vu
        else:
            u = np.concatenate([edges_u, edges_v])
            v = np.concatenate([edges_v, edges_u])
            c = np.concatenate([caps_uv, caps_vu])
            ci = np.empty_like(u)
        cmax = float(c.max()) if len(c) else 1.0
        scale = _SCALE / max(cmax, 1e-30)
        np.multiply(c, scale, out=c)
        np.rint(c, out=c)
        np.maximum(c, 0, out=c)
        ci[:] = c
        keep = ci > 0
        mat = csr_matrix((ci[keep], (u[keep], v[keep])), shape=(n, n))
        mat.sum_duplicates()
        res = _scipy_maxflow(mat, s, t)
        side = _residual_source_side(mat, res.flow, n, s)
        return res.flow_value / scale, side

    dinic = Dinic(n)
    for u, v, cuv, cvu in zip(edges_u, edges_v, caps_uv, caps_vu):
        dinic.add_edge(int(u), int(v), float(cuv), float(cvu))
    val = dinic.max_flow(s, t)
    return val, dinic.min_cut_side(s)
