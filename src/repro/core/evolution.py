"""Graph-evolution trace generation (paper Sec. V-A & VI-A).

Per time slot: draw the number of changed links from a Gaussian whose mean is
``pct * |E|`` and std is half of that, then uniformly realize link
insertions/deletions between randomly selected vertices; same recipe for
vertex insertions/deletions.  Changes are restricted to a small extent [75].
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.graphs.datagraph import DataGraph


@dataclasses.dataclass
class GraphDelta:
    add_edges: np.ndarray
    del_edges: np.ndarray
    add_vertices: int
    del_vertices: np.ndarray

    @property
    def empty(self) -> bool:
        return (
            len(self.add_edges) == 0 and len(self.del_edges) == 0
            and self.add_vertices == 0 and len(self.del_vertices) == 0
        )


def _gauss_count(rng, mean: float) -> int:
    return max(0, int(round(rng.normal(mean, mean / 2.0))))


def sample_delta(
    graph: DataGraph,
    pct_links: float = 0.01,
    pct_vertices: float = 0.0,
    seed: int = 0,
) -> GraphDelta:
    rng = np.random.default_rng(seed)
    e = graph.edges
    n_changes = _gauss_count(rng, pct_links * max(len(e), 1))

    add_edges, del_edges = [], []
    for _ in range(n_changes):
        if rng.uniform() < 0.5 and len(e):          # deletion
            del_edges.append(e[rng.integers(0, len(e))])
        else:                                        # insertion
            u, v = rng.integers(0, graph.n, size=2)
            if u != v:
                add_edges.append((min(u, v), max(u, v)))

    nv = _gauss_count(rng, pct_vertices * graph.n) if pct_vertices > 0 else 0
    add_vertices, del_vertices = 0, []
    for _ in range(nv):
        if rng.uniform() < 0.5:
            add_vertices += 1
        else:
            del_vertices.append(int(rng.integers(0, graph.n)))
    # New vertices join with a couple of links to existing ones.
    base_n = graph.n
    for k in range(add_vertices):
        vid = base_n + k
        for _ in range(int(rng.integers(1, 4))):
            u = int(rng.integers(0, base_n))
            add_edges.append((min(u, vid), max(u, vid)))

    return GraphDelta(
        add_edges=np.array(add_edges, dtype=np.int64).reshape(-1, 2),
        del_edges=np.array(del_edges, dtype=np.int64).reshape(-1, 2),
        add_vertices=add_vertices,
        del_vertices=np.array(sorted(set(del_vertices)), dtype=np.int64),
    )


def apply_delta(graph: DataGraph, delta: GraphDelta) -> DataGraph:
    return graph.with_changes(
        add_edges=delta.add_edges if len(delta.add_edges) else None,
        del_edges=delta.del_edges if len(delta.del_edges) else None,
        add_vertices=delta.add_vertices,
        del_vertices=delta.del_vertices if len(delta.del_vertices) else None,
    )


def evolution_trace(
    graph: DataGraph,
    slots: int,
    pct_links: float = 0.01,
    pct_vertices: float = 0.0,
    seed: int = 0,
) -> List[GraphDelta]:
    """Pre-generate the whole trace so experiments are reproducible."""
    return [
        sample_delta(graph, pct_links, pct_vertices, seed=seed + 1000 + t)
        for t in range(slots)
    ]


def changed_vertices(
    old: DataGraph, new: DataGraph, assign_old: np.ndarray
) -> np.ndarray:
    """GLAD-E's filter (Alg. 2 line 1): vertices that are newly added OR have
    acquired a new neighbor residing on a *different* server.  Returns a bool
    mask over new.n (padded: new vertices are always True)."""
    mask = np.zeros(new.n, dtype=bool)
    if new.n > old.n:
        mask[old.n:] = True
    n = max(old.n, new.n)
    if len(new.edges) == 0:
        return mask
    # Vectorized: key-match new links against old, then flag the endpoints
    # of genuinely-new links whose endpoints live on different servers
    # (inserted vertices count as their own pseudo-server).
    new_keys = new.edges[:, 0] * n + new.edges[:, 1]
    if len(old.edges):
        old_keys = old.edges[:, 0] * n + old.edges[:, 1]
        fresh = ~np.isin(new_keys, old_keys)
    else:
        fresh = np.ones(len(new_keys), dtype=bool)
    eu, ev = new.edges[fresh, 0], new.edges[fresh, 1]
    pad = np.concatenate([assign_old[:old.n],
                          -1 - np.arange(max(n - old.n, 0))])
    cross = pad[eu] != pad[ev]
    mask[eu[cross]] = True
    mask[ev[cross]] = True
    return mask
