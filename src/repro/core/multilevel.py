"""Multilevel GLAD: a METIS-style V-cycle over the pairwise min-cut engine.

Flat GLAD sweeps pay O(n) member volume per round from the very first
iteration, so million-vertex layouts spend almost all their wall time on
first-pass cuts whose decisions are dominated by coarse cluster structure.
The V-cycle factors that structure out:

  coarsen   iterative heavy-edge matching (vectorized over the DataGraph
            CSR, decided in the quantized integer weight domain) contracts
            matched pairs into coarse vertices until ``coarsen_to`` is
            reached.  Each coarse level is a real ``DataGraph`` +
            ``CostModel`` pair: coarse edge weights are the summed fine
            weights, and the coarse unary matrix is the row-sum of the fine
            one (folded into the coarse network's ``mu``; compute and
            per-vertex maintenance coefficients are zeroed so nothing is
            double counted).  Because intra-cluster links cost tau[i,i] = 0
            under any projection, the coarse objective of a coarse
            assignment EQUALS the fine objective of its projection — the
            hierarchy restricts the search space, never distorts the cost
            (pinned by a hypothesis property test).
  solve     the coarsest level is solved by the EXISTING engine
            (:func:`repro.core.glad_s.glad_s`, batched disjoint-pair
            rounds) — no new optimizer code at any level.
  refine    each assignment is projected one level down
            (``assign[cluster_of]``) and the same engine re-runs with the
            projection as warm init and a boundary-active mask (endpoints
            of cut links + ``refine_hops`` neighborhood rings).  The active
            mask is exactly the regime the engine's 'auto' policies enable
            the AssemblyCache and warm-start (ResidualCut) for, so
            cross-round caching, persistency peeling and warm re-solves
            compose per level unchanged.  ``cache_bytes``/``chunk_nodes``
            are scaled to each level's vertex count, so coarse levels never
            reserve the finest level's budgets.

The finest refinement is literally a flat ``glad_s`` call on the original
cost model — its trajectory is bit-identical to running the flat engine
from the same projected init and mask (golden-fixture pinned).

Matching is capacity-aware (cluster fine-vertex counts are capped at
``MAX_CLUSTER_FACTOR * n / coarsen_to`` so no coarse vertex grows beyond
what a balanced layout could place) and mu-aware: a merge commits both
endpoints to one server, so candidates whose unary preference disagreement
provably exceeds the traffic the merge can save are gated out
(``MU_GATE_SLACK``).
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import List, Optional

import numpy as np

from repro.core.cost import CostModel
from repro.core.engine import AUTO_CHUNK_NODES
from repro.graphs.datagraph import DataGraph, contract_graph, csr_multirange
from repro.graphs.edgenet import EdgeNetwork

#: ``multilevel='auto'`` turns the V-cycle on from this vertex count.
MULTILEVEL_AUTO_MIN_N = 200_000
#: Default coarsest-level size (the level the full-R solve runs at).
#: Chosen so the coarsest exhaustive-patience solve stays a small share of
#: the V-cycle wall clock at n=50k/m=32 while final cost tracks the flat
#: engine within 1e-3 (BENCH_layout multilevel cells).
COARSEN_TO = 1024
#: Matching proposal rounds per coarsening level.
MATCH_ROUNDS = 4
#: Stop coarsening when a level shrinks by less than this factor.
STAGNATION_FRAC = 0.95
#: Cluster fine-vertex cap = this factor x (n / coarsen_to).
MAX_CLUSTER_FACTOR = 1.5
#: mu gate: allow a merge only while the unary disagreement lower bound
#: stays under SLACK x tau_ref x link weight (the traffic scale the merge
#: can save).  Permissive on purpose — it prunes egregious merges only.
MU_GATE_SLACK = 4.0
#: Integer domain for matching decisions (mirrors maxflow's quantization).
_WQ_SCALE = 10 ** 7
#: Floor for a level's scaled AssemblyCache budget.
_MIN_LEVEL_CACHE = 8 << 20


@dataclasses.dataclass
class Level:
    """One rung of the coarsening hierarchy.

    ``cluster_of`` maps the NEXT-FINER level's vertices onto this level's
    (``None`` at the finest level).  ``vertex_w`` counts the fine vertices
    each coarse vertex carries (the capacity weight the matcher caps).
    """

    cm: CostModel
    cluster_of: Optional[np.ndarray]
    vertex_w: np.ndarray


#: Largest float64 magnitude a quantized weight may round to and still fit
#: int64.  Anything past this would WRAP silently under ``.astype(int64)``
#: (numpy does not raise) and corrupt every downstream matching decision.
_INT64_LIMIT_F = float(2 ** 63 - 1024)


def _quantize_scaled(vals: np.ndarray, scale: float) -> np.ndarray:
    """Elementwise quantization at a fixed scale, with a loud int64-domain
    guard: weights whose scaled magnitude leaves the int64 range (huge
    negatives, inf/nan from upstream weight sums) raise instead of
    wrapping.  Shared by the in-core and streamed coarsening paths so
    both make bit-identical decisions chunk by chunk."""
    scaled = np.rint(vals * scale)
    bad = ~(np.abs(scaled) <= _INT64_LIMIT_F)     # catches nan/inf too
    if bad.any():
        k = int(np.flatnonzero(bad)[0])
        raise ValueError(
            f"quantized edge weight overflows the int64 matching domain "
            f"(weight {vals[k]!r} at scale {scale!r}); summed parallel "
            f"edge weights saturated — refuse to wrap silently")
    return scaled.astype(np.int64)


def quantize_weights(w: np.ndarray) -> np.ndarray:
    """Edge weights -> the integer domain matching decisions are made in
    (scale-invariant, deterministic ties).  Raises on weights that do not
    fit the int64 domain after scaling (silent wraparound would corrupt
    matchings at n>=2M where contracted parallel-edge sums grow large)."""
    if len(w) and not np.isfinite(w).all():
        raise ValueError("non-finite edge weight entering quantization "
                         "(overflowed parallel-edge weight sum?)")
    mx = float(w.max()) if len(w) else 0.0
    if mx <= 0.0:
        return np.zeros(len(w), dtype=np.int64)
    return _quantize_scaled(w, _WQ_SCALE / mx)


def _mix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Deterministic per-(vertex, neighbor) hash for tie-breaking.

    Equal-weight candidates (the whole finest level, when links are unit
    weight) must not all prefer the same smallest-id neighbor — that herds
    every proposal onto a few hubs and each handshake round matches only
    one tail per hub.  A splitmix-style hash spreads the ties uniformly
    while staying a pure function of the ids (coarsening stays
    deterministic, no RNG)."""
    x = (a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         ^ (b.astype(np.uint64) + np.uint64(0xBF58476D1CE4E5B9)))
    x ^= x >> np.uint64(31)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(29)
    return x


def matching_gate(
    graph: DataGraph,
    unary: np.ndarray,
    tau_ref: float,
    lo: int = 0,
    hi: Optional[int] = None,
    pref: Optional[np.ndarray] = None,
    base: Optional[np.ndarray] = None,
) -> np.ndarray:
    """mu-gate bits for every CSR entry of vertices in ``[lo, hi)``.

    Entry k (vertex v -> neighbor nbr) is True when the merge is allowed:
    the unary-disagreement lower bound stays under ``MU_GATE_SLACK x
    tau_ref x link weight``.  A pure elementwise function of
    (unary, tau_ref, weights), so computing it for the full CSR, for a
    vertex window (the streamed matcher), or for a round's candidate
    subset (the original in-line form) yields bit-identical bits — and
    comparing bits across cost models is an EXACT test for whether a
    level's matching is unchanged (the LevelStack reuse criterion)."""
    if hi is None:
        hi = graph.n
    indptr = graph.indptr
    s, e = int(indptr[lo]), int(indptr[hi])
    if s == e:
        return np.zeros(0, dtype=bool)
    counts = np.diff(indptr[lo:hi + 1])
    v = np.repeat(np.arange(lo, hi, dtype=np.int64), counts)
    nbr = graph.indices[s:e]
    if pref is None:
        pref = np.argmin(unary, axis=1).astype(np.int64)
        base = unary[np.arange(graph.n), pref]
    if graph.edge_weights is None:
        w_e = np.ones(e - s, dtype=np.float64)
    else:
        w_e = graph.edge_weights[graph.edge_ids[s:e]].astype(np.float64)
    d_lb = np.minimum(unary[v, pref[nbr]] - base[v],
                      unary[nbr, pref[v]] - base[nbr])
    return MU_GATE_SLACK * tau_ref * w_e >= d_lb


def heavy_edge_matching(
    graph: DataGraph,
    vertex_w: np.ndarray,
    max_w: int,
    unary: Optional[np.ndarray] = None,
    tau_ref: float = 0.0,
    rounds: int = MATCH_ROUNDS,
    gate: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Iterative HEM over the CSR: ``match[v]`` = partner (or v itself).

    Per round: every unmatched vertex PROPOSES to its heaviest eligible
    unmatched neighbor (integer-quantized weight; ties broken by the
    deterministic :func:`_mix` hash so equal-weight levels don't herd onto
    hubs).  Every proposed-to vertex then ACCEPTS its heaviest incoming
    proposer, overriding its own outgoing proposal — the incoming-aware
    handshake is what lets a hub pair up every round instead of chasing a
    neighbor that never looks back.  Vertices whose accept/propose
    pointers agree (``c[c[v]] == v``) match.  Eligibility = the merged
    capacity weight fits ``max_w`` and, when ``unary`` is given, the mu
    gate holds.  Fully deterministic — no RNG anywhere, so coarsening is a
    pure function of the cost model (the determinism the smoke bench
    pins).
    """
    n = graph.n
    match = np.arange(n, dtype=np.int64)
    if graph.num_edges == 0:
        return match
    indptr, indices, eids = graph.indptr, graph.indices, graph.edge_ids
    w = graph.weights_or_ones().astype(np.float64)
    wq = quantize_weights(w)
    matched = np.zeros(n, dtype=bool)
    if gate is None and unary is not None and tau_ref > 0.0:
        # One elementwise pass over the full CSR replaces the original
        # per-round candidate-subset computation — same bits (the gate is
        # a pure function of each entry), one gather instead of four per
        # round, and the LevelStack caches exactly this array.
        gate = matching_gate(graph, unary, tau_ref)
    for _ in range(rounds):
        un = np.flatnonzero(~matched)
        flat, rep = csr_multirange(indptr, un)
        if len(flat) == 0:
            break
        v = un[rep]
        nbr = indices[flat]
        ew = eids[flat]
        ok = ~matched[nbr]
        ok &= vertex_w[v] + vertex_w[nbr] <= max_w
        if gate is not None:
            # Lower bound on the unary penalty of co-locating v and nbr:
            # one of them must leave its preferred server (see
            # :func:`matching_gate`).
            ok &= gate[flat]
        if not ok.any():
            break
        v, nbr, cw = v[ok], nbr[ok], wq[ew[ok]]
        h = _mix(v, nbr)
        # Proposal: per proposer v, heaviest neighbor, hash tie-break.
        order = np.lexsort((h, -cw, v))
        vs_, nb_, cw_, h_ = v[order], nbr[order], cw[order], h[order]
        head = np.ones(len(order), dtype=bool)
        head[1:] = vs_[1:] != vs_[:-1]
        pv, pt = vs_[head], nb_[head]            # proposer -> target
        pw, ph = cw_[head], h_[head]
        # Acceptance: per target, heaviest incoming proposer (hash, then
        # proposer id, break residual ties deterministically).
        order2 = np.lexsort((pv, ph, -pw, pt))
        t2, p2 = pt[order2], pv[order2]
        head2 = np.ones(len(order2), dtype=bool)
        head2[1:] = t2[1:] != t2[:-1]
        c = np.full(n, -1, dtype=np.int64)
        c[pv] = pt                               # own outgoing proposal
        c[t2[head2]] = p2[head2]                 # incoming winner overrides
        cand = np.flatnonzero(c >= 0)
        partner = c[cand]
        mutual = (c[partner] == cand) & (cand < partner)
        a, b = cand[mutual], partner[mutual]
        if len(a) == 0:
            break
        match[a] = b
        match[b] = a
        matched[a] = True
        matched[b] = True
    return match


def clusters_from_matching(match: np.ndarray):
    """Matching -> (cluster_of, num_clusters); coarse ids ordered by each
    cluster's smallest member id (deterministic)."""
    rep = np.minimum(np.arange(len(match), dtype=np.int64), match)
    uniq, cluster_of = np.unique(rep, return_inverse=True)
    return cluster_of.astype(np.int64), int(len(uniq))


def coarse_cost_model(
    cm: CostModel, graph_c: DataGraph, cluster_of: np.ndarray, nc: int
) -> CostModel:
    """Exact coarse model: coarse ``mu`` rows are the summed fine ``unary``
    rows; compute/per-vertex-maintenance coefficients are zeroed (already
    inside the fine unary), ``tau``/``w``/``eps`` carry over.  The coarse
    ``unary`` therefore equals the summed fine unary and, with summed edge
    weights and tau[i,i] = 0, the coarse total of any coarse assignment
    equals the fine total of its projection (up to float summation order).
    """
    net = cm.net
    order = np.argsort(cluster_of, kind="stable")
    starts = np.searchsorted(cluster_of[order], np.arange(nc))
    mu_c = np.add.reduceat(cm.unary[order], starts, axis=0)
    zeros = np.zeros(net.m, dtype=np.float64)
    net_c = EdgeNetwork(
        m=net.m, w=net.w, tau=net.tau, alpha=zeros, beta=zeros, gamma=zeros,
        rho=zeros, eps=net.eps, mu=mu_c, sku=net.sku, coords=net.coords,
    )
    return CostModel(net_c, graph_c, cm.gnn)


def build_levels(
    cm: CostModel,
    coarsen_to: int = COARSEN_TO,
    max_levels: Optional[int] = None,
    mu_gate: bool = True,
    chunk_vertices: "int | str | None" = None,
) -> List[Level]:
    """Coarsening hierarchy, finest first.  Stops at ``coarsen_to``
    vertices, at ``max_levels`` rungs, or when matching stagnates.

    ``chunk_vertices`` routes the build through the streamed coarsening
    path (:mod:`repro.core.multilevel_stream`): matching and contraction
    walk the CSR in bounded vertex windows of that size ('auto' picks the
    default window), so peak transient memory is a knob instead of
    O(n + m) per level.  The streamed levels are BIT-IDENTICAL to the
    in-core ones for any window size (property-pinned)."""
    if chunk_vertices is not None:
        from repro.core.multilevel_stream import build_levels_streamed
        return build_levels_streamed(
            cm, coarsen_to=coarsen_to, max_levels=max_levels,
            mu_gate=mu_gate, chunk_vertices=chunk_vertices)
    levels = [Level(cm=cm, cluster_of=None,
                    vertex_w=np.ones(cm.graph.n, dtype=np.int64))]
    tau_ref = cm.tau_ref() if mu_gate else 0.0
    cap = max(2, int(np.ceil(
        MAX_CLUSTER_FACTOR * cm.graph.n / max(coarsen_to, 1))))
    while True:
        cur = levels[-1]
        g = cur.cm.graph
        if g.n <= coarsen_to or g.num_edges == 0:
            break
        if max_levels is not None and len(levels) >= max_levels:
            break
        match = heavy_edge_matching(
            g, cur.vertex_w, cap,
            unary=cur.cm.unary if mu_gate else None, tau_ref=tau_ref)
        cluster_of, nc = clusters_from_matching(match)
        if nc >= STAGNATION_FRAC * g.n:
            break
        g_c = contract_graph(g, cluster_of, nc)
        cm_c = coarse_cost_model(cur.cm, g_c, cluster_of, nc)
        vw_c = np.bincount(cluster_of, weights=cur.vertex_w,
                           minlength=nc).astype(np.int64)
        levels.append(Level(cm=cm_c, cluster_of=cluster_of, vertex_w=vw_c))
    return levels


def _gate_equal(a: Optional[np.ndarray], b: Optional[np.ndarray]) -> bool:
    """Exact equality of two mu-gate bit vectors (None = ungated level)."""
    if a is None or b is None:
        return a is None and b is None
    return a.shape == b.shape and bool(np.array_equal(a, b))


class LevelStack:
    """Persistent coarsening hierarchy reused across relayouts.

    ``build_levels`` is a pure function of (graph structure, edge weights,
    mu-gate bits, capacity cap) — it never reads the current assignment.
    A fault-loop relayout (degrade / straggler / revive) churns the
    ASSIGNMENT of most vertices but leaves the data graph untouched and
    usually leaves the gate bits untouched too, so the expensive parts of
    the V-cycle (matching + contraction per level) can be reused verbatim
    and only the cheap coarse cost models (unary row-sums under the new
    network) rebuilt.  :meth:`acquire` returns a level stack BIT-IDENTICAL
    to a fresh ``build_levels(cm)`` call:

      * per level, the cached gate bits are compared against freshly
        computed bits under the new cost model.  Gate bits are an EXACT
        certificate — matching is a pure function of (structure, quantized
        weights, vertex_w, cap, gate bits), all of which are equal when the
        bits are — so equality proves the cached matching is what a fresh
        build would recompute.
      * bits differ -> the level is re-matched for real; if the new
        matching still equals the cached one, structure reuse continues
        below.  A genuinely diverged matching forces a fresh rebuild from
        that level down (coarse ids are renumbered by
        ``clusters_from_matching`` and the ``_mix`` tie-break hashes key on
        them, so nothing beneath a divergence is salvageable).
      * a stagnation-terminated stack caches the terminal gate + attempted
        matching so termination itself is re-verified exactly; when the
        new model no longer stagnates, the stack simply EXTENDS with fresh
        levels.

    A graph change (GLAD-E evolution) invalidates the whole stack —
    :meth:`valid_for` checks the finest graph by identity, falling back to
    a structural compare.  Owned by
    :class:`repro.core.engine.LayoutSession` (one stack per V-cycle
    configuration), which is how the stack survives across GLAD-E
    escalations and fault relayouts.
    """

    def __init__(self, coarsen_to: int = COARSEN_TO,
                 max_levels: Optional[int] = None, mu_gate: bool = True):
        self.coarsen_to = int(coarsen_to)
        self.max_levels = max_levels
        self.mu_gate = bool(mu_gate)
        self._levels: Optional[List[Level]] = None
        self._gates: List[Optional[np.ndarray]] = []
        self._matches: List[np.ndarray] = []
        # (reason, gate, match): how the cached build stopped.  'size' and
        # 'depth' are pure functions of structure; 'stagnation' keeps the
        # terminal gate bits + attempted matching for exact re-verification.
        self._term: Optional[tuple] = None
        self.builds = 0              # acquisitions that rebuilt from scratch
        self.refreshes = 0           # acquisitions served off the cache
        self.levels_reused = 0       # cumulative matchings reused verbatim
        self.levels_rebuilt = 0      # cumulative matchings recomputed
        self.last_stats: dict = {}

    # ------------------------------------------------------------ validity
    def valid_for(self, cm: CostModel) -> bool:
        """Is the cached stack built over this cost model's graph?  Object
        identity first (the fault runtime keeps one DataGraph across
        events), structural equality as the fallback."""
        if self._levels is None:
            return False
        g0 = self._levels[0].cm.graph
        g = cm.graph
        if g is g0:
            return True
        if g.n != g0.n or g.num_edges != g0.num_edges:
            return False
        if not np.array_equal(g.edges, g0.edges):
            return False
        w0, w1 = g0.edge_weights, g.edge_weights
        if (w0 is None) != (w1 is None):
            return False
        return w0 is None or bool(np.array_equal(w0, w1))

    def invalidate(self) -> None:
        self._levels = None
        self._gates = []
        self._matches = []
        self._term = None

    # ------------------------------------------------------------- helpers
    def _cap(self, n: int) -> int:
        return max(2, int(np.ceil(
            MAX_CLUSTER_FACTOR * n / max(self.coarsen_to, 1))))

    def _gate_for(self, g: DataGraph, unary: np.ndarray, tau_ref: float,
                  chunk) -> Optional[np.ndarray]:
        if not self.mu_gate or tau_ref <= 0.0:
            return None
        if chunk is not None:
            from repro.core.multilevel_stream import matching_gate_streamed
            return matching_gate_streamed(g, unary, tau_ref,
                                          chunk_vertices=chunk)
        return matching_gate(g, unary, tau_ref)

    def _match_with(self, g: DataGraph, vertex_w: np.ndarray, cap: int,
                    gate: Optional[np.ndarray], chunk) -> np.ndarray:
        if chunk is not None:
            from repro.core.multilevel_stream import (
                heavy_edge_matching_streamed)
            return heavy_edge_matching_streamed(
                g, vertex_w, cap, gate=gate, chunk_vertices=chunk)
        return heavy_edge_matching(g, vertex_w, cap, gate=gate)

    def _coarse_cm(self, cm_f: CostModel, g_c: DataGraph,
                   cluster_of: np.ndarray, nc: int, chunk) -> CostModel:
        if chunk is not None:
            from repro.core.multilevel_stream import (
                coarse_cost_model_streamed)
            return coarse_cost_model_streamed(cm_f, g_c, cluster_of, nc,
                                              chunk_vertices=chunk)
        return coarse_cost_model(cm_f, g_c, cluster_of, nc)

    def _grow(self, levels: List[Level], gates: list, matches: list,
              tau_ref: float, cap: int, chunk, pending=None) -> None:
        """Extend ``levels`` with freshly built rungs until termination;
        ``pending`` hands over an already-computed (gate, match) for the
        current finest-unprocessed level (the divergence hand-off — its
        size/depth preconditions held for the cached build of the same
        structure, so they are not re-checked)."""
        while True:
            cur = levels[-1]
            g = cur.cm.graph
            if pending is None:
                if g.n <= self.coarsen_to or g.num_edges == 0:
                    self._term = ("size", None, None)
                    return
                if (self.max_levels is not None
                        and len(levels) >= self.max_levels):
                    self._term = ("depth", None, None)
                    return
                gate = self._gate_for(g, cur.cm.unary, tau_ref, chunk)
                match = self._match_with(g, cur.vertex_w, cap, gate, chunk)
            else:
                gate, match = pending
                pending = None
            cluster_of, nc = clusters_from_matching(match)
            if nc >= STAGNATION_FRAC * g.n:
                self._term = ("stagnation", gate, match)
                return
            gates.append(gate)
            matches.append(match)
            if chunk is not None:
                from repro.core.multilevel_stream import (
                    coarse_vertex_w_streamed, contract_graph_streamed)
                g_c = contract_graph_streamed(g, cluster_of, nc,
                                              chunk_vertices=chunk)
                vw_c = coarse_vertex_w_streamed(cluster_of, cur.vertex_w,
                                                nc, chunk_vertices=chunk)
            else:
                g_c = contract_graph(g, cluster_of, nc)
                vw_c = np.bincount(cluster_of, weights=cur.vertex_w,
                                   minlength=nc).astype(np.int64)
            cm_c = self._coarse_cm(cur.cm, g_c, cluster_of, nc, chunk)
            levels.append(Level(cm=cm_c, cluster_of=cluster_of,
                                vertex_w=vw_c))

    # ------------------------------------------------------------- acquire
    def acquire(self, cm: CostModel,
                chunk_vertices: "int | str | None" = None) -> List[Level]:
        """Level stack for ``cm``, bit-identical to a fresh
        ``build_levels(cm, ...)`` — built from scratch when the graph
        changed, refreshed off the cache otherwise (reused matchings +
        rebuilt coarse cost models)."""
        chunk = chunk_vertices
        tau_ref = cm.tau_ref() if self.mu_gate else 0.0
        cap = self._cap(cm.graph.n)
        if not self.valid_for(cm):
            levels = [Level(cm=cm, cluster_of=None,
                            vertex_w=np.ones(cm.graph.n, dtype=np.int64))]
            gates: list = []
            matches: list = []
            self._grow(levels, gates, matches, tau_ref, cap, chunk)
            self._levels, self._gates, self._matches = (
                levels, gates, matches)
            self.builds += 1
            self.levels_rebuilt += len(matches)
            self.last_stats = dict(mode="build", levels=len(levels),
                                   reused=0, rebuilt=len(matches),
                                   rematch=0)
            return levels

        self.refreshes += 1
        old_levels, old_matches = self._levels, self._matches
        old_gates, old_term = self._gates, self._term
        levels = [Level(cm=cm, cluster_of=None,
                        vertex_w=old_levels[0].vertex_w)]
        gates, matches = [], []
        reused = rematch = 0
        pending = None               # diverged (gate, match) hand-off
        for k in range(len(old_matches)):
            cur = levels[-1]
            gate = self._gate_for(cur.cm.graph, cur.cm.unary, tau_ref,
                                  chunk)
            if _gate_equal(gate, old_gates[k]):
                match = old_matches[k]
            else:
                match = self._match_with(cur.cm.graph, cur.vertex_w, cap,
                                         gate, chunk)
                if not np.array_equal(match, old_matches[k]):
                    pending = (gate, match)
                    break
                rematch += 1
            reused += 1
            gates.append(gate)
            matches.append(match)
            old = old_levels[k + 1]
            nc = old.cm.graph.n
            cm_c = self._coarse_cm(cur.cm, old.cm.graph, old.cluster_of,
                                   nc, chunk)
            levels.append(Level(cm=cm_c, cluster_of=old.cluster_of,
                                vertex_w=old.vertex_w))
        if pending is not None:
            self._grow(levels, gates, matches, tau_ref, cap, chunk,
                       pending=pending)
        else:
            cur = levels[-1]
            g = cur.cm.graph
            if g.n <= self.coarsen_to or g.num_edges == 0:
                self._term = ("size", None, None)
            elif (self.max_levels is not None
                    and len(levels) >= self.max_levels):
                self._term = ("depth", None, None)
            else:
                # The cached build stagnated here; re-verify exactly.
                _, tgate, tmatch = old_term
                gate = self._gate_for(g, cur.cm.unary, tau_ref, chunk)
                if _gate_equal(gate, tgate):
                    self._term = ("stagnation", gate, tmatch)
                else:
                    match = self._match_with(g, cur.vertex_w, cap, gate,
                                             chunk)
                    cluster_of, nc = clusters_from_matching(match)
                    if nc >= STAGNATION_FRAC * g.n:
                        self._term = ("stagnation", gate, match)
                    else:
                        # Termination no longer reproduces: the stack
                        # extends with fresh rungs from here down.
                        self._grow(levels, gates, matches, tau_ref, cap,
                                   chunk, pending=(gate, match))
        self._levels, self._gates, self._matches = levels, gates, matches
        rebuilt = len(matches) - reused
        self.levels_reused += reused
        self.levels_rebuilt += rebuilt
        self.last_stats = dict(mode="refresh", levels=len(levels),
                               reused=reused, rebuilt=rebuilt,
                               rematch=rematch)
        return levels


def _slim_level_stats(stats: dict) -> dict:
    """``record_levels=False`` telemetry: the O(n) replay arrays
    (projected init / active mask) and the per-iteration history collapse
    to checksums + sizes, so scale cells stop retaining O(levels x n)
    memory for bookkeeping nobody replays."""
    out = dict(stats)
    for key in ("init", "active"):
        arr = out.get(key)
        if arr is None:
            out[key + "_crc32"] = None
            out[key + "_size"] = 0
        else:
            arr = np.ascontiguousarray(arr)
            out[key + "_crc32"] = int(zlib.crc32(arr.tobytes()))
            out[key + "_size"] = int(arr.size)
        out[key] = None
    hist = out.get("history") or []
    out["history_crc32"] = (
        int(zlib.crc32(np.asarray(hist, dtype=np.float64).tobytes()))
        if len(hist) else None)
    out["history_len"] = len(hist)
    out["history"] = []
    return out


def restrict_assign(cluster_of: np.ndarray, nc: int, assign: np.ndarray,
                    m: int) -> np.ndarray:
    """Fine -> coarse restriction of a warm init: member-weighted majority
    vote per cluster, ties to the smallest server id."""
    cnt = np.bincount(cluster_of * m + assign, minlength=nc * m)
    return cnt.reshape(nc, m).argmax(axis=1).astype(np.int64)


def boundary_active(graph: DataGraph, assign: np.ndarray,
                    hops: int = 1) -> np.ndarray:
    """Refinement mask: endpoints of cut links, expanded ``hops`` rings."""
    act = np.zeros(graph.n, dtype=bool)
    e = graph.edges
    if len(e) == 0:
        return act
    cut = assign[e[:, 0]] != assign[e[:, 1]]
    act[e[cut, 0]] = True
    act[e[cut, 1]] = True
    for _ in range(int(hops)):
        src = np.flatnonzero(act)
        flat, _ = csr_multirange(graph.indptr, src)
        if len(flat):
            act[graph.indices[flat]] = True
    return act


def _level_knobs(n_level: int, n_finest: int, cache_bytes: int,
                 chunk_nodes) -> tuple:
    """Scale the engine budgets to a level's size: the AssemblyCache budget
    shrinks with the vertex count (a coarse level's pair assemblies are
    proportionally small) and the glued-union chunk never exceeds the
    level itself."""
    frac = n_level / max(n_finest, 1)
    cb = min(int(cache_bytes),
             max(_MIN_LEVEL_CACHE, int(cache_bytes * frac)))
    if chunk_nodes == "auto":
        cn = min(AUTO_CHUNK_NODES, max(1024, n_level))
    else:
        cn = chunk_nodes
    return cb, cn


def glad_multilevel(
    cm: CostModel,
    R: Optional[int] = None,
    init: Optional[np.ndarray] = None,
    seed: int = 0,
    backend: str = "auto",
    coarsen_to: int = COARSEN_TO,
    levels: Optional[int] = None,
    refine_R: Optional[int] = None,
    refine_hops: int = 1,
    round_solver: str = "auto",
    workers: int = 0,
    worker_mode: str = "thread",
    cache: "bool | str" = "auto",
    cache_bytes: int = 256 << 20,
    chunk_nodes: "int | str" = "auto",
    warm: "bool | str" = "auto",
    mu_gate: bool = True,
    max_iterations: int = 100_000,
    on_iteration=None,
    chunk_vertices: "int | str | None" = None,
    record_levels: bool = True,
    session=None,
):
    """The V-cycle driver: coarsen, solve the coarsest level with ``R``
    patience, then project + refine each level with ``refine_R`` patience
    (default ``max(3, m)`` — the GLAD-E incremental setting) under a
    boundary-active mask.  Every solve is a plain :func:`glad_s` call
    (batched sweep), so all engine knobs compose per level.

    ``chunk_vertices`` streams the coarsening (bounded vertex windows, see
    :func:`build_levels`).  ``session`` — a
    :class:`repro.core.engine.LayoutSession` — supplies a persistent
    :class:`LevelStack` for this V-cycle configuration (reused matchings
    across relayouts of the same graph) and is adopted by the FINEST
    refinement solve (same graph as the session's flat engine; coarse
    levels always run fresh per-level engines).  ``record_levels=False``
    slims the per-level telemetry to checksums + sizes
    (:func:`_slim_level_stats`) so scale runs don't retain O(levels x n)
    replay arrays.  None of the three knobs changes the trajectory — the
    assign/cost/history stream is bit-identical with any combination.

    Returns a ``GladResult`` whose ``history``/``iterations``/``accepted``
    concatenate the per-level solves and whose ``levels`` field holds one
    stats dict per solve — including each refinement's projected ``init``
    and ``active`` mask (under ``record_levels=True``), so callers can
    replay any level on the flat engine bit-for-bit (the golden-fixture
    contract).  ``result.coarsen`` reports the LevelStack's reuse stats
    when a session was supplied.
    """
    from repro.core.glad_s import GladResult, glad_s   # lazy: import cycle

    t0 = time.perf_counter()
    coarsen_stats = None
    if session is not None:
        lstack = session.level_stack(coarsen_to=coarsen_to,
                                     max_levels=levels, mu_gate=mu_gate)
        stack = lstack.acquire(cm, chunk_vertices=chunk_vertices)
        coarsen_stats = dict(lstack.last_stats, builds=lstack.builds,
                             refreshes=lstack.refreshes)
    else:
        stack = build_levels(cm, coarsen_to=coarsen_to, max_levels=levels,
                             mu_gate=mu_gate, chunk_vertices=chunk_vertices)
    flat_kw = dict(backend=backend, sweep="batched",
                   round_solver=round_solver, workers=workers,
                   worker_mode=worker_mode, cache=cache, warm=warm,
                   max_iterations=max_iterations,
                   on_iteration=on_iteration, multilevel=False)
    n0 = cm.graph.n
    if len(stack) == 1:
        # Nothing to coarsen (tiny graph / no links): flat solve, annotated.
        res = glad_s(cm, R=R, init=init, seed=seed, cache_bytes=cache_bytes,
                     chunk_nodes=chunk_nodes, session=session, **flat_kw)
        stats = dict(level=0, role="coarsest", n=n0,
                     edges=cm.graph.num_edges, init=init, active=None,
                     R=R, cost=res.cost, iterations=res.iterations,
                     accepted=res.accepted, history=list(res.history),
                     wall_time_s=res.wall_time_s)
        res.levels = [stats if record_levels else _slim_level_stats(stats)]
        res.coarsen = coarsen_stats
        return res

    # Restrict a provided warm init down the stack (majority vote per rung).
    coarse_init = None
    if init is not None:
        coarse_init = np.asarray(init, dtype=np.int64)
        for lvl in stack[1:]:
            coarse_init = restrict_assign(
                lvl.cluster_of, lvl.cm.graph.n, coarse_init, cm.net.m)

    level_stats: List[dict] = []
    top = stack[-1]
    cb, cn = _level_knobs(top.cm.graph.n, n0, cache_bytes, chunk_nodes)
    res = glad_s(top.cm, R=R, init=coarse_init, seed=seed, cache_bytes=cb,
                 chunk_nodes=cn, **flat_kw)
    assign = res.assign
    history = list(res.history)
    iters, accepted = res.iterations, res.accepted
    stats = dict(
        level=len(stack) - 1, role="coarsest", n=top.cm.graph.n,
        edges=top.cm.graph.num_edges, init=coarse_init, active=None, R=R,
        cost=res.cost, iterations=res.iterations, accepted=res.accepted,
        history=list(res.history), wall_time_s=res.wall_time_s)
    level_stats.append(stats if record_levels else _slim_level_stats(stats))

    if refine_R is None:
        refine_R = max(3, cm.net.m)
    # Streamed session-free V-cycles own their coarse levels outright, so
    # the descent can release each level's derived caches (CSR views +
    # unary — lazily rebuilt, bitwise identical) the moment its assignment
    # has been projected down: at most two adjacent levels stay cached,
    # keeping refinement's peak RSS on the same bounded footing as the
    # streamed build.  A session's LevelStack keeps its caches — that is
    # its memory-for-refresh-speed trade.
    release_coarse = chunk_vertices is not None and session is None
    for k in range(len(stack) - 2, -1, -1):
        lvl = stack[k]
        proj = assign[stack[k + 1].cluster_of]
        act = boundary_active(lvl.cm.graph, proj, hops=refine_hops)
        stats = dict(level=k, role="refine", n=lvl.cm.graph.n,
                     edges=lvl.cm.graph.num_edges, init=proj, active=act,
                     R=refine_R)
        if not act.any():
            # Projection has no cut links at this level: nothing to refine.
            assign = proj
            stats.update(cost=float(lvl.cm.total(proj)), iterations=0,
                         accepted=0, history=[], wall_time_s=0.0)
            level_stats.append(stats if record_levels
                               else _slim_level_stats(stats))
            if release_coarse:
                from repro.core.multilevel_stream import release_level_views
                release_level_views(stack[k + 1])
            continue
        cb, cn = _level_knobs(lvl.cm.graph.n, n0, cache_bytes, chunk_nodes)
        # Only the finest refinement shares the session's graph, so only
        # it adopts the persistent engine; coarse levels run fresh
        # per-level engines (a rebind across level sizes cannot exist).
        r = glad_s(lvl.cm, R=refine_R, init=proj, active=act, seed=seed,
                   cache_bytes=cb, chunk_nodes=cn,
                   session=session if k == 0 else None, **flat_kw)
        assign = r.assign
        history.extend(r.history)
        iters += r.iterations
        accepted += r.accepted
        stats.update(cost=r.cost, iterations=r.iterations,
                     accepted=r.accepted, history=list(r.history),
                     wall_time_s=r.wall_time_s)
        level_stats.append(stats if record_levels
                           else _slim_level_stats(stats))
        if release_coarse:
            from repro.core.multilevel_stream import release_level_views
            release_level_views(stack[k + 1])

    f = cm.factors(assign)
    moved = (np.flatnonzero(assign != np.asarray(init, dtype=np.int64))
             if init is not None else np.arange(n0, dtype=np.int64))
    return GladResult(
        assign=assign, cost=f["total"], history=history, iterations=iters,
        accepted=accepted, wall_time_s=time.perf_counter() - t0, factors=f,
        moved=moved, levels=level_stats, coarsen=coarsen_stats,
    )
