"""Multilevel GLAD: a METIS-style V-cycle over the pairwise min-cut engine.

Flat GLAD sweeps pay O(n) member volume per round from the very first
iteration, so million-vertex layouts spend almost all their wall time on
first-pass cuts whose decisions are dominated by coarse cluster structure.
The V-cycle factors that structure out:

  coarsen   iterative heavy-edge matching (vectorized over the DataGraph
            CSR, decided in the quantized integer weight domain) contracts
            matched pairs into coarse vertices until ``coarsen_to`` is
            reached.  Each coarse level is a real ``DataGraph`` +
            ``CostModel`` pair: coarse edge weights are the summed fine
            weights, and the coarse unary matrix is the row-sum of the fine
            one (folded into the coarse network's ``mu``; compute and
            per-vertex maintenance coefficients are zeroed so nothing is
            double counted).  Because intra-cluster links cost tau[i,i] = 0
            under any projection, the coarse objective of a coarse
            assignment EQUALS the fine objective of its projection — the
            hierarchy restricts the search space, never distorts the cost
            (pinned by a hypothesis property test).
  solve     the coarsest level is solved by the EXISTING engine
            (:func:`repro.core.glad_s.glad_s`, batched disjoint-pair
            rounds) — no new optimizer code at any level.
  refine    each assignment is projected one level down
            (``assign[cluster_of]``) and the same engine re-runs with the
            projection as warm init and a boundary-active mask (endpoints
            of cut links + ``refine_hops`` neighborhood rings).  The active
            mask is exactly the regime the engine's 'auto' policies enable
            the AssemblyCache and warm-start (ResidualCut) for, so
            cross-round caching, persistency peeling and warm re-solves
            compose per level unchanged.  ``cache_bytes``/``chunk_nodes``
            are scaled to each level's vertex count, so coarse levels never
            reserve the finest level's budgets.

The finest refinement is literally a flat ``glad_s`` call on the original
cost model — its trajectory is bit-identical to running the flat engine
from the same projected init and mask (golden-fixture pinned).

Matching is capacity-aware (cluster fine-vertex counts are capped at
``MAX_CLUSTER_FACTOR * n / coarsen_to`` so no coarse vertex grows beyond
what a balanced layout could place) and mu-aware: a merge commits both
endpoints to one server, so candidates whose unary preference disagreement
provably exceeds the traffic the merge can save are gated out
(``MU_GATE_SLACK``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.core.cost import CostModel
from repro.core.engine import AUTO_CHUNK_NODES
from repro.graphs.datagraph import DataGraph, contract_graph, csr_multirange
from repro.graphs.edgenet import EdgeNetwork

#: ``multilevel='auto'`` turns the V-cycle on from this vertex count.
MULTILEVEL_AUTO_MIN_N = 200_000
#: Default coarsest-level size (the level the full-R solve runs at).
#: Chosen so the coarsest exhaustive-patience solve stays a small share of
#: the V-cycle wall clock at n=50k/m=32 while final cost tracks the flat
#: engine within 1e-3 (BENCH_layout multilevel cells).
COARSEN_TO = 1024
#: Matching proposal rounds per coarsening level.
MATCH_ROUNDS = 4
#: Stop coarsening when a level shrinks by less than this factor.
STAGNATION_FRAC = 0.95
#: Cluster fine-vertex cap = this factor x (n / coarsen_to).
MAX_CLUSTER_FACTOR = 1.5
#: mu gate: allow a merge only while the unary disagreement lower bound
#: stays under SLACK x tau_ref x link weight (the traffic scale the merge
#: can save).  Permissive on purpose — it prunes egregious merges only.
MU_GATE_SLACK = 4.0
#: Integer domain for matching decisions (mirrors maxflow's quantization).
_WQ_SCALE = 10 ** 7
#: Floor for a level's scaled AssemblyCache budget.
_MIN_LEVEL_CACHE = 8 << 20


@dataclasses.dataclass
class Level:
    """One rung of the coarsening hierarchy.

    ``cluster_of`` maps the NEXT-FINER level's vertices onto this level's
    (``None`` at the finest level).  ``vertex_w`` counts the fine vertices
    each coarse vertex carries (the capacity weight the matcher caps).
    """

    cm: CostModel
    cluster_of: Optional[np.ndarray]
    vertex_w: np.ndarray


def quantize_weights(w: np.ndarray) -> np.ndarray:
    """Edge weights -> the integer domain matching decisions are made in
    (scale-invariant, deterministic ties)."""
    mx = float(w.max()) if len(w) else 0.0
    if mx <= 0.0:
        return np.zeros(len(w), dtype=np.int64)
    return np.rint(w * (_WQ_SCALE / mx)).astype(np.int64)


def _mix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Deterministic per-(vertex, neighbor) hash for tie-breaking.

    Equal-weight candidates (the whole finest level, when links are unit
    weight) must not all prefer the same smallest-id neighbor — that herds
    every proposal onto a few hubs and each handshake round matches only
    one tail per hub.  A splitmix-style hash spreads the ties uniformly
    while staying a pure function of the ids (coarsening stays
    deterministic, no RNG)."""
    x = (a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         ^ (b.astype(np.uint64) + np.uint64(0xBF58476D1CE4E5B9)))
    x ^= x >> np.uint64(31)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(29)
    return x


def heavy_edge_matching(
    graph: DataGraph,
    vertex_w: np.ndarray,
    max_w: int,
    unary: Optional[np.ndarray] = None,
    tau_ref: float = 0.0,
    rounds: int = MATCH_ROUNDS,
) -> np.ndarray:
    """Iterative HEM over the CSR: ``match[v]`` = partner (or v itself).

    Per round: every unmatched vertex PROPOSES to its heaviest eligible
    unmatched neighbor (integer-quantized weight; ties broken by the
    deterministic :func:`_mix` hash so equal-weight levels don't herd onto
    hubs).  Every proposed-to vertex then ACCEPTS its heaviest incoming
    proposer, overriding its own outgoing proposal — the incoming-aware
    handshake is what lets a hub pair up every round instead of chasing a
    neighbor that never looks back.  Vertices whose accept/propose
    pointers agree (``c[c[v]] == v``) match.  Eligibility = the merged
    capacity weight fits ``max_w`` and, when ``unary`` is given, the mu
    gate holds.  Fully deterministic — no RNG anywhere, so coarsening is a
    pure function of the cost model (the determinism the smoke bench
    pins).
    """
    n = graph.n
    match = np.arange(n, dtype=np.int64)
    if graph.num_edges == 0:
        return match
    indptr, indices, eids = graph.indptr, graph.indices, graph.edge_ids
    w = graph.weights_or_ones().astype(np.float64)
    wq = quantize_weights(w)
    matched = np.zeros(n, dtype=bool)
    if unary is not None:
        pref = np.argmin(unary, axis=1).astype(np.int64)
        base = unary[np.arange(n), pref]
    for _ in range(rounds):
        un = np.flatnonzero(~matched)
        flat, rep = csr_multirange(indptr, un)
        if len(flat) == 0:
            break
        v = un[rep]
        nbr = indices[flat]
        ew = eids[flat]
        ok = ~matched[nbr]
        ok &= vertex_w[v] + vertex_w[nbr] <= max_w
        if unary is not None and tau_ref > 0.0:
            # Lower bound on the unary penalty of co-locating v and nbr:
            # one of them must leave its preferred server.
            d_lb = np.minimum(unary[v, pref[nbr]] - base[v],
                              unary[nbr, pref[v]] - base[nbr])
            ok &= MU_GATE_SLACK * tau_ref * w[ew] >= d_lb
        if not ok.any():
            break
        v, nbr, cw = v[ok], nbr[ok], wq[ew[ok]]
        h = _mix(v, nbr)
        # Proposal: per proposer v, heaviest neighbor, hash tie-break.
        order = np.lexsort((h, -cw, v))
        vs_, nb_, cw_, h_ = v[order], nbr[order], cw[order], h[order]
        head = np.ones(len(order), dtype=bool)
        head[1:] = vs_[1:] != vs_[:-1]
        pv, pt = vs_[head], nb_[head]            # proposer -> target
        pw, ph = cw_[head], h_[head]
        # Acceptance: per target, heaviest incoming proposer (hash, then
        # proposer id, break residual ties deterministically).
        order2 = np.lexsort((pv, ph, -pw, pt))
        t2, p2 = pt[order2], pv[order2]
        head2 = np.ones(len(order2), dtype=bool)
        head2[1:] = t2[1:] != t2[:-1]
        c = np.full(n, -1, dtype=np.int64)
        c[pv] = pt                               # own outgoing proposal
        c[t2[head2]] = p2[head2]                 # incoming winner overrides
        cand = np.flatnonzero(c >= 0)
        partner = c[cand]
        mutual = (c[partner] == cand) & (cand < partner)
        a, b = cand[mutual], partner[mutual]
        if len(a) == 0:
            break
        match[a] = b
        match[b] = a
        matched[a] = True
        matched[b] = True
    return match


def clusters_from_matching(match: np.ndarray):
    """Matching -> (cluster_of, num_clusters); coarse ids ordered by each
    cluster's smallest member id (deterministic)."""
    rep = np.minimum(np.arange(len(match), dtype=np.int64), match)
    uniq, cluster_of = np.unique(rep, return_inverse=True)
    return cluster_of.astype(np.int64), int(len(uniq))


def coarse_cost_model(
    cm: CostModel, graph_c: DataGraph, cluster_of: np.ndarray, nc: int
) -> CostModel:
    """Exact coarse model: coarse ``mu`` rows are the summed fine ``unary``
    rows; compute/per-vertex-maintenance coefficients are zeroed (already
    inside the fine unary), ``tau``/``w``/``eps`` carry over.  The coarse
    ``unary`` therefore equals the summed fine unary and, with summed edge
    weights and tau[i,i] = 0, the coarse total of any coarse assignment
    equals the fine total of its projection (up to float summation order).
    """
    net = cm.net
    order = np.argsort(cluster_of, kind="stable")
    starts = np.searchsorted(cluster_of[order], np.arange(nc))
    mu_c = np.add.reduceat(cm.unary[order], starts, axis=0)
    zeros = np.zeros(net.m, dtype=np.float64)
    net_c = EdgeNetwork(
        m=net.m, w=net.w, tau=net.tau, alpha=zeros, beta=zeros, gamma=zeros,
        rho=zeros, eps=net.eps, mu=mu_c, sku=net.sku, coords=net.coords,
    )
    return CostModel(net_c, graph_c, cm.gnn)


def build_levels(
    cm: CostModel,
    coarsen_to: int = COARSEN_TO,
    max_levels: Optional[int] = None,
    mu_gate: bool = True,
) -> List[Level]:
    """Coarsening hierarchy, finest first.  Stops at ``coarsen_to``
    vertices, at ``max_levels`` rungs, or when matching stagnates."""
    levels = [Level(cm=cm, cluster_of=None,
                    vertex_w=np.ones(cm.graph.n, dtype=np.int64))]
    tau_ref = cm.tau_ref() if mu_gate else 0.0
    cap = max(2, int(np.ceil(
        MAX_CLUSTER_FACTOR * cm.graph.n / max(coarsen_to, 1))))
    while True:
        cur = levels[-1]
        g = cur.cm.graph
        if g.n <= coarsen_to or g.num_edges == 0:
            break
        if max_levels is not None and len(levels) >= max_levels:
            break
        match = heavy_edge_matching(
            g, cur.vertex_w, cap,
            unary=cur.cm.unary if mu_gate else None, tau_ref=tau_ref)
        cluster_of, nc = clusters_from_matching(match)
        if nc >= STAGNATION_FRAC * g.n:
            break
        g_c = contract_graph(g, cluster_of, nc)
        cm_c = coarse_cost_model(cur.cm, g_c, cluster_of, nc)
        vw_c = np.bincount(cluster_of, weights=cur.vertex_w,
                           minlength=nc).astype(np.int64)
        levels.append(Level(cm=cm_c, cluster_of=cluster_of, vertex_w=vw_c))
    return levels


def restrict_assign(cluster_of: np.ndarray, nc: int, assign: np.ndarray,
                    m: int) -> np.ndarray:
    """Fine -> coarse restriction of a warm init: member-weighted majority
    vote per cluster, ties to the smallest server id."""
    cnt = np.bincount(cluster_of * m + assign, minlength=nc * m)
    return cnt.reshape(nc, m).argmax(axis=1).astype(np.int64)


def boundary_active(graph: DataGraph, assign: np.ndarray,
                    hops: int = 1) -> np.ndarray:
    """Refinement mask: endpoints of cut links, expanded ``hops`` rings."""
    act = np.zeros(graph.n, dtype=bool)
    e = graph.edges
    if len(e) == 0:
        return act
    cut = assign[e[:, 0]] != assign[e[:, 1]]
    act[e[cut, 0]] = True
    act[e[cut, 1]] = True
    for _ in range(int(hops)):
        src = np.flatnonzero(act)
        flat, _ = csr_multirange(graph.indptr, src)
        if len(flat):
            act[graph.indices[flat]] = True
    return act


def _level_knobs(n_level: int, n_finest: int, cache_bytes: int,
                 chunk_nodes) -> tuple:
    """Scale the engine budgets to a level's size: the AssemblyCache budget
    shrinks with the vertex count (a coarse level's pair assemblies are
    proportionally small) and the glued-union chunk never exceeds the
    level itself."""
    frac = n_level / max(n_finest, 1)
    cb = min(int(cache_bytes),
             max(_MIN_LEVEL_CACHE, int(cache_bytes * frac)))
    if chunk_nodes == "auto":
        cn = min(AUTO_CHUNK_NODES, max(1024, n_level))
    else:
        cn = chunk_nodes
    return cb, cn


def glad_multilevel(
    cm: CostModel,
    R: Optional[int] = None,
    init: Optional[np.ndarray] = None,
    seed: int = 0,
    backend: str = "auto",
    coarsen_to: int = COARSEN_TO,
    levels: Optional[int] = None,
    refine_R: Optional[int] = None,
    refine_hops: int = 1,
    round_solver: str = "auto",
    workers: int = 0,
    worker_mode: str = "thread",
    cache: "bool | str" = "auto",
    cache_bytes: int = 256 << 20,
    chunk_nodes: "int | str" = "auto",
    warm: "bool | str" = "auto",
    mu_gate: bool = True,
    max_iterations: int = 100_000,
    on_iteration=None,
):
    """The V-cycle driver: coarsen, solve the coarsest level with ``R``
    patience, then project + refine each level with ``refine_R`` patience
    (default ``max(3, m)`` — the GLAD-E incremental setting) under a
    boundary-active mask.  Every solve is a plain :func:`glad_s` call
    (batched sweep), so all engine knobs compose per level.

    Returns a ``GladResult`` whose ``history``/``iterations``/``accepted``
    concatenate the per-level solves and whose ``levels`` field holds one
    stats dict per solve — including each refinement's projected ``init``
    and ``active`` mask, so callers can replay any level on the flat
    engine bit-for-bit (the golden-fixture contract).
    """
    from repro.core.glad_s import GladResult, glad_s   # lazy: import cycle

    t0 = time.perf_counter()
    stack = build_levels(cm, coarsen_to=coarsen_to, max_levels=levels,
                         mu_gate=mu_gate)
    flat_kw = dict(backend=backend, sweep="batched",
                   round_solver=round_solver, workers=workers,
                   worker_mode=worker_mode, cache=cache, warm=warm,
                   max_iterations=max_iterations,
                   on_iteration=on_iteration, multilevel=False)
    n0 = cm.graph.n
    if len(stack) == 1:
        # Nothing to coarsen (tiny graph / no links): flat solve, annotated.
        res = glad_s(cm, R=R, init=init, seed=seed, cache_bytes=cache_bytes,
                     chunk_nodes=chunk_nodes, **flat_kw)
        res.levels = [dict(level=0, role="coarsest", n=n0,
                           edges=cm.graph.num_edges, init=init, active=None,
                           R=R, cost=res.cost, iterations=res.iterations,
                           accepted=res.accepted, history=list(res.history),
                           wall_time_s=res.wall_time_s)]
        return res

    # Restrict a provided warm init down the stack (majority vote per rung).
    coarse_init = None
    if init is not None:
        coarse_init = np.asarray(init, dtype=np.int64)
        for lvl in stack[1:]:
            coarse_init = restrict_assign(
                lvl.cluster_of, lvl.cm.graph.n, coarse_init, cm.net.m)

    level_stats: List[dict] = []
    top = stack[-1]
    cb, cn = _level_knobs(top.cm.graph.n, n0, cache_bytes, chunk_nodes)
    res = glad_s(top.cm, R=R, init=coarse_init, seed=seed, cache_bytes=cb,
                 chunk_nodes=cn, **flat_kw)
    assign = res.assign
    history = list(res.history)
    iters, accepted = res.iterations, res.accepted
    level_stats.append(dict(
        level=len(stack) - 1, role="coarsest", n=top.cm.graph.n,
        edges=top.cm.graph.num_edges, init=coarse_init, active=None, R=R,
        cost=res.cost, iterations=res.iterations, accepted=res.accepted,
        history=list(res.history), wall_time_s=res.wall_time_s))

    if refine_R is None:
        refine_R = max(3, cm.net.m)
    for k in range(len(stack) - 2, -1, -1):
        lvl = stack[k]
        proj = assign[stack[k + 1].cluster_of]
        act = boundary_active(lvl.cm.graph, proj, hops=refine_hops)
        stats = dict(level=k, role="refine", n=lvl.cm.graph.n,
                     edges=lvl.cm.graph.num_edges, init=proj, active=act,
                     R=refine_R)
        if not act.any():
            # Projection has no cut links at this level: nothing to refine.
            assign = proj
            stats.update(cost=float(lvl.cm.total(proj)), iterations=0,
                         accepted=0, history=[], wall_time_s=0.0)
            level_stats.append(stats)
            continue
        cb, cn = _level_knobs(lvl.cm.graph.n, n0, cache_bytes, chunk_nodes)
        r = glad_s(lvl.cm, R=refine_R, init=proj, active=act, seed=seed,
                   cache_bytes=cb, chunk_nodes=cn, **flat_kw)
        assign = r.assign
        history.extend(r.history)
        iters += r.iterations
        accepted += r.accepted
        stats.update(cost=r.cost, iterations=r.iterations,
                     accepted=r.accepted, history=list(r.history),
                     wall_time_s=r.wall_time_s)
        level_stats.append(stats)

    f = cm.factors(assign)
    moved = (np.flatnonzero(assign != np.asarray(init, dtype=np.int64))
             if init is not None else np.arange(n0, dtype=np.int64))
    return GladResult(
        assign=assign, cost=f["total"], history=history, iterations=iters,
        accepted=accepted, wall_time_s=time.perf_counter() - t0, factors=f,
        moved=moved, levels=level_stats,
    )
