"""Version-compatible accessors for JAX APIs that drifted across releases.

The repo targets current JAX but must run on older installs (the pinned CI
image ships 0.4.x).  Three surfaces moved:

  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)`` —
    absent before 0.5; meshes there are implicitly fully ``Auto``.
  * ``jax.shard_map`` — lived at ``jax.experimental.shard_map.shard_map``
    with ``check_rep`` instead of ``check_vma``.
  * ``jax.lax.ragged_dot_general`` / ``RaggedDotDimensionNumbers`` — absent;
    callers need a segment-sum fallback for the grouped outer product
    (see models/moe.py).

Every accessor resolves the feature at call time (not import time) so test
monkeypatching and lazy plugin loading keep working.
"""
from __future__ import annotations

import jax


def axis_type_auto():
    """``jax.sharding.AxisType.Auto`` where it exists, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return getattr(axis_type, "Auto", None)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with all-Auto axis types when supported.

    Older JAX has neither ``AxisType`` nor the ``axis_types`` kwarg; its
    meshes behave as fully automatic, which is exactly what every caller
    here wants, so omitting the kwarg is semantically equivalent.
    """
    auto = axis_type_auto()
    if auto is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(auto,) * len(axis_names))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old.

    ``check_vma`` maps onto the legacy ``check_rep`` flag (same meaning:
    statically verify per-value replication/varying-axes annotations).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every JAX version.

    Old JAX wrapped the per-device properties in a one-element list; new
    JAX returns the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def pallas_tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the CompilerParams rename.

    New JAX exposes ``pltpu.CompilerParams``; older releases call the same
    dataclass ``TPUCompilerParams``.
    """
    import jax.experimental.pallas.tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def has_ragged_dot_general() -> bool:
    return hasattr(jax.lax, "ragged_dot_general") and hasattr(
        jax.lax, "RaggedDotDimensionNumbers")
