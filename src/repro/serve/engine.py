"""Batched serving engine: continuous batching over a slot-based KV cache.

One jitted decode_step serves B slots per tick; requests flow through
  queue -> prefill (builds the request's KV, written into a free slot)
  -> decode ticks (all live slots advance one token)
  -> completion (EOS / max_new_tokens) frees the slot.

Per-slot lengths ride in the cache's ``len`` vector, so ragged occupancy
needs no recompilation.  This is the paper's "resident service" pattern
(Sec. II-A: GNN services process streams continuously) applied to LM decode.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models as zoo
from repro.models.common import LMConfig
from repro.models.transformer import Dist


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (L,) i32
    max_new_tokens: int = 16
    eos_id: int = 0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    prefills: int = 0
    completed: int = 0
    generated_tokens: int = 0


class ServeEngine:
    """Only transformer-family archs (KV-cache semantics) for now; SSM
    archs decode through their own state caches via the same interface."""

    def __init__(self, cfg: LMConfig, params, slots: int = 4,
                 max_len: int = 256, dist: Dist = Dist()):
        self.cfg, self.params, self.dist = cfg, params, dist
        self.slots = slots
        self.max_len = max_len
        self.cache = zoo.init_cache(cfg, slots, max_len)
        self.live: List[Optional[Request]] = [None] * slots
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()
        # Prompt-length bucketing: pad prompts to power-of-2 buckets so the
        # jitted prefill traces O(log max_len) specializations instead of
        # one per distinct length (a compile storm under real traffic).
        # Only KV-cache families — pad positions are inert there (causal
        # attention + decode's len-mask).  Recurrent families (ssm/xlstm)
        # thread pad tokens through their state, and vlm offsets positions
        # by the patch count, so both keep exact-length prefill.
        self._bucketed = cfg.family in ("dense", "moe")
        # Trace counters (same contract as make_bsp_forward's stats): the
        # increment runs at TRACE time only, so tests can assert the
        # retrace bound directly.
        self.trace_counts = {"prefill": 0, "decode": 0}

        def _decode_fn(p, t, c):
            self.trace_counts["decode"] += 1
            return zoo.decode_step(cfg, p, t, c, dist)

        def _prefill_fn(p, b):
            self.trace_counts["prefill"] += 1
            return zoo.prefill(cfg, p, b, max_len, dist)

        self._decode = jax.jit(_decode_fn)
        self._prefill = jax.jit(_prefill_fn)

    # ----------------------------------------------------------------- admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.live) if r is None]

    @staticmethod
    def _bucket(length: int) -> int:
        """Smallest power of two >= length."""
        return 1 << max(length - 1, 0).bit_length()

    def _insert(self, slot: int, req: Request) -> bool:
        """Prefill one request; splice its KV into the batch cache.  If the
        request already finishes at prefill (first generated token is EOS,
        or a one-token budget), it completes here and the slot stays free —
        returns True iff the slot was occupied."""
        L = len(req.prompt)
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        if self._bucketed:
            bucket = min(self._bucket(L), self.max_len)
            prompt = jnp.pad(prompt, ((0, 0), (0, bucket - L)))
            batch = {"tokens": prompt,
                     "lengths": jnp.asarray([L], jnp.int32)}
        else:
            batch = {"tokens": prompt}
        logits, rcache = self._prefill(self.params, batch)
        self.stats.prefills += 1
        tok = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(tok)
        if tok == req.eos_id or req.max_new_tokens <= 1:
            req.done = True
            self.stats.completed += 1
            return False
        for key in ("k", "v"):
            if key in self.cache:
                self.cache[key] = self.cache[key].at[:, slot].set(
                    rcache[key][:, 0])
        for key in rcache:
            if key in ("k", "v", "len"):
                continue
            if key in self.cache:            # ssm states etc.
                self.cache[key] = self.cache[key].at[:, slot].set(
                    rcache[key][:, 0])
        self.cache["len"] = self.cache["len"].at[slot].set(L)
        self.live[slot] = req
        return True

    # ------------------------------------------------------------------ tick
    def tick(self):
        """Admit from queue, then advance every live slot one token."""
        for slot in self._free_slots():
            # A request that completes at prefill leaves the slot free for
            # the next queued one.
            while self.queue:
                if self._insert(slot, self.queue.popleft()):
                    break

        if not any(r is not None for r in self.live):
            return

        last = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.live):
            if r is not None:
                last[i, 0] = r.out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(last), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        # One host transfer for all slot lengths — the per-slot
        # int(self.cache["len"][i]) reads were a device sync per live slot
        # per tick.
        lens = np.asarray(self.cache["len"])
        self.stats.ticks += 1

        for i, r in enumerate(self.live):
            if r is None:
                continue
            tok = int(nxt[i])
            r.out_tokens.append(tok)
            self.stats.generated_tokens += 1
            full = int(lens[i]) >= self.max_len - 1
            if tok == r.eos_id or len(r.out_tokens) >= r.max_new_tokens or full:
                r.done = True
                self.live[i] = None
                self.cache["len"] = self.cache["len"].at[i].set(0)
                self.stats.completed += 1

    def run(self, max_ticks: int = 1000):
        while (self.queue or any(r is not None for r in self.live)) \
                and self.stats.ticks < max_ticks:
            self.tick()
        return self.stats
