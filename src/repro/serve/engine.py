"""Batched serving engine: continuous batching over a slot-based KV cache.

One jitted decode_step serves B slots per tick; requests flow through
  queue -> prefill (builds the request's KV, written into a free slot)
  -> decode ticks (all live slots advance one token)
  -> completion (EOS / max_new_tokens) frees the slot.

Per-slot lengths ride in the cache's ``len`` vector, so ragged occupancy
needs no recompilation.  This is the paper's "resident service" pattern
(Sec. II-A: GNN services process streams continuously) applied to LM decode.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models as zoo
from repro.models.common import LMConfig
from repro.models.transformer import Dist


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (L,) i32
    max_new_tokens: int = 16
    eos_id: int = 0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    prefills: int = 0
    completed: int = 0
    generated_tokens: int = 0


class ServeEngine:
    """Only transformer-family archs (KV-cache semantics) for now; SSM
    archs decode through their own state caches via the same interface."""

    def __init__(self, cfg: LMConfig, params, slots: int = 4,
                 max_len: int = 256, dist: Dist = Dist()):
        self.cfg, self.params, self.dist = cfg, params, dist
        self.slots = slots
        self.max_len = max_len
        self.cache = zoo.init_cache(cfg, slots, max_len)
        self.live: List[Optional[Request]] = [None] * slots
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()

        self._decode = jax.jit(
            lambda p, t, c: zoo.decode_step(cfg, p, t, c, dist))
        self._prefill = jax.jit(
            lambda p, b: zoo.prefill(cfg, p, b, max_len, dist),
            static_argnames=())

    # ----------------------------------------------------------------- admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.live) if r is None]

    def _insert(self, slot: int, req: Request):
        """Prefill one request and splice its KV into the batch cache."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        batch = {"tokens": prompt}
        logits, rcache = self._prefill(self.params, batch)
        L = len(req.prompt)
        for key in ("k", "v"):
            if key in self.cache:
                self.cache[key] = self.cache[key].at[:, slot].set(
                    rcache[key][:, 0])
        for key in rcache:
            if key in ("k", "v", "len"):
                continue
            if key in self.cache:            # ssm states etc.
                self.cache[key] = self.cache[key].at[:, slot].set(
                    rcache[key][:, 0])
        self.cache["len"] = self.cache["len"].at[slot].set(L)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(tok)
        self.live[slot] = req
        self.stats.prefills += 1

    # ------------------------------------------------------------------ tick
    def tick(self):
        """Admit from queue, then advance every live slot one token."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._insert(slot, self.queue.popleft())

        if not any(r is not None for r in self.live):
            return

        last = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.live):
            if r is not None:
                last[i, 0] = r.out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(last), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        self.stats.ticks += 1

        for i, r in enumerate(self.live):
            if r is None:
                continue
            tok = int(nxt[i])
            r.out_tokens.append(tok)
            self.stats.generated_tokens += 1
            full = int(self.cache["len"][i]) >= self.max_len - 1
            if tok == r.eos_id or len(r.out_tokens) >= r.max_new_tokens or full:
                r.done = True
                self.live[i] = None
                self.cache["len"] = self.cache["len"].at[i].set(0)
                self.stats.completed += 1

    def run(self, max_ticks: int = 1000):
        while (self.queue or any(r is not None for r in self.live)) \
                and self.stats.ticks < max_ticks:
            self.tick()
        return self.stats
