"""GNN training (node classification, the paper's SIoT/Yelp tasks).

Single-device full-graph training plus the distributed train step: gradients
of the BSP forward are psum'd across the data axis (each device owns the loss
of its resident vertices — the layout decides who computes what, exactly the
paper's C_P accounting).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn.models import GNNConfig, forward, loss_fn


def sgd_step(params, grads, lr: float):
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)


@functools.partial(jax.jit, static_argnums=(0, 5))
def train_step(cfg: GNNConfig, params, features, src_dst, labels, lr: float,
               mask=None):
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, features, src_dst, labels, mask))(params)
    return sgd_step(params, grads, lr), loss


def fit(cfg: GNNConfig, params, features, src_dst, labels, steps: int = 100,
        lr: float = 0.05, mask=None, log_every: int = 0):
    """Full-batch training loop; returns (params, losses)."""
    losses = []
    feats = jnp.asarray(features)
    sd = jnp.asarray(src_dst)
    lab = jnp.asarray(labels)
    for s in range(steps):
        params, loss = train_step(cfg, params, feats, sd, lab, lr, mask)
        losses.append(float(loss))
        if log_every and s % log_every == 0:
            print(f"step {s:4d} loss {float(loss):.4f}")
    return params, losses


def accuracy(cfg: GNNConfig, params, features, src_dst, labels) -> float:
    logits = forward(cfg, params, jnp.asarray(features), jnp.asarray(src_dst))
    pred = np.asarray(jnp.argmax(logits, -1))
    return float((pred == np.asarray(labels)).mean())


def make_distributed_train_step(
    cfg: GNNConfig, bsp_forward: Callable, labels_blocks, mask_blocks,
    lr: float = 0.05,
):
    """Distributed train step over the BSP engine.

    ``bsp_forward(params, blocks) -> blocks`` is the shard_map'd forward from
    gnn.distributed; labels/mask are (P, cap) blocks.  Grads flow through the
    collectives (ppermute/all_gather transpose to themselves / reduce-scatter)
    so no manual psum is needed — shard_map handles the adjoint exchange.
    """
    labels_blocks = jnp.asarray(labels_blocks)
    mask_blocks = jnp.asarray(mask_blocks).astype(jnp.float32)

    def loss_of(params, blocks):
        out = bsp_forward(params, blocks)                   # (P, cap, classes)
        logp = jax.nn.log_softmax(out, axis=-1)
        nll = -jnp.take_along_axis(logp, labels_blocks[..., None], axis=-1)[..., 0]
        nll = nll * mask_blocks
        return nll.sum() / jnp.maximum(mask_blocks.sum(), 1.0)

    @jax.jit
    def step(params, blocks):
        loss, grads = jax.value_and_grad(loss_of)(params, blocks)
        return sgd_step(params, grads, lr), loss

    return step
