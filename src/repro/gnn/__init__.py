from repro.gnn.models import (
    GNNConfig, directed_edges, forward, init_params, loss_fn, predict,
    segment_sum,
)
from repro.gnn.distributed import (
    PlanBSR, PlanCaps, PlanDelta, ShardPlan, build_plan_bsr, compile_plan,
    gather_outputs, make_bsp_forward, patch_plan, plan_caps, plans_equal,
    recompile_like, scatter_features, scatter_ints, simulate_bsp_forward,
)

__all__ = [
    "GNNConfig", "directed_edges", "forward", "init_params", "loss_fn",
    "predict", "segment_sum",
    "PlanBSR", "PlanCaps", "PlanDelta", "ShardPlan", "build_plan_bsr",
    "compile_plan", "gather_outputs", "make_bsp_forward", "patch_plan",
    "plan_caps", "plans_equal", "recompile_like", "scatter_features",
    "scatter_ints", "simulate_bsp_forward",
]
