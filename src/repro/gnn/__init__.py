from repro.gnn.models import (
    GNNConfig, directed_edges, forward, init_params, loss_fn, predict,
    segment_sum,
)
from repro.gnn.distributed import (
    PlanBSR, PlanCaps, PlanDelta, ShardPlan, build_plan_bsr, compile_plan,
    gather_outputs, make_bsp_forward, patch_plan, plan_caps, plans_equal,
    recompile_like, scatter_features, scatter_ints, scatter_replica_halo,
    set_replication, simulate_bsp_forward,
)
from repro.gnn.serving import (
    EgoBatch, FeatureCache, GNNServeEngine, ServeStats, ego_tables,
    extract_ego, extract_ego_batch, link_traffic, make_ego_forward,
    replicate_for_stream, request_traffic, serving_cost, zipf_requests,
)

__all__ = [
    "GNNConfig", "directed_edges", "forward", "init_params", "loss_fn",
    "predict", "segment_sum",
    "PlanBSR", "PlanCaps", "PlanDelta", "ShardPlan", "build_plan_bsr",
    "compile_plan", "gather_outputs", "make_bsp_forward", "patch_plan",
    "plan_caps", "plans_equal", "recompile_like", "scatter_features",
    "scatter_ints", "scatter_replica_halo", "set_replication",
    "simulate_bsp_forward",
    "EgoBatch", "FeatureCache", "GNNServeEngine", "ServeStats", "ego_tables",
    "extract_ego", "extract_ego_batch", "link_traffic", "make_ego_forward",
    "replicate_for_stream", "request_traffic", "serving_cost",
    "zipf_requests",
]
