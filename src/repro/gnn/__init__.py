from repro.gnn.models import (
    GNNConfig, directed_edges, forward, init_params, loss_fn, predict,
    segment_sum,
)
from repro.gnn.distributed import (
    ShardPlan, compile_plan, gather_outputs, make_bsp_forward,
    scatter_features, scatter_ints, simulate_bsp_forward,
)

__all__ = [
    "GNNConfig", "directed_edges", "forward", "init_params", "loss_fn",
    "predict", "segment_sum",
    "ShardPlan", "compile_plan", "gather_outputs", "make_bsp_forward",
    "scatter_features", "scatter_ints", "simulate_bsp_forward",
]
