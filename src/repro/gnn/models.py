"""GNN models exactly per the paper's Sec. II-A execution semantics.

  GCN  (Eq. 1):  a_v = sum_{u in N_v} h_u
                 h_v' = sigma(W . (a_v + h_v) / (|N_v| + 1))
  GAT  (Eq. 2):  a_v = sum_{u in N_v u {v}} eta_vu . W h_u,  h_v' = sigma(a_v)
  SAGE (Eq. 3):  a_v = mean_{u in N_v} h_u
                 h_v' = sigma(W . concat(a_v, h_v))

All models are pure functions over a params pytree and an edge list; the
neighbor aggregation runs through a pluggable ``segment_sum`` so the Pallas
kernel (kernels/gnn_aggregate) and the distributed BSP engine can reuse the
same layer semantics.  Graphs are encoded as a directed src->dst edge array
(each undirected link appears twice) — the canonical message-passing layout.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Aggregate = Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray]
# (messages (E, d), dst_ids (E,), num_nodes) -> (n, d) summed per dst.


def segment_sum(messages: jnp.ndarray, dst: jnp.ndarray, n: int) -> jnp.ndarray:
    """Default jnp aggregation (the ref path; kernels/ops.py overrides)."""
    return jax.ops.segment_sum(messages, dst, num_segments=n)


def directed_edges(edges: np.ndarray) -> np.ndarray:
    """Undirected (E,2) u<v edge list -> directed (2E,2) src->dst pairs."""
    if len(edges) == 0:
        return np.zeros((0, 2), dtype=np.int32)
    fwd = edges
    bwd = edges[:, ::-1]
    return np.concatenate([fwd, bwd], axis=0).astype(np.int32)


def degrees_from_directed(src_dst: jnp.ndarray, n: int) -> jnp.ndarray:
    ones = jnp.ones((src_dst.shape[0],), jnp.float32)
    return jax.ops.segment_sum(ones, src_dst[:, 1], num_segments=n)


# ---------------------------------------------------------------- parameters
def _glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    lim = (6.0 / (fan_in + fan_out)) ** 0.5
    return jax.random.uniform(key, shape, dtype, -lim, lim)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    model: str                      # 'gcn' | 'gat' | 'sage'
    layer_dims: Sequence[int]       # [s_0, ..., s_K]
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        # Tuple-ize so the config is hashable (jit static argument).
        object.__setattr__(self, "layer_dims", tuple(self.layer_dims))

    @property
    def num_layers(self) -> int:
        return len(self.layer_dims) - 1


def init_params(key: jax.Array, cfg: GNNConfig):
    params = []
    for k in range(cfg.num_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        d_in, d_out = cfg.layer_dims[k], cfg.layer_dims[k + 1]
        if cfg.model == "gcn":
            layer = {"w": _glorot(k1, (d_in, d_out), cfg.dtype)}
        elif cfg.model == "gat":
            layer = {
                "w": _glorot(k1, (d_in, d_out), cfg.dtype),
                "att_src": _glorot(k2, (d_out, 1), cfg.dtype)[:, 0],
                "att_dst": _glorot(k3, (d_out, 1), cfg.dtype)[:, 0],
            }
        elif cfg.model == "sage":
            layer = {"w": _glorot(k1, (2 * d_in, d_out), cfg.dtype)}
        else:
            raise ValueError(cfg.model)
        params.append(layer)
    return params


# -------------------------------------------------------------------- layers
def _activation(x: jnp.ndarray, last: bool) -> jnp.ndarray:
    return x if last else jax.nn.relu(x)


def gcn_layer(p, h, src_dst, deg, n, last, aggregate: Aggregate):
    msgs = h[src_dst[:, 0]]
    agg = aggregate(msgs, src_dst[:, 1], n)                       # sum_{N_v} h_u
    out = (agg + h) / (deg[:, None] + 1.0)                        # / (|N_v|+1)
    return _activation(out @ p["w"], last)


def gat_layer(p, h, src_dst, deg, n, last, aggregate: Aggregate):
    wh = h @ p["w"]                                               # W h_u
    # Attention logits per link (GATv1): LeakyReLU(a_s . Wh_dst + a_d . Wh_src)
    alpha_dst = wh @ p["att_src"]                                 # (n,)
    alpha_src = wh @ p["att_dst"]                                 # (n,)
    # Self loops: every vertex attends to itself too (Eq. 2: N_v u {v}).
    self_ids = jnp.arange(n, dtype=src_dst.dtype)
    src = jnp.concatenate([src_dst[:, 0], self_ids])
    dst = jnp.concatenate([src_dst[:, 1], self_ids])
    logits = jax.nn.leaky_relu(alpha_dst[dst] + alpha_src[src], 0.2)
    # Softmax over each dst's incoming links (numerically stable via segment max).
    seg_max = jax.ops.segment_max(logits, dst, num_segments=n)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(logits - seg_max[dst])
    denom = aggregate(ex[:, None], dst, n)[:, 0]                  # sum exp per dst
    eta = ex / jnp.maximum(denom[dst], 1e-16)                     # eta_vu
    agg = aggregate(eta[:, None] * wh[src], dst, n)               # sum eta W h_u
    return _activation(agg, last)


def sage_layer(p, h, src_dst, deg, n, last, aggregate: Aggregate):
    msgs = h[src_dst[:, 0]]
    agg = aggregate(msgs, src_dst[:, 1], n) / jnp.maximum(deg, 1.0)[:, None]
    cat = jnp.concatenate([agg, h], axis=-1)                      # (a_v, h_v)
    return _activation(cat @ p["w"], last)


_LAYERS = {"gcn": gcn_layer, "gat": gat_layer, "sage": sage_layer}


def forward(
    cfg: GNNConfig,
    params,
    features: jnp.ndarray,
    src_dst: jnp.ndarray,
    n: Optional[int] = None,
    aggregate: Aggregate = segment_sum,
) -> jnp.ndarray:
    """Full-graph inference: features (n, s_0) -> embeddings (n, s_K)."""
    n = n if n is not None else features.shape[0]
    deg = degrees_from_directed(src_dst, n)
    layer_fn = _LAYERS[cfg.model]
    h = features.astype(cfg.dtype)
    for k, p in enumerate(params):
        h = layer_fn(p, h, src_dst, deg, n, k == cfg.num_layers - 1, aggregate)
    return h


def loss_fn(cfg: GNNConfig, params, features, src_dst, labels, mask=None,
            aggregate: Aggregate = segment_sum):
    """Node-classification cross entropy (the paper's SIoT/Yelp tasks)."""
    logits = forward(cfg, params, features, src_dst, aggregate=aggregate)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


@functools.partial(jax.jit, static_argnums=(0,))
def predict(cfg: GNNConfig, params, features, src_dst):
    return jnp.argmax(forward(cfg, params, features, src_dst), axis=-1)
