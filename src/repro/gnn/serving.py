"""Request-driven GNN serving over the live ShardPlan (paper Sec. II-A).

Everything else in the repo is whole-graph BSP forward; the paper's target
workload is a RESIDENT SERVICE answering streams of per-user requests, each
touching only the small k-hop ego-subgraph of its target vertex (the
Fograph scenario).  This module is that request path:

  * :func:`extract_ego` / :func:`extract_ego_batch` — batched k-hop
    ego-subgraph extraction against the partitioned graph with STATIC
    shapes: fixed fanout per hop, node/arc counts padded to power-of-2
    buckets (the graphbolt ``neighbor_sampler`` idiom), so the jitted
    forward traces O(log) specializations instead of one per request.
  * :func:`make_ego_forward` — the batched ego inference, reusing the
    EXACT layer functions of :mod:`repro.gnn.models`.  With full fanout
    the target rows reproduce the whole-graph forward — bit-exact for
    GCN, within ~1 ulp for GAT/SAGE (XLA reduction-order effects; see
    the function docstring): extraction keeps every node's incoming
    arcs in ascending-neighbor order, the same per-destination float
    summation order as ``directed_edges`` (both reduce to the CSR
    neighbor order), and full-graph degrees ride in as data.
    Depth-``hops`` nodes contribute raw features only — their own
    (truncated) aggregations never reach the target row.
  * :class:`FeatureCache` — per-server cache of remote feature rows with
    hot-vertex admission, mirroring the layout engine's TinyLFU-lite
    ``_admit`` discipline (AssemblyCache): under budget pressure a row is
    admitted only when touched >= 2 times and strictly more often than
    the LRU victim; halo-seeded rows are resident from the start.
  * :class:`GNNServeEngine` — queue -> batch -> extract -> forward ticks
    over the LIVE plan: homes come from ``plan.assign`` at tick time and
    caches re-seed when ``plan.version`` moves, so a fault-runtime
    ``patch_plan`` mid-stream keeps the service answering.  Reports
    throughput and p50/p99 latency under (Zipf-skewed) request streams.
  * :func:`zipf_requests` / :func:`request_traffic` /
    :func:`serving_cost` — skewed streams, the (optionally ego-propagated)
    requests/vertex histogram that feeds ``CostModel(traffic=...)`` (the
    paper's traffic-weighted unary compute row), and the analytic
    per-request serving cost under distributed ego execution that
    compares traffic-aware vs traffic-blind layouts.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn.distributed import ShardPlan
from repro.gnn.models import _LAYERS, GNNConfig, segment_sum
from repro.graphs.datagraph import DataGraph, csr_multirange


def _pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    return 1 << max(int(x) - 1, 0).bit_length()


# ------------------------------------------------------------ request streams
def zipf_requests(n: int, num_requests: int, s: float = 1.1,
                  seed: int = 0) -> np.ndarray:
    """Zipf-skewed request targets: vertex popularity follows rank^-s over
    a seeded random rank permutation (the hot set is not id-correlated)."""
    rng = np.random.default_rng(seed)
    ranks = rng.permutation(n)
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    p = np.empty(n, dtype=np.float64)
    p[ranks] = w / w.sum()
    return rng.choice(n, size=num_requests, p=p).astype(np.int64)


def request_traffic(n: int, targets: np.ndarray, smooth: float = 0.0,
                    graph: Optional[DataGraph] = None,
                    hops: int = 0) -> np.ndarray:
    """Traffic weights for ``CostModel(traffic=...)``, normalized to MEAN 1.

    With ``graph``/``hops``, each request's count propagates to every
    vertex of its ``hops``-ego — the number of request egos that TOUCH a
    vertex, which is exactly the weight its compute row carries under
    distributed ego execution (see :func:`serving_cost`).  Without, it is
    the plain requests/target histogram.  Mean-1 normalization keeps the
    traffic-aware C_P on the same scale as the blind one, so aware and
    blind layout costs stay comparable.  ``smooth`` adds a uniform floor
    (cold vertices keep a nonzero compute row)."""
    targets = np.asarray(targets, dtype=np.int64)
    if graph is not None and hops > 0:
        counts = np.zeros(n, dtype=np.float64)
        uniq, cnt = np.unique(targets, return_counts=True)
        for v, c in zip(uniq, cnt):
            nodes, _, _ = extract_ego(graph, int(v), hops)
            counts[nodes] += float(c)
    else:
        counts = np.bincount(targets, minlength=n).astype(np.float64)
    counts += float(smooth)
    mean = counts.mean()
    return counts / mean if mean > 0 else np.ones(n)


def link_traffic(graph: DataGraph, targets: np.ndarray, hops: int,
                 fanout: Optional[int] = None,
                 smooth: float = 0.0) -> np.ndarray:
    """Per-LINK ego-crossing histogram, mean-1 normalized — the edge-weight
    side of a traffic-aware layout.

    A request's remote ego rows are fetched across the links its ego
    spans, so the number of request egos containing a link is the weight
    its cut cost carries under serving.  Feed the product
    ``graph.weights_or_ones() * link_traffic(...)`` into a graph copy
    (``dataclasses.replace(graph, edge_weights=...)``) and GLAD's pairwise
    C_T term prices exactly that: hot neighborhoods get pulled onto one
    server, which is what the fetch term of :func:`serving_cost` rewards.
    (The unary side is :func:`request_traffic`; the serving bench composes
    both.)"""
    e = graph.edges
    counts = np.zeros(len(e), dtype=np.float64)
    if len(e):
        keys = e[:, 0] * graph.n + e[:, 1]            # canonical lo < hi
        order = np.argsort(keys)
        skeys = keys[order]
        uniq, cnt = np.unique(np.asarray(targets, dtype=np.int64),
                              return_counts=True)
        for v, c in zip(uniq, cnt):
            _, arcs, _ = extract_ego(graph, int(v), hops, fanout)
            if not len(arcs):
                continue
            k = arcs.min(axis=1) * graph.n + arcs.max(axis=1)
            eids = np.unique(order[np.searchsorted(skeys, k)])
            counts[eids] += float(c)
    counts += float(smooth)
    mean = counts.mean()
    return counts / mean if mean > 0 else np.ones(len(e))


# ------------------------------------------------------------- ego extraction
def extract_ego(graph: DataGraph, target: int, hops: int,
                fanout: Optional[int] = None):
    """k-hop ego subgraph of ``target``: (nodes, arcs, depth).

    ``nodes`` (global ids, ``nodes[0] == target``) are the vertices within
    ``hops``; ``arcs`` (global (src, dst)) are ALL incoming arcs of every
    node at depth < hops — exactly what a ``hops``-layer GNN needs to
    reproduce the whole-graph output at the target (depth-``hops`` nodes
    contribute raw features only, so they carry no arcs).  Per-destination
    arcs are contiguous in ascending src order — the same summation order
    as the full-graph ``directed_edges`` path, which is what makes the ego
    forward bit-match the oracle.  ``fanout`` truncates each node's
    neighbor list to its first ``fanout`` entries (ascending-id prefix —
    deterministic sampling; ``None`` / >= max degree is exact)."""
    indptr, indices = graph.indptr, graph.indices
    visited = np.zeros(graph.n, dtype=bool)
    visited[target] = True
    nodes = [np.array([target], dtype=np.int64)]
    depths = [np.zeros(1, dtype=np.int64)]
    srcs, dsts = [], []
    frontier = np.array([target], dtype=np.int64)
    for d in range(hops):
        if not len(frontier):
            break
        flat, rep = csr_multirange(indptr, frontier)
        nbrs = indices[flat]
        if fanout is not None and len(nbrs):
            counts = indptr[frontier + 1] - indptr[frontier]
            within = (np.arange(len(flat))
                      - np.repeat(np.cumsum(counts) - counts, counts))
            keep = within < fanout
            nbrs, rep = nbrs[keep], rep[keep]
        srcs.append(nbrs.astype(np.int64))
        dsts.append(frontier[rep])
        new = np.unique(nbrs[~visited[nbrs]])
        if len(new):
            visited[new] = True
            nodes.append(new.astype(np.int64))
            depths.append(np.full(len(new), d + 1, dtype=np.int64))
        frontier = new.astype(np.int64)
    all_nodes = np.concatenate(nodes)
    all_depth = np.concatenate(depths)
    if srcs:
        arcs = np.stack([np.concatenate(srcs), np.concatenate(dsts)], axis=1)
    else:
        arcs = np.zeros((0, 2), dtype=np.int64)
    return all_nodes, arcs, all_depth


@dataclasses.dataclass
class EgoBatch:
    """Flattened disjoint union of B ego subgraphs, bucket-padded.

    Local flat id of request b's i-th node is ``b * node_cap + i`` (target
    always slot 0); ``arcs`` pads point at the ``dummy`` row, whose
    aggregation lands in a segment the forward slices off."""

    nodes: np.ndarray        # (B, node_cap) global ids, -1 pad
    arcs: np.ndarray         # (arc_cap, 2) int32 LOCAL flat (src, dst)
    targets: np.ndarray      # (B,) global ids, -1 = empty slot
    num_nodes: np.ndarray    # (B,) real nodes per request
    num_arcs: int            # real arcs (before bucket padding)
    hops: int
    fanout: Optional[int]

    @property
    def batch(self) -> int:
        return int(self.nodes.shape[0])

    @property
    def node_cap(self) -> int:
        return int(self.nodes.shape[1])

    @property
    def dummy(self) -> int:
        return self.batch * self.node_cap


def extract_ego_batch(graph: DataGraph, targets: np.ndarray, hops: int,
                      fanout: Optional[int] = None,
                      batch: Optional[int] = None) -> EgoBatch:
    """Batched extraction with jit-stable shapes: ``node_cap`` (per-request
    node slots) and the arc count are padded to power-of-2 buckets, and the
    batch dimension to ``batch`` (short final batches pad with empty
    requests, target -1)."""
    targets = np.asarray(targets, dtype=np.int64)
    B = int(batch) if batch is not None else len(targets)
    if len(targets) > B:
        raise ValueError(f"{len(targets)} targets > batch {B}")
    egos = [extract_ego(graph, int(t), hops, fanout) for t in targets]
    node_cap = _pow2(max((len(nd) for nd, _, _ in egos), default=1))
    arc_cap = _pow2(max(sum(len(a) for _, a, _ in egos), 1))
    nodes = np.full((B, node_cap), -1, dtype=np.int64)
    num_nodes = np.zeros(B, dtype=np.int64)
    dummy = B * node_cap
    arcs = np.full((arc_cap, 2), dummy, dtype=np.int32)
    tgt = np.full(B, -1, dtype=np.int64)
    at = 0
    for b, (nd, ac, _) in enumerate(egos):
        nodes[b, : len(nd)] = nd
        num_nodes[b] = len(nd)
        tgt[b] = targets[b]
        if len(ac):
            # global -> local slot within this request (nd rows are unique).
            order = np.argsort(nd, kind="stable")
            pos = order[np.searchsorted(nd[order], ac)]
            arcs[at: at + len(ac)] = (b * node_cap + pos).astype(np.int32)
            at += len(ac)
    return EgoBatch(nodes=nodes, arcs=arcs, targets=tgt,
                    num_nodes=num_nodes, num_arcs=at, hops=hops,
                    fanout=fanout)


def ego_tables(ego: EgoBatch, features: np.ndarray, degrees: np.ndarray):
    """Device-ready arrays for an EgoBatch: the flattened feature table
    (dummy zero row last), FULL-GRAPH degree per slot (GCN/SAGE normalize
    by true degree, never by the sampled arc count), and the target rows
    (slot 0 of every request)."""
    d = features.shape[1]
    flat = np.zeros((ego.dummy + 1, d), dtype=features.dtype)
    valid = ego.nodes >= 0
    vflat = valid.reshape(-1)
    flat[: ego.dummy][vflat] = features[ego.nodes[valid]]
    deg = np.zeros(ego.dummy + 1, dtype=np.float32)
    deg[: ego.dummy][vflat] = degrees[ego.nodes[valid]]
    tgt_rows = (np.arange(ego.batch) * ego.node_cap).astype(np.int32)
    return flat, deg, tgt_rows


# -------------------------------------------------------------- ego inference
def make_ego_forward(cfg: GNNConfig, params, jit: bool = True):
    """Jitted batched ego forward: (feats (dummy+1, s_0), arcs, deg,
    tgt_rows) -> (B, s_K) embeddings at the targets.

    Runs the UNMODIFIED layer functions of :mod:`repro.gnn.models` over the
    flattened union graph, so semantics (and, with full fanout, bits) match
    the whole-graph forward at the target rows.  ``fwd.stats['traces']``
    counts jit traces (incremented at trace time — the make_bsp_forward
    contract): bucketed shapes bound it by O(log) per dimension.

    ``jit=False`` runs the same program eagerly.  Exactness vs the eager
    whole-graph oracle is model-dependent (XLA reduction-order effects,
    pinned by tests/test_serving.py):

      * gcn  — BIT-exact, jitted or eager: its only reductions are
               segment sums (order preserved by extraction) and
               (M, K) @ (K, N) matmuls, whose per-row bits are
               independent of M on XLA CPU;
      * sage — bit-exact eagerly; under jit XLA splits the
               dot-of-concatenate ``[agg, h] @ w`` into two partial
               matmuls, moving the target row by ~1 ulp;
      * gat  — within ~1 ulp either way: the attention logits are
               matvecs ``wh @ att`` whose rounding DOES depend on the
               table height, so the ego table (different M than the
               full graph) can flip the last bit of a softmax weight."""
    state = {"traces": 0}
    layer_fn = _LAYERS[cfg.model]
    K = cfg.num_layers

    def _fwd(feats, arcs, deg, tgt_rows):
        state["traces"] += 1             # python body runs once per trace
        n = feats.shape[0]
        h = feats.astype(cfg.dtype)
        for k, p in enumerate(params):
            h = layer_fn(p, h, arcs, deg, n, k == K - 1, segment_sum)
        return h[tgt_rows]

    jfn = jax.jit(_fwd) if jit else _fwd

    def fwd(feats, arcs, deg, tgt_rows):
        return jfn(feats, arcs, deg, tgt_rows)

    fwd.stats = state
    return fwd


# ---------------------------------------------------------------- feature DB
class FeatureCache:
    """Per-server cache of REMOTE feature rows under a byte budget.

    Admission/eviction mirror the layout engine's AssemblyCache exactly
    (TinyLFU-lite + LRU): under budget pressure a fetched row is admitted
    only when it has been touched at least twice AND strictly more often
    than the LRU victim plus one (the engine's anti-thrash margin); rows
    seeded resident (the plan's halo — they ARE the server's read set)
    bypass admission like the engine's proven-hot rebuilds."""

    def __init__(self, row_bytes: int, cache_bytes: int):
        self.row_bytes = max(int(row_bytes), 1)
        self.cache_bytes = int(cache_bytes)
        self._rows: "OrderedDict[int, None]" = OrderedDict()
        self._touches: Dict[int, int] = {}
        self._used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.rejected = 0

    @property
    def resident(self) -> int:
        return len(self._rows)

    def seed(self, ids: np.ndarray) -> None:
        """Install rows as resident (halo seeding) — bypasses admission."""
        for v in np.asarray(ids, dtype=np.int64):
            v = int(v)
            if v not in self._rows:
                self._rows[v] = None
                self._used += self.row_bytes
        self._evict()

    def lookup(self, ids: np.ndarray) -> np.ndarray:
        """Touch every id; True where resident (hit refreshes LRU)."""
        hit = np.zeros(len(ids), dtype=bool)
        for k, v in enumerate(np.asarray(ids, dtype=np.int64)):
            v = int(v)
            self._touches[v] = self._touches.get(v, 0) + 1
            if v in self._rows:
                self._rows.move_to_end(v)
                hit[k] = True
        nh = int(hit.sum())
        self.hits += nh
        self.misses += len(ids) - nh
        return hit

    def admit(self, ids: np.ndarray) -> None:
        """Offer fetched rows for residency (call after a lookup miss)."""
        for v in np.asarray(ids, dtype=np.int64):
            v = int(v)
            if v in self._rows:
                continue
            if self._admit(self._touches.get(v, 0)):
                self._rows[v] = None
                self._used += self.row_bytes
                self._evict()
            else:
                self.rejected += 1

    def _admit(self, touches: int) -> bool:
        if not self._rows or self._used + self.row_bytes <= self.cache_bytes:
            return True
        if touches < 2:
            return False
        victim = next(iter(self._rows))
        return touches > self._touches.get(victim, 0) + 1

    def _evict(self) -> None:
        while self._used > self.cache_bytes and len(self._rows) > 1:
            self._rows.popitem(last=False)
            self._used -= self.row_bytes
            self.evictions += 1


# ------------------------------------------------------------- serving engine
@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    wall_time_s: float = 0.0
    local_rows: int = 0          # ego rows owned by the home server
    replica_hit_rows: int = 0    # remote rows resident as plan replicas
    cache_hit_rows: int = 0      # remote rows served from the home's cache
    fetched_rows: int = 0        # remote rows pulled cross-server
    fetch_cost: float = 0.0      # sum tau[home, owner] over fetched rows
    plan_refreshes: int = 0      # cache re-seeds after plan.version moved

    @property
    def throughput_rps(self) -> float:
        return (self.requests / self.wall_time_s
                if self.wall_time_s > 0 else 0.0)


class GNNServeEngine:
    """Resident request service over the live partitioned graph.

    Each tick pops up to ``batch`` queued targets, extracts their ego
    subgraphs, accounts feature locality against the CURRENT
    ``plan.assign`` (home = the target's server; remote rows consult the
    plan's REPLICA table first — a replica-resident row is served from the
    home's persistent copy at zero fetch — then the home's
    :class:`FeatureCache`; misses charge ``tau[home, owner]``), and runs
    the jitted batched ego forward.  The plan is read live: when
    ``plan.version`` moves (a fault-runtime ``patch_plan``), caches and
    replica masks re-seed and serving continues — no rebuild of the
    engine.  Re-seeds also SNAPSHOT the per-epoch counters: ``stats``
    stays cumulative across the engine's whole life, ``epoch_stats`` /
    ``latency_percentiles(window='epoch')`` cover only the current plan
    version (throughput/p99 after a patch must not be diluted by the old
    plan's rows — the ledger before this snapshot silently mixed plans),
    and ``epoch_history`` keeps the closed epochs.  ``hops`` defaults to
    the model depth (exact receptive field); ``fanout`` bounds per-hop
    neighbors (None = exact)."""

    def __init__(self, cfg: GNNConfig, params, graph: DataGraph,
                 plan: ShardPlan, features: Optional[np.ndarray] = None,
                 hops: Optional[int] = None, fanout: Optional[int] = None,
                 batch: int = 8, cache_bytes: int = 1 << 20, net=None):
        self.cfg, self.params = cfg, params
        self.graph = graph
        self.plan = plan
        feats = features if features is not None else graph.features
        if feats is None:
            raise ValueError("serving needs vertex features")
        self.features = np.asarray(feats)
        self.hops = int(hops) if hops is not None else cfg.num_layers
        self.fanout = fanout
        self.batch = int(batch)
        self.cache_bytes = int(cache_bytes)
        self.net = net                      # optional: prices fetch_cost
        self.queue: deque = deque()         # (target, t_submit)
        self.stats = ServeStats()
        self.latencies: List[float] = []
        # Per-plan-version window: reset on every cache re-seed so the
        # post-patch report covers the new plan only.
        self.epoch_stats = ServeStats()
        self.epoch_latencies: List[float] = []
        self.epoch_history: List[dict] = []
        self.fwd = make_ego_forward(cfg, params)
        self._degrees = graph.degrees.astype(np.float32)
        self._caches: Dict[int, FeatureCache] = {}
        self._replica_mask: Dict[int, np.ndarray] = {}
        self._plan_version = -1
        self._refresh_caches()

    # ------------------------------------------------------------------ admin
    def _refresh_caches(self) -> None:
        if self._plan_version >= 0:
            self._close_epoch()
        row_bytes = self.features.shape[1] * self.features.dtype.itemsize
        self._caches = {}
        for p in range(self.plan.num_parts):
            c = FeatureCache(row_bytes, self.cache_bytes)
            halo = self.plan.halo[p]
            c.seed(halo[halo >= 0])
            self._caches[p] = c
        # Replica tier: rows the plan keeps PERSISTENTLY resident on each
        # server (read-only copies synced once per epoch, not cached
        # fetches) — consulted before the cache, never evicted.
        self._replica_mask = {}
        if getattr(self.plan, "has_replicas", False):
            for p in range(self.plan.num_parts):
                ids = self.plan.replica[p]
                m = np.zeros(self.graph.n, dtype=bool)
                m[ids[ids >= 0]] = True
                self._replica_mask[p] = m
        self._plan_version = self.plan.version

    def _close_epoch(self) -> None:
        """Archive the finished plan-version window and start a fresh one."""
        self.epoch_history.append({
            "plan_version": self._plan_version,
            "stats": self.epoch_stats,
            "latency": self.latency_percentiles(window="epoch"),
        })
        self.epoch_stats = ServeStats()
        self.epoch_latencies = []

    def cache_stats(self) -> Dict[str, int]:
        out = {"hits": 0, "misses": 0, "evictions": 0, "rejected": 0,
               "resident": 0}
        for c in self._caches.values():
            out["hits"] += c.hits
            out["misses"] += c.misses
            out["evictions"] += c.evictions
            out["rejected"] += c.rejected
            out["resident"] += c.resident
        return out

    def submit(self, targets) -> None:
        now = time.perf_counter()
        for t in np.atleast_1d(np.asarray(targets, dtype=np.int64)):
            self.queue.append((int(t), now))

    # ------------------------------------------------------------------ serve
    def _account(self, ego: EgoBatch, targets: np.ndarray) -> None:
        assign = self.plan.assign
        tau = self.net.tau if self.net is not None else None
        ledgers = (self.stats, self.epoch_stats)
        for b in range(len(targets)):
            home = int(assign[targets[b]])
            row = ego.nodes[b]
            ns = row[row >= 0]
            owners = assign[ns]
            local = owners == home
            for st in ledgers:
                st.local_rows += int(local.sum())
            remote = ns[~local]
            if not len(remote):
                continue
            rmask = self._replica_mask.get(home)
            if rmask is not None:
                rhit = rmask[remote]
                for st in ledgers:
                    st.replica_hit_rows += int(rhit.sum())
                remote = remote[~rhit]
                if not len(remote):
                    continue
            cache = self._caches[home]
            hit = cache.lookup(remote)
            for st in ledgers:
                st.cache_hit_rows += int(hit.sum())
            missed = remote[~hit]
            fc = (float(tau[home, assign[missed]].sum())
                  if tau is not None and len(missed) else 0.0)
            for st in ledgers:
                st.fetched_rows += len(missed)
                st.fetch_cost += fc
            cache.admit(missed)

    def tick(self) -> Optional[np.ndarray]:
        """Serve one batch off the queue; returns (served, s_K) embeddings
        in pop order, or None when idle."""
        if not self.queue:
            return None
        if self._plan_version != self.plan.version:
            self._refresh_caches()
            self.stats.plan_refreshes += 1
        t0 = time.perf_counter()
        take = min(self.batch, len(self.queue))
        items = [self.queue.popleft() for _ in range(take)]
        targets = np.array([t for t, _ in items], dtype=np.int64)
        ego = extract_ego_batch(self.graph, targets, self.hops, self.fanout,
                                batch=self.batch)
        self._account(ego, targets)
        feats, deg, tgt_rows = ego_tables(ego, self.features, self._degrees)
        out = np.asarray(self.fwd(jnp.asarray(feats), jnp.asarray(ego.arcs),
                                  jnp.asarray(deg), jnp.asarray(tgt_rows)))
        now = time.perf_counter()
        for st in (self.stats, self.epoch_stats):
            st.wall_time_s += now - t0
            st.batches += 1
            st.requests += take
        for _, ts in items:
            self.latencies.append(now - ts)
            self.epoch_latencies.append(now - ts)
        return out[:take]

    def run(self, max_batches: int = 10 ** 9) -> ServeStats:
        while self.queue and self.stats.batches < max_batches:
            self.tick()
        return self.stats

    def serve(self, targets) -> np.ndarray:
        """Submit + drain synchronously; returns (len(targets), s_K)."""
        self.submit(targets)
        outs = []
        while self.queue:
            outs.append(self.tick())
        return (np.concatenate(outs, axis=0) if outs
                else np.zeros((0, self.cfg.layer_dims[-1]), np.float32))

    def latency_percentiles(self, window: str = "all") -> Dict[str, float]:
        """``window='all'``: engine lifetime; ``'epoch'``: current plan
        version only (the post-patch report)."""
        lats = self.latencies if window == "all" else self.epoch_latencies
        if not lats:
            return {"p50": 0.0, "p99": 0.0}
        arr = np.asarray(lats)
        return {"p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99))}


# ---------------------------------------------------------------- evaluation
def _replication_masks(replication, assign: np.ndarray, num_parts: int,
                       n: int):
    """(num_parts, n) bool of MATERIALIZED replicas (request minus homed)
    from a Replication / plain dict / replicated ShardPlan's request."""
    by_part = getattr(replication, "by_part", None)
    if by_part is None:
        by_part = getattr(replication, "replication", replication)
    mask = np.zeros((num_parts, n), dtype=bool)
    for p, ids in (by_part or {}).items():
        ids = np.asarray(ids, dtype=np.int64)
        ids = ids[(ids >= 0) & (ids < n)]
        mask[int(p), ids[assign[ids] != int(p)]] = True
    return mask


def serving_cost(cm, assign: np.ndarray, targets: np.ndarray, hops: int,
                 fanout: Optional[int] = None, replication=None,
                 sync_weight: float = 0.5, storage: float = 0.0) -> float:
    """Analytic serving cost of a layout under a request stream, under the
    paper's DISTRIBUTED execution model: each ego vertex aggregates at its
    own host (the BSP forward restricted to the ego — C_P of node ``u`` at
    ``assign[u]``), and every remotely-owned row ships its result to the
    target's home once, at ``tau[home, owner]``.  Summed over the stream,
    the compute term is exactly the ego-propagated
    :func:`request_traffic`-weighted unary compute row — the quantity a
    traffic-aware ``CostModel`` hands GLAD.

    ``replication`` (a ``core.Replication``, a ``{part: ids}`` dict, or a
    replicated ShardPlan) prices replica-resident rows at ZERO fetch —
    the copy already lives at the home, so only the one-time sync
    (``sync_weight * tau[owner, p]`` per materialized replica, the same
    rule as ``CostModel.replicate_greedy``) plus ``storage`` is charged,
    once per replica, independent of how many requests read it.  Compute
    stays at the owner — replication moves bytes, not FLOPs.

    Pass a traffic-BLIND CostModel: the stream itself carries the request
    weighting here, so a traffic-scaled ``cp_matrix`` would double count.
    This is the metric the serving bench uses to compare traffic-aware vs
    traffic-blind (and replicated vs move-only) layouts in the same
    window."""
    if cm.traffic is not None:
        raise ValueError("pass a traffic-blind CostModel (traffic=None)")
    assign = np.asarray(assign, dtype=np.int64)
    uniq, cnt = np.unique(np.asarray(targets, dtype=np.int64),
                          return_counts=True)
    cp, tau = cm.cp_matrix, cm.net.tau
    rmask = None
    total = 0.0
    if replication is not None:
        rmask = _replication_masks(replication, assign, cm.net.m,
                                   cm.graph.n)
        ps, vs = np.nonzero(rmask)
        total += float((sync_weight * tau[assign[vs], ps]).sum())
        total += storage * len(vs)
    for v, c in zip(uniq, cnt):
        nodes, _, _ = extract_ego(cm.graph, int(v), hops, fanout)
        h = int(assign[v])
        owners = assign[nodes]
        cost = float(cp[nodes, owners].sum())
        rn = nodes[owners != h]
        if rmask is not None and len(rn):
            rn = rn[~rmask[h, rn]]
        if len(rn):
            cost += float(tau[h, assign[rn]].sum())
        total += float(c) * cost
    return total


def replicate_for_stream(cm, assign: np.ndarray, targets: np.ndarray,
                         hops: int, fanout: Optional[int] = None,
                         sync_weight: float = 0.5, storage: float = 0.0,
                         budget: Optional[int] = None):
    """Serving-side move-vs-replicate greedy: pick the replica set that
    minimizes :func:`serving_cost` for THIS stream.

    ``CostModel.replicate_greedy`` weighs replicas against the layout's
    recurring halo traffic; under request serving the right weight is the
    stream itself — ``w(v, h)`` = requests homed at ``h`` whose ego
    contains remote row ``v``, each saving one ``tau[h, owner]`` fetch.
    Replicating v into h is again a unary decision given the layout:
    ``gain = w(v, h) * tau[h, owner] - (sync_weight * tau[owner, h] +
    storage)``; all positive-gain pairs are accepted (they are independent,
    so the greedy is exact for this overlay), ``budget`` caps replicas per
    part (highest gain first, id tie-break).  Returns a
    ``core.Replication`` ready for ``serving_cost(replication=...)`` /
    ``set_replication``."""
    from repro.core.cost import Replication

    if cm.traffic is not None:
        raise ValueError("pass a traffic-blind CostModel (traffic=None)")
    assign = np.asarray(assign, dtype=np.int64)
    m, n = cm.net.m, cm.graph.n
    tau = cm.net.tau
    w = np.zeros((m, n), dtype=np.float64)      # fetch multiplicity (h, v)
    uniq, cnt = np.unique(np.asarray(targets, dtype=np.int64),
                          return_counts=True)
    for v, c in zip(uniq, cnt):
        nodes, _, _ = extract_ego(cm.graph, int(v), hops, fanout)
        h = int(assign[v])
        rn = nodes[assign[nodes] != h]
        w[h, rn] += float(c)
    owner = np.broadcast_to(assign, (m, n))
    hcol = np.arange(m)[:, None]
    gain = w * tau[hcol, owner] - (sync_weight * tau[owner, hcol] + storage)
    gain = np.where(w > 0, gain, -np.inf)
    by_part, saved_t, sync_t = {}, 0.0, 0.0
    for p in range(m):
        ids = np.flatnonzero(gain[p] > 1e-12)
        if budget is not None and len(ids) > budget:
            ids = ids[np.lexsort((ids, -gain[p, ids]))[:budget]]
            ids = np.sort(ids)
        if len(ids):
            by_part[p] = ids.astype(np.int64)
            saved_t += float((w[p, ids] * tau[p, assign[ids]]).sum())
            sync_t += float((sync_weight * tau[assign[ids], p]).sum())
    count = sum(len(v) for v in by_part.values())
    stor_t = storage * count
    return Replication(by_part=by_part,
                       gain=saved_t - sync_t - stor_t, saved=saved_t,
                       sync=sync_t, storage=stor_t,
                       sync_weight=sync_weight, storage_cost=storage)
