"""Distributed BSP GNN engine (paper Sec. III-B "cross-edge traffic" -> TPU).

The paper's execution model: each edge server hosts a vertex partition, and a
BSP synchronization round per GNN layer exchanges the feature vectors of
vertices whose links are cut by the layout.  On a TPU mesh this becomes:

  * vertices     -> padded per-device blocks (shape-static, layout-agnostic)
  * cut links    -> halo exchange collectives between mesh slices
  * BSP round    -> one collective phase per layer inside shard_map

Two exchange paths:
  * ``ppermute`` — point-to-point rotation rounds that move ONLY the rows the
    receiving device actually needs (bytes proportional to the layout's cut —
    this is where GLAD's C_T minimization physically lands).  Empty rounds are
    pruned host-side, so a good layout compiles to fewer collectives.
  * ``allgather`` — gather every block everywhere (bytes independent of the
    layout; the de-facto-baseline exchange used for comparison and as the
    large-P fallback).

Two aggregation paths (the per-layer neighbor sum on each device):
  * ``segment`` — gather messages by the edge table, ``segment_sum`` by
    destination.  Works for every model; the non-TPU default.
  * ``pallas``  — the device's edge table re-tiled into the block-sparse
    (values, block_cols) layout of ``kernels/gnn_aggregate`` and aggregated
    as an MXU matmul (``spmm``; vectorized jnp fallback off TPU).  GCN/SAGE
    only — GAT's per-link softmax weights are feature-dependent, so it stays
    on the segment path regardless of the knob.

Plan lifecycle (compile -> patch -> retrace):

  * :func:`compile_plan` builds a :class:`ShardPlan` ONCE on host from
    (DataGraph, DevicePartition); all arrays are rectangular so the jitted
    program never sees dynamic shapes.  ``slack`` reserves capacity headroom
    (local/halo/edge slots and ppermute round widths are padded past the
    current need) so the plan can absorb relayouts without changing shape.
  * :func:`patch_plan` updates the plan IN PLACE for a new assignment (and
    optionally an evolved graph): only the dirty partitions — those that
    gained/lost members, or host a neighbor of a moved/changed vertex —
    rebuild their local/halo/edge tables; everything else is untouched.
    The patched arrays are bit-identical to a from-scratch compile at the
    same capacities (:func:`recompile_like` is the oracle).
  * :func:`make_bsp_forward` feeds the plan arrays to the jitted forward as
    *operands*, re-read on every call, so a value-only patch triggers ZERO
    retraces.  A retrace happens exactly when a capacity grows (arrays
    change shape — grow-by-doubling keeps that rare) or a new ppermute
    round appears (the collective schedule itself changed).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import jaxcompat
from repro.core.partition import DevicePartition, halos_of
from repro.gnn.models import GNNConfig, segment_sum
from repro.graphs.datagraph import DataGraph
from repro.kernels.gnn_aggregate import spmm as _spmm, spmm_jnp as _spmm_jnp

_I32_MAX = np.iinfo(np.int32).max


def _pad_up(x: int, mult: int) -> int:
    return max(mult, ((x + mult - 1) // mult) * mult)


def _slack_cap(need: int, slack: float, pad_mult: int) -> int:
    """Capacity for ``need`` items with fractional headroom, pad-aligned."""
    return _pad_up(int(np.ceil(need * (1.0 + slack))), pad_mult)


def _grow_cap(cur: int, need: int, pad_mult: int) -> int:
    """Grow-by-doubling: smallest doubling of ``cur`` that fits ``need``."""
    cur = max(cur, pad_mult)
    while cur < need:
        cur *= 2
    return _pad_up(cur, pad_mult)


def _check_int32(cap: int, halo_cap: int) -> None:
    # Per-device tables (edges_src/edges_dst, round send/recv) hold LOCAL
    # coordinates bounded by cap + halo_cap + 1, pinned int32.  Global slot
    # ids p * cap + k are int64 (slot_of / halo_slot) — at large P * cap
    # they overflow int32 long before any per-device coordinate does.
    if cap + halo_cap + 1 > _I32_MAX:
        raise OverflowError(
            f"device table coordinates (cap={cap} + halo_cap={halo_cap} + 1) "
            f"exceed int32; shrink the partition capacity")


@dataclasses.dataclass
class PlanCaps:
    """Pinned plan capacities — compile with these and the arrays come out
    shape-identical (and, for the same assignment, bit-identical) to the
    plan they were read from.  ``round_widths`` also pins the ppermute
    schedule: every listed shift is emitted even when currently empty."""

    cap: int
    halo_cap: int
    e_cap: int
    round_widths: dict                  # shift -> padded width
    bsr_max_blocks: Optional[int] = None
    r_cap: int = 0                      # replica slots per device


@dataclasses.dataclass
class PlanBSR:
    """Per-device block-sparse (BSR) retiling of the plan's edge tables.

    The aggregation A @ table (A[dst, src] = link weight, table = [local;
    halo; zero row]) chopped into dense (bm, bk) blocks per device, in the
    exact (values, block_cols) layout ``kernels/gnn_aggregate.spmm``
    consumes.  All devices share one ``max_blocks`` so the stacked arrays
    are rectangular for shard_map."""

    bm: int
    bk: int
    nb: int                             # dst block-rows per device
    max_blocks: int                     # stored blocks per dst block-row
    src_rows: int                       # table rows padded to a bk multiple
    values: np.ndarray                  # (P, nb*max_blocks, bm, bk) f32
    block_cols: np.ndarray              # (P, nb, max_blocks) int32


@dataclasses.dataclass
class PlanDelta:
    """What :func:`patch_plan` did — and whether the next forward retraces."""

    moved: np.ndarray                   # vertices whose server changed
    new_vertices: int                   # appended since the old plan
    dirty_parts: np.ndarray             # partitions whose tables rebuilt
    patched: bool                       # False -> full rebuild (a cap grew)
    grew: tuple = ()                    # which capacities grew, if any
    rounds_added: int = 0               # new ppermute shifts (schedule grew)

    @property
    def retrace_expected(self) -> bool:
        return bool(self.grew) or self.rounds_added > 0


@dataclasses.dataclass
class ShardPlan:
    """Rectangular, device-ready encoding of a GLAD layout."""

    num_parts: int
    cap: int                      # local vertex slots per device
    halo_cap: int                 # halo slots per device
    e_cap: int                    # directed-edge slots per device
    local: np.ndarray             # (P, cap) global vertex ids, -1 pad
    local_mask: np.ndarray        # (P, cap) bool
    slot_of: np.ndarray           # (n,) -> p * cap + k  (int64: P*cap scale)
    halo: np.ndarray              # (P, halo_cap) global ids, -1 pad
    halo_slot: np.ndarray         # (P, halo_cap) global SLOT ids, P*cap pad
    edges_src: np.ndarray         # (P, e_cap) table idx: [0,cap)=local,
                                  #   [cap,cap+halo_cap)=halo, pad=cap+halo_cap
    edges_dst: np.ndarray         # (P, e_cap) local idx, pad = cap
    deg: np.ndarray               # (P, cap) float32 global degree
    rounds: Sequence[dict]        # pruned ppermute rounds (stable schedule)
    halo_bytes_ppermute: int      # exchanged payload rows (sum over rounds)
    halo_rows_allgather: int      # rows moved by the naive path
    assign: np.ndarray            # (n,) the assignment this plan encodes
    pad_mult: int = 8
    slack: float = 0.0            # capacity-headroom fraction
    version: int = 0              # bumped by every patch (device-array cache)
    bsr: Optional[PlanBSR] = None
    # ---- persistent replica residents (move-vs-replicate overlay) -------
    # ``replication`` is the AUTHORITATIVE request: part -> sorted global
    # ids the part should host as read-only copies, independent of where
    # they are currently homed.  ``replica`` is its materialization at the
    # current assignment — the request minus ids homed on the part — in a
    # rectangular (P, r_cap) table parallel to ``halo`` (sorted ascending,
    # -1 pad), patched in place by :func:`patch_plan` under the same
    # bit-identity-vs-fresh-compile contract as every other table.
    # ``rounds0`` is the layer-0 ppermute schedule with replica-resident
    # landing slots pruned (replicas carry RAW input features, so only the
    # first exchange shrinks; deeper layers move activations and use the
    # full ``rounds``); ``replica_halo_mask`` marks which halo slots those
    # are, and ``halo_bytes_ppermute0`` counts the layer-0 rows that still
    # cross the network.
    replication: Optional[dict] = None
    r_cap: int = 0
    replica: Optional[np.ndarray] = None          # (P, r_cap) ids, -1 pad
    replica_halo_mask: Optional[np.ndarray] = None  # (P, halo_cap) bool
    rounds0: Optional[Sequence[dict]] = None
    halo_bytes_ppermute0: int = 0

    @property
    def table_rows(self) -> int:
        return self.cap + self.halo_cap + 1     # +1 zero row for padding

    @property
    def n(self) -> int:
        return int(self.slot_of.shape[0])

    @property
    def has_replicas(self) -> bool:
        return self.replication is not None


# --------------------------------------------------------- host construction
def _degree_buckets(deg: np.ndarray) -> np.ndarray:
    """Power-of-two degree buckets: floor(log2(deg)) (degree <= 1 -> 0).

    The member-slotting key: coarse enough that the small degree drift of
    incremental graph evolution almost never crosses a bucket boundary,
    while hubs still sort ahead of the tail (the BSR-density property the
    tiled aggregation kernels rely on)."""
    return np.where(deg > 1,
                    np.log2(np.maximum(deg, 1)).astype(np.int64), 0)


def _part_members(graph: DataGraph, assign: np.ndarray, num_parts: int,
                  parts=None) -> dict:
    """Per-part member lists: degree-BUCKET descending, vertex-id ascending
    within a bucket.

    Deterministic — two compiles of the same assignment produce identical
    tables — and hub-first, the within-partition ordering the BSR tiling
    assumes (kernels/gnn_aggregate: degree ordering concentrates links in
    few blocks, so block density tracks layout quality).  Bucketing by
    floor(log2(degree)) instead of exact degree makes slots ID-STABLE
    across patches: a vertex whose degree drifts within its power-of-two
    bucket keeps its relative slot, so ``patch_plan`` reslots (and the BSR
    layer retiles) only the parts whose membership or bucket census
    actually changed — the prerequisite for finer per-block-row BSR
    patching."""
    b = _degree_buckets(graph.degrees)
    out = {}
    for p in (range(num_parts) if parts is None else parts):
        vs = np.flatnonzero(assign == p)
        if len(vs):
            vs = vs[np.lexsort((vs, -b[vs]))]
        out[int(p)] = vs.astype(np.int64)
    return out


def _edge_tables(graph: DataGraph, assign: np.ndarray, loc_idx: np.ndarray,
                 halos: dict, parts, cap: int, halo_cap: int,
                 num_parts: int):
    """Per-device directed edge lists in table coordinates for ``parts``.

    The edge list is doubled into (src, dst) arcs; arcs are grouped by
    destination part PRESERVING the doubled order, so each destination's
    float summation order is graph-intrinsic — independent of the layout,
    the capacities, and of which parts this call rebuilds.  Returns
    (rows: dict p -> (src_row, dst_row, count), counts: (P,) arc counts).
    """
    e = graph.edges
    parts = [int(p) for p in parts]
    counts = {p: 0 for p in parts}
    if len(e) == 0:
        return {p: (np.zeros(0, np.int32), np.zeros(0, np.int32), 0)
                for p in parts}, counts
    # Prefilter by destination part BEFORE doubling, so a dirty-part patch
    # touches O(arcs incident to dirty parts), not O(2|E|).  Selection
    # preserves the doubled order: forward arcs (edge order) then backward
    # arcs (edge order) — the per-part subsequences match a full compile.
    pe_u, pe_v = assign[e[:, 0]], assign[e[:, 1]]
    inpart = np.zeros(num_parts, dtype=bool)
    inpart[parts] = True
    m1 = inpart[pe_v]                    # forward arcs: dst = e[:, 1]
    m2 = inpart[pe_u]                    # backward arcs: dst = e[:, 0]
    srcs = np.concatenate([e[m1, 0], e[m2, 1]])
    dsts = np.concatenate([e[m1, 1], e[m2, 0]])
    ps = np.concatenate([pe_v[m1], pe_u[m2]])
    # One stable part-sort groups every part's arcs (stable = doubled order
    # preserved within each part) instead of an O(|parts| * |arcs|) scan.
    order = np.argsort(ps, kind="stable")
    ps_sorted = ps[order]
    rows = {}
    for p in sorted(parts):
        lo, hi = np.searchsorted(ps_sorted, [p, p + 1])
        idx = order[lo:hi]
        s, d = srcs[idx], dsts[idx]
        same = assign[s] == p
        s_tab = np.where(same, loc_idx[s], 0).astype(np.int64)
        crossm = ~same
        if crossm.any():
            s_tab[crossm] = cap + np.searchsorted(halos[p], s[crossm])
        rows[p] = (s_tab.astype(np.int32), loc_idx[d].astype(np.int32),
                   int(len(s)))
        counts[p] = int(len(s))
    return rows, counts


def _build_rounds(assign: np.ndarray, halos: dict, loc_idx: np.ndarray,
                  num_parts: int, halo_cap: int, pad_mult: int,
                  slack: float, keep_widths: Optional[dict] = None):
    """ppermute rotation schedule.

    ``keep_widths`` pins the schedule: every listed shift is emitted even if
    it carries no traffic (so a patched plan keeps its collective structure
    and the jitted forward its signature), and pinned widths only grow —
    by doubling — when traffic overflows them.  Returns
    (rounds, total_rows, widths, widths_grew, new_shifts)."""
    rounds = []
    total_rows = 0
    widths = dict(keep_widths) if keep_widths else {}
    widths_grew = False
    new_shifts = 0
    for s in range(1, num_parts):
        sends = []
        for p in range(num_parts):
            q = (p + s) % num_parts
            hq = halos[q]
            sends.append(hq[assign[hq] == p] if len(hq) else hq)
        max_send = max((len(x) for x in sends), default=0)
        if max_send == 0 and s not in widths:
            continue
        if s not in widths:
            widths[s] = _slack_cap(max_send, slack, pad_mult)
            if keep_widths is not None:
                new_shifts += 1
        elif max_send > widths[s]:
            widths[s] = _grow_cap(widths[s], max_send, pad_mult)
            widths_grew = True
        w = widths[s]
        send_idx = np.full((num_parts, w), -1, dtype=np.int32)
        recv_pos = np.full((num_parts, w), halo_cap, dtype=np.int32)
        for p in range(num_parts):
            q = (p + s) % num_parts
            rows = sends[p]
            if len(rows):
                send_idx[p, : len(rows)] = loc_idx[rows]
                # device q receives from p at shift s; store where each row
                # lands in q's halo buffer.
                recv_pos[q, : len(rows)] = np.searchsorted(halos[q], rows)
            total_rows += len(rows)
        rounds.append({
            "shift": s, "send_idx": send_idx, "recv_pos": recv_pos,
            "width": w,
        })
    return rounds, total_rows, widths, widths_grew, new_shifts


def _patch_rounds(plan: ShardPlan, assign: np.ndarray, halos: dict,
                  loc_idx: np.ndarray, halo_changed, mover_parts, resized):
    """Incremental ppermute-schedule patch.

    The (p -> q) pair of a round changes only when q's halo SET changed
    (membership/order -> every sender's rows and recv positions may move),
    or p is a mover's old/new home (its selection inside stable halos
    flipped), or p re-slotted (its members' local indices shifted).  The
    affected pairs' rows are derived in ONE pass: every halo entry is a
    (receiver, sender, position) triple whose round is shift = (q - p) mod
    P; one lexsort of the affected triples groups every pair's send rows
    in halo order, so cost is O(affected halo entries * log) — flat in P —
    instead of per-pair python dispatch.  Traffic accounting is maintained
    by delta.  Pinned shifts persist even when empty; a pair gaining
    traffic on a missing shift adds a round (schedule change -> retrace);
    width overflow grows by doubling and copies the unaffected rows
    verbatim (shape change -> retrace).  Returns (widths_grew,
    new_shifts)."""
    Pn, halo_cap = plan.num_parts, plan.halo_cap
    dirty = sorted({int(q) for q in halo_changed})
    movres = sorted({int(p) for p in mover_parts} | {int(p) for p in resized})
    if not dirty and not movres:
        return False, 0
    by_shift = {r["shift"]: r for r in plan.rounds}
    total = plan.halo_bytes_ppermute
    widths_grew = False
    new_shifts = 0

    # Affected triples: receiver dirty (whole halo column) or sender
    # moved/re-slotted (its selection or local indices changed).
    in_q = np.zeros(Pn, dtype=bool)
    in_q[dirty] = True
    in_p = np.zeros(Pn, dtype=bool)
    in_p[movres] = True
    qs_l, hv_l, pos_l = [], [], []
    for q in range(Pn):
        hq = halos[q]
        if len(hq):
            qs_l.append(np.full(len(hq), q, dtype=np.int64))
            hv_l.append(hq)
            pos_l.append(np.arange(len(hq), dtype=np.int64))
    per_shift: dict = {}
    hv = pos = None
    if qs_l:
        qs = np.concatenate(qs_l)
        hv = np.concatenate(hv_l)
        pos = np.concatenate(pos_l)
        snd = assign[hv]
        aff = in_q[qs] | in_p[snd]
        qs, hv, pos, snd = qs[aff], hv[aff], pos[aff], snd[aff]
        if len(qs):
            shift = (qs - snd) % Pn
            order = np.lexsort((pos, snd, shift))
            shift, snd = shift[order], snd[order]
            pos, hv = pos[order], hv[order]
            key = shift * Pn + snd
            bounds = np.flatnonzero(np.diff(key)) + 1
            starts = np.concatenate([[0], bounds])
            ends = np.concatenate([bounds, [len(key)]])
            for a, b in zip(starts, ends):
                per_shift.setdefault(int(shift[a]), []).append(
                    (int(snd[a]), int(a), int(b)))

    for s in sorted(set(per_shift) | set(by_shift)):
        glist = per_shift.get(s, [])
        gmax = max((b - a for _, a, b in glist), default=0)
        r = by_shift.get(s)
        if r is None:
            # Shift currently pruned: it gains a round only if an affected
            # pair now carries traffic (clean pairs were and stay empty).
            if gmax == 0:
                continue
            w = _slack_cap(gmax, plan.slack, plan.pad_mult)
            r = {"shift": s,
                 "send_idx": np.full((Pn, w), -1, dtype=np.int32),
                 "recv_pos": np.full((Pn, w), halo_cap, dtype=np.int32),
                 "width": w}
            by_shift[s] = r
            new_shifts += 1
        elif gmax > r["width"]:
            # Grow by doubling; unaffected rows are value-unchanged, so
            # copy them verbatim into the wider arrays.
            w = _grow_cap(r["width"], gmax, plan.pad_mult)
            ns = np.full((Pn, w), -1, dtype=np.int32)
            nr = np.full((Pn, w), halo_cap, dtype=np.int32)
            ns[:, : r["width"]] = r["send_idx"]
            nr[:, : r["width"]] = r["recv_pos"]
            r["send_idx"], r["recv_pos"], r["width"] = ns, nr, w
            widths_grew = True
        # Clear + account every affected pair of this round (send row p and
        # recv row q belong exclusively to pair (p -> q=(p+s)%P)), then
        # scatter the recomputed rows of the pairs that carry traffic.
        ps = np.unique(np.array(
            [(q - s) % Pn for q in dirty] + movres, dtype=np.int64))
        total -= int((r["send_idx"][ps] >= 0).sum())
        r["send_idx"][ps] = -1
        r["recv_pos"][(ps + s) % Pn] = halo_cap
        for p, a, b in glist:
            k = b - a
            r["send_idx"][p, :k] = loc_idx[hv[a:b]]
            r["recv_pos"][(p + s) % Pn, :k] = pos[a:b]
            total += k
    plan.rounds = [by_shift[s] for s in sorted(by_shift)]
    plan.halo_bytes_ppermute = total
    return widths_grew, new_shifts


# ------------------------------------------------------------- replication
def _normalize_replication(replication, n: int) -> Optional[dict]:
    """Canonical replication request: ``{part: sorted unique int64 ids}``
    with out-of-range ids dropped and empty parts removed; ``None`` when
    nothing remains.  Accepts a core.cost.Replication (its ``by_part``),
    a plain dict, or None."""
    if replication is None:
        return None
    by_part = getattr(replication, "by_part", replication)
    out = {}
    for p, ids in by_part.items():
        ids = np.unique(np.asarray(ids, dtype=np.int64))
        ids = ids[(ids >= 0) & (ids < n)]
        if len(ids):
            out[int(p)] = ids
    return out or None


def _replica_rows(replication: Optional[dict], assign: np.ndarray,
                  parts) -> dict:
    """Materialized replica row per part: the request minus ids currently
    HOMED on the part (a resident needs no copy — but the request keeps the
    id, so a later move away re-materializes it)."""
    out = {}
    for p in parts:
        ids = (replication or {}).get(int(p))
        if ids is None:
            out[int(p)] = np.zeros(0, dtype=np.int64)
        else:
            out[int(p)] = ids[assign[ids] != p]
    return out


def _derive_rounds0(plan: ShardPlan) -> None:
    """Layer-0 ppermute schedule: the full ``rounds`` with every send whose
    landing halo slot is replica-resident pruned (send -1 / recv dump slot).

    A pure function of (rounds, halo, replica) recomputed wholesale after
    every compile/patch — so the patch-vs-fresh-compile bit-identity of
    those tables carries over to ``rounds0`` for free.  Shifts and widths
    mirror ``rounds`` exactly: a value-only patch keeps the jitted
    forward's signature, replica hits only blank out payload rows."""
    if not plan.has_replicas:
        plan.replica_halo_mask = None
        plan.rounds0 = plan.rounds
        plan.halo_bytes_ppermute0 = plan.halo_bytes_ppermute
        return
    Pn, halo_cap = plan.num_parts, plan.halo_cap
    mask = np.zeros((Pn, halo_cap + 1), dtype=bool)   # col halo_cap: pad slot
    for p in range(Pn):
        ids = plan.replica[p]
        ids = ids[ids >= 0]
        hp = plan.halo[p]
        cnt = int((hp >= 0).sum())
        if len(ids) and cnt:
            # Replicas that are ALSO halo members shrink the exchange;
            # serving-only replicas (outside the halo) simply don't match.
            k = np.searchsorted(hp[:cnt], ids)
            k = np.minimum(k, cnt - 1)
            mask[p, k[hp[k] == ids]] = True
    plan.replica_halo_mask = mask[:, :halo_cap]
    rounds0, total0 = [], 0
    for r in plan.rounds:
        q_of = (np.arange(Pn) + r["shift"]) % Pn      # receiver of each sender
        hit = mask[np.arange(Pn)[:, None], r["recv_pos"]]   # by receiver row
        send0 = np.where(hit[q_of], np.int32(-1), r["send_idx"])
        recv0 = np.where(hit, np.int32(halo_cap), r["recv_pos"])
        total0 += int((send0 >= 0).sum())
        rounds0.append({"shift": r["shift"], "send_idx": send0,
                        "recv_pos": recv0, "width": r["width"]})
    plan.rounds0 = rounds0
    plan.halo_bytes_ppermute0 = total0


def scatter_replica_halo(plan: ShardPlan, features: np.ndarray) -> np.ndarray:
    """(n, d) -> (P, halo_cap, d): each device's halo buffer pre-filled with
    its replica-resident rows (raw input features), zeros elsewhere — the
    layer-0 ``replica0`` operand of :func:`make_bsp_forward`."""
    features = np.asarray(features)
    d = features.shape[1] if features.ndim > 1 else 1
    out = np.zeros((plan.num_parts, plan.halo_cap, d), dtype=features.dtype)
    if plan.has_replicas and plan.replica_halo_mask is not None:
        m = plan.replica_halo_mask
        out[m] = features.reshape(len(features), d)[plan.halo[m]]
    return out


def set_replication(plan: ShardPlan, replication) -> PlanDelta:
    """Install (or clear, with None) the plan's replication request IN
    PLACE: re-materializes the replica table at the current assignment,
    re-derives the layer-0 schedule, bumps the version.  Growing ``r_cap``
    (or toggling replicas on/off) changes the forward's signature — one
    retrace; re-installing within capacity is value-only."""
    req = _normalize_replication(replication, plan.n)
    plan.replication = req
    Pn = plan.num_parts
    grew = ()
    if req is None:
        if plan.r_cap:
            grew = ("r_cap",)
        plan.r_cap = 0
        plan.replica = np.full((Pn, 0), -1, dtype=np.int64)
    else:
        rows = _replica_rows(req, plan.assign, range(Pn))
        need = max((len(r) for r in rows.values()), default=0)
        r_cap = plan.r_cap
        if need > r_cap:
            r_cap = (_grow_cap(r_cap, need, plan.pad_mult) if r_cap
                     else _slack_cap(need, plan.slack, plan.pad_mult))
            grew = ("r_cap",)
        plan.r_cap = r_cap
        replica = np.full((Pn, r_cap), -1, dtype=np.int64)
        for p in range(Pn):
            replica[p, : len(rows[p])] = rows[p]
        plan.replica = replica
    _derive_rounds0(plan)
    plan.version += 1
    return PlanDelta(
        moved=np.zeros(0, dtype=np.int64), new_vertices=0,
        dirty_parts=np.arange(Pn, dtype=np.int64), patched=True, grew=grew)


def _compile_from_assign(
    graph: DataGraph, assign: np.ndarray, num_parts: int,
    pad_mult: int = 8, slack: float = 0.0, caps: Optional[PlanCaps] = None,
    grow: bool = False, replication=None,
) -> ShardPlan:
    """Full host-side plan compilation (numpy only, no jax device state).

    With ``caps`` the capacities (and the ppermute schedule) are pinned, so
    the result is shape-compatible with — and for the same assignment
    bit-identical to — the plan the caps were read from.  Construction is
    deterministic throughout: members degree-ordered with id tie-breaks,
    halos ascending by id, arcs in doubled-edge order."""
    assign = np.asarray(assign, dtype=np.int64)
    Pn, n = num_parts, graph.n

    members = _part_members(graph, assign, Pn)
    sizes = np.array([len(members[p]) for p in range(Pn)], dtype=np.int64)
    max_size = int(sizes.max()) if Pn else 1
    if caps is not None:
        if max_size > caps.cap and not grow:
            raise ValueError(f"pinned cap {caps.cap} < needed {max_size}")
        cap = _grow_cap(caps.cap, max_size, pad_mult)
    else:
        cap = _slack_cap(max_size, slack, pad_mult)

    halos = halos_of(graph, assign, Pn)
    max_halo = max((len(halos[p]) for p in range(Pn)), default=1)
    if caps is not None:
        if max_halo > caps.halo_cap and not grow:
            raise ValueError(
                f"pinned halo_cap {caps.halo_cap} < needed {max_halo}")
        halo_cap = _grow_cap(caps.halo_cap, max_halo, pad_mult)
    else:
        halo_cap = _slack_cap(max_halo, slack, pad_mult)
    _check_int32(cap, halo_cap)

    # Global slot ids are p * cap + k: int64 by construction (P * cap
    # overflows int32 at production scale — satellite audit pin).
    local = np.full((Pn, cap), -1, dtype=np.int64)
    slot_of = np.full(n, -1, dtype=np.int64)
    deg_all = graph.degrees.astype(np.float32)
    deg = np.zeros((Pn, cap), dtype=np.float32)
    for p in range(Pn):
        vs = members[p]
        local[p, : len(vs)] = vs
        slot_of[vs] = p * cap + np.arange(len(vs), dtype=np.int64)
        deg[p, : len(vs)] = deg_all[vs]
    local_mask = local >= 0
    loc_idx = slot_of - assign * cap

    halo = np.full((Pn, halo_cap), -1, dtype=np.int64)
    halo_slot = np.full((Pn, halo_cap), Pn * cap, dtype=np.int64)
    for p in range(Pn):
        hs = halos[p]
        halo[p, : len(hs)] = hs
        halo_slot[p, : len(hs)] = slot_of[hs]

    rows, counts = _edge_tables(graph, assign, loc_idx, halos,
                                range(Pn), cap, halo_cap, Pn)
    max_e = max(counts.values(), default=0)
    if caps is not None:
        if max_e > caps.e_cap and not grow:
            raise ValueError(f"pinned e_cap {caps.e_cap} < needed {max_e}")
        e_cap = _grow_cap(caps.e_cap, max_e, pad_mult)
    else:
        e_cap = _slack_cap(max_e, slack, pad_mult)
    edges_src = np.full((Pn, e_cap), cap + halo_cap, dtype=np.int32)
    edges_dst = np.full((Pn, e_cap), cap, dtype=np.int32)
    for p in range(Pn):
        s_row, d_row, cnt = rows[p]
        edges_src[p, :cnt] = s_row
        edges_dst[p, :cnt] = d_row

    keep = caps.round_widths if caps is not None else None
    rounds, total_rows, _w, _grew, _new = _build_rounds(
        assign, halos, loc_idx, Pn, halo_cap, pad_mult, slack,
        keep_widths=keep)

    repl = _normalize_replication(replication, n)
    if repl is not None:
        rows_r = _replica_rows(repl, assign, range(Pn))
        max_r = max((len(r) for r in rows_r.values()), default=0)
        if caps is not None:
            if max_r > caps.r_cap and not grow:
                raise ValueError(
                    f"pinned r_cap {caps.r_cap} < needed {max_r}")
            # A pinned r_cap that fits is kept EXACTLY (0 is a legit pinned
            # value _grow_cap can't reproduce).
            r_cap = (caps.r_cap if max_r <= caps.r_cap
                     else _grow_cap(caps.r_cap, max_r, pad_mult))
        else:
            r_cap = _slack_cap(max_r, slack, pad_mult)
    else:
        r_cap = caps.r_cap if caps is not None else 0
    replica = np.full((Pn, r_cap), -1, dtype=np.int64)
    if repl is not None:
        for p in range(Pn):
            replica[p, : len(rows_r[p])] = rows_r[p]

    plan = ShardPlan(
        num_parts=Pn, cap=cap, halo_cap=halo_cap, e_cap=e_cap,
        local=local, local_mask=local_mask, slot_of=slot_of,
        halo=halo, halo_slot=halo_slot,
        edges_src=edges_src, edges_dst=edges_dst, deg=deg,
        rounds=rounds,
        halo_bytes_ppermute=total_rows,
        halo_rows_allgather=Pn * cap * max(Pn - 1, 0),
        assign=assign.copy(), pad_mult=pad_mult, slack=slack,
        replication=repl, r_cap=r_cap, replica=replica,
    )
    _derive_rounds0(plan)
    return plan


def compile_plan(
    graph: DataGraph, part: DevicePartition, pad_mult: int = 8,
    slack: float = 0.0, caps: Optional[PlanCaps] = None,
    replication=None,
) -> ShardPlan:
    """Host-side plan compilation from a DevicePartition.

    ``slack`` reserves fractional capacity headroom on every padded axis so
    later :func:`patch_plan` calls stay shape-stable (no retrace); ``caps``
    pins capacities outright (the patch oracle / growth path).
    ``replication`` seeds the plan's replica table — defaults to the
    partition's attached move-vs-replicate overlay (``part.replication``
    from a ``glad_s(..., replicate=True)`` solve) when present."""
    if replication is None:
        replication = getattr(part, "replication", None)
    return _compile_from_assign(graph, part.assign, part.num_parts,
                                pad_mult=pad_mult, slack=slack, caps=caps,
                                replication=replication)


def plan_caps(plan: ShardPlan) -> PlanCaps:
    """The plan's current capacities, pinnable into a fresh compile."""
    return PlanCaps(
        cap=plan.cap, halo_cap=plan.halo_cap, e_cap=plan.e_cap,
        round_widths={r["shift"]: r["width"] for r in plan.rounds},
        bsr_max_blocks=None if plan.bsr is None else plan.bsr.max_blocks,
        r_cap=plan.r_cap,
    )


def recompile_like(plan: ShardPlan, graph: DataGraph,
                   assign: np.ndarray) -> ShardPlan:
    """From-scratch compile at ``plan``'s capacities (the patch oracle):
    a correct :func:`patch_plan` leaves ``plan`` array-identical to this."""
    caps = plan_caps(plan)
    fresh = _compile_from_assign(graph, assign, plan.num_parts,
                                 pad_mult=plan.pad_mult, slack=plan.slack,
                                 caps=caps, replication=plan.replication)
    if plan.bsr is not None:
        build_plan_bsr(fresh, bm=plan.bsr.bm, bk=plan.bsr.bk,
                       max_blocks=plan.bsr.max_blocks)
    return fresh


def plans_equal(a: ShardPlan, b: ShardPlan) -> list:
    """Array-level comparison; returns the list of differing fields."""
    bad = []
    for f in ("num_parts", "cap", "halo_cap", "e_cap",
              "halo_bytes_ppermute", "halo_rows_allgather",
              "r_cap", "halo_bytes_ppermute0"):
        if getattr(a, f) != getattr(b, f):
            bad.append(f)
    for f in ("local", "local_mask", "slot_of", "halo", "halo_slot",
              "edges_src", "edges_dst", "deg", "assign",
              "replica", "replica_halo_mask"):
        if not np.array_equal(getattr(a, f) if getattr(a, f) is not None
                              else np.zeros(0),
                              getattr(b, f) if getattr(b, f) is not None
                              else np.zeros(0)):
            bad.append(f)
    for name, ga, gb in (("rounds", a.rounds, b.rounds),
                         ("rounds0", a.rounds0 or (), b.rounds0 or ())):
        if len(ga) != len(gb):
            bad.append(f"{name}(len)")
            continue
        for ra, rb in zip(ga, gb):
            if (ra["shift"] != rb["shift"] or ra["width"] != rb["width"]
                    or not np.array_equal(ra["send_idx"], rb["send_idx"])
                    or not np.array_equal(ra["recv_pos"], rb["recv_pos"])):
                bad.append(f"{name}(shift={ra['shift']})")
    if (a.bsr is None) != (b.bsr is None):
        bad.append("bsr(presence)")
    elif a.bsr is not None:
        for f in ("bm", "bk", "nb", "max_blocks", "src_rows"):
            if getattr(a.bsr, f) != getattr(b.bsr, f):
                bad.append(f"bsr.{f}")
        for f in ("values", "block_cols"):
            if not np.array_equal(getattr(a.bsr, f), getattr(b.bsr, f)):
                bad.append(f"bsr.{f}")
    return bad


# ------------------------------------------------------------- incremental
def patch_plan(
    plan: ShardPlan,
    graph: DataGraph,
    new_assign: np.ndarray,
    dirty_vertices: Optional[np.ndarray] = None,
) -> PlanDelta:
    """Patch ``plan`` in place for a new assignment (and/or evolved graph).

    Only the dirty partitions — those that gained/lost members, or host a
    neighbor of a moved/structurally-changed vertex — rebuild their
    local/halo/edge tables (and BSR rows); ``halo_slot`` is refreshed
    globally (values only, O(P * halo_cap)) because re-slotting a partition
    shifts the global slot ids other partitions' halos reference.  The
    ppermute schedule is rebuilt with pinned shifts/widths so the jitted
    forward keeps its signature.

    ``dirty_vertices``: vertices whose incident structure changed (new /
    removed links, fresh insertions) — pass the endpoints of a
    ``GraphDelta`` when the graph itself evolved.  Vertex DELETIONS keep
    their id slot but implicitly remove every incident arc, and those
    arcs are invisible in the new edge set — so pass the deleted
    vertices' PRE-DELTA neighborhoods (``old_graph.neighbors(v)``) too,
    or the parts that lose the deleted vertex from halos/edge tables are
    never marked dirty.  Assignment-only relayouts can omit it; movers
    are derived from the assignment diff.

    Any capacity overflow falls back to a full rebuild at grown
    (doubled) capacities — flagged in the returned :class:`PlanDelta`,
    whose ``retrace_expected`` says whether the next forward recompiles.
    """
    Pn = plan.num_parts
    new_assign = np.asarray(new_assign, dtype=np.int64)
    if len(new_assign) != graph.n:
        raise ValueError(f"assign has {len(new_assign)} entries for "
                         f"{graph.n} vertices")
    if len(new_assign) and (new_assign.min() < 0 or new_assign.max() >= Pn):
        raise ValueError("assignment targets outside [0, num_parts)")
    n_old = plan.n
    if graph.n < n_old:
        # Vertex deletions renumber the universe — no incremental mapping.
        return _rebuild(plan, graph, new_assign, grew=("universe",))

    moved = np.flatnonzero(new_assign[:n_old] != plan.assign)
    new_vertices = graph.n - n_old
    dirty = [moved, np.arange(n_old, graph.n, dtype=np.int64)]
    if dirty_vertices is not None and len(dirty_vertices):
        dv = np.asarray(dirty_vertices, dtype=np.int64)
        dirty.append(dv[dv < graph.n])
    dv = np.unique(np.concatenate(dirty))
    if len(dv) == 0:
        plan.assign = new_assign.copy()
        return PlanDelta(moved=moved, new_vertices=0,
                         dirty_parts=np.zeros(0, np.int64), patched=True)

    # Dirty partitions: old/new homes of the dirty vertices plus every
    # partition hosting one of their (current) neighbors — those see halo
    # membership and boundary-coordinate changes.
    dmask = np.zeros(graph.n, dtype=bool)
    dmask[dv] = True
    plist = [plan.assign[dv[dv < n_old]], new_assign[dv]]
    e = graph.edges
    if len(e):
        em = dmask[e[:, 0]] | dmask[e[:, 1]]
        plist += [new_assign[e[em, 0]], new_assign[e[em, 1]]]
    D = np.unique(np.concatenate(plist))

    # ---- growth checks (grow-by-doubling on any overflow -> full rebuild)
    grew = []
    sizes = np.bincount(new_assign, minlength=Pn)
    cap = plan.cap
    if sizes.max() > cap:
        grew.append("cap")
    halosD = halos_of(graph, new_assign, Pn, parts=D)
    max_halo = max((len(h) for h in halosD.values()), default=0)
    if max_halo > plan.halo_cap:
        grew.append("halo_cap")
    if grew:
        return _rebuild(plan, graph, new_assign, grew=tuple(grew))

    members = _part_members(graph, new_assign, Pn, parts=D)
    deg_all = graph.degrees.astype(np.float32)
    if graph.n > n_old:
        slot_of = np.full(graph.n, -1, dtype=np.int64)
        slot_of[:n_old] = plan.slot_of
        plan.slot_of = slot_of
    resized = []                         # parts whose slotting changed
    halo_changed = []                    # parts whose halo set changed
    for p in D:
        vs = members[int(p)]
        old_row = plan.local[p].copy()
        plan.local[p] = -1
        plan.local[p, : len(vs)] = vs
        if not np.array_equal(old_row, plan.local[p]):
            resized.append(int(p))
        plan.deg[p] = 0.0
        plan.deg[p, : len(vs)] = deg_all[vs]
        plan.slot_of[vs] = p * cap + np.arange(len(vs), dtype=np.int64)
        old_halo = plan.halo[p].copy()
        plan.halo[p] = -1
        hs = halosD[int(p)]
        plan.halo[p, : len(hs)] = hs
        if not np.array_equal(old_halo, plan.halo[p]):
            halo_changed.append(int(p))
    plan.local_mask = plan.local >= 0
    loc_idx = plan.slot_of - new_assign * cap
    # Movers' old/new homes: their selection inside STABLE halos flipped,
    # so their send rows must be recomputed toward every receiver.
    mover_parts = np.unique(np.concatenate(
        [plan.assign[moved], new_assign[moved]])) if len(moved) else []

    # Global slot ids shifted for every member of a re-slotted partition;
    # refresh halo_slot everywhere (values only — cheap, shape-stable).
    valid = plan.halo >= 0
    plan.halo_slot[...] = Pn * cap
    plan.halo_slot[valid] = plan.slot_of[plan.halo[valid]]

    halos_all = {p: (halosD[int(p)] if int(p) in halosD
                     else plan.halo[p][plan.halo[p] >= 0])
                 for p in range(Pn)}
    rows, counts = _edge_tables(graph, new_assign, loc_idx, halos_all,
                                D, cap, plan.halo_cap, Pn)
    if max(counts.values(), default=0) > plan.e_cap:
        # Roll back nothing: the tables written above are re-derived by the
        # full rebuild from (graph, new_assign) — plan state is overwritten.
        return _rebuild(plan, graph, new_assign, grew=("e_cap",))
    for p in D:
        s_row, d_row, cnt = rows[int(p)]
        plan.edges_src[p] = cap + plan.halo_cap
        plan.edges_dst[p] = cap
        plan.edges_src[p, :cnt] = s_row
        plan.edges_dst[p, :cnt] = d_row

    # Replica rows: part p's materialization (request minus homed ids)
    # changes only when a replicated vertex moves to or from p — both homes
    # are in D, so refreshing the dirty parts covers every changed row.
    if plan.has_replicas:
        rrows = _replica_rows(plan.replication, new_assign, D)
        if max((len(r) for r in rrows.values()), default=0) > plan.r_cap:
            return _rebuild(plan, graph, new_assign, grew=("r_cap",))
        for p in D:
            plan.replica[p] = -1
            plan.replica[p, : len(rrows[int(p)])] = rrows[int(p)]

    widths_grew, new_shifts = _patch_rounds(
        plan, new_assign, halos_all, loc_idx, halo_changed, mover_parts,
        resized)
    _derive_rounds0(plan)
    plan.assign = new_assign.copy()
    plan.version += 1

    delta = PlanDelta(
        moved=moved, new_vertices=new_vertices, dirty_parts=D, patched=True,
        grew=("round_width",) if widths_grew else (),
        rounds_added=new_shifts)
    if plan.bsr is not None:
        _patch_plan_bsr(plan, D, delta)
    return delta


def _rebuild(plan: ShardPlan, graph: DataGraph,
             new_assign: np.ndarray, grew: tuple) -> PlanDelta:
    """Full recompile at grown (doubled-as-needed) capacities, written into
    ``plan`` in place so callers holding the plan object see the update."""
    n_old = plan.n
    moved = (np.flatnonzero(new_assign[:n_old] != plan.assign)
             if graph.n >= n_old else np.arange(graph.n, dtype=np.int64))
    # Existing capacities become minimums (grow-by-doubling past them) and
    # the collective schedule persists: pinned shifts stay, widths re-grow
    # inside _build_rounds if they must.
    caps = PlanCaps(
        cap=plan.cap, halo_cap=plan.halo_cap, e_cap=plan.e_cap,
        round_widths={r["shift"]: r["width"] for r in plan.rounds},
        r_cap=plan.r_cap,
    )
    if "universe" in grew:
        caps = None                      # renumbered graph: clean slate
    bsr = plan.bsr
    fresh = _compile_from_assign(graph, new_assign, plan.num_parts,
                                 pad_mult=plan.pad_mult, slack=plan.slack,
                                 caps=caps, grow=True,
                                 replication=plan.replication)
    grew = tuple(grew) + tuple(
        f for f in ("cap", "halo_cap", "e_cap", "r_cap")
        if getattr(fresh, f) != getattr(plan, f) and f not in grew)
    version = plan.version + 1
    plan.__dict__.update(fresh.__dict__)
    plan.version = version
    if bsr is not None:
        build_plan_bsr(plan, bm=bsr.bm, bk=bsr.bk)
    return PlanDelta(
        moved=moved, new_vertices=max(graph.n - n_old, 0),
        dirty_parts=np.arange(plan.num_parts, dtype=np.int64),
        patched=False, grew=grew)


# --------------------------------------------------------- block-sparse tiling
def _device_block_rows(edges_src_row: np.ndarray, edges_dst_row: np.ndarray,
                       cap: int, bm: int, bk: int, nb: int) -> list:
    """One device's edge table -> per-dst-block-row [(src_block, block)].

    Deterministic: blocks keyed and emitted in (dst_block, src_block)
    lexicographic order; padded table entries (dst == cap) are dropped."""
    live = edges_dst_row < cap
    src, dst = edges_src_row[live], edges_dst_row[live]
    rows = [[] for _ in range(nb)]
    if len(src) == 0:
        return rows
    ib = dst // bm
    jb = src // bk
    order = np.lexsort((jb, ib))
    src, dst, ib, jb = src[order], dst[order], ib[order], jb[order]
    key = ib.astype(np.int64) * (1 << 32) + jb
    bounds = np.flatnonzero(np.diff(key)) + 1
    starts = np.concatenate([[0], bounds])
    ends = np.concatenate([bounds, [len(src)]])
    for a, b in zip(starts, ends):
        i, j = int(ib[a]), int(jb[a])
        blk = np.zeros((bm, bk), np.float32)
        np.add.at(blk, (dst[a:b] - i * bm, src[a:b] - j * bk), 1.0)
        rows[i].append((j, blk))
    return rows


def build_plan_bsr(plan: ShardPlan, bm: int = 8, bk: int = 128,
                   max_blocks: Optional[int] = None) -> PlanBSR:
    """Re-tile every device's edge table into the kernel's BSR layout.

    ``max_blocks`` pins the per-row block budget (patch oracle); otherwise
    it is the current max over devices padded by the plan's slack."""
    Pn, cap = plan.num_parts, plan.cap
    nb = _pad_up(cap, bm) // bm
    src_rows = _pad_up(plan.table_rows, bk)
    per_dev = [
        _device_block_rows(plan.edges_src[p], plan.edges_dst[p],
                           cap, bm, bk, nb)
        for p in range(Pn)
    ]
    need = max((len(r) for rows in per_dev for r in rows), default=0)
    if max_blocks is None:
        max_blocks = max(1, int(np.ceil(max(need, 1) * (1.0 + plan.slack))))
    elif need > max_blocks:
        raise ValueError(f"pinned max_blocks {max_blocks} < needed {need}")
    values = np.zeros((Pn, nb * max_blocks, bm, bk), np.float32)
    block_cols = np.zeros((Pn, nb, max_blocks), np.int32)
    for p in range(Pn):
        _fill_device_bsr(values[p], block_cols[p], per_dev[p], max_blocks)
    plan.bsr = PlanBSR(bm=bm, bk=bk, nb=nb, max_blocks=max_blocks,
                       src_rows=src_rows, values=values,
                       block_cols=block_cols)
    return plan.bsr


def _fill_device_bsr(values_p, block_cols_p, rows, max_blocks):
    values_p[...] = 0.0
    block_cols_p[...] = 0
    for i, row in enumerate(rows):
        for k, (j, blk) in enumerate(row):      # rows already (i, j)-sorted
            values_p[i * max_blocks + k] = blk
            block_cols_p[i, k] = j


def _patch_plan_bsr(plan: ShardPlan, dirty_parts, delta: PlanDelta) -> None:
    """Rebuild only the dirty devices' BSR rows; grow-by-doubling
    ``max_blocks`` (full re-tile + retrace) when a device overflows it."""
    bsr = plan.bsr
    per_dev = {
        int(p): _device_block_rows(plan.edges_src[p], plan.edges_dst[p],
                                   plan.cap, bsr.bm, bsr.bk, bsr.nb)
        for p in dirty_parts
    }
    need = max((len(r) for rows in per_dev.values() for r in rows), default=0)
    if need > bsr.max_blocks or _pad_up(plan.table_rows, bsr.bk) != bsr.src_rows:
        grown = bsr.max_blocks
        while grown < need:
            grown *= 2
        build_plan_bsr(plan, bm=bsr.bm, bk=bsr.bk,
                       max_blocks=max(grown, 1))
        delta.grew = delta.grew + ("bsr_max_blocks",)
        return
    for p, rows in per_dev.items():
        _fill_device_bsr(bsr.values[p], bsr.block_cols[p], rows,
                         bsr.max_blocks)


# ------------------------------------------------------------ data shuffling
def scatter_features(plan: ShardPlan, features: np.ndarray) -> np.ndarray:
    """(n, d) -> (P, cap, d) per-device blocks (zero rows on padding)."""
    features = np.asarray(features)
    d = features.shape[1] if features.ndim > 1 else 1
    out = np.zeros((plan.num_parts, plan.cap, d), dtype=features.dtype)
    valid = plan.local >= 0
    out[valid] = features.reshape(len(features), d)[plan.local[valid]]
    return out


def scatter_ints(plan: ShardPlan, values: np.ndarray, pad=0) -> np.ndarray:
    """(n,) -> (P, cap) per-device blocks; padding (and every slot of an
    empty partition) carries ``pad``."""
    out = np.full((plan.num_parts, plan.cap), pad, dtype=values.dtype)
    valid = plan.local >= 0
    if valid.any():
        out[valid] = values[plan.local[valid]]
    return out


def gather_outputs(plan: ShardPlan, blocks: np.ndarray, n: int) -> np.ndarray:
    """(P, cap, ...) -> (n, ...) inverse of scatter_features; rows of
    vertices not present in the plan (never with patch) stay zero."""
    out = np.zeros((n,) + blocks.shape[2:], dtype=blocks.dtype)
    valid = plan.local >= 0
    if valid.any():
        out[plan.local[valid]] = blocks[valid]
    return out


# ------------------------------------------------------------- device kernel
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_aggregate(cfg: GNNConfig, aggregate: str) -> str:
    """Aggregate-path decision (mirrors the solver-mode matrix; README):

      * 'segment' — gather + segment_sum.  Every model, every backend.
      * 'pallas'  — block-sparse SpMM over the plan's BSR tiling.  GCN/SAGE
        only (GAT's softmax link weights are feature-dependent); executes
        the Pallas kernel on TPU, the vectorized jnp BSR fallback elsewhere.
      * 'auto'    — 'pallas' exactly when it wins: TPU backend + GCN/SAGE;
        'segment' otherwise.
    """
    if aggregate == "auto":
        return ("pallas" if _on_tpu() and cfg.model in ("gcn", "sage")
                else "segment")
    if aggregate not in ("segment", "pallas"):
        raise ValueError(f"unknown aggregate {aggregate!r}")
    if aggregate == "pallas" and cfg.model == "gat":
        return "segment"
    return aggregate


def _bsr_aggregate(h_local, halo, vals, cols, src_rows, impl):
    """Per-device neighbor sum as block-sparse SpMM over the padded table."""
    d = h_local.shape[1]
    bm, bk = int(vals.shape[-2]), int(vals.shape[-1])
    zero_row = jnp.zeros((1, d), h_local.dtype)
    table = jnp.concatenate([h_local, halo, zero_row], axis=0)
    pad_d = (-d) % 128 if d > 128 else 0
    x = jnp.pad(table, ((0, src_rows - table.shape[0]), (0, pad_d)))
    if impl == "pallas":
        out = _spmm(vals, cols, x, bm=bm, bk=bk)
    else:
        out = _spmm_jnp(vals, cols, x, bm, bk)
    return out[: h_local.shape[0], :d]


def _exchange_ppermute(h_local, rounds, halo_cap, axis_name, init=None):
    """Move exactly the cut-link rows (paper's C_T) via rotation rounds.

    ``init``: optional (halo_cap + 1, d) starting halo buffer — the layer-0
    replica path pre-fills replica-resident slots with their (locally
    stored) raw features and runs the PRUNED ``rounds0`` schedule, whose
    dump-slot receives land on row halo_cap and never clobber real slots."""
    d = h_local.shape[-1]
    halo = init if init is not None else jnp.zeros((halo_cap + 1, d),
                                                   h_local.dtype)
    zero_row = jnp.zeros((1, d), h_local.dtype)
    table = jnp.concatenate([h_local, zero_row], axis=0)
    for r in rounds:
        send = table[jnp.where(r["send_idx"] < 0, h_local.shape[0], r["send_idx"])]
        got = jax.lax.ppermute(
            send, axis_name,
            [(p, (p + r["shift"]) % r["nparts"]) for p in range(r["nparts"])],
        )
        halo = halo.at[r["recv_pos"]].set(got)
    return halo[:halo_cap]


def _exchange_allgather(h_local, halo_slot, axis_name):
    """Naive exchange: gather all blocks, pick halo rows (layout-agnostic)."""
    d = h_local.shape[-1]
    all_blocks = jax.lax.all_gather(h_local, axis_name)     # (P, cap, d)
    flat = all_blocks.reshape(-1, d)
    flat = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], axis=0)
    idx = jnp.minimum(halo_slot, flat.shape[0] - 1)
    return flat[idx]


def _device_layer(cfg, p, h_local, halo, plan_arrs, last,
                  agg_mode="segment", agg_impl="jnp", src_rows=0):
    """One GNN layer on one device, mirroring models.py semantics exactly.

    ``h_local``: (cap, d); ``halo``: (halo_cap, d).  Aggregation runs over
    the device's edge list in table coordinates (padded edges hit the zero
    row and the dummy cap-th destination segment), or — ``agg_mode ==
    'pallas'``, GCN/SAGE — over the plan's block-sparse retiling of the
    same table (matches to fp32 tolerance: different summation order).
    """
    cap = h_local.shape[0]
    edges_src, edges_dst, deg = (
        plan_arrs["edges_src"], plan_arrs["edges_dst"], plan_arrs["deg"])
    zero_row = jnp.zeros((1, h_local.shape[1]), h_local.dtype)
    use_bsr = agg_mode == "pallas" and cfg.model in ("gcn", "sage")
    if use_bsr:
        bsr_agg = _bsr_aggregate(h_local, halo, plan_arrs["bsr_values"],
                                 plan_arrs["bsr_cols"], src_rows, agg_impl)

    if cfg.model == "gcn":
        if use_bsr:
            agg = bsr_agg
        else:
            table = jnp.concatenate([h_local, halo, zero_row], axis=0)
            msgs = table[edges_src]
            agg = segment_sum(msgs, edges_dst, cap + 1)[:cap]
        out = (agg + h_local) / (deg[:, None] + 1.0)
        out = out @ p["w"]
    elif cfg.model == "sage":
        if use_bsr:
            agg = bsr_agg
        else:
            table = jnp.concatenate([h_local, halo, zero_row], axis=0)
            msgs = table[edges_src]
            agg = segment_sum(msgs, edges_dst, cap + 1)[:cap]
        agg = agg / jnp.maximum(deg, 1.0)[:, None]
        out = jnp.concatenate([agg, h_local], axis=-1) @ p["w"]
    elif cfg.model == "gat":
        # Compute W h for every table row locally (pull-then-compute BSP).
        table_h = jnp.concatenate([h_local, halo, zero_row], axis=0)
        wh = table_h @ p["w"]
        a_dst = wh[:cap] @ p["att_src"]                  # only local dsts score
        a_src = wh @ p["att_dst"]
        logits = jax.nn.leaky_relu(a_dst[edges_dst % cap] + a_src[edges_src], 0.2)
        # Mask padded edges out of the softmax.
        pad = edges_dst >= cap
        logits = jnp.where(pad, -jnp.inf, logits)
        self_logit = jax.nn.leaky_relu(a_dst + wh[:cap] @ p["att_dst"], 0.2)
        seg_max = jax.ops.segment_max(logits, edges_dst, num_segments=cap + 1)[:cap]
        seg_max = jnp.maximum(jnp.where(jnp.isfinite(seg_max), seg_max, -jnp.inf),
                              self_logit)
        ex = jnp.where(pad, 0.0, jnp.exp(logits - seg_max[edges_dst % cap]))
        ex_self = jnp.exp(self_logit - seg_max)
        denom = segment_sum(ex[:, None], edges_dst, cap + 1)[:cap, 0] + ex_self
        num = segment_sum(ex[:, None] * wh[edges_src], edges_dst, cap + 1)[:cap]
        num = num + ex_self[:, None] * wh[:cap]
        out = num / jnp.maximum(denom, 1e-16)[:, None]
    else:
        raise ValueError(cfg.model)
    return out if last else jax.nn.relu(out)


def _bsp_forward_device(cfg, params, h_local, plan_arrs, rounds, halo_cap,
                        exchange, axis_name, agg_mode="segment",
                        agg_impl="jnp", src_rows=0, rounds0=None, halo0=None):
    """``rounds0``/``halo0``: the replica fast path for the FIRST exchange —
    replicas store raw input features, so layer 0 serves their halo slots
    from the pre-filled ``halo0`` buffer and runs the pruned schedule;
    deeper layers move fresh activations and always use ``rounds``."""
    for k, p in enumerate(params):
        if exchange == "ppermute":
            if k == 0 and halo0 is not None:
                halo = _exchange_ppermute(h_local, rounds0, halo_cap,
                                          axis_name, init=halo0)
            else:
                halo = _exchange_ppermute(h_local, rounds, halo_cap,
                                          axis_name)
        else:
            halo = _exchange_allgather(h_local, plan_arrs["halo_slot"], axis_name)
        h_local = _device_layer(cfg, p, h_local, halo, plan_arrs,
                                k == len(params) - 1, agg_mode, agg_impl,
                                src_rows)
    return h_local


def make_bsp_forward(
    cfg: GNNConfig,
    plan: ShardPlan,
    mesh: Mesh,
    axis_name: str = "data",
    exchange: str = "ppermute",
    aggregate: str = "auto",
):
    """Build the full BSP forward: (params, blocks (P,cap,d)) -> blocks.

    The returned callable is jitted internally and reads the plan's arrays
    at CALL time, passing them as operands — so a :func:`patch_plan` that
    kept every capacity (the common case, given slack headroom) is picked
    up with ZERO retraces; capacity growth or a new ppermute round changes
    the operand signature and recompiles exactly once.  ``fwd.stats``
    exposes ``{'traces': ..., 'builds': ...}`` for the retrace-count
    assertions in tests and benchmarks.

    ``exchange='ppermute'`` moves only cut-link rows (GLAD-aware);
    ``'allgather'`` is the layout-agnostic baseline.  ``aggregate`` picks
    the per-device neighbor sum — see :func:`resolve_aggregate`.
    """
    mode = resolve_aggregate(cfg, aggregate)
    if mode == "pallas" and plan.bsr is None:
        build_plan_bsr(plan)
    impl = "pallas" if _on_tpu() else "jnp"
    spec_b = P(axis_name)
    state = {"sig": None, "fn": None, "version": -1, "ops": None,
             "traces": 0, "builds": 0}

    def _use_replicas():
        return exchange == "ppermute" and plan.has_replicas

    def _signature():
        sig = (plan.cap, plan.halo_cap, plan.e_cap)
        if exchange == "ppermute":
            # allgather never sees the ppermute schedule — folding it in
            # would recompile that path on schedule-only patches.
            sig += (tuple(r["shift"] for r in plan.rounds),
                    tuple(r["width"] for r in plan.rounds))
        if _use_replicas():
            # rounds0 mirrors rounds' shifts/widths, so toggling replicas
            # only adds the halo0 operand + the pruned tables: one flag.
            # r_cap growth alone is value-only (no shape in the jaxpr).
            sig += ("repl",)
        if mode == "pallas":
            b = plan.bsr
            sig += (b.bm, b.bk, b.max_blocks, b.src_rows)
        return sig

    def _operands():
        ops = [plan.edges_src, plan.edges_dst, plan.deg, plan.halo_slot]
        if mode == "pallas":
            ops += [plan.bsr.values, plan.bsr.block_cols]
        if exchange == "ppermute":
            for r in plan.rounds:
                ops += [r["send_idx"], r["recv_pos"]]
            if _use_replicas():
                for r in plan.rounds0:
                    ops += [r["send_idx"], r["recv_pos"]]
        return tuple(jnp.asarray(a) for a in ops)

    def _build():
        shifts = tuple(r["shift"] for r in plan.rounds)
        halo_cap, nparts = plan.halo_cap, plan.num_parts
        src_rows = plan.bsr.src_rows if mode == "pallas" else 0
        n_fixed = 6 if mode == "pallas" else 4
        n_rounds = len(shifts) if exchange == "ppermute" else 0
        has_repl = _use_replicas()

        def inner(params, blocks, *rest):
            state["traces"] += 1         # python body runs once per trace
            if has_repl:
                halo0_blk, ops = rest[0], rest[1:]
            else:
                halo0_blk, ops = None, rest
            plan_arrs = {
                "edges_src": ops[0][0], "edges_dst": ops[1][0],
                "deg": ops[2][0], "halo_slot": ops[3][0],
            }
            if mode == "pallas":
                plan_arrs["bsr_values"] = ops[4][0]
                plan_arrs["bsr_cols"] = ops[5][0]

            def mk_rounds(base):
                return [
                    {"shift": s, "nparts": nparts,
                     "send_idx": ops[base + 2 * k][0],
                     "recv_pos": ops[base + 2 * k + 1][0]}
                    for k, s in enumerate(shifts[:n_rounds])
                ]
            local_rounds = mk_rounds(n_fixed)
            rounds0 = mk_rounds(n_fixed + 2 * n_rounds) if has_repl else None
            halo0 = None
            if has_repl:
                h0 = halo0_blk[0].astype(blocks.dtype)
                halo0 = jnp.concatenate(
                    [h0, jnp.zeros((1, h0.shape[-1]), h0.dtype)], axis=0)
            out = _bsp_forward_device(
                cfg, params, blocks[0], plan_arrs, local_rounds,
                halo_cap, exchange, axis_name, mode, impl, src_rows,
                rounds0=rounds0, halo0=halo0)
            return out[None]

        n_ops = n_fixed + 2 * n_rounds * (2 if has_repl else 1)
        n_lead = 1 if has_repl else 0
        smapped = jaxcompat.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), spec_b) + (spec_b,) * (n_lead + n_ops),
            out_specs=spec_b)
        return jax.jit(smapped)

    def forward(params, blocks, replica0=None):
        sig = _signature()
        if sig != state["sig"]:
            state["fn"] = _build()
            state["sig"] = sig
            state["builds"] += 1
            state["version"] = -1        # force operand refresh
        if state["version"] != plan.version:
            state["ops"] = _operands()
            state["version"] = plan.version
        if _use_replicas():
            if replica0 is None:
                raise ValueError(
                    "plan has replicas: pass replica0="
                    "scatter_replica_halo(plan, features) so layer 0 can "
                    "serve replica-resident halo slots locally")
            return state["fn"](params, blocks, jnp.asarray(replica0),
                               *state["ops"])
        return state["fn"](params, blocks, *state["ops"])

    forward.stats = state
    forward.plan = plan
    return forward


# ----------------------------------------------------- single-device oracle
def simulate_bsp_forward(cfg, params, plan: ShardPlan, features: np.ndarray,
                         exchange: str = "ppermute",
                         aggregate: str = "auto") -> np.ndarray:
    """Run the exact device computation without a multi-device mesh: the halo
    is served from the global feature table (mathematically identical to
    either exchange path).  Used by tests and the CPU examples."""
    mode = resolve_aggregate(cfg, aggregate)
    if mode == "pallas" and plan.bsr is None:
        build_plan_bsr(plan)
    impl = "pallas" if _on_tpu() else "jnp"
    src_rows = plan.bsr.src_rows if mode == "pallas" else 0
    blocks = jnp.asarray(scatter_features(plan, features))
    Pn, cap, d = blocks.shape

    def one_layer_all(h_blocks, k, p, last):
        flat = h_blocks.reshape(Pn * cap, -1)
        flat = jnp.concatenate([flat, jnp.zeros((1, flat.shape[1]), flat.dtype)])
        outs = []
        for q in range(Pn):
            idx = jnp.minimum(jnp.asarray(plan.halo_slot[q]), Pn * cap)
            halo = flat[idx]
            plan_arrs = {
                "edges_src": jnp.asarray(plan.edges_src[q]),
                "edges_dst": jnp.asarray(plan.edges_dst[q]),
                "deg": jnp.asarray(plan.deg[q]),
            }
            if mode == "pallas":
                plan_arrs["bsr_values"] = jnp.asarray(plan.bsr.values[q])
                plan_arrs["bsr_cols"] = jnp.asarray(plan.bsr.block_cols[q])
            outs.append(_device_layer(cfg, p, h_blocks[q], halo, plan_arrs,
                                      last, mode, impl, src_rows))
        return jnp.stack(outs)

    h = blocks
    for k, p in enumerate(params):
        h = one_layer_all(h, k, p, k == len(params) - 1)
    return np.asarray(gather_outputs(plan, np.asarray(h), features.shape[0]))
