"""Distributed BSP GNN engine (paper Sec. III-B "cross-edge traffic" -> TPU).

The paper's execution model: each edge server hosts a vertex partition, and a
BSP synchronization round per GNN layer exchanges the feature vectors of
vertices whose links are cut by the layout.  On a TPU mesh this becomes:

  * vertices     -> padded per-device blocks (shape-static, layout-agnostic)
  * cut links    -> halo exchange collectives between mesh slices
  * BSP round    -> one collective phase per layer inside shard_map

Two exchange paths:
  * ``ppermute`` — point-to-point rotation rounds that move ONLY the rows the
    receiving device actually needs (bytes proportional to the layout's cut —
    this is where GLAD's C_T minimization physically lands).  Empty rounds are
    pruned host-side, so a good layout compiles to fewer collectives.
  * ``allgather`` — gather every block everywhere (bytes independent of the
    layout; the de-facto-baseline exchange used for comparison and as the
    large-P fallback).

A ShardPlan is compiled ONCE on host from (DataGraph, DevicePartition); all
arrays are rectangular so the jitted program never sees dynamic shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import jaxcompat
from repro.core.partition import DevicePartition
from repro.gnn.models import GNNConfig, segment_sum
from repro.graphs.datagraph import DataGraph


def _pad_up(x: int, mult: int) -> int:
    return max(mult, ((x + mult - 1) // mult) * mult)


@dataclasses.dataclass
class ShardPlan:
    """Rectangular, device-ready encoding of a GLAD layout."""

    num_parts: int
    cap: int                      # local vertex slots per device
    halo_cap: int                 # halo slots per device
    e_cap: int                    # directed-edge slots per device
    local: np.ndarray             # (P, cap) global vertex ids, -1 pad
    local_mask: np.ndarray        # (P, cap) bool
    slot_of: np.ndarray           # (n,) -> p * cap + k
    halo: np.ndarray              # (P, halo_cap) global ids, -1 pad
    halo_slot: np.ndarray         # (P, halo_cap) global SLOT ids, P*cap pad
    edges_src: np.ndarray         # (P, e_cap) table idx: [0,cap)=local,
                                  #   [cap,cap+halo_cap)=halo, pad=cap+halo_cap
    edges_dst: np.ndarray         # (P, e_cap) local idx, pad = cap
    deg: np.ndarray               # (P, cap) float32 global degree
    rounds: Sequence[dict]        # pruned ppermute rounds
    halo_bytes_ppermute: int      # exchanged payload rows (sum over rounds)
    halo_rows_allgather: int      # rows moved by the naive path

    @property
    def table_rows(self) -> int:
        return self.cap + self.halo_cap + 1     # +1 zero row for padding


def compile_plan(
    graph: DataGraph, part: DevicePartition, pad_mult: int = 8
) -> ShardPlan:
    """Host-side plan compilation (numpy only, no jax device state)."""
    Pn = part.num_parts
    assign = part.assign
    n = graph.n

    parts = [np.where(assign == p)[0] for p in range(Pn)]
    cap = _pad_up(max((len(q) for q in parts), default=1), pad_mult)
    local = np.full((Pn, cap), -1, dtype=np.int64)
    slot_of = np.full(n, -1, dtype=np.int64)
    for p, vs in enumerate(parts):
        local[p, : len(vs)] = vs
        slot_of[vs] = p * cap + np.arange(len(vs))
    local_mask = local >= 0

    # Local index of every vertex within its own part (slot_of = p*cap + k).
    loc_idx = slot_of - assign.astype(np.int64) * cap

    # Halo membership: out-of-part neighbors each part aggregates from.
    # ``halos[p]`` is sorted-unique, so a vertex's halo position on p is a
    # searchsorted lookup — no per-vertex dicts.
    e = graph.edges
    halos = []
    for p in range(Pn):
        if len(e) == 0:
            halos.append(np.zeros(0, np.int64))
            continue
        mu = assign[e[:, 0]] == p
        mv = assign[e[:, 1]] == p
        need = np.concatenate([e[mu & ~mv, 1], e[mv & ~mu, 0]])
        halos.append(np.unique(need))
    halo_cap = _pad_up(max((len(h) for h in halos), default=1), pad_mult)
    halo = np.full((Pn, halo_cap), -1, dtype=np.int64)
    halo_slot = np.full((Pn, halo_cap), Pn * cap, dtype=np.int64)
    for p, hs in enumerate(halos):
        halo[p, : len(hs)] = hs
        halo_slot[p, : len(hs)] = slot_of[hs]

    # Per-device directed edge lists in table coordinates, fully vectorized:
    # double the edge list into (src, dst) arcs, group by destination part,
    # translate sources to local or halo coordinates per part.
    e_cap = pad_mult
    edges_src = np.full((Pn, pad_mult), cap + halo_cap, dtype=np.int32)
    edges_dst = np.full((Pn, pad_mult), cap, dtype=np.int32)
    if len(e):
        src_all = np.concatenate([e[:, 0], e[:, 1]])
        dst_all = np.concatenate([e[:, 1], e[:, 0]])
        p_all = assign[dst_all]
        d_loc = loc_idx[dst_all]
        same = assign[src_all] == p_all
        s_tab = np.where(same, loc_idx[src_all], 0)
        for p in range(Pn):
            crossp = ~same & (p_all == p)
            if crossp.any():
                s_tab[crossp] = cap + np.searchsorted(
                    halos[p], src_all[crossp])
        counts = np.bincount(p_all, minlength=Pn)
        e_cap = _pad_up(int(counts.max()), pad_mult)
        edges_src = np.full((Pn, e_cap), cap + halo_cap, dtype=np.int32)
        edges_dst = np.full((Pn, e_cap), cap, dtype=np.int32)
        order = np.argsort(p_all, kind="stable")
        offs = np.arange(len(order)) - np.repeat(
            np.cumsum(counts) - counts, counts)
        edges_src[p_all[order], offs] = s_tab[order]
        edges_dst[p_all[order], offs] = d_loc[order]

    deg_all = graph.degrees.astype(np.float32)
    deg = np.zeros((Pn, cap), dtype=np.float32)
    for p, vs in enumerate(parts):
        deg[p, : len(vs)] = deg_all[vs]

    # ppermute rotation schedule; prune rounds with no traffic anywhere.
    rounds = []
    total_rows = 0
    for s in range(1, Pn):
        sends = []                 # per source device p: rows destined to q
        for p in range(Pn):
            q = (p + s) % Pn
            hq = halos[q]
            sends.append(hq[assign[hq] == p] if len(hq) else hq)
        max_send = max((len(x) for x in sends), default=0)
        if max_send == 0:
            continue
        max_send = _pad_up(max_send, pad_mult)
        send_idx = np.full((Pn, max_send), -1, dtype=np.int32)
        recv_pos = np.full((Pn, max_send), halo_cap, dtype=np.int32)
        for p in range(Pn):
            q = (p + s) % Pn
            rows = sends[p]
            if len(rows):
                send_idx[p, : len(rows)] = loc_idx[rows]
                # device q receives from p at round s; store where each row
                # lands in q's halo buffer.
                recv_pos[q, : len(rows)] = np.searchsorted(halos[q], rows)
            total_rows += len(rows)
        rounds.append({
            "shift": s, "send_idx": send_idx, "recv_pos": recv_pos,
            "width": max_send,
        })

    return ShardPlan(
        num_parts=Pn, cap=cap, halo_cap=halo_cap, e_cap=e_cap,
        local=local, local_mask=local_mask, slot_of=slot_of,
        halo=halo, halo_slot=halo_slot,
        edges_src=edges_src, edges_dst=edges_dst, deg=deg,
        rounds=rounds,
        halo_bytes_ppermute=total_rows,
        halo_rows_allgather=Pn * cap * max(Pn - 1, 0),
    )


# ------------------------------------------------------------ data shuffling
def scatter_features(plan: ShardPlan, features: np.ndarray) -> np.ndarray:
    """(n, d) -> (P, cap, d) per-device blocks (zero rows on padding)."""
    d = features.shape[1]
    out = np.zeros((plan.num_parts, plan.cap, d), dtype=features.dtype)
    valid = plan.local >= 0
    out[valid] = features[plan.local[valid]]
    return out


def scatter_ints(plan: ShardPlan, values: np.ndarray, pad=0) -> np.ndarray:
    out = np.full((plan.num_parts, plan.cap), pad, dtype=values.dtype)
    valid = plan.local >= 0
    out[valid] = values[plan.local[valid]]
    return out


def gather_outputs(plan: ShardPlan, blocks: np.ndarray, n: int) -> np.ndarray:
    """(P, cap, d) -> (n, d) inverse of scatter_features."""
    out = np.zeros((n,) + blocks.shape[2:], dtype=blocks.dtype)
    valid = plan.local >= 0
    out[plan.local[valid]] = blocks[valid]
    return out


# ------------------------------------------------------------- device kernel
def _exchange_ppermute(h_local, rounds, halo_cap, axis_name):
    """Move exactly the cut-link rows (paper's C_T) via rotation rounds."""
    d = h_local.shape[-1]
    halo = jnp.zeros((halo_cap + 1, d), h_local.dtype)
    zero_row = jnp.zeros((1, d), h_local.dtype)
    table = jnp.concatenate([h_local, zero_row], axis=0)
    for r in rounds:
        send = table[jnp.where(r["send_idx"] < 0, h_local.shape[0], r["send_idx"])]
        got = jax.lax.ppermute(
            send, axis_name,
            [(p, (p + r["shift"]) % r["nparts"]) for p in range(r["nparts"])],
        )
        halo = halo.at[r["recv_pos"]].set(got)
    return halo[:halo_cap]


def _exchange_allgather(h_local, halo_slot, axis_name):
    """Naive exchange: gather all blocks, pick halo rows (layout-agnostic)."""
    d = h_local.shape[-1]
    all_blocks = jax.lax.all_gather(h_local, axis_name)     # (P, cap, d)
    flat = all_blocks.reshape(-1, d)
    flat = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], axis=0)
    idx = jnp.minimum(halo_slot, flat.shape[0] - 1)
    return flat[idx]


def _device_layer(cfg, p, h_local, halo, plan_arrs, last):
    """One GNN layer on one device, mirroring models.py semantics exactly.

    ``h_local``: (cap, d); ``halo``: (halo_cap, d).  Aggregation runs over the
    device's edge list in table coordinates; padded edges hit the zero row and
    the dummy (cap-th) destination segment.
    """
    cap = h_local.shape[0]
    edges_src, edges_dst, deg = (
        plan_arrs["edges_src"], plan_arrs["edges_dst"], plan_arrs["deg"])
    zero_row = jnp.zeros((1, h_local.shape[1]), h_local.dtype)

    if cfg.model == "gcn":
        table = jnp.concatenate([h_local, halo, zero_row], axis=0)
        msgs = table[edges_src]
        agg = segment_sum(msgs, edges_dst, cap + 1)[:cap]
        out = (agg + h_local) / (deg[:, None] + 1.0)
        out = out @ p["w"]
    elif cfg.model == "sage":
        table = jnp.concatenate([h_local, halo, zero_row], axis=0)
        msgs = table[edges_src]
        agg = segment_sum(msgs, edges_dst, cap + 1)[:cap]
        agg = agg / jnp.maximum(deg, 1.0)[:, None]
        out = jnp.concatenate([agg, h_local], axis=-1) @ p["w"]
    elif cfg.model == "gat":
        # Compute W h for every table row locally (pull-then-compute BSP).
        table_h = jnp.concatenate([h_local, halo, zero_row], axis=0)
        wh = table_h @ p["w"]
        a_dst = wh[:cap] @ p["att_src"]                  # only local dsts score
        a_src = wh @ p["att_dst"]
        logits = jax.nn.leaky_relu(a_dst[edges_dst % cap] + a_src[edges_src], 0.2)
        # Mask padded edges out of the softmax.
        pad = edges_dst >= cap
        logits = jnp.where(pad, -jnp.inf, logits)
        self_logit = jax.nn.leaky_relu(a_dst + wh[:cap] @ p["att_dst"], 0.2)
        seg_max = jax.ops.segment_max(logits, edges_dst, num_segments=cap + 1)[:cap]
        seg_max = jnp.maximum(jnp.where(jnp.isfinite(seg_max), seg_max, -jnp.inf),
                              self_logit)
        ex = jnp.where(pad, 0.0, jnp.exp(logits - seg_max[edges_dst % cap]))
        ex_self = jnp.exp(self_logit - seg_max)
        denom = segment_sum(ex[:, None], edges_dst, cap + 1)[:cap, 0] + ex_self
        num = segment_sum(ex[:, None] * wh[edges_src], edges_dst, cap + 1)[:cap]
        num = num + ex_self[:, None] * wh[:cap]
        out = num / jnp.maximum(denom, 1e-16)[:, None]
    else:
        raise ValueError(cfg.model)
    return out if last else jax.nn.relu(out)


def _bsp_forward_device(cfg, params, h_local, plan_arrs, rounds, halo_cap,
                        exchange, axis_name):
    for k, p in enumerate(params):
        if exchange == "ppermute":
            halo = _exchange_ppermute(h_local, rounds, halo_cap, axis_name)
        else:
            halo = _exchange_allgather(h_local, plan_arrs["halo_slot"], axis_name)
        h_local = _device_layer(cfg, p, h_local, halo, plan_arrs,
                                k == len(params) - 1)
    return h_local


def make_bsp_forward(
    cfg: GNNConfig,
    plan: ShardPlan,
    mesh: Mesh,
    axis_name: str = "data",
    exchange: str = "ppermute",
):
    """Build the shard_map'd full forward: (params, blocks (P,cap,d)) -> blocks.

    ``exchange='ppermute'`` moves only cut-link rows (GLAD-aware);
    ``'allgather'`` is the layout-agnostic baseline.
    """
    rounds = [
        {"shift": r["shift"], "nparts": plan.num_parts,
         "send_idx": r["send_idx"], "recv_pos": r["recv_pos"]}
        for r in plan.rounds
    ]
    spec_b = P(axis_name)

    # Round index arrays enter as sharded operands so each device slices its
    # own row; two arrays (send_idx, recv_pos) per pruned round.
    round_ops = []
    for r in rounds:
        round_ops.append(r["send_idx"])
        round_ops.append(r["recv_pos"])

    def wrapper(params, blocks):
        def inner(params, blocks, es, ed, dg, hs, *round_arrs):
            plan_arrs = {
                "edges_src": es[0], "edges_dst": ed[0],
                "deg": dg[0], "halo_slot": hs[0],
            }
            local_rounds = []
            for k, r in enumerate(rounds):
                local_rounds.append({
                    "shift": r["shift"], "nparts": r["nparts"],
                    "send_idx": round_arrs[2 * k][0],
                    "recv_pos": round_arrs[2 * k + 1][0],
                })
            out = _bsp_forward_device(
                cfg, params, blocks[0], plan_arrs, local_rounds,
                plan.halo_cap, exchange, axis_name)
            return out[None]

        smapped = jaxcompat.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), spec_b, spec_b, spec_b, spec_b, spec_b)
            + tuple(spec_b for _ in round_ops),
            out_specs=spec_b,
        )
        return smapped(
            params, blocks,
            jnp.asarray(plan.edges_src), jnp.asarray(plan.edges_dst),
            jnp.asarray(plan.deg), jnp.asarray(plan.halo_slot),
            *[jnp.asarray(a) for a in round_ops],
        )

    return wrapper


# ----------------------------------------------------- single-device oracle
def simulate_bsp_forward(cfg, params, plan: ShardPlan, features: np.ndarray,
                         exchange: str = "ppermute") -> np.ndarray:
    """Run the exact device computation without a multi-device mesh: the halo
    is served from the global feature table (mathematically identical to
    either exchange path).  Used by tests and the CPU examples."""
    blocks = jnp.asarray(scatter_features(plan, features))
    Pn, cap, d = blocks.shape

    def one_layer_all(h_blocks, k, p, last):
        flat = h_blocks.reshape(Pn * cap, -1)
        flat = jnp.concatenate([flat, jnp.zeros((1, flat.shape[1]), flat.dtype)])
        outs = []
        for q in range(Pn):
            idx = jnp.minimum(jnp.asarray(plan.halo_slot[q]), Pn * cap)
            halo = flat[idx]
            plan_arrs = {
                "edges_src": jnp.asarray(plan.edges_src[q]),
                "edges_dst": jnp.asarray(plan.edges_dst[q]),
                "deg": jnp.asarray(plan.deg[q]),
            }
            outs.append(_device_layer(cfg, p, h_blocks[q], halo, plan_arrs, last))
        return jnp.stack(outs)

    h = blocks
    for k, p in enumerate(params):
        h = one_layer_all(h, k, p, k == len(params) - 1)
    return np.asarray(gather_outputs(plan, np.asarray(h), features.shape[0]))
