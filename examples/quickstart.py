"""Quickstart: cost-optimized graph layout for distributed GNN processing.

Builds a Yelp-like data graph + a heterogeneous 8-server edge fleet,
compares Random / Greedy / GLAD-S layouts, then actually RUNS the
distributed GNN under the optimized layout and verifies numerics.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CostModel, glad_s, greedy_layout, random_layout,
                        workload_for)
from repro.core.partition import partition_from_assign
from repro.gnn import (GNNConfig, compile_plan, directed_edges, forward,
                       init_params, simulate_bsp_forward)
from repro.graphs import build_edge_network, synthetic_yelp


def main():
    print("== GLAD quickstart ==")
    g = synthetic_yelp(n=600, target_links=800)
    net = build_edge_network(g, 8, seed=0)
    cm = CostModel(net, g, workload_for("gcn", 100))

    rand = random_layout(cm, seed=0)
    greedy = greedy_layout(cm)
    res = glad_s(cm, seed=0)
    print(f"cost: random={cm.total(rand):9.1f}  greedy={cm.total(greedy):9.1f}"
          f"  GLAD-S={res.cost:9.1f}  "
          f"({1 - res.cost / cm.total(rand):.1%} cheaper than random, "
          f"{res.iterations} iterations, {res.wall_time_s:.2f}s)")
    print("factors:", {k: round(v, 1) for k, v in res.factors.items()})

    # Execute the distributed GNN under both layouts; numerics must agree.
    cfg = GNNConfig("gcn", (100, 16, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    ref = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                             jnp.asarray(directed_edges(g.edges))))
    for name, assign in (("random", rand), ("GLAD-S", res.assign)):
        part = partition_from_assign(g, assign, net.m, cm.factors(assign))
        plan = compile_plan(g, part)
        out = simulate_bsp_forward(cfg, params, plan, g.features)
        err = float(np.abs(out - ref).max())
        print(f"{name:8s}: cut_links={part.cut_links:5d} "
              f"halo_rows_exchanged={plan.halo_bytes_ppermute:6d} "
              f"ppermute_rounds={len(plan.rounds):3d}  max_err={err:.2e}")
    print("the GLAD layout moves fewer halo rows for identical outputs.")


if __name__ == "__main__":
    main()
