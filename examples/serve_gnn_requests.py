"""Closed loop: request-driven GNN serving over a live layout that survives
a server failure mid-stream.

  build graph/fleet -> GLAD layout (traffic-aware) -> compile ShardPlan
  -> serve a Zipf request stream -> server dies -> ElasticCoordinator
  re-layouts -> patch_plan patches the live plan -> serving continues
  (the engine re-seeds its caches off the new halos; no rebuild).

  PYTHONPATH=src python examples/serve_gnn_requests.py [--requests 2000]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.core import CostModel, workload_for
from repro.core.glad_s import glad_s
from repro.core.partition import partition_from_assign
from repro.gnn import (GNNConfig, GNNServeEngine, compile_plan, init_params,
                       link_traffic, patch_plan, request_traffic,
                       zipf_requests)
from repro.graphs import build_edge_network, synthetic_yelp
from repro.runtime import ElasticCoordinator


def main(requests: int = 2000, servers: int = 6):
    print("== request-driven serving over a live, fault-tolerant layout ==")
    g = synthetic_yelp(n=800, target_links=1000)
    net = build_edge_network(g, servers, seed=0, mu_factor=2.0)
    gnn = workload_for("gcn", g.features.shape[1])

    # The stream is known-skewed (Zipf): hand GLAD the traffic histogram
    # (unary compute rows) and ego-crossing edge weights (pairwise C_T)
    # so hot neighborhoods dominate the placement on both axes.
    stream = zipf_requests(g.n, requests, s=1.1, seed=0)
    g_aware = dataclasses.replace(
        g, edge_weights=g.weights_or_ones() * link_traffic(g, stream, 2))
    cm = CostModel(net, g_aware, gnn,
                   traffic=request_traffic(g.n, stream, graph=g, hops=2))
    res = glad_s(cm, R=servers, seed=0, sweep="batched")
    part = partition_from_assign(g, res.assign, servers, res.factors)
    plan = compile_plan(g, part, slack=0.5)
    print(f"layout: cost {res.cost:.1f} over {servers} servers, "
          f"plan v{plan.version}")

    cfg = GNNConfig("gcn", (g.features.shape[1], 16, 4))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = GNNServeEngine(cfg, params, g, plan, batch=16, net=net)

    half = requests // 2
    eng.serve(stream[:half])
    s = eng.stats
    print(f"first half: {s.requests} served, "
          f"{s.throughput_rps:.0f} req/s, p99 "
          f"{eng.latency_percentiles()['p99'] * 1e3:.1f} ms, rows "
          f"local/hit/fetched = {s.local_rows}/{s.cache_hit_rows}/"
          f"{s.fetched_rows}")

    # A server dies mid-stream.  The coordinator disconnects it, GLAD
    # re-layouts incrementally, and the move delta patches the LIVE plan.
    dead = int(np.bincount(part.assign, minlength=servers).argmax())
    coord = ElasticCoordinator(net, g, gnn, part)
    new_part = coord.on_failure([dead])
    ev = coord.events[-1]
    pd = patch_plan(plan, g, new_part.assign)
    print(f"server {dead} FAILED: re-layout moved {ev.migrated} vertices "
          f"in {ev.wall_time_s * 1e3:.0f} ms "
          f"(cost {ev.old_cost:.0f} -> {ev.new_cost:.0f}); plan "
          f"{'patched' if pd.patched else 'rebuilt'} to v{plan.version}, "
          f"dirty {len(pd.dirty_parts)}/{plan.num_parts} partitions")

    eng.serve(stream[half:])
    s = eng.stats
    assert not np.isin(plan.assign, [dead]).any()
    print(f"second half: {s.requests} total served, cache re-seeds "
          f"{s.plan_refreshes}, rows local/hit/fetched = "
          f"{s.local_rows}/{s.cache_hit_rows}/{s.fetched_rows}, "
          f"fetch cost {s.fetch_cost:.1f}")
    print(f"overall: {s.throughput_rps:.0f} req/s, p99 "
          f"{eng.latency_percentiles()['p99'] * 1e3:.1f} ms, "
          f"forward traces {eng.fwd.stats['traces']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--servers", type=int, default=6)
    a = ap.parse_args()
    main(a.requests, a.servers)
