"""GLAD beyond the paper: MoE expert placement as a graph-layout problem.

Experts = vertices (weighted by routed-token load), co-activation = links
(tokens routed to both experts pay cross-slice traffic when separated),
mesh slices = servers.  GLAD-S minimizes exactly the paper's C_P + C_T —
here that means balanced expert load with co-activated experts co-located.

  PYTHONPATH=src python examples/expert_placement.py
"""
import numpy as np

from repro.core.partition import coactivation_graph, expert_layout


def synth_routing(E=64, groups=8, tokens=200_000, seed=0):
    """Co-routing histogram with planted expert communities (tokens prefer
    experts in the same latent group — the structure GLAD should discover)."""
    rng = np.random.default_rng(seed)
    counts = np.zeros((E, E))
    per = E // groups
    for _ in range(tokens // 100):
        gidx = rng.integers(0, groups)
        pool = np.arange(gidx * per, (gidx + 1) * per)
        # top-6-of-group with a little leakage
        k = rng.choice(pool, size=4, replace=False)
        if rng.uniform() < 0.2:
            k[-1] = rng.integers(0, E)
        for a in k:
            counts[a, a] += 100 / 4
            for b in k:
                if a < b:
                    counts[a, b] += 100 / 12
                    counts[b, a] += 100 / 12
    return counts


def main():
    print("== MoE expert layout via GLAD (deepseek-moe geometry) ==")
    counts = synth_routing()
    part = expert_layout(counts, num_slices=8, pods=2, seed=0)
    g = coactivation_graph(counts)
    rng = np.random.default_rng(0)
    rand_assign = rng.integers(0, 8, size=64)
    rand_cut_w = sum(counts[u, v] for u, v in g.edges
                     if rand_assign[u] != rand_assign[v])
    glad_cut_w = sum(counts[u, v] for u, v in g.edges
                     if part.assign[u] != part.assign[v])
    load = counts.diagonal()
    glad_load = np.array([load[part.assign == s].sum() for s in range(8)])
    rand_load = np.array([load[rand_assign == s].sum() for s in range(8)])
    print(f"cross-slice co-activation weight: random={rand_cut_w:.0f} "
          f"GLAD={glad_cut_w:.0f} ({1 - glad_cut_w / max(rand_cut_w, 1):.1%} less all-to-all)")
    print(f"load imbalance (max/mean): random={rand_load.max()/rand_load.mean():.2f} "
          f"GLAD={glad_load.max()/glad_load.mean():.2f}")
    print("per-slice experts:", np.bincount(part.assign, minlength=8))


if __name__ == "__main__":
    main()
