"""Serve a small LM with continuously-batched requests (reduced llama
config on CPU; the same engine drives the full configs on a pod).

  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models as zoo
from repro.configs import get_smoke_config
from repro.serve import Request, ServeEngine


def main():
    print("== batched LM serving (continuous batching) ==")
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"),
                              dtype=jnp.float32)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=4, max_len=96)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(12):
        prompt = rng.integers(1, cfg.vocab, size=int(rng.integers(4, 20)))
        eng.submit(Request(uid=uid, prompt=prompt.astype(np.int32),
                           max_new_tokens=12, eos_id=-1))
    stats = eng.run()
    dt = time.perf_counter() - t0
    print(f"completed {stats.completed} requests in {stats.ticks} decode "
          f"ticks ({stats.prefills} prefills), "
          f"{stats.generated_tokens} tokens in {dt:.2f}s "
          f"({stats.generated_tokens / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
