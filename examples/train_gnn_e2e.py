"""End-to-end driver: train a GNN node classifier over a GLAD-partitioned
graph for a few hundred steps, with checkpointing and a simulated node
failure + elastic re-layout mid-run.

  PYTHONPATH=src python examples/train_gnn_e2e.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.core import data_partition, workload_for
from repro.gnn import GNNConfig, directed_edges, init_params
from repro.gnn.training import accuracy, train_step
from repro.graphs import build_edge_network, synthetic_siot
from repro.runtime import ElasticCoordinator, FailureDetector
from repro.train import CheckpointManager


def main(steps: int = 300):
    print("== distributed GNN training with GLAD layout + fault handling ==")
    g = synthetic_siot(n=1200, target_links=4000)
    gnn_w = workload_for("gcn", 52)
    net = build_edge_network(g, 6, seed=0)
    part = data_partition(g, gnn_w, num_parts=6, net=net, seed=0)
    print(f"GLAD layout: cut_links={part.cut_links} "
          f"cost={part.cost_factors['total']:.1f}")

    cfg = GNNConfig("gcn", (52, 32, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    sd = directed_edges(g.edges)
    ckdir = tempfile.mkdtemp(prefix="gnn_ck_")
    ck = CheckpointManager(ckdir, keep=2, async_write=False)

    fd = FailureDetector(6, timeout_s=5.0)
    coord = ElasticCoordinator(net, g, gnn_w, part)

    a0 = accuracy(cfg, params, g.features, sd, g.labels)
    feats, sdj, lab = (jnp.asarray(g.features), jnp.asarray(sd),
                      jnp.asarray(g.labels))
    half = steps // 2
    losses = []
    for s in range(half):
        params, loss = train_step(cfg, params, feats, sdj, lab, 0.05)
        losses.append(float(loss))
        for d in range(6):
            fd.heartbeat(d, now=float(s))
    ck.save(half, {"params": params})
    print(f"step {half}: loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpointed to {ckdir}")

    # Simulate node 4 dying: detector notices, GLAD-E re-layouts survivors.
    for d in (0, 1, 2, 3, 5):
        fd.heartbeat(d, now=float(half + 6))
    dead = fd.sweep(now=float(half + 6))
    print(f"failure detected on servers {dead}")
    coord.on_failure(dead)
    ev = coord.events[-1]
    print(f"elastic re-layout: migrated={ev.migrated} vertices, "
          f"cost {ev.old_cost:.1f} -> {ev.new_cost:.1f}, "
          f"{ev.wall_time_s * 1e3:.0f} ms")

    # Restore and continue on the shrunken fleet.
    restored, _ = ck.restore(half, {"params": params})
    params = restored["params"]
    for s in range(half, steps):
        params, loss = train_step(cfg, params, feats, sdj, lab, 0.05)
        losses.append(float(loss))
    a1 = accuracy(cfg, params, g.features, sd, g.labels)
    print(f"step {steps}: loss {losses[-1]:.3f}; "
          f"accuracy {a0:.3f} -> {a1:.3f}")
    assert losses[-1] < losses[0] and a1 > a0
    print("OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    main(ap.parse_args().steps)
