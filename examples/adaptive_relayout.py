"""Online scenario: the data graph evolves every time slot; GLAD-A decides
between incremental (GLAD-E) and global (GLAD-S) re-layout under an SLA.

  PYTHONPATH=src python examples/adaptive_relayout.py [--slots 30]
"""
import argparse


from repro.core import GladA, workload_for
from repro.core.evolution import apply_delta, evolution_trace
from repro.graphs import build_edge_network, synthetic_yelp


def main(slots: int = 30, theta: float = 10.0):
    print("== adaptive layout scheduling under graph evolution ==")
    g = synthetic_yelp(n=800, target_links=1000)
    net = build_edge_network(g, 8, seed=0)
    gnn = workload_for("gat", 100)
    sched = GladA(net, gnn, g, theta=theta, R=3, seed=0)
    print(f"initial layout cost {sched.last_cost:.1f} (SLA theta={theta})")

    cur = g
    for delta in evolution_trace(g, slots, pct_links=0.02,
                                 pct_vertices=0.01, seed=1):
        cur = apply_delta(cur, delta)
        rec = sched.step(cur)
        bar = "#" * int(40 * min(rec.cost / sched.records[0].cost, 2) / 2)
        print(f"t={rec.t:3d} {rec.algorithm:6s} cost={rec.cost:9.1f} "
              f"drift={rec.drift_estimate:8.2f} migrated={rec.migrated_vertices:4d} "
              f"|{bar}")
    n_s = sum(1 for r in sched.records[1:] if r.algorithm == "glad-s")
    print(f"GLAD-S invoked {n_s}/{slots} slots; "
          f"final cost {sched.last_cost:.1f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=30)
    ap.add_argument("--theta", type=float, default=10.0)
    a = ap.parse_args()
    main(a.slots, a.theta)
