"""Online scenario: the data graph evolves every time slot; GLAD-A decides
between incremental (GLAD-E) and global (GLAD-S) re-layout under an SLA —
and a live ShardPlan follows the layout through the incremental plan
pipeline: evolve -> relayout -> patch_plan -> resumed forward, with a full
plan recompile only when a capacity actually grows.

  PYTHONPATH=src python examples/adaptive_relayout.py [--slots 30]
"""
import argparse

import numpy as np

from repro.core import GladA, workload_for
from repro.core.evolution import apply_delta, evolution_trace
from repro.core.partition import partition_from_assign
from repro.gnn import (GNNConfig, compile_plan, init_params, patch_plan,
                       simulate_bsp_forward)
from repro.graphs import build_edge_network, synthetic_yelp


def main(slots: int = 30, theta: float = 10.0):
    print("== adaptive layout scheduling under graph evolution ==")
    g = synthetic_yelp(n=800, target_links=1000)
    net = build_edge_network(g, 8, seed=0)
    gnn = workload_for("gat", 100)
    sched = GladA(net, gnn, g, theta=theta, R=3, seed=0)
    print(f"initial layout cost {sched.last_cost:.1f} (SLA theta={theta})")

    # Serving side: one ShardPlan compiled with capacity headroom, then
    # PATCHED in place every slot (dirty partitions only).  A value-only
    # patch leaves every array shape unchanged, so a jitted BSP forward
    # bound to this plan would not retrace (see tests/test_plan_patch.py
    # for the retrace-count assertion on a real 8-device mesh).
    import jax
    cfg = GNNConfig("gcn", (g.features.shape[1], 16, 4))
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan = compile_plan(
        g, partition_from_assign(g, sched.assign, net.m, {}), slack=0.5)
    _ = simulate_bsp_forward(cfg, params, plan, g.features)
    patched = rebuilt = 0

    cur = g
    for delta in evolution_trace(g, slots, pct_links=0.02,
                                 pct_vertices=0.01, seed=1):
        new_graph = apply_delta(cur, delta)
        rec = sched.step(new_graph)
        # Structure deltas: endpoints of inserted/removed links (inserted
        # vertices are movers by construction, patch_plan derives them).
        # Deleted vertices keep their id slot but lose every incident arc
        # — those arcs are invisible in the NEW edge set, so their
        # pre-delta neighborhoods must be marked dirty explicitly.
        dirty = [delta.add_edges.ravel(), delta.del_edges.ravel(),
                 delta.del_vertices]
        dirty += [cur.neighbors(int(v)) for v in delta.del_vertices]
        dirty = np.unique(np.concatenate([d for d in dirty if len(d)])) \
            if any(len(d) for d in dirty) else None
        pd = patch_plan(plan, new_graph, sched.assign, dirty_vertices=dirty)
        patched += pd.patched
        rebuilt += not pd.patched
        out = simulate_bsp_forward(cfg, params, plan, new_graph.features)
        cur = new_graph
        bar = "#" * int(40 * min(rec.cost / sched.records[0].cost, 2) / 2)
        print(f"t={rec.t:3d} {rec.algorithm:6s} cost={rec.cost:9.1f} "
              f"drift={rec.drift_estimate:8.2f} "
              f"migrated={rec.migrated_vertices:4d} "
              f"plan={'patch' if pd.patched else 'REBUILD':7s} "
              f"dirty={len(pd.dirty_parts)}/{plan.num_parts} "
              f"emb={float(np.abs(out).mean()):.4f} |{bar}")
    n_s = sum(1 for r in sched.records[1:] if r.algorithm == "glad-s")
    print(f"GLAD-S invoked {n_s}/{slots} slots; "
          f"final cost {sched.last_cost:.1f}")
    print(f"plan lifecycle: {patched} in-place patches, {rebuilt} full "
          f"rebuilds (capacity growth), plan v{plan.version} "
          f"cap={plan.cap} halo_cap={plan.halo_cap} e_cap={plan.e_cap}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=30)
    ap.add_argument("--theta", type=float, default=10.0)
    a = ap.parse_args()
    main(a.slots, a.theta)
