"""Distributed BSP engine: plan invariants + simulate==oracle (+ real
shard_map collectives in a 4-device subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import partition_from_assign
from repro.gnn.distributed import compile_plan, simulate_bsp_forward
from repro.gnn.models import GNNConfig, directed_edges, forward, init_params
from tests.conftest import random_graph


def _plan_for(g, parts, seed=0):
    assign = np.random.default_rng(seed).integers(0, parts, size=g.n)
    part = partition_from_assign(g, assign, parts, {})
    return assign, part, compile_plan(g, part)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000))
def test_plan_invariants(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(8, 40)), 20)
    parts = int(rng.integers(2, 5))
    assign, part, plan = _plan_for(g, parts, seed)
    # 1) every vertex appears in exactly one local block.
    seen = plan.local[plan.local >= 0]
    assert sorted(seen.tolist()) == list(range(g.n))
    # 2) every cut link's remote endpoint is in the destination's halo.
    for u, v in g.edges:
        pu, pv = assign[u], assign[v]
        if pu != pv:
            assert u in plan.halo[pv], (u, v)
            assert v in plan.halo[pu], (u, v)
    # 3) ppermute rounds deliver exactly the halo rows (no dupes/misses).
    delivered = [set() for _ in range(parts)]
    for r in plan.rounds:
        s = r["shift"]
        for p in range(parts):
            q = (p + s) % parts
            for k, li in enumerate(r["send_idx"][p]):
                if li >= 0:
                    vtx = plan.local[p, li]
                    pos = r["recv_pos"][q, k]
                    assert plan.halo[q, pos] == vtx
                    delivered[q].add(int(vtx))
    for p in range(parts):
        expect = set(plan.halo[p][plan.halo[p] >= 0].tolist())
        assert delivered[p] == expect


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_simulate_matches_full_forward(model, small_siot):
    g = small_siot
    assign, part, plan = _plan_for(g, 4, seed=1)
    cfg = GNNConfig(model, (8,) + (16, 2))
    feats = np.random.default_rng(0).normal(size=(g.n, 8)).astype(np.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ref = np.asarray(forward(cfg, params, jnp.asarray(feats),
                             jnp.asarray(directed_edges(g.edges))))
    out = simulate_bsp_forward(cfg, params, plan, feats)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import numpy as np, jax, jax.numpy as jnp
    from repro.graphs import synthetic_siot
    from repro.gnn import (GNNConfig, init_params, forward, directed_edges,
                           compile_plan, make_bsp_forward, scatter_features,
                           gather_outputs)
    from repro.core.partition import partition_from_assign

    g = synthetic_siot(n=120, target_links=300)
    assign = np.random.default_rng(0).integers(0, 4, size=g.n)
    part = partition_from_assign(g, assign, 4, {})
    plan = compile_plan(g, part)
    from repro.jaxcompat import make_mesh
    mesh = make_mesh((4,), ('data',))
    blocks = jnp.asarray(scatter_features(plan, g.features))
    sd = jnp.asarray(directed_edges(g.edges))
    for model in ['gcn', 'sage', 'gat']:
        cfg = GNNConfig(model, (52, 16, 2))
        params = init_params(jax.random.PRNGKey(0), cfg)
        ref = np.asarray(forward(cfg, params, jnp.asarray(g.features), sd))
        for ex in ['ppermute', 'allgather']:
            with mesh:
                fwd = make_bsp_forward(cfg, plan, mesh, exchange=ex)
                out_blocks = np.asarray(jax.jit(fwd)(params, blocks))
            out = gather_outputs(plan, out_blocks, g.n)
            err = float(np.abs(ref - out).max() / (np.abs(ref).max() + 1e-9))
            assert err < 1e-4, (model, ex, err)
    print('MULTIDEV_OK')
""")


def test_shard_map_multidevice_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
