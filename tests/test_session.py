"""Cross-slot persistent LayoutSession: bit-identity vs per-slot rebuild.

The session's ONLY contract is that it changes wall time, never bits: a
sequence of relayouts driven through one adopted engine (CostModel.rebind
diffing net / unary / graph deltas into per-vertex epoch bumps) must produce
EXACTLY the trajectories, costs, assignments and moved sets of the same
sequence run with a fresh engine per slot.  A deterministic slot script
pins the interesting transitions (evolve, degrade, fail, revive); the fuzz
harness interleaves them randomly.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, workload_for
from repro.core.engine import LayoutSession
from repro.core.evolution import apply_delta, evolution_trace
from repro.core.glad_e import glad_e
from repro.core.glad_s import glad_s
from repro.graphs.datagraph import synthetic_yelp
from repro.graphs.edgenet import build_edge_network

REGIMES = [(False, False), (True, False), (True, True)]


def _result_tuple(res):
    return (res.cost, tuple(res.history), res.assign.copy(),
            np.sort(res.moved).copy() if res.moved is not None else None)


def _assert_same(a, b, slot):
    assert a[0] == b[0], f"slot {slot}: cost diverged"
    assert a[1] == b[1], f"slot {slot}: history diverged"
    np.testing.assert_array_equal(a[2], b[2], err_msg=f"slot {slot}: assign")
    if a[3] is not None or b[3] is not None:
        np.testing.assert_array_equal(a[3], b[3],
                                      err_msg=f"slot {slot}: moved")


def _run_script(session, cache, warm):
    """Fixed slot script over every transition kind the session must
    survive: full solve, graph evolution (insertions included), server
    degrade, server failure (orphan re-homing), evolution on the degraded
    fleet, revive, and a final evolution on the restored fleet."""
    g0 = synthetic_yelp(n=220, target_links=330, seed=3)
    net0 = build_edge_network(g0, 5, seed=3)
    gnn = workload_for("gcn", 48)
    deltas = evolution_trace(g0, 3, pct_links=0.04, pct_vertices=0.02,
                             seed=4)
    opts = dict(sweep="batched", cache=cache, warm=warm, session=session)
    out = []

    graph, net = g0, net0
    cm = CostModel(net, graph, gnn)
    res = glad_s(cm, R=5, seed=0, **opts)                    # slot 0: full
    out.append(_result_tuple(res))
    assign = res.assign

    g1 = apply_delta(graph, deltas[0])                       # slot 1: evolve
    res = glad_e(CostModel(net, g1, gnn), graph, assign, seed=1, **opts)
    out.append(_result_tuple(res))
    graph, assign = g1, res.assign

    net = net0.degrade(1, 3.0)                               # slot 2: degrade
    res = glad_s(CostModel(net, graph, gnn), init=assign, R=5, seed=2,
                 **opts)
    out.append(_result_tuple(res))
    assign = res.assign

    net = net.without_server(3)                              # slot 3: fail
    init = assign.copy()
    init[init == 3] = 0                  # deterministic orphan re-homing
    res = glad_s(CostModel(net, graph, gnn), init=init, R=5, seed=3,
                 **opts)
    out.append(_result_tuple(res))
    assign = res.assign

    g2 = apply_delta(graph, deltas[1])                       # slot 4: evolve
    res = glad_e(CostModel(net, g2, gnn), graph, assign, seed=4, **opts)
    out.append(_result_tuple(res))
    graph, assign = g2, res.assign

    net = net0.degrade(1, 3.0)                               # slot 5: revive 3
    res = glad_s(CostModel(net, graph, gnn), init=assign, R=5, seed=5,
                 **opts)
    out.append(_result_tuple(res))
    assign = res.assign

    g3 = apply_delta(graph, deltas[2])                       # slot 6: evolve
    res = glad_e(CostModel(net, g3, gnn), graph, assign, seed=6, **opts)
    out.append(_result_tuple(res))
    return out


@pytest.mark.parametrize("cache,warm", REGIMES)
def test_session_slot_script_bit_identical(cache, warm):
    ses = LayoutSession(cache=cache, warm=warm)
    got = _run_script(ses, cache, warm)
    ref = _run_script(None, cache, warm)
    for slot, (a, b) in enumerate(zip(got, ref)):
        _assert_same(a, b, slot)
    # The session must actually have REBOUND (diffed) engines, not
    # silently rebuilt one per slot.
    assert ses.adoptions >= 6
    assert ses.rebinds >= ses.adoptions - 1


def test_degrade_rebind_column_patches_instead_of_rebuilding():
    """A dense per-server repricing (degrade/revive — the fault loop's
    bread and butter) must not cost the session its assemblies: tau, and
    therefore every internal arc, is untouched by compute repricing, so
    the affected pairs re-gather whole theta columns IN PLACE (counted
    as 'patched', never 'misses') and the retained warm residuals are
    repaired rather than re-pushed.  A mild degrade keeps the layout
    (mostly) put, so the relayout is the confirm-shaped probe sweep
    where every engine byte carried across the rebind pays off."""
    g = synthetic_yelp(n=1200, target_links=1800, seed=7)
    net0 = build_edge_network(g, 4, seed=7)
    gnn = workload_for("gcn", 32)
    ses = LayoutSession(cache=True, warm=True)
    res0 = glad_s(CostModel(net0, g, gnn), R=4, seed=0, sweep="batched",
                  cache=True, warm=True, session=ses)
    eng = ses.engine
    before = dict(eng.cache_stats())
    net1 = net0.degrade(1, 1.1)
    res1 = glad_s(CostModel(net1, g, gnn), init=res0.assign.copy(), R=4,
                  seed=1, sweep="batched", cache=True, warm=True,
                  session=ses)
    assert ses.rebinds == 1 and ses.engine is eng
    after = eng.cache_stats()
    assert after["patched"] > before["patched"]    # column patches engaged
    # Resident entries must never be rebuilt over a degrade rebind: new
    # assemblies are allowed only for pairs the first slot never cached.
    uncached = 4 * 3 // 2 - before["entries"]      # m=4: 6 possible pairs
    assert after["misses"] - before["misses"] <= uncached
    assert (after["warm_hits"] + after["warm_repairs"]
            > before["warm_hits"] + before["warm_repairs"])
    ref = glad_s(CostModel(net1, g, gnn), init=res0.assign.copy(), R=4,
                 seed=1, sweep="batched", cache=True, warm=True)
    assert res1.history == ref.history
    np.testing.assert_array_equal(res1.assign, ref.assign)


def test_session_guards():
    cm = CostModel(build_edge_network(synthetic_yelp(n=60, target_links=90,
                                                     seed=0), 4, seed=0),
                   synthetic_yelp(n=60, target_links=90, seed=0),
                   workload_for("gcn", 16))
    ses = LayoutSession()
    with pytest.raises(ValueError, match="incremental"):
        glad_s(cm, session=ses, engine="reference")


def test_session_multilevel_coexist_bit_identical():
    """The session x multilevel exclusion is gone: the V-cycle runs with
    a session (which then owns the persistent LevelStack, and whose
    engine the finest refinement adopts) and its trajectory stays
    bit-identical to the sessionless call."""
    g = synthetic_yelp(n=60, target_links=90, seed=0)
    cm = CostModel(build_edge_network(g, 4, seed=0), g,
                   workload_for("gcn", 16))
    ses = LayoutSession()
    res = glad_s(cm, seed=0, sweep="batched", multilevel=True,
                 coarsen_to=16, session=ses)
    ref = glad_s(cm, seed=0, sweep="batched", multilevel=True,
                 coarsen_to=16)
    assert res.history == ref.history
    np.testing.assert_array_equal(res.assign, ref.assign)
    assert res.coarsen is not None and res.coarsen["mode"] == "build"
    assert ses.stack_valid_for(cm, coarsen_to=16)


def test_session_adopt_falls_back_on_incompatible_model():
    """A model the diff cannot express (different fleet size) silently
    falls back to a fresh engine — adopt never fails, it just loses the
    carried state."""
    g = synthetic_yelp(n=80, target_links=120, seed=1)
    gnn = workload_for("gcn", 16)
    ses = LayoutSession()
    cm4 = CostModel(build_edge_network(g, 4, seed=1), g, gnn)
    r4 = glad_s(cm4, R=4, seed=0, sweep="batched", session=ses)
    cm5 = CostModel(build_edge_network(g, 5, seed=1), g, gnn)
    r5 = glad_s(cm5, init=r4.assign, R=5, seed=0, sweep="batched",
                session=ses)
    ref = glad_s(cm5, init=r4.assign, R=5, seed=0, sweep="batched")
    assert r5.history == ref.history
    np.testing.assert_array_equal(r5.assign, ref.assign)
    assert ses.adoptions == 2 and ses.rebinds == 0


# ------------------------------------------------------------------- fuzz
def _fuzz_sequence(seed, cache, warm, session):
    """Random interleaving of evolve / degrade / fail / revive slots,
    mirroring ElasticCoordinator's net bookkeeping (pristine + op replay)."""
    rng = np.random.default_rng(seed)
    g = synthetic_yelp(n=150, target_links=220, seed=seed % 7)
    net0 = build_edge_network(g, 4, seed=seed % 5)
    gnn = workload_for("gcn", 24)
    opts = dict(sweep="batched", cache=cache, warm=warm, session=session)

    ops = []                     # surviving ("dead", d) / ("deg", d, f)

    def current_net():
        net = net0
        for op in ops:
            net = (net.without_server(op[1]) if op[0] == "dead"
                   else net.degrade(op[1], op[2]))
        return net

    net = net0
    res = glad_s(CostModel(net, g, gnn), R=4, seed=seed, **opts)
    out = [_result_tuple(res)]
    assign, graph = res.assign, g
    for slot in range(5):
        dead = {op[1] for op in ops if op[0] == "dead"}
        live = [i for i in range(4) if i not in dead]
        kinds = ["evolve", "degrade"]
        if len(live) > 2:
            kinds.append("fail")
        if ops:
            kinds.append("revive")
        kind = kinds[int(rng.integers(0, len(kinds)))]
        if kind == "evolve":
            delta = evolution_trace(graph, 1, pct_links=0.05,
                                    pct_vertices=0.02,
                                    seed=seed * 31 + slot)[0]
            g2 = apply_delta(graph, delta)
            res = glad_e(CostModel(net, g2, gnn), graph, assign,
                         seed=seed + slot, **opts)
            graph = g2
        else:
            if kind == "degrade":
                ops.append(("deg", int(rng.choice(live)), 2.5))
            elif kind == "fail":
                d = int(rng.choice(live))
                ops.append(("dead", d))
                assign = assign.copy()
                assign[assign == d] = [i for i in live if i != d][0]
            else:                                            # revive
                victim = ops[int(rng.integers(0, len(ops)))][1]
                ops = [op for op in ops if op[1] != victim]
            net = current_net()
            res = glad_s(CostModel(net, graph, gnn), init=assign, R=4,
                         seed=seed + slot, **opts)
        out.append(_result_tuple(res))
        assign = res.assign
    return out


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_session_fuzz_slot_sequences_bit_identical(seed):
    for cache, warm in REGIMES:
        ses = LayoutSession(cache=cache, warm=warm)
        got = _fuzz_sequence(seed, cache, warm, ses)
        ref = _fuzz_sequence(seed, cache, warm, None)
        for slot, (a, b) in enumerate(zip(got, ref)):
            _assert_same(a, b, slot)
