"""Incremental ShardPlan pipeline: patch-vs-recompile bit-identity over
randomized move/evolve sequences, capacity-growth fallbacks, empty-partition
regressions, dtype pins, move-delta threading — and a real 8-device
subprocess asserting zero jit retraces on value-only patches plus parity of
every (exchange x aggregate) path against the oracle and a dense forward."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, workload_for
from repro.core.evolution import apply_delta, sample_delta
from repro.core.glad_e import glad_e
from repro.core.glad_s import glad_s
from repro.core.partition import partition_from_assign
from repro.gnn.distributed import (
    _check_int32, build_plan_bsr, compile_plan, gather_outputs, patch_plan,
    plans_equal, recompile_like, resolve_aggregate, scatter_features,
    scatter_ints, simulate_bsp_forward,
)
from repro.gnn.models import GNNConfig, directed_edges, forward, init_params
from repro.graphs.datagraph import DataGraph
from repro.graphs.edgenet import build_edge_network
from tests.conftest import random_graph


def _plan_for(g, parts, seed=0, slack=0.0):
    assign = np.random.default_rng(seed).integers(0, parts, size=g.n)
    part = partition_from_assign(g, assign, parts, {})
    return assign, compile_plan(g, part, slack=slack)


def _forward_pair(cfg, params, plan_a, plan_b, feats):
    out_a = simulate_bsp_forward(cfg, params, plan_a, feats)
    out_b = simulate_bsp_forward(cfg, params, plan_b, feats)
    np.testing.assert_array_equal(out_a, out_b)
    return out_a


# ------------------------------------------------- randomized move sequences
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_patch_bit_identical_to_fresh_compile(seed):
    """Random relayout sequences: the patched plan is array-identical to a
    from-scratch compile at the same capacities, and its forward is
    bit-identical — growth steps (fallback rebuild) included."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(24, 64)), int(rng.integers(8, 60)))
    P = int(rng.integers(2, 6))
    slack = float(rng.choice([0.0, 0.3]))
    assign, plan = _plan_for(g, P, seed=seed, slack=slack)
    if rng.uniform() < 0.5:
        build_plan_bsr(plan, bm=4, bk=8)
    cfg = GNNConfig(str(rng.choice(["gcn", "sage"])), (8, 8, 2))
    params = init_params(jax.random.PRNGKey(seed), cfg)

    cur = assign.copy()
    for step in range(4):
        k = int(rng.integers(1, max(2, g.n // 3)))
        movers = rng.choice(g.n, size=k, replace=False)
        new = cur.copy()
        new[movers] = rng.integers(0, P, size=k)
        delta = patch_plan(plan, g, new)
        fresh = recompile_like(plan, g, new)
        assert plans_equal(plan, fresh) == []
        assert np.array_equal(np.sort(delta.moved),
                              np.flatnonzero(cur != new))
        if step % 2 == 0:
            _forward_pair(cfg, params, plan, fresh, g.features)
        cur = new


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_patch_tracks_graph_evolution(seed):
    """Evolve the graph (insert/delete links, insert AND delete vertices),
    relayout via GLAD-E, patch with the returned move delta + structure
    endpoints — patched plan bit-identical to a fresh compile every slot.

    Deleted vertices keep their id slot (the universe is append-only) but
    lose every incident arc; per the patch_plan contract their PRE-DELTA
    neighborhoods join the dirty set (the removed arcs are invisible in
    the new edge list)."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(30, 60)), int(rng.integers(20, 50)))
    P = 4
    net = build_edge_network(g, P, seed=seed)
    gnn = workload_for("gcn", 10)
    assign = glad_s(CostModel(net, g, gnn), R=2, seed=seed).assign
    plan = compile_plan(g, partition_from_assign(g, assign, P, {}), slack=0.4)
    build_plan_bsr(plan, bm=4, bk=8)

    for t in range(3):
        delta = sample_delta(g, pct_links=0.08, pct_vertices=0.05,
                             seed=seed + 17 * t)
        g_new = apply_delta(g, delta)
        net_new = build_edge_network(g_new, P, seed=seed)
        res = glad_e(CostModel(net_new, g_new, gnn), g, assign, seed=seed)
        structural = [delta.add_edges.ravel(), delta.del_edges.ravel(),
                      delta.del_vertices]
        structural += [g.neighbors(int(v)) for v in delta.del_vertices]
        structural = (np.unique(np.concatenate(structural))
                      if any(len(s) for s in structural) else None)
        pd = patch_plan(plan, g_new, res.assign, dirty_vertices=structural)
        fresh = recompile_like(plan, g_new, res.assign)
        assert plans_equal(plan, fresh) == []
        # glad_e's move delta covers every net mover + insertion.
        assert set(np.flatnonzero(
            res.assign[:g.n] != assign)) <= set(res.moved.tolist())
        assert pd.new_vertices == g_new.n - g.n
        g, assign = g_new, res.assign


def test_patch_noop_and_validation(small_siot):
    g = small_siot
    assign, plan = _plan_for(g, 4, seed=3, slack=0.2)
    v0 = plan.version
    pd = patch_plan(plan, g, assign)
    assert pd.patched and len(pd.moved) == 0 and len(pd.dirty_parts) == 0
    assert not pd.retrace_expected and plan.version == v0
    with pytest.raises(ValueError):
        patch_plan(plan, g, assign[:-1])
    bad = assign.copy()
    bad[0] = 7
    with pytest.raises(ValueError):
        patch_plan(plan, g, bad)


def test_growth_falls_back_to_doubled_rebuild(small_siot):
    """Overflowing any capacity triggers a full rebuild at doubled caps,
    still bit-identical to a pinned fresh compile, and flags the retrace."""
    g = small_siot
    assign, plan = _plan_for(g, 4, seed=1, slack=0.0)
    build_plan_bsr(plan, bm=4, bk=8)
    cap0, v0 = plan.cap, plan.version
    new = assign.copy()
    new[: g.n // 2] = 0                          # stampede into part 0
    pd = patch_plan(plan, g, new)
    assert not pd.patched and pd.grew and pd.retrace_expected
    assert plan.cap > cap0 and plan.cap % plan.pad_mult == 0
    assert plan.version == v0 + 1
    assert plans_equal(plan, recompile_like(plan, g, new)) == []
    # Relayouts within the grown headroom patch in place again.
    new2 = new.copy()
    new2[:2] = 1
    pd2 = patch_plan(plan, g, new2)
    assert pd2.patched and not pd2.grew


# --------------------------------------------------- empty-partition fallout
def test_empty_partition_plan_and_forward():
    """A server with zero members after relayout must still produce valid
    padded blocks and a correct forward (regression: zero-length groups)."""
    rng = np.random.default_rng(0)
    g = random_graph(rng, 40, 30)
    assign = np.zeros(g.n, dtype=np.int64)       # parts 1..3 empty
    plan = compile_plan(g, partition_from_assign(g, assign, 4, {}))
    assert plan.local.shape[0] == 4
    assert (plan.local[1:] == -1).all()

    blocks = scatter_features(plan, g.features)
    assert blocks.shape[:2] == (4, plan.cap)
    ints = scatter_ints(plan, np.arange(g.n), pad=-7)
    assert (ints[1:] == -7).all()
    back = gather_outputs(plan, blocks, g.n)
    np.testing.assert_array_equal(back, g.features)

    cfg = GNNConfig("gcn", (8, 8, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    ref = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                             jnp.asarray(directed_edges(g.edges))))
    out = simulate_bsp_forward(cfg, params, plan, g.features)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_relayout_emptying_a_partition_patches_cleanly():
    rng = np.random.default_rng(5)
    g = random_graph(rng, 36, 40)
    assign, plan = _plan_for(g, 3, seed=5, slack=1.0)
    build_plan_bsr(plan, bm=4, bk=8)
    new = assign.copy()
    new[new == 2] = 0                            # part 2 now empty
    pd = patch_plan(plan, g, new)
    assert pd.patched
    assert plans_equal(plan, recompile_like(plan, g, new)) == []
    cfg = GNNConfig("sage", (8, 8, 2))
    params = init_params(jax.random.PRNGKey(5), cfg)
    seg = simulate_bsp_forward(cfg, params, plan, g.features,
                               aggregate="segment")
    bsr = simulate_bsp_forward(cfg, params, plan, g.features,
                               aggregate="pallas")
    np.testing.assert_allclose(bsr, seg, rtol=2e-4, atol=2e-4)


def test_edgeless_graph_plan():
    g = DataGraph(n=6, edges=np.zeros((0, 2), dtype=np.int64))
    g.features = np.random.default_rng(0).normal(size=(6, 4)).astype(np.float32)
    assign = np.array([0, 0, 1, 1, 2, 2])
    plan = compile_plan(g, partition_from_assign(g, assign, 3, {}))
    assert plan.rounds == [] and plan.halo_bytes_ppermute == 0
    pd = patch_plan(plan, g, np.array([0, 1, 1, 2, 2, 0]))
    assert plans_equal(plan, recompile_like(plan, g, plan.assign)) == []
    assert pd.patched or pd.grew


# ------------------------------------------------- dtype pins / determinism
def test_plan_dtypes_and_determinism(small_siot):
    g = small_siot
    assign, plan = _plan_for(g, 4, seed=2)
    # Global slot ids (p * cap + k) overflow int32 at production P * cap:
    # pinned int64.  Per-device coordinates are bounded by table_rows and
    # guarded: pinned int32.
    assert plan.slot_of.dtype == np.int64
    assert plan.halo_slot.dtype == np.int64
    assert plan.local.dtype == np.int64
    assert plan.edges_src.dtype == np.int32
    assert plan.edges_dst.dtype == np.int32
    for r in plan.rounds:
        assert r["send_idx"].dtype == np.int32
        assert r["recv_pos"].dtype == np.int32
    # Deterministic construction: recompiling yields identical tables.
    part = partition_from_assign(g, assign, 4, {})
    again = compile_plan(g, part)
    assert plans_equal(plan, again) == []
    build_plan_bsr(plan, bm=4, bk=8)
    build_plan_bsr(again, bm=4, bk=8)
    assert plans_equal(plan, again) == []
    # Members are degree-BUCKET-ordered within each partition (BSR
    # contract): bucket floor(log2(deg)) non-increasing, vertex id
    # ascending inside each bucket — id-stable slotting across patches.
    from repro.gnn.distributed import _degree_buckets
    b = _degree_buckets(g.degrees)
    for p in range(plan.num_parts):
        vs = plan.local[p][plan.local[p] >= 0]
        db = b[vs]
        assert (np.diff(db) <= 0).all()
        for bucket in np.unique(db):
            ids = vs[db == bucket]
            assert (np.diff(ids) > 0).all()


def test_member_slots_stable_under_in_bucket_degree_drift():
    """The satellite fix for the degree-order reshuffle: a degree bump
    that stays inside its power-of-two bucket must NOT move any member's
    slot, so ``patch_plan`` only reslots parts whose bucket census truly
    changed.  (Exact-degree ordering reshuffled the whole part whenever
    one edge landed.)"""
    from repro.gnn.distributed import _part_members

    # Cycle 0-1-2-3-0: every vertex degree 2 (bucket 1).
    g0 = DataGraph(n=4, edges=np.array([[0, 1], [1, 2], [2, 3], [0, 3]]))
    # Chord 0-2: degrees of 0 and 2 become 3 — still bucket 1.
    g1 = DataGraph(n=4, edges=np.array([[0, 1], [1, 2], [2, 3], [0, 3],
                                        [0, 2]]))
    assign = np.zeros(4, dtype=np.int64)
    m0 = _part_members(g0, assign, 1)[0]
    m1 = _part_members(g1, assign, 1)[0]
    np.testing.assert_array_equal(m0, m1)
    # A bucket-crossing bump (degree 2 -> 4) DOES reorder: hub first.
    g2 = DataGraph(n=6, edges=np.array([[0, 1], [1, 2], [2, 3], [0, 3]]))
    g3 = DataGraph(n=6, edges=np.array([[0, 1], [1, 2], [2, 3], [0, 3],
                                        [2, 4], [2, 5]]))
    assign6 = np.zeros(6, dtype=np.int64)
    m2 = _part_members(g2, assign6, 1)[0]
    m3 = _part_members(g3, assign6, 1)[0]
    assert m3[0] == 2 and not np.array_equal(m2, m3)


def test_int32_guard():
    _check_int32(1 << 10, 1 << 10)               # fine
    with pytest.raises(OverflowError):
        _check_int32(1 << 31, 8)


def test_resolve_aggregate_matrix():
    gcn = GNNConfig("gcn", (4, 2))
    gat = GNNConfig("gat", (4, 2))
    assert resolve_aggregate(gcn, "segment") == "segment"
    assert resolve_aggregate(gcn, "pallas") == "pallas"
    assert resolve_aggregate(gat, "pallas") == "segment"   # softmax weights
    assert resolve_aggregate(gcn, "auto") in ("segment", "pallas")
    with pytest.raises(ValueError):
        resolve_aggregate(gcn, "nope")


# --------------------------------------------------- move-delta threading
def test_glad_s_reports_move_delta(cm_small):
    init = np.random.default_rng(0).integers(
        0, cm_small.net.m, size=cm_small.graph.n)
    res = glad_s(cm_small, R=2, init=init, seed=0, sweep="batched")
    np.testing.assert_array_equal(
        np.sort(res.moved), np.flatnonzero(res.assign != init))


def test_fault_events_carry_move_delta(small_yelp):
    from repro.runtime.fault import ElasticCoordinator
    g = small_yelp
    net = build_edge_network(g, 4, seed=0)
    gnn = workload_for("gcn", 10)
    assign = np.random.default_rng(0).integers(0, 4, size=g.n)
    part = partition_from_assign(g, assign, 4, {})
    coord = ElasticCoordinator(net, g, gnn, part)
    new_part = coord.on_failure([3], seed=0)
    ev = coord.events[-1]
    np.testing.assert_array_equal(
        np.sort(ev.moved), np.flatnonzero(new_part.assign != assign))
    np.testing.assert_array_equal(ev.moved, coord.last_moved)
    assert ev.migrated == len(ev.moved)
    # The delta drives a plan patch end-to-end.
    plan = compile_plan(g, partition_from_assign(g, assign, 4, {}), slack=0.5)
    patch_plan(plan, g, new_part.assign)
    assert plans_equal(plan, recompile_like(plan, g, new_part.assign)) == []


# ------------------------------------------------------- 8-device subprocess
_PARITY_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import numpy as np, jax, jax.numpy as jnp
    from repro.graphs import synthetic_siot
    from repro.gnn import (GNNConfig, init_params, forward, directed_edges,
                           compile_plan, make_bsp_forward, scatter_features,
                           gather_outputs, simulate_bsp_forward)
    from repro.core.partition import partition_from_assign
    from repro.jaxcompat import make_mesh

    g = synthetic_siot(n=160, target_links=420)
    assign = np.random.default_rng(0).integers(0, 8, size=g.n)
    plan = compile_plan(g, partition_from_assign(g, assign, 8, {}))
    mesh = make_mesh((8,), ('data',))
    blocks = jnp.asarray(scatter_features(plan, g.features))
    sd = jnp.asarray(directed_edges(g.edges))
    combos = [(m, ex, 'segment') for m in ('gcn', 'sage', 'gat')
              for ex in ('ppermute', 'allgather')]
    combos += [(m, 'ppermute', 'pallas') for m in ('gcn', 'sage')]
    for model, ex, agg in combos:
        cfg = GNNConfig(model, (52, 16, 2))
        params = init_params(jax.random.PRNGKey(0), cfg)
        ref = np.asarray(forward(cfg, params, jnp.asarray(g.features), sd))
        fwd = make_bsp_forward(cfg, plan, mesh, exchange=ex, aggregate=agg)
        out = gather_outputs(plan, np.asarray(fwd(params, blocks)), g.n)
        sim = simulate_bsp_forward(cfg, params, plan, g.features,
                                   aggregate=agg)
        for name, got in (('dense', ref), ('simulate', sim)):
            err = float(np.abs(got - out).max() / (np.abs(got).max() + 1e-9))
            assert err < 1e-4, (model, ex, agg, name, err)
    print('PARITY8_OK')
""")


_PATCH_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import numpy as np, jax, jax.numpy as jnp
    from repro.graphs import synthetic_siot
    from repro.gnn import (GNNConfig, init_params, compile_plan, patch_plan,
                           recompile_like, plans_equal, make_bsp_forward,
                           scatter_features, gather_outputs)
    from repro.core.partition import partition_from_assign
    from repro.jaxcompat import make_mesh

    rng = np.random.default_rng(0)
    g = synthetic_siot(n=240, target_links=700)
    assign = rng.integers(0, 8, size=g.n)
    plan = compile_plan(g, partition_from_assign(g, assign, 8, {}),
                        slack=0.5)
    mesh = make_mesh((8,), ('data',))
    cfg = GNNConfig('gcn', (52, 16, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    fwd = make_bsp_forward(cfg, plan, mesh, exchange='ppermute',
                           aggregate='pallas')
    blocks = jnp.asarray(scatter_features(plan, g.features))
    out0 = np.asarray(fwd(params, blocks))
    assert fwd.stats['traces'] == 1, fwd.stats

    # Value-only patches: zero retraces across a whole move sequence.
    cur = assign
    for step in range(4):
        movers = rng.choice(g.n, size=6, replace=False)
        new = cur.copy()
        new[movers] = rng.integers(0, 8, size=6)
        delta = patch_plan(plan, g, new)
        assert delta.patched and not delta.retrace_expected, vars(delta)
        fresh = recompile_like(plan, g, new)
        assert plans_equal(plan, fresh) == [], plans_equal(plan, fresh)
        out_p = np.asarray(fwd(params, blocks))
        assert fwd.stats['traces'] == 1, (step, fwd.stats)
        # Bit-identity: a fresh forward over the freshly-compiled plan.
        fwd_f = make_bsp_forward(cfg, fresh, mesh, exchange='ppermute',
                                 aggregate='pallas')
        out_f = np.asarray(fwd_f(params, blocks))
        assert np.array_equal(out_p, out_f), step
        cur = new

    # Capacity growth: exactly one recompile, result still exact.
    new = cur.copy()
    new[: g.n // 2] = 0
    delta = patch_plan(plan, g, new)
    assert (not delta.patched) and delta.retrace_expected, vars(delta)
    blocks2 = jnp.asarray(scatter_features(plan, g.features))
    out_g = np.asarray(fwd(params, blocks2))
    assert fwd.stats['traces'] == 2, fwd.stats
    fresh = recompile_like(plan, g, new)
    fwd_f = make_bsp_forward(cfg, fresh, mesh, exchange='ppermute',
                             aggregate='pallas')
    assert np.array_equal(out_g, np.asarray(fwd_f(params, blocks2)))
    assert np.array_equal(
        gather_outputs(plan, out_g, g.n)[plan.assign >= 0].shape,
        gather_outputs(fresh, out_g, g.n)[plan.assign >= 0].shape)
    print('PATCH8_OK')
""")


def _run_subprocess(script, token):
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert token in r.stdout, r.stdout + r.stderr


def test_multidevice_parity_suite_subprocess():
    _run_subprocess(_PARITY_SUBPROCESS, "PARITY8_OK")


def test_patched_plan_zero_retrace_subprocess():
    _run_subprocess(_PATCH_SUBPROCESS, "PATCH8_OK")
