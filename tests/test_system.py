"""End-to-end behaviour of the paper's system (Sec. VI claims, scaled to CI):

  * GLAD-S produces large cost reductions vs Random (Fig. 8/9 direction),
  * the optimized layout runs the ACTUAL distributed GNN with fewer halo
    rows (=C_T) and identical numerics,
  * dynamic pipeline: evolution -> GLAD-A keeps cost below No-Adjustment.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CostModel, GladA, data_partition, glad_s,
                        random_layout, workload_for)
from repro.core.evolution import apply_delta, evolution_trace
from repro.core.partition import partition_from_assign
from repro.gnn import (GNNConfig, compile_plan, directed_edges, forward,
                       init_params, simulate_bsp_forward)
from repro.graphs import build_edge_network, synthetic_siot, synthetic_yelp


def test_glad_cost_reduction_vs_random():
    """Direction + magnitude of Fig. 8/9: big cost cut vs Random."""
    g = synthetic_siot(n=400, target_links=1400)
    net = build_edge_network(g, 12, seed=0)
    cm = CostModel(net, g, workload_for("gat", 52))
    rand = np.mean([cm.total(random_layout(cm, seed=s)) for s in range(5)])
    res = glad_s(cm, seed=0)
    reduction = 1.0 - res.cost / rand
    assert reduction > 0.30, f"only {reduction:.1%} cost reduction"


def test_layout_cuts_halo_traffic_and_keeps_numerics():
    g = synthetic_yelp(n=200, target_links=300)
    gnn = workload_for("gcn", 100)
    cfg = GNNConfig("gcn", (100, 16, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    ref = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                             jnp.asarray(directed_edges(g.edges))))

    rng = np.random.default_rng(0)
    rand_part = partition_from_assign(
        g, rng.integers(0, 4, size=g.n), 4, {})
    glad_part = data_partition(g, gnn, num_parts=4, seed=0)
    plan_r = compile_plan(g, rand_part)
    plan_g = compile_plan(g, glad_part)
    # GLAD moves strictly fewer halo rows (the physical C_T).
    assert plan_g.halo_bytes_ppermute <= plan_r.halo_bytes_ppermute
    # Numerics identical under either layout.
    for plan in (plan_r, plan_g):
        out = simulate_bsp_forward(cfg, params, plan, g.features)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_dynamic_pipeline_beats_no_adjustment():
    g = synthetic_yelp(n=150, target_links=220)
    gnn = workload_for("gcn", 100)
    net = build_edge_network(g, 4, seed=0)
    sched = GladA(net, gnn, g, theta=5.0, seed=0)
    no_adjust_assign = sched.assign.copy()
    costs_adaptive, costs_static = [], []
    cur = g
    for t, delta in enumerate(evolution_trace(g, 5, pct_links=0.05,
                                              pct_vertices=0.02, seed=3)):
        cur = apply_delta(cur, delta)
        rec = sched.step(cur)
        costs_adaptive.append(rec.cost)
        cm = CostModel(net, cur, gnn)
        carried = np.zeros(cur.n, dtype=np.int64)
        keep = min(len(no_adjust_assign), cur.n)
        carried[:keep] = no_adjust_assign[:keep]
        costs_static.append(cm.total(carried))
    assert np.mean(costs_adaptive) <= np.mean(costs_static) + 1e-6
