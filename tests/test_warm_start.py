"""Differential fuzz harness for the warm-start incremental max-flow.

The warm solver's ONLY contract is bit-identity: for any perturbation
sequence, the warm-started source-side mask equals the cold
``min_st_cut_csr`` mask AND the pure-python Dinic oracle's mask on the same
quantized integer problem (the minimal source side of a min cut is unique,
so every correct solver must return the same bits).  The harness drives
random capacity / t-link / membership perturbation sequences through one
retained :class:`ResidualCut` and checks all three solvers on every step;
heavier sequences run behind the ``slow`` marker.

Engine-level tests pin the same property end to end: GLAD trajectories are
bit-identical under {cache on/off} x {warm on/off}, warm re-solves after
external perturbations reproduce cold costs exactly, and the warm state
obeys the cache's byte ledger.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, workload_for
from repro.core.engine import PairCutEngine, round_robin_rounds
from repro.core.glad_s import glad_s
from repro.core.maxflow import (PEEL_GATE_FRAC, Dinic, ResidualCut,
                                assemble_symmetric_flow_csr, min_st_cut_csr,
                                peel_gate_fraction, peel_warm_solve)
from repro.graphs.datagraph import DataGraph, synthetic_siot
from repro.graphs.edgenet import build_edge_network


# --------------------------------------------------------------- generators
def _random_universe(rng, k_max=16):
    """A random GLAD-shaped auxiliary 'universe': canonical undirected
    internal links (both directed arcs, row-grouped ascending) and
    nonnegative t-links — the structural contract of the engine's gather."""
    k = int(rng.integers(2, k_max))
    n_links = int(rng.integers(1, 3 * k))
    a = rng.integers(0, k, size=n_links)
    b = rng.integers(0, k, size=n_links)
    keep = a != b
    a, b = a[keep], b[keep]
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    key, inv = np.unique(lo * k + hi, return_inverse=True)
    w = np.bincount(inv, weights=rng.uniform(0.05, 4.0, size=len(a)),
                    minlength=len(key))
    lo, hi = key // k, key % k
    links = np.stack([lo, hi], axis=1)
    ti = rng.uniform(0.0, 5.0, size=k).round(4)
    tj = rng.uniform(0.0, 5.0, size=k).round(4)
    return k, links, w, ti, tj


def _restrict(k, links, w, ti, tj, member_mask):
    """Restrict the universe to ``member_mask`` (contiguous relabel) and
    emit canonical both-direction arcs — models a membership change."""
    sel = np.flatnonzero(member_mask)
    loc = np.full(k, -1, dtype=np.int64)
    loc[sel] = np.arange(len(sel))
    keep = member_mask[links[:, 0]] & member_mask[links[:, 1]]
    lo = loc[links[keep, 0]]
    hi = loc[links[keep, 1]]
    ww = w[keep]
    ia = np.concatenate([lo, hi])
    ib = np.concatenate([hi, lo])
    iw = np.concatenate([ww, ww])
    order = np.lexsort((ib, ia))
    return (len(sel), ia[order], ib[order], iw[order],
            ti[sel].copy(), tj[sel].copy())


def _cold_mask(k, ia, ib, iw, ti, tj):
    """The cold reference: direct symmetric-CSR assembly + scipy solve."""
    n, s, t, ip, co, ca = assemble_symmetric_flow_csr(
        k, ia, ib, iw, ti.copy(), tj.copy(), presorted=True)
    _, side = min_st_cut_csr(n, s, t, ip, co, ca)
    return side[:k]


def _dinic_mask(k, ia, ib, iw, ti, tj):
    """Pure-python oracle ON THE QUANTIZED PROBLEM: replicate the cold
    path's integer scaling, then solve with float-capacity Dinic (exact on
    integers) and return its residual-reachability mask — the same unique
    minimal source side every correct solver must find."""
    caps = np.concatenate([ti, tj, iw]).astype(np.float64)
    cmax = float(caps.max()) if len(caps) else 1.0
    scale = 10 ** 7 / max(cmax, 1e-30)
    q = lambda x: np.maximum(np.rint(np.asarray(x, np.float64) * scale), 0)
    qi, qj, qw = q(ti), q(tj), q(iw)
    d = Dinic(k + 2)
    S, T = k, k + 1
    for v in range(k):
        d.add_edge(S, v, float(qj[v]))
        d.add_edge(v, T, float(qi[v]))
    for a, b, ww in zip(ia, ib, qw):
        if a < b:                 # both directions arrive; add each once
            d.add_edge(int(a), int(b), float(ww), float(ww))
    d.max_flow(S, T)
    return d.min_cut_side(S)[:k]


def _perturb(rng, k, links, w, ti, tj):
    """One random perturbation: t-link tweaks, undirected-capacity tweaks,
    or both (values stay nonnegative)."""
    what = rng.integers(0, 3)
    if what != 1:
        wh = rng.integers(0, k, size=int(rng.integers(1, k + 1)))
        ti = ti.copy()
        ti[wh] = np.maximum(ti[wh] + rng.normal(0, 2.0, size=len(wh)), 0)
        wh = rng.integers(0, k, size=int(rng.integers(1, k + 1)))
        tj = tj.copy()
        tj[wh] = np.maximum(tj[wh] * rng.uniform(0, 3, size=len(wh)), 0)
    if what != 0 and len(w):
        wh = rng.integers(0, len(w), size=int(rng.integers(1, len(w) + 1)))
        w = w.copy()
        w[wh] = np.maximum(w[wh] + rng.normal(0, 1.5, size=len(wh)), 0)
    return links, w, ti, tj


def _assert_flow_invariants(rc):
    """The retained flow must stay a FEASIBLE flow after every repair:
    antisymmetric, within capacity, and conserved at every non-terminal
    node.  A drain bug (e.g. reducing a shared arc twice) breaks one of
    these long before it breaks a mask on a lucky instance."""
    n = rc.n
    rows = np.repeat(np.arange(n), np.diff(rc.indptr))
    assert (rc.flow <= rc.cap).all(), "capacity violated"
    # antisymmetry: flow[u,v] == -flow[v,u]
    key = rows * n + rc.cols.astype(np.int64)
    tkey = rc.cols.astype(np.int64) * n + rows
    rev = np.searchsorted(key, tkey)
    np.testing.assert_array_equal(rc.flow, -rc.flow[rev])
    # conservation at member nodes (net outflow zero)
    net = np.zeros(n, dtype=np.int64)
    np.add.at(net, rows, rc.flow)
    assert (net[:rc.k] == 0).all(), "conservation violated"


def _run_differential_sequence(seed, steps, k_max=16):
    """Drive one perturbation sequence; assert warm == cold == Dinic masks
    bit-for-bit on every step.  Returns the observed resolve modes."""
    rng = np.random.default_rng(seed)
    k, links, w, ti, tj = _random_universe(rng, k_max=k_max)
    member = np.ones(k, dtype=bool)
    prob = _restrict(k, links, w, ti, tj, member)
    side, rc = ResidualCut.prime(*[np.copy(x) if isinstance(x, np.ndarray)
                                   else x for x in prob])
    np.testing.assert_array_equal(side, _cold_mask(*prob))
    np.testing.assert_array_equal(side, _dinic_mask(*prob))
    modes = []
    for _ in range(steps):
        if rng.uniform() < 0.25:
            # Membership perturbation: structure changes, warm state is
            # re-primed (exactly what the engine does on membership churn).
            member = rng.uniform(size=k) < rng.uniform(0.4, 1.0)
            if member.sum() < 2:
                member[:2] = True
            prob = _restrict(k, links, w, ti, tj, member)
            side, rc = ResidualCut.prime(*prob)
            modes.append("prime")
        else:
            links, w, ti, tj = _perturb(rng, k, links, w, ti, tj)
            prob = _restrict(k, links, w, ti, tj, member)
            if rc is None or rc.k != prob[0]:   # pragma: no cover - guard
                side, rc = ResidualCut.prime(*prob)
                modes.append("prime")
            else:
                side, mode = rc.resolve(*prob[1:])
                modes.append(mode)
        if rc is not None:
            _assert_flow_invariants(rc)
        np.testing.assert_array_equal(side, _cold_mask(*prob))
        np.testing.assert_array_equal(side, _dinic_mask(*prob))
    return modes


# ------------------------------------------------------- differential fuzz
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_warm_masks_bit_identical_to_cold_and_dinic(seed):
    """Tier-1 fuzz: every step of a random capacity/t-link/membership
    perturbation sequence yields identical masks from the warm solver, the
    cold scipy path and the Dinic oracle."""
    _run_differential_sequence(seed, steps=8)


def test_warm_exercises_every_resolve_mode():
    """The harness must actually reach hit/warm/cold modes (otherwise the
    fuzz only covers the prime path and the bit-identity claim is hollow)."""
    seen = set()
    for seed in range(40):
        seen.update(_run_differential_sequence(seed, steps=6))
        if {"hit", "warm", "cold"} <= seen:
            break
    assert {"hit", "warm", "cold"} <= seen, seen


@pytest.mark.slow
@settings(max_examples=150, deadline=None)
@given(st.integers(0, 100_000))
def test_warm_masks_bit_identical_fuzz_heavy(seed):
    """Heavy on-demand tier (-m slow): longer sequences, larger blocks."""
    _run_differential_sequence(seed + 1, steps=25, k_max=28)


def test_resolve_rejects_structure_change():
    """A changed internal-arc structure must be re-primed, not resolved —
    the engine drops warm state on membership patches; a caller that
    forgets gets a loud error instead of a silently wrong mask."""
    rng = np.random.default_rng(3)
    k, links, w, ti, tj = _random_universe(rng)
    prob = _restrict(k, links, w, ti, tj, np.ones(k, dtype=bool))
    _, rc = ResidualCut.prime(*prob)
    member = np.ones(k, dtype=bool)
    member[0] = False
    smaller = _restrict(k, links, w, ti, tj, member)
    with pytest.raises(ValueError, match="structure changed"):
        rc.resolve(smaller[1], smaller[2], smaller[3], smaller[4],
                   smaller[5])


def test_drain_handles_saturating_decrease_chain():
    """Deterministic drain exercise: prime a path network s-a-b-t at full
    flow, then cut an interior capacity to a fraction — the drain must
    cancel the excess along the flow's own path and the repaired solve must
    match cold (covers the backward AND forward walks)."""
    ia = np.array([0, 1], dtype=np.int64)
    ib = np.array([1, 0], dtype=np.int64)
    for new_mid in (0.0, 0.4, 2.0):
        iw = np.array([5.0, 5.0])
        ti = np.array([0.0, 4.0])     # a->T 0, b->T 4
        tj = np.array([4.0, 0.0])     # S->a 4, S->b 0
        prob = (2, ia, ib, iw, ti, tj)
        side, rc = ResidualCut.prime(*prob)
        assert rc.flow.max() > 0      # the prime actually pushed flow
        iw2 = np.array([new_mid, new_mid])
        side2, mode = rc.resolve(ia, ib, iw2, ti, tj)
        np.testing.assert_array_equal(
            side2, _cold_mask(2, ia, ib, iw2, ti, tj))
        np.testing.assert_array_equal(
            side2, _dinic_mask(2, ia, ib, iw2, ti, tj))


# ------------------------------------------------ peel <-> warm interaction
def test_peel_gate_shared_between_block_solver_and_warm_router():
    """The warm router and the block solver must agree on the peel-vs-direct
    decision: peel_gate_fraction is the single source of truth."""
    rng = np.random.default_rng(11)
    k, links, w, ti, tj = _random_universe(rng)
    prob = _restrict(k, links, w, ti, tj, np.ones(k, dtype=bool))
    frac = peel_gate_fraction(prob[0], prob[1], prob[3], prob[4], prob[5])
    assert 0.0 <= frac <= 1.0
    assert 0.0 < PEEL_GATE_FRAC < 1.0


def test_peel_warm_solve_differential_vs_cold_and_dinic():
    """:func:`peel_warm_solve` (quantize + persistency peel + keyed warm
    survivor solve) returns the SAME mask as the cold solver and the Dinic
    oracle on every step of random perturbation sequences — and a re-solve
    of an unchanged problem must come back as a pure warm HIT through the
    retained keyed residual."""
    hit_seen = False
    for seed in range(30):
        rng = np.random.default_rng(1000 + seed)
        k, links, w, ti, tj = _random_universe(rng)
        member = np.ones(k, dtype=bool)
        rc = key = None
        for _ in range(5):
            prob = _restrict(k, links, w, ti, tj, member)
            old_rc = rc
            side, rc, key, _mode = peel_warm_solve(
                *prob, residual=rc, residual_key=key)
            np.testing.assert_array_equal(side, _cold_mask(*prob))
            np.testing.assert_array_equal(side, _dinic_mask(*prob))
            # The returned state describes THIS problem only if it was
            # primed/matched here (a fully-peeled or overflown solve passes
            # stale state through untouched for a later key match).
            fresh = (rc is not None and key is not None
                     and (rc is not old_rc or _mode in ("hit", "warm")))
            if fresh:
                # Same problem, same forced set: the keyed residual must
                # resolve as a hit and return identical bits.
                side2, rc, key, mode2 = peel_warm_solve(
                    *prob, residual=rc, residual_key=key)
                np.testing.assert_array_equal(side2, side)
                assert mode2 == "hit"
                hit_seen = True
            if rng.uniform() < 0.25:
                member = rng.uniform(size=k) < rng.uniform(0.4, 1.0)
                if member.sum() < 2:
                    member[:2] = True
                rc = key = None        # membership changed: engine re-keys
            links, w, ti, tj = _perturb(rng, k, links, w, ti, tj)
    assert hit_seen


def test_warm_state_dropped_when_peel_frontier_engages():
    """Re-solve after the forced set grows past the gate: the engine routes
    to the peeled path, and any FULL-CORE residual is dropped (its caps no
    longer describe the problem being solved).  The peeled solve then primes
    a residual KEYED by the forced set, so the peel regime itself warms on
    re-probe; masks stay exact throughout.

    Built on a tiny engine so the full epoch/cache plumbing is exercised,
    not just the maxflow layer."""
    g = synthetic_siot(n=160, target_links=600, seed=2)
    net = build_edge_network(g, 4, seed=2)
    cm = CostModel(net, g, workload_for("gcn", 24))
    rng = np.random.default_rng(0)
    init = rng.integers(0, 4, size=g.n).astype(np.int64)
    eng = PairCutEngine(cm, init, cache=True, warm=True)
    cold_eng = PairCutEngine(cm, init.copy(), cache=False, warm=False)
    connected = {(int(i), int(j)) for i, j in net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(4)]
    rounds = [r for r in rounds if r]
    for _ in range(6):
        for rnd in rounds:
            # The pairwise route sends EVERY dirty solve through the warm
            # router (the block route keeps fresh assemblies cold), so the
            # peel gate's drop-state path is guaranteed to be exercised.
            got = eng.sweep_round(rnd, solver="pairwise")
            ref = cold_eng.sweep_round(rnd, solver="pairwise")
            assert got == ref
    np.testing.assert_array_equal(eng.state.assign, cold_eng.state.assign)
    assert eng.state.total == cold_eng.state.total
    st_ = eng.cache_stats()
    # Early churny rounds must have hit the cold/peel fallback at least
    # once — that is the 'frontier engages -> state dropped' path.
    assert st_["warm_cold"] > 0
    # And every cached entry that still holds warm state is consistent:
    # a full-core residual spans the core; a peel-keyed one spans exactly
    # the survivors of the forced set it is keyed by.
    for e in eng._cache.values():
        if e.residual is not None:
            if e.residual_key is None:
                assert e.residual.k == len(e.core)
            else:
                assert len(e.residual_key) == len(e.core)
                assert e.residual.k == int(e.residual_key.sum())


def test_peel_keyed_residuals_warm_hit_on_converged_reprobe():
    """The converged-but-peel-gated regime must WARM-HIT, not re-solve
    cold: residuals primed on the peeled survivor problem are keyed by the
    forced set, so a re-probe with an unchanged forced set resolves the
    retained residual.  (Pre-PR the peel branch dropped warm state every
    time it engaged — exactly where the peel wins.)

    The workload is built to make the gate fire WITH survivors: a heavy
    ring core whose internal arcs outweigh any t-link gap (the cascade
    cannot force it) plus a light periphery whose unary pull dwarfs its
    incident caps (forced immediately — frac above the gate)."""
    rng = np.random.default_rng(7)
    n_core, n = 24, 160
    edges = []
    for i in range(n_core):
        edges.append((i, (i + 1) % n_core))
        edges.append((i, (i + 5) % n_core))
    for v in range(n_core, n):
        a, b = rng.integers(0, n_core, 2)
        edges.append((v, int(a)))
        edges.append((v, int(b)))
    edges = np.array(sorted({(min(a, b), max(a, b))
                             for a, b in edges if a != b}), dtype=np.int64)
    wts = np.where((edges[:, 0] < n_core) & (edges[:, 1] < n_core),
                   50.0, 0.02)
    g = DataGraph(n, edges, coords=rng.random((n, 2)), edge_weights=wts)
    net = build_edge_network(g, 4, seed=0)
    cm = CostModel(net, g, workload_for("gcn", 24))
    init = rng.integers(0, 4, size=n).astype(np.int64)
    eng = PairCutEngine(cm, init, cache=True, warm=True)
    cold_eng = PairCutEngine(cm, init.copy(), cache=False, warm=False)
    connected = {(int(i), int(j)) for i, j in net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(4)]
    rounds = [r for r in rounds if r]
    while True:
        acc = 0
        for rnd in rounds:
            got = eng.sweep_round(rnd, solver="pairwise")
            assert got == cold_eng.sweep_round(rnd, solver="pairwise")
            acc += sum(1 for _, ok in got if ok)
        if acc == 0:
            break
    # Prime pass: one re-probe so peel-gated pairs prime keyed residuals.
    eng._version += 1
    eng._server_dirty[:] = eng._version
    for rnd in rounds:
        eng.sweep_round(rnd, solver="pairwise")
    keyed = [e for e in eng._cache.values()
             if e.residual is not None and e.residual_key is not None]
    assert keyed, "workload never engaged the peel gate at convergence"
    before = dict(eng.cache_stats())
    total_before = eng.state.total
    eng._version += 1
    eng._server_dirty[:] = eng._version       # dirty, epochs untouched
    for rnd in rounds:
        for _, ok in eng.sweep_round(rnd, solver="pairwise"):
            assert not ok                     # converged: all rejects
    after = eng.cache_stats()
    assert eng.state.total == total_before
    # Every keyed residual resolves as a pure warm hit on the re-probe.
    assert after["warm_hits"] >= before["warm_hits"] + len(keyed)
    np.testing.assert_array_equal(eng.state.assign, cold_eng.state.assign)


# ----------------------------------------------------- engine-level identity
def _tiny_cm(seed=0, n=300, m=6):
    g = synthetic_siot(n=n, target_links=int(n * 3.5), seed=seed)
    net = build_edge_network(g, m, seed=seed)
    return CostModel(net, g, workload_for("gcn", 32))


@pytest.mark.parametrize("cache,warm", [(False, False), (True, False),
                                        (False, "auto"), (True, True)])
def test_glad_s_trajectory_identical_across_regimes(cache, warm):
    """Full batched GLAD-S runs are bit-identical under every cache x warm
    regime (the golden-trajectory guarantee extended to warm starts)."""
    cm = _tiny_cm()
    ref = glad_s(cm, seed=0, sweep="batched", cache=False, warm=False)
    got = glad_s(cm, seed=0, sweep="batched", cache=cache, warm=warm)
    assert got.history == ref.history
    np.testing.assert_array_equal(got.assign, ref.assign)
    assert got.cost == ref.cost


def test_warm_true_with_cache_false_raises():
    cm = _tiny_cm()
    with pytest.raises(ValueError, match="warm=True requires"):
        PairCutEngine(cm, np.zeros(cm.graph.n, dtype=np.int64),
                      cache=False, warm=True)


def test_external_commit_keeps_warm_engine_exact():
    """apply_assignment (the on_commit epoch plumbing) + warm re-solve:
    after externally-imposed moves, the warm engine's re-converged layout
    must exactly match a cold engine fed the same sequence — stale epochs
    would silently diverge here."""
    cm = _tiny_cm(seed=1)
    n, m = cm.graph.n, cm.net.m
    rng = np.random.default_rng(5)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in cm.net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(m)]
    rounds = [r for r in rounds if r]

    def converge(eng):
        while True:
            acc = sum(1 for rnd in rounds
                      for _, ok in eng.sweep_round(rnd) if ok)
            if acc == 0:
                return

    warm_eng = PairCutEngine(cm, init, cache=True, warm=True)
    cold_eng = PairCutEngine(cm, init.copy(), cache=False, warm=False)
    converge(warm_eng)
    converge(cold_eng)
    for step in range(6):
        prng = np.random.default_rng(100 + step)
        mv = prng.choice(n, size=3, replace=False)
        ns = (warm_eng.state.assign[mv]
              + prng.integers(1, m, size=3)) % m
        d1 = warm_eng.apply_assignment(mv, ns)
        d2 = cold_eng.apply_assignment(mv, ns)
        assert d1 == d2
        converge(warm_eng)
        converge(cold_eng)
        np.testing.assert_array_equal(warm_eng.state.assign,
                                      cold_eng.state.assign)
        assert warm_eng.state.total == cold_eng.state.total
    # The exercise must actually have used the warm machinery.
    st_ = warm_eng.cache_stats()
    assert st_["warm_hits"] + st_["warm_repairs"] + st_["warm_cold"] > 0


def test_converged_reprobe_is_all_warm_hits():
    """Force a full re-probe of a converged engine without touching any
    vertex: every solved pair must come back as a warm hit (mask-only BFS)
    and propose no move — the converged-regime fast path."""
    cm = _tiny_cm(seed=2)
    n, m = cm.graph.n, cm.net.m
    rng = np.random.default_rng(0)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in cm.net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(m)]
    rounds = [r for r in rounds if r]
    eng = PairCutEngine(cm, init, cache=True, warm=True)
    while True:
        if sum(1 for rnd in rounds
               for _, ok in eng.sweep_round(rnd) if ok) == 0:
            break
    before = dict(eng.cache_stats())
    total_before = eng.state.total
    eng._version += 1
    eng._server_dirty[:] = eng._version       # dirty, epochs untouched
    for rnd in rounds:
        for _, ok in eng.sweep_round(rnd):
            assert not ok                     # converged: all rejects
    after = eng.cache_stats()
    assert eng.state.total == total_before
    assert after["warm_hits"] > before["warm_hits"]
    assert after["warm_repairs"] == before["warm_repairs"]
    assert after["misses"] >= before["misses"]   # empty pairs only


def test_residual_bytes_counted_in_lru_budget():
    """Warm state must be charged to the cache's byte ledger: the ledger
    equals the sum of entry nbytes (which include residuals), and dropping
    residuals refunds exactly their bytes."""
    cm = _tiny_cm(seed=3)
    n, m = cm.graph.n, cm.net.m
    rng = np.random.default_rng(1)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in cm.net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(m)]
    rounds = [r for r in rounds if r]
    eng = PairCutEngine(cm, init, cache=True, warm=True)
    for _ in range(4):
        for rnd in rounds:
            eng.sweep_round(rnd)
    real = sum(e.nbytes for e in eng._cache.values())
    assert eng._cache_used == real
    with_rc = [(key, e) for key, e in eng._cache.items()
               if e.residual is not None]
    if with_rc:                                # drop one, ledger follows
        key, e = with_rc[0]
        rc_bytes = e.residual.nbytes
        used = eng._cache_used
        eng._drop_residual(e, key)
        assert eng._cache_used == used - rc_bytes
        assert eng._cache_used == sum(x.nbytes
                                      for x in eng._cache.values())


def test_prime_growth_respects_byte_budget():
    """Priming residuals on verbatim hits (a converged re-probe) grows the
    ledger WITHOUT an assembly miss — the eviction loop must still run, or
    the budget silently overruns in exactly the warm start's target
    regime."""
    cm = _tiny_cm(seed=6)
    n, m = cm.graph.n, cm.net.m
    rng = np.random.default_rng(3)
    init = rng.integers(0, m, size=n).astype(np.int64)
    connected = {(int(i), int(j)) for i, j in cm.net.pairs}
    rounds = [[p for p in rnd if p in connected]
              for rnd in round_robin_rounds(m)]
    rounds = [r for r in rounds if r]
    budget = 96 << 10
    eng = PairCutEngine(cm, init, cache=True, warm=True,
                        cache_bytes=budget)
    for _ in range(3):
        for rnd in rounds:
            eng.sweep_round(rnd)
        eng._version += 1
        eng._server_dirty[:] = eng._version      # re-probe: prime on hits
    assert eng._cache_used == sum(e.nbytes for e in eng._cache.values())
    assert eng._cache_used <= budget or len(eng._cache) == 1


def test_warm_respects_tight_byte_budget():
    """A budget too small for everything still produces exact results —
    evicted warm state only costs a re-prime."""
    cm = _tiny_cm(seed=4)
    n, m = cm.graph.n, cm.net.m
    rng = np.random.default_rng(2)
    init = rng.integers(0, m, size=n).astype(np.int64)
    ref = glad_s(cm, seed=0, init=init.copy(), sweep="batched",
                 cache=False, warm=False)
    got = glad_s(cm, seed=0, init=init.copy(), sweep="batched",
                 cache=True, warm=True, cache_bytes=64 << 10)
    assert got.history == ref.history
    np.testing.assert_array_equal(got.assign, ref.assign)
