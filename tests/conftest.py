"""Shared fixtures + marker config.  NOTE: no XLA_FLAGS here — smoke tests
must see the real (single) device; only dryrun sets the 512-device flag, and
the multi-device integration tests spawn subprocesses.

Markers: ``slow`` (long property/fuzz runs) and ``bench`` (wall-clock
comparisons).  Tier-1 runs with an implicit ``-m "not slow"``-style default:
when no ``-m`` expression is given, slow/bench tests are deselected so the
default suite stays fast; run them on demand with e.g. ``-m slow``,
``-m bench`` or ``-m "slow or not slow"`` (everything)."""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running property/fuzz tests "
        "(deselected unless an -m expression is given)")
    config.addinivalue_line(
        "markers", "bench: wall-clock benchmark tests "
        "(deselected unless an -m expression is given)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("-m"):
        return                     # an explicit -m expression takes over
    # A test named by node id on the command line was asked for explicitly —
    # run it even without -m (pytest convention: selection beats markers).
    explicit = [a.split("::", 1)[1].split("[", 1)[0]
                for a in config.args if "::" in a]
    skip = pytest.mark.skip(
        reason="slow/bench: deselected by default, pass -m to opt in")
    for item in items:
        if "slow" not in item.keywords and "bench" not in item.keywords:
            continue
        name = item.nodeid.split("::", 1)[-1].split("[", 1)[0]
        if name in explicit:
            continue
        item.add_marker(skip)

try:  # pragma: no cover - exercised only where hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    from tests._hypothesis_fallback import install as _install_hypothesis

    _install_hypothesis()

from repro.core.cost import CostModel, workload_for
from repro.graphs.datagraph import DataGraph, synthetic_siot, synthetic_yelp
from repro.graphs.edgenet import build_edge_network


@pytest.fixture(scope="session")
def small_yelp():
    return synthetic_yelp(n=120, target_links=160)


@pytest.fixture(scope="session")
def small_siot():
    return synthetic_siot(n=150, target_links=450)


@pytest.fixture()
def cm_small(small_yelp):
    net = build_edge_network(small_yelp, 4, seed=0)
    return CostModel(net, small_yelp, workload_for("gcn", 100))


def random_graph(rng, n, extra_edges):
    """Connected-ish random graph for property tests."""
    edges = []
    for v in range(1, n):
        edges.append((rng.integers(0, v), v))
    for _ in range(extra_edges):
        u, v = rng.integers(0, n, size=2)
        if u != v:
            edges.append((min(u, v), max(u, v)))
    g = DataGraph(n=n, edges=np.array(edges))
    g.coords = rng.uniform(0, 10, size=(n, 2)).astype(np.float32)
    g.features = rng.normal(size=(n, 8)).astype(np.float32)
    g.labels = rng.integers(0, 2, size=n)
    return g
