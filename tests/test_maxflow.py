"""Max-flow/min-cut: scipy backend vs pure-python Dinic oracle, the
symmetric-CSR fast path, the block-diagonal round solver, and CutArena."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.maxflow import (_SCALE, CutArena, Dinic,
                                assemble_symmetric_flow_csr,
                                concat_flow_blocks, min_st_cut,
                                min_st_cut_csr, min_st_cut_csr_blocks,
                                min_st_cut_csr_many, min_st_cut_many,
                                peel_forced)


def _random_network(rng, n, m):
    us = rng.integers(0, n, size=m)
    vs = rng.integers(0, n, size=m)
    keep = us != vs
    us, vs = us[keep], vs[keep]
    caps = rng.uniform(0.1, 5.0, size=len(us)).round(3)
    return us, vs, caps


def test_known_cut_value():
    # s -> a (3), s -> b (2), a -> t (2), b -> t (3), a -> b (1): max flow 5.
    us = np.array([0, 0, 1, 2, 1])
    vs = np.array([1, 2, 3, 3, 2])
    caps = np.array([3.0, 2.0, 2.0, 3.0, 1.0])
    zero = np.zeros(5)
    for backend in ("scipy", "dinic"):
        val, side = min_st_cut(4, 0, 3, us, vs, caps, zero, backend=backend)
        assert val == pytest.approx(5.0, abs=1e-6)
        assert side[0] and not side[3]


def test_disconnected_zero_flow():
    val, side = min_st_cut(4, 0, 3, np.array([0]), np.array([1]),
                           np.array([1.0]), np.array([0.0]), backend="dinic")
    assert val == 0.0
    assert side[0] and not side[3]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_backends_agree_on_cut_value(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 12))
    m = int(rng.integers(n, 4 * n))
    us, vs, caps = _random_network(rng, n, m)
    if len(us) == 0:
        return
    zero = np.zeros(len(us))
    v1, s1 = min_st_cut(n, 0, n - 1, us, vs, caps, zero, backend="scipy")
    v2, s2 = min_st_cut(n, 0, n - 1, us, vs, caps, zero, backend="dinic")
    assert v1 == pytest.approx(v2, rel=1e-5, abs=1e-5)
    # Both sides must be valid s-t separations.
    for s in (s1, s2):
        assert s[0] and not s[n - 1]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_cut_value_equals_crossing_capacity(seed):
    """Min-cut duality: flow value == capacity crossing the returned cut."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 10))
    us, vs, caps = _random_network(rng, n, 3 * n)
    if len(us) == 0:
        return
    zero = np.zeros(len(us))
    val, side = min_st_cut(n, 0, n - 1, us, vs, caps, zero, backend="dinic")
    crossing = sum(c for u, v, c in zip(us, vs, caps)
                   if side[u] and not side[v])
    assert val == pytest.approx(crossing, rel=1e-6, abs=1e-6)


# ------------------------------------------- symmetric-CSR path vs Dinic
def _random_aux_block(rng, k_max=12):
    """Random GLAD-shaped auxiliary block: k member nodes, canonical
    (deduplicated) undirected internal links emitted as both directed arcs,
    nonnegative t-link caps — the structural contract of the engine's
    CSR member gather."""
    k = int(rng.integers(1, k_max))
    n_links = int(rng.integers(0, 3 * k))
    a = rng.integers(0, k, size=n_links)
    b = rng.integers(0, k, size=n_links)
    keep = a != b
    a, b = a[keep], b[keep]
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    key, inv = np.unique(lo * k + hi, return_inverse=True)
    w = np.bincount(inv, weights=rng.uniform(0.05, 4.0, size=len(a)),
                    minlength=len(key))             # merge parallel links
    lo, hi = key // k, key % k
    int_a = np.concatenate([lo, hi])
    int_b = np.concatenate([hi, lo])
    int_w = np.concatenate([w, w])
    theta_i = rng.uniform(0.0, 5.0, size=k).round(4)
    theta_j = rng.uniform(0.0, 5.0, size=k).round(4)
    return k, int_a, int_b, int_w, theta_i, theta_j


def _dinic_block_value(k, int_a, int_b, int_w, theta_i, theta_j):
    """Pure-python oracle for one auxiliary block; returns (value, side)."""
    d = Dinic(k + 2)
    S, T = k, k + 1
    for v in range(k):
        d.add_edge(S, v, float(theta_j[v]))
        d.add_edge(v, T, float(theta_i[v]))
    for a, b, w in zip(int_a, int_b, int_w):
        if a < b:              # arcs come in both directions; add each once
            d.add_edge(int(a), int(b), float(w), float(w))
    val = d.max_flow(S, T)
    return val, d.min_cut_side(S)


def _crossing_capacity(side, k, int_a, int_b, int_w, theta_i, theta_j):
    """Capacity of the s-t cut induced by a member-side mask."""
    cross = float(theta_j[~side[:k]].sum()) + float(theta_i[side[:k]].sum())
    cut_arcs = side[int_a] & ~side[int_b]
    return cross + float(np.asarray(int_w)[cut_arcs].sum())


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_min_st_cut_csr_matches_dinic_oracle(seed):
    """The symmetric-CSR scipy fast path (direct assembly, int scaling,
    array-difference residual) finds a minimum cut: its induced crossing
    capacity equals the pure-python Dinic optimum."""
    rng = np.random.default_rng(seed)
    k, int_a, int_b, int_w, theta_i, theta_j = _random_aux_block(rng)
    n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
        k, int_a, int_b, int_w, theta_i, theta_j)
    caps_orig = caps.copy()          # the solver clobbers caps
    val, side = min_st_cut_csr(n, s, t, indptr, cols, caps)
    ref_val, _ = _dinic_block_value(k, int_a, int_b, int_w, theta_i, theta_j)
    assert side[s] and not side[t]
    assert val == pytest.approx(ref_val, rel=1e-5, abs=1e-5)
    # The returned partition must itself be an optimal cut.
    crossing = _crossing_capacity(side, k, int_a, int_b, int_w,
                                  theta_i, theta_j)
    assert crossing == pytest.approx(ref_val, rel=1e-5, abs=1e-5)
    # Sanity: assembly left capacities untouched until the solve.
    assert (caps_orig >= 0).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_block_diagonal_cuts_match_dinic_oracle(seed):
    """One shared-source/sink flow pass over a block-diagonal union solves
    every block to its own Dinic optimum (tentpole correctness)."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 6))
    blocks = [_random_aux_block(rng) for _ in range(B)]
    block_ptr, int_a, int_b, int_w, th_i, th_j = concat_flow_blocks(blocks)
    side = min_st_cut_csr_blocks(block_ptr, int_a, int_b, int_w, th_i, th_j,
                                 backend="scipy")
    assert side.shape == (int(block_ptr[-1]),)
    for b, (k, ia, ib, iw, ti, tj) in enumerate(blocks):
        lo, hi = int(block_ptr[b]), int(block_ptr[b + 1])
        ref_val, _ = _dinic_block_value(k, ia, ib, iw, ti, tj)
        blk_side = np.concatenate([side[lo:hi], [True, False]])
        crossing = _crossing_capacity(blk_side, k, ia, ib, iw, ti, tj)
        assert crossing == pytest.approx(ref_val, rel=1e-5, abs=1e-5), b


def test_block_solver_keeps_resolution_across_magnitudes():
    """Regression: blocks are scaled to their own capacity maximum before
    the shared integer quantization, so a block 1e9x cheaper than the
    round's largest block still gets its exact min cut (previously its
    capacities quantized to noise under the single global scale)."""
    rng = np.random.default_rng(42)
    for _ in range(10):
        blocks = []
        for scale in (1e9, 1.0, 1e-6):
            k, ia, ib, iw, ti, tj = _random_aux_block(rng)
            blocks.append((k, ia, ib, iw * scale, ti * scale, tj * scale))
        block_ptr, ia, ib, iw, ti, tj = concat_flow_blocks(blocks)
        side = min_st_cut_csr_blocks(block_ptr, ia, ib, iw, ti, tj,
                                     backend="scipy")
        for b, (k, ba, bb, bw, bi, bj) in enumerate(blocks):
            lo, hi = int(block_ptr[b]), int(block_ptr[b + 1])
            ref_val, _ = _dinic_block_value(k, ba, bb, bw, bi, bj)
            blk = np.concatenate([side[lo:hi], [True, False]])
            crossing = _crossing_capacity(blk, k, ba, bb, bw, bi, bj)
            assert crossing == pytest.approx(ref_val, rel=1e-5), b


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_block_solver_backends_agree(seed):
    """scipy single-pass vs per-block Dinic (serial and worker-pool) induce
    cuts of equal capacity on every block."""
    rng = np.random.default_rng(seed)
    blocks = [_random_aux_block(rng) for _ in range(int(rng.integers(1, 5)))]
    block_ptr, int_a, int_b, int_w, th_i, th_j = concat_flow_blocks(blocks)
    args = (block_ptr, int_a, int_b, int_w, th_i, th_j)
    s_scipy = min_st_cut_csr_blocks(*args, backend="scipy")
    s_dinic = min_st_cut_csr_blocks(*args, backend="dinic")
    s_pool = min_st_cut_csr_blocks(*args, backend="dinic", workers=2)
    np.testing.assert_array_equal(s_dinic, s_pool)
    for b, (k, ia, ib, iw, ti, tj) in enumerate(blocks):
        lo, hi = int(block_ptr[b]), int(block_ptr[b + 1])
        for s in (s_scipy, s_dinic):
            blk = np.concatenate([s[lo:hi], [True, False]])
            c = _crossing_capacity(blk, k, ia, ib, iw, ti, tj)
            ref_val, _ = _dinic_block_value(k, ia, ib, iw, ti, tj)
            assert c == pytest.approx(ref_val, rel=1e-5, abs=1e-5), b


@pytest.mark.slow
@settings(max_examples=200, deadline=None)
@given(st.integers(0, 100_000))
def test_block_diagonal_cuts_match_dinic_oracle_fuzz(seed):
    """Heavier on-demand fuzz of the block-diagonal solver (-m slow)."""
    rng = np.random.default_rng(seed + 1)
    B = int(rng.integers(1, 10))
    blocks = [_random_aux_block(rng, k_max=25) for _ in range(B)]
    block_ptr, int_a, int_b, int_w, th_i, th_j = concat_flow_blocks(blocks)
    side = min_st_cut_csr_blocks(block_ptr, int_a, int_b, int_w, th_i, th_j,
                                 backend="scipy")
    for b, (k, ia, ib, iw, ti, tj) in enumerate(blocks):
        lo, hi = int(block_ptr[b]), int(block_ptr[b + 1])
        ref_val, _ = _dinic_block_value(k, ia, ib, iw, ti, tj)
        blk_side = np.concatenate([side[lo:hi], [True, False]])
        crossing = _crossing_capacity(blk_side, k, ia, ib, iw, ti, tj)
        assert crossing == pytest.approx(ref_val, rel=1e-5, abs=1e-4), b


# ------------------------------------------------- persistency peel + chunks
def _sorted_arcs(int_a, int_b, int_w):
    order = np.lexsort((int_b, int_a))
    return int_a[order], int_b[order], np.asarray(int_w)[order]


def test_peel_forced_settles_known_cascade():
    """Chain a - b - c with huge t-link gaps at the ends: the peel must fix
    a to the source, c to the sink, absorb both arcs into b, and settle b
    too — no flow solve left."""
    int_a = np.array([0, 1, 1, 2])
    int_b = np.array([1, 0, 2, 1])
    int_w = np.array([10, 10, 10, 10], dtype=np.int64)
    th_i = np.array([0, 30, 100], dtype=np.int64)    # cap(v->t)
    th_j = np.array([100, 0, 0], dtype=np.int64)     # cap(s->v)
    alive, src = peel_forced(3, int_a, int_b, int_w.astype(np.float64),
                             th_i, th_j)
    assert not alive.any()
    # a: th_j - th_i = 100 > capsum 10 -> source; c: gap -100 -> sink;
    # b inherits a's arc into th_j (0+10) and c's into th_i (30+10):
    # gap 10 - 40 = -30 > remaining capsum 0 -> sink.
    np.testing.assert_array_equal(src, [True, False, False])


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_peeled_blocks_mask_identical_to_unpeeled(seed):
    """The peel path (quantize -> force -> compact -> prescaled solve) must
    return the exact minimal-source-side mask of the unpeeled quantized
    solve — bit for bit, not just cost-equal.  Thetas are inflated so the
    adaptive gate engages on one copy and not the other."""
    rng = np.random.default_rng(seed)
    blocks = [_random_aux_block(rng) for _ in range(int(rng.integers(1, 4)))]
    block_ptr, ia, ib, iw, ti, tj = concat_flow_blocks(blocks)
    ia, ib, iw = _sorted_arcs(ia, ib, iw)
    boost = rng.uniform(5.0, 50.0, size=len(ti))     # most nodes forceable
    ti2, tj2 = ti * boost, tj * boost
    peeled = min_st_cut_csr_blocks(block_ptr, ia, ib, iw, ti2, tj2,
                                   backend="scipy", presorted=True)
    # Reference: the pre-peel float path on the same (normalized) caps.
    nb = len(block_ptr) - 1
    t_i, t_j, w = ti2.copy(), tj2.copy(), iw.copy()
    if nb > 1:
        node_blk = np.repeat(np.arange(nb), np.diff(block_ptr))
        bmax = np.zeros(nb)
        np.maximum.at(bmax, node_blk, t_i)
        np.maximum.at(bmax, node_blk, t_j)
        if len(ia):
            np.maximum.at(bmax, node_blk[ia], w)
        inv = 1.0 / np.maximum(bmax, 1e-30)
        t_i, t_j = t_i * inv[node_blk], t_j * inv[node_blk]
        if len(ia):
            w = w * inv[node_blk[ia]]
    nc = int(block_ptr[-1])
    n, s, t, indptr, cols, caps = assemble_symmetric_flow_csr(
        nc, ia, ib, w, t_i, t_j, presorted=True)
    _, ref = min_st_cut_csr(n, s, t, indptr, cols, caps)
    np.testing.assert_array_equal(peeled, ref[:nc])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_chunked_block_solve_mask_identical(seed):
    """Chunking the glued union (any chunk size, with or without a worker
    pool) must not change a single mask bit: per-block quantization is
    composition-invariant."""
    rng = np.random.default_rng(seed)
    blocks = [_random_aux_block(rng) for _ in range(int(rng.integers(2, 7)))]
    block_ptr, ia, ib, iw, ti, tj = concat_flow_blocks(blocks)
    ia, ib, iw = _sorted_arcs(ia, ib, iw)
    args = (block_ptr, ia, ib, iw, ti, tj)
    whole = min_st_cut_csr_blocks(*args, backend="scipy", presorted=True)
    for chunk in (1, 5, 16):
        chunked = min_st_cut_csr_blocks(
            *args, backend="scipy", presorted=True, chunk_nodes=chunk)
        np.testing.assert_array_equal(whole, chunked, err_msg=str(chunk))
    pooled = min_st_cut_csr_blocks(*args, backend="scipy", presorted=True,
                                   chunk_nodes=5, workers=2)
    np.testing.assert_array_equal(whole, pooled)


def test_peel_zero_capacity_internal_arcs_mask_identical():
    """Zero-capacity internal arcs (quantization can round small weights to
    0, and evolution can zero a link) must not confuse the peel: a zero arc
    adds nothing to capsum, forces across it like any other, and the
    composed mask stays bit-identical to the unpeeled solve."""
    rng = np.random.default_rng(17)
    for trial in range(10):
        k, ia, ib, iw, ti, tj = _random_aux_block(rng)
        ia, ib, iw = _sorted_arcs(ia, ib, iw)
        if len(iw):
            # zero a subset of undirected links (both directed copies
            # share the same (lo, hi) weight by construction)
            lo = np.minimum(ia, ib)
            hi = np.maximum(ia, ib)
            keys = lo * k + hi
            kill = rng.uniform(size=len(iw)) < 0.4
            iw = np.where(np.isin(keys, keys[kill]), 0.0, iw)
        boost = rng.uniform(5.0, 50.0, size=k)       # engage the peel gate
        ti2, tj2 = ti * boost, tj * boost
        bp = np.array([0, k], dtype=np.int64)
        peeled = min_st_cut_csr_blocks(bp, ia, ib, iw, ti2, tj2,
                                       backend="scipy", presorted=True)
        n, s, t, ip, co, ca = assemble_symmetric_flow_csr(
            k, ia, ib, iw, ti2.copy(), tj2.copy(), presorted=True)
        _, ref = min_st_cut_csr(n, s, t, ip, co, ca)
        np.testing.assert_array_equal(peeled, ref[:k], err_msg=str(trial))


def test_peel_fully_forced_core_skips_scipy_entirely():
    """A cascade that settles EVERY node leaves an empty scipy problem; the
    block solver must return the forced mask directly and that mask must
    match the unpeeled reference (the 'empty flow problem' edge case)."""
    int_a = np.array([0, 1, 1, 2])
    int_b = np.array([1, 0, 2, 1])
    int_w = np.array([10.0, 10.0, 10.0, 10.0])
    th_i = np.array([0.0, 30.0, 100.0])
    th_j = np.array([100.0, 0.0, 0.0])
    alive, src = peel_forced(3, int_a, int_b, int_w.copy(),
                             th_i.astype(np.int64).copy(),
                             th_j.astype(np.int64).copy())
    assert not alive.any()                      # peel settled every node
    bp = np.array([0, 3], dtype=np.int64)
    side = min_st_cut_csr_blocks(bp, int_a, int_b, int_w, th_i, th_j,
                                 backend="scipy", presorted=True)
    n, s, t, ip, co, ca = assemble_symmetric_flow_csr(
        3, int_a, int_b, int_w, th_i.copy(), th_j.copy(), presorted=True)
    _, ref = min_st_cut_csr(n, s, t, ip, co, ca)
    np.testing.assert_array_equal(side, ref[:3])
    np.testing.assert_array_equal(side, [True, False, False])


def test_chunked_block_solve_process_pool_mask_identical():
    """The chunked fan-out's PROCESS pool (chunk-problem tuples pickled to
    workers) must reproduce the serial masks bit-for-bit — the dedicated
    process-path coverage the thread-only test left open."""
    rng = np.random.default_rng(23)
    blocks = [_random_aux_block(rng) for _ in range(8)]
    bp, ia, ib, iw, ti, tj = concat_flow_blocks(blocks)
    ia, ib, iw = _sorted_arcs(ia, ib, iw)
    boost = rng.uniform(5.0, 50.0, size=len(ti))    # engage peel + chunks
    ti, tj = ti * boost, tj * boost
    args = (bp, ia, ib, iw, ti, tj)
    serial = min_st_cut_csr_blocks(*args, backend="scipy", presorted=True,
                                   chunk_nodes=10)
    pooled = min_st_cut_csr_blocks(*args, backend="scipy", presorted=True,
                                   chunk_nodes=10, workers=2,
                                   worker_mode="process")
    np.testing.assert_array_equal(serial, pooled)


def test_min_st_cut_csr_many_rejects_aliased_problems():
    """Batching arena-backed assembly views is a silent-corruption footgun:
    every problem aliases the arena's last contents and the in-place cap
    scaling clobbers across problems.  The batch API must refuse loudly."""
    rng = np.random.default_rng(29)
    arena = CutArena()
    specs, problems = [], []
    for _ in range(3):
        k, ia, ib, iw, ti, tj = _random_aux_block(rng)
        ia, ib, iw = _sorted_arcs(ia, ib, iw)
        specs.append((k, ia, ib, iw, ti, tj))
        problems.append(assemble_symmetric_flow_csr(
            k, ia, ib, iw, ti, tj, arena=arena, presorted=True))
    with pytest.raises(ValueError, match="share capacity memory"):
        min_st_cut_csr_many(problems)
    # The same problems assembled into owned arrays are accepted (and the
    # tuples survive the process-pool pickling round trip).
    owned = [assemble_symmetric_flow_csr(*s, presorted=True) for s in specs]
    results = min_st_cut_csr_many(owned, workers=2, worker_mode="process")
    assert len(results) == 3
    for (v, side), (k, *_rest) in zip(results, specs):
        assert side[k] and not side[k + 1]        # S source-side, T not


def test_min_st_cut_csr_many_matches_serial():
    """The CSR worker pool (thread and process) returns the same cuts in
    input order as serial execution; prescaled problems round-trip too."""
    rng = np.random.default_rng(11)
    problems = []
    for _ in range(5):
        k, ia, ib, iw, ti, tj = _random_aux_block(rng)
        ia, ib, iw = _sorted_arcs(ia, ib, iw)
        problems.append(assemble_symmetric_flow_csr(
            k, ia, ib, iw, ti, tj, presorted=True))
    serial = min_st_cut_csr_many([
        (n, s, t, ip, co, ca.copy()) for n, s, t, ip, co, ca in problems])
    threads = min_st_cut_csr_many([
        (n, s, t, ip, co, ca.copy()) for n, s, t, ip, co, ca in problems],
        workers=2)
    procs = min_st_cut_csr_many([
        (n, s, t, ip, co, ca.copy()) for n, s, t, ip, co, ca in problems],
        workers=2, worker_mode="process")
    for (v1, s1), (v2, s2), (v3, s3) in zip(serial, threads, procs):
        assert v1 == pytest.approx(v2, rel=1e-9)
        assert v1 == pytest.approx(v3, rel=1e-9)
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(s1, s3)


def test_min_st_cut_csr_prescaled_uses_caps_verbatim():
    """prescaled=True must treat integer-valued caps as final: a problem
    whose caps already carry the 1/_SCALE resolution solves to the same
    partition whether quantized by the solver or by the caller."""
    rng = np.random.default_rng(3)
    k, ia, ib, iw, ti, tj = _random_aux_block(rng)
    ia, ib, iw = _sorted_arcs(ia, ib, iw)
    cmax = max(ti.max(), tj.max(), iw.max() if len(iw) else 0.0)
    scale = _SCALE / max(cmax, 1e-30)
    q = lambda x: np.maximum(np.rint(x * scale), 0)  # noqa: E731
    n, s, t, ip, co, ca = assemble_symmetric_flow_csr(
        k, ia, ib, q(iw), q(ti), q(tj), presorted=True)
    _, side_pre = min_st_cut_csr(n, s, t, ip, co, ca, prescaled=True)
    n, s, t, ip, co, ca = assemble_symmetric_flow_csr(
        k, ia, ib, iw, ti, tj, presorted=True)
    _, side_auto = min_st_cut_csr(n, s, t, ip, co, ca)
    np.testing.assert_array_equal(side_pre, side_auto)


def test_min_st_cut_many_orders_and_workers():
    """min_st_cut_many returns results in input order, identical across
    serial / thread-pool / process-pool execution."""
    rng = np.random.default_rng(7)
    problems = []
    for _ in range(6):
        n = int(rng.integers(4, 9))
        us, vs, caps = _random_network(rng, n, 3 * n)
        problems.append((n, 0, n - 1, us, vs, caps, np.zeros(len(us))))
    serial = min_st_cut_many(problems, backend="dinic")
    threads = min_st_cut_many(problems, backend="dinic", workers=3)
    for (v1, s1), (v2, s2) in zip(serial, threads):
        assert v1 == pytest.approx(v2, rel=1e-9)
        np.testing.assert_array_equal(s1, s2)


# ------------------------------------------------------------------ CutArena
def test_cut_arena_growth_is_monotone():
    """A smaller request after a larger one must reuse the same backing
    buffers (no downward reallocation mid-sweep), and capacity only grows."""
    arena = CutArena()
    u1, _, _, _ = arena.edge_buffers(5000)
    big = arena._u
    cap_after_big = arena._cap
    assert cap_after_big >= 5000
    u2, _, _, _ = arena.edge_buffers(37)            # shrinking round
    assert arena._u is big and arena._cap == cap_after_big
    assert len(u2) == 37
    u3, _, _, _ = arena.edge_buffers(4096)          # big again: still no realloc
    assert arena._u is big and arena._cap == cap_after_big
    assert len(u3) == 4096
    arena.edge_buffers(3 * cap_after_big)           # genuine growth
    assert arena._cap >= max(3 * cap_after_big, cap_after_big)
    assert arena._cap >= cap_after_big              # monotone


def test_cut_arena_flow_csr_buffers_monotone_and_sized():
    arena = CutArena()
    indptr, cols, caps = arena.flow_csr_buffers(100, 9000)
    assert len(indptr) == 100 and len(cols) == 9000 and len(caps) == 9000
    rows_cap, nnz_cap = arena._rows_cap, arena._nnz_cap
    base_cols = arena._cols
    indptr2, cols2, caps2 = arena.flow_csr_buffers(10, 50)   # smaller round
    assert arena._cols is base_cols
    assert arena._rows_cap == rows_cap and arena._nnz_cap == nnz_cap
    assert len(indptr2) == 10 and len(cols2) == 50
    arena.flow_csr_buffers(10, 4 * nnz_cap)                  # grow nnz only
    assert arena._nnz_cap >= 4 * nnz_cap
    assert arena._rows_cap == rows_cap
    # dtypes stay solver-compatible
    assert indptr.dtype == np.int32 and cols.dtype == np.int32
    assert caps.dtype == np.float64
