"""Max-flow/min-cut: scipy backend vs pure-python Dinic oracle."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.maxflow import Dinic, min_st_cut


def _random_network(rng, n, m):
    us = rng.integers(0, n, size=m)
    vs = rng.integers(0, n, size=m)
    keep = us != vs
    us, vs = us[keep], vs[keep]
    caps = rng.uniform(0.1, 5.0, size=len(us)).round(3)
    return us, vs, caps


def test_known_cut_value():
    # s -> a (3), s -> b (2), a -> t (2), b -> t (3), a -> b (1): max flow 5.
    us = np.array([0, 0, 1, 2, 1])
    vs = np.array([1, 2, 3, 3, 2])
    caps = np.array([3.0, 2.0, 2.0, 3.0, 1.0])
    zero = np.zeros(5)
    for backend in ("scipy", "dinic"):
        val, side = min_st_cut(4, 0, 3, us, vs, caps, zero, backend=backend)
        assert val == pytest.approx(5.0, abs=1e-6)
        assert side[0] and not side[3]


def test_disconnected_zero_flow():
    val, side = min_st_cut(4, 0, 3, np.array([0]), np.array([1]),
                           np.array([1.0]), np.array([0.0]), backend="dinic")
    assert val == 0.0
    assert side[0] and not side[3]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_backends_agree_on_cut_value(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 12))
    m = int(rng.integers(n, 4 * n))
    us, vs, caps = _random_network(rng, n, m)
    if len(us) == 0:
        return
    zero = np.zeros(len(us))
    v1, s1 = min_st_cut(n, 0, n - 1, us, vs, caps, zero, backend="scipy")
    v2, s2 = min_st_cut(n, 0, n - 1, us, vs, caps, zero, backend="dinic")
    assert v1 == pytest.approx(v2, rel=1e-5, abs=1e-5)
    # Both sides must be valid s-t separations.
    for s in (s1, s2):
        assert s[0] and not s[n - 1]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_cut_value_equals_crossing_capacity(seed):
    """Min-cut duality: flow value == capacity crossing the returned cut."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 10))
    us, vs, caps = _random_network(rng, n, 3 * n)
    if len(us) == 0:
        return
    zero = np.zeros(len(us))
    val, side = min_st_cut(n, 0, n - 1, us, vs, caps, zero, backend="dinic")
    crossing = sum(c for u, v, c in zip(us, vs, caps)
                   if side[u] and not side[v])
    assert val == pytest.approx(crossing, rel=1e-6, abs=1e-6)
