"""Out-of-core multilevel: streamed coarsening + persistent level stacks.

Two load-bearing contracts:

  * ``build_levels_streamed`` is BIT-IDENTICAL to the in-core
    ``build_levels`` for EVERY chunk size — windows of one vertex, windows
    that split matched pairs across a boundary, windows larger than the
    graph.  Streaming changes peak memory, never a single bit of the
    hierarchy.
  * ``LevelStack.acquire`` is BIT-IDENTICAL to a fresh ``build_levels``
    under whatever cost model it refreshes against: reused matchings are
    certified by exact gate-bit equality, anything else is re-matched or
    rebuilt for real.  Sessions change wall time, never bits — the same
    contract the engine's LayoutSession pins.

Plus the int64-domain overflow guards on quantization and contraction
(silent wraparound at n>=2M would corrupt matchings), the fault-loop
session-survival regression, and the ``record_levels`` telemetry slimming.
"""
import dataclasses
import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, workload_for
from repro.core.engine import LayoutSession
from repro.core.glad_s import glad_s
from repro.core.multilevel import (
    LevelStack,
    build_levels,
    glad_multilevel,
    heavy_edge_matching,
    quantize_weights,
)
from repro.core.multilevel_stream import build_levels_streamed
from repro.graphs.datagraph import DataGraph, contract_graph, synthetic_yelp
from repro.graphs.edgenet import build_edge_network
from tests.conftest import random_graph


def _cm(rng, n, m, extra_edges=None, mu_factor=2.0, seed=0):
    g = random_graph(rng, n, n if extra_edges is None else extra_edges)
    net = build_edge_network(g, m, seed=seed, mu_factor=mu_factor)
    return CostModel(net, g, workload_for("gcn", 8))


def _assert_levels_equal(ref, got):
    """Exact per-level equality of every array the hierarchy carries."""
    assert len(got) == len(ref)
    for k, (a, b) in enumerate(zip(ref, got)):
        if k:
            np.testing.assert_array_equal(a.cluster_of, b.cluster_of,
                                          err_msg=f"level {k} cluster_of")
        np.testing.assert_array_equal(a.vertex_w, b.vertex_w,
                                      err_msg=f"level {k} vertex_w")
        np.testing.assert_array_equal(a.cm.graph.edges, b.cm.graph.edges,
                                      err_msg=f"level {k} edges")
        wa, wb = a.cm.graph.edge_weights, b.cm.graph.edge_weights
        assert (wa is None) == (wb is None)
        if wa is not None:
            np.testing.assert_array_equal(wa, wb,
                                          err_msg=f"level {k} weights")
        np.testing.assert_array_equal(a.cm.unary, b.cm.unary,
                                      err_msg=f"level {k} unary")


# ----------------------------------------------- streamed == in-core, exact

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000), st.integers(1, 400))
def test_streamed_levels_bit_identical_any_chunk(seed, chunk):
    """The streamed coarsening is a pure re-chunking: for ANY window size
    every level's cluster map, vertex weights, edges, summed edge weights
    and coarse unary are bit-identical to the in-core build."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 300))
    cm = _cm(rng, n, int(rng.integers(2, 6)), seed=seed)
    ref = build_levels(cm, coarsen_to=max(4, n // 8))
    got = build_levels_streamed(cm, coarsen_to=max(4, n // 8),
                                chunk_vertices=chunk)
    _assert_levels_equal(ref, got)


def test_streamed_chunk_boundaries_split_matched_pairs():
    """Window boundaries that cut straight through matched pairs (the
    spill-buffer path) must not change a single matching decision.  The
    chunk sizes here are chosen so the finest matching provably contains
    pairs whose endpoints land in different windows."""
    rng = np.random.default_rng(7)
    cm = _cm(rng, 240, 4, extra_edges=720, seed=7)
    g = cm.graph
    cap = 10 ** 9
    match = heavy_edge_matching(g, np.ones(g.n, dtype=np.int64), cap,
                                unary=cm.unary, tau_ref=cm.tau_ref())
    ref = build_levels(cm, coarsen_to=16)
    exercised = 0
    for chunk in (1, 3, 17, 100):
        v = np.arange(g.n)
        split = (match != v) & (v // chunk != match // chunk)
        exercised += int(split.any())
        got = build_levels_streamed(cm, coarsen_to=16, chunk_vertices=chunk)
        _assert_levels_equal(ref, got)
    assert exercised == 4, "no chunk size actually split a matched pair"


def test_streamed_dispatch_via_build_levels_and_auto_chunk():
    """``build_levels(chunk_vertices=...)`` routes through the streamed
    path; 'auto' resolves the default window; bad sizes raise."""
    rng = np.random.default_rng(3)
    cm = _cm(rng, 120, 3, seed=3)
    ref = build_levels(cm, coarsen_to=16)
    _assert_levels_equal(ref, build_levels(cm, coarsen_to=16,
                                           chunk_vertices=13))
    _assert_levels_equal(ref, build_levels(cm, coarsen_to=16,
                                           chunk_vertices="auto"))
    with pytest.raises(ValueError, match="chunk_vertices"):
        build_levels(cm, coarsen_to=16, chunk_vertices=0)


def test_release_views_rebuilds_bitwise_identical():
    """Released caches (CSR views, unary) are pure functions of the level
    data: the next access rebuilds them bit-for-bit.  The streamed build
    leans on this — every level but the coarsest is released — so the
    contract is pinned directly, coarse zero-coefficient models included."""
    rng = np.random.default_rng(11)
    cm = _cm(rng, 200, 4, extra_edges=600, seed=11)
    levels = build_levels_streamed(cm, coarsen_to=16, chunk_vertices=29)
    assert len(levels) > 2
    for k, lvl in enumerate(levels):
        g = lvl.cm.graph
        before = (g.indptr.copy(), g.indices.copy(), g.edge_ids.copy(),
                  g.degrees.copy(), lvl.cm.unary.copy())
        from repro.core.multilevel_stream import release_level_views
        release_level_views(lvl)
        assert g._indptr is None and g._indices is None
        assert g._edge_ids is None and lvl.cm._unary is None
        after = (g.indptr, g.indices, g.edge_ids, g.degrees, lvl.cm.unary)
        for name, a, b in zip(
                ("indptr", "indices", "edge_ids", "degrees", "unary"),
                before, after):
            np.testing.assert_array_equal(a, b,
                                          err_msg=f"level {k} {name}")


def test_build_levels_streamed_releases_all_but_coarsest():
    """The streamed build drops every finished level's derived caches
    (the retained hierarchy's CSR + unary dominate peak RSS at scale);
    the coarsest keeps its caches — the V-cycle solves it next.
    ``release_views=False`` keeps everything for callers that prefer the
    in-core residency profile."""
    rng = np.random.default_rng(5)
    cm = _cm(rng, 200, 4, extra_edges=600, seed=5)
    levels = build_levels_streamed(cm, coarsen_to=16, chunk_vertices=64)
    assert len(levels) > 2
    for lvl in levels[:-1]:
        assert lvl.cm.graph._indptr is None
        assert lvl.cm._unary is None

    cm2 = _cm(np.random.default_rng(5), 200, 4, extra_edges=600, seed=5)
    kept = build_levels_streamed(cm2, coarsen_to=16, chunk_vertices=64,
                                 release_views=False)
    # Every level the build gated stays materialized (the coarsest is
    # never gated — the loop stops before touching its caches).
    assert all(lvl.cm.graph._indptr is not None for lvl in kept[:-1])
    assert all(lvl.cm._unary is not None for lvl in kept[:-1])
    _assert_levels_equal(kept, levels)


# ----------------------------------------------------- int64 domain guards

def test_quantize_weights_rejects_nonfinite_and_overflow():
    """Summed parallel-edge weights that saturate float64 or blow past the
    int64 matching domain must raise loudly — ``.astype(int64)`` would
    WRAP silently and corrupt every downstream matching decision."""
    with pytest.raises(ValueError, match="non-finite"):
        quantize_weights(np.array([1.0, np.inf]))
    with pytest.raises(ValueError, match="non-finite"):
        quantize_weights(np.array([np.nan]))
    # Scale is set by the max (1.0 -> 1e7); the huge NEGATIVE weight then
    # leaves the int64 range after scaling.
    with pytest.raises(ValueError, match="int64"):
        quantize_weights(np.array([1.0, -1e300]))
    # Sane weights at any magnitude ratio still quantize.
    q = quantize_weights(np.array([1.0, 0.5, 1e-12]))
    assert q.dtype == np.int64 and q[0] == 10 ** 7


def test_contract_graph_rejects_cluster_key_and_weight_overflow():
    edges = np.array([[0, 1], [2, 3]], dtype=np.int64)
    g = DataGraph(4, edges)
    with pytest.raises(ValueError, match="packed edge key"):
        contract_graph(g, np.array([0, 1, 2, 3]), 3_100_000_000)
    # Two parallel fine edges whose float64 weight sum overflows to inf.
    g2 = DataGraph(4, np.array([[0, 1], [2, 3]], dtype=np.int64))
    g2.edge_weights = np.array([1e308, 1e308])
    with pytest.raises(ValueError, match="non-finite"):
        contract_graph(g2, np.array([0, 1, 0, 1]), 2)


def test_contract_graph_streamed_guards_match_in_core():
    from repro.core.multilevel_stream import contract_graph_streamed
    g = DataGraph(4, np.array([[0, 1], [2, 3]], dtype=np.int64))
    with pytest.raises(ValueError, match="packed edge key"):
        contract_graph_streamed(g, np.array([0, 1, 2, 3]), 3_100_000_000)
    g2 = DataGraph(4, np.array([[0, 1], [2, 3]], dtype=np.int64))
    g2.edge_weights = np.array([1e308, 1e308])
    with pytest.raises(ValueError, match="non-finite"):
        contract_graph_streamed(g2, np.array([0, 1, 0, 1]), 2,
                                chunk_vertices=1)


# ------------------------------------------------- LevelStack exact reuse

def _perturb(cm, rng):
    """One random relayout-style model change over the SAME graph: degrade
    a server's compute, rescale tau, or leave the model alone (pure
    assignment churn) — the event mix a fault loop produces."""
    kind = int(rng.integers(0, 3))
    net = cm.net
    if kind == 0:
        alpha = net.alpha.copy()
        alpha[int(rng.integers(0, net.m))] *= float(rng.uniform(1.1, 4.0))
        net = dataclasses.replace(net, alpha=alpha)
    elif kind == 1:
        net = dataclasses.replace(net, tau=net.tau * float(
            rng.uniform(0.5, 2.0)))
    return CostModel(net, cm.graph, cm.gnn)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 5000))
def test_level_stack_refresh_bit_identical_over_random_sequences(seed):
    """Over a random sequence of same-graph model changes, every
    ``acquire`` must hand back exactly what a fresh ``build_levels`` would
    — reused matchings included (the gate-bit certificate at work)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(80, 220))
    cm = _cm(rng, n, int(rng.integers(2, 5)), seed=seed)
    stack = LevelStack(coarsen_to=max(4, n // 10))
    for step in range(4):
        chunk = [None, 1, 37, "auto"][int(rng.integers(0, 4))]
        got = stack.acquire(cm, chunk_vertices=chunk)
        ref = build_levels(cm, coarsen_to=max(4, n // 10))
        _assert_levels_equal(ref, got)
        cm = _perturb(cm, rng)
    assert stack.builds == 1 and stack.refreshes == 3


def test_level_stack_pure_assignment_churn_reuses_everything():
    """Relayouts that only churn the ASSIGNMENT (same graph, same model)
    reuse every cached matching verbatim — coarsening is assignment-free,
    which is exactly why the stack survives >50%-churn relayouts."""
    rng = np.random.default_rng(11)
    cm = _cm(rng, 300, 4, seed=11)
    stack = LevelStack(coarsen_to=32)
    first = stack.acquire(cm)
    again = stack.acquire(cm)
    _assert_levels_equal(first, again)
    assert stack.last_stats["mode"] == "refresh"
    assert stack.last_stats["rebuilt"] == 0
    assert stack.last_stats["reused"] == len(first) - 1


def test_level_stack_invalidated_by_graph_change():
    rng = np.random.default_rng(5)
    cm1 = _cm(rng, 150, 3, seed=5)
    cm2 = _cm(rng, 160, 3, seed=6)
    stack = LevelStack(coarsen_to=16)
    stack.acquire(cm1)
    assert stack.valid_for(cm1) and not stack.valid_for(cm2)
    got = stack.acquire(cm2)                     # full rebuild, not garbage
    _assert_levels_equal(build_levels(cm2, coarsen_to=16), got)
    assert stack.builds == 2 and stack.refreshes == 0


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 5000))
def test_session_vcycle_matches_fresh_over_random_slot_sequences(seed):
    """End-to-end: a session-carried V-cycle relayout sequence (high-churn
    inits, degrading/recovering models) produces bit-identical layouts,
    costs and histories to sessionless solves at every slot."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(70, 160))
    cm = _cm(rng, n, int(rng.integers(2, 5)), seed=seed)
    ses = LayoutSession()
    init = rng.integers(0, cm.net.m, size=n).astype(np.int64)
    for step in range(3):
        a = glad_s(cm, init=init, seed=seed + step, sweep="batched",
                   multilevel=True, coarsen_to=max(4, n // 8), session=ses)
        b = glad_s(cm, init=init, seed=seed + step, sweep="batched",
                   multilevel=True, coarsen_to=max(4, n // 8))
        assert a.history == b.history
        np.testing.assert_array_equal(a.assign, b.assign)
        np.testing.assert_array_equal(np.sort(a.moved), np.sort(b.moved))
        # next slot: heavy churn — shuffle a majority of the layout.
        init = a.assign.copy()
        flip = rng.random(n) < 0.7
        init[flip] = rng.integers(0, cm.net.m, size=int(flip.sum()))
        cm = _perturb(cm, rng)


# ------------------------------------------- fault loop keeps the session

def test_escalating_fault_loop_keeps_session_and_stack_alive():
    """Regression (PR 10): ElasticCoordinator used to FORCE session=None
    whenever multilevel was enabled, so every escalated relayout rebuilt
    both the engine and the hierarchy from scratch.  The session and the
    LevelStack now coexist: across an escalating fault loop the engine
    rebinds (observable via its stats) and the stack refreshes instead of
    rebuilding."""
    from repro.core import data_partition
    from repro.runtime import ElasticCoordinator
    g = synthetic_yelp(n=200, target_links=300)
    gnn = workload_for("gcn", 8)
    # mu_factor large enough that layouts span servers — otherwise the
    # finest refinement has no cut links and never engages the engine.
    net = build_edge_network(g, 6, seed=0, mu_factor=3.0)
    part = data_partition(g, gnn, num_parts=6, net=net, seed=0)
    coord = ElasticCoordinator(net, g, gnn, part, multilevel=True,
                               coarsen_to=32)
    ses = coord._session
    assert ses is not None, "multilevel no longer drops the session"
    coord.on_straggler([0], slow_factor=10.0, seed=0)
    coord.on_failure([5], seed=0)
    coord.on_revive([5], seed=0)
    # Engine engagement: every escalated relayout's finest refinement
    # adopted the ONE persistent engine, and at least one adoption was
    # served by a rebind rather than a rebuild.
    assert ses is coord._session
    assert ses.adoptions >= 3
    assert ses.rebinds >= 1
    # Hierarchy engagement: one build, then refreshes off the cache.
    stack = ses.level_stack(coarsen_to=32)
    assert stack.builds == 1
    assert stack.refreshes >= 2
    assert ses.stack_valid_for(CostModel(coord.net, g, gnn), coarsen_to=32)


def test_fault_relayouts_with_session_match_sessionless_arm():
    """The coordinator's escalated relayouts must be bit-identical between
    the session arm and the session=False control arm — migrations and
    costs exactly equal, event for event."""
    from repro.core import data_partition
    from repro.runtime import ElasticCoordinator
    g = synthetic_yelp(n=160, target_links=240)
    gnn = workload_for("gcn", 8)
    net = build_edge_network(g, 5, seed=1, mu_factor=3.0)
    part = data_partition(g, gnn, num_parts=5, net=net, seed=1)

    def run(session):
        coord = ElasticCoordinator(net, g, gnn, part, multilevel=True,
                                   coarsen_to=24, session=session)
        coord.on_straggler([1], slow_factor=8.0, seed=3)
        coord.on_failure([4], seed=3)
        return coord

    a, b = run(True), run(False)
    assert b._session is None
    for ea, eb in zip(a.events, b.events):
        assert ea.new_cost == eb.new_cost
        assert ea.migrated == eb.migrated
        np.testing.assert_array_equal(ea.moved, eb.moved)
    np.testing.assert_array_equal(a.part.assign, b.part.assign)


# --------------------------------------------------- record_levels slimming

def test_record_levels_false_slims_telemetry_not_trajectory():
    rng = np.random.default_rng(9)
    cm = _cm(rng, 200, 4, seed=9)
    full = glad_multilevel(cm, seed=2, coarsen_to=24)
    slim = glad_multilevel(cm, seed=2, coarsen_to=24, record_levels=False)
    assert slim.history == full.history and slim.cost == full.cost
    np.testing.assert_array_equal(slim.assign, full.assign)
    assert len(slim.levels) == len(full.levels)
    for fs, ss in zip(full.levels, slim.levels):
        assert ss["init"] is None and ss["active"] is None
        assert ss["history"] == []
        assert ss["history_len"] == len(fs["history"])
        for key in ("level", "role", "n", "edges", "cost", "iterations",
                    "accepted"):
            assert ss[key] == fs[key]
        for key in ("init", "active"):
            arr = fs[key]
            if arr is None:
                assert ss[key + "_crc32"] is None and ss[key + "_size"] == 0
            else:
                arr = np.ascontiguousarray(arr)
                assert ss[key + "_size"] == arr.size
                assert ss[key + "_crc32"] == zlib.crc32(arr.tobytes())
        if len(fs["history"]):
            assert ss["history_crc32"] == zlib.crc32(
                np.asarray(fs["history"], dtype=np.float64).tobytes())


def test_glad_e_auto_policy_escalates_earlier_with_valid_stack():
    """The churn-measured policy: identical churn between the fresh and
    stacked break-evens escalates ONLY when the session holds a hierarchy
    that is still valid for the evolved graph."""
    import importlib
    # repro.core re-exports the glad_e FUNCTION under the module's name.
    gemod = importlib.import_module("repro.core.glad_e")
    churn = (gemod.MULTILEVEL_ESCALATE_STACKED
             + gemod.MULTILEVEL_ESCALATE_FRESH) / 2.0
    assert gemod.MULTILEVEL_ESCALATE_STACKED < churn
    assert churn < gemod.MULTILEVEL_ESCALATE_FRESH
    rng = np.random.default_rng(21)
    cm = _cm(rng, 120, 3, seed=21)
    ses = LayoutSession()
    # A stack built over THIS graph (fault-style relayout: graph constant).
    ses.level_stack(coarsen_to=1024).acquire(cm)
    assert ses.stack_valid_for(cm, coarsen_to=1024)

    calls = []
    import repro.core.multilevel as mlmod
    real = mlmod.glad_multilevel

    def spy(c, **kw):
        calls.append(True)
        return real(c, **kw)

    import unittest.mock as mock
    n_churn = int(round(churn * cm.graph.n))
    active = np.zeros(cm.graph.n, dtype=bool)
    active[:n_churn] = True
    # glad_e binds changed_vertices at import; glad_s imports
    # glad_multilevel lazily from the multilevel module at call time.
    with mock.patch.object(gemod, "changed_vertices",
                           return_value=active), \
            mock.patch.object(mlmod, "glad_multilevel", spy):
        gemod.glad_e(cm, cm.graph, np.zeros(cm.graph.n, dtype=np.int64),
                     seed=0, multilevel="auto")          # no session: flat
        assert calls == []
        gemod.glad_e(cm, cm.graph, np.zeros(cm.graph.n, dtype=np.int64),
                     seed=0, multilevel="auto", session=ses)
        assert calls == [True]                           # stacked: V-cycle
