"""Replication overlay: the move-vs-replicate greedy's exact accounting,
the ``replicate=`` solver knob (overlay never perturbs the cut trajectory),
replica tables through compile / patch_plan / set_replication (bit-identity
vs the fresh-compile oracle), the replicated multi-device forward (bit-match
vs the unreplicated plan), the serve path's replica tier + per-epoch ledger
snapshot, and the fault coordinator's degraded-mode replica fallback."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CostModel, data_partition, workload_for
from repro.core.cost import Replication
from repro.core.glad_s import glad_s
from repro.core.partition import partition_from_assign
from repro.gnn.distributed import (compile_plan, patch_plan, plans_equal,
                                   recompile_like, set_replication)
from repro.gnn.models import GNNConfig, init_params
from repro.gnn.serving import (GNNServeEngine, replicate_for_stream,
                               serving_cost, zipf_requests)
from repro.graphs.edgenet import build_edge_network
from repro.runtime import ElasticCoordinator
from tests.conftest import random_graph


def _cluster(seed=0, n=160, links=240, m=4):
    """Random graph + a fleet with real placement structure (mu_factor=2.0
    keeps compute from collapsing every vertex onto one server)."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n, links)
    gnn = workload_for("gcn", g.features.shape[1])
    net = build_edge_network(g, m, seed=seed, mu_factor=2.0)
    cm = CostModel(net, g, gnn)
    assign = rng.integers(0, m, size=g.n)
    return g, gnn, net, cm, assign


def _singleton_net(cm, assign, v, p):
    """Exact net charge of replicating just v into p."""
    one = Replication(by_part={int(p): np.array([v], dtype=np.int64)},
                      gain=0.0, saved=0.0, sync=0.0, storage=0.0,
                      sync_weight=0.5, storage_cost=0.0)
    return cm.replication_cost(assign, one)["net"]


# ----------------------------------------------------------- greedy overlay
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_replicate_greedy_accounting_identity(seed):
    g, gnn, net, cm, assign = _cluster(seed)
    repl = cm.replicate_greedy(assign)
    assert repl.count > 0, "fixture should produce a non-trivial overlay"
    acc = cm.replication_cost(assign, repl)
    # The greedy accepts only positive gains, so its net is never a charge.
    assert acc["net"] <= 1e-9
    assert repl.gain == pytest.approx(-acc["net"])
    assert acc["net"] == pytest.approx(
        acc["sync"] + acc["storage"] - acc["saved"])
    assert acc["total"] == pytest.approx(cm.total(assign) + acc["net"])
    for p, ids in repl.by_part.items():
        assert (assign[ids] != p).all(), "home residents need no copy"
        assert (np.diff(ids) > 0).all(), "ids sorted unique per part"
        # Unary decisions: every accepted placement pays for itself.
        for v in ids[: min(4, len(ids))]:
            assert _singleton_net(cm, assign, int(v), int(p)) < 0


def test_replicate_greedy_budget_keeps_top_gains(seed=3):
    g, gnn, net, cm, assign = _cluster(seed)
    full = cm.replicate_greedy(assign)
    capped = cm.replicate_greedy(assign, budget=1)
    again = cm.replicate_greedy(assign, budget=1)
    for p, ids in capped.by_part.items():
        assert len(ids) <= 1
        np.testing.assert_array_equal(ids, again.by_part[p])  # deterministic
        if not len(ids) or len(full.by_part[p]) < 2:
            continue
        kept = -_singleton_net(cm, assign, int(ids[0]), p)
        for v in full.by_part[p]:
            if int(v) != int(ids[0]):
                assert kept >= -_singleton_net(cm, assign, int(v), p) - 1e-9


def test_replicate_greedy_empty_without_cut():
    g, gnn, net, cm, _ = _cluster(4)
    assign = np.zeros(g.n, dtype=np.int64)        # one server: no cut links
    repl = cm.replicate_greedy(assign)
    assert repl.count == 0
    acc = cm.replication_cost(assign, repl)
    assert acc["net"] == 0.0
    assert acc["total"] == pytest.approx(cm.total(assign))


# ------------------------------------------------------------- solver knob
def test_glad_s_replicate_never_perturbs_the_cut():
    g, gnn, net, cm, assign = _cluster(5)
    base = glad_s(cm, init=assign, R=net.m, seed=0, sweep="batched")
    repl = glad_s(cm, init=assign, R=net.m, seed=0, sweep="batched",
                  replicate=True)
    # Overlay is a post-pass: cut trajectory bit-identical with knob on/off.
    np.testing.assert_array_equal(base.assign, repl.assign)
    assert base.cost == repl.cost
    assert base.history == repl.history
    assert base.replication is None
    assert repl.replication is not None
    assert repl.replicated_cost == pytest.approx(
        repl.cost - repl.replication.gain)
    assert repl.replicated_cost <= repl.cost + 1e-9
    assert repl.repl_history is not None
    if repl.accepted:
        assert len(repl.repl_history) >= 1


def test_data_partition_replicate_attaches_overlay():
    g, gnn, net, cm, _ = _cluster(6)
    part = data_partition(g, gnn, net.m, net=net, seed=0, replicate=True)
    plain = data_partition(g, gnn, net.m, net=net, seed=0)
    np.testing.assert_array_equal(part.assign, plain.assign)
    assert plain.replication is None
    assert part.replication is not None
    # compile_plan picks the attached overlay up by default.
    plan = compile_plan(g, part, slack=0.25)
    assert plan.has_replicas == (part.replication.count > 0)


def test_coordinator_replica_fallback_and_overlay_persistence():
    g, gnn, net, cm, _ = _cluster(7, m=6)
    part = data_partition(g, gnn, 6, net=net, seed=0, replicate=True)
    assert part.replication is not None

    def run():
        coord = ElasticCoordinator(net, g, gnn, part, replicate=True)
        # Kill a server that HOMES replicated vertices, so orphans with
        # live copies exist and the fallback path actually fires.
        homed = {int(part.assign[v]) for ids in
                 part.replication.by_part.values() for v in ids}
        dead = min(homed) if homed else 0
        coord.on_failure([dead], seed=0)
        return coord, dead

    coord, dead = run()
    assert not (coord.part.assign == dead).any()
    assert coord.part.replication is not None     # overlay survives events
    assert np.isfinite(coord.events[-1].new_cost)
    coord2, _ = run()                              # fallback deterministic
    np.testing.assert_array_equal(coord.part.assign, coord2.part.assign)


# -------------------------------------------------- plan patch bit-identity
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_patch_and_set_replication_match_recompile(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(60, 140))
    g = random_graph(rng, n, int(rng.integers(40, 120)))
    m = 4
    net = build_edge_network(g, m, seed=seed % 7, mu_factor=2.0)
    cm = CostModel(net, g, workload_for("gcn", g.features.shape[1]))
    assign = rng.integers(0, m, size=n)
    plan = compile_plan(g, partition_from_assign(g, assign, m, {}),
                        slack=0.5, replication=cm.replicate_greedy(assign))
    cur = assign
    for step in range(4):
        movers = rng.choice(n, size=min(6, n), replace=False)
        new = cur.copy()
        new[movers] = rng.integers(0, m, size=len(movers))
        patch_plan(plan, g, new)
        assert plans_equal(plan, recompile_like(plan, g, new)) == []
        cur = new
        if step == 1:
            # Re-target the overlay mid-sequence (fresh greedy on the
            # moved cut), then keep patching on top of it.
            set_replication(plan, cm.replicate_greedy(cur))
            assert plans_equal(plan, recompile_like(plan, g, cur)) == []
    set_replication(plan, None)                   # clear back to replica-free
    assert not plan.has_replicas
    assert plans_equal(plan, recompile_like(plan, g, cur)) == []


def test_patch_rehomes_replicated_vertex_exactly():
    """Moving a replicated vertex ONTO its replica host (and off again)
    must re-materialize that host's replica row — the case where the
    request is stable but the materialization changes."""
    g, gnn, net, cm, assign = _cluster(8)
    repl = cm.replicate_greedy(assign)
    p, ids = next((p, ids) for p, ids in sorted(repl.by_part.items())
                  if len(ids))
    v = int(ids[0])
    plan = compile_plan(g, partition_from_assign(g, assign, net.m, {}),
                        slack=0.5, replication=repl)
    for dest in (p, int(assign[v])):              # onto the host, then back
        new = plan.assign.copy()
        new[v] = dest
        patch_plan(plan, g, new)
        assert plans_equal(plan, recompile_like(plan, g, new)) == []
        homed = v in plan.replica[p]
        assert homed == (dest != p)


# --------------------------------------------- replicated forward (8 dev)
_REPL_FWD_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import numpy as np, jax, jax.numpy as jnp
    from repro.graphs import synthetic_siot
    from repro.graphs.edgenet import build_edge_network
    from repro.core import CostModel, workload_for
    from repro.core.partition import partition_from_assign
    from repro.gnn import (GNNConfig, init_params, compile_plan,
                           make_bsp_forward, scatter_features,
                           scatter_replica_halo, gather_outputs)
    from repro.jaxcompat import make_mesh

    g = synthetic_siot(n=160, target_links=420)
    assign = np.random.default_rng(0).integers(0, 8, size=g.n)
    net = build_edge_network(g, 8, seed=0, mu_factor=2.0)
    cm = CostModel(net, g, workload_for('gcn', g.features.shape[1]))
    repl = cm.replicate_greedy(assign)
    assert repl.count > 0
    part = partition_from_assign(g, assign, 8, {})
    plain = compile_plan(g, part, slack=0.25)
    rplan = compile_plan(g, part, slack=0.25, replication=repl)
    # Replica-resident rows are pruned from the layer-0 exchange.
    assert rplan.halo_bytes_ppermute0 < rplan.halo_bytes_ppermute
    mesh = make_mesh((8,), ('data',))
    blocks = jnp.asarray(scatter_features(plain, g.features))
    halo0 = jnp.asarray(scatter_replica_halo(rplan, g.features))
    params = None
    for model in ('gcn', 'sage', 'gat'):
        cfg = GNNConfig(model, (52, 16, 2))
        params = init_params(jax.random.PRNGKey(0), cfg)
        f0 = make_bsp_forward(cfg, plain, mesh, exchange='ppermute')
        f1 = make_bsp_forward(cfg, rplan, mesh, exchange='ppermute')
        ref = gather_outputs(plain, np.asarray(f0(params, blocks)), g.n)
        out = gather_outputs(rplan, np.asarray(f1(params, blocks, halo0)),
                             g.n)
        # Replicas carry EXACT copies of what the pruned ppermute entries
        # would have delivered, so the forward is bit-identical.
        assert np.array_equal(ref, out), model
    cfg = GNNConfig('gcn', (52, 16, 2))
    f1 = make_bsp_forward(cfg, rplan, mesh, exchange='ppermute')
    try:
        f1(params, blocks)
        raise SystemExit('missing replica0 must raise')
    except ValueError:
        pass
    print('REPLFWD8_OK')
""")


def _run_subprocess(script, token):
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert token in r.stdout, r.stdout + r.stderr


def test_replicated_forward_bit_matches_unreplicated_subprocess():
    _run_subprocess(_REPL_FWD_SUBPROCESS, "REPLFWD8_OK")


# ------------------------------------------------------------- serve path
def _serving_setup(seed=0):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, 140, 220)
    m = 4
    net = build_edge_network(g, m, seed=seed, mu_factor=2.0)
    cm = CostModel(net, g, workload_for("gcn", g.features.shape[1]))
    assign = rng.integers(0, m, size=g.n)
    targets = zipf_requests(g.n, 400, s=1.1, seed=seed)
    return g, net, cm, assign, targets


def test_serving_cost_replication_identity():
    g, net, cm, assign, targets = _serving_setup(0)
    base = serving_cost(cm, assign, targets, hops=2)
    repl = replicate_for_stream(cm, assign, targets, hops=2)
    assert repl.count > 0
    got = serving_cost(cm, assign, targets, hops=2, replication=repl)
    # gain is defined against THIS stream, so the ledger closes exactly.
    assert got == pytest.approx(base - repl.gain)
    assert got <= base + 1e-9
    capped = replicate_for_stream(cm, assign, targets, hops=2, budget=2)
    assert all(len(ids) <= 2 for ids in capped.by_part.values())
    assert serving_cost(cm, assign, targets, hops=2,
                        replication=capped) <= base + 1e-9


def _drain(eng):
    while eng.tick() is not None:
        pass


def test_engine_replica_tier_served_before_cache():
    g, net, cm, assign, targets = _serving_setup(1)
    part = partition_from_assign(g, assign, net.m, {})
    cfg = GNNConfig("gcn", (g.features.shape[1], 16, 2))
    import jax
    params = init_params(jax.random.PRNGKey(0), cfg)
    repl = replicate_for_stream(cm, assign, targets, hops=2)
    plans = {
        "plain": compile_plan(g, part, slack=0.5),
        "repl": compile_plan(g, part, slack=0.5, replication=repl),
    }
    stats = {}
    for name, plan in plans.items():
        eng = GNNServeEngine(cfg, params, g, plan, hops=2, net=net,
                             cache_bytes=0)       # cache off: tier isolated
        eng.submit(targets[:160])
        _drain(eng)
        stats[name] = eng.stats
    assert stats["plain"].replica_hit_rows == 0
    assert stats["repl"].replica_hit_rows > 0
    # Same stream, same homes: remote rows only shift between tiers.
    assert stats["repl"].local_rows == stats["plain"].local_rows
    assert (stats["repl"].replica_hit_rows + stats["repl"].cache_hit_rows
            + stats["repl"].fetched_rows
            == stats["plain"].cache_hit_rows + stats["plain"].fetched_rows)
    assert stats["repl"].fetch_cost < stats["plain"].fetch_cost


def test_engine_epoch_snapshot_on_plan_patch():
    """Regression: per-epoch counters must reset when the plan re-seeds —
    post-patch throughput/p99 covers the new plan only, while the
    cumulative ledger keeps the engine's whole life."""
    g, net, cm, assign, targets = _serving_setup(2)
    part = partition_from_assign(g, assign, net.m, {})
    cfg = GNNConfig("gcn", (g.features.shape[1], 16, 2))
    import jax
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan = compile_plan(g, part, slack=0.5,
                        replication=cm.replicate_greedy(assign))
    eng = GNNServeEngine(cfg, params, g, plan, hops=2, net=net)
    eng.submit(targets[:64])
    _drain(eng)
    assert eng.epoch_history == []
    first = eng.epoch_stats.requests
    assert first == 64

    rng = np.random.default_rng(9)
    movers = rng.choice(g.n, size=8, replace=False)
    new = plan.assign.copy()
    new[movers] = rng.integers(0, net.m, size=len(movers))
    patch_plan(plan, g, new)
    eng.submit(targets[64:96])
    _drain(eng)

    assert len(eng.epoch_history) == 1
    closed = eng.epoch_history[0]
    assert closed["stats"].requests == first
    assert closed["plan_version"] == eng.plan.version - 1
    assert eng.epoch_stats.requests == 32          # new window: new plan only
    assert eng.stats.requests == first + 32        # cumulative keeps both
    assert eng.stats.plan_refreshes == 1
    assert set(closed["latency"]) == {"p50", "p99"}
