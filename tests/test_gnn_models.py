"""GNN layer semantics vs hand-rolled numpy oracles (paper Eqs. 1-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gnn.models import GNNConfig, directed_edges, forward, init_params
from repro.gnn.training import accuracy, fit


def tiny_graph():
    # 0-1, 0-2, 1-2, 2-3 (vertex 4 isolated)
    edges = np.array([[0, 1], [0, 2], [1, 2], [2, 3]])
    feats = np.arange(20, dtype=np.float32).reshape(5, 4) / 10.0
    return edges, feats


def np_gcn_layer(W, h, nbrs, last=False):
    n = h.shape[0]
    out = np.zeros((n, W.shape[1]), np.float32)
    for v in range(n):
        agg = h[nbrs[v]].sum(0) if len(nbrs[v]) else np.zeros(h.shape[1])
        z = (agg + h[v]) / (len(nbrs[v]) + 1.0)
        out[v] = z @ W
    return out if last else np.maximum(out, 0)


def np_sage_layer(W, h, nbrs, last=False):
    n = h.shape[0]
    out = np.zeros((n, W.shape[1]), np.float32)
    for v in range(n):
        agg = (h[nbrs[v]].mean(0) if len(nbrs[v])
               else np.zeros(h.shape[1], np.float32))
        z = np.concatenate([agg, h[v]]) @ W
        out[v] = z
    return out if last else np.maximum(out, 0)


def _nbrs(edges, n):
    nb = [[] for _ in range(n)]
    for u, v in edges:
        nb[u].append(v)
        nb[v].append(u)
    return nb


@pytest.mark.parametrize("model,oracle",
                         [("gcn", np_gcn_layer), ("sage", np_sage_layer)])
def test_layer_semantics_vs_numpy(model, oracle):
    edges, feats = tiny_graph()
    nbrs = _nbrs(edges, 5)
    cfg = GNNConfig(model, (4, 3, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    out = np.asarray(forward(cfg, params, jnp.asarray(feats),
                             jnp.asarray(directed_edges(edges))))
    h = feats
    for k, p in enumerate(params):
        h = oracle(np.asarray(p["w"]), h, nbrs, last=(k == 1))
    np.testing.assert_allclose(out, h, rtol=1e-5, atol=1e-5)


def test_gat_attention_rows_sum_to_one():
    """GAT eta_vu softmax: reconstruct weights and verify the aggregation."""
    edges, feats = tiny_graph()
    cfg = GNNConfig("gat", (4, 3))
    params = init_params(jax.random.PRNGKey(1), cfg)
    sd = jnp.asarray(directed_edges(edges))
    out = forward(cfg, params, jnp.asarray(feats), sd)
    # Oracle: explicit softmax attention per destination incl. self loop.
    p = params[0]
    wh = feats @ np.asarray(p["w"])
    a_src, a_dst = np.asarray(p["att_src"]), np.asarray(p["att_dst"])
    nbrs = _nbrs(edges, 5)
    expect = np.zeros_like(wh)
    for v in range(5):
        cand = nbrs[v] + [v]
        logits = np.array([
            np.where((wh[v] @ a_src + wh[u] @ a_dst) > 0,
                     wh[v] @ a_src + wh[u] @ a_dst,
                     0.2 * (wh[v] @ a_src + wh[u] @ a_dst)) for u in cand])
        w = np.exp(logits - logits.max())
        w = w / w.sum()
        expect[v] = sum(wi * wh[u] for wi, u in zip(w, cand))
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4, atol=1e-4)


def test_isolated_vertex_no_nan():
    edges, feats = tiny_graph()
    for model in ("gcn", "gat", "sage"):
        cfg = GNNConfig(model, (4, 3, 2))
        params = init_params(jax.random.PRNGKey(2), cfg)
        out = forward(cfg, params, jnp.asarray(feats),
                      jnp.asarray(directed_edges(edges)))
        assert bool(jnp.isfinite(out).all()), model


def test_training_improves(small_yelp):
    cfg = GNNConfig("gcn", (100, 16, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    sd = directed_edges(small_yelp.edges)
    a0 = accuracy(cfg, params, small_yelp.features, sd, small_yelp.labels)
    params, losses = fit(cfg, params, small_yelp.features, sd,
                         small_yelp.labels, steps=40, lr=0.1)
    a1 = accuracy(cfg, params, small_yelp.features, sd, small_yelp.labels)
    assert losses[-1] < losses[0]
    assert a1 >= a0
