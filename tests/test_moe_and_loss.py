"""Units born from the §Perf hillclimb: grouped GEMM adjoints, block-capacity
MoE semantics, sharded CE equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.jaxcompat import make_mesh
from repro.models.common import LMConfig, sharded_ce_loss
from repro.models.moe import grouped_gemm, moe_ffn, moe_ffn_dense_ref, router_topk

RNG = np.random.default_rng(0)


def _dense_grouped(x, w, gs):
    bounds = jnp.cumsum(gs)
    gid = jnp.searchsorted(bounds, jnp.arange(x.shape[0]), side="right")
    return jnp.einsum("mk,mkn->mn", x, w[gid])


@pytest.mark.parametrize("m,k,n,g", [(32, 16, 12, 4), (64, 8, 8, 8),
                                     (16, 32, 4, 2)])
def test_grouped_gemm_forward_and_adjoints(m, k, n, g):
    x = jnp.asarray(RNG.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(g, k, n)).astype(np.float32))
    sizes = RNG.multinomial(m, np.ones(g) / g)
    gs = jnp.asarray(sizes, jnp.int32)
    np.testing.assert_allclose(np.asarray(grouped_gemm(x, w, gs)),
                               np.asarray(_dense_grouped(x, w, gs)),
                               rtol=1e-5, atol=1e-5)
    g1 = jax.grad(lambda x, w: (grouped_gemm(x, w, gs) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    g2 = jax.grad(lambda x, w: (_dense_grouped(x, w, gs) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_router_topk_weights_normalized():
    x = jnp.asarray(RNG.normal(size=(3, 5, 16)).astype(np.float32))
    wr = jnp.asarray(RNG.normal(size=(16, 8)).astype(np.float32))
    idx, w, aux = router_topk(x, wr, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert np.isfinite(float(aux)) and float(aux) > 0.0
    assert idx.shape == (3, 5, 3)
    assert int(idx.max()) < 8


def test_moe_capacity_drops_overflow():
    """With capacity_factor tiny, overflow rows are dropped, not corrupted."""
    cfg = LMConfig(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                   n_kv_heads=2, d_ff=0, vocab=64, n_experts=4, top_k=2,
                   expert_d_ff=8, capacity_factor=0.25, dtype=jnp.float32)
    k = jax.random.split(jax.random.PRNGKey(0), 4)
    p = {"router": jax.random.normal(k[0], (16, 4)) * 0.1,
         "w13": jax.random.normal(k[1], (4, 16, 16)) * 0.1,
         "w2": jax.random.normal(k[2], (4, 8, 16)) * 0.1}
    x = jax.random.normal(k[3], (2, 8, 16))
    mesh = make_mesh((1, 1), ("data", "model"))
    out, _ = jax.jit(lambda p, x: moe_ffn(cfg, p, x, mesh, ("data",)))(p, x)
    assert bool(jnp.isfinite(out).all())
    # Dropped tokens contribute zero, so |out| <= |dense ref|-ish magnitude.
    ref, _ = moe_ffn_dense_ref(cfg, p, x)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(ref).max()) * 2 + 1e-3


def test_sharded_ce_equals_naive():
    B, L, V = 3, 7, 50
    logits = jnp.asarray(RNG.normal(size=(B, L, V)).astype(np.float32)) * 3
    labels = jnp.asarray(RNG.integers(0, V, size=(B, L)), jnp.int32)
    labels = labels.at[0, 0].set(-100)
    loss = sharded_ce_loss(logits, labels)
    # Naive reference
    mask = (labels >= 0)
    lab = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    ref = ((lse - gold) * mask).sum() / mask.sum()
    assert float(loss) == pytest.approx(float(ref), rel=1e-6)
    # Grads agree
    g1 = jax.grad(lambda l: sharded_ce_loss(l, labels))(logits)
    g2 = jax.grad(lambda l: (
        (jax.scipy.special.logsumexp(l, -1)
         - jnp.take_along_axis(l, lab[..., None], -1)[..., 0]) * mask
    ).sum() / mask.sum())(logits)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-5, atol=1e-6)


def test_sharded_ce_extreme_logits_stable():
    logits = jnp.asarray([[[1e4, -1e4, 0.0]]], jnp.float32)
    labels = jnp.asarray([[0]], jnp.int32)
    assert float(sharded_ce_loss(logits, labels)) == pytest.approx(0.0,
                                                                   abs=1e-3)
