"""Fault-tolerance runtime: detection, elastic GLAD re-layout, stragglers."""
import numpy as np
import pytest

from repro.core import CostModel, workload_for, data_partition
from repro.graphs import synthetic_yelp
from repro.graphs.edgenet import pod_edge_network
from repro.runtime import ElasticCoordinator, FailureDetector


@pytest.fixture()
def cluster():
    g = synthetic_yelp(n=200, target_links=300)
    gnn = workload_for("gcn", 100)
    net = pod_edge_network(6, g.n, pods=2, seed=0)
    # Heterogeneous-ish rho so the layout spreads across servers.
    net.rho = np.linspace(0.1, 0.2, 6)
    part = data_partition(g, gnn, num_parts=6, net=net, seed=0)
    return g, gnn, net, part


def test_failure_detector_timeout_and_recovery():
    fd = FailureDetector(4, timeout_s=10)
    for d in range(4):
        fd.heartbeat(d, now=0.0)
    fd.heartbeat(0, now=12.0)
    dead = fd.sweep(now=15.0)
    assert set(dead) == {1, 2, 3}
    fd.heartbeat(1, now=16.0)               # node came back
    assert fd.devices[1].alive
    assert fd.sweep(now=17.0) == []


def test_straggler_detection_ewma():
    fd = FailureDetector(4)
    for s in range(6):
        for d in range(4):
            fd.heartbeat(d, now=float(s), step_time_s=5.0 if d == 2 else 1.0)
    assert fd.stragglers(factor=2.0) == [2]


def test_elastic_failure_relayout_no_orphans(cluster):
    g, gnn, net, part = cluster
    # Force some vertices onto server 5 so the failure actually migrates.
    assign = part.assign.copy()
    assign[:40] = 5
    from repro.core.partition import partition_from_assign
    cm = CostModel(net, g, gnn)
    part = partition_from_assign(g, assign, 6, cm.factors(assign))
    coord = ElasticCoordinator(net, g, gnn, part)
    newp = coord.on_failure([5])
    assert not (newp.assign == 5).any()
    ev = coord.events[-1]
    assert ev.migrated >= 40
    assert np.isfinite(ev.new_cost)


def test_straggler_relayout_reduces_load_on_slow_server(cluster):
    g, gnn, net, part = cluster
    from repro.core.partition import partition_from_assign
    assign = np.zeros(g.n, dtype=np.int64)          # all on server 0
    cm = CostModel(net, g, gnn)
    part = partition_from_assign(g, assign, 6, cm.factors(assign))
    coord = ElasticCoordinator(net, g, gnn, part)
    before = (part.assign == 0).sum()
    newp = coord.on_straggler([0], slow_factor=50.0)
    after = (newp.assign == 0).sum()
    assert after < before                            # load moved off


def test_checkpoint_restore_after_failure_smaller_mesh(tmp_path):
    """Elastic restart: save under one 'mesh', restore leaves host-side —
    mesh shape never constrains the restore."""
    import jax, jax.numpy as jnp
    from repro.train import CheckpointManager
    ck = CheckpointManager(str(tmp_path), async_write=False)
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    ck.save(1, state, extra={"mesh": "2x16x16"})
    restored, man = ck.restore(1, state)
    assert man["extra"]["mesh"] == "2x16x16"
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
