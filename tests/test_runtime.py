"""Fault-tolerance runtime: detection, elastic GLAD re-layout, stragglers."""
import time

import numpy as np
import pytest

from repro.core import CostModel, workload_for, data_partition
from repro.graphs import synthetic_yelp
from repro.graphs.edgenet import pod_edge_network
from repro.runtime import ElasticCoordinator, FailureDetector


@pytest.fixture()
def cluster():
    g = synthetic_yelp(n=200, target_links=300)
    gnn = workload_for("gcn", 100)
    net = pod_edge_network(6, g.n, pods=2, seed=0)
    # Heterogeneous-ish rho so the layout spreads across servers.
    net.rho = np.linspace(0.1, 0.2, 6)
    part = data_partition(g, gnn, num_parts=6, net=net, seed=0)
    return g, gnn, net, part


def test_failure_detector_timeout_and_recovery():
    fd = FailureDetector(4, timeout_s=10)
    for d in range(4):
        fd.heartbeat(d, now=0.0)
    fd.heartbeat(0, now=12.0)
    dead = fd.sweep(now=15.0)
    assert set(dead) == {1, 2, 3}
    fd.revive(1, now=16.0)                  # explicit re-admission
    assert fd.devices[1].alive
    assert fd.sweep(now=17.0) == []


def test_heartbeat_after_death_is_ignored():
    """A late heartbeat from a swept-dead device must NOT resurrect it —
    sweep() reports each death exactly once and the coordinator has
    already dropped the server; only revive() re-admits."""
    fd = FailureDetector(2, timeout_s=10)
    fd.heartbeat(0, now=0.0)
    fd.heartbeat(1, now=0.0)
    assert fd.sweep(now=15.0) == [0, 1]
    fd.heartbeat(0, now=16.0, step_time_s=1.0)     # late packet
    assert not fd.devices[0].alive
    assert fd.devices[0].step_time_ewma == 0.0
    assert fd.sweep(now=17.0) == []                # no double-report
    fd.revive(0, now=18.0)
    assert fd.devices[0].alive
    assert fd.sweep(now=19.0) == []


def test_straggler_detection_ewma():
    fd = FailureDetector(4)
    for s in range(6):
        for d in range(4):
            fd.heartbeat(d, now=float(s), step_time_s=5.0 if d == 2 else 1.0)
    assert fd.stragglers(factor=2.0) == [2]


def test_failure_detector_cold_start_no_false_positives():
    """Regression: a fresh detector held last_heartbeat=0.0 for every
    device, so the FIRST sweep with a wall-clock `now` (epoch seconds,
    vastly larger than any timeout) declared the entire fleet dead before
    any device ever heartbeated.  Registration must start the timeout
    clock at first observation, not at epoch zero."""
    fd = FailureDetector(4, timeout_s=30.0)
    now = time.time()                       # wall-clock scale >> timeout_s
    assert fd.sweep(now) == []              # pre-fix: the whole fleet
    # Heartbeating devices stay alive; a device that stays silent still
    # dies exactly one timeout period after its registration stamp.
    fd.heartbeat(0, now=now + 20.0)
    fd.heartbeat(1, now=now + 20.0)
    assert fd.sweep(now + 31.0) == [2, 3]
    assert fd.devices[0].alive and fd.devices[1].alive


def test_straggler_detected_at_two_devices():
    """Regression: the fleet median included the candidate's own EWMA, so
    at m=2 a 10x-slow device was mathematically undetectable at factor=2
    (10 > 2 * median([1, 10]) = 11 is false).  Leave-one-out: each device
    is compared against the median of the OTHER live devices."""
    fd = FailureDetector(2)
    fd.heartbeat(0, now=1.0, step_time_s=1.0)
    fd.heartbeat(1, now=1.0, step_time_s=10.0)
    assert fd.stragglers(factor=2.0) == [1]
    # A single live sample has no peers to compare against: no flag.
    fd2 = FailureDetector(2)
    fd2.heartbeat(0, now=1.0, step_time_s=10.0)
    assert fd2.stragglers(factor=2.0) == []


def test_elastic_failure_relayout_no_orphans(cluster):
    g, gnn, net, part = cluster
    # Force some vertices onto server 5 so the failure actually migrates.
    assign = part.assign.copy()
    assign[:40] = 5
    from repro.core.partition import partition_from_assign
    cm = CostModel(net, g, gnn)
    part = partition_from_assign(g, assign, 6, cm.factors(assign))
    coord = ElasticCoordinator(net, g, gnn, part)
    newp = coord.on_failure([5])
    assert not (newp.assign == 5).any()
    ev = coord.events[-1]
    assert ev.migrated >= 40
    assert np.isfinite(ev.new_cost)


def test_straggler_relayout_reduces_load_on_slow_server(cluster):
    g, gnn, net, part = cluster
    from repro.core.partition import partition_from_assign
    assign = np.zeros(g.n, dtype=np.int64)          # all on server 0
    cm = CostModel(net, g, gnn)
    part = partition_from_assign(g, assign, 6, cm.factors(assign))
    coord = ElasticCoordinator(net, g, gnn, part)
    before = (part.assign == 0).sum()
    newp = coord.on_straggler([0], slow_factor=50.0)
    after = (newp.assign == 0).sum()
    assert after < before                            # load moved off


def test_repeated_failures_keep_costs_finite_and_stable(cluster):
    """Regression: without_server used an ESCALATING sentinel (big x 1e6
    per call), so a failure sequence overflowed the cost arithmetic into
    inf/garbage.  Three sequential failures must keep every event cost
    finite, pin the offline sentinel bit-stable, and stay deterministic."""
    from repro.graphs.edgenet import OFFLINE_COST
    g, gnn, net, part = cluster

    def run():
        coord = ElasticCoordinator(net, g, gnn, part)
        for d in (5, 3, 1):
            coord.on_failure([d], seed=0)
        return coord

    coord = run()
    assert len(coord.events) == 3
    for ev in coord.events:
        assert np.isfinite(ev.old_cost), ev
        assert np.isfinite(ev.new_cost), ev
    # No vertex left on a dead server.
    assert not np.isin(coord.part.assign, [1, 3, 5]).any()
    # The sentinel is the SAME fixed value for every dead server, however
    # late in the sequence it died (idempotent, no escalation).
    for d in (5, 3, 1):
        assert (coord.net.tau[d, :] == OFFLINE_COST).all()
        assert (coord.net.tau[:, d] == OFFLINE_COST).all()
        assert (coord.net.mu[:, d] == OFFLINE_COST).all()
    again = coord.net.without_server(5)            # idempotent re-kill
    np.testing.assert_array_equal(again.tau, coord.net.tau)
    # Deterministic trajectory: a re-run lands on identical assignments.
    coord2 = run()
    np.testing.assert_array_equal(coord.part.assign, coord2.part.assign)
    for a, b in zip(coord.events, coord2.events):
        assert a.new_cost == b.new_cost


def test_kill_revive_relayout_round_trip(cluster):
    """Regression: FailureDetector.revive re-admitted a repaired device but
    the coordinator's net kept pricing it at OFFLINE_COST forever —
    without_server has no inverse.  on_revive rebuilds the net from the
    pristine topology (replaying surviving ops), so after kill -> revive
    the net is bitwise healthy again and the relayout's cost returns to
    the healthy regime."""
    g, gnn, net, part = cluster
    from repro.core.partition import partition_from_assign
    assign = part.assign.copy()
    assign[:40] = 5                      # load the doomed server
    cm = CostModel(net, g, gnn)
    part = partition_from_assign(g, assign, 6, cm.factors(assign))
    coord = ElasticCoordinator(net, g, gnn, part)
    coord.on_failure([5], seed=0)
    killed_cost = coord.events[-1].new_cost
    assert not (coord.part.assign == 5).any()
    newp = coord.on_revive([5], seed=0)
    ev = coord.events[-1]
    assert ev.kind == "revive"
    # The net is bitwise the pristine topology again — no OFFLINE pricing.
    np.testing.assert_array_equal(coord.net.tau, net.tau)
    np.testing.assert_array_equal(coord.net.mu, net.mu)
    np.testing.assert_array_equal(coord.net.w, net.w)
    # And the relayout under the restored fleet is no worse than the
    # degraded regime it replaces (server 5 is usable again).
    assert np.isfinite(ev.new_cost)
    assert ev.new_cost <= killed_cost + 1e-9
    np.testing.assert_array_equal(newp.assign, coord.part.assign)


def test_on_revive_replays_surviving_ops(cluster):
    """Reviving one device must preserve every OTHER outstanding
    degradation: kill 5, degrade 4, revive 5 -> the net still prices 4 as
    degraded but 5 as healthy; reviving 4 too restores the pristine net."""
    g, gnn, net, part = cluster
    coord = ElasticCoordinator(net, g, gnn, part)
    coord.on_failure([5], seed=0)
    coord.on_straggler([4], slow_factor=3.0, seed=0)
    coord.on_revive([5], seed=0)
    expect = net.degrade(4, 3.0)
    np.testing.assert_array_equal(coord.net.tau, expect.tau)
    np.testing.assert_array_equal(coord.net.alpha, expect.alpha)
    np.testing.assert_array_equal(coord.net.mu, expect.mu)
    coord.on_revive([4], seed=0)
    np.testing.assert_array_equal(coord.net.alpha, net.alpha)
    np.testing.assert_array_equal(coord.net.beta, net.beta)
    np.testing.assert_array_equal(coord.net.gamma, net.gamma)
    np.testing.assert_array_equal(coord.net.tau, net.tau)
    # A degraded-then-dead device revives at pristine coefficients.
    coord.on_straggler([2], slow_factor=4.0, seed=0)
    coord.on_failure([2], seed=0)
    coord.on_revive([2], seed=0)
    np.testing.assert_array_equal(coord.net.alpha, net.alpha)
    np.testing.assert_array_equal(coord.net.tau, net.tau)


def test_on_failure_old_cost_uses_degraded_net(cluster):
    """old_cost must be 'what staying put would cost NOW' — computed under
    the degraded net, same convention as on_straggler — so event deltas
    are comparable across kinds."""
    g, gnn, net, part = cluster
    coord = ElasticCoordinator(net, g, gnn, part)
    degraded = net.without_server(5)
    expect = CostModel(degraded, g, gnn).total(part.assign)
    coord.on_failure([5])
    assert coord.events[-1].old_cost == expect


def test_checkpoint_restore_after_failure_smaller_mesh(tmp_path):
    """Elastic restart: save under one 'mesh', restore leaves host-side —
    mesh shape never constrains the restore."""
    import jax, jax.numpy as jnp
    from repro.train import CheckpointManager
    ck = CheckpointManager(str(tmp_path), async_write=False)
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    ck.save(1, state, extra={"mesh": "2x16x16"})
    restored, man = ck.restore(1, state)
    assert man["extra"]["mesh"] == "2x16x16"
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
