"""Fault-tolerance runtime: detection, elastic GLAD re-layout, stragglers."""
import numpy as np
import pytest

from repro.core import CostModel, workload_for, data_partition
from repro.graphs import synthetic_yelp
from repro.graphs.edgenet import pod_edge_network
from repro.runtime import ElasticCoordinator, FailureDetector


@pytest.fixture()
def cluster():
    g = synthetic_yelp(n=200, target_links=300)
    gnn = workload_for("gcn", 100)
    net = pod_edge_network(6, g.n, pods=2, seed=0)
    # Heterogeneous-ish rho so the layout spreads across servers.
    net.rho = np.linspace(0.1, 0.2, 6)
    part = data_partition(g, gnn, num_parts=6, net=net, seed=0)
    return g, gnn, net, part


def test_failure_detector_timeout_and_recovery():
    fd = FailureDetector(4, timeout_s=10)
    for d in range(4):
        fd.heartbeat(d, now=0.0)
    fd.heartbeat(0, now=12.0)
    dead = fd.sweep(now=15.0)
    assert set(dead) == {1, 2, 3}
    fd.revive(1, now=16.0)                  # explicit re-admission
    assert fd.devices[1].alive
    assert fd.sweep(now=17.0) == []


def test_heartbeat_after_death_is_ignored():
    """A late heartbeat from a swept-dead device must NOT resurrect it —
    sweep() reports each death exactly once and the coordinator has
    already dropped the server; only revive() re-admits."""
    fd = FailureDetector(2, timeout_s=10)
    fd.heartbeat(0, now=0.0)
    fd.heartbeat(1, now=0.0)
    assert fd.sweep(now=15.0) == [0, 1]
    fd.heartbeat(0, now=16.0, step_time_s=1.0)     # late packet
    assert not fd.devices[0].alive
    assert fd.devices[0].step_time_ewma == 0.0
    assert fd.sweep(now=17.0) == []                # no double-report
    fd.revive(0, now=18.0)
    assert fd.devices[0].alive
    assert fd.sweep(now=19.0) == []


def test_straggler_detection_ewma():
    fd = FailureDetector(4)
    for s in range(6):
        for d in range(4):
            fd.heartbeat(d, now=float(s), step_time_s=5.0 if d == 2 else 1.0)
    assert fd.stragglers(factor=2.0) == [2]


def test_elastic_failure_relayout_no_orphans(cluster):
    g, gnn, net, part = cluster
    # Force some vertices onto server 5 so the failure actually migrates.
    assign = part.assign.copy()
    assign[:40] = 5
    from repro.core.partition import partition_from_assign
    cm = CostModel(net, g, gnn)
    part = partition_from_assign(g, assign, 6, cm.factors(assign))
    coord = ElasticCoordinator(net, g, gnn, part)
    newp = coord.on_failure([5])
    assert not (newp.assign == 5).any()
    ev = coord.events[-1]
    assert ev.migrated >= 40
    assert np.isfinite(ev.new_cost)


def test_straggler_relayout_reduces_load_on_slow_server(cluster):
    g, gnn, net, part = cluster
    from repro.core.partition import partition_from_assign
    assign = np.zeros(g.n, dtype=np.int64)          # all on server 0
    cm = CostModel(net, g, gnn)
    part = partition_from_assign(g, assign, 6, cm.factors(assign))
    coord = ElasticCoordinator(net, g, gnn, part)
    before = (part.assign == 0).sum()
    newp = coord.on_straggler([0], slow_factor=50.0)
    after = (newp.assign == 0).sum()
    assert after < before                            # load moved off


def test_repeated_failures_keep_costs_finite_and_stable(cluster):
    """Regression: without_server used an ESCALATING sentinel (big x 1e6
    per call), so a failure sequence overflowed the cost arithmetic into
    inf/garbage.  Three sequential failures must keep every event cost
    finite, pin the offline sentinel bit-stable, and stay deterministic."""
    from repro.graphs.edgenet import OFFLINE_COST
    g, gnn, net, part = cluster

    def run():
        coord = ElasticCoordinator(net, g, gnn, part)
        for d in (5, 3, 1):
            coord.on_failure([d], seed=0)
        return coord

    coord = run()
    assert len(coord.events) == 3
    for ev in coord.events:
        assert np.isfinite(ev.old_cost), ev
        assert np.isfinite(ev.new_cost), ev
    # No vertex left on a dead server.
    assert not np.isin(coord.part.assign, [1, 3, 5]).any()
    # The sentinel is the SAME fixed value for every dead server, however
    # late in the sequence it died (idempotent, no escalation).
    for d in (5, 3, 1):
        assert (coord.net.tau[d, :] == OFFLINE_COST).all()
        assert (coord.net.tau[:, d] == OFFLINE_COST).all()
        assert (coord.net.mu[:, d] == OFFLINE_COST).all()
    again = coord.net.without_server(5)            # idempotent re-kill
    np.testing.assert_array_equal(again.tau, coord.net.tau)
    # Deterministic trajectory: a re-run lands on identical assignments.
    coord2 = run()
    np.testing.assert_array_equal(coord.part.assign, coord2.part.assign)
    for a, b in zip(coord.events, coord2.events):
        assert a.new_cost == b.new_cost


def test_on_failure_old_cost_uses_degraded_net(cluster):
    """old_cost must be 'what staying put would cost NOW' — computed under
    the degraded net, same convention as on_straggler — so event deltas
    are comparable across kinds."""
    g, gnn, net, part = cluster
    coord = ElasticCoordinator(net, g, gnn, part)
    degraded = net.without_server(5)
    expect = CostModel(degraded, g, gnn).total(part.assign)
    coord.on_failure([5])
    assert coord.events[-1].old_cost == expect


def test_checkpoint_restore_after_failure_smaller_mesh(tmp_path):
    """Elastic restart: save under one 'mesh', restore leaves host-side —
    mesh shape never constrains the restore."""
    import jax, jax.numpy as jnp
    from repro.train import CheckpointManager
    ck = CheckpointManager(str(tmp_path), async_write=False)
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    ck.save(1, state, extra={"mesh": "2x16x16"})
    restored, man = ck.restore(1, state)
    assert man["extra"]["mesh"] == "2x16x16"
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
