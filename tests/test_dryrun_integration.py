"""Integration guard for deliverable (e): one full dry-run cell per family
compiles on the production mesh in a 512-fake-device subprocess, and the
artifact carries sane corrected roofline terms."""
import json
import os
import subprocess
import sys

import pytest

CELLS = [
    ("llama3.2-1b", "decode_32k"),        # dense serve + seq-sharded KV
    ("zamba2-1.2b", "long_500k"),         # hybrid recurrent long-context
]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_dryrun_cell_compiles_and_reports(arch, shape, tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=1500)
    assert "1 ok" in r.stdout, r.stdout + r.stderr[-2000:]
    rec = json.load(open(tmp_path / "pod16x16" / f"{arch}__{shape}.json"))
    assert rec["status"] == "ok"
    rf = rec["roofline"]
    assert rf["flops_per_device"] > 0
    assert 0 < rf["useful_ratio"] <= 1.5
    assert rf["bottleneck"] in ("compute", "memory", "collective")
    assert rec["memory"]["peak_estimate_bytes"] > 0
