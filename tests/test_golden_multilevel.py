"""Golden multilevel V-cycle regression.

Pins (a) the coarsening hierarchy — level sizes and a checksum of every
cluster map, so matching stays a deterministic pure function of the cost
model — and (b) the FINEST-LEVEL refinement trajectory bit-for-bit, both
as produced inside the V-cycle and as replayed by a flat ``glad_s`` call
from the recorded projected init + boundary mask.  The two must agree
with the committed history hex-for-hex: the finest refinement IS the flat
engine, not a lookalike.

REGENERATION RECIPE (only for a deliberate trajectory- or
coarsening-semantics change): rebuild the instance from ``params``, run
``glad_s(..., multilevel=True, coarsen_to=params['coarsen_to'])``, dump
level sizes, per-rung cluster checksums (splitmix-mixed XOR, see below),
and the finest level's R/active-count/iterations/accepted/history(+hex)/
cost(+hex)/final assign to ``fixtures/golden_multilevel.json``.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.cost import CostModel, workload_for
from repro.core.glad_s import glad_s
from repro.core.multilevel import build_levels
from repro.graphs.datagraph import synthetic_siot
from repro.graphs.edgenet import build_edge_network

FIXTURE = (pathlib.Path(__file__).parent / "fixtures"
           / "golden_multilevel.json")


def _cluster_checksum(cluster_of):
    return int(np.bitwise_xor.reduce(
        (cluster_of.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15))
        ^ np.arange(len(cluster_of), dtype=np.uint64)))


@pytest.fixture(scope="module")
def golden_ml():
    with open(FIXTURE) as f:
        fix = json.load(f)
    p = fix["params"]
    g = synthetic_siot(n=p["n"], target_links=p["target_links"],
                       seed=p["graph_seed"])
    net = build_edge_network(g, p["m"], seed=p["net_seed"],
                             mu_factor=p["mu_factor"])
    cm = CostModel(net, g, workload_for(p["gnn_model"], p["in_dim"]))
    res = glad_s(cm, seed=p["glad_seed"], sweep="batched", multilevel=True,
                 coarsen_to=p["coarsen_to"])
    return fix, cm, res


def test_coarsening_hierarchy_matches_golden(golden_ml):
    fix, cm, _ = golden_ml
    stack = build_levels(cm, coarsen_to=fix["params"]["coarsen_to"])
    assert [l.cm.graph.n for l in stack] == fix["level_sizes"]
    assert ([_cluster_checksum(l.cluster_of) for l in stack[1:]]
            == fix["cluster_checksums"])


def test_finest_refinement_matches_golden_bit_for_bit(golden_ml):
    fix, _, res = golden_ml
    finest = res.levels[-1]
    assert finest["level"] == 0 and finest["role"] == "refine"
    assert int(finest["active"].sum()) == fix["active_count"]
    assert finest["R"] == fix["refine_R"]
    assert finest["iterations"] == fix["iterations"]
    assert finest["accepted"] == fix["accepted"]
    got_hex = [np.float64(h).hex() for h in finest["history"]]
    assert got_hex == fix["history_hex"]
    assert np.float64(finest["cost"]).hex() == fix["final_cost_hex"]
    np.testing.assert_array_equal(res.assign, np.array(fix["assign"]))


def test_flat_replay_of_finest_level_matches_golden_bit_for_bit(golden_ml):
    """Run the flat engine from the V-cycle's recorded projected init and
    boundary mask: it must walk the committed trajectory exactly."""
    fix, cm, res = golden_ml
    finest = res.levels[-1]
    replay = glad_s(cm, R=finest["R"], init=finest["init"],
                    active=finest["active"],
                    seed=fix["params"]["glad_seed"], sweep="batched")
    assert replay.iterations == fix["iterations"]
    assert replay.accepted == fix["accepted"]
    assert ([np.float64(h).hex() for h in replay.history]
            == fix["history_hex"])
    assert np.float64(replay.cost).hex() == fix["final_cost_hex"]
    np.testing.assert_array_equal(replay.assign, np.array(fix["assign"]))


def test_reused_level_stack_replays_golden_bit_for_bit(golden_ml):
    """PR 10's persistent LevelStack: a V-cycle run whose coarsening was
    served ENTIRELY off a reused cached hierarchy (zero rebuilt levels)
    must still walk the committed finest-refinement trajectory hex-for-hex
    and land on the committed assign."""
    from repro.core.engine import LayoutSession
    fix, cm, _ = golden_ml
    p = fix["params"]
    ses = LayoutSession()
    kw = dict(seed=p["glad_seed"], sweep="batched", multilevel=True,
              coarsen_to=p["coarsen_to"], session=ses)
    first = glad_s(cm, **kw)                    # builds + caches the stack
    assert first.coarsen["mode"] == "build"
    res = glad_s(cm, **kw)                      # replays through the cache
    assert res.coarsen["mode"] == "refresh"
    assert res.coarsen["rebuilt"] == 0
    assert res.coarsen["reused"] == len(fix["cluster_checksums"])
    finest = res.levels[-1]
    assert ([np.float64(h).hex() for h in finest["history"]]
            == fix["history_hex"])
    assert np.float64(finest["cost"]).hex() == fix["final_cost_hex"]
    np.testing.assert_array_equal(res.assign, np.array(fix["assign"]))


def test_golden_multilevel_fixture_is_self_consistent(golden_ml):
    fix, cm, _ = golden_ml
    assert cm.total(np.array(fix["assign"])) == pytest.approx(
        fix["final_cost"], rel=1e-12)
    h = np.array(fix["history"])
    assert (np.diff(h) <= 1e-9).all()
    assert h[-1] == pytest.approx(fix["final_cost"], rel=1e-12)
    assert fix["accepted"] >= 1      # the pinned refinement really moves
    assert len(fix["level_sizes"]) == len(fix["cluster_checksums"]) + 1
