"""Request-driven serving: ego extraction parity vs a dense BFS oracle,
ego-forward bit-match vs the whole-graph forward, cache admission, and the
live-plan serving loop (including a mid-stream plan patch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import partition_from_assign
from repro.gnn.distributed import compile_plan, patch_plan
from repro.gnn.models import (GNNConfig, directed_edges, forward,
                              init_params)
from repro.gnn.serving import (FeatureCache, GNNServeEngine, ego_tables,
                               extract_ego, extract_ego_batch, link_traffic,
                               make_ego_forward, request_traffic,
                               serving_cost, zipf_requests)
from tests.conftest import random_graph


# ------------------------------------------------------------------ extraction
def _dense_bfs(g, target, hops):
    """Oracle: hop distances via dense boolean adjacency propagation."""
    adj = np.zeros((g.n, g.n), dtype=bool)
    for u, v in g.edges:
        adj[u, v] = adj[v, u] = True
    dist = np.full(g.n, -1, dtype=np.int64)
    dist[target] = 0
    frontier = np.zeros(g.n, dtype=bool)
    frontier[target] = True
    for d in range(1, hops + 1):
        frontier = adj[frontier].any(axis=0) & (dist < 0)
        dist[frontier] = d
    return dist


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_extract_ego_matches_dense_bfs(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(20, 60)), 40)
    target = int(rng.integers(0, g.n))
    hops = 2
    nodes, arcs, depth = extract_ego(g, target, hops)
    dist = _dense_bfs(g, target, hops)

    # Node set == vertices within `hops`, target first, depths exact.
    assert nodes[0] == target
    assert set(nodes.tolist()) == set(np.flatnonzero(dist >= 0).tolist())
    assert len(nodes) == len(set(nodes.tolist()))
    np.testing.assert_array_equal(depth, dist[nodes])

    # Arcs: ALL incoming arcs of every node at depth < hops, none for the
    # depth-`hops` rim, each dst's srcs in ascending order (the summation
    # order that makes the forward bit-match the oracle).
    inner = nodes[depth < hops]
    adj = {}
    for u, v in g.edges:
        adj.setdefault(int(u), set()).add(int(v))
        adj.setdefault(int(v), set()).add(int(u))
    expect = {(s, int(d)) for d in inner for s in adj.get(int(d), ())}
    got = {(int(s), int(d)) for s, d in arcs}
    assert got == expect
    rim = set(nodes[depth == hops].tolist())
    assert not rim & {int(d) for _, d in arcs}
    for d in np.unique(arcs[:, 1]) if len(arcs) else []:
        srcs = arcs[arcs[:, 1] == d, 0]
        assert (np.diff(srcs) > 0).all(), f"dst {d} srcs not ascending"


def test_extract_ego_fanout_prefix_deterministic(small_siot):
    g = small_siot
    a1 = extract_ego(g, 5, 2, fanout=3)
    a2 = extract_ego(g, 5, 2, fanout=3)
    for x, y in zip(a1, a2):
        np.testing.assert_array_equal(x, y)
    nodes, arcs, _ = a1
    for d in np.unique(arcs[:, 1]):
        srcs = arcs[arcs[:, 1] == d, 0]
        assert len(srcs) <= 3
        # Ascending-id prefix of the full neighbor list.
        np.testing.assert_array_equal(srcs, g.neighbors(int(d))[:len(srcs)])


def test_extract_ego_batch_padding_invariants(small_siot):
    g = small_siot
    targets = np.array([0, 7, 31])
    ego = extract_ego_batch(g, targets, hops=2, batch=4)
    assert ego.batch == 4 and ego.targets[3] == -1
    assert ego.node_cap == 1 << (ego.node_cap.bit_length() - 1)  # pow2
    assert ego.arcs.shape[0] == 1 << (ego.arcs.shape[0].bit_length() - 1)
    # Pad arcs point at the dummy row; real arcs stay inside their request's
    # slot range; slot 0 of each live request is its target.
    assert (ego.arcs[ego.num_arcs:] == ego.dummy).all()
    for b, t in enumerate(targets):
        assert ego.nodes[b, 0] == t
        assert ego.num_nodes[b] >= 1
    real = ego.arcs[: ego.num_arcs]
    assert (real < ego.dummy).all() and (real >= 0).all()


# ----------------------------------------------------------------- ego forward
@pytest.mark.parametrize("jit", [True, False])
def test_ego_forward_gcn_bitmatches_oracle(jit, small_siot):
    """With full fanout the GCN ego forward is BIT-exact vs the whole-graph
    forward at the target rows, jitted or eager: its only reductions are
    segment sums (order preserved by extraction) and matmuls whose per-row
    bits are M-independent on XLA CPU."""
    g = small_siot
    cfg = GNNConfig("gcn", (g.features.shape[1], 16, 4))
    params = init_params(jax.random.PRNGKey(0), cfg)
    oracle = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                                jnp.asarray(directed_edges(g.edges))))
    targets = np.array([0, 7, 31, 149, 80])
    ego = extract_ego_batch(g, targets, hops=cfg.num_layers, batch=8)
    feats, deg, tgt = ego_tables(ego, g.features,
                                 g.degrees.astype(np.float32))
    fwd = make_ego_forward(cfg, params, jit=jit)
    out = np.asarray(fwd(jnp.asarray(feats), jnp.asarray(ego.arcs),
                         jnp.asarray(deg), jnp.asarray(tgt)))
    np.testing.assert_array_equal(out[: len(targets)], oracle[targets])


def test_ego_forward_sage_eager_exact_jit_one_ulp(small_siot):
    """SAGE: the eager ego forward is bit-exact; under jit XLA splits the
    dot-of-concat ``[agg, h] @ w`` into two partial matmuls, so the jitted
    path is only allclose (~1 ulp)."""
    g = small_siot
    cfg = GNNConfig("sage", (g.features.shape[1], 16, 4))
    params = init_params(jax.random.PRNGKey(0), cfg)
    oracle = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                                jnp.asarray(directed_edges(g.edges))))
    targets = np.array([3, 77, 140])
    ego = extract_ego_batch(g, targets, hops=cfg.num_layers, batch=4)
    feats, deg, tgt = ego_tables(ego, g.features,
                                 g.degrees.astype(np.float32))
    args = (jnp.asarray(feats), jnp.asarray(ego.arcs), jnp.asarray(deg),
            jnp.asarray(tgt))
    eager = np.asarray(make_ego_forward(cfg, params, jit=False)(*args))
    np.testing.assert_array_equal(eager[: len(targets)], oracle[targets])
    jitted = np.asarray(make_ego_forward(cfg, params)(*args))
    np.testing.assert_allclose(jitted[: len(targets)], oracle[targets],
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("jit", [True, False])
def test_ego_forward_gat_within_ulp(jit, small_siot):
    """GAT: the attention logits are matvecs ``wh @ att`` whose rounding
    depends on the table height on XLA CPU, so even the eager ego path can
    flip the last bit of a softmax weight — pinned to ~1-ulp allclose."""
    g = small_siot
    cfg = GNNConfig("gat", (g.features.shape[1], 16, 4))
    params = init_params(jax.random.PRNGKey(0), cfg)
    oracle = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                                jnp.asarray(directed_edges(g.edges))))
    targets = np.array([0, 7, 31, 149, 80])
    ego = extract_ego_batch(g, targets, hops=cfg.num_layers, batch=8)
    feats, deg, tgt = ego_tables(ego, g.features,
                                 g.degrees.astype(np.float32))
    fwd = make_ego_forward(cfg, params, jit=jit)
    out = np.asarray(fwd(jnp.asarray(feats), jnp.asarray(ego.arcs),
                         jnp.asarray(deg), jnp.asarray(tgt)))
    np.testing.assert_allclose(out[: len(targets)], oracle[targets],
                               rtol=1e-5, atol=1e-6)


def test_ego_forward_retrace_bound(small_siot):
    """Bucketed shapes: repeated batches retrace only on a NEW
    (node_cap, arc_cap) bucket pair, not per request."""
    g = small_siot
    cfg = GNNConfig("gcn", (g.features.shape[1], 8, 2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    fwd = make_ego_forward(cfg, params)
    rng = np.random.default_rng(0)
    shapes = set()
    for _ in range(12):
        targets = rng.choice(g.n, size=4, replace=False)
        ego = extract_ego_batch(g, targets, hops=2, batch=4)
        feats, deg, tgt = ego_tables(ego, g.features,
                                     g.degrees.astype(np.float32))
        fwd(jnp.asarray(feats), jnp.asarray(ego.arcs), jnp.asarray(deg),
            jnp.asarray(tgt))
        shapes.add((ego.node_cap, ego.arcs.shape[0]))
    assert fwd.stats["traces"] == len(shapes)
    assert fwd.stats["traces"] < 12


# ---------------------------------------------------------------- FeatureCache
def test_feature_cache_admission_discipline():
    c = FeatureCache(row_bytes=10, cache_bytes=40)     # 4 rows
    c.seed(np.array([1, 2]))                           # resident, no gate
    assert c.resident == 2
    # Under budget: admitted unconditionally.
    c.lookup(np.array([3]))
    c.admit(np.array([3]))
    c.lookup(np.array([4]))
    c.admit(np.array([4]))
    assert c.resident == 4
    # Over budget + cold (1 touch): rejected, no eviction.
    c.lookup(np.array([5]))
    c.admit(np.array([5]))
    assert c.resident == 4 and c.rejected == 1
    # Hot row (touched far more than the LRU victim): admitted, LRU evicted.
    for _ in range(5):
        c.lookup(np.array([6]))
    c.admit(np.array([6]))
    assert 6 in c._rows and c.resident == 4 and c.evictions == 1
    # Hits refresh LRU and count.
    hit = c.lookup(np.array([6, 99]))
    assert hit.tolist() == [True, False]
    assert c.hits >= 1 and c.misses >= 1


def test_feature_cache_seed_evicts_to_budget():
    c = FeatureCache(row_bytes=10, cache_bytes=25)     # 2 rows fit
    c.seed(np.arange(5))
    assert c.resident == 2 and c.evictions == 3


# --------------------------------------------------------------------- streams
def test_zipf_requests_skewed_and_deterministic():
    a = zipf_requests(100, 2000, s=1.2, seed=7)
    b = zipf_requests(100, 2000, s=1.2, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 100
    counts = np.bincount(a, minlength=100)
    assert counts.max() > 5 * counts.mean()            # skew


def test_request_traffic_mean_one():
    t = request_traffic(50, zipf_requests(50, 500, seed=1))
    assert t.shape == (50,) and abs(t.mean() - 1.0) < 1e-12
    ts = request_traffic(50, np.array([0, 0, 1]), smooth=0.5)
    assert ts.min() > 0                                 # uniform floor


def test_request_traffic_ego_propagation(small_siot):
    """With graph/hops the count of a request spreads over its whole ego:
    a single request weights every vertex of its 2-hop ball equally."""
    g = small_siot
    t = request_traffic(g.n, np.array([7]), graph=g, hops=2)
    nodes, _, _ = extract_ego(g, 7, 2)
    assert abs(t.mean() - 1.0) < 1e-12
    on = np.zeros(g.n, dtype=bool)
    on[nodes] = True
    assert (t[on] > 0).all() and (t[~on] == 0).all()
    assert np.unique(t[on]).size == 1                   # equal weight


def test_link_traffic_counts_ego_crossings(small_siot):
    """link_traffic = per canonical edge, the request mass whose ego
    contains it (each ego counts an edge once, regardless of arc
    direction), mean-1 normalized."""
    g = small_siot
    stream = np.array([7, 7, 7, 30])
    lt = link_traffic(g, stream, hops=2)
    assert lt.shape == (len(g.edges),)
    assert abs(lt.mean() - 1.0) < 1e-12

    raw = np.zeros(len(g.edges))
    keymap = {(int(a), int(b)): i for i, (a, b) in enumerate(g.edges)}
    for v, c in zip(*np.unique(stream, return_counts=True)):
        _, arcs, _ = extract_ego(g, int(v), 2)
        seen = {(min(int(a), int(b)), max(int(a), int(b)))
                for a, b in arcs}
        for k in seen:
            raw[keymap[k]] += c
    assert np.allclose(lt, raw / raw.mean())
    # Edges untouched by every ego carry zero weight.
    assert (lt[raw == 0] == 0).all() and (lt[raw > 0] > 0).all()


# --------------------------------------------------------------- serving loop
@pytest.fixture()
def served_cluster(small_siot):
    g = small_siot
    cfg = GNNConfig("gcn", (g.features.shape[1], 16, 4))
    params = init_params(jax.random.PRNGKey(1), cfg)
    assign = np.random.default_rng(0).integers(0, 4, size=g.n)
    plan = compile_plan(g, partition_from_assign(g, assign, 4, {}),
                        slack=0.5)
    return g, cfg, params, plan


def test_engine_serves_oracle_outputs(served_cluster):
    g, cfg, params, plan = served_cluster
    eng = GNNServeEngine(cfg, params, g, plan, batch=4)
    targets = zipf_requests(g.n, 17, seed=2)
    out = eng.serve(targets)
    oracle = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                                jnp.asarray(directed_edges(g.edges))))
    np.testing.assert_array_equal(out, oracle[targets])
    assert eng.stats.requests == 17
    assert eng.stats.batches == 5                       # ceil(17/4)
    assert eng.stats.local_rows + eng.stats.cache_hit_rows \
        + eng.stats.fetched_rows > 0
    assert eng.latency_percentiles()["p99"] >= \
        eng.latency_percentiles()["p50"] >= 0.0
    assert eng.stats.throughput_rps > 0


def test_engine_survives_plan_patch_mid_stream(served_cluster):
    """The fault-runtime handoff: patch_plan moves vertices mid-stream; the
    engine re-seeds caches off the new halos and keeps answering with
    oracle-exact outputs."""
    g, cfg, params, plan = served_cluster
    eng = GNNServeEngine(cfg, params, g, plan, batch=4)
    oracle = np.asarray(forward(cfg, params, jnp.asarray(g.features),
                                jnp.asarray(directed_edges(g.edges))))
    first = np.array([0, 1, 2, 3])
    np.testing.assert_array_equal(eng.serve(first), oracle[first])

    new_assign = plan.assign.copy()
    new_assign[:30] = (new_assign[:30] + 1) % 4        # relayout delta
    patch_plan(plan, g, new_assign)
    second = np.array([5, 8, 13, 21])
    np.testing.assert_array_equal(eng.serve(second), oracle[second])
    assert eng.stats.plan_refreshes == 1
    cs = eng.cache_stats()
    assert cs["resident"] >= 0 and cs["hits"] + cs["misses"] >= 0


def test_engine_fetch_accounting_against_plan(served_cluster):
    """Every ego row is either local, a cache hit, or fetched — and the
    halo-seeded caches make the plan's read set hit-resident at tick 1."""
    g, cfg, params, plan = served_cluster
    eng = GNNServeEngine(cfg, params, g, plan, batch=4,
                         cache_bytes=1 << 22)
    targets = np.array([0, 40, 90, 120])
    eng.serve(targets)
    total = sum(len(extract_ego(g, int(t), cfg.num_layers)[0])
                for t in targets)
    s = eng.stats
    assert s.local_rows + s.cache_hit_rows + s.fetched_rows == total
    # Remote rows inside the home's halo are seeded -> some hits expected
    # unless every ego row happened to be local.
    if s.local_rows < total:
        assert s.cache_hit_rows + s.fetched_rows > 0


# ---------------------------------------------------------------- serving cost
def test_serving_cost_guards_and_orders_layouts(cm_small):
    cm = cm_small
    g = cm.graph
    targets = zipf_requests(g.n, 200, seed=3)
    assign = np.random.default_rng(0).integers(0, cm.net.m, size=g.n)
    c = serving_cost(cm, assign, targets, hops=2)
    assert np.isfinite(c) and c > 0
    # A layout colocating every hot ego on its home server must not cost
    # more than the same metric with all traffic forced cross-server.
    one_home = np.zeros(g.n, dtype=np.int64)
    assert serving_cost(cm, one_home, targets, hops=2) <= c * 10  # sanity

    from repro.core.cost import CostModel
    aware = CostModel(cm.net, g, cm.gnn,
                      traffic=request_traffic(g.n, targets))
    with pytest.raises(ValueError):
        serving_cost(aware, assign, targets, hops=2)
