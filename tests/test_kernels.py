"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.gnn.models import directed_edges
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gnn_aggregate import build_bsr, spmm
from repro.kernels.ops import BSRAggregate
from repro.kernels.ref import attention_ref, spmm_ref
from tests.conftest import random_graph

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------- spmm
@pytest.mark.parametrize("n,extra,bm,bk,d", [
    (40, 60, 8, 128, 128),
    (100, 200, 8, 128, 256),
    (17, 10, 16, 128, 128),
    (250, 500, 8, 256, 128),
])
def test_spmm_matches_ref_and_segment_sum(n, extra, bm, bk, d):
    g = random_graph(RNG, n, extra)
    sd = directed_edges(g.edges)
    vals, cols, n_dst, n_src = build_bsr(sd, None, n, bm, bk)
    feats = RNG.normal(size=(n_src, d)).astype(np.float32)
    out = spmm(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(feats),
               bm=bm, bk=bk, interpret=True)
    ref = spmm_ref(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(feats),
                   bm, bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    oracle = jax.ops.segment_sum(jnp.asarray(feats)[sd[:, 0]],
                                 jnp.asarray(sd[:, 1]), num_segments=n_dst)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_spmm_weighted_edges():
    g = random_graph(RNG, 30, 40)
    sd = directed_edges(g.edges)
    w = RNG.uniform(0.1, 2.0, size=len(sd)).astype(np.float32)
    vals, cols, n_dst, n_src = build_bsr(sd, w, g.n, 8, 128)
    feats = RNG.normal(size=(n_src, 128)).astype(np.float32)
    out = spmm(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(feats),
               bm=8, bk=128, interpret=True)
    oracle = jax.ops.segment_sum(
        jnp.asarray(w)[:, None] * jnp.asarray(feats)[sd[:, 0]],
        jnp.asarray(sd[:, 1]), num_segments=n_dst)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_bsr_aggregate_wrapper_pads_feature_dim(small_yelp):
    sd = directed_edges(small_yelp.edges)
    agg = BSRAggregate(sd, small_yelp.n)
    out = agg(jnp.asarray(small_yelp.features), impl="ref")
    oracle = jax.ops.segment_sum(
        jnp.asarray(small_yelp.features)[sd[:, 0]], jnp.asarray(sd[:, 1]),
        num_segments=small_yelp.n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- attention
CASES = [
    # B, Hq, Hkv, Lq, Lk, D, causal, kv_len, dtype
    (2, 4, 2, 128, 128, 64, True, None, jnp.float32),
    (1, 8, 8, 192, 192, 64, True, None, jnp.float32),
    (2, 4, 1, 100, 100, 32, True, None, jnp.float32),
    (1, 4, 2, 1, 256, 64, True, [190], jnp.float32),
    (2, 2, 2, 64, 64, 16, False, None, jnp.float32),
    (1, 4, 4, 96, 160, 64, True, None, jnp.float32),
    (2, 4, 2, 64, 64, 64, True, None, jnp.bfloat16),
]


@pytest.mark.parametrize("B,Hq,Hkv,Lq,Lk,D,causal,kv_len,dtype", CASES)
def test_flash_attention_sweep(B, Hq, Hkv, Lq, Lk, D, causal, kv_len, dtype):
    rng = np.random.default_rng(B * 100 + Lq)
    q = jnp.asarray(rng.normal(size=(B, Hq, Lq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)), dtype)
    kl = jnp.asarray(kv_len, jnp.int32) if kv_len else None
    out = flash_attention(q, k, v, kl, causal=causal, bq=64, bkv=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, kv_len=kl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_flash_attention_property(seed):
    """Random shapes: kernel == reference."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 3))
    Hkv = int(rng.integers(1, 3))
    Hq = Hkv * int(rng.integers(1, 4))
    Lq = int(rng.integers(1, 70))
    Lk = Lq + int(rng.integers(0, 70))
    D = int(rng.choice([16, 32, 64]))
    q = jnp.asarray(rng.normal(size=(B, Hq, Lq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, bq=32, bkv=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_attention_softmax_rows_bounded():
    """Outputs are convex combinations of V rows (within numerics)."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 2, 32, 16)).astype(np.float32))
    v = jnp.asarray(rng.uniform(0, 1, size=(1, 2, 32, 16)).astype(np.float32))
    out = flash_attention(q, q, v, causal=True, bq=16, bkv=16, interpret=True)
    assert float(out.min()) >= -1e-5
    assert float(out.max()) <= 1.0 + 1e-5
