"""Cost model (paper Eq. 4-9): brute-force cross-check + structural
properties (Thm 2 pseudo-boolean decomposition, Thm 3 submodularity)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, workload_for
from repro.graphs.edgenet import build_edge_network
from tests.conftest import random_graph


def brute_force_cost(cm, assign):
    """Direct Eq. (4)-(9) evaluation, O(n^2) loops — the oracle."""
    net, g = cm.net, cm.graph
    gnn = cm.gnn
    cu = sum(net.mu[v, assign[v]] for v in range(g.n))
    deg = g.degrees
    cp = 0.0
    for v in range(g.n):
        i = assign[v]
        cp += (net.alpha[i] * deg[v] * gnn.agg_units
               + net.beta[i] * gnn.upd_units + net.gamma[i] * gnn.act_units)
    ct = sum(net.tau[assign[u], assign[v]] for u, v in g.edges)
    cmn = sum(net.rho[assign[v]] for v in range(g.n)) + net.eps.sum()
    return cu + cp + ct + cmn


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 5000))
def test_vectorized_matches_bruteforce(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(5, 25)), 10)
    net = build_edge_network(g, int(rng.integers(2, 5)), seed=seed)
    cm = CostModel(net, g, workload_for("gat", 8))
    assign = rng.integers(0, net.m, size=g.n)
    assert cm.total(assign) == pytest.approx(brute_force_cost(cm, assign),
                                             rel=1e-9)


def test_pseudo_boolean_decomposition(cm_small):
    """C == C0 + C1(x) + C2(x,x) with the Thm-2 terms (unary/constant)."""
    rng = np.random.default_rng(0)
    g, net = cm_small.graph, cm_small.net
    assign = rng.integers(0, net.m, size=g.n)
    c1 = cm_small.unary[np.arange(g.n), assign].sum()
    e = g.edges
    c2 = net.tau[assign[e[:, 0]], assign[e[:, 1]]].sum()
    total = c1 + c2 + cm_small.constant
    assert total == pytest.approx(cm_small.total(assign), rel=1e-9)


def test_factor_signs_and_zero_traffic_when_colocated(cm_small):
    assign = np.zeros(cm_small.graph.n, dtype=np.int64)   # all on server 0
    f = cm_small.factors(assign)
    assert f["C_T"] == 0.0
    assert all(v >= 0 for v in f.values())


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000))
def test_submodularity_marginal_fp(seed):
    """Thm 3 for the compute factor: F_P(X, v) >= F_P(Y, v) for X ⊆ Y."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, 14, 12)
    net = build_edge_network(g, 3, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 8))
    perm = rng.permutation(g.n)
    kx, ky = sorted(rng.integers(1, g.n - 1, size=2))
    X = np.zeros(g.n, bool)
    Y = np.zeros(g.n, bool)
    X[perm[:kx]] = True
    Y[perm[:ky]] = True                      # X ⊆ Y by construction
    outside = np.where(~Y)[0]
    v = int(outside[rng.integers(0, len(outside))])
    assert cm.marginal_fp(X, v) >= cm.marginal_fp(Y, v) - 1e-9


def test_mutating_caller_arrays_cannot_corrupt_cached_deltas():
    """CostModel copies/freezes mu at construction and freezes the cached
    unary matrix: mutating the caller's arrays afterwards must not change
    any cached evaluation, and in-place writes to cm.unary must fail."""
    rng = np.random.default_rng(0)
    g = random_graph(rng, 30, 25)
    net = build_edge_network(g, 3, seed=0)
    caller_mu = net.mu                       # the caller-owned array
    cm = CostModel(net, g, workload_for("gcn", 8))
    assign = rng.integers(0, 3, size=g.n)
    before = cm.total(assign)
    state = cm.layout_state(assign)
    moved = np.array([0, 1])
    new = np.array([2, 2])
    delta_before = state.delta(moved, new)

    caller_mu += 1e6                         # sabotage after construction
    assert cm.total(assign) == pytest.approx(before, rel=1e-12)
    assert state.delta(moved, new) == pytest.approx(delta_before, rel=1e-9)
    state.commit(moved, new)
    assert state.total == pytest.approx(cm.total(state.assign), rel=1e-9)

    with pytest.raises(ValueError):
        cm.unary[0, 0] = 1.0                 # frozen
    with pytest.raises(ValueError):
        cm.net.mu[0, 0] = 1.0                # the model's copy is frozen too


def test_traffic_bytes_counts_cut_links(cm_small):
    g = cm_small.graph
    assign = np.arange(g.n) % cm_small.net.m
    cut = (assign[g.edges[:, 0]] != assign[g.edges[:, 1]]).sum()
    b = cm_small.traffic_bytes(assign, feat_bytes=4)
    layers = len(cm_small.gnn.layer_dims) - 1
    assert b == cut * 2 * 4 * layers
