"""Golden-trajectory regression: a committed GLAD-S run on a small
deterministic instance.

The sequential sweep must reproduce the fixture's full iteration history
and final assignment BIT-FOR-BIT (the incremental engine's trajectory
guarantee); the batched sweeps — per-pair and block-diagonal — must reach
the same final cost.  Regenerate the fixture only for a deliberate
trajectory-semantics change (see the inline recipe below).
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.cost import CostModel, workload_for
from repro.core.glad_s import glad_s
from repro.graphs.datagraph import synthetic_siot
from repro.graphs.edgenet import build_edge_network

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / "golden_glad_s.json"


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        fix = json.load(f)
    p = fix["params"]
    g = synthetic_siot(n=p["n"], target_links=p["target_links"],
                       seed=p["graph_seed"])
    net = build_edge_network(g, p["m"], seed=p["net_seed"])
    cm = CostModel(net, g, workload_for(p["gnn_model"], p["in_dim"]))
    return fix, cm, p["glad_seed"]


def test_sequential_sweep_reproduces_golden_bit_for_bit(golden):
    fix, cm, seed = golden
    res = glad_s(cm, seed=seed, sweep="single")
    assert res.iterations == fix["iterations"]
    assert res.accepted == fix["accepted"]
    got_hex = [np.float64(h).hex() for h in res.history]
    assert got_hex == fix["history_hex"]
    assert np.float64(res.cost).hex() == fix["final_cost_hex"]
    np.testing.assert_array_equal(res.assign, np.array(fix["assign"]))


@pytest.mark.parametrize("round_solver", ["pairwise", "block"])
def test_batched_sweeps_reach_golden_final_cost(golden, round_solver):
    fix, cm, seed = golden
    res = glad_s(cm, seed=seed, sweep="batched", round_solver=round_solver)
    assert res.cost == pytest.approx(fix["final_cost"], rel=1e-12)


def test_golden_fixture_is_self_consistent(golden):
    """The committed assignment really evaluates to the committed cost, and
    the history is monotone non-increasing (accepts only improve)."""
    fix, cm, _ = golden
    assert cm.total(np.array(fix["assign"])) == pytest.approx(
        fix["final_cost"], rel=1e-12)
    h = np.array(fix["history"])
    assert (np.diff(h) <= 1e-9).all()
    assert h[-1] == pytest.approx(fix["final_cost"], rel=1e-12)
