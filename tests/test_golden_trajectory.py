"""Golden-trajectory regression: committed GLAD runs on small
deterministic instances.

The sequential sweep must reproduce the GLAD-S fixture's full iteration
history and final assignment BIT-FOR-BIT (the incremental engine's
trajectory guarantee); the batched sweeps — per-pair and block-diagonal —
must reach the same final cost.  The GLAD-E fixture pins a masked
relayout (evolved graph + drifted carried-over layout + active mask, the
glad_e inner call) bit-for-bit under EVERY {cache on/off} x {warm on/off}
regime, so trajectory drift from assembly caching or warm-started
max-flow re-solves can never land silently.  Regenerate a fixture only
for a deliberate trajectory-semantics change (see the inline recipes
below).
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.cost import CostModel, workload_for
from repro.core.engine import LayoutSession
from repro.core.evolution import apply_delta, changed_vertices, sample_delta
from repro.core.glad_s import glad_s
from repro.graphs.datagraph import synthetic_siot
from repro.graphs.edgenet import build_edge_network

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / "golden_glad_s.json"
FIXTURE_E = pathlib.Path(__file__).parent / "fixtures" / "golden_glad_e.json"


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        fix = json.load(f)
    p = fix["params"]
    g = synthetic_siot(n=p["n"], target_links=p["target_links"],
                       seed=p["graph_seed"])
    net = build_edge_network(g, p["m"], seed=p["net_seed"])
    cm = CostModel(net, g, workload_for(p["gnn_model"], p["in_dim"]))
    return fix, cm, p["glad_seed"]


def test_sequential_sweep_reproduces_golden_bit_for_bit(golden):
    fix, cm, seed = golden
    res = glad_s(cm, seed=seed, sweep="single")
    assert res.iterations == fix["iterations"]
    assert res.accepted == fix["accepted"]
    got_hex = [np.float64(h).hex() for h in res.history]
    assert got_hex == fix["history_hex"]
    assert np.float64(res.cost).hex() == fix["final_cost_hex"]
    np.testing.assert_array_equal(res.assign, np.array(fix["assign"]))


@pytest.mark.parametrize("round_solver", ["pairwise", "block"])
def test_batched_sweeps_reach_golden_final_cost(golden, round_solver):
    fix, cm, seed = golden
    res = glad_s(cm, seed=seed, sweep="batched", round_solver=round_solver)
    assert res.cost == pytest.approx(fix["final_cost"], rel=1e-12)


# ------------------------------------------------- GLAD-E masked relayout
@pytest.fixture(scope="module")
def golden_e():
    """Rebuild the fixture's scenario.  REGENERATION RECIPE: run this
    builder, then a masked batched glad_s with (cache=False, warm=False),
    and dump params/history/history_hex/final_cost(.hex)/iterations/
    accepted/assign to fixtures/golden_glad_e.json — but only for a
    DELIBERATE trajectory-semantics change."""
    with open(FIXTURE_E) as f:
        fix = json.load(f)
    p = fix["params"]
    g0 = synthetic_siot(n=p["n"], target_links=p["target_links"],
                        seed=p["graph_seed"])
    net = build_edge_network(g0, p["m"], seed=p["net_seed"])
    cm0 = CostModel(net, g0, workload_for(p["gnn_model"], p["in_dim"]))
    base = glad_s(cm0, seed=p["base_seed"], sweep="single")
    delta = sample_delta(g0, pct_links=p["delta_pct_links"],
                         pct_vertices=p["delta_pct_vertices"],
                         seed=p["delta_seed"])
    g1 = apply_delta(g0, delta)
    cm1 = CostModel(net, g1, workload_for(p["gnn_model"], p["in_dim"]))
    # Carried-over layout with drift: pad the inserted vertices, scramble
    # a slice (the layout served while the graph evolved).
    rng = np.random.default_rng(p["scramble_seed"])
    assign = np.zeros(g1.n, dtype=np.int64)
    assign[:g0.n] = base.assign
    if g1.n > g0.n:
        assign[g0.n:] = rng.integers(0, p["m"], size=g1.n - g0.n)
    scr = rng.uniform(size=g1.n) < p["scramble_frac"]
    assign[scr] = rng.integers(0, p["m"], size=int(scr.sum()))
    active = changed_vertices(g0, g1, assign)
    active |= scr
    for v in np.flatnonzero(scr):
        active[g1.indices[g1.indptr[v]:g1.indptr[v + 1]]] = True
    return fix, cm1, assign, active, p


@pytest.mark.parametrize("cache,warm", [(False, False), (True, False),
                                        (True, True), (True, "auto")])
def test_glad_e_masked_relayout_reproduces_golden_bit_for_bit(
        golden_e, cache, warm):
    """Every cache x warm regime must reproduce the SAME committed masked
    relayout — full history and final assignment, bit for bit."""
    fix, cm1, assign, active, p = golden_e
    res = glad_s(cm1, R=p["m"], init=assign.copy(), active=active,
                 seed=p["glad_seed"], sweep="batched", cache=cache,
                 warm=warm)
    assert res.iterations == fix["iterations"]
    assert res.accepted == fix["accepted"]
    got_hex = [np.float64(h).hex() for h in res.history]
    assert got_hex == fix["history_hex"]
    assert np.float64(res.cost).hex() == fix["final_cost_hex"]
    np.testing.assert_array_equal(res.assign, np.array(fix["assign"]))


@pytest.mark.parametrize("cache,warm", [(True, False), (True, True)])
def test_session_rebound_engine_reproduces_golden_e(golden_e, cache, warm):
    """A LayoutSession that already served a DIFFERENT slot (the full
    pre-evolution solve) and is then rebound onto the golden scenario must
    reproduce the committed masked relayout bit-for-bit — carried cache
    entries and warm residuals may only change wall time, never the
    trajectory."""
    fix, cm1, assign, active, p = golden_e
    g0 = synthetic_siot(n=p["n"], target_links=p["target_links"],
                        seed=p["graph_seed"])
    net = build_edge_network(g0, p["m"], seed=p["net_seed"])
    cm0 = CostModel(net, g0, workload_for(p["gnn_model"], p["in_dim"]))
    ses = LayoutSession(cache=cache, warm=warm)
    glad_s(cm0, seed=p["base_seed"], sweep="batched", cache=cache,
           warm=warm, session=ses)                 # warm the session
    res = glad_s(cm1, R=p["m"], init=assign.copy(), active=active,
                 seed=p["glad_seed"], sweep="batched", cache=cache,
                 warm=warm, session=ses)
    assert ses.rebinds == 1                        # adopted, not rebuilt
    assert res.iterations == fix["iterations"]
    assert res.accepted == fix["accepted"]
    got_hex = [np.float64(h).hex() for h in res.history]
    assert got_hex == fix["history_hex"]
    assert np.float64(res.cost).hex() == fix["final_cost_hex"]
    np.testing.assert_array_equal(res.assign, np.array(fix["assign"]))


def test_glad_e_golden_fixture_is_self_consistent(golden_e):
    fix, cm1, _, _, _ = golden_e
    assert cm1.total(np.array(fix["assign"])) == pytest.approx(
        fix["final_cost"], rel=1e-12)
    h = np.array(fix["history"])
    assert (np.diff(h) <= 1e-9).all()
    assert h[-1] == pytest.approx(fix["final_cost"], rel=1e-12)
    assert fix["accepted"] >= 2      # the fixture actually moves vertices


def test_golden_fixture_is_self_consistent(golden):
    """The committed assignment really evaluates to the committed cost, and
    the history is monotone non-increasing (accepts only improve)."""
    fix, cm, _ = golden
    assert cm.total(np.array(fix["assign"])) == pytest.approx(
        fix["final_cost"], rel=1e-12)
    h = np.array(fix["history"])
    assert (np.diff(h) <= 1e-9).all()
    assert h[-1] == pytest.approx(fix["final_cost"], rel=1e-12)
