"""Training substrate: grad-accum equivalence, optimizers, checkpointing,
restart determinism, gradient compression."""
import dataclasses
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models as zoo
from repro.configs import get_smoke_config
from repro.models.common import ShapeCfg
from repro.models.transformer import Dist
from repro.train import (CheckpointManager, OptConfig, batch_at_step,
                         init_error_feedback, init_opt_state,
                         make_train_step)
from repro.train.optim import clip_by_global_norm


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"),
                              dtype=jnp.float32)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeCfg("t", 32, 8, "train")
    batch = {k: jnp.asarray(v) for k, v in
             batch_at_step(cfg, shape, 0).items()}
    return cfg, params, shape, batch


def test_microbatch_equals_fullbatch_grads(setup):
    """Accumulated microbatch grads == monolithic grads (same tokens)."""
    cfg, params, shape, batch = setup
    opt = OptConfig(lr=0.0, weight_decay=0.0)     # lr=0: params unchanged
    s1 = jax.jit(make_train_step(cfg, Dist(), opt, microbatches=1))
    s4 = jax.jit(make_train_step(cfg, Dist(), opt, microbatches=4))
    o = init_opt_state(opt, params)
    _, o1, _, m1 = s1(params, o, None, batch)
    _, o4, _, m4 = s4(params, o, None, batch)
    # Same loss; the optimizer's first moments see the same grads.
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(o1.m), jax.tree.leaves(o4.m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


@pytest.mark.parametrize("name", ["adamw", "lion"])
def test_optimizer_decreases_loss(setup, name):
    cfg, params, shape, batch = setup
    opt = OptConfig(name=name, lr=5e-3 if name == "adamw" else 5e-4)
    step = jax.jit(make_train_step(cfg, Dist(), opt))
    o = init_opt_state(opt, params)
    p = params
    losses = []
    for s in range(6):
        p, o, _, m = step(p, o, None, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_compression_error_feedback_converges(setup):
    """int8+EF training tracks the uncompressed trajectory."""
    cfg, params, shape, batch = setup
    opt = OptConfig(lr=5e-3)
    plain = jax.jit(make_train_step(cfg, Dist(), opt))
    comp = jax.jit(make_train_step(cfg, Dist(), opt, compress_grads=True))
    p1 = p2 = params
    o1 = o2 = init_opt_state(opt, params)
    ef = init_error_feedback(params)
    for s in range(5):
        p1, o1, _, m1 = plain(p1, o1, None, batch)
        p2, o2, ef, m2 = comp(p2, o2, ef, batch)
    assert float(m2["loss"]) < 6.0
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.3


def test_checkpoint_roundtrip_and_gc(setup):
    cfg, params, shape, batch = setup
    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, keep=2, async_write=False)
        for s in (1, 2, 3):
            ck.save(s, {"p": params, "s": jnp.asarray(s)})
        assert ck.all_steps() == [2, 3]            # gc kept last 2
        restored, man = ck.restore(3, {"p": params, "s": jnp.asarray(0)})
        assert man["step"] == 3
        for a, b in zip(jax.tree.leaves(restored["p"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d)


def test_checkpoint_atomicity_tmp_ignored(setup):
    cfg, params, _, _ = setup
    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, async_write=False)
        ck.save(1, {"p": params})
        import os
        os.makedirs(os.path.join(d, "step_00000009.tmp"))  # crashed writer
        assert ck.latest_step() == 1
    finally:
        shutil.rmtree(d)


def test_restart_determinism(setup):
    """Train 4 steps == train 2, checkpoint, restore, train 2 (same data)."""
    cfg, params, shape, _ = setup
    opt = OptConfig(lr=1e-3)
    step = jax.jit(make_train_step(cfg, Dist(), opt))

    def run(p, o, s0, n):
        for s in range(s0, s0 + n):
            b = {k: jnp.asarray(v) for k, v in
                 batch_at_step(cfg, shape, s).items()}
            p, o, _, m = step(p, o, None, b)
        return p, o, m

    pA, oA, mA = run(params, init_opt_state(opt, params), 0, 4)

    pB, oB, _ = run(params, init_opt_state(opt, params), 0, 2)
    d = tempfile.mkdtemp()
    try:
        ck = CheckpointManager(d, async_write=False)
        ck.save(2, {"p": pB, "o": oB})
        (rest, _) = ck.restore(2, {"p": pB, "o": oB})
        pC, oC, mC = run(rest["p"], rest["o"], 2, 2)
        assert float(mA["loss"]) == pytest.approx(float(mC["loss"]),
                                                  rel=1e-6)
        for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pC)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
    finally:
        shutil.rmtree(d)


def test_data_pipeline_deterministic_and_sharded(setup):
    cfg, _, shape, _ = setup
    b1 = batch_at_step(cfg, shape, 7)
    b2 = batch_at_step(cfg, shape, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at_step(cfg, shape, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    half = batch_at_step(cfg, shape, 7, host_slice=slice(0, shape.global_batch // 2))
    assert half["tokens"].shape[0] == shape.global_batch // 2
    np.testing.assert_array_equal(
        half["tokens"], b1["tokens"][:shape.global_batch // 2])
