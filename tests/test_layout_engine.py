"""Incremental layout engine: delta-cost exactness, engine/reference
equivalence, batched-sweep quality, and the direct-CSR cut fast path."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, workload_for
from repro.core.engine import PairCutEngine, round_robin_rounds
from repro.core.glad_s import glad_s, solve_pair
from repro.graphs.edgenet import build_edge_network
from tests.conftest import random_graph


def _instance(rng, n=None, m=None, weighted=False):
    n = n or int(rng.integers(8, 40))
    m = m or int(rng.integers(2, 6))
    g = random_graph(rng, n, int(rng.integers(4, 30)))
    if weighted:
        g.edge_weights = rng.uniform(0.2, 3.0, size=len(g.edges))
    net = build_edge_network(g, m, seed=int(rng.integers(0, 1000)))
    return CostModel(net, g, workload_for("gcn", 8)), g, net


# ------------------------------------------------------------- LayoutState
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 5000))
def test_delta_equals_full_reevaluation(seed):
    """state.delta(moved) == total(after) - total(before), for random move
    batches, committing every other one (so caches are exercised too)."""
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng, weighted=bool(seed % 2))
    state = cm.layout_state(rng.integers(0, net.m, size=g.n))
    assert state.total == pytest.approx(cm.total(state.assign), rel=1e-12)
    for t in range(20):
        k = int(rng.integers(1, max(2, g.n // 2)))
        moved = rng.choice(g.n, size=k, replace=False)
        new = rng.integers(0, net.m, size=k)
        before = cm.total(state.assign)
        prop = state.assign.copy()
        prop[moved] = new
        expect = cm.total(prop) - before
        assert state.delta(moved, new) == pytest.approx(expect, abs=1e-8)
        if t % 2 == 0:
            state.commit(moved, new)
            assert state.total == pytest.approx(cm.total(state.assign),
                                                abs=1e-7)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000))
def test_delta_on_every_accepted_move_during_glad(seed):
    """Each accepted GLAD-S iteration's cached total matches a from-scratch
    evaluation (the accept path never drifts from the true objective)."""
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng)
    totals = []
    res = glad_s(cm, seed=seed,
                 on_iteration=lambda it, c: totals.append(c))
    assert totals[-1] == pytest.approx(cm.total(res.assign), rel=1e-9)
    assert res.cost == pytest.approx(cm.total(res.assign), rel=1e-9)


# ------------------------------------------------- engine == reference path
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000))
def test_incremental_matches_reference_trajectory(seed):
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng, weighted=bool(seed % 3 == 0))
    ref = glad_s(cm, seed=seed, engine="reference")
    inc = glad_s(cm, seed=seed, engine="incremental")
    assert inc.cost == pytest.approx(ref.cost, rel=1e-6)
    assert inc.iterations == ref.iterations
    assert inc.accepted == ref.accepted
    np.testing.assert_allclose(inc.history, ref.history, rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000))
def test_engine_solve_pair_matches_reference_solve_pair(seed):
    """The vectorized auxiliary construction (CSR gather + singleton
    reduction + symmetric flow CSR) induces the same cut cost as the seed's
    per-edge-scan construction."""
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng)
    assign = rng.integers(0, net.m, size=g.n)
    i, j = sorted(rng.choice(net.m, size=2, replace=False))
    ref_prop = solve_pair(cm, assign, int(i), int(j))
    eng = PairCutEngine(cm, assign)
    sol = eng.solve_pair(int(i), int(j))
    assert (ref_prop is None) == (sol is None)
    if sol is not None:
        members, proposed = sol
        eng_prop = assign.copy()
        eng_prop[members] = proposed
        # Cuts may tie; the induced objective must agree.
        assert cm.total(eng_prop) == pytest.approx(cm.total(ref_prop),
                                                   rel=1e-6)


# ------------------------------------------------------------ batched sweep
def test_round_robin_rounds_cover_all_pairs_disjointly():
    for m in range(2, 12):
        rounds = round_robin_rounds(m)
        seen = set()
        for rnd in rounds:
            used = [s for p in rnd for s in p]
            assert len(used) == len(set(used)), "pairs in a round overlap"
            seen.update(rnd)
        assert seen == {(i, j) for i in range(m) for j in range(i + 1, m)}


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 5000))
def test_batched_sweep_not_worse_than_sequential(seed):
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng)
    seq = glad_s(cm, seed=seed, sweep="single")
    bat = glad_s(cm, seed=seed, sweep="batched")
    assert bat.cost <= seq.cost + 1e-9
    h = np.array(bat.history)
    assert (np.diff(h) <= 1e-9).all()


def test_batched_sweep_fixed_seeds_small_yelp(cm_small):
    for seed in (0, 1, 2):
        seq = glad_s(cm_small, seed=seed, sweep="single")
        bat = glad_s(cm_small, seed=seed, sweep="batched")
        assert bat.cost <= seq.cost + 1e-9


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 5000))
def test_batched_terminates_pairwise_optimal(seed):
    """Dirty-pair bookkeeping regression: after a batched run converges, no
    server pair admits an improving cut (a stale 'clean' stamp must never
    mask an improving re-solve)."""
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng)
    res = glad_s(cm, seed=seed, sweep="batched")
    eng = PairCutEngine(cm, res.assign)
    for i, j in net.pairs:
        _, accepted = eng.try_pair(int(i), int(j))
        assert not accepted, (seed, i, j)


def test_batched_respects_active_mask(cm_small):
    rng = np.random.default_rng(3)
    init = rng.integers(0, cm_small.net.m, size=cm_small.graph.n)
    active = np.zeros(cm_small.graph.n, bool)
    active[:10] = True
    res = glad_s(cm_small, init=init, active=active, seed=3, sweep="batched")
    assert (res.assign[10:] == init[10:]).all()


# ------------------------------------------------ block-diagonal round solver
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 5000))
def test_block_sweep_not_worse_than_sequential(seed):
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng)
    seq = glad_s(cm, seed=seed, sweep="single")
    blk = glad_s(cm, seed=seed, sweep="batched", round_solver="block")
    assert blk.cost <= seq.cost + 1e-9
    h = np.array(blk.history)
    assert (np.diff(h) <= 1e-9).all()


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 5000))
def test_block_sweep_terminates_pairwise_optimal(seed):
    """After a block-solver run converges, no server pair admits an
    improving cut — the batch assembly + shared-source solve must not mask
    any improving re-solve behind a stale stamp or a wrong scatter."""
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng)
    res = glad_s(cm, seed=seed, sweep="batched", round_solver="block")
    eng = PairCutEngine(cm, res.assign)
    for i, j in net.pairs:
        _, accepted = eng.try_pair(int(i), int(j))
        assert not accepted, (seed, i, j)


def test_block_sweep_matches_pairwise_small_yelp(cm_small):
    for seed in (0, 1, 2):
        pw = glad_s(cm_small, seed=seed, sweep="batched",
                    round_solver="pairwise")
        blk = glad_s(cm_small, seed=seed, sweep="batched",
                     round_solver="block")
        assert blk.cost == pytest.approx(pw.cost, rel=1e-12)


def test_block_sweep_respects_active_mask(cm_small):
    rng = np.random.default_rng(3)
    init = rng.integers(0, cm_small.net.m, size=cm_small.graph.n)
    active = np.zeros(cm_small.graph.n, bool)
    active[:10] = True
    res = glad_s(cm_small, init=init, active=active, seed=3, sweep="batched",
                 round_solver="block")
    assert (res.assign[10:] == init[10:]).all()


def test_unknown_round_solver_raises(cm_small):
    with pytest.raises(ValueError):
        glad_s(cm_small, seed=0, sweep="batched", round_solver="nope")


def test_block_sweep_round_handles_overlapping_pairs():
    """Blocks are only defined for a matching; a round whose pairs share a
    server must fall back to per-pair solves (same results as pairwise),
    not silently misclassify the shared server's members."""
    from tests.conftest import random_graph
    rng = np.random.default_rng(7)
    g = random_graph(rng, 30, 20)
    net = build_edge_network(g, 4, seed=0)
    cm = CostModel(net, g, workload_for("gcn", 4))
    init = rng.integers(0, 4, 30)
    overlap = [(0, 1), (1, 2)]
    e1 = PairCutEngine(cm, init.copy())
    r1 = e1.sweep_round(overlap, solver="block")
    e2 = PairCutEngine(cm, init.copy())
    r2 = e2.sweep_round(overlap, solver="pairwise")
    assert r1 == r2
    assert e1.state.total == pytest.approx(e2.state.total, rel=1e-12)
    np.testing.assert_array_equal(e1.state.assign, e2.state.assign)


@pytest.mark.bench
def test_block_sweep_cost_parity_midsize():
    """Benchmark-shaped instance (n=2000, m=16): block-diagonal and
    per-pair batched sweeps converge to the same final cost (the
    acceptance-criterion invariant, CI-sized)."""
    from repro.graphs.datagraph import synthetic_siot
    g = synthetic_siot(n=2000, target_links=8400, seed=0)
    net = build_edge_network(g, 16, seed=0)
    cm = CostModel(net, g, workload_for("gcn", 52))
    pw = glad_s(cm, seed=0, sweep="batched", round_solver="pairwise")
    blk = glad_s(cm, seed=0, sweep="batched", round_solver="block")
    assert blk.cost == pytest.approx(pw.cost, rel=1e-12)


# ------------------------------------------------------ assembly cache (PR 3)
def test_cache_on_off_bit_identical_trajectories(cm_small):
    """Every sweep discipline produces the exact same iteration history and
    final assignment with the AssemblyCache on or off — patched arrays are
    bit-identical to fresh gathers."""
    for sweep, rs in (("single", "auto"), ("batched", "pairwise"),
                      ("batched", "block")):
        on = glad_s(cm_small, seed=1, sweep=sweep, round_solver=rs,
                    cache=True)
        off = glad_s(cm_small, seed=1, sweep=sweep, round_solver=rs,
                     cache=False)
        assert ([np.float64(a).hex() for a in on.history]
                == [np.float64(b).hex() for b in off.history]), (sweep, rs)
        np.testing.assert_array_equal(on.assign, off.assign)


def test_cache_theta_patch_after_disjoint_commit(cm_small):
    """A commit touching other servers leaves the pair's membership intact:
    the next solve must be served by an O(touched) theta patch (or verbatim
    reuse) and still match a cache-free engine exactly."""
    rng = np.random.default_rng(5)
    m = cm_small.net.m
    init = rng.integers(0, m, size=cm_small.graph.n).astype(np.int64)
    eng = PairCutEngine(cm_small, init.copy(), cache=True)
    assert eng.solve_pair(2, 3) is not None
    movers = np.flatnonzero(eng.state.assign == 0)[:3]
    old = eng.state.assign[movers].copy()
    eng.state.commit(movers, np.full(len(movers), 1))   # unconditional move
    eng._mark_dirty(movers, old)
    sol = eng.solve_pair(2, 3)
    assert eng.cache_stats()["patched"] + eng.cache_stats()["hits"] >= 1
    ref = PairCutEngine(cm_small, eng.state.assign.copy(),
                        cache=False).solve_pair(2, 3)
    np.testing.assert_array_equal(sol[0], ref[0])
    np.testing.assert_array_equal(sol[1], ref[1])


def test_cache_membership_patch_after_cross_commit(cm_small):
    """Moving a few members OUT of the pair triggers the incremental
    membership patch; the refreshed assembly must equal a from-scratch
    one bit for bit."""
    rng = np.random.default_rng(6)
    m = cm_small.net.m
    init = rng.integers(0, 2, size=cm_small.graph.n).astype(np.int64)
    eng = PairCutEngine(cm_small, init.copy(), cache=True)
    assert eng.solve_pair(0, 1) is not None
    movers = np.flatnonzero(eng.state.assign == 0)[:2]
    old = eng.state.assign[movers].copy()
    eng.state.commit(movers, np.full(len(movers), 3))   # leave the pair
    eng._mark_dirty(movers, old)
    sol = eng.solve_pair(0, 1)
    assert eng.cache_stats()["patched"] >= 1
    e = eng._cache[(0, 1)]
    fresh = eng._assemble_full(0, 1)
    np.testing.assert_array_equal(e.members, fresh.members)
    np.testing.assert_array_equal(e.theta_i, fresh.theta_i)
    np.testing.assert_array_equal(e.theta_j, fresh.theta_j)
    np.testing.assert_array_equal(e.int_a, fresh.int_a)
    np.testing.assert_array_equal(e.int_b, fresh.int_b)
    np.testing.assert_array_equal(e.int_w, fresh.int_w)
    ref = PairCutEngine(cm_small, eng.state.assign.copy(),
                        cache=False).solve_pair(0, 1)
    np.testing.assert_array_equal(sol[1], ref[1])


def test_cache_lru_eviction_under_tiny_budget(cm_small):
    """A starved byte budget forces evictions but never wrong results."""
    eng = PairCutEngine(cm_small, np.zeros(cm_small.graph.n, np.int64),
                        cache=True, cache_bytes=1)
    res = glad_s(cm_small, seed=2, sweep="batched", cache=True,
                 cache_bytes=1)
    ref = glad_s(cm_small, seed=2, sweep="batched", cache=False)
    assert res.cost == pytest.approx(ref.cost, rel=1e-12)
    np.testing.assert_array_equal(res.assign, ref.assign)
    assert eng.cache_stats()["bytes"] >= 0


def test_cache_auto_policy_follows_active_mask(cm_small):
    """'auto' enables the cache exactly for incremental (active-mask)
    workloads."""
    init = np.zeros(cm_small.graph.n, np.int64)
    cold = PairCutEngine(cm_small, init)
    assert not cold._cache_on
    act = np.zeros(cm_small.graph.n, bool)
    act[:20] = True
    warm = PairCutEngine(cm_small, init, active=act)
    assert warm._cache_on
    forced = PairCutEngine(cm_small, init, cache=False, active=act)
    assert not forced._cache_on


def test_auto_round_solver_matches_explicit(cm_small):
    """solver='auto' must produce the same sweep results as whichever
    concrete solver it resolves to (both produce identical proposals)."""
    rng = np.random.default_rng(9)
    init = rng.integers(0, cm_small.net.m, cm_small.graph.n).astype(np.int64)
    rounds = round_robin_rounds(cm_small.net.m)
    outs = {}
    for chunk in (0, 1):      # 1 forces the 'pairwise' side of the policy
        eng = PairCutEngine(cm_small, init.copy(), chunk_nodes=chunk)
        for rnd in rounds:
            eng.sweep_round(rnd, solver="auto")
        outs[chunk] = (eng.state.total, eng.state.assign.copy())
    assert outs[0][0] == pytest.approx(outs[1][0], rel=1e-12)
    np.testing.assert_array_equal(outs[0][1], outs[1][1])


# ------------------------------------------------------- engine result shape
def test_glad_result_fields_preserved(cm_small):
    res = glad_s(cm_small, seed=0)
    assert set(res.factors) == {"C_U", "C_P", "C_T", "C_M", "total"}
    assert res.cost == pytest.approx(res.factors["total"], rel=1e-9)
    assert len(res.history) == res.iterations + 1
    assert res.accepted <= res.iterations
    assert res.wall_time_s >= 0.0


def test_unknown_engine_and_sweep_raise(cm_small):
    with pytest.raises(ValueError):
        glad_s(cm_small, seed=0, engine="nope")
    with pytest.raises(ValueError):
        glad_s(cm_small, seed=0, sweep="nope")
