"""Offline stand-in for the slice of the `hypothesis` API this suite uses.

The property tests only need ``@settings(max_examples=N, deadline=None)``,
``@given(st.integers(lo, hi))`` and ``strategies as st``.  When the real
hypothesis package is unavailable (air-gapped CI), ``install()`` registers a
minimal deterministic replacement under ``sys.modules['hypothesis']`` so the
five property-test modules collect and run: each ``@given`` test is executed
``max_examples`` times with values drawn from a per-test seeded RNG, so runs
are reproducible (no shrinking, no database).
"""
from __future__ import annotations

import functools
import sys
import types
import zlib

import numpy as np


class _IntegersStrategy:
    def __init__(self, min_value: int, max_value: int):
        self.min_value = int(min_value)
        self.max_value = int(max_value)

    def example(self, rng: np.random.Generator) -> int:
        return int(rng.integers(self.min_value, self.max_value + 1))


def integers(min_value: int, max_value: int) -> _IntegersStrategy:
    return _IntegersStrategy(min_value, max_value)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", 20)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                try:
                    fn(*[s.example(rng) for s in strategies])
                except _Unsatisfied:
                    continue          # assume() failed: discard the example

        # pytest resolves fixture parameters from the *wrapped* signature via
        # __wrapped__; drop it so the strategy-supplied arguments are not
        # mistaken for fixtures.
        del wrapper.__wrapped__
        return wrapper

    return deco


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied("assumption failed")
    return True


class _Unsatisfied(Exception):
    pass


def install() -> None:
    """Register the fallback as ``hypothesis`` / ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = st_mod
    mod.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
