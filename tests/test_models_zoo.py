"""Per-arch smoke tests (deliverable f): REDUCED configs of every assigned
architecture run one forward + one train step on CPU; output shapes + no
NaNs.  Decode==forward consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models as zoo
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models.transformer import Dist, vocab_padded
from repro.train import OptConfig, init_opt_state, make_train_step


def _smoke(arch):
    return dataclasses.replace(get_smoke_config(arch), dtype=jnp.float32)


def _batch(cfg, B=2, L=16, seed=0):
    kq, kl = jax.random.split(jax.random.PRNGKey(seed))
    b = {"tokens": jax.random.randint(kq, (B, L), 0, cfg.vocab),
         "labels": jax.random.randint(kl, (B, L), 0, cfg.vocab)}
    if cfg.family == "encdec":
        b["frames"] = jnp.ones((B, cfg.frontend_len, cfg.frontend_dim),
                               jnp.float32)
    if cfg.family == "vlm":
        b["patches"] = jnp.ones((B, cfg.frontend_len, cfg.frontend_dim),
                                jnp.float32)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_shapes_no_nan(arch):
    cfg = _smoke(arch)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = zoo.forward(cfg, params, batch)
    L_expect = 16 + (cfg.frontend_len if cfg.family == "vlm" else 0)
    assert logits.shape == (2, L_expect, vocab_padded(cfg))
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = _smoke(arch)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptConfig(name=cfg.optimizer, lr=1e-2)
    ostate = init_opt_state(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, Dist(), opt_cfg))
    batch = _batch(cfg)
    l0 = None
    for s in range(3):
        params, ostate, _, m = step(params, ostate, None, batch)
        assert np.isfinite(float(m["loss"])), arch
        l0 = float(m["loss"]) if l0 is None else l0
    assert float(m["loss"]) < l0, f"{arch}: loss did not decrease"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-moe-16b",
                                  "zamba2-1.2b", "xlstm-1.3b",
                                  "seamless-m4t-medium", "internvl2-2b",
                                  "qwen2.5-32b"])
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) + decode_step(next) == forward(prompt+next)[-1]."""
    cfg = _smoke(arch)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    pb = {"tokens": toks[:, :8]}
    fb = {"tokens": toks[:, :9]}
    if cfg.family == "encdec":
        frames = jnp.ones((2, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
        pb["frames"] = frames
        fb["frames"] = frames
    if cfg.family == "vlm":
        patches = jnp.ones((2, cfg.frontend_len, cfg.frontend_dim),
                           jnp.float32)
        pb["patches"] = patches
        fb["patches"] = patches
    # max_len must cover prompt (+ patch positions for vlm) + new tokens.
    max_len = 16 + (cfg.frontend_len if cfg.family == "vlm" else 0)
    lg_pf, cache = zoo.prefill(cfg, params, pb, max_len=max_len)
    lg_dec, cache = zoo.decode_step(cfg, params, toks[:, 8:9], cache)
    full, _ = zoo.forward(cfg, params, fb)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyper-parameters."""
    spec = {
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.vocab == V, arch
        if cfg.n_experts:
            assert cfg.expert_d_ff == ff, arch
        else:
            assert cfg.d_ff == ff, arch
    # MoE structure
    ds = get_config("deepseek-moe-16b")
    assert (ds.n_experts, ds.top_k, ds.n_shared_experts) == (64, 6, 2)
    k2 = get_config("kimi-k2-1t-a32b")
    assert (k2.n_experts, k2.top_k) == (384, 8)
    # Param-count sanity vs the model names.
    assert 0.9e9 < get_config("llama3.2-1b").params_count() < 1.6e9
    assert 30e9 < get_config("qwen2.5-32b").params_count() < 36e9
    assert 0.9e12 < k2.params_count() < 1.15e12


def test_moe_sharded_equals_dense_ref_subprocess_free():
    """moe_ffn (1x1 mesh) == moe_ffn_dense_ref on the same inputs."""
    from repro.models.moe import moe_ffn, moe_ffn_dense_ref
    from repro.models.common import LMConfig
    cfg = LMConfig(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                   n_kv_heads=2, d_ff=0, vocab=64, n_experts=4, top_k=2,
                   expert_d_ff=8, capacity_factor=4.0, dtype=jnp.float32)
    k = jax.random.split(jax.random.PRNGKey(0), 4)
    p = {"router": jax.random.normal(k[0], (16, 4)) * 0.1,
         "w13": jax.random.normal(k[1], (4, 16, 16)) * 0.1,
         "w2": jax.random.normal(k[2], (4, 8, 16)) * 0.1}
    x = jax.random.normal(k[3], (2, 6, 16))
    from repro.jaxcompat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    ref, _ = moe_ffn_dense_ref(cfg, p, x)
    out, _ = jax.jit(lambda p, x: moe_ffn(cfg, p, x, mesh, ("data",)))(p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
