"""Serving engine: continuous batching correctness — ragged batched decode
must produce the SAME tokens as each request served alone."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models as zoo
from repro.configs import get_smoke_config
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"),
                              dtype=jnp.float32)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _solo_decode(cfg, params, prompt, n_new, max_len):
    eng = ServeEngine(cfg, params, slots=1, max_len=max_len)
    req = Request(uid=0, prompt=prompt, max_new_tokens=n_new, eos_id=-1)
    eng.submit(req)
    eng.run()
    return req.out_tokens


def test_batched_equals_solo(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 400, size=rng.integers(3, 9)).astype(np.int32)
               for _ in range(5)]
    solo = [_solo_decode(cfg, params, p, 6, 64) for p in prompts]

    eng = ServeEngine(cfg, params, slots=2, max_len=64)   # forces queueing
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6, eos_id=-1)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert stats.completed == 5
    for r, s in zip(reqs, solo):
        assert r.out_tokens == s, f"request {r.uid} diverged"


def test_queue_respects_slots(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=np.array([1, 2, 3], np.int32),
                           max_new_tokens=4, eos_id=-1))
    eng.tick()
    live = sum(r is not None for r in eng.live)
    assert live <= 2
    eng.run()
    assert eng.stats.completed == 4


def test_engine_stops_at_max_len(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, max_len=12)
    eng.submit(Request(uid=0, prompt=np.array([5] * 8, np.int32),
                       max_new_tokens=100, eos_id=-1))
    eng.run(max_ticks=50)
    assert eng.stats.completed == 1          # hit the cache limit, freed


def test_eos_at_prefill_frees_slot_same_tick(model):
    """A request whose FIRST generated token is EOS must complete at
    insert time (no slot occupied, no decode), and the freed slot admits
    the next queued request in the same tick."""
    cfg, params = model
    prompt = np.array([7, 11, 13], np.int32)
    # Learn what the first generated token is from an EOS-free solo run.
    first_tok = _solo_decode(cfg, params, prompt, 1, 32)[0]

    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    hit = Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=first_tok)
    follow = Request(uid=1, prompt=np.array([1, 2, 3, 4], np.int32),
                     max_new_tokens=3, eos_id=-1)
    eng.submit(hit)
    eng.submit(follow)
    eng.tick()
    assert hit.done and hit.out_tokens == [first_tok]
    assert eng.live[0] is follow             # slot handed over same tick
    assert eng.stats.completed == 1
    # The same tick's decode already advanced the admitted request.
    assert int(np.asarray(eng.cache["len"])[0]) == len(follow.prompt) + 1
    assert len(follow.out_tokens) == 2       # prefill token + one decode
    eng.run()
    assert eng.stats.completed == 2 and follow.done


def test_one_token_budget_completes_at_prefill(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    req = Request(uid=0, prompt=np.array([3, 5, 9], np.int32),
                  max_new_tokens=1, eos_id=-1)
    eng.submit(req)
    eng.run(max_ticks=5)
    assert req.done and len(req.out_tokens) == 1
    assert eng.stats.completed == 1
    assert eng.stats.ticks == 0              # never needed a decode
    assert req.out_tokens == _solo_decode(cfg, params, req.prompt, 1, 32)


def test_prefill_retraces_bounded_by_buckets(model):
    """Prompt-length bucketing: many distinct lengths must trace only
    O(log max_len) prefill specializations, and batched outputs still
    match solo runs (pad positions are inert under causal attention)."""
    cfg, params = model
    rng = np.random.default_rng(1)
    lengths = list(range(3, 17))             # 14 distinct lengths
    prompts = [rng.integers(1, 400, size=ln).astype(np.int32)
               for ln in lengths]
    solo = [_solo_decode(cfg, params, p, 3, 64) for p in prompts]

    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3, eos_id=-1)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng.stats.completed == len(prompts)
    buckets = {max(1 << (ln - 1).bit_length(), 1) for ln in lengths}
    assert eng.trace_counts["prefill"] <= len(buckets)   # 4/8/16 -> 3
    assert eng.trace_counts["decode"] == 1
    for r, s in zip(reqs, solo):
        assert r.out_tokens == s, f"request {r.uid} diverged"
