"""Serving engine: continuous batching correctness — ragged batched decode
must produce the SAME tokens as each request served alone."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models as zoo
from repro.configs import get_smoke_config
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"),
                              dtype=jnp.float32)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _solo_decode(cfg, params, prompt, n_new, max_len):
    eng = ServeEngine(cfg, params, slots=1, max_len=max_len)
    req = Request(uid=0, prompt=prompt, max_new_tokens=n_new, eos_id=-1)
    eng.submit(req)
    eng.run()
    return req.out_tokens


def test_batched_equals_solo(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 400, size=rng.integers(3, 9)).astype(np.int32)
               for _ in range(5)]
    solo = [_solo_decode(cfg, params, p, 6, 64) for p in prompts]

    eng = ServeEngine(cfg, params, slots=2, max_len=64)   # forces queueing
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6, eos_id=-1)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert stats.completed == 5
    for r, s in zip(reqs, solo):
        assert r.out_tokens == s, f"request {r.uid} diverged"


def test_queue_respects_slots(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=np.array([1, 2, 3], np.int32),
                           max_new_tokens=4, eos_id=-1))
    eng.tick()
    live = sum(r is not None for r in eng.live)
    assert live <= 2
    eng.run()
    assert eng.stats.completed == 4


def test_engine_stops_at_max_len(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, max_len=12)
    eng.submit(Request(uid=0, prompt=np.array([5] * 8, np.int32),
                       max_new_tokens=100, eos_id=-1))
    eng.run(max_ticks=50)
    assert eng.stats.completed == 1          # hit the cache limit, freed
