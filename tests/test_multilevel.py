"""Multilevel GLAD V-cycle (heavy-edge coarsening + per-level refinement).

The load-bearing property is EXACTNESS of the hierarchy: the coarse
objective of any coarse assignment equals the fine objective of its
projection, because intra-cluster links cost tau[i,i] = 0, inter-cluster
edge weights sum, and the coarse unary matrix is the row-sum of the fine
one.  Everything else (matching validity, capacity caps, determinism,
restriction/projection, boundary masks, engine dispatch, the glad_e
escalation) guards the plumbing around that invariant.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, workload_for
from repro.core.engine import PairCutEngine
from repro.core.glad_s import glad_s
from repro.core.multilevel import (
    boundary_active,
    build_levels,
    clusters_from_matching,
    glad_multilevel,
    heavy_edge_matching,
    quantize_weights,
    restrict_assign,
)
from repro.graphs.datagraph import DataGraph, contract_graph, synthetic_siot
from repro.graphs.edgenet import build_edge_network
from tests.conftest import random_graph


def _cm(rng, n, m, extra_edges=None, mu_factor=2.0, seed=0):
    """Nontrivial instance: mu_factor large enough that the optimum uses
    several servers (the build_edge_network default collapses to one at
    small n, which makes refinement vacuous)."""
    g = random_graph(rng, n, n if extra_edges is None else extra_edges)
    net = build_edge_network(g, m, seed=seed, mu_factor=mu_factor)
    return CostModel(net, g, workload_for("gcn", 8))


# ---------------------------------------------------------------- exactness

@settings(max_examples=12, deadline=None)
@given(st.integers(0, 5000))
def test_coarse_cost_equals_projected_fine_cost(seed):
    """For EVERY adjacent level pair and random coarse assignment: the
    coarse total equals the fine total of the projection (tight rtol —
    only float summation order may differ)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(30, 120))
    cm = _cm(rng, n, int(rng.integers(2, 5)), seed=seed)
    stack = build_levels(cm, coarsen_to=max(4, n // 6))
    assert len(stack) >= 2, "instance failed to coarsen at all"
    for fine, coarse in zip(stack[:-1], stack[1:]):
        nc = coarse.cm.graph.n
        for _ in range(3):
            a_c = rng.integers(0, cm.net.m, size=nc).astype(np.int64)
            a_f = a_c[coarse.cluster_of]
            assert coarse.cm.total(a_c) == pytest.approx(
                fine.cm.total(a_f), rel=1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000))
def test_coarsening_respects_capacity_and_partition(seed):
    """vertex_w is a partition of the fine vertices (sums preserved) and
    every cluster respects the matcher's capacity cap."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 150))
    cm = _cm(rng, n, 3, seed=seed)
    coarsen_to = max(4, n // 8)
    stack = build_levels(cm, coarsen_to=coarsen_to)
    from repro.core.multilevel import MAX_CLUSTER_FACTOR
    cap = max(2, int(np.ceil(MAX_CLUSTER_FACTOR * n / coarsen_to)))
    for lvl in stack:
        assert int(lvl.vertex_w.sum()) == n
        assert lvl.vertex_w.max() <= cap
        if lvl.cluster_of is not None:
            assert lvl.cluster_of.min() >= 0
            assert lvl.cluster_of.max() == lvl.cm.graph.n - 1


# ----------------------------------------------------- matching / contraction

def test_matching_is_valid_involution_between_neighbors():
    rng = np.random.default_rng(7)
    g = random_graph(rng, 80, 120)
    vw = np.ones(g.n, dtype=np.int64)
    match = heavy_edge_matching(g, vw, max_w=2)
    np.testing.assert_array_equal(match[match], np.arange(g.n))
    nbrs = {tuple(e) for e in g.edges} | {tuple(e[::-1]) for e in g.edges}
    paired = np.flatnonzero(match != np.arange(g.n))
    assert len(paired) > 0
    for v in paired:
        assert (int(v), int(match[v])) in nbrs


def test_matching_capacity_gate_blocks_overweight_pairs():
    g = DataGraph(n=4, edges=np.array([[0, 1], [1, 2], [2, 3]]))
    vw = np.array([3, 1, 1, 3], dtype=np.int64)
    match = heavy_edge_matching(g, vw, max_w=2)
    # Only 1-2 fits under the cap; 0 and 3 must stay singletons.
    assert match[0] == 0 and match[3] == 3
    assert match[1] == 2 and match[2] == 1


def test_matching_prefers_heavy_edges():
    g = DataGraph(n=4, edges=np.array([[0, 1], [1, 2], [2, 3]]))
    g.edge_weights = np.array([1.0, 50.0, 1.0])
    match = heavy_edge_matching(g, np.ones(4, np.int64), max_w=2)
    assert match[1] == 2 and match[2] == 1


def test_quantize_weights_scale_invariant():
    w = np.array([1.0, 2.0, 4.0])
    np.testing.assert_array_equal(quantize_weights(w),
                                  quantize_weights(w * 1e-6))
    assert quantize_weights(np.zeros(3)).dtype == np.int64


def test_contract_graph_sums_parallel_edge_weights():
    g = DataGraph(n=4, edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]]))
    g.edge_weights = np.array([5.0, 1.0, 2.0, 3.0])
    # clusters {0,1} and {2,3}: intra edges 0-1 (w=5) and 2-3 (w=3) vanish;
    # 0-2 (1) and 1-3 (2) become one coarse link of weight 3.
    cluster_of = np.array([0, 0, 1, 1])
    gc = contract_graph(g, cluster_of, 2)
    assert gc.n == 2 and gc.num_edges == 1
    np.testing.assert_array_equal(gc.edges, [[0, 1]])
    np.testing.assert_allclose(gc.edge_weights, [3.0])


def test_clusters_from_matching_orders_by_smallest_member():
    match = np.array([2, 1, 0, 4, 3])
    cluster_of, nc = clusters_from_matching(match)
    assert nc == 3
    np.testing.assert_array_equal(cluster_of, [0, 1, 0, 2, 2])


def test_coarsening_is_deterministic():
    rng = np.random.default_rng(11)
    cm = _cm(rng, 200, 4)
    s1 = build_levels(cm, coarsen_to=16)
    s2 = build_levels(cm, coarsen_to=16)
    assert len(s1) == len(s2)
    for a, b in zip(s1[1:], s2[1:]):
        np.testing.assert_array_equal(a.cluster_of, b.cluster_of)
        np.testing.assert_array_equal(a.cm.graph.edges, b.cm.graph.edges)


# ------------------------------------------------- restriction / projection

def test_restrict_assign_majority_vote_ties_to_smallest():
    cluster_of = np.array([0, 0, 0, 1, 1])
    assign = np.array([2, 2, 1, 3, 0])
    out = restrict_assign(cluster_of, 2, assign, m=4)
    np.testing.assert_array_equal(out, [2, 0])   # tie 0-vs-3 -> 0


def test_boundary_active_marks_cut_endpoints_and_rings():
    g = DataGraph(n=5, edges=np.array([[0, 1], [1, 2], [2, 3], [3, 4]]))
    assign = np.array([0, 0, 1, 1, 1])
    act0 = boundary_active(g, assign, hops=0)
    np.testing.assert_array_equal(act0, [False, True, True, False, False])
    act1 = boundary_active(g, assign, hops=1)
    np.testing.assert_array_equal(act1, [True, True, True, True, False])
    uncut = boundary_active(g, np.zeros(5, np.int64), hops=1)
    assert not uncut.any()


# --------------------------------------------------------------- the V-cycle

def test_vcycle_matches_flat_quality_and_reports_levels():
    rng = np.random.default_rng(0)
    g = synthetic_siot(n=2000, target_links=8400, seed=0)
    net = build_edge_network(g, 8, seed=0, mu_factor=2.0)
    cm = CostModel(net, g, workload_for("gcn", 52))
    flat = glad_s(cm, seed=0, sweep="batched")
    ml = glad_s(cm, seed=0, sweep="batched", multilevel=True, coarsen_to=256)
    assert ml.cost <= flat.cost * 1.05
    assert ml.cost == pytest.approx(cm.total(ml.assign), rel=1e-12)
    assert ml.levels is not None and len(ml.levels) >= 2
    assert ml.levels[0]["role"] == "coarsest"
    assert ml.levels[-1]["level"] == 0
    assert ml.iterations == sum(ls["iterations"] for ls in ml.levels)
    # moved covers every vertex whose final placement differs from init
    # (init None -> all vertices reported).
    assert len(ml.moved) == g.n


def test_finest_refinement_bit_identical_to_flat_replay():
    """The finest refinement IS a flat glad_s call: replaying it from the
    recorded projected init + boundary mask must reproduce the history
    hex-for-hex and the assignment exactly."""
    rng = np.random.default_rng(3)
    g = synthetic_siot(n=1500, target_links=6300, seed=3)
    net = build_edge_network(g, 8, seed=3, mu_factor=2.0)
    cm = CostModel(net, g, workload_for("gcn", 52))
    ml = glad_s(cm, seed=3, sweep="batched", multilevel=True, coarsen_to=128)
    finest = ml.levels[-1]
    assert finest["level"] == 0 and finest["role"] == "refine"
    assert finest["active"].any(), "instance must exercise real refinement"
    replay = glad_s(cm, R=finest["R"], init=finest["init"],
                    active=finest["active"], seed=3, sweep="batched")
    assert ([np.float64(h).hex() for h in replay.history]
            == [np.float64(h).hex() for h in finest["history"]])
    np.testing.assert_array_equal(replay.assign, ml.assign)
    # The level's recorded cost is the engine's incremental total — bit
    # comparable; ml.cost is recomputed from factors (summation order may
    # differ by a ulp) so it only gets a tight approx.
    assert np.float64(replay.cost).hex() == np.float64(finest["cost"]).hex()
    assert ml.cost == pytest.approx(replay.cost, rel=1e-12)


def test_vcycle_warm_init_restricts_down_the_stack():
    rng = np.random.default_rng(5)
    cm = _cm(rng, 300, 4)
    init = rng.integers(0, 4, size=300).astype(np.int64)
    ml = glad_multilevel(cm, init=init, seed=5, coarsen_to=32)
    flat_from_init = glad_s(cm, init=init, seed=5, sweep="batched")
    assert ml.cost <= flat_from_init.cost * 1.05
    moved_set = set(ml.moved.tolist())
    diff = set(np.flatnonzero(ml.assign != init).tolist())
    assert diff == moved_set


def test_vcycle_tiny_graph_degenerates_to_flat():
    rng = np.random.default_rng(9)
    cm = _cm(rng, 20, 3)
    ml = glad_s(cm, seed=1, sweep="batched", multilevel=True,
                coarsen_to=1024)
    flat = glad_s(cm, seed=1, sweep="batched")
    np.testing.assert_array_equal(ml.assign, flat.assign)
    assert len(ml.levels) == 1 and ml.levels[0]["role"] == "coarsest"


def test_vcycle_levels_knob_caps_stack_depth():
    rng = np.random.default_rng(13)
    cm = _cm(rng, 400, 4)
    ml = glad_s(cm, seed=0, sweep="batched", multilevel=True, coarsen_to=8,
                levels=2)
    # levels=2 -> one coarsening rung -> coarsest + exactly one refinement.
    assert len(ml.levels) == 2


# ----------------------------------------------------------------- dispatch

def test_multilevel_rejects_reference_engine_and_active_mask(cm_small):
    with pytest.raises(ValueError, match="multilevel"):
        glad_s(cm_small, multilevel=True, engine="reference")
    act = np.zeros(cm_small.graph.n, dtype=bool)
    act[:5] = True
    with pytest.raises(ValueError, match="multilevel"):
        glad_s(cm_small, multilevel=True, active=act)


def test_multilevel_auto_threshold(cm_small, monkeypatch):
    import repro.core.multilevel as mlmod
    calls = []
    real = mlmod.glad_multilevel

    def spy(cm, **kw):
        calls.append(cm.graph.n)
        return real(cm, **kw)

    monkeypatch.setattr(mlmod, "glad_multilevel", spy)
    monkeypatch.setattr("repro.core.glad_s.glad_multilevel", spy,
                        raising=False)
    # Below the auto threshold: 'auto' must stay flat.
    glad_s(cm_small, seed=0, sweep="batched", multilevel="auto")
    assert calls == []
    monkeypatch.setattr(mlmod, "MULTILEVEL_AUTO_MIN_N", 10)
    glad_s(cm_small, seed=0, sweep="batched", multilevel="auto")
    assert calls == [cm_small.graph.n]


def test_glad_e_escalation_routes_through_vcycle():
    from repro.core.glad_e import glad_e
    from repro.core.evolution import apply_delta, sample_delta
    gnn = workload_for("gcn", 16)
    g0 = synthetic_siot(n=400, target_links=1680, seed=2)
    net0 = build_edge_network(g0, 4, seed=2, mu_factor=2.0)
    cm0 = CostModel(net0, g0, gnn)
    base = glad_s(cm0, seed=2, sweep="batched")
    delta = sample_delta(g0, pct_links=0.2, pct_vertices=0.05, seed=2)
    g1 = apply_delta(g0, delta)
    net1 = build_edge_network(g1, 4, seed=2, mu_factor=2.0)
    net1.mu = net1.mu[:g1.n]
    cm1 = CostModel(net1, g1, gnn)
    esc = glad_e(cm1, g0, base.assign, seed=2, multilevel=True,
                 coarsen_to=64)
    assert esc.levels is not None   # escalated solves carry level stats
    flat = glad_e(cm1, g0, base.assign, seed=2)
    assert esc.cost <= flat.cost * 1.05


# --------------------------------- AssemblyCache pair-frequency admission

def test_admission_gates_cold_pairs_under_pressure(cm_small):
    """Under budget pressure a first-touch pair is assembled but NOT
    admitted (no eviction churn); displacement needs a lead of TWO over
    the LRU victim (one would be indistinguishable from cyclic-scan phase
    skew — see PairCutEngine._admit)."""
    rng = np.random.default_rng(4)
    init = rng.integers(0, cm_small.net.m, size=cm_small.graph.n)
    eng = PairCutEngine(cm_small, init.astype(np.int64), cache=True)
    assert eng.solve_pair(0, 1) is not None     # fills the (empty) cache
    e01 = eng._cache[(0, 1)]
    eng._cache_bytes = eng._cache_used          # now: zero headroom
    assert eng.solve_pair(2, 3) is not None     # first touch -> rejected
    st_ = eng.cache_stats()
    assert st_["rejected"] == 1 and st_["evictions"] == 0
    assert (2, 3) not in eng._cache
    assert eng._cache[(0, 1)] is e01            # resident entry untouched
    # Second touch: lead of 1 over resident (0,1) — still phase-skew
    # territory, still rejected.
    assert eng.solve_pair(2, 3) is not None
    assert (2, 3) not in eng._cache
    assert eng.cache_stats()["evictions"] == 0
    # Third touch: lead of 2 -> genuinely hotter, displaces the resident.
    assert eng.solve_pair(2, 3) is not None
    assert (2, 3) in eng._cache
    assert eng.cache_stats()["evictions"] >= 1


def test_admission_uniform_scan_freezes_resident_set(cm_small):
    """A uniform scan over more pairs than fit must stop thrashing: after
    the warmup pass, evictions stay flat while hits keep accruing."""
    rng = np.random.default_rng(8)
    m = cm_small.net.m
    init = rng.integers(0, m, size=cm_small.graph.n).astype(np.int64)
    pairs = [(i, j) for i in range(m) for j in range(i + 1, m)]
    eng = PairCutEngine(cm_small, init.copy(), cache=True)
    for p in pairs:                             # size the budget to ~2 pairs
        eng.solve_pair(*p)
    budget = max(e.nbytes for e in eng._cache.values()) * 2
    eng = PairCutEngine(cm_small, init.copy(), cache=True,
                        cache_bytes=budget)
    for _ in range(2):
        for p in pairs:
            eng.solve_pair(*p)
    ev_warm = eng.cache_stats()["evictions"]
    for _ in range(3):
        for p in pairs:
            eng.solve_pair(*p)
    st_ = eng.cache_stats()
    assert st_["evictions"] == ev_warm          # admission froze the set
    assert st_["rejected"] > 0
    assert st_["hits"] + st_["patched"] > 0     # residents keep serving


@pytest.mark.parametrize("budget", [1, 64 << 10])
def test_admission_never_changes_trajectories(cm_small, budget):
    """Admission decides WHICH assemblies are retained, never their
    content: starved-budget runs stay bit-identical to cache-free ones."""
    act = np.zeros(cm_small.graph.n, dtype=bool)
    act[: cm_small.graph.n // 2] = True
    init = np.arange(cm_small.graph.n, dtype=np.int64) % cm_small.net.m
    kw = dict(R=6, init=init, active=act, seed=3, sweep="batched")
    res = glad_s(cm_small, cache=True, cache_bytes=budget, **kw)
    ref = glad_s(cm_small, cache=False, **kw)
    assert ([np.float64(a).hex() for a in res.history]
            == [np.float64(b).hex() for b in ref.history])
    np.testing.assert_array_equal(res.assign, ref.assign)
