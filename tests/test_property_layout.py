"""Property-based correctness harness for the layout engine.

Two invariant families (hypothesis; offline the `_hypothesis_fallback`
shim supplies a deterministic replacement):

  * the delta-accept path — ``LayoutState``'s cached total after any random
    sequence of delta/propose/commit/discard operations equals a fresh
    ``CostModel.total()`` recompute (the engine never drifts from the true
    objective);
  * the block-diagonal round solver — one batch-assembled
    ``_solve_round_blocks`` call over a round of disjoint server pairs
    induces, per pair, a proposal whose objective equals the per-pair
    ``solve_pair`` solve (ties may flip members; cost may not).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, workload_for
from repro.core.engine import PairCutEngine, round_robin_rounds
from repro.core.glad_s import glad_s
from repro.graphs.edgenet import build_edge_network
from tests.conftest import random_graph


def _instance(rng, weighted=False):
    n = int(rng.integers(8, 40))
    m = int(rng.integers(2, 7))
    g = random_graph(rng, n, int(rng.integers(4, 30)))
    if weighted:
        g.edge_weights = rng.uniform(0.2, 3.0, size=len(g.edges))
    net = build_edge_network(g, m, seed=int(rng.integers(0, 1000)))
    return CostModel(net, g, workload_for("gcn", 8)), g, net


# --------------------------------------------------- delta == full recompute
def _random_move_sequence(seed, n_ops):
    """Drive a LayoutState through a random op sequence, checking the cached
    total against a from-scratch CostModel.total() after every mutation."""
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng, weighted=bool(seed % 2))
    state = cm.layout_state(rng.integers(0, net.m, size=g.n))
    assert state.total == pytest.approx(cm.total(state.assign), rel=1e-12)
    for _ in range(n_ops):
        k = int(rng.integers(1, max(2, g.n // 2)))
        moved = rng.choice(g.n, size=k, replace=False)
        new = rng.integers(0, net.m, size=k)
        prop = state.assign.copy()
        prop[moved] = new
        expect_delta = cm.total(prop) - cm.total(state.assign)
        op = int(rng.integers(0, 4))
        if op == 0:                                    # read-only delta
            assert state.delta(moved, new) == pytest.approx(
                expect_delta, abs=1e-8)
        elif op == 1:                                  # direct commit
            state.commit(moved, new)
            np.testing.assert_array_equal(state.assign, prop)
        elif op == 2:                                  # propose -> accept
            d = state.propose(moved, new)
            assert d == pytest.approx(expect_delta, abs=1e-8)
            state.commit_pending()
            np.testing.assert_array_equal(state.assign, prop)
        else:                                          # propose -> reject
            state.propose(moved, new)
            state.discard_pending()
            with pytest.raises(RuntimeError):
                state.commit_pending()
        assert state.total == pytest.approx(cm.total(state.assign), abs=1e-7)
    # Closing invariant: cached components still reconcile exactly.
    assert state.total == pytest.approx(
        state.unary_pick.sum() + state.edge_ct.sum() + cm.constant, abs=1e-9)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 50_000))
def test_delta_accept_equals_recompute_over_move_sequences(seed):
    _random_move_sequence(seed, n_ops=12)


@pytest.mark.slow
@settings(max_examples=150, deadline=None)
@given(st.integers(0, 1_000_000))
def test_delta_accept_equals_recompute_fuzz(seed):
    """Heavier on-demand version (-m slow): longer sequences, more seeds."""
    _random_move_sequence(seed, n_ops=40)


# ----------------------------------------- block round solve == pair solves
def _check_round_blocks_match_pair_solves(seed):
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng, weighted=bool(seed % 3 == 0))
    assign = rng.integers(0, net.m, size=g.n)
    rounds = round_robin_rounds(net.m)
    rnd = rounds[int(rng.integers(0, len(rounds)))]
    if not rnd:
        return
    eng = PairCutEngine(cm, assign)
    batch = eng._solve_round_blocks(rnd)
    assert len(batch) == len(rnd)
    for (i, j), sol in zip(rnd, batch):
        ref = eng.solve_pair(int(i), int(j))
        assert (ref is None) == (sol is None)
        if sol is None:
            continue
        members, proposed = sol
        ref_members, ref_proposed = ref
        np.testing.assert_array_equal(members, ref_members)
        # Cuts may tie differently (block-global integer scaling); the
        # induced objective must agree exactly.
        a1, a2 = assign.copy(), assign.copy()
        a1[members] = proposed
        a2[ref_members] = ref_proposed
        assert cm.total(a1) == pytest.approx(cm.total(a2), rel=1e-9), (i, j)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 50_000))
def test_block_round_solve_matches_pair_solves(seed):
    _check_round_blocks_match_pair_solves(seed)


@pytest.mark.slow
@settings(max_examples=150, deadline=None)
@given(st.integers(0, 1_000_000))
def test_block_round_solve_matches_pair_solves_fuzz(seed):
    _check_round_blocks_match_pair_solves(seed)


# ------------------------------------- cache invalidation across mutations
def _hex_history(res):
    return [np.float64(h).hex() for h in res.history]


def _check_cache_invariant_under_evolution(seed):
    """Interleaved GLAD rounds with the AssemblyCache enabled/disabled must
    produce IDENTICAL accepted-move sequences (bit-for-bit histories and
    final assignments), before and after random ``evolution.sample_delta``
    mutations — i.e. epochs/patching never serve a stale assembly."""
    from repro.core.evolution import apply_delta, sample_delta
    from repro.core.glad_e import glad_e

    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng, weighted=bool(seed % 2))
    sweep = ("single", "batched")[seed % 2]
    rs = ("pairwise", "block")[(seed // 2) % 2]
    on = glad_s(cm, seed=seed, sweep=sweep, round_solver=rs, cache=True)
    off = glad_s(cm, seed=seed, sweep=sweep, round_solver=rs, cache=False)
    assert _hex_history(on) == _hex_history(off)
    np.testing.assert_array_equal(on.assign, off.assign)

    # Evolve the graph and re-layout incrementally (the active-mask path —
    # what cache='auto' enables): still identical with cache forced on/off.
    delta = sample_delta(g, pct_links=0.15, pct_vertices=0.05,
                         seed=seed + 17)
    g2 = apply_delta(g, delta)
    cm2 = CostModel(net, g2, cm.gnn)
    e_on = glad_e(cm2, g, on.assign, seed=seed, cache=True)
    e_off = glad_e(cm2, g, on.assign, seed=seed, cache=False)
    assert _hex_history(e_on) == _hex_history(e_off)
    np.testing.assert_array_equal(e_on.assign, e_off.assign)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 50_000))
def test_cache_identical_accept_sequences_under_evolution(seed):
    _check_cache_invariant_under_evolution(seed)


@pytest.mark.slow
@settings(max_examples=120, deadline=None)
@given(st.integers(0, 1_000_000))
def test_cache_identical_accept_sequences_under_evolution_fuzz(seed):
    """Heavier on-demand version (-m slow)."""
    _check_cache_invariant_under_evolution(seed)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50_000))
def test_block_round_respects_active_mask(seed):
    """Frozen vertices never appear in any block's member set."""
    rng = np.random.default_rng(seed)
    cm, g, net = _instance(rng)
    assign = rng.integers(0, net.m, size=g.n)
    active = rng.uniform(size=g.n) < 0.5
    eng = PairCutEngine(cm, assign, active=active)
    rnd = round_robin_rounds(net.m)[0]
    for sol in eng._solve_round_blocks(rnd):
        if sol is None:
            continue
        members, _ = sol
        assert active[members].all()
