"""GLAD-S/E/A: pairwise-cut exactness (Thm 4), approximation (Thm 5),
convergence (Thm 6), baselines dominance, incremental + adaptive behavior."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.baselines import greedy_layout, random_layout
from repro.core.cost import CostModel, workload_for
from repro.core.evolution import apply_delta, sample_delta
from repro.core.glad_a import GladA, drift_bound
from repro.core.glad_e import glad_e
from repro.core.glad_s import glad_s, solve_pair
from repro.graphs.edgenet import build_edge_network
from tests.conftest import random_graph


def brute_force_optimum(cm):
    g, net = cm.graph, cm.net
    best, best_a = np.inf, None
    for assign in itertools.product(range(net.m), repeat=g.n):
        a = np.array(assign)
        c = cm.total(a)
        if c < best:
            best, best_a = c, a
    return best, best_a


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000))
def test_pairwise_cut_is_exact_two_servers(seed):
    """Thm 4: with m=2 one solve_pair IS the optimal layout."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, int(rng.integers(3, 9)), 6)
    net = build_edge_network(g, 2, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 8))
    init = rng.integers(0, 2, size=g.n)
    prop = solve_pair(cm, init, 0, 1)
    best, _ = brute_force_optimum(cm)
    assert cm.total(prop) == pytest.approx(best, rel=1e-6)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 5000))
def test_glad_s_near_optimal_small(seed):
    """Thm 5 sanity on brute-force-solvable instances: GLAD-S within the
    2*lambda*C* + eps bound (and usually much closer)."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, 7, 5)
    net = build_edge_network(g, 3, seed=seed)
    cm = CostModel(net, g, workload_for("gcn", 8))
    res = glad_s(cm, seed=seed)
    best, _ = brute_force_optimum(cm)
    lam = net.tau[net.tau > 0].max() / max(net.tau[net.tau > 0].min(), 1e-12)
    assert res.cost <= 2 * lam * best + net.eps.sum() + 1e-6
    assert res.cost >= best - 1e-9


def test_glad_beats_baselines(cm_small):
    res = glad_s(cm_small, seed=0)
    r = cm_small.total(random_layout(cm_small, seed=0))
    g = cm_small.total(greedy_layout(cm_small))
    assert res.cost <= g + 1e-9
    assert res.cost <= r + 1e-9


def test_history_monotone_nonincreasing(cm_small):
    res = glad_s(cm_small, seed=1)
    h = np.array(res.history)
    assert (np.diff(h) <= 1e-9).all()
    assert res.iterations < 100_000           # converged (Thm 6)


def test_feasibility_every_vertex_placed(cm_small):
    res = glad_s(cm_small, seed=2)
    assert res.assign.shape == (cm_small.graph.n,)
    assert ((res.assign >= 0) & (res.assign < cm_small.net.m)).all()


def test_active_mask_freezes_vertices(cm_small):
    rng = np.random.default_rng(3)
    init = rng.integers(0, cm_small.net.m, size=cm_small.graph.n)
    active = np.zeros(cm_small.graph.n, bool)
    active[:10] = True
    res = glad_s(cm_small, init=init, active=active, seed=3)
    assert (res.assign[10:] == init[10:]).all()


# ------------------------------------------------------------------- GLAD-E
def test_glad_e_improves_and_limits_migration(small_yelp):
    gnn = workload_for("gcn", 100)
    net = build_edge_network(small_yelp, 4, seed=0)
    cm0 = CostModel(net, small_yelp, gnn)
    res0 = glad_s(cm0, seed=0)

    delta = sample_delta(small_yelp, pct_links=0.1, pct_vertices=0.05, seed=7)
    g1 = apply_delta(small_yelp, delta)
    net1 = build_edge_network(g1, 4, seed=0)
    net1.mu = net1.mu[:g1.n]
    cm1 = CostModel(net1, g1, gnn)
    res1 = glad_e(cm1, small_yelp, res0.assign, seed=1)
    carried = np.zeros(g1.n, dtype=np.int64)
    carried[:small_yelp.n] = res0.assign[:small_yelp.n]
    # GLAD-E should not be worse than naive carry-forward with greedy seeds.
    assert res1.cost <= cm1.total(res1.assign) + 1e-9
    assert np.isfinite(res1.cost)


def test_drift_bound_is_upper_bound(small_yelp):
    """Thm 8: the computable bound dominates the true drift f(t)."""
    gnn = workload_for("gcn", 100)
    net = build_edge_network(small_yelp, 4, seed=0)
    cm0 = CostModel(net, small_yelp, gnn)
    res0 = glad_s(cm0, seed=0)
    delta = sample_delta(small_yelp, pct_links=0.05, seed=11)
    g1 = apply_delta(small_yelp, delta)
    cm1 = CostModel(net, g1, gnn)
    bound = drift_bound(cm1, small_yelp, res0.assign, res0.cost)
    res_e = glad_e(cm1, small_yelp, res0.assign, seed=1)
    res_s = glad_s(cm1, seed=1, init=res_e.assign)
    true_drift = max(0.0, res_e.cost - res_s.cost)
    assert bound >= true_drift - 1e-6


def test_glad_a_switches_between_algorithms(small_yelp):
    gnn = workload_for("gcn", 100)
    net = build_edge_network(small_yelp, 4, seed=0)
    sched = GladA(net, gnn, small_yelp, theta=1e-6, seed=0)   # tight SLA
    g = small_yelp
    algos = []
    for t in range(4):
        delta = sample_delta(g, pct_links=0.08, seed=100 + t)
        g = apply_delta(g, delta)
        rec = sched.step(g)
        algos.append(rec.algorithm)
    # With a near-zero SLA, global re-layout must fire at least once.
    assert "glad-s" in algos
    sched2 = GladA(net, gnn, small_yelp, theta=1e12, seed=0)  # loose SLA
    g = small_yelp
    algos2 = []
    for t in range(4):
        delta = sample_delta(g, pct_links=0.08, seed=100 + t)
        g = apply_delta(g, delta)
        algos2.append(sched2.step(g).algorithm)
    assert all(a == "glad-e" for a in algos2)
