"""Launch-layer units: HLO collective parser, roofline math, registry,
sharding-spec divisibility for every (arch x shape)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (ARCHS, all_cells, applicable_shapes, get_config,
                           input_specs)
from repro.launch.hlo import (_shape_bytes, model_flops_for,
                              parse_collectives, _wire_bytes)
from repro.models.common import SHAPES


def test_shape_bytes():
    assert _shape_bytes("bf16[4,128]{1,0}") == 4 * 128 * 2
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("(bf16[2,2], f32[3])") == 8 + 12
    assert _shape_bytes("s32[]") == 0 or _shape_bytes("s32[]") == 4


def test_parse_collectives_literal_groups():
    hlo = """
  %ag = bf16[32,2048]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar.1 = f32[128]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %cp = f32[64]{0} collective-permute(%y), source_target_pairs={{0,1}}
"""
    cs = parse_collectives(hlo)
    assert len(cs) == 3
    ag, ar, cp = cs
    assert ag.kind == "all-gather" and ag.group_size == 4
    assert ag.bytes_buffer == 32 * 2048 * 2
    assert ar.kind == "all-reduce" and ar.group_size == 2
    assert cp.wire_bytes == 64 * 4


def test_parse_collectives_iota_groups():
    hlo = "%ag = bf16[16,16]{1,0} all-gather(%p), replica_groups=[32,16]<=[512], dimensions={0}"
    (c,) = parse_collectives(hlo)
    assert c.group_size == 16


def test_wire_bytes_model():
    assert _wire_bytes("all-reduce", 100, 2) == pytest.approx(100.0)
    assert _wire_bytes("all-gather", 160, 16) == pytest.approx(150.0)
    assert _wire_bytes("reduce-scatter", 10, 16) == pytest.approx(150.0)
    assert _wire_bytes("all-reduce", 100, 1) == 0.0


def test_model_flops_accounting():
    cfg = get_config("llama3.2-1b")
    tr = model_flops_for(cfg, SHAPES["train_4k"])
    n = cfg.params_count()
    assert tr == pytest.approx(6.0 * n * 4096 * 256)
    # MoE: active params only.
    k2 = get_config("kimi-k2-1t-a32b")
    tr2 = model_flops_for(k2, SHAPES["train_4k"])
    assert tr2 < 6.0 * k2.params_count() * 4096 * 256 * 0.1   # ~32B active


def test_registry_cells_and_skips():
    cells = list(all_cells())
    assert len(cells) == 40
    skipped = [c for c in cells if c[2]]
    assert len(skipped) == 8                    # long_500k skips
    assert all(s == "long_500k" for _, s, r in skipped if r)
    assert "long_500k" in applicable_shapes("zamba2-1.2b")
    assert "long_500k" in applicable_shapes("xlstm-1.3b")
    assert "long_500k" not in applicable_shapes("llama3.2-1b")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_input_specs_are_abstract(arch):
    cfg = get_config(arch)
    for shape_name in applicable_shapes(arch):
        shape = SHAPES[shape_name]
        specs = input_specs(cfg, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        assert specs["tokens"].shape[0] == shape.global_batch


def _mesh_div_check(spec: P, shape, mesh_shape):
    """Every sharded dim must divide by the product of its axes."""
    sizes = {"pod": 2, "data": 16, "model": 16}
    for dim, names in zip(shape, tuple(spec) + (None,) * len(shape)):
        if names is None:
            continue
        ns = names if isinstance(names, tuple) else (names,)
        prod = 1
        for nm in ns:
            prod *= sizes[nm]
        assert dim % prod == 0, (spec, shape)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_spec_divisibility(arch):
    """Every parameter's PartitionSpec divides its dims on the 2x16x16 mesh
    — the static precondition for the multi-pod dry-run."""
    from repro import models as zoo
    from repro.models.transformer import Dist

    cfg = get_config(arch)
    dist = Dist(None, batch_axes=("pod", "data"))
    params_abs = jax.eval_shape(
        lambda: zoo.init_params(cfg, jax.random.PRNGKey(0)))
    specs = zoo.param_specs(cfg, dist)
    flat_p = jax.tree_util.tree_leaves_with_path(params_abs)
    flat_s = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for (pp, leaf), (sp, spec) in zip(flat_p, flat_s):
        assert jax.tree_util.keystr(pp) == jax.tree_util.keystr(sp)
        _mesh_div_check(spec, leaf.shape, (2, 16, 16))
