"""Deliverable (g) reader: render the dry-run artifacts into the roofline
table (EXPERIMENTS.md §Roofline source of truth)."""
from __future__ import annotations

import json
import os

HBM_PER_CHIP = 16 * 2**30      # v5e


def load(outdir="benchmarks/artifacts", mesh="pod16x16"):
    d = os.path.join(outdir, mesh)
    recs = []
    if not os.path.isdir(d):
        return recs
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                recs.append(json.load(f))
    return recs


def table(outdir="benchmarks/artifacts", mesh="pod16x16", markdown=False):
    rows = []
    for r in load(outdir, mesh):
        if r["status"] != "ok":
            rows.append([r["arch"], r["shape"], r["status"],
                         r.get("reason", r.get("error", ""))[:60],
                         "", "", "", "", "", ""])
            continue
        rf = r["roofline"]
        mem = r["memory"]["peak_estimate_bytes"]
        fits = "Y" if mem <= HBM_PER_CHIP else "OVER"
        rows.append([
            r["arch"], r["shape"], "ok", fits,
            f"{rf['compute_s']:.2e}", f"{rf['memory_s']:.2e}",
            f"{rf['collective_s']:.2e}", rf["bottleneck"],
            f"{rf['useful_ratio']:.3f}",
            f"{mem/2**30:.2f}",
        ])
    header = ["arch", "shape", "status", "fits16G", "compute_s", "memory_s",
              "collective_s", "bottleneck", "useful", "peak_GiB/dev"]
    if markdown:
        print("| " + " | ".join(header) + " |")
        print("|" + "---|" * len(header))
        for r in rows:
            print("| " + " | ".join(str(x) for x in r) + " |")
    else:
        print(",".join(header))
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


def run(full: bool = False):
    print("# mesh pod16x16 (exact probe-corrected terms — the §Roofline table)")
    table(mesh="pod16x16")
    print("# mesh pod2x16x16 (compile-proof sweep; cost columns UNCORRECTED "
          "for scan trip counts — see EXPERIMENTS.md §Roofline note 1)")
    table(mesh="pod2x16x16")
    return []


if __name__ == "__main__":
    import sys
    table(mesh=sys.argv[1] if len(sys.argv) > 1 else "pod16x16",
          markdown="--md" in sys.argv)
