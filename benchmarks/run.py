"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--smoke]
                                          [--check-parity]

``--smoke`` runs CI-sized sanity passes (the layout-engine benchmark at
quick sizes plus the plan-patch and serving cells, one repetition, written
to BENCH_layout.smoke.json) so the harness can be exercised cheaply without
touching the committed numbers; it exits nonzero if the engine paths
disagree on any final cost, if a patched ShardPlan diverges from a fresh
compile, if the 8-device retrace counts are off, or if the serving cell's
oracle parity / traffic-aware ordering gates fail.

``--check-parity`` re-runs the quick grids and exits nonzero if any cell's
final cost diverges from the committed BENCH_layout.json beyond 1e-12
relative, or the plan-patch cell's traffic accounting drifts — the CI gate
against silent cost regressions.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (adaptability, convergence, cost_comparison,
                        cost_factors, kernel_density, layout_engine,
                        overhead, plan_patch, roofline_table, sensitivity,
                        serving)

SECTIONS = [
    ("cost_comparison  (Fig. 8/9)", cost_comparison.run),
    ("cost_factors     (Fig. 10-13)", cost_factors.run),
    ("convergence      (Fig. 14/15)", convergence.run),
    ("adaptability     (Fig. 16)", adaptability.run),
    ("overhead         (Fig. 17/18)", overhead.run),
    ("sensitivity      (Fig. 19/20)", sensitivity.run),
    ("kernel_density   (ablation: layout -> MXU)", kernel_density.run),
    ("roofline_table   (deliverable g)", roofline_table.run),
    ("layout_engine    (engine vs seed, round solvers)", layout_engine.run),
    ("plan_patch       (incremental ShardPlan pipeline)", plan_patch.run),
    ("serving          (request-driven ego inference)", serving.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graphs (slow)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sanity pass (layout_engine quick, 1 rep, "
                         "separate output file; fails on cost mismatch)")
    ap.add_argument("--check-parity", action="store_true",
                    help="re-run the quick grid and fail if any final cost "
                         "diverges from the committed BENCH_layout.json")
    args = ap.parse_args()
    if args.check_parity:
        rc = layout_engine.check_parity()
        rc = plan_patch.check_parity() or rc
        rc = serving.check_parity() or rc
        sys.exit(rc)
    if args.smoke:
        print("\n===== smoke: layout_engine (quick, 1 rep) =====")
        t0 = time.perf_counter()
        rc = layout_engine.run(smoke=True)
        print(f"# smoke wall time: {time.perf_counter() - t0:.1f}s")
        print("\n===== smoke: plan_patch (quick, 1 rep) =====")
        t0 = time.perf_counter()
        rc = plan_patch.run(smoke=True) or rc
        print(f"# smoke wall time: {time.perf_counter() - t0:.1f}s")
        print("\n===== smoke: serving (quick) =====")
        t0 = time.perf_counter()
        rc = serving.run(smoke=True) or rc
        print(f"# smoke wall time: {time.perf_counter() - t0:.1f}s")
        sys.exit(rc or 0)
    for name, fn in SECTIONS:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        fn(full=args.full)
        print(f"# section wall time: {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
