"""Fig. 16: dynamic graph evolution over T time slots (GAT over Yelp,
10 servers, 1% link churn): No-Adjustment vs Greedy vs GLAD-E vs Adaptive
(GLAD-A), plus GLAD-A's algorithm invocations."""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, emit, fleet
from repro.core import CostModel, workload_for
from repro.core.baselines import greedy_layout
from repro.core.evolution import apply_delta, evolution_trace
from repro.core.glad_a import GladA
from repro.core.glad_e import glad_e
from repro.core.glad_s import glad_s


def run(full: bool = False, slots: int = 40, servers: int = 10,
        theta: float = 10.0):
    g0 = dataset("yelp", full)
    net = fleet(g0, servers)
    gnn = workload_for("gat", 100)

    cm0 = CostModel(net, g0, gnn)
    init = glad_s(cm0, R=3, seed=0)
    norm = init.cost

    sched = GladA(net, gnn, g0, theta=theta, R=3, seed=0)
    assign_na = init.assign.copy()
    assign_ge = init.assign.copy()
    prev_ge_graph = g0

    rows = []
    trace = evolution_trace(g0, slots, pct_links=0.01, seed=42)
    cur = g0
    for t, delta in enumerate(trace):
        cur = apply_delta(cur, delta)
        cm = CostModel(net, cur, gnn)
        # No adjustment: carry the initial layout forward.
        carried = np.zeros(cur.n, dtype=np.int64)
        carried[:min(len(assign_na), cur.n)] = \
            assign_na[:min(len(assign_na), cur.n)]
        c_na = cm.total(carried)
        # Greedy re-run every slot.
        c_gr = cm.total(greedy_layout(cm))
        # GLAD-E incremental.
        res_ge = glad_e(cm, prev_ge_graph, assign_ge, seed=t)
        assign_ge, prev_ge_graph = res_ge.assign, cur
        # Adaptive.
        rec = sched.step(cur)
        rows.append([t, cur.num_edges, round(c_na / norm, 4),
                     round(c_gr / norm, 4), round(res_ge.cost / norm, 4),
                     round(rec.cost / norm, 4), rec.algorithm])
    n_glads = sum(1 for r in rows if r[6] == "glad-s")
    print(f"# GLAD-A invoked GLAD-S {n_glads}/{slots} slots")
    return emit(rows, ["slot", "links", "no_adjust", "greedy", "glad_e",
                       "adaptive", "adaptive_algo"])


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv,
        slots=200 if "--full" in sys.argv else 40)
